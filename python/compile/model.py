"""L2 — decoder-only transformer LM whose hot GEMMs run through the
L1 Pallas kernel (`kernels.ficco_gemm.linear`, forward and backward).

This is the model the end-to-end driver trains (DESIGN.md §6): RMSNorm,
multi-head causal self-attention, SwiGLU-free GELU MLP, learned
positional embeddings, tied LM head, Adam. Everything is a pure
function of (params, opt state, batch) so `aot.py` can lower
`train_step` to a single HLO artifact the Rust runtime executes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import ficco_gemm


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq: int
    batch: int
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


PRESETS: Dict[str, Config] = {
    # Fast preset for pytest and smoke runs.
    "tiny": Config("tiny", vocab=512, d_model=64, n_layers=2, n_heads=4, seq=32, batch=4,
                   lr=1e-3),
    # Development-scale model.
    "small": Config("small", vocab=4096, d_model=256, n_layers=4, n_heads=8, seq=64, batch=8,
                    lr=6e-4),
    # The ~100M-parameter end-to-end validation model (DESIGN.md §6).
    "m100": Config("m100", vocab=16384, d_model=768, n_layers=12, n_heads=12, seq=128, batch=4),
}


def param_count(cfg: Config) -> int:
    d = cfg.d_model
    per_layer = 4 * d * d + 2 * d * 4 * d + 2 * d  # attn + mlp + norms
    return cfg.vocab * d + cfg.seq * d + cfg.n_layers * per_layer + d


def init_params(rng: jax.Array, cfg: Config) -> Dict[str, Any]:
    """Standard scaled-normal init. Pure function of the RNG key so it
    can be lowered to an `init` artifact."""
    d = cfg.d_model
    n = cfg.n_layers
    k_emb, k_pos, k_layers = jax.random.split(rng, 3)
    scale = d ** -0.5
    init = lambda key, shape, s: (jax.random.normal(key, shape, jnp.float32) * s)

    layers = []
    keys = jax.random.split(k_layers, n)
    for i in range(n):
        ks = jax.random.split(keys[i], 4)
        layers.append({
            "ln1": jnp.ones((d,), jnp.float32),
            "wqkv": init(ks[0], (d, 3 * d), scale),
            "wo": init(ks[1], (d, d), scale / (2 * n) ** 0.5),
            "ln2": jnp.ones((d,), jnp.float32),
            "wup": init(ks[2], (d, 4 * d), scale),
            "wdown": init(ks[3], (4 * d, d), scale / (2 * n) ** 0.5),
        })
    return {
        # GPT-2-style small embedding init; with the tied LM head this
        # puts the initial loss near ln(vocab).
        "embed": init(k_emb, (cfg.vocab, d), 0.02),
        "pos": init(k_pos, (cfg.seq, d), 0.02),
        "ln_f": jnp.ones((d,), jnp.float32),
        "layers": layers,
    }


def rmsnorm(x: jax.Array, g: jax.Array) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def _linear2d(x: jax.Array, w: jax.Array) -> jax.Array:
    """(…, d_in) @ (d_in, d_out) through the Pallas kernel."""
    lead = x.shape[:-1]
    flat = x.reshape((-1, x.shape[-1]))
    y = ficco_gemm.linear(flat, w)
    return y.reshape(lead + (w.shape[1],))


def attention(x: jax.Array, layer: Dict[str, Any], cfg: Config) -> jax.Array:
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    qkv = _linear2d(x, layer["wqkv"])  # (b, t, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / hd ** 0.5
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return _linear2d(out, layer["wo"])


def mlp(x: jax.Array, layer: Dict[str, Any]) -> jax.Array:
    return _linear2d(jax.nn.gelu(_linear2d(x, layer["wup"])), layer["wdown"])


def forward(params: Dict[str, Any], tokens: jax.Array, cfg: Config) -> jax.Array:
    """tokens (b, t) int32 → logits (b, t, vocab)."""
    x = params["embed"][tokens] + params["pos"][None, : tokens.shape[1]]
    for layer in params["layers"]:
        x = x + attention(rmsnorm(x, layer["ln1"]), layer, cfg)
        x = x + mlp(rmsnorm(x, layer["ln2"]), layer)
    x = rmsnorm(x, params["ln_f"])
    # Tied LM head through the Pallas kernel.
    return _linear2d(x, params["embed"].T)


def loss_fn(params, tokens, targets, cfg: Config) -> jax.Array:
    """Mean next-token cross-entropy (targets already shifted)."""
    logits = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


# ---------------------------------------------------------------- Adam

def init_opt(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, opt, cfg: Config):
    step = opt["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.beta1 ** t
    bc2 = 1.0 - cfg.beta2 ** t

    def upd(p, g, m, v):
        m2 = cfg.beta1 * m + (1 - cfg.beta1) * g
        v2 = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        p2 = p - cfg.lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        return p2, m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(opt["m"])[0]
    flat_v = jax.tree_util.tree_flatten(opt["v"])[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params2 = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    m2 = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    v2 = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return params2, {"m": m2, "v": v2, "step": step}


def train_step(params, opt, tokens, targets, cfg: Config) -> Tuple[Any, Any, jax.Array]:
    """One fwd+bwd+Adam step. Lowered whole by aot.py."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens, targets, cfg))(params)
    params2, opt2 = adam_update(params, grads, opt, cfg)
    return params2, opt2, loss


# -------------------------------------------------- flattening helpers
# The Rust runtime passes buffers positionally; the manifest records
# this exact order (jax tree flatten order: dict keys sorted).

def flatten_state(params, opt):
    flat, treedef = jax.tree_util.tree_flatten((params, opt))
    return flat, treedef


def state_spec(cfg: Config):
    """Shapes/dtypes of the flattened (params, opt) state without
    materializing it."""
    shaped = jax.eval_shape(
        lambda key: _init_state(key, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    flat, _ = jax.tree_util.tree_flatten(shaped)
    return flat


def _init_state(key, cfg: Config):
    params = init_params(key, cfg)
    return params, init_opt(params)


def init_state(key, cfg: Config):
    return _init_state(key, cfg)
