"""AOT compile path: lower the L2/L1 computations to HLO **text**
artifacts the Rust runtime loads via PJRT.

Artifacts produced (see `artifacts/manifest.txt` after `make artifacts`):

- ``init_<preset>``        — uint32[2] PRNG key → flattened (params, opt)
- ``train_step_<preset>``  — flattened state + tokens/targets → new state + loss
- ``fwd_<preset>``         — flattened params + tokens → logits (inference)
- ``pallas_gemm_*``        — the L1 Pallas kernels at the shapes the
  coordinator's numeric schedule validation uses (plain + accumulate)

Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts [--presets tiny,small,m100]``
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ficco_gemm

# Validation GEMM geometry: the default used by `ficco validate`
# (rust/src/coordinator). Shapes for the full GEMM, the shard-level
# pieces, the FiCCO pieces, and the 2D K-blocks all derive from it.
VALIDATE_M, VALIDATE_N, VALIDATE_K, VALIDATE_G = 256, 128, 192, 8


def to_hlo_text(fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_str(s) -> str:
    """'f32:256x192' — parsed by rust/src/runtime/manifest.rs."""
    dt = {"float32": "f32", "int32": "i32", "uint32": "u32"}[str(s.dtype)]
    dims = "x".join(str(d) for d in s.shape) if s.shape else "scalar"
    return f"{dt}:{dims}"


class Writer:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.records = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, in_specs, out_specs):
        t0 = time.time()
        text = to_hlo_text(fn, *in_specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        ins = ",".join(spec_str(s) for s in in_specs)
        outs = ",".join(spec_str(s) for s in out_specs)
        self.records.append(f"{name}\t{fname}\t{ins}\t{outs}")
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s")

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.txt")
        with open(path, "w") as f:
            f.write("# name\tfile\tinputs\toutputs\n")
            f.write("\n".join(self.records) + "\n")
        print(f"wrote {path} ({len(self.records)} artifacts)")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def emit_model(w: Writer, preset: str):
    cfg = model.PRESETS[preset]
    print(f"preset {preset}: ~{model.param_count(cfg) / 1e6:.1f}M params")
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    state_flat = model.state_spec(cfg)

    # init: key -> flat state
    def init_fn(key_data):
        key = jax.random.wrap_key_data(key_data)
        params, opt = model.init_state(key, cfg)
        return tuple(jax.tree_util.tree_flatten((params, opt))[0])

    w.emit(f"init_{preset}", init_fn, [key_spec], state_flat)

    # train_step: flat state + tokens + targets -> flat state + loss
    _, treedef = jax.tree_util.tree_flatten(
        jax.eval_shape(lambda k: model.init_state(k, cfg), key_spec)
    )

    def step_fn(*args):
        flat = args[: len(state_flat)]
        tokens, targets = args[len(state_flat) :]
        params, opt = jax.tree_util.tree_unflatten(treedef, list(flat))
        params2, opt2, loss = model.train_step(params, opt, tokens, targets, cfg)
        return tuple(jax.tree_util.tree_flatten((params2, opt2))[0]) + (loss,)

    tok = i32(cfg.batch, cfg.seq)
    w.emit(
        f"train_step_{preset}",
        step_fn,
        list(state_flat) + [tok, tok],
        list(state_flat) + [f32()],
    )

    # fwd: params + tokens -> logits (serving / eval path)
    n_params = len(jax.tree_util.tree_flatten(
        jax.eval_shape(lambda k: model.init_params(k, cfg), key_spec))[0])
    params_flat = state_flat[:0]  # placeholder; recompute properly below
    params_shaped = jax.eval_shape(lambda k: model.init_params(k, cfg), key_spec)
    params_flat, params_treedef = jax.tree_util.tree_flatten(params_shaped)
    assert len(params_flat) == n_params

    def fwd_fn(*args):
        flat = args[:-1]
        tokens = args[-1]
        params = jax.tree_util.tree_unflatten(params_treedef, list(flat))
        return (model.forward(params, tokens, cfg),)

    w.emit(
        f"fwd_{preset}",
        fwd_fn,
        list(params_flat) + [tok],
        [f32(cfg.batch, cfg.seq, cfg.vocab)],
    )


def split(total: int, parts: int, i: int) -> tuple[int, int]:
    """Balanced split — MUST match rust/src/schedule/generate.rs."""
    return (i * total // parts, (i + 1) * total // parts)


def emit_validation_gemms(w: Writer):
    """The L1 kernels at every shape the coordinator's numeric
    validation of the FiCCO schedules needs (DESIGN.md §3)."""
    m, n, k, g = VALIDATE_M, VALIDATE_N, VALIDATE_K, VALIDATE_G
    shard = split(m, g, 0)[1] - split(m, g, 0)[0]
    piece = split(shard, g, 0)[1] - split(shard, g, 0)[0]
    kblock = split(k, g, 0)[1] - split(k, g, 0)[0]
    hetero = shard - piece  # (g-1) pieces fused

    plain_shapes = sorted({
        (m, k),          # baseline full GEMM
        (shard, k),      # shard-overlap / uniform-fused-1D step
        (piece, k),      # hetero-unfused-1D piece
        (hetero, k),     # hetero-fused-1D step
    })
    for (mm, kk) in plain_shapes:
        name = f"pallas_gemm_{mm}x{n}x{kk}"
        w.emit(
            name,
            lambda a, b: (ficco_gemm.matmul(a, b),),
            [f32(mm, kk), f32(kk, n)],
            [f32(mm, n)],
        )
    # 2D accumulate step: C += A[:, kblock] @ B[kblock, :]
    w.emit(
        f"pallas_gemm_acc_{m}x{n}x{kblock}",
        lambda c, a, b: (ficco_gemm.matmul_accumulate(c, a, b),),
        [f32(m, n), f32(m, kblock), f32(kblock, n)],
        [f32(m, n)],
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small,m100")
    args = ap.parse_args()

    w = Writer(args.out_dir)
    emit_validation_gemms(w)
    for preset in [p for p in args.presets.split(",") if p]:
        emit_model(w, preset)
    w.finish()


if __name__ == "__main__":
    main()
