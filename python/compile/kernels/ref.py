"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest asserts allclose between these and `ficco_gemm`)."""

import jax
import jax.numpy as jnp


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def matmul_accumulate(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    return c + jnp.dot(a, b, preferred_element_type=jnp.float32)


def decomposed_row_sharded(a, b, ways: int):
    """FiCCO 1D semantics: row-shard A, GEMM each piece, concatenate.
    Must equal the whole GEMM exactly (modulo float reassociation —
    none here, since row sharding never splits the reduction)."""
    pieces = jnp.split(a, ways, axis=0)
    return jnp.concatenate([matmul(p, b) for p in pieces], axis=0)


def decomposed_col_sharded(a, b, ways: int):
    """FiCCO 2D semantics: column-shard A (and row-shard B), accumulate
    partial GEMMs. Splits the reduction, so comparisons use a float
    tolerance."""
    a_pieces = jnp.split(a, ways, axis=1)
    b_pieces = jnp.split(b, ways, axis=0)
    c = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
    for ap, bp in zip(a_pieces, b_pieces):
        c = matmul_accumulate(c, ap, bp)
    return c
