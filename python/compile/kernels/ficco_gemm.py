"""L1 — FiCCO's compute primitive as Pallas kernels.

The paper's unit of compute is a (possibly partial, possibly
accumulating) GEMM over a finer-grain shard: ``C (+)= A_piece @ B``
(§V). On the paper's GPUs that is a hipblaslt kernel; here it is
re-expressed for a TPU-like machine (DESIGN.md §2, Hardware
Adaptation):

- tiles sized for VMEM and the MXU's 128x128 systolic array;
- the grid's K axis plays the role of FiCCO's column (2D) decomposition:
  each K-step accumulates into the output block, exactly the dataflow
  the uniform-fused-2D schedule needs;
- the grid's M axis corresponds to row (1D) decomposition.

Kernels are lowered with ``interpret=True`` — the CPU PJRT client
cannot execute Mosaic custom-calls, and correctness is what the AOT
path certifies (real-TPU performance is estimated analytically in
EXPERIMENTS.md §Perf from VMEM footprint and MXU utilization).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned preferred tile extents, largest first. `_pick_block`
# returns the largest one that divides the dimension, so awkward shapes
# stay correct (smaller tiles, as a real kernel's tail handling would).
_PREFERRED = (512, 256, 128, 64, 32, 16, 8, 4, 2, 1)


def _pick_block(dim: int, cap: int) -> int:
    for b in _PREFERRED:
        if b <= cap and dim % b == 0:
            return b
    return 1


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output block; K-grid accumulation in f32."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 512, bn: int = 512, bk: int = 512):
    """``a @ b`` via the tiled Pallas kernel (f32 accumulation).

    Block caps (bm, bn, bk) bound VMEM footprint; actual blocks are the
    largest preferred extents dividing each dimension.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def _matmul_acc_kernel(c_ref, a_ref, b_ref, o_ref):
    """Accumulating block: ``o = c + a @ b`` with K-grid accumulation.

    This is the 2D-schedule primitive: the caller holds a partial C
    (earlier K blocks of the global reduction) and folds in one more
    decomposed K block.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _seed():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_accumulate(
    c: jax.Array, a: jax.Array, b: jax.Array, *, bm: int = 512, bn: int = 512, bk: int = 512
):
    """``c + a @ b`` (the paper's accumulative GEMM for column sharding)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert c.shape == (m, n), f"accumulator shape {c.shape} != ({m}, {n})"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(c, a, b)


@jax.custom_vjp
def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable ``x @ w`` whose forward AND backward GEMMs run
    through the Pallas kernel — so the lowered training step exercises
    the L1 kernel on every hot matmul of fwd and bwd."""
    return matmul(x, w)


def _linear_fwd(x, w):
    return matmul(x, w), (x, w)


def _linear_bwd(res, g):
    x, w = res
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    return dx.astype(x.dtype), dw.astype(w.dtype)


linear.defvjp(_linear_fwd, _linear_bwd)


def vmem_footprint(m: int, n: int, k: int, *, bm: int = 512, bn: int = 512, bk: int = 512,
                   elem_bytes: int = 4) -> dict:
    """Static VMEM/MXU estimate for EXPERIMENTS.md §Perf: bytes resident
    per grid step and the MXU utilization bound from tile geometry."""
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    a = bm * bk * elem_bytes
    b = bk * bn * elem_bytes
    c = bm * bn * 4  # f32 accumulator
    # MXU is a 128x128 systolic array: utilization limited by how the
    # block tiles map onto it.
    mxu = min(bm, 128) * min(bn, 128) / (128.0 * 128.0)
    return {
        "block": (bm, bn, bk),
        "vmem_bytes": a + b + c,
        "mxu_tile_utilization": mxu,
        "grid": (m // bm, n // bn, k // bk),
    }
