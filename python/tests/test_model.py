"""L2 correctness: transformer shapes, differentiability, training
signal, and optimizer behaviour (all on the tiny preset)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

CFG = model.PRESETS["tiny"]


@pytest.fixture(scope="module")
def state():
    return model.init_state(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def batch():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    tokens = jax.random.randint(k1, (CFG.batch, CFG.seq), 0, CFG.vocab)
    targets = jax.random.randint(k2, (CFG.batch, CFG.seq), 0, CFG.vocab)
    return tokens, targets


def test_param_count_matches_structure(state):
    params, _ = state
    total = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert total == model.param_count(CFG)


def test_forward_shapes(state, batch):
    params, _ = state
    logits = model.forward(params, batch[0], CFG)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform(state, batch):
    params, _ = state
    loss = model.loss_fn(params, batch[0], batch[1], CFG)
    expected = np.log(CFG.vocab)
    assert abs(float(loss) - expected) < 0.5, f"loss {loss} vs ln(V) {expected}"


def test_causality(state):
    """Changing a future token must not affect earlier logits."""
    params, _ = state
    tokens = jnp.zeros((1, CFG.seq), jnp.int32)
    la = model.forward(params, tokens, CFG)
    lb = model.forward(params, tokens.at[0, -1].set(5), CFG)
    np.testing.assert_allclose(la[0, :-1], lb[0, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(la[0, -1], lb[0, -1])


def test_loss_decreases_over_steps(state, batch):
    params, opt = state
    tokens, targets = batch
    step = jax.jit(lambda p, o: model.train_step(p, o, tokens, targets, CFG))
    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, f"no learning: {losses}"
    assert all(np.isfinite(l) for l in losses)


def test_adam_step_counter(state, batch):
    params, opt = state
    p2, o2, _ = model.train_step(params, opt, batch[0], batch[1], CFG)
    assert int(o2["step"]) == int(opt["step"]) + 1


def test_grads_flow_to_all_params(state, batch):
    params, _ = state
    grads = jax.grad(lambda p: model.loss_fn(p, batch[0], batch[1], CFG))(params)
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        norm = float(jnp.linalg.norm(g))
        assert norm > 0, f"dead gradient at {jax.tree_util.keystr(path)}"


def test_state_spec_matches_real_state(state):
    flat, _ = jax.tree_util.tree_flatten(state)
    spec = model.state_spec(CFG)
    assert len(flat) == len(spec)
    for got, want in zip(flat, spec):
        assert got.shape == want.shape, (got.shape, want.shape)
        assert got.dtype == want.dtype


def test_deterministic_init():
    a = model.init_state(jax.random.PRNGKey(7), CFG)
    b = model.init_state(jax.random.PRNGKey(7), CFG)
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(x, y)


def test_presets_sane():
    assert model.param_count(model.PRESETS["m100"]) > 90e6
    assert model.param_count(model.PRESETS["m100"]) < 120e6
    for cfg in model.PRESETS.values():
        assert cfg.d_model % cfg.n_heads == 0
