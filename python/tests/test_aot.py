"""AOT path: manifest structure, HLO text well-formedness, and the
split-boundary contract shared with the Rust schedule generators."""

import os

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.txt")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def parse_manifest():
    records = {}
    with open(MANIFEST) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            name, fname, ins, outs = line.split("\t")
            records[name] = (fname, ins.split(","), outs.split(","))
    return records


def test_manifest_covers_presets():
    rec = parse_manifest()
    for preset in ["tiny", "small", "m100"]:
        for kind in ["init", "train_step", "fwd"]:
            assert f"{kind}_{preset}" in rec, f"missing {kind}_{preset}"


def test_manifest_files_exist_and_are_hlo_text():
    rec = parse_manifest()
    for name, (fname, _, _) in rec.items():
        path = os.path.join(ART, fname)
        assert os.path.exists(path), path
        head = open(path).read(4096)
        assert "HloModule" in head, f"{name}: not HLO text"
        assert "ENTRY" in open(path).read(), f"{name}: no ENTRY computation"


def test_train_step_arity():
    rec = parse_manifest()
    cfg = model.PRESETS["tiny"]
    n_state = len(model.state_spec(cfg))
    _, ins, outs = rec["train_step_tiny"]
    assert len(ins) == n_state + 2  # + tokens + targets
    assert len(outs) == n_state + 1  # + loss
    assert outs[-1] == "f32:scalar"
    assert ins[-1] == f"i32:{cfg.batch}x{cfg.seq}"


def test_validation_gemm_artifacts_present():
    rec = parse_manifest()
    m, n, k, g = aot.VALIDATE_M, aot.VALIDATE_N, aot.VALIDATE_K, aot.VALIDATE_G
    shard = m // g
    piece = shard // g
    for mm in [m, shard, piece, shard - piece]:
        assert f"pallas_gemm_{mm}x{n}x{k}" in rec
    assert f"pallas_gemm_acc_{m}x{n}x{k // g}" in rec


def test_split_matches_rust_contract():
    """aot.split must agree with rust/src/schedule/generate.rs::split
    (balanced floor split) — spot values mirrored from the Rust tests."""
    assert aot.split(1000, 3, 0) == (0, 333)
    assert aot.split(1000, 3, 1) == (333, 666)
    assert aot.split(1000, 3, 2) == (666, 1000)
    # exact partition for awkward sizes
    for total in [1, 7, 100, 4097]:
        for parts in [1, 3, 8]:
            prev = 0
            for i in range(parts):
                lo, hi = aot.split(total, parts, i)
                assert lo == prev
                prev = hi
            assert prev == total


def test_spec_str_format():
    import jax.numpy as jnp
    import jax

    assert aot.spec_str(jax.ShapeDtypeStruct((2, 3), jnp.float32)) == "f32:2x3"
    assert aot.spec_str(jax.ShapeDtypeStruct((), jnp.float32)) == "f32:scalar"
    assert aot.spec_str(jax.ShapeDtypeStruct((5,), jnp.int32)) == "i32:5"
