"""L1 correctness: the Pallas kernels vs the pure-jnp oracle.

This is the core build-time correctness signal — hypothesis sweeps
shapes (including awkward non-power-of-two dims) and dtypes, asserting
allclose against `ref.py`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ficco_gemm, ref

TOL = dict(rtol=1e-4, atol=1e-4)
TOL16 = dict(rtol=2e-2, atol=2e-2)


def rand(shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


class TestMatmulBasic:
    def test_square(self):
        a, b = rand((64, 64), seed=1), rand((64, 64), seed=2)
        np.testing.assert_allclose(ficco_gemm.matmul(a, b), ref.matmul(a, b), **TOL)

    def test_rectangular(self):
        a, b = rand((128, 32), seed=3), rand((32, 256), seed=4)
        np.testing.assert_allclose(ficco_gemm.matmul(a, b), ref.matmul(a, b), **TOL)

    def test_vector_like(self):
        a, b = rand((1, 96), seed=5), rand((96, 7), seed=6)
        np.testing.assert_allclose(ficco_gemm.matmul(a, b), ref.matmul(a, b), **TOL)

    def test_odd_dims(self):
        a, b = rand((33, 17), seed=7), rand((17, 5), seed=8)
        np.testing.assert_allclose(ficco_gemm.matmul(a, b), ref.matmul(a, b), **TOL)

    def test_bf16_inputs(self):
        a = rand((64, 48), jnp.bfloat16, seed=9)
        b = rand((48, 32), jnp.bfloat16, seed=10)
        out = ficco_gemm.matmul(a, b)
        assert out.dtype == jnp.float32  # f32 accumulation
        np.testing.assert_allclose(out, ref.matmul(a, b), **TOL16)

    def test_block_caps_do_not_change_result(self):
        a, b = rand((256, 192), seed=11), rand((192, 128), seed=12)
        full = ficco_gemm.matmul(a, b, bm=512, bn=512, bk=512)
        tiled = ficco_gemm.matmul(a, b, bm=32, bn=32, bk=16)
        np.testing.assert_allclose(full, tiled, **TOL)


class TestAccumulate:
    def test_basic(self):
        c = rand((64, 32), seed=13)
        a, b = rand((64, 48), seed=14), rand((48, 32), seed=15)
        np.testing.assert_allclose(
            ficco_gemm.matmul_accumulate(c, a, b), ref.matmul_accumulate(c, a, b), **TOL
        )

    def test_chained_accumulation_equals_full_gemm(self):
        """The 2D schedule invariant: accumulating over K blocks equals
        the undecomposed GEMM (within reassociation tolerance)."""
        a, b = rand((96, 128), seed=16), rand((128, 64), seed=17)
        ways = 8
        c = jnp.zeros((96, 64), jnp.float32)
        for ap, bp in zip(jnp.split(a, ways, axis=1), jnp.split(b, ways, axis=0)):
            c = ficco_gemm.matmul_accumulate(c, ap, bp)
        np.testing.assert_allclose(c, ref.matmul(a, b), **TOL)


class TestLinearVjp:
    def test_forward(self):
        a, b = rand((48, 40), seed=18), rand((40, 24), seed=19)
        np.testing.assert_allclose(ficco_gemm.linear(a, b), ref.matmul(a, b), **TOL)

    def test_gradients_match_jnp(self):
        a, b = rand((48, 40), seed=20), rand((40, 24), seed=21)
        g = jax.grad(lambda x, w: (ficco_gemm.linear(x, w) ** 2).sum(), argnums=(0, 1))(a, b)
        gr = jax.grad(lambda x, w: ((x @ w) ** 2).sum(), argnums=(0, 1))(a, b)
        np.testing.assert_allclose(g[0], gr[0], **TOL)
        np.testing.assert_allclose(g[1], gr[1], **TOL)


class TestFiccoDecompositionSemantics:
    """The schedule-level numeric invariants the Rust coordinator also
    checks, proven at the kernel level here."""

    def test_row_sharding_exact(self):
        # Mathematically exact (no reduction split), but XLA's dot may
        # still reblock K differently per shape — float tolerance.
        a, b = rand((128, 64), seed=22), rand((64, 32), seed=23)
        np.testing.assert_allclose(
            ref.decomposed_row_sharded(a, b, 8), ref.matmul(a, b), **TOL
        )

    def test_col_sharding_close(self):
        a, b = rand((64, 128), seed=24), rand((128, 32), seed=25)
        np.testing.assert_allclose(
            ref.decomposed_col_sharded(a, b, 8), ref.matmul(a, b), **TOL
        )


# ------------------------------------------------------ hypothesis sweeps

dims = st.integers(min_value=1, max_value=96)


@settings(max_examples=40, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_any_shape(m, k, n, seed):
    a, b = rand((m, k), seed=seed), rand((k, n), seed=seed + 1)
    np.testing.assert_allclose(ficco_gemm.matmul(a, b), ref.matmul(a, b), **TOL)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_accumulate_matches_ref_any_shape(m, k, n, seed):
    c = rand((m, n), seed=seed + 2)
    a, b = rand((m, k), seed=seed), rand((k, n), seed=seed + 1)
    np.testing.assert_allclose(
        ficco_gemm.matmul_accumulate(c, a, b), ref.matmul_accumulate(c, a, b), **TOL
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 64),
    k=dims,
    n=dims,
    ways=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_row_decomposition_invariant(m, k, n, ways, seed):
    m = m * ways  # divisible
    a, b = rand((m, k), seed=seed), rand((k, n), seed=seed + 1)
    got = jnp.concatenate(
        [ficco_gemm.matmul(p, b) for p in jnp.split(a, ways, axis=0)], axis=0
    )
    np.testing.assert_allclose(got, ref.matmul(a, b), **TOL)


@settings(max_examples=20, deadline=None)
@given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    m=st.sampled_from([16, 32, 64]),
    k=st.sampled_from([16, 48, 128]),
    n=st.sampled_from([8, 32, 96]),
)
def test_dtype_sweep(dtype, m, k, n):
    a, b = rand((m, k), dtype, seed=m), rand((k, n), dtype, seed=n)
    tol = TOL if dtype == jnp.float32 else TOL16
    out = ficco_gemm.matmul(a, b)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(out, ref.matmul(a, b), **tol)


def test_vmem_footprint_reporting():
    info = ficco_gemm.vmem_footprint(512, 512, 1024)
    bm, bn, bk = info["block"]
    assert 512 % bm == 0 and 512 % bn == 0 and 1024 % bk == 0
    assert info["vmem_bytes"] <= 16 << 20, "blocks must fit VMEM"
    assert 0 < info["mxu_tile_utilization"] <= 1.0


def test_footprint_small_dims_low_mxu():
    info = ficco_gemm.vmem_footprint(4, 512, 512)
    assert info["mxu_tile_utilization"] < 0.1
