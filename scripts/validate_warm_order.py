#!/usr/bin/env python3
"""Property-validate the warm-started search ordering (ISSUE 8).

A faithful python port of `search_in`'s exhaustive branch
(rust/src/search/mod.rs) — cold enumeration-order walk vs the
warm-started best-bound-first walk with seed phase, carried cell
incumbent, and sorted-tail mass prune — exercised over thousands of
randomized synthetic plan spaces with heavy float-equal makespan ties.

Synthetic evaluator: each "plan" is an integer; its makespan is a
deterministic quantized hash (quantization manufactures exact-tie
collisions, the adversarial case for ordering changes) and its bound a
deterministic fraction of the makespan (sometimes exactly tight,
another adversarial case for the 1+1e-9 margin).

Checked on every trial:

 1. warm and cold report the bitwise-identical best (plan id and
    makespan), for every predicted-seed shape (none / preset /
    in-space / out-of-space);
 2. evaluated + pruned partition the same deduped candidate universe;
 3. warm never simulates more candidates than cold (plus at most the
    one unconditional predicted seed);
 4. warm's evaluated set is contained in cold's (plus the seed) — the
    ordering-theorem set inclusion, not just the count;
 5. every warm-pruned candidate is strictly worse than the final best
    (the tail cut never discards a potential tie);
 6. a carried cell incumbent (any candidate's makespan, as
    `tune_cell_in` carries) changes neither the result bits nor the
    evaluated set.

Exit 0 with a summary line on success; assertion failure otherwise.
"""

import hashlib
import random
import struct
import sys

MARGIN = 1.0 + 1e-9
PRESETS = 6


def bits(x):
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def h(plan, salt, space_seed):
    d = hashlib.sha256(f"{space_seed}:{salt}:{plan}".encode()).digest()
    return int.from_bytes(d[:8], "little")


def makespan(plan, space_seed, tie_quantum):
    # Quantized so distinct plans collide on exact float equality.
    return 1.0 + (h(plan, "ms", space_seed) % tie_quantum) / 8.0


def bound(plan, space_seed, tie_quantum):
    ms = makespan(plan, space_seed, tie_quantum)
    r = h(plan, "lb", space_seed) % 100
    if r < 20:
        return ms  # exactly tight bound
    return ms * (0.3 + 0.7 * (r / 100.0))


class Walk:
    """One search over (presets, space): mirrors search_in exactly."""

    def __init__(self, presets, space, space_seed, tie_quantum):
        self.space_seed = space_seed
        self.tie_quantum = tie_quantum
        self.presets = presets
        self.space = space
        self.evaluated = []  # plan ids, in visit order
        self.pruned = []
        # Preset phase (both modes identical).
        self.seen = []
        self.inc_ms = None
        self.inc_plan = None
        self.inc_canon = None
        for i, p in enumerate(presets):
            self.seen.append(p)
            self._eval(p, i)
        # Deduped space with canonical enumeration indices.
        self.pending = []
        canon = PRESETS
        for p in space:
            if p in self.seen:
                continue
            self.seen.append(p)
            self.pending.append((canon, p))
            canon += 1

    def _eval(self, plan, canon):
        ms = makespan(plan, self.space_seed, self.tie_quantum)
        self.evaluated.append(plan)
        self._offer(plan, ms, canon)
        return ms

    def _offer(self, plan, ms, canon):
        if self.inc_ms is None or ms < self.inc_ms or (ms == self.inc_ms and canon < self.inc_canon):
            self.inc_ms, self.inc_plan, self.inc_canon = ms, plan, canon

    def cold(self):
        for canon, p in self.pending:
            cutoff = self.inc_ms * MARGIN
            if bound(p, self.space_seed, self.tie_quantum) > cutoff:
                self.pruned.append(p)
            else:
                self._eval(p, canon)
        return self

    def warm(self, predicted=None, carried=None):
        # Seed phase: the predicted plan, iff it is a pending space
        # member (presets are already evaluated; anything else ignored).
        pos = next((i for i, (_, p) in enumerate(self.pending) if p == predicted), None)
        if pos is not None:
            canon, p = self.pending.pop(pos)
            self._eval(p, canon)
        # Carried incumbent: only when its plan is a candidate here.
        carried_ms = float("inf")
        if carried is not None and carried in self.seen:
            carried_ms = makespan(carried, self.space_seed, self.tie_quantum)
        # Order phase: ascending (bound, canon).
        ordered = sorted(
            ((bound(p, self.space_seed, self.tie_quantum), canon, p) for canon, p in self.pending),
            key=lambda t: (t[0], t[1]),
        )
        # Walk phase with the sorted-tail mass prune.
        for i, (b, canon, p) in enumerate(ordered):
            cutoff = min(self.inc_ms, carried_ms) * MARGIN
            if b > cutoff:
                self.pruned.extend(p for _, _, p in ordered[i:])
                break
            self._eval(p, canon)
        return self


def random_trial(rng, trial):
    space_seed = trial
    tie_quantum = rng.choice([4, 8, 16, 64])  # smaller = more exact ties
    universe = rng.randrange(1_000_000)
    presets = [universe * 1000 + i for i in range(PRESETS)]
    n_space = rng.randrange(0, 60)
    space = []
    for _ in range(n_space):
        roll = rng.random()
        if roll < 0.1 and space:
            space.append(rng.choice(space))  # duplicate
        elif roll < 0.2:
            space.append(rng.choice(presets))  # preset re-enumerated
        else:
            space.append(universe * 1000 + 100 + rng.randrange(200))
    mk = lambda: Walk(presets, list(space), space_seed, tie_quantum)

    cold = mk().cold()
    total = len(cold.evaluated) + len(cold.pruned)
    best = (cold.inc_plan, bits(cold.inc_ms))

    # Predicted-seed shapes: none, a preset, an in-space member, a
    # stranger; carried shapes: none, the optimum, a random candidate.
    preds = [None, rng.choice(presets), universe * 1000 + 999_999]
    if space:
        preds.append(rng.choice(space))
    carrieds = [None, cold.inc_plan] + ([rng.choice(space)] if space else [])
    for pred in preds:
        for carried in carrieds:
            w = mk().warm(predicted=pred, carried=carried)
            name = f"trial {trial} pred={pred} carried={carried}"
            assert (w.inc_plan, bits(w.inc_ms)) == best, f"{name}: best diverged"
            assert len(w.evaluated) + len(w.pruned) == total, f"{name}: universe split"
            seeded = pred is not None and pred in w.evaluated[PRESETS : PRESETS + 1]
            slack = 1 if seeded else 0
            assert len(w.evaluated) <= len(cold.evaluated) + slack, (
                f"{name}: warm simulated more ({len(w.evaluated)} vs {len(cold.evaluated)})"
            )
            extra = set(w.evaluated) - set(cold.evaluated)
            assert extra <= ({pred} if pred is not None else set()), (
                f"{name}: warm evaluated outside cold's set: {extra}"
            )
            for p in w.pruned:
                ms = makespan(p, space_seed, tie_quantum)
                assert ms > w.inc_ms, f"{name}: pruned a potential tie/best ({p}: {ms})"
            if carried is not None and pred is None:
                plain = mk().warm()
                assert set(plain.evaluated) == set(w.evaluated), (
                    f"{name}: carried incumbent changed the evaluated set"
                )
    return total


def main():
    trials = int(sys.argv[1]) if len(sys.argv) > 1 else 3000
    rng = random.Random(20260808)
    candidates = 0
    for trial in range(trials):
        candidates += random_trial(rng, trial)
    print(
        f"validate_warm_order: OK — {trials} randomized spaces "
        f"({candidates} candidates), warm bitwise-identical to cold, "
        "never more simulations, ties never pruned"
    )


if __name__ == "__main__":
    main()
