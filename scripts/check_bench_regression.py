#!/usr/bin/env python3
"""Gate a fresh perf_hotpath run against the committed BENCH_hotpath.json.

Usage: check_bench_regression.py COMMITTED_JSON FRESH_JSON

Rules (ISSUE 6/7/8, CI `sim-differential` job):

- Every measurement section present in the committed baseline must
  also be present in the fresh run — a candidate that silently drops a
  gated block (e.g. a bench refactor losing the `search` section) is a
  loud failure, not a skipped gate.
- The fresh run must be structurally sound: the tune-cell and
  fair-sharing sections present, evaluations/sec positive, and the
  incremental fair-sharing path not slower than the kept-verbatim
  from-scratch recompute measured in the same run (small noise
  allowance for --quick CI boxes).
- ISSUE 8: when the fresh run carries a `search` section, the
  relational gates are always on (they compare numbers measured within
  one process, so no baseline is needed): the warm-started walk must
  simulate strictly fewer candidates than the cold enumeration-order
  walk, prune at least as large a fraction, and report the bitwise
  identical best plan.
- If the committed snapshot is a real rust-bench measurement (no
  "provenance" marker; positive throughput numbers), apply the 20%
  regression rule: fresh evaluations/sec must be at least 0.8x the
  committed value, for the tune cell, the incremental fair-sharing
  figure, and the warm-search figure.
- If the committed snapshot is marked with a "provenance" note,
  absolute throughputs are not comparable across harnesses: skip the
  absolute gates, say so, and remind the committer to refresh the
  baseline with a rust-provenance run.
- ISSUE 7: when the fresh run carries a "recorder" section, the
  TimelineRecorder overhead on `run_full` must stay within 1.5x of
  the recorder-off run.
- ISSUE 9: when the fresh run carries a "robust" section (and the
  committed baseline has one, so a bench refactor dropping it fails
  loudly via the section-presence rule above), the relational gates
  arm: ensemble-eval throughput must be positive, the robust pick
  deterministic in-process, and the per-ensemble-evaluation cost must
  stay within 3x the nominal search's per-candidate cost measured in
  the same run (ensemble members re-lower the same plan, so a member
  eval should cost about one nominal eval, not a fresh search).
- ISSUE 10: when the fresh run carries a "stepper" section, the
  relational gates arm: the step-per-event replay must be bit-identical
  to the one-shot run (replay_matches_one_shot), step throughput must
  be positive, and driving one step per event must stay within 1.5x of
  the one-shot run_lean measured in the same run (the stepper adds one
  scratch hand-off per event, nothing more).

Exit 0 on pass, 1 on any gate failure.
"""

import json
import sys


def fail(msg):
    print(f"BENCH REGRESSION: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    if len(sys.argv) != 3:
        fail(f"usage: {sys.argv[0]} COMMITTED_JSON FRESH_JSON")
    with open(sys.argv[1]) as f:
        committed = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)

    # A gated block committed in the baseline must not vanish from the
    # candidate: dropping a section would otherwise read as "gate
    # skipped" instead of "metric lost".
    for key, value in committed.items():
        if isinstance(value, dict) and key not in fresh:
            fail(
                f"committed baseline has a '{key}' section but the fresh "
                "run does not — the bench lost a gated metric"
            )

    # Structural soundness of the fresh run.
    for section in ("tune_cell", "fair_sharing"):
        if section not in fresh:
            fail(f"fresh run is missing the '{section}' section")
    fresh_eps = fresh["tune_cell"].get("evals_per_sec", 0.0)
    if not fresh_eps > 0.0:
        fail(f"fresh tune-cell evals_per_sec is {fresh_eps}")
    fs = fresh["fair_sharing"]
    for key in ("slow_evals_per_sec", "incremental_evals_per_sec", "speedup_vs_slow"):
        if not fs.get(key, 0.0) > 0.0:
            fail(f"fresh fair_sharing.{key} is {fs.get(key)}")

    # The incremental path must never lose to the from-scratch
    # recompute it replaces (0.95 allows --quick timer noise).
    if fs["speedup_vs_slow"] < 0.95:
        fail(
            "incremental fair sharing is slower than the from-scratch "
            f"recompute: speedup_vs_slow = {fs['speedup_vs_slow']:.3f}"
        )

    # Warm-search ordering gates (ISSUE 8). All relational: measured
    # within the fresh run, so they arm with no baseline at all.
    search = fresh.get("search")
    if search is not None:
        for key in ("warm_evals_per_sec", "cold_evals_per_sec"):
            if not search.get(key, 0.0) > 0.0:
                fail(f"fresh search.{key} is {search.get(key)}")
        warm_ev = search.get("warm_evaluated", 0)
        cold_ev = search.get("cold_evaluated", 0)
        if not (0 < warm_ev < cold_ev):
            fail(
                "warm ordering must simulate strictly fewer candidates than "
                f"the cold enumeration walk: warm {warm_ev} vs cold {cold_ev}"
            )
        if search.get("warm_pruned_fraction", 0.0) < search.get("cold_pruned_fraction", 0.0):
            fail(
                "warm ordering pruned a smaller fraction than cold: "
                f"{search.get('warm_pruned_fraction')} vs "
                f"{search.get('cold_pruned_fraction')}"
            )
        if search.get("best_agrees_bitwise") is not True:
            fail("warm and cold searches disagree on the best plan (bitwise)")
        print(
            f"search gate OK: warm {warm_ev} evals vs cold {cold_ev} "
            f"(pruned fraction {search.get('warm_pruned_fraction')} vs "
            f"{search.get('cold_pruned_fraction')}), best plan "
            f"{search.get('best_plan')} identical"
        )

    # Flight-recorder overhead gate (ISSUE 7). The ratio is measured
    # within the fresh run itself, so no committed baseline is needed;
    # older fresh artifacts without the section skip the gate.
    rec = fresh.get("recorder")
    if rec is not None:
        ratio = rec.get("overhead_ratio", 0.0)
        if not ratio > 0.0:
            fail(f"fresh recorder.overhead_ratio is {rec.get('overhead_ratio')}")
        if ratio > 1.5:
            fail(
                "TimelineRecorder overhead on run_full exceeds the 1.5x "
                f"budget: {ratio:.3f}x (off {rec.get('off_seconds')}s, "
                f"on {rec.get('on_seconds')}s)"
            )
        print(f"recorder gate OK: run_full + TimelineRecorder at {ratio:.2f}x (budget 1.5x)")

    # Robust re-rank gates (ISSUE 9). Relational like the search gates:
    # every number compared is measured within the fresh run.
    rob = fresh.get("robust")
    if rob is not None:
        for key in ("reranked", "ensemble_evals"):
            if not rob.get(key, 0) > 0:
                fail(f"fresh robust.{key} is {rob.get(key)}")
        if not rob.get("ensemble_evals_per_sec", 0.0) > 0.0:
            fail(
                f"fresh robust.ensemble_evals_per_sec is "
                f"{rob.get('ensemble_evals_per_sec')}"
            )
        if rob.get("pick_stable") is not True:
            fail("robust re-rank pick was not deterministic in-process")
        per_ens = rob.get("seconds_per_ensemble_eval", 0.0)
        tune = fresh["tune_cell"]
        evaluated = tune.get("evaluated", 0)
        per_nominal = (
            tune.get("median_seconds", 0.0) / evaluated if evaluated > 0 else 0.0
        )
        if per_nominal > 0.0 and per_ens > 3.0 * per_nominal:
            fail(
                "robust ensemble evaluation cost exceeds the 3x-per-candidate "
                f"budget: {per_ens:.9f}s/ensemble-eval vs {per_nominal:.9f}s/"
                "nominal-eval"
            )
        print(
            f"robust gate OK: {rob['reranked']} plans x {rob.get('samples')} samples "
            f"at {rob['ensemble_evals_per_sec']:.1f} ensemble-evals/s "
            f"({rob.get('rerank_overhead_vs_search')}x of the nominal search)"
        )

    # Resumable-stepper gates (ISSUE 10). Relational: the overhead
    # ratio and the bitwise replay flag are measured within the fresh
    # run itself.
    stp = fresh.get("stepper")
    if stp is not None:
        if not stp.get("steps", 0) > 0:
            fail(f"fresh stepper.steps is {stp.get('steps')}")
        if not stp.get("steps_per_sec", 0.0) > 0.0:
            fail(f"fresh stepper.steps_per_sec is {stp.get('steps_per_sec')}")
        if stp.get("replay_matches_one_shot") is not True:
            fail("step-per-event replay diverged from the one-shot run (bitwise)")
        ratio = stp.get("overhead_vs_one_shot", 0.0)
        if not ratio > 0.0:
            fail(f"fresh stepper.overhead_vs_one_shot is {ratio}")
        if ratio > 1.5:
            fail(
                "step-per-event driving exceeds the 1.5x one-shot budget: "
                f"{ratio:.3f}x (one-shot {stp.get('one_shot_seconds')}s, "
                f"stepped {stp.get('median_seconds')}s)"
            )
        print(
            f"stepper gate OK: {stp['steps']} steps at "
            f"{stp['steps_per_sec']:.1f} steps/s, {ratio:.2f}x of one-shot "
            "(budget 1.5x), replay bitwise-identical"
        )

    comparable = "provenance" not in committed
    if not comparable:
        print(
            "baseline carries a provenance note (authoring-time snapshot, "
            "not a rust-bench measurement); absolute throughput gates "
            "skipped — refresh BENCH_hotpath.json from a rust-bench run "
            "to arm the 20% regression rule."
        )
        print(
            f"fresh: tune cell {fresh_eps:.1f} evals/s, incremental fair sharing "
            f"{fs['speedup_vs_slow']:.2f}x vs slow — OK"
        )
        return

    # The 20% rule against a comparable (rust-bench) baseline.
    committed_eps = committed["tune_cell"]["evals_per_sec"]
    if committed_eps > 0.0 and fresh_eps < 0.8 * committed_eps:
        fail(
            f"tune-cell evals/sec regressed >20%: {fresh_eps:.1f} vs "
            f"committed {committed_eps:.1f}"
        )
    committed_inc = committed.get("fair_sharing", {}).get("incremental_evals_per_sec", 0.0)
    if committed_inc > 0.0 and fs["incremental_evals_per_sec"] < 0.8 * committed_inc:
        fail(
            "incremental fair-sharing evals/sec regressed >20%: "
            f"{fs['incremental_evals_per_sec']:.1f} vs committed {committed_inc:.1f}"
        )
    committed_warm = committed.get("search", {}).get("warm_evals_per_sec", 0.0)
    if search is not None and committed_warm > 0.0:
        if search["warm_evals_per_sec"] < 0.8 * committed_warm:
            fail(
                "warm-search evals/sec regressed >20%: "
                f"{search['warm_evals_per_sec']:.1f} vs committed {committed_warm:.1f}"
            )
    print(
        f"bench gate OK: tune cell {fresh_eps:.1f} evals/s "
        f"(committed {committed_eps:.1f}), incremental fair sharing "
        f"{fs['speedup_vs_slow']:.2f}x vs slow"
    )


if __name__ == "__main__":
    main()
