#!/usr/bin/env python3
"""Canonicalize a `ficco tune` artifact for warm-vs-cold comparison.

Usage: strip_search_effort.py ARTIFACT [> canonical]

The warm-started search order is bit-identical to the cold
enumeration-order reference in every *result* field (best plan,
makespans, speedups, picks), but legitimately differs in search
*effort*: the `evaluated`/`pruned` split (warm prunes more) and the
jobs/run-dependent `telemetry` tail. This tool strips exactly those
fields so `ficco tune --warm on` and `--warm off` artifacts can be
compared byte-for-byte in CI:

- JSON (`{"results":[...],"telemetry":{...}}`): keep only `results`,
  drop each row's `evaluated` and `pruned`, re-emit with sorted keys
  and a fixed separator so the output is canonical.
- CSV (tune header): drop the `evaluated` and `pruned` columns by
  header name, keep row order and every other column byte-verbatim.

Any other shape is an error — this is a comparison gate, so a file we
do not recognize must fail loudly rather than canonicalize to ''.
"""

import json
import sys

EFFORT_FIELDS = ("evaluated", "pruned")


def fail(msg):
    print(f"strip_search_effort: {msg}", file=sys.stderr)
    sys.exit(1)


def strip_json(text):
    doc = json.loads(text)
    if not isinstance(doc, dict) or "results" not in doc:
        fail("JSON artifact has no 'results' array")
    rows = doc["results"]
    if not isinstance(rows, list):
        fail("'results' is not an array")
    out = []
    for row in rows:
        if not isinstance(row, dict):
            fail("non-object row in 'results'")
        out.append({k: v for k, v in row.items() if k not in EFFORT_FIELDS})
    return json.dumps({"results": out}, sort_keys=True, separators=(",", ":")) + "\n"


def strip_csv(text):
    lines = text.splitlines()
    if not lines:
        fail("empty CSV artifact")
    header = lines[0].split(",")
    keep = [i for i, name in enumerate(header) if name not in EFFORT_FIELDS]
    if len(keep) != len(header) - len(EFFORT_FIELDS):
        fail(f"CSV header lacks the effort columns {EFFORT_FIELDS}: {lines[0]!r}")
    out = []
    for line in lines:
        cols = line.split(",")
        if len(cols) != len(header):
            fail(f"ragged CSV row ({len(cols)} cols, header has {len(header)}): {line!r}")
        out.append(",".join(cols[i] for i in keep))
    return "\n".join(out) + "\n"


def main():
    if len(sys.argv) != 2:
        fail(f"usage: {sys.argv[0]} ARTIFACT")
    with open(sys.argv[1]) as f:
        text = f.read()
    stripped = text.lstrip()
    if stripped.startswith("{") or stripped.startswith("["):
        sys.stdout.write(strip_json(text))
    else:
        sys.stdout.write(strip_csv(text))


if __name__ == "__main__":
    main()
