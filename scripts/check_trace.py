#!/usr/bin/env python3
"""Validate a `ficco trace` Perfetto artifact (ISSUE 7, CI `trace-smoke` job).

Usage: check_trace.py TRACE_JSON [TIMELINE_CSV]

Checks, in order:

- The file parses as JSON and carries the Chrome-trace skeleton
  ui.perfetto.dev expects: a `traceEvents` array and
  `displayTimeUnit: "ms"`.
- The `ficco` header object names the run (scenario/machine/mech/plan)
  and its derived totals (makespan, gap_time, throttled_time) are
  finite and non-negative, with gap + throttled time each bounded by
  a stream/task multiple of the makespan left to the simulator.
- Track metadata is well formed: every referenced (pid, tid) has a
  `process_name`, and every `X` span and `B`/`E` window sits inside
  [0, makespan] (timestamps in microseconds).
- Duration events balance: per (pid, tid, name), `B` and `E` events
  pair up with no window left open and no negative-length window.
- Complete (`X`) spans per track do not overlap.
- If TIMELINE_CSV is given: the header matches the exporter's schema,
  every row is a known record type, and the task-span count equals the
  trace's work-span count.

Exit 0 on pass, 1 on any failure.
"""

import json
import math
import sys
from collections import defaultdict

EPS_US = 1e-3  # slack on microsecond timestamps


def fail(msg):
    print(f"TRACE CHECK: {msg}", file=sys.stderr)
    sys.exit(1)


def check_trace(path):
    with open(path) as f:
        try:
            trace = json.load(f)
        except json.JSONDecodeError as e:
            fail(f"{path} is not valid JSON: {e}")

    for key in ("ficco", "displayTimeUnit", "traceEvents"):
        if key not in trace:
            fail(f"missing top-level '{key}'")
    if trace["displayTimeUnit"] != "ms":
        fail(f"displayTimeUnit is {trace['displayTimeUnit']!r}, expected 'ms'")

    hdr = trace["ficco"]
    for key in ("scenario", "machine", "mech", "plan"):
        if not hdr.get(key):
            fail(f"ficco header is missing '{key}'")
    makespan = hdr.get("makespan")
    if not isinstance(makespan, (int, float)) or not math.isfinite(makespan) or makespan <= 0:
        fail(f"ficco.makespan is {makespan!r}")
    for key in ("gap_time", "throttled_time"):
        v = hdr.get(key)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v < 0:
            fail(f"ficco.{key} is {v!r}")

    events = trace["traceEvents"]
    if not isinstance(events, list) or not events:
        fail("traceEvents is empty")
    horizon_us = makespan * 1e6 + EPS_US

    named_pids = set()
    spans = defaultdict(list)  # (pid, tid) -> [(ts, ts+dur, name)]
    open_windows = defaultdict(list)  # (pid, tid, name) -> [B timestamps]
    n_work_spans = 0
    saw_plan_instant = False
    for ev in events:
        ph = ev.get("ph")
        pid, tid, name = ev.get("pid"), ev.get("tid"), ev.get("name", "")
        if ph == "M":
            if name == "process_name":
                named_pids.add(pid)
            continue
        if ph == "I":
            saw_plan_instant |= name == "plan"
            continue
        if ph == "C":
            if not (-EPS_US <= ev["ts"] <= horizon_us):
                fail(f"counter sample for {name!r} at ts={ev['ts']} outside the run")
            continue
        if ph == "X":
            ts, dur = ev["ts"], ev["dur"]
            if dur < 0:
                fail(f"span {name!r} has negative duration {dur}")
            if ts < -EPS_US or ts + dur > horizon_us:
                fail(f"span {name!r} [{ts}, {ts + dur}] outside [0, {horizon_us}]")
            spans[(pid, tid)].append((ts, ts + dur, name))
            n_work_spans += ev.get("cat") == "work"
            continue
        if ph == "B":
            if not (-EPS_US <= ev["ts"] <= horizon_us):
                fail(f"window {name!r} opens at ts={ev['ts']} outside the run")
            open_windows[(pid, tid, name)].append(ev["ts"])
            continue
        if ph == "E":
            stack = open_windows[(pid, tid, name)]
            if not stack:
                fail(f"unbalanced E for {name!r} on (pid={pid}, tid={tid})")
            t0 = stack.pop()
            if ev["ts"] < t0 - EPS_US or ev["ts"] > horizon_us:
                fail(f"window {name!r} [{t0}, {ev['ts']}] is malformed")
            continue
        fail(f"unknown event phase {ph!r}")

    for (pid, tid, name), stack in open_windows.items():
        if stack:
            fail(f"{len(stack)} unclosed {name!r} window(s) on (pid={pid}, tid={tid})")
    if not saw_plan_instant:
        fail("no 'plan' instant event — run identity missing from the trace")
    if n_work_spans == 0:
        fail("no work spans in the trace")

    for (pid, tid), track in spans.items():
        if pid not in named_pids:
            fail(f"events on pid={pid} but no process_name metadata for it")
        # Setup [ready, start] and work [start, finish] spans on one
        # track abut but never overlap.
        track.sort()
        for (a0, a1, an), (b0, b1, bn) in zip(track, track[1:]):
            if b0 < a1 - EPS_US:
                fail(
                    f"overlapping spans on (pid={pid}, tid={tid}): "
                    f"{an!r} [{a0}, {a1}] vs {bn!r} [{b0}, {b1}]"
                )

    n_tracks = len({(pid, tid) for pid, tid in spans})
    print(
        f"trace OK: {hdr['scenario']} on {hdr['machine']} plan {hdr['plan']} — "
        f"{n_work_spans} work spans on {n_tracks} tracks, "
        f"makespan {makespan:.6g}s, gap {hdr['gap_time']:.3g}s, "
        f"throttled {hdr['throttled_time']:.3g}s"
    )
    return n_work_spans


def check_csv(path, n_work_spans):
    with open(path) as f:
        lines = f.read().splitlines()
    if not lines or lines[0] != "record,track,label,t_ready,t_start,t_end,value":
        fail(f"{path}: unexpected header {lines[0] if lines else '<empty>'!r}")
    known = {"task", "gap", "throttled", "busy"}
    counts = defaultdict(int)
    for line in lines[1:]:
        record = line.split(",", 1)[0]
        if record not in known:
            fail(f"{path}: unknown record type in {line!r}")
        counts[record] += 1
    if counts["task"] != n_work_spans:
        fail(
            f"{path}: {counts['task']} task rows vs {n_work_spans} work spans "
            "in the trace — exporters disagree"
        )
    if counts["busy"] == 0:
        fail(f"{path}: no busy-integral rows")
    print(
        f"timeline OK: {counts['task']} tasks, {counts['gap']} gaps, "
        f"{counts['throttled']} throttled windows, {counts['busy']} busy integrals"
    )


def main():
    if len(sys.argv) not in (2, 3):
        fail(f"usage: {sys.argv[0]} TRACE_JSON [TIMELINE_CSV]")
    n_work_spans = check_trace(sys.argv[1])
    if len(sys.argv) == 3:
        check_csv(sys.argv[2], n_work_spans)


if __name__ == "__main__":
    main()
