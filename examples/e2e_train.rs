//! End-to-end validation driver (DESIGN.md §6): trains the ~100M-param
//! decoder-only transformer on the synthetic Zipf-Markov corpus for a
//! few hundred steps, entirely through the Rust PJRT runtime executing
//! the AOT train_step artifact (L2 JAX model, L1 Pallas GEMMs — no
//! Python at runtime), logging the loss curve to results/.
//!
//! Environment knobs (so CI can run a shorter configuration):
//!   FICCO_E2E_PRESET=tiny|small|m100   (default m100)
//!   FICCO_E2E_STEPS=N                  (default 300)
//!
//! Run: `cargo run --release --example e2e_train`

use ficco::train::{run, TrainConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let preset = std::env::var("FICCO_E2E_PRESET").unwrap_or_else(|_| "m100".into());
    let steps: usize = std::env::var("FICCO_E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let cfg = TrainConfig {
        preset: preset.clone(),
        steps,
        seed: 2025,
        artifacts: "artifacts".into(),
        log_every: 10,
        loss_csv: Some(format!("results/e2e_loss_{preset}.csv")),
        overlap_report: true,
    };
    let report = run(&cfg)?;

    // Success criteria for the e2e run: finite, decreasing loss.
    let first = *report.losses.first().expect("losses");
    let last = *report.losses.last().expect("losses");
    assert!(last.is_finite() && last < first, "training must make progress");
    println!(
        "\ne2e OK: {} steps, loss {first:.3} -> {last:.3}, {:.1} tokens/s",
        report.losses.len(),
        report.tokens_per_second
    );
    Ok(())
}
