//! Expert-parallel MoE dispatch (§III-C / Fig 5): the all-to-all
//! token dispersal before expert MLPs, including the *asymmetric*
//! routing case where token counts differ per GPU pair — the
//! finer-grain pieces hide the asymmetry that shard-granular overlap
//! cannot.
//!
//! Uses the Mixtral EP scenarios (Table I g14–g16) plus a skewed
//! variant built directly on the cluster simulator.
//!
//! Run: `cargo run --release --example moe_dispatch`

use ficco::cost::gemm::{GemmCost, Sharding};
use ficco::heuristics;
use ficco::hw::Machine;
use ficco::schedule::{exec::ScenarioEval, Kind};
use ficco::sim::{ClusterSim, CommMech};
use ficco::util::rng::Rng;
use ficco::util::table::{x, Align, Table};
use ficco::workloads;

fn main() {
    let machine = Machine::mi300x_8();

    println!("Mixtral expert-parallel dispatch scenarios (Table I g14-g16):\n");
    let mut t = Table::new(vec!["scenario", "tokens (M)", "pick", "speedup", "best"])
        .align(0, Align::Left)
        .align(2, Align::Left);
    for g in ["g14", "g15", "g16"] {
        let sc = workloads::by_name(g).unwrap();
        let pick = heuristics::pick(&machine, &sc).pick;
        let ev = ScenarioEval::run(&machine, &sc, &Kind::ALL);
        let (_, best) = ev.best_ficco().expect("all FiCCO kinds evaluated");
        t.row(vec![
            g.to_string(),
            sc.gemm.m.to_string(),
            pick.name().to_string(),
            x(ev.speedup(pick)),
            x(best),
        ]);
    }
    print!("{}", t.render());

    // Asymmetric routing: expert hotness skews per-pair volumes.
    // Compare shard-granular overlap (whole skewed chunk per step, the
    // largest chunk dominating each step) against FiCCO's piece-level
    // all-to-all where large chunks stream while compute proceeds.
    println!("\nasymmetric routing (Zipf expert hotness, g14 volume):");
    let sc = workloads::by_name("g14").unwrap();
    let total_rx = sc.rx_bytes_per_gpu();
    let mut rng = Rng::new(0xA11);
    // Per-source skew weights (normalized): hot experts get several
    // times the traffic of cold ones.
    let weights: Vec<f64> = (0..8).map(|_| 0.25 + rng.f64() * 1.75).collect();
    let wsum: f64 = weights.iter().sum();

    // Shard-granular (AsyncTP-like): one peer at a time over a single
    // P2P lane — a hot pair stalls the whole step pipeline.
    // FiCCO: per-pair lanes stream pieces all-to-all concurrently, so
    // cold-pair compute proceeds while hot pairs are still sending.
    for (label, serial_p2p) in [("shard-granular P2P", true), ("FiCCO all-to-all", false)] {
        let mut sim = ClusterSim::new(machine.clone());
        let gcost = GemmCost::new(&machine.gpu);
        let chunk_gemm = sc.gemm.shard(Sharding::Row, 8);
        let tg = gcost.time(&chunk_gemm);
        for dst in 0..8 {
            let mut prev: Option<ficco::sim::TaskId> = None;
            for s in 1..8 {
                let src = (dst + s) % 8;
                let chunk = total_rx / 7.0 * weights[src] / (wsum / 8.0);
                let (slot, dep): (usize, Vec<_>) = if serial_p2p {
                    (0, prev.into_iter().collect())
                } else {
                    ((dst + 8 - src - 1) % 8, vec![])
                };
                let pieces = if serial_p2p { 1 } else { 8 };
                let mut piece_ids = Vec::new();
                for p in 0..pieces {
                    let d: Vec<_> = if p == 0 {
                        dep.clone()
                    } else {
                        vec![piece_ids[p - 1]]
                    };
                    piece_ids.push(sim.transfer_task(
                        src,
                        dst,
                        slot,
                        format!("tok {src}->{dst}/{p}"),
                        chunk / pieces as f64,
                        CommMech::Dma,
                        &d,
                    ));
                }
                prev = piece_ids.last().copied();
                // Expert GEMM on this chunk once enough pieces landed
                // (FiCCO can start after the first piece; shard waits
                // for the whole chunk). Model: depend on first 1/8 for
                // FiCCO (compute streams behind comm), whole otherwise.
                let gate = if serial_p2p { *piece_ids.last().unwrap() } else { piece_ids[0] };
                sim.gemm_task(
                    dst,
                    format!("expert g{dst} s{s}"),
                    tg,
                    chunk_gemm.bytes(),
                    gcost.cus_used(&chunk_gemm),
                    &[gate],
                );
            }
        }
        let rep = sim.run().expect("sim");
        println!(
            "  {label:<20} makespan {}",
            ficco::util::human_time(rep.makespan)
        );
    }
    println!("\nfiner grains let cold-pair compute start while hot pairs stream (Fig 5).");

    // The same asymmetry, now first-class: `Scenario::with_skew`
    // routes hot-expert imbalance through the partition layer, so
    // every schedule, validator, cost model and search path sees the
    // skewed per-GPU extents (DESIGN.md §5).
    println!("\nfirst-class routing skew (g14, Zipf hotness via Scenario::with_skew):");
    for skew in [0.0, 0.6, 1.2] {
        let sc = workloads::by_name("g14").unwrap().with_skew(skew, 7);
        let ev = ScenarioEval::run(&machine, &sc, &Kind::ALL);
        let (best, s) = ev.best_ficco().expect("all FiCCO kinds evaluated");
        println!(
            "  skew {skew:<4} imbalance {:<6} best {:<18} speedup {}",
            x(sc.partition(1).imbalance()),
            best.name(),
            x(s),
        );
    }
}
