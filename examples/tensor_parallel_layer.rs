//! Tensor-sequence-parallel transformer layer (the paper's §II-A
//! SP+TP motivation): walks the four GEMMs of one llama-2-70b
//! transformer block at production batch — exactly Table I's g5–g8 —
//! through the full design space, and shows the end-to-end block time
//! with serial execution vs heuristic-picked FiCCO schedules.
//!
//! Run: `cargo run --release --example tensor_parallel_layer`

use ficco::heuristics;
use ficco::hw::Machine;
use ficco::schedule::{exec::ScenarioEval, Kind};
use ficco::util::table::{x, Align, Table};
use ficco::workloads;

fn main() {
    let machine = Machine::mi300x_8();
    // One llama-2-70b block under SP+TP at 8 GPUs: attention in/out
    // projections (g5, g6) and MLP up/down (g7, g8).
    let block = [
        ("attn qkv proj", "g5"),
        ("attn out proj", "g6"),
        ("mlp up proj", "g7"),
        ("mlp down proj", "g8"),
    ];

    let mut t = Table::new(vec![
        "layer GEMM", "scenario", "serial", "pick", "picked speedup", "best ficco",
    ])
    .align(0, Align::Left)
    .align(1, Align::Left)
    .align(3, Align::Left);

    let mut serial_total = 0.0;
    let mut ficco_total = 0.0;
    for (layer, g) in block {
        let sc = workloads::by_name(g).unwrap();
        let pick = heuristics::pick(&machine, &sc).pick;
        let ev = ScenarioEval::run(&machine, &sc, &Kind::ALL);
        let picked = ev.speedup(pick);
        let (_, best) = ev.best_ficco().expect("all FiCCO kinds evaluated");
        let picked_time = ev.baseline / picked;
        serial_total += ev.baseline;
        ficco_total += picked_time;
        t.row(vec![
            layer.to_string(),
            g.to_string(),
            ficco::util::human_time(ev.baseline),
            pick.name().to_string(),
            x(picked),
            x(best),
        ]);
    }
    println!("llama-2-70b transformer block, SP+TP on 8x MI300X:\n");
    print!("{}", t.render());
    println!(
        "\nblock total: serial {} -> FiCCO {}  ({} end-to-end)",
        ficco::util::human_time(serial_total),
        ficco::util::human_time(ficco_total),
        x(serial_total / ficco_total)
    );
}
