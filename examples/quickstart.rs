//! Quickstart: the complete FiCCO flow on one scenario.
//!
//! 1. Pick a data-dependent compute/communication scenario (Table I g5).
//! 2. Ask the heuristic (Fig 12a) for the bespoke FiCCO schedule.
//! 3. Simulate all schedules on the 8x MI300X machine model and
//!    compare speedups over the serial baseline.
//! 4. Numerically validate the picked schedule against the serial
//!    result with real data through the PJRT runtime (L1 Pallas
//!    kernels where shapes match).
//!
//! Run: `cargo run --release --example quickstart`

use ficco::coordinator;
use ficco::heuristics;
use ficco::hw::Machine;
use ficco::schedule::{exec::ScenarioEval, Kind};
use ficco::util::table::x;
use ficco::workloads;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let machine = Machine::mi300x_8();
    let sc = workloads::by_name("g5").expect("table1 scenario");
    println!(
        "scenario g5: GEMM ({}, {}, {}) fed by an {} over {} GPUs\n",
        sc.gemm.m,
        sc.gemm.n,
        sc.gemm.k,
        sc.collective.name(),
        sc.ngpus
    );

    // 2. Heuristic decision from static GEMM properties alone.
    let decision = heuristics::pick(&machine, &sc);
    println!("heuristic pick: {}\n  because: {}\n", decision.pick.name(), decision.reason);

    // 3. Simulate every schedule in the design space.
    let ev = ScenarioEval::run(&machine, &sc, &Kind::ALL);
    println!("simulated on the 8x MI300X model:");
    for r in &ev.results {
        println!(
            "  {:<18} {:>10}  speedup {}",
            r.kind.name(),
            ficco::util::human_time(r.makespan),
            x(ev.speedup(r.kind))
        );
    }
    let (oracle, s) = ev.best_ficco().expect("all FiCCO kinds evaluated");
    println!(
        "\noracle best: {} at {} (heuristic {})",
        oracle.name(),
        x(s),
        if oracle == decision.pick { "HIT" } else { "miss" }
    );

    // 4. Real-data validation of the schedule semantics (scaled-down
    // geometry so the CPU run is instant; the decomposition logic is
    // shape-generic and validated property-style in the test suite).
    println!("\nnumeric validation (256x128x192, 8 ranks, real data via PJRT):");
    coordinator::validate_all_schedules("artifacts", 256, 128, 192, 8)?;
    Ok(())
}
