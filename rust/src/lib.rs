//! # FiCCO — Finer-Grain Compute-Communication Overlap
//!
//! Reproduction of "Design Space Exploration of DMA based Finer-Grain
//! Compute Communication Overlap" (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! Layer map:
//! - **L3 (this crate)** — the coordinator and the paper's systems
//!   contribution: the FiCCO schedule design space ([`schedule`]),
//!   DIL/CIL characterization ([`cost`], [`sim`]), schedule-selection
//!   heuristics ([`heuristics`]), DMA communication offload (modelled
//!   in [`sim::cluster`], exercised by [`coordinator`]).
//! - **L2/L1 (build-time Python)** — `python/compile/` lowers a JAX
//!   transformer whose GEMMs are Pallas kernels to HLO text artifacts
//!   loaded by [`runtime`].
//!
//! The design-space *exploration* the paper's title promises lives in
//! three layers:
//!
//! - [`plan`] — the parameterized schedule-plan space: a
//!   [`plan::Plan`] names the axes (decomposition degree, fused vs
//!   unfused compute, 1D-row vs 2D-column shape, head start, comm
//!   mechanism, comm-slot width) and one generator
//!   ([`plan::lower`]) subsumes the six legacy schedule kinds as
//!   named presets;
//! - [`search`] — plan-space search against the fluid simulator:
//!   exhaustive enumeration or beam local search, cost-model
//!   lower-bound pruning, a memoized evaluation cache, and a
//!   deterministic parallel tune driver (the `ficco tune`
//!   subcommand);
//! - [`explore`] — the parallel sweep engine evaluating the scenario
//!   × schedule × machine × mechanism × GPU-count × skew product on
//!   an ordered worker pool ([`util::pool`]) with deterministic,
//!   byte-stable CSV/JSON output (the `ficco sweep` subcommand).
//!
//! The simulator core is *resumable* (`DESIGN.md` §11): [`sim::Engine`]
//! exposes a stepper (`begin_run` / `step` / `advance_until` /
//! `admit_tasks` / `finish_run`) with a caller-owned virtual clock and
//! mid-run task admission, the one-shot runs being thin bit-identical
//! drivers over the same core. Multiple schedule instances co-tenant
//! one machine through per-tenant stream banks in [`sim::ClusterSim`],
//! surfaced as `Evaluator::cotenant` ([`schedule::exec`]), the
//! co-tenant sweep runner in [`explore`], and the `ficco cotenant`
//! subcommand with per-job slowdown-vs-isolated exhibits.
//!
//! The selection side is closed by [`heuristics`]: the frozen Fig-12a
//! static rule, plus the calibrated plan-space model
//! ([`heuristics::model`]) that `ficco calibrate` fits against
//! tune-searched optima ([`heuristics::fit`], training data via
//! [`search::training`]; contract in `DESIGN.md` §7).
//!
//! Traffic is not assumed uniform: [`plan::Partition`] makes per-GPU
//! row ownership first-class, and `Scenario::with_skew` opens the
//! EP/MoE expert-imbalance axis (hot-expert Zipf routing) through
//! every layer — lowering, validation, closed-form costs, the numeric
//! executor, and the search cache (`DESIGN.md` §5).
//!
//! Machine presets beyond the paper's MI300X-8 testbed — an
//! H100-DGX-like switched node and a PCIe-Gen4-class box — are
//! registered in [`hw`].
//!
//! See `DESIGN.md` for the full inventory and the experiment index.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod explore;
pub mod heuristics;
pub mod hw;
pub mod metrics;
pub mod obs;
pub mod plan;
pub mod runtime;
pub mod schedule;
pub mod search;
pub mod sim;
pub mod train;
pub mod util;
pub mod workloads;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
