//! # FiCCO — Finer-Grain Compute-Communication Overlap
//!
//! Reproduction of "Design Space Exploration of DMA based Finer-Grain
//! Compute Communication Overlap" (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! Layer map:
//! - **L3 (this crate)** — the coordinator and the paper's systems
//!   contribution: the FiCCO schedule design space ([`schedule`]),
//!   DIL/CIL characterization ([`cost`], [`sim`]), schedule-selection
//!   heuristics ([`heuristics`]), DMA communication offload (modelled
//!   in [`sim::cluster`], exercised by [`coordinator`]).
//! - **L2/L1 (build-time Python)** — `python/compile/` lowers a JAX
//!   transformer whose GEMMs are Pallas kernels to HLO text artifacts
//!   loaded by [`runtime`].
//!
//! See `DESIGN.md` for the full inventory and the experiment index.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod heuristics;
pub mod hw;
pub mod metrics;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod train;
pub mod util;
pub mod workloads;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
