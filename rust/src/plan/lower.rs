//! Lowering: one generator from any [`Plan`] point to a [`Schedule`].
//!
//! Two emission modes, selected by the comm-slot width:
//!
//! - **full-width** (`slots == ngpus-1`): every (src, dst) pair rides
//!   its own lane, so transfers to distinct peers are unordered and
//!   emission is receiver-major — this specializes to the legacy
//!   baseline, uniform-fused-1D/2D and hetero generators bit-for-bit
//!   (same node structure, stream assignment and insertion order, so
//!   the fluid simulator reproduces their makespans exactly);
//! - **chained** (`slots < ngpus-1`): transfers share lanes, so each
//!   (receiver, lane) chain is serialized by explicit deps and
//!   emission is round-major (receiver `r` takes piece `p` from peer
//!   `(r+s) mod n` at round `s` — a perfect matching per round). With
//!   `slots == 1`, `pieces == 1`, unfused and head-start this is
//!   exactly the legacy shard-overlap (AsyncTP-style) generator.
//!
//! Stream insertion order matters: the simulator serializes each
//! stream FIFO, so the emission orders above are part of the plan's
//! semantics, not cosmetics.

use super::{CommShape, Partition, Plan};
use crate::cost::gemm::GemmShape;
use crate::schedule::generate::{lane, region, split, Builder};
use crate::schedule::{Region, Scenario, Schedule};

/// Region of piece `p` of GPU `q`'s shard under `shape`. Row extents
/// come from the scenario's partition (uniform or skewed); the 2D
/// K-split stays balanced (the reduction dimension is weight-resident,
/// not routed).
fn piece_region(part: &Partition, sc: &Scenario, shape: CommShape, q: usize, p: usize) -> Region {
    match shape {
        CommShape::Row => region(part.piece_rows(q, p), (0, sc.gemm.k)),
        CommShape::Col => {
            let ks = split(sc.gemm.k, part.pieces as u64, p as u64);
            region(part.shard_rows(q), ks)
        }
    }
}

/// Generate the schedule for `plan` on `scenario`. Panics on an
/// invalid plan (see [`Plan::check`]); search-side callers enumerate
/// only checked plans.
pub fn lower(plan: &Plan, sc: &Scenario) -> Schedule {
    lower_opts(plan, sc, None, true)
}

/// [`lower`] with the cell-invariant prefix split out: an optional
/// precomputed [`Partition`] (the scenario's routing geometry at
/// `plan.pieces` — the only lowering input that does not change from
/// candidate to candidate within a (scenario, pieces) group, and the
/// expensive one under skew) and a label switch (see
/// [`Builder::new_with_labels`]). The emitted node structure is
/// bit-identical to [`lower`] for any plan: the partition is a pure
/// function of `(sc.gemm.m, sc.ngpus, plan.pieces, sc.skew,
/// sc.skew_seed)`, so a cached instance substitutes exactly.
///
/// Panics if a supplied partition disagrees with the scenario/plan it
/// is used for (debug builds; the cell-scoped caller keys its cache
/// on exactly the partition inputs).
pub fn lower_opts(
    plan: &Plan,
    sc: &Scenario,
    part: Option<&Partition>,
    labels: bool,
) -> Schedule {
    plan.check(sc.ngpus)
        .unwrap_or_else(|e| panic!("invalid plan {} for {}: {e}", plan.id(), sc.name));
    let n = sc.ngpus;
    let owned;
    let part = match part {
        Some(p) => {
            debug_assert_eq!(p.pieces, plan.pieces, "partition/plan pieces mismatch");
            debug_assert_eq!(p.ngpus, sc.ngpus, "partition/scenario ngpus mismatch");
            debug_assert_eq!(p.m, sc.gemm.m, "partition/scenario M mismatch");
            p
        }
        None => {
            owned = sc.partition(plan.pieces);
            &owned
        }
    };
    let mut b = Builder::new_with_labels(labels);
    if plan.slots >= n - 1 {
        lower_full(plan, sc, part, &mut b);
    } else {
        lower_chained(plan, sc, part, &mut b);
    }
    Schedule {
        kind: plan.kind(),
        scenario: sc.clone(),
        plan: Some(*plan),
        nodes: b.nodes,
    }
}

/// Emit the head-start GEMM: the whole local shard, full K, computed
/// immediately with no dependencies.
fn head_start_gemm(sc: &Scenario, part: &Partition, b: &mut Builder, r: usize) {
    let g = &sc.gemm;
    let (lo, hi) = part.shard_rows(r);
    b.gemm(
        r,
        GemmShape { m: hi - lo, ..*g },
        vec![region((lo, hi), (0, g.k))],
        0,
        vec![],
    );
}

/// Per-piece GEMM shape (unfused compute) for one region.
fn piece_shape(plan: &Plan, sc: &Scenario, reg: &Region, p: usize) -> GemmShape {
    let g = &sc.gemm;
    match plan.shape {
        CommShape::Row => GemmShape {
            m: reg.row_hi - reg.row_lo,
            ..*g
        },
        CommShape::Col => GemmShape {
            m: reg.row_hi - reg.row_lo,
            k: reg.k_hi - reg.k_lo,
            accumulate: p > 0,
            ..*g
        },
    }
}

/// Emit the fused compute for one (receiver, piece-step): gather the
/// arrivals (and, for uniform plans, the local piece) into one GEMM,
/// scattering row-sharded outputs back. The shard-level uniform point
/// (`pieces == 1`, no head start) degenerates to the serial baseline:
/// a one-shot exchange lands every shard in its final layout, so no
/// gather/scatter copies are needed and a single GEMM consumes the
/// whole input.
fn emit_fused(
    plan: &Plan,
    sc: &Scenario,
    b: &mut Builder,
    r: usize,
    p: usize,
    covers: Vec<Region>,
    xfers: Vec<usize>,
) {
    let g = &sc.gemm;
    let e = g.dtype.bytes() as f64;
    let step = p + if plan.head_start { 1 } else { 0 };
    let rows_total: u64 = covers.iter().map(|c| c.row_hi - c.row_lo).sum();
    let k_len = match covers.first() {
        Some(c) => c.k_hi - c.k_lo,
        None => g.k,
    };
    let shape = GemmShape {
        m: rows_total,
        k: k_len,
        accumulate: plan.shape == CommShape::Col && p > 0,
        ..*g
    };
    if plan.pieces == 1 && !plan.head_start {
        b.gemm(r, shape, covers, step, xfers);
        return;
    }
    let gather_bytes = rows_total as f64 * k_len as f64 * e;
    let gather = b.gather(r, gather_bytes, step, xfers);
    let gemm = b.gemm(r, shape, covers, step, vec![gather]);
    if plan.shape == CommShape::Row {
        let scatter_bytes = rows_total as f64 * g.n as f64 * e;
        b.scatter(r, scatter_bytes, step, vec![gemm]);
    }
}

/// Full-width lowering: receiver-major emission, a dedicated lane per
/// (src, dst) pair, no transfer chaining (stream FIFO orders repeats
/// of the same pair across piece steps).
fn lower_full(plan: &Plan, sc: &Scenario, part: &Partition, b: &mut Builder) {
    let n = sc.ngpus;
    let d = plan.pieces;
    for r in 0..n {
        if plan.head_start {
            head_start_gemm(sc, part, b, r);
        }
        for p in 0..d {
            let mut xfers: Vec<usize> = Vec::new();
            let mut covers: Vec<Region> = Vec::new();
            // (dep, region) per piece consumed this step; local pieces
            // (uniform plans only) carry no dependency.
            let mut pieces: Vec<(Option<usize>, Region)> = Vec::new();
            for q in 0..n {
                let reg = piece_region(part, sc, plan.shape, q, p);
                if q == r {
                    if !plan.head_start {
                        covers.push(reg);
                        pieces.push((None, reg));
                    }
                    continue;
                }
                let x = b.xfer(r, q, reg, p, lane(q, r, n), vec![]);
                xfers.push(x);
                covers.push(reg);
                pieces.push((Some(x), reg));
            }
            if plan.fused {
                emit_fused(plan, sc, b, r, p, covers, xfers);
            } else {
                let step = p + if plan.head_start { 1 } else { 0 };
                for (dep, reg) in pieces {
                    let deps = match dep {
                        Some(x) => vec![x],
                        None => vec![],
                    };
                    b.gemm(r, piece_shape(plan, sc, &reg, p), vec![reg], step, deps);
                }
            }
        }
    }
}

/// Narrow-slot lowering: round-major emission with per-(receiver,
/// lane) dependency chains serializing transfers that share a lane.
fn lower_chained(plan: &Plan, sc: &Scenario, part: &Partition, b: &mut Builder) {
    let n = sc.ngpus;
    let d = plan.pieces;
    let w = plan.slots;
    if plan.head_start {
        for r in 0..n {
            head_start_gemm(sc, part, b, r);
        }
    }
    // Last transfer per (receiver, lane): the chain tails.
    let mut chain: Vec<Vec<Option<usize>>> = vec![vec![None; w]; n];
    for p in 0..d {
        let step = p + if plan.head_start { 1 } else { 0 };
        // Arrivals per receiver this piece step (fused plans compute
        // them together once the step's rounds are emitted).
        let mut got: Vec<Vec<(usize, Region)>> = vec![Vec::new(); n];
        for s_off in 1..n {
            for r in 0..n {
                let q = (r + s_off) % n;
                let reg = piece_region(part, sc, plan.shape, q, p);
                let lane_i = (n - 1 - s_off) % w;
                let deps = match chain[r][lane_i] {
                    Some(x) => vec![x],
                    None => vec![],
                };
                let x = b.xfer(r, q, reg, p, lane_i, deps);
                chain[r][lane_i] = Some(x);
                if plan.fused {
                    got[r].push((x, reg));
                } else {
                    b.gemm(r, piece_shape(plan, sc, &reg, p), vec![reg], step, vec![x]);
                }
            }
        }
        if plan.fused {
            for (r, arrivals) in got.into_iter().enumerate() {
                let mut covers: Vec<Region> = Vec::new();
                let mut xfers: Vec<usize> = Vec::new();
                if !plan.head_start {
                    covers.push(piece_region(part, sc, plan.shape, r, p));
                }
                for (x, reg) in arrivals {
                    xfers.push(x);
                    covers.push(reg);
                }
                emit_fused(plan, sc, b, r, p, covers, xfers);
            }
        } else if !plan.head_start {
            // Uniform unfused: the local piece of this step still
            // needs computing (no transfer, no dependency).
            for r in 0..n {
                let reg = piece_region(part, sc, plan.shape, r, p);
                b.gemm(r, piece_shape(plan, sc, &reg, p), vec![reg], step, vec![]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{validate::validate, Kind, OpKind};
    use crate::sim::CommMech;

    fn sc() -> Scenario {
        Scenario::new("t", 4096, 1024, 2048)
    }

    #[test]
    fn presets_lower_to_legacy_structure() {
        let sc = sc();
        // Baseline: 56 whole-shard transfers, 8 GEMMs, no copies.
        let base = Plan::preset(Kind::Baseline, &sc).lower(&sc);
        assert_eq!(base.n_xfers(), 8 * 7);
        assert_eq!(base.n_gemms(), 8);
        // Shard overlap: 8 local + 56 per-shard GEMMs, chained lanes.
        let so = Plan::preset(Kind::ShardOverlap, &sc).lower(&sc);
        assert_eq!(so.n_xfers(), 8 * 7);
        assert_eq!(so.n_gemms(), 8 * 8);
        // Uniform fused 1D: 8x the transfer count, same bytes.
        let uf = Plan::preset(Kind::UniformFused1D, &sc).lower(&sc);
        assert_eq!(uf.n_xfers(), 8 * base.n_xfers());
        assert!((uf.comm_bytes() - base.comm_bytes()).abs() < 1.0);
        // Hetero unfused: no gather/scatter nodes at all.
        let hu = Plan::preset(Kind::HeteroUnfused1D, &sc).lower(&sc);
        assert!(!hu
            .nodes
            .iter()
            .any(|n| matches!(n.kind, OpKind::Gather { .. } | OpKind::Scatter { .. })));
        assert_eq!(hu.n_gemms(), 8 * (1 + 8 * 7));
    }

    #[test]
    fn every_preset_validates_everywhere() {
        for (m, n, k, g) in [(4096, 1024, 2048, 8), (1009, 37, 977, 8), (17, 3, 1031, 3)] {
            let sc = Scenario::new("t", m, n, k).with_ngpus(g);
            for kind in Kind::ALL {
                let sched = Plan::preset(kind, &sc).lower(&sc);
                validate(&sched).unwrap_or_else(|e| panic!("{kind:?} {m}x{n}x{k}/{g}: {e}"));
            }
        }
    }

    #[test]
    fn novel_points_validate() {
        let sc = sc();
        let novel = [
            // Half-degree uniform fused.
            Plan {
                pieces: 4,
                shape: CommShape::Row,
                fused: true,
                head_start: false,
                mech: CommMech::Dma,
                slots: 7,
            },
            // Narrow-lane FiCCO (2 lanes, 8 pieces, head start).
            Plan {
                pieces: 8,
                shape: CommShape::Row,
                fused: true,
                head_start: true,
                mech: CommMech::Dma,
                slots: 2,
            },
            // Column-sharded with head start (not in the legacy six).
            Plan {
                pieces: 8,
                shape: CommShape::Col,
                fused: true,
                head_start: true,
                mech: CommMech::Dma,
                slots: 7,
            },
            // Unfused column decomposition.
            Plan {
                pieces: 4,
                shape: CommShape::Col,
                fused: false,
                head_start: false,
                mech: CommMech::Kernel,
                slots: 3,
            },
            // Over-decomposed: more pieces than shard rows.
            Plan {
                pieces: 16,
                shape: CommShape::Row,
                fused: false,
                head_start: true,
                mech: CommMech::Dma,
                slots: 1,
            },
        ];
        for plan in novel {
            let sched = plan.lower(&sc);
            validate(&sched).unwrap_or_else(|e| panic!("{}: {e}", plan.id()));
            assert!(sched.plan == Some(plan));
        }
    }

    #[test]
    fn deps_are_topologically_ordered_for_novel_points() {
        let sc = sc();
        let plan = Plan {
            pieces: 3,
            shape: CommShape::Row,
            fused: true,
            head_start: true,
            mech: CommMech::Dma,
            slots: 2,
        };
        let s = plan.lower(&sc);
        for (i, node) in s.nodes.iter().enumerate() {
            for &dep in &node.deps {
                assert!(dep < i, "node {i} deps on later node {dep}");
            }
        }
    }

    #[test]
    fn chained_lanes_serialize_transfers() {
        let sc = sc();
        let plan = Plan {
            pieces: 2,
            shape: CommShape::Row,
            fused: true,
            head_start: false,
            mech: CommMech::Dma,
            slots: 1,
        };
        let s = plan.lower(&sc);
        // Single lane: on each receiver, every transfer after the
        // first depends on the previous one.
        for gpu in 0..sc.ngpus {
            let xfer_ids: Vec<usize> = s
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| n.gpu == gpu && matches!(n.kind, OpKind::Xfer { .. }))
                .map(|(i, _)| i)
                .collect();
            assert_eq!(xfer_ids.len(), 2 * 7);
            for pair in xfer_ids.windows(2) {
                assert_eq!(s.nodes[pair[1]].deps, vec![pair[0]], "gpu {gpu}");
            }
        }
    }
}
