//! The parameterized FiCCO schedule-plan space.
//!
//! The six hard-coded [`Kind`]s materialize six points of the design
//! space the paper argues FiCCO opens up. A [`Plan`] names the axes of
//! that space explicitly:
//!
//! - **`pieces`** — decomposition degree: how many communication
//!   pieces each GPU's shard is split into (1 = shard-level, the
//!   paper's FiCCO schedules use `ngpus`, but nothing forces that);
//! - **`shape`** — 1D row-sharded ([`CommShape::Row`]) vs 2D
//!   column/K-sharded ([`CommShape::Col`]) communication;
//! - **`fused`** — whether each step's arrivals are gathered into one
//!   shard-sized GEMM (low DIL, pays gather/scatter copies) or each
//!   piece gets its own small GEMM (no copies, higher DIL);
//! - **`head_start`** — whether the local shard is computed
//!   immediately while remote pieces are still in flight;
//! - **`mech`** — communication mechanism (DMA offload vs
//!   GPU-core/RCCL-style copy kernels);
//! - **`slots`** — comm-slot width: how many per-peer transfer lanes
//!   each GPU drives concurrently (1 = single P2P stream, the
//!   AsyncTP-style constraint; `ngpus-1` = full-mesh lane per peer).
//!
//! [`lower`] turns any valid `Plan` into a [`Schedule`] through one
//! generator; each legacy `Kind` is a named preset point
//! ([`Plan::preset`]) whose lowering reproduces the legacy generator's
//! simulated makespan exactly (see `rust/tests/plan_parity.rs`). The
//! search subsystem ([`crate::search`]) evaluates this space against
//! the fluid simulator. See `DESIGN.md` §2 for the space's semantics
//! and invariants.

mod lower;
pub mod partition;

pub use lower::{lower, lower_opts};
pub use partition::Partition;

use crate::schedule::{Kind, Scenario, Schedule};
use crate::sim::CommMech;

/// Communication decomposition shape: which input dimension the
/// per-shard pieces split.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommShape {
    /// Split shard rows (1D buffers; outputs partition by row).
    Row,
    /// Split the reduction dimension K (2D buffers; accumulating
    /// GEMMs, no output scatter).
    Col,
}

impl CommShape {
    pub fn name(self) -> &'static str {
        match self {
            CommShape::Row => "row",
            CommShape::Col => "col",
        }
    }

    pub fn parse(s: &str) -> Option<CommShape> {
        match s {
            "row" => Some(CommShape::Row),
            "col" => Some(CommShape::Col),
            _ => None,
        }
    }
}

/// One point of the FiCCO schedule-plan space. Small, `Copy`, and
/// hashable so it can key evaluation caches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Plan {
    /// Communication pieces per shard (decomposition degree, ≥ 1).
    pub pieces: usize,
    /// 1D row vs 2D column communication shape.
    pub shape: CommShape,
    /// Fused per-step GEMM (with gather/scatter) vs per-piece GEMMs.
    pub fused: bool,
    /// Compute the local shard immediately at step 0.
    pub head_start: bool,
    /// Mechanism moving the pieces (DMA engines vs copy kernels).
    pub mech: CommMech,
    /// Concurrent transfer lanes per GPU (1..=ngpus-1).
    pub slots: usize,
}

impl Plan {
    /// The preset plan reproducing a legacy [`Kind`] on `sc` (the
    /// scenario supplies `ngpus` and the FiCCO mechanism; the
    /// PyTorch-stack baselines are pinned to core-driven comm exactly
    /// as the legacy executor pinned them).
    pub fn preset(kind: Kind, sc: &Scenario) -> Plan {
        let n = sc.ngpus;
        let full = n.saturating_sub(1).max(1);
        match kind {
            Kind::Baseline => Plan {
                pieces: 1,
                shape: CommShape::Row,
                fused: true,
                head_start: false,
                mech: CommMech::Kernel,
                slots: full,
            },
            Kind::ShardOverlap => Plan {
                pieces: 1,
                shape: CommShape::Row,
                fused: false,
                head_start: true,
                mech: CommMech::Kernel,
                slots: 1,
            },
            Kind::UniformFused1D => Plan {
                pieces: n,
                shape: CommShape::Row,
                fused: true,
                head_start: false,
                mech: sc.mech,
                slots: full,
            },
            Kind::HeteroFused1D => Plan {
                pieces: n,
                shape: CommShape::Row,
                fused: true,
                head_start: true,
                mech: sc.mech,
                slots: full,
            },
            Kind::HeteroUnfused1D => Plan {
                pieces: n,
                shape: CommShape::Row,
                fused: false,
                head_start: true,
                mech: sc.mech,
                slots: full,
            },
            Kind::UniformFused2D => Plan {
                pieces: n,
                shape: CommShape::Col,
                fused: true,
                head_start: false,
                mech: sc.mech,
                slots: full,
            },
        }
    }

    /// All six legacy presets for `sc`, in [`Kind::ALL`] order.
    pub fn presets(sc: &Scenario) -> Vec<Plan> {
        Kind::ALL.iter().map(|&k| Plan::preset(k, sc)).collect()
    }

    /// Structural validity of the plan for a machine of `ngpus` GPUs.
    pub fn check(&self, ngpus: usize) -> Result<(), String> {
        if ngpus < 2 {
            return Err(format!("plans need >= 2 GPUs, got {ngpus}"));
        }
        if self.pieces == 0 {
            return Err("pieces must be >= 1".into());
        }
        if self.pieces > Plan::MAX_PIECES {
            return Err(format!(
                "pieces {} exceeds the sanity cap {}",
                self.pieces,
                Plan::MAX_PIECES
            ));
        }
        let full = ngpus - 1;
        if self.slots == 0 || self.slots > full {
            return Err(format!("slots must be in 1..={full}, got {}", self.slots));
        }
        Ok(())
    }

    /// Sanity cap on the decomposition degree (a schedule has
    /// `O(ngpus² · pieces)` nodes; beyond this the simulation cost is
    /// absurd and the small-message ramp makes the plan hopeless).
    pub const MAX_PIECES: usize = 256;

    /// The legacy [`Kind`] this plan is classified as, used for
    /// reporting and for the isolated comm-leg closed form. Exact for
    /// the six presets; nearest-neighbour for the rest of the space.
    pub fn kind(&self) -> Kind {
        match (self.shape, self.pieces, self.head_start, self.fused) {
            (CommShape::Col, _, _, _) => Kind::UniformFused2D,
            (CommShape::Row, 1, false, true) => Kind::Baseline,
            (CommShape::Row, 1, true, false) if self.slots == 1 => Kind::ShardOverlap,
            (CommShape::Row, _, true, true) => Kind::HeteroFused1D,
            (CommShape::Row, _, true, false) => Kind::HeteroUnfused1D,
            (CommShape::Row, _, false, _) => Kind::UniformFused1D,
        }
    }

    /// Compact stable identifier, e.g. `row-d8-fused-hs-s7-dma`.
    pub fn id(&self) -> String {
        format!(
            "{}-d{}-{}-{}-s{}-{}",
            self.shape.name(),
            self.pieces,
            if self.fused { "fused" } else { "unfused" },
            if self.head_start { "hs" } else { "uni" },
            self.slots,
            self.mech.name(),
        )
    }

    /// Parse an [`Plan::id`]-formatted string back into a plan.
    pub fn parse_id(s: &str) -> Option<Plan> {
        let parts: Vec<&str> = s.split('-').collect();
        if parts.len() != 6 {
            return None;
        }
        let shape = CommShape::parse(parts[0])?;
        let pieces: usize = parts[1].strip_prefix('d')?.parse().ok()?;
        let fused = match parts[2] {
            "fused" => true,
            "unfused" => false,
            _ => return None,
        };
        let head_start = match parts[3] {
            "hs" => true,
            "uni" => false,
            _ => return None,
        };
        let slots: usize = parts[4].strip_prefix('s')?.parse().ok()?;
        let mech = CommMech::parse(parts[5])?;
        Some(Plan {
            pieces,
            shape,
            fused,
            head_start,
            mech,
            slots,
        })
    }

    /// Lower this plan for a scenario (see [`lower`]).
    pub fn lower(&self, sc: &Scenario) -> Schedule {
        lower(self, sc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sc() -> Scenario {
        Scenario::new("t", 4096, 1024, 2048)
    }

    #[test]
    fn presets_classify_back_to_their_kind() {
        let sc = sc();
        for kind in Kind::ALL {
            let p = Plan::preset(kind, &sc);
            assert_eq!(p.kind(), kind, "{kind:?}");
            assert!(p.check(sc.ngpus).is_ok(), "{kind:?}");
        }
    }

    #[test]
    fn preset_mech_pins_pytorch_baselines_to_kernel() {
        let mut s = sc();
        s.mech = CommMech::Dma;
        assert_eq!(Plan::preset(Kind::Baseline, &s).mech, CommMech::Kernel);
        assert_eq!(Plan::preset(Kind::ShardOverlap, &s).mech, CommMech::Kernel);
        assert_eq!(Plan::preset(Kind::UniformFused1D, &s).mech, CommMech::Dma);
    }

    #[test]
    fn id_round_trips() {
        let sc = sc();
        for kind in Kind::ALL {
            let p = Plan::preset(kind, &sc);
            assert_eq!(Plan::parse_id(&p.id()), Some(p), "{}", p.id());
        }
        let q = Plan {
            pieces: 3,
            shape: CommShape::Col,
            fused: false,
            head_start: true,
            mech: CommMech::Dma,
            slots: 2,
        };
        assert_eq!(q.id(), "col-d3-unfused-hs-s2-dma");
        assert_eq!(Plan::parse_id(&q.id()), Some(q));
        assert_eq!(Plan::parse_id("nonsense"), None);
        assert_eq!(Plan::parse_id("row-dx-fused-hs-s1-dma"), None);
    }

    #[test]
    fn check_rejects_degenerate_knobs() {
        let p = Plan::preset(Kind::UniformFused1D, &sc());
        assert!(p.check(1).is_err(), "single GPU");
        assert!(Plan { pieces: 0, ..p }.check(8).is_err());
        assert!(Plan { slots: 0, ..p }.check(8).is_err());
        assert!(Plan { slots: 8, ..p }.check(8).is_err(), "slots > n-1");
        assert!(Plan { pieces: 100_000, ..p }.check(8).is_err());
        assert!(Plan { slots: 3, pieces: 2, ..p }.check(8).is_ok());
    }
}
