//! First-class row partitions: who owns which rows of the global
//! input, and how each shard subdivides into communication pieces.
//!
//! Until this module existed the `M / ngpus` uniform-shard arithmetic
//! was recomputed independently in at least five layers (scenario byte
//! accounting, plan lowering, schedule validation, the numeric
//! executor, and the closed-form collective costs). A [`Partition`]
//! makes the row layout a single source of truth and — crucially —
//! lets it be *non-uniform*: EP/MoE expert routing skews how many
//! tokens each GPU owns, which breaks the AG↔A2A volume equivalence
//! the uniform path relies on (`DESIGN.md` §1).
//!
//! Contract (see `DESIGN.md` §5):
//!
//! - shard bounds are monotone with `bounds[0] == 0` and
//!   `bounds[ngpus] == m` — shards tile `[0, M)` exactly, so total
//!   bytes are conserved for any skew;
//! - piece sub-extents tile each shard exactly (balanced integer
//!   split within the shard);
//! - **`skew == 0` reproduces the legacy uniform floor arithmetic
//!   bit-for-bit**: `bounds[i] == i·m/n`, identical to
//!   `schedule::generate::split` — the frozen parity and golden tests
//!   stay byte-stable;
//! - skewed bounds are a pure function of `(m, ngpus, skew, seed)`
//!   (deterministic via [`crate::util::rng`]), so caches keyed on
//!   those inputs stay sound.
//!
//! The skew model is hot-expert / Zipf-style routing: GPU ranks are
//! deterministically shuffled by `seed` into a hotness order, and the
//! GPU at hotness position `r` receives weight `(r+1)^-skew`. `skew =
//! 0` is balanced routing; `skew = 1` gives the hottest expert a
//! harmonic-series share; larger values concentrate further.

use crate::util::rng::Rng;

/// Fixed-point scale for routing weights. At `skew == 0` every weight
/// is exactly `SCALE`, so cumulative bounds reduce to the uniform
/// `i·m/n` floor split.
const SCALE: u64 = 1 << 20;

/// Row layout of the global `M×K` input over `ngpus` GPUs, with each
/// shard subdivided into `pieces` communication pieces.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    /// Total rows partitioned.
    pub m: u64,
    pub ngpus: usize,
    /// Communication pieces per shard (decomposition degree, ≥ 1).
    pub pieces: usize,
    /// Shard row bounds: `bounds[q]..bounds[q+1]` is GPU `q`'s shard.
    bounds: Vec<u64>,
}

impl Partition {
    /// Balanced partition: GPU `q` owns rows `[q·m/n, (q+1)·m/n)` —
    /// exactly the legacy `generate::split` floor arithmetic.
    pub fn uniform(m: u64, ngpus: usize, pieces: usize) -> Partition {
        assert!(ngpus >= 1 && pieces >= 1);
        let bounds = (0..=ngpus as u64).map(|i| i * m / ngpus as u64).collect();
        Partition {
            m,
            ngpus,
            pieces,
            bounds,
        }
    }

    /// Skewed partition: Zipf-style hot-expert routing with exponent
    /// `skew` over a `seed`-shuffled hotness order. `skew == 0`
    /// returns [`Partition::uniform`] exactly (seed-independent).
    pub fn skewed(m: u64, ngpus: usize, pieces: usize, skew: f64, seed: u64) -> Partition {
        assert!(
            skew.is_finite() && skew >= 0.0,
            "skew must be finite and >= 0, got {skew}"
        );
        if skew == 0.0 {
            return Partition::uniform(m, ngpus, pieces);
        }
        assert!(ngpus >= 1 && pieces >= 1);
        // Deterministic hotness order: which GPU is the hot expert.
        let mut order: Vec<usize> = (0..ngpus).collect();
        let mut rng = Rng::new(seed ^ 0xF1CC0_5EED);
        rng.shuffle(&mut order);
        // Fixed-point Zipf weights (≥ 1 so no shard weight vanishes
        // entirely; empty shards can still arise for tiny m, which the
        // schedule layers tolerate as zero-area regions).
        let mut weights = vec![0u64; ngpus];
        for (hot_rank, &gpu) in order.iter().enumerate() {
            let w = ((hot_rank + 1) as f64).powf(-skew) * SCALE as f64;
            weights[gpu] = (w.round() as u64).max(1);
        }
        let total: u128 = weights.iter().map(|&w| w as u128).sum();
        let mut bounds = Vec::with_capacity(ngpus + 1);
        let mut cum: u128 = 0;
        bounds.push(0u64);
        for &w in &weights {
            cum += w as u128;
            bounds.push((m as u128 * cum / total) as u64);
        }
        Partition {
            m,
            ngpus,
            pieces,
            bounds,
        }
    }

    /// Row range of GPU `q`'s shard.
    pub fn shard_rows(&self, q: usize) -> (u64, u64) {
        (self.bounds[q], self.bounds[q + 1])
    }

    /// Rows in GPU `q`'s shard.
    pub fn shard_len(&self, q: usize) -> u64 {
        self.bounds[q + 1] - self.bounds[q]
    }

    /// Row range of piece `p` within GPU `q`'s shard (balanced
    /// sub-split — identical to the legacy nested `split` at any
    /// skew, applied to this shard's extent).
    pub fn piece_rows(&self, q: usize, p: usize) -> (u64, u64) {
        assert!(p < self.pieces);
        let (lo, hi) = self.shard_rows(q);
        let len = hi - lo;
        let (d, p) = (self.pieces as u64, p as u64);
        (lo + p * len / d, lo + (p + 1) * len / d)
    }

    /// Largest shard, in rows.
    pub fn max_shard(&self) -> u64 {
        (0..self.ngpus).map(|q| self.shard_len(q)).max().unwrap_or(0)
    }

    /// Mean shard, in rows.
    pub fn mean_shard(&self) -> f64 {
        self.m as f64 / self.ngpus as f64
    }

    /// Max/mean shard ratio — 1.0 for a balanced partition (up to the
    /// ±1-row floor rounding), growing with routing skew. The static
    /// heuristic reads this as its imbalance feature.
    pub fn imbalance(&self) -> f64 {
        if self.m == 0 {
            return 1.0;
        }
        self.max_shard() as f64 / self.mean_shard()
    }

    /// Rows GPU `q` must receive (everything outside its shard).
    pub fn rx_rows(&self, q: usize) -> u64 {
        self.m - self.shard_len(q)
    }

    /// Per-GPU shard sizes in bytes for a row of `row_bytes` bytes.
    pub fn shard_bytes_per_gpu(&self, row_bytes: f64) -> Vec<f64> {
        (0..self.ngpus)
            .map(|q| self.shard_len(q) as f64 * row_bytes)
            .collect()
    }

    /// Mean shard bytes — the uniform value, written with the exact
    /// expression the pre-partition `Scenario::shard_bytes` used so
    /// `skew == 0` byte accounting is bit-identical.
    pub fn mean_shard_bytes(&self, row_bytes_k: f64, elem_bytes: f64) -> f64 {
        (self.m as f64 / self.ngpus as f64) * row_bytes_k * elem_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_matches_legacy_split() {
        use crate::schedule::generate::split;
        for (m, n) in [(4096u64, 8usize), (1009, 8), (17, 3), (7, 8), (0, 4)] {
            let part = Partition::uniform(m, n, 4);
            for q in 0..n {
                let want = split(m, n as u64, q as u64);
                assert_eq!(part.shard_rows(q), want, "m={m} n={n} q={q}");
            }
            for q in 0..n {
                for p in 0..4 {
                    let (lo, hi) = part.shard_rows(q);
                    let (plo, phi) = split(hi - lo, 4, p as u64);
                    assert_eq!(part.piece_rows(q, p), (lo + plo, lo + phi));
                }
            }
        }
    }

    #[test]
    fn skew_zero_is_uniform_for_any_seed() {
        for seed in [0u64, 7, 0xDEAD] {
            assert_eq!(
                Partition::skewed(1009, 8, 3, 0.0, seed),
                Partition::uniform(1009, 8, 3)
            );
        }
    }

    #[test]
    fn skewed_bounds_tile_and_conserve() {
        for (m, n, skew, seed) in [
            (4096u64, 8usize, 0.5f64, 1u64),
            (1009, 8, 1.0, 2),
            (17, 3, 2.0, 3),
            (1_607_680, 8, 1.5, 4),
        ] {
            let part = Partition::skewed(m, n, 4, skew, seed);
            let mut covered = 0u64;
            let mut prev = 0u64;
            for q in 0..n {
                let (lo, hi) = part.shard_rows(q);
                assert_eq!(lo, prev, "contiguous at q={q}");
                assert!(hi >= lo);
                covered += hi - lo;
                prev = hi;
                // Pieces tile the shard.
                let mut piece_prev = lo;
                for p in 0..part.pieces {
                    let (plo, phi) = part.piece_rows(q, p);
                    assert_eq!(plo, piece_prev);
                    piece_prev = phi;
                }
                assert_eq!(piece_prev, hi);
            }
            assert_eq!(covered, m, "rows conserved");
            assert_eq!(prev, m, "full cover");
        }
    }

    #[test]
    fn skew_actually_skews_and_is_deterministic() {
        let a = Partition::skewed(65536, 8, 8, 1.0, 42);
        let b = Partition::skewed(65536, 8, 8, 1.0, 42);
        assert_eq!(a, b, "deterministic for a seed");
        assert!(a.imbalance() > 1.2, "imbalance {}", a.imbalance());
        assert!(
            a != Partition::uniform(65536, 8, 8),
            "skew 1.0 must move bounds"
        );
        // A different seed permutes the hotness order but keeps the
        // same weight profile (up to ±1-row floor rounding).
        let c = Partition::skewed(65536, 8, 8, 1.0, 43);
        assert!(
            (a.max_shard() as i64 - c.max_shard() as i64).abs() <= 1,
            "hotness profile must be seed-independent: {} vs {}",
            a.max_shard(),
            c.max_shard()
        );
    }

    #[test]
    fn higher_skew_concentrates_more() {
        let mild = Partition::skewed(1 << 20, 8, 8, 0.5, 9);
        let hot = Partition::skewed(1 << 20, 8, 8, 2.0, 9);
        assert!(hot.imbalance() > mild.imbalance());
        assert!(mild.imbalance() > 1.0);
    }

    #[test]
    fn byte_accounting_matches_row_accounting() {
        let part = Partition::skewed(4096, 8, 4, 1.0, 5);
        let per = part.shard_bytes_per_gpu(1024.0 * 2.0);
        let total: f64 = per.iter().sum();
        assert_eq!(total, 4096.0 * 1024.0 * 2.0);
        assert_eq!(part.rx_rows(0), 4096 - part.shard_len(0));
    }
}
