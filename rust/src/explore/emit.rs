//! Deterministic sweep-result emitters.
//!
//! [`CsvEmitter`] and [`JsonEmitter`] stream [`CellResult`]s as they
//! are delivered (the sweep runner already reorders completions into
//! cell order), producing a byte-identical `"results"` body for any
//! `--jobs` value: every number is formatted with Rust's
//! deterministic shortest-round-trip `Display`, and the
//! jobs-dependent wall-clock timings ride in a trailing `"telemetry"`
//! object that byte-compares strip via
//! [`crate::obs::canonical_artifact_view`]. [`summary`] condenses a
//! finished sweep into a [`metrics::Exhibit`] (geomean speedup per
//! machine × schedule kind) so sweep output plugs into the same
//! table/CSV tooling as the paper figures.

use std::io::{self, Write};

use super::{BestPlan, CellResult, CotenantCellResult, KindRow};
use crate::metrics::Exhibit;
use crate::obs::Telemetry;
use crate::schedule::Kind;
use crate::util::stats;
use crate::util::table::{f, Align, Table};

/// Bit-exact f64 serialization for the resume journal: the hex of
/// `to_bits`, parsed back with `from_bits` — round-trips every value
/// (negative zero, subnormals) exactly, which is what makes resumed
/// artifacts byte-identical to straight-through runs.
pub(crate) fn fbits(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

pub(crate) fn parse_fbits(s: &str) -> Option<f64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

/// Serialize one [`CellResult`] as a resume-journal record: one field
/// per line in struct order, floats as [`fbits`] hex so a resumed run
/// reproduces the original artifact byte-for-byte. The `-` sentinel
/// marks absent optionals (plan ids and kind names never equal `-`).
pub fn cell_record(c: &CellResult) -> String {
    let mut out = String::from("ficco-cell-v1\n");
    out.push_str(&format!("{}\n", c.index));
    out.push_str(&format!("{}\n", c.machine_name));
    out.push_str(&format!("{}\n", c.topology));
    out.push_str(&format!("{}\n", c.ngpus));
    out.push_str(&format!("{}\n", c.scenario));
    out.push_str(&format!("{}\n", c.collective));
    out.push_str(&format!("{}\n", c.mech));
    out.push_str(&format!("{}\n", fbits(c.skew)));
    out.push_str(&format!("{}\n{}\n{}\n", c.m, c.n, c.k));
    out.push_str(&format!("{}\n", c.pick.name()));
    out.push_str(&format!(
        "{}\n",
        c.oracle.map(Kind::name).unwrap_or("-")
    ));
    out.push_str(&format!("{}\n", fbits(c.ideal_speedup)));
    out.push_str(&format!("{}\n", fbits(c.eval_seconds)));
    match &c.best_plan {
        Some(b) => out.push_str(&format!("{} {}\n", b.id, fbits(b.speedup))),
        None => out.push_str("-\n"),
    }
    match &c.model_plan {
        Some(p) => out.push_str(&format!("{p}\n")),
        None => out.push_str("-\n"),
    }
    out.push_str(&format!("rows {}\n", c.rows.len()));
    for r in &c.rows {
        out.push_str(&format!(
            "{} {} {} {} {} {} {} {} {} {}\n",
            r.kind.name(),
            fbits(r.makespan),
            fbits(r.speedup),
            fbits(r.gemm_leg),
            fbits(r.comm_leg),
            fbits(r.gemm_cil),
            fbits(r.comm_cil),
            r.n_tasks,
            r.is_pick,
            r.is_oracle,
        ));
    }
    out.pop();
    out
}

/// Parse a [`cell_record`] payload. Any malformed/truncated/
/// version-mismatched record yields `None`, which resume treats as
/// "cell not done" — the fail-safe is re-running a cell, never
/// emitting corrupt data.
pub fn parse_cell_record(s: &str) -> Option<CellResult> {
    let mut lines = s.lines();
    if lines.next()? != "ficco-cell-v1" {
        return None;
    }
    let index = lines.next()?.parse().ok()?;
    let machine_name = lines.next()?.to_string();
    let topology = lines.next()?.to_string();
    let ngpus = lines.next()?.parse().ok()?;
    let scenario = lines.next()?.to_string();
    let collective = lines.next()?.to_string();
    let mech = lines.next()?.to_string();
    let skew = parse_fbits(lines.next()?)?;
    let m = lines.next()?.parse().ok()?;
    let n = lines.next()?.parse().ok()?;
    let k = lines.next()?.parse().ok()?;
    let pick = Kind::parse(lines.next()?)?;
    let oracle = match lines.next()? {
        "-" => None,
        name => Some(Kind::parse(name)?),
    };
    let ideal_speedup = parse_fbits(lines.next()?)?;
    let eval_seconds = parse_fbits(lines.next()?)?;
    let best_plan = match lines.next()? {
        "-" => None,
        line => {
            let (id, sp) = line.rsplit_once(' ')?;
            Some(BestPlan {
                id: id.to_string(),
                speedup: parse_fbits(sp)?,
            })
        }
    };
    let model_plan = match lines.next()? {
        "-" => None,
        p => Some(p.to_string()),
    };
    let nrows: usize = lines.next()?.strip_prefix("rows ")?.parse().ok()?;
    let mut rows = Vec::with_capacity(nrows);
    for _ in 0..nrows {
        let mut f = lines.next()?.split(' ');
        let row = KindRow {
            kind: Kind::parse(f.next()?)?,
            makespan: parse_fbits(f.next()?)?,
            speedup: parse_fbits(f.next()?)?,
            gemm_leg: parse_fbits(f.next()?)?,
            comm_leg: parse_fbits(f.next()?)?,
            gemm_cil: parse_fbits(f.next()?)?,
            comm_cil: parse_fbits(f.next()?)?,
            n_tasks: f.next()?.parse().ok()?,
            is_pick: f.next()?.parse().ok()?,
            is_oracle: f.next()?.parse().ok()?,
        };
        if f.next().is_some() {
            return None;
        }
        rows.push(row);
    }
    if lines.next().is_some() {
        return None;
    }
    Some(CellResult {
        index,
        machine_name,
        topology,
        ngpus,
        scenario,
        collective,
        mech,
        skew,
        m,
        n,
        k,
        pick,
        oracle,
        ideal_speedup,
        rows,
        best_plan,
        model_plan,
        eval_seconds,
    })
}

/// Column header shared by the CSV emitter and its tests. The
/// best-plan columns are filled only when the sweep ran with a
/// plan-space search (`--search`), and `model_pick` only when a
/// calibrated model was loaded (`--model`); they stay empty otherwise
/// so the artifact shape is stable.
pub const CSV_HEADER: &str = "scenario,machine,topology,ngpus,mech,collective,skew,m,n,k,kind,\
makespan,speedup,gemm_leg,comm_leg,gemm_cil,comm_cil,n_tasks,is_pick,is_oracle,\
best_plan,best_plan_speedup,model_pick";

/// RFC-4180-ish quoting for the free-form name fields (CLI-produced
/// names are comma-free, but `Scenario::new` is public API).
pub(crate) fn csv_escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// CSV rows (one per schedule kind) for a single cell.
pub fn csv_rows(c: &CellResult) -> String {
    let (best_plan, best_plan_speedup) = match &c.best_plan {
        Some(b) => (b.id.clone(), b.speedup.to_string()),
        None => (String::new(), String::new()),
    };
    let model_pick = c.model_plan.clone().unwrap_or_default();
    let mut out = String::new();
    for r in &c.rows {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            csv_escape(&c.scenario),
            csv_escape(&c.machine_name),
            c.topology,
            c.ngpus,
            c.mech,
            c.collective,
            c.skew,
            c.m,
            c.n,
            c.k,
            r.kind.name(),
            r.makespan,
            r.speedup,
            r.gemm_leg,
            r.comm_leg,
            r.gemm_cil,
            r.comm_cil,
            r.n_tasks,
            r.is_pick,
            r.is_oracle,
            best_plan,
            best_plan_speedup,
            model_pick,
        ));
    }
    out
}

pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One cell as a JSON object (rows nested under `"schedules"`).
pub fn json_cell(c: &CellResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\"scenario\":\"{}\",\"machine\":\"{}\",\"topology\":\"{}\",\"ngpus\":{},\
         \"mech\":\"{}\",\"collective\":\"{}\",\"skew\":{},\"m\":{},\"n\":{},\"k\":{},\
         \"heuristic_pick\":\"{}\",\"oracle\":{},\"ideal_speedup\":{},\
         \"best_plan\":{},\"model_pick\":{},\"schedules\":[",
        json_escape(&c.scenario),
        json_escape(&c.machine_name),
        c.topology,
        c.ngpus,
        c.mech,
        c.collective,
        c.skew,
        c.m,
        c.n,
        c.k,
        c.pick.name(),
        match c.oracle {
            Some(k) => format!("\"{}\"", k.name()),
            None => "null".to_string(),
        },
        c.ideal_speedup,
        match &c.best_plan {
            Some(b) => format!(
                "{{\"id\":\"{}\",\"speedup\":{}}}",
                json_escape(&b.id),
                b.speedup
            ),
            None => "null".to_string(),
        },
        match &c.model_plan {
            Some(p) => format!("\"{}\"", json_escape(p)),
            None => "null".to_string(),
        },
    ));
    for (i, r) in c.rows.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"kind\":\"{}\",\"makespan\":{},\"speedup\":{},\"gemm_leg\":{},\
             \"comm_leg\":{},\"gemm_cil\":{},\"comm_cil\":{},\"n_tasks\":{},\
             \"is_pick\":{},\"is_oracle\":{}}}",
            r.kind.name(),
            r.makespan,
            r.speedup,
            r.gemm_leg,
            r.comm_leg,
            r.gemm_cil,
            r.comm_cil,
            r.n_tasks,
            r.is_pick,
            r.is_oracle,
        ));
    }
    out.push_str("]}");
    out
}

/// Streams CSV rows cell by cell (header written on construction).
pub struct CsvEmitter<W: Write> {
    w: W,
}

impl<W: Write> CsvEmitter<W> {
    pub fn new(mut w: W) -> io::Result<CsvEmitter<W>> {
        writeln!(w, "{CSV_HEADER}")?;
        Ok(CsvEmitter { w })
    }

    pub fn cell(&mut self, c: &CellResult) -> io::Result<()> {
        self.w.write_all(csv_rows(c).as_bytes())
    }

    pub fn finish(mut self) -> io::Result<W> {
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Streams `{"results":[...],"telemetry":{...}}`: a deterministic
/// array of cell objects plus the run's [`Telemetry`] tail (supplied
/// at [`finish`](JsonEmitter::finish) time, after the pool has
/// joined).
pub struct JsonEmitter<W: Write> {
    w: W,
    count: usize,
}

impl<W: Write> JsonEmitter<W> {
    pub fn new(mut w: W) -> io::Result<JsonEmitter<W>> {
        w.write_all(b"{\"results\":[")?;
        Ok(JsonEmitter { w, count: 0 })
    }

    pub fn cell(&mut self, c: &CellResult) -> io::Result<()> {
        if self.count > 0 {
            self.w.write_all(b",")?;
        }
        self.w.write_all(b"\n")?;
        self.w.write_all(json_cell(c).as_bytes())?;
        self.count += 1;
        Ok(())
    }

    pub fn finish(mut self, telemetry: &Telemetry) -> io::Result<W> {
        self.w.write_all(b"\n],\n\"telemetry\":")?;
        self.w.write_all(telemetry.to_json().as_bytes())?;
        self.w.write_all(b"\n}\n")?;
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Condense a finished sweep into an exhibit: geomean speedup per
/// machine × schedule kind, plus heuristic hit rates per machine.
pub fn summary(cells: &[CellResult]) -> Exhibit {
    let mut machines: Vec<String> = Vec::new();
    for c in cells {
        if !machines.contains(&c.machine_name) {
            machines.push(c.machine_name.clone());
        }
    }
    let kinds: Vec<Kind> = match cells.first() {
        Some(c) => c.rows.iter().map(|r| r.kind).collect(),
        None => Vec::new(),
    };

    let mut table = {
        let mut headers = vec!["machine".to_string(), "cells".to_string()];
        headers.extend(kinds.iter().map(|k| k.name().to_string()));
        headers.push("hit rate".to_string());
        Table::new(headers).align(0, Align::Left)
    };
    let mut summaries = Vec::new();
    for mach in &machines {
        let group: Vec<&CellResult> = cells.iter().filter(|c| &c.machine_name == mach).collect();
        let mut row = vec![mach.clone(), group.len().to_string()];
        for &kind in &kinds {
            let speedups: Vec<f64> = group
                .iter()
                .filter_map(|c| c.rows.iter().find(|r| r.kind == kind))
                .map(|r| r.speedup)
                .collect();
            // A zero/NaN speedup cell is dropped from the geomean —
            // the cell and a `geomean_skipped_*` summary flag the
            // drop instead of skipping silently (the old behaviour
            // was an abort).
            let (g, skipped, cell) = stats::geomean_summary(&speedups);
            row.push(cell);
            if kind.is_ficco() {
                summaries.push((format!("geomean_{}_{}", mach, kind.name()), g));
                if skipped > 0 {
                    summaries.push((
                        format!("geomean_skipped_{}_{}", mach, kind.name()),
                        skipped as f64,
                    ));
                }
            }
        }
        // A cell is scoreable only when the oracle is meaningful: the
        // oracle is argmin over *evaluated* FiCCO kinds, so comparing
        // it against the pick requires the full FiCCO family to have
        // run (a one-kind `--kinds` filter would make every surviving
        // cell a trivial hit) and the pick itself to be among the
        // evaluated kinds.
        fn scoreable(c: &CellResult) -> bool {
            c.oracle.is_some()
                && Kind::FICCO
                    .iter()
                    .all(|k| c.rows.iter().any(|r| r.kind == *k))
                && c.rows.iter().any(|r| r.kind == c.pick)
        }
        let hits = group
            .iter()
            .filter(|c| scoreable(c) && c.oracle == Some(c.pick))
            .count();
        let scored = group.iter().filter(|c| scoreable(c)).count();
        // No scoreable cells (pick filtered out everywhere) is "no
        // data", not a 0% hit rate — print n/a and omit the summary.
        if scored == 0 {
            row.push("n/a".to_string());
        } else {
            let rate = hits as f64 / scored as f64;
            row.push(f(100.0 * rate, 0));
            summaries.push((format!("hit_rate_{mach}"), rate));
        }
        table.row(row);
    }
    Exhibit {
        title: "Sweep summary: geomean speedup over serial baseline",
        table,
        summaries,
    }
}

/// Column header for the co-tenant CSV (one row per tenant). The
/// robust columns are filled only under `--robust`; they stay empty
/// otherwise so the artifact shape is stable.
pub const COTENANT_CSV_HEADER: &str = "scenario,machine,topology,ngpus,mech,collective,skew,\
m,n,k,tenants,stagger,job,kind,plan,offset,isolated,makespan,slowdown,n_tasks,span,events,\
robust_p50,robust_p95,robust_worst";

/// CSV rows (one per tenant) for a single co-tenant cell.
pub fn cotenant_csv_rows(c: &CotenantCellResult) -> String {
    let (p50, p95, worst) = match &c.robust {
        Some(r) => (r.p50.to_string(), r.p95.to_string(), r.worst.to_string()),
        None => (String::new(), String::new(), String::new()),
    };
    let mut out = String::new();
    for j in &c.jobs {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            csv_escape(&c.scenario),
            csv_escape(&c.machine_name),
            c.topology,
            c.ngpus,
            c.mech,
            c.collective,
            c.skew,
            c.m,
            c.n,
            c.k,
            c.tenants,
            c.stagger,
            j.job,
            j.kind.name(),
            j.plan_id,
            j.offset,
            j.isolated,
            j.makespan,
            j.slowdown,
            j.n_tasks,
            c.span,
            c.events,
            p50,
            p95,
            worst,
        ));
    }
    out
}

/// One co-tenant cell as a JSON object (tenants nested under `"jobs"`).
pub fn cotenant_json_cell(c: &CotenantCellResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\"scenario\":\"{}\",\"machine\":\"{}\",\"topology\":\"{}\",\"ngpus\":{},\
         \"mech\":\"{}\",\"collective\":\"{}\",\"skew\":{},\"m\":{},\"n\":{},\"k\":{},\
         \"tenants\":{},\"stagger\":{},\"span\":{},\"events\":{},\"robust\":{},\"jobs\":[",
        json_escape(&c.scenario),
        json_escape(&c.machine_name),
        c.topology,
        c.ngpus,
        c.mech,
        c.collective,
        c.skew,
        c.m,
        c.n,
        c.k,
        c.tenants,
        c.stagger,
        c.span,
        c.events,
        match &c.robust {
            Some(r) => format!(
                "{{\"nominal\":{},\"p50\":{},\"p95\":{},\"worst\":{}}}",
                r.nominal, r.p50, r.p95, r.worst
            ),
            None => "null".to_string(),
        },
    ));
    for (i, j) in c.jobs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"job\":{},\"kind\":\"{}\",\"plan\":\"{}\",\"offset\":{},\"isolated\":{},\
             \"makespan\":{},\"slowdown\":{},\"n_tasks\":{}}}",
            j.job,
            j.kind.name(),
            json_escape(&j.plan_id),
            j.offset,
            j.isolated,
            j.makespan,
            j.slowdown,
            j.n_tasks,
        ));
    }
    out.push_str("]}");
    out
}

/// Streams co-tenant CSV rows cell by cell.
pub struct CotenantCsvEmitter<W: Write> {
    w: W,
}

impl<W: Write> CotenantCsvEmitter<W> {
    pub fn new(mut w: W) -> io::Result<CotenantCsvEmitter<W>> {
        writeln!(w, "{COTENANT_CSV_HEADER}")?;
        Ok(CotenantCsvEmitter { w })
    }

    pub fn cell(&mut self, c: &CotenantCellResult) -> io::Result<()> {
        self.w.write_all(cotenant_csv_rows(c).as_bytes())
    }

    pub fn finish(mut self) -> io::Result<W> {
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Streams `{"results":[...],"telemetry":{...}}` for co-tenant cells
/// — same canonical-view split as [`JsonEmitter`], so the byte-compare
/// tooling works unchanged.
pub struct CotenantJsonEmitter<W: Write> {
    w: W,
    count: usize,
}

impl<W: Write> CotenantJsonEmitter<W> {
    pub fn new(mut w: W) -> io::Result<CotenantJsonEmitter<W>> {
        w.write_all(b"{\"results\":[")?;
        Ok(CotenantJsonEmitter { w, count: 0 })
    }

    pub fn cell(&mut self, c: &CotenantCellResult) -> io::Result<()> {
        if self.count > 0 {
            self.w.write_all(b",")?;
        }
        self.w.write_all(b"\n")?;
        self.w.write_all(cotenant_json_cell(c).as_bytes())?;
        self.count += 1;
        Ok(())
    }

    pub fn finish(mut self, telemetry: &Telemetry) -> io::Result<W> {
        self.w.write_all(b"\n],\n\"telemetry\":")?;
        self.w.write_all(telemetry.to_json().as_bytes())?;
        self.w.write_all(b"\n}\n")?;
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Condense a finished co-tenant run into an exhibit: geomean
/// slowdown-vs-isolated per machine × tenant position, plus the
/// geomean joint-span stretch over tenant 0's isolated makespan.
pub fn cotenant_summary(cells: &[CotenantCellResult]) -> Exhibit {
    let mut machines: Vec<String> = Vec::new();
    for c in cells {
        if !machines.contains(&c.machine_name) {
            machines.push(c.machine_name.clone());
        }
    }
    let npos = cells.first().map(|c| c.jobs.len()).unwrap_or(0);
    let mut table = {
        let mut headers = vec!["machine".to_string(), "cells".to_string()];
        headers.extend((0..npos).map(|k| format!("job{k} slowdown")));
        headers.push("span stretch".to_string());
        Table::new(headers).align(0, Align::Left)
    };
    let mut summaries = Vec::new();
    for mach in &machines {
        let group: Vec<&CotenantCellResult> =
            cells.iter().filter(|c| &c.machine_name == mach).collect();
        let mut row = vec![mach.clone(), group.len().to_string()];
        for k in 0..npos {
            let slowdowns: Vec<f64> = group
                .iter()
                .filter_map(|c| c.jobs.get(k))
                .map(|j| j.slowdown)
                .collect();
            let (g, skipped, cell) = stats::geomean_summary(&slowdowns);
            row.push(cell);
            summaries.push((format!("geomean_slowdown_{mach}_job{k}"), g));
            if skipped > 0 {
                summaries.push((
                    format!("geomean_skipped_{mach}_job{k}"),
                    skipped as f64,
                ));
            }
        }
        // Joint-span stretch: how much longer the shared machine takes
        // to drain all tenants than tenant 0 alone would run.
        let stretches: Vec<f64> = group
            .iter()
            .filter(|c| !c.jobs.is_empty())
            .map(|c| c.span / c.jobs[0].isolated)
            .collect();
        let (g, _, cell) = stats::geomean_summary(&stretches);
        row.push(cell);
        summaries.push((format!("geomean_span_stretch_{mach}"), g));
        table.row(row);
    }
    Exhibit {
        title: "Co-tenant summary: geomean slowdown vs isolated, per tenant",
        table,
        summaries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::{eval_cell, SweepSpec};
    use crate::hw::Machine;
    use crate::schedule::Scenario;
    use crate::sim::CommMech;

    fn results() -> Vec<CellResult> {
        let spec = SweepSpec {
            scenarios: vec![Scenario::new("t", 8192, 512, 1024)],
            kinds: vec![Kind::UniformFused1D],
            machines: vec![("mi300x-8".into(), Machine::mi300x_8())],
            mechs: vec![CommMech::Dma],
            gpu_counts: Vec::new(),
            skews: Vec::new(),
            skew_seed: crate::explore::DEFAULT_SKEW_SEED,
            search: None,
            model: None,
        };
        spec.cells().iter().map(eval_cell).collect()
    }

    #[test]
    fn csv_shape_matches_header() {
        let rs = results();
        let ncols = CSV_HEADER.split(',').count();
        for line in csv_rows(&rs[0]).lines() {
            assert_eq!(line.split(',').count(), ncols, "{line}");
        }
    }

    #[test]
    fn emitters_stream_and_terminate() {
        let rs = results();
        let mut csv = CsvEmitter::new(Vec::new()).unwrap();
        let mut json = JsonEmitter::new(Vec::new()).unwrap();
        for c in &rs {
            csv.cell(c).unwrap();
            json.cell(c).unwrap();
        }
        let csv = String::from_utf8(csv.finish().unwrap()).unwrap();
        let json = String::from_utf8(json.finish(&Telemetry::default()).unwrap()).unwrap();
        assert!(csv.starts_with("scenario,machine"));
        assert_eq!(csv.lines().count(), 1 + rs[0].rows.len());
        assert!(json.starts_with("{\"results\":["));
        assert!(json.trim_end().ends_with('}'));
        assert!(json.contains("\n],\n\"telemetry\":"));
        assert!(json.contains("\"heuristic_pick\""));
        let canon = crate::obs::canonical_artifact_view(&json);
        assert!(canon.ends_with("\n]"));
        assert!(!canon.contains("telemetry"));
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("x\ny"), "x\\ny");
    }

    #[test]
    fn csv_escapes_awkward_names() {
        assert_eq!(csv_escape("g1"), "g1");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("q\"q"), "\"q\"\"q\"");
        // A comma-bearing scenario name keeps the column count stable.
        let spec = SweepSpec {
            scenarios: vec![Scenario::new("odd,name", 8192, 512, 1024)],
            kinds: vec![Kind::UniformFused1D],
            machines: vec![("mi300x-8".into(), Machine::mi300x_8())],
            mechs: vec![CommMech::Dma],
            gpu_counts: Vec::new(),
            skews: Vec::new(),
            skew_seed: crate::explore::DEFAULT_SKEW_SEED,
            search: None,
            model: None,
        };
        let r = eval_cell(&spec.cells()[0]);
        let ncols = CSV_HEADER.split(',').count();
        for line in csv_rows(&r).lines() {
            // Count columns respecting quotes.
            let mut cols = 1;
            let mut in_quotes = false;
            for ch in line.chars() {
                match ch {
                    '"' => in_quotes = !in_quotes,
                    ',' if !in_quotes => cols += 1,
                    _ => {}
                }
            }
            assert_eq!(cols, ncols, "{line}");
        }
    }

    #[test]
    fn every_kind_name_round_trips_through_parse() {
        for k in Kind::ALL {
            assert_eq!(Kind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn cell_record_round_trips_to_identical_emitter_bytes() {
        let mut c = results().remove(0);
        // Exercise the optional fields too.
        c.best_plan = Some(BestPlan {
            id: "row-d8-fused-hs-s7-dma".to_string(),
            speedup: 1.2345678901234567,
        });
        c.model_plan = Some("col-d4-fused-hs-s3-p2p".to_string());
        c.oracle = Some(Kind::UniformFused1D);
        for cell in [&results()[0], &c] {
            let rec = cell_record(cell);
            let back = parse_cell_record(&rec).expect("record parses");
            assert_eq!(csv_rows(&back), csv_rows(cell));
            assert_eq!(json_cell(&back), json_cell(cell));
            assert_eq!(back.index, cell.index);
            assert_eq!(fbits(back.eval_seconds), fbits(cell.eval_seconds));
        }
    }

    #[test]
    fn malformed_cell_records_parse_to_none() {
        let rec = cell_record(&results()[0]);
        assert!(parse_cell_record("").is_none());
        assert!(parse_cell_record("garbage").is_none());
        assert!(parse_cell_record(&rec[..rec.len() / 2]).is_none());
        assert!(parse_cell_record(&format!("{rec}\nextra")).is_none());
        let wrong_version = rec.replacen("ficco-cell-v1", "ficco-cell-v0", 1);
        assert!(parse_cell_record(&wrong_version).is_none());
    }

    #[test]
    fn fbits_round_trips_awkward_floats() {
        for v in [0.0, -0.0, 1.5, f64::MIN_POSITIVE / 2.0, 1e300, f64::INFINITY] {
            let back = parse_fbits(&fbits(v)).unwrap();
            assert_eq!(back.to_bits(), v.to_bits());
        }
        assert!(parse_fbits("shorty").is_none());
        assert!(parse_fbits("zzzzzzzzzzzzzzzz").is_none());
    }

    fn cotenant_results() -> Vec<CotenantCellResult> {
        let spec = SweepSpec {
            scenarios: vec![Scenario::new("t", 8192, 512, 1024)],
            kinds: vec![Kind::UniformFused1D],
            machines: vec![("mi300x-8".into(), Machine::mi300x_8())],
            mechs: vec![CommMech::Dma],
            gpu_counts: Vec::new(),
            skews: Vec::new(),
            skew_seed: crate::explore::DEFAULT_SKEW_SEED,
            search: None,
            model: None,
        };
        crate::explore::run_cotenant_cells(&spec.cells(), 2, 0.25, None, 1, |_| true).cells
    }

    #[test]
    fn cotenant_csv_shape_matches_header() {
        let rs = cotenant_results();
        let ncols = COTENANT_CSV_HEADER.split(',').count();
        for line in cotenant_csv_rows(&rs[0]).lines() {
            assert_eq!(line.split(',').count(), ncols, "{line}");
        }
        // Robust columns fill without changing the column count.
        let mut c = rs[0].clone();
        c.robust = Some(crate::schedule::exec::RobustStats {
            nominal: c.span,
            p50: c.span,
            p95: c.span * 1.1,
            worst: c.span * 1.2,
        });
        for line in cotenant_csv_rows(&c).lines() {
            assert_eq!(line.split(',').count(), ncols, "{line}");
        }
        assert!(cotenant_json_cell(&c).contains("\"robust\":{\"nominal\":"));
        assert!(cotenant_json_cell(&rs[0]).contains("\"robust\":null"));
    }

    #[test]
    fn cotenant_emitters_stream_and_terminate() {
        let rs = cotenant_results();
        let mut csv = CotenantCsvEmitter::new(Vec::new()).unwrap();
        let mut json = CotenantJsonEmitter::new(Vec::new()).unwrap();
        for c in &rs {
            csv.cell(c).unwrap();
            json.cell(c).unwrap();
        }
        let csv = String::from_utf8(csv.finish().unwrap()).unwrap();
        let json = String::from_utf8(json.finish(&Telemetry::default()).unwrap()).unwrap();
        assert!(csv.starts_with("scenario,machine"));
        assert_eq!(csv.lines().count(), 1 + rs[0].jobs.len());
        assert!(json.starts_with("{\"results\":["));
        assert!(json.contains("\n],\n\"telemetry\":"));
        assert!(json.contains("\"tenants\":2"));
        let canon = crate::obs::canonical_artifact_view(&json);
        assert!(canon.ends_with("\n]"));
        assert!(!canon.contains("telemetry"));
    }

    #[test]
    fn cotenant_summary_has_per_job_geomeans() {
        let rs = cotenant_results();
        let e = cotenant_summary(&rs);
        assert_eq!(e.table.n_rows(), 1);
        assert!(e.summary("geomean_slowdown_mi300x-8_job0") >= 1.0 - 1e-9);
        assert!(e.summary("geomean_slowdown_mi300x-8_job1") >= 1.0 - 1e-9);
        assert!(e.summary("geomean_span_stretch_mi300x-8") >= 1.0 - 1e-9);
    }

    #[test]
    fn summary_has_machine_rows_and_geomeans() {
        let rs = results();
        let e = summary(&rs);
        assert_eq!(e.table.n_rows(), 1);
        let g = e.summary("geomean_mi300x-8_uniform-fused-1D");
        assert!(g > 0.0);
    }
}
