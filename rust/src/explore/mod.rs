//! Parallel design-space sweep engine.
//!
//! The paper's thesis is that FiCCO "opens up a wider design space of
//! execution schedules than possible at shard-level alone" — which
//! only shows when the scenario × schedule × machine × mechanism ×
//! GPU-count space is swept *jointly*. This module turns that product
//! into an explicit work list and evaluates it concurrently:
//!
//! - [`SweepSpec`] names the axes: scenarios (Table I rows, synthetic
//!   suites, or custom shapes), schedule [`Kind`]s, machine presets
//!   (see [`Machine::preset_names`]), communication mechanisms, and
//!   GPU counts.
//! - [`SweepSpec::cells`] flattens the product into ordered
//!   [`Cell`]s; each cell is one (scenario, machine, mech, ngpus)
//!   point evaluated across every requested schedule kind (the serial
//!   baseline is always included as the speedup reference).
//! - [`run`] evaluates cells on the deterministic ordered worker pool
//!   ([`crate::util::pool`]). The fluid simulator is pure, so cells
//!   are embarrassingly parallel; the pool's reorder buffer delivers
//!   results to the caller in deterministic cell order regardless of
//!   `jobs`, which is what makes the CSV/JSON emitters ([`emit`])
//!   byte-stable under any parallelism.
//! - With [`SweepSpec::search`] set, each cell additionally searches
//!   the parameterized plan space ([`crate::search`]) and reports the
//!   best-found plan next to the fixed-kind rows.
//!
//! Per-cell wall time is measured ([`CellResult::eval_seconds`]) and
//! surfaced — together with the merged per-worker pipeline counters
//! ([`crate::obs::Counters`]) — in the report's `telemetry` block,
//! which the emitters append *outside* the byte-compared artifact
//! body (see [`crate::obs::canonical_artifact_view`]), so output
//! files stay reproducible while the timings stay inspectable.

pub mod emit;

use std::sync::Mutex;
use std::time::Instant;

use crate::hw::Machine;
use crate::obs::{Counters, Telemetry};
use crate::schedule::exec::{Evaluator, ScenarioEval};
use crate::schedule::{Kind, Scenario};
use crate::sim::CommMech;
use crate::workloads;

pub use crate::util::pool::{clamp_jobs, MAX_JOBS};

/// Default hotness seed for sweep-axis skew (kept stable so skewed
/// sweep artifacts are reproducible across runs and job counts).
pub const DEFAULT_SKEW_SEED: u64 = 2025;

/// The axes of one sweep: the cartesian product of everything listed.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Base scenarios (name, GEMM shape, collective). The mechanism
    /// and GPU count fields are overridden per cell.
    pub scenarios: Vec<Scenario>,
    /// Schedule kinds to evaluate. [`Kind::Baseline`] is implied.
    pub kinds: Vec<Kind>,
    /// Named machine presets.
    pub machines: Vec<(String, Machine)>,
    pub mechs: Vec<CommMech>,
    /// GPU-count overrides; empty means each machine's native count.
    pub gpu_counts: Vec<usize>,
    /// Expert-imbalance skew axis. Empty means `[0.0]` (balanced
    /// routing only, the legacy sweep). Applied to every base
    /// scenario that does not carry an intrinsic skew of its own
    /// (the `moe:` synthetic suite keeps its sampled skews).
    pub skews: Vec<f64>,
    /// Hotness seed for axis-applied skews.
    pub skew_seed: u64,
    /// When set, each cell also searches the parameterized plan space
    /// and the emitters fill the best-plan columns.
    pub search: Option<crate::search::SearchCfg>,
    /// When set, the per-cell static pick comes from this calibrated
    /// plan-space model (`--model`) instead of the frozen Fig-12a
    /// rule, and the emitters fill the `model_pick` column.
    pub model: Option<crate::heuristics::model::HeuristicModel>,
}

impl SweepSpec {
    /// The full paper suite: all Table I scenarios × every schedule
    /// kind × every machine preset × both mechanisms at native GPU
    /// counts.
    pub fn full_paper_suite() -> SweepSpec {
        SweepSpec {
            scenarios: workloads::table1().iter().map(|r| r.scenario()).collect(),
            kinds: Kind::ALL.to_vec(),
            machines: Machine::preset_names()
                .iter()
                .map(|&n| (n.to_string(), Machine::preset(n).unwrap()))
                .collect(),
            mechs: vec![CommMech::Dma, CommMech::Kernel],
            gpu_counts: Vec::new(),
            skews: Vec::new(),
            skew_seed: DEFAULT_SKEW_SEED,
            search: None,
            model: None,
        }
    }

    /// Build a spec from CLI-style comma-separated filters. Accepted:
    /// - scenarios: `table1`, `g1,g5,g13`, `synth:COUNT:SEED`,
    ///   `moe:COUNT:SEED` (skewed EP dispatch suite),
    ///   `holdout:COUNT:SEED` (calibration holdout suite)
    /// - kinds: `all` or schedule names (`uniform-fused-1D`, ...)
    /// - machines: `all` or preset names (`mi300x-8`, ...)
    /// - mechs: `dma`, `rccl` (alias `kernel`), or `dma,rccl`
    /// - gpus: `native` or counts like `4,8`
    /// - skews: expert-imbalance values like `0,0.6,1.2` (`0` =
    ///   balanced legacy routing)
    pub fn from_filters(
        scenarios: &str,
        kinds: &str,
        machines: &str,
        mechs: &str,
        gpus: &str,
        skews: &str,
    ) -> Result<SweepSpec, String> {
        let mut spec = SweepSpec {
            scenarios: Vec::new(),
            kinds: Vec::new(),
            machines: Vec::new(),
            mechs: Vec::new(),
            gpu_counts: Vec::new(),
            skews: Vec::new(),
            skew_seed: DEFAULT_SKEW_SEED,
            search: None,
            model: None,
        };

        for part in scenarios.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if part == "table1" {
                spec.scenarios
                    .extend(workloads::table1().iter().map(|r| r.scenario()));
            } else if let Some(rest) = part.strip_prefix("synth:") {
                let (count, seed) = rest
                    .split_once(':')
                    .ok_or_else(|| format!("bad synth filter '{part}' (want synth:COUNT:SEED)"))?;
                let count: usize = count
                    .parse()
                    .map_err(|_| format!("bad synth count in '{part}'"))?;
                let seed: u64 = seed
                    .parse()
                    .map_err(|_| format!("bad synth seed in '{part}'"))?;
                spec.scenarios
                    .extend(workloads::synthetic_scenarios(seed, count));
            } else if let Some(rest) = part.strip_prefix("moe:") {
                let (count, seed) = rest
                    .split_once(':')
                    .ok_or_else(|| format!("bad moe filter '{part}' (want moe:COUNT:SEED)"))?;
                let count: usize = count
                    .parse()
                    .map_err(|_| format!("bad moe count in '{part}'"))?;
                let seed: u64 = seed
                    .parse()
                    .map_err(|_| format!("bad moe seed in '{part}'"))?;
                spec.scenarios
                    .extend(workloads::synthetic_moe_scenarios(seed, count));
            } else if let Some(rest) = part.strip_prefix("holdout:") {
                let (count, seed) = rest.split_once(':').ok_or_else(|| {
                    format!("bad holdout filter '{part}' (want holdout:COUNT:SEED)")
                })?;
                let count: usize = count
                    .parse()
                    .map_err(|_| format!("bad holdout count in '{part}'"))?;
                let seed: u64 = seed
                    .parse()
                    .map_err(|_| format!("bad holdout seed in '{part}'"))?;
                spec.scenarios
                    .extend(workloads::holdout_scenarios(seed, count));
            } else if let Some(sc) = workloads::by_name(part) {
                spec.scenarios.push(sc);
            } else {
                return Err(format!(
                    "unknown scenario '{part}' (try one of {}, table1, synth:N:SEED, moe:N:SEED, \
                     holdout:N:SEED)",
                    workloads::names().join("/")
                ));
            }
        }
        // Drop exact duplicates (e.g. `--scenarios table1,g1`) so no
        // scenario is double-weighted in the emitted rows and
        // summary geomeans. Identity is (name, shape, collective,
        // intrinsic skew): same-named synthetic scenarios from
        // different seeds differ in shape and are kept.
        let mut uniq: Vec<Scenario> = Vec::with_capacity(spec.scenarios.len());
        for sc in spec.scenarios {
            let dup = uniq.iter().any(|u| {
                u.name == sc.name
                    && u.gemm == sc.gemm
                    && u.collective == sc.collective
                    && u.skew == sc.skew
            });
            if !dup {
                uniq.push(sc);
            }
        }
        spec.scenarios = uniq;

        for part in skews.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let skew: f64 = part
                .parse()
                .map_err(|_| format!("bad skew '{part}' (want e.g. 0,0.6,1.2)"))?;
            if !skew.is_finite() || skew < 0.0 {
                return Err(format!("skew must be finite and >= 0, got '{part}'"));
            }
            if !spec.skews.contains(&skew) {
                spec.skews.push(skew);
            }
        }

        for part in kinds.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if part == "all" {
                spec.kinds.extend(Kind::ALL);
            } else if part == "ficco" {
                spec.kinds.extend(Kind::FICCO);
            } else {
                spec.kinds.push(
                    Kind::parse(part).ok_or_else(|| format!("unknown schedule kind '{part}'"))?,
                );
            }
        }

        for part in machines.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if part == "all" {
                for &n in Machine::preset_names() {
                    if !spec.machines.iter().any(|(have, _)| have == n) {
                        spec.machines
                            .push((n.to_string(), Machine::preset(n).unwrap()));
                    }
                }
            } else {
                let m = Machine::preset(part).ok_or_else(|| {
                    format!(
                        "unknown machine '{part}' (presets: {})",
                        Machine::preset_names().join(", ")
                    )
                })?;
                if !spec.machines.iter().any(|(have, _)| have == part) {
                    spec.machines.push((part.to_string(), m));
                }
            }
        }

        for part in mechs.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let mech =
                CommMech::parse(part).ok_or_else(|| format!("unknown mechanism '{part}'"))?;
            if !spec.mechs.contains(&mech) {
                spec.mechs.push(mech);
            }
        }

        let mut saw_native = false;
        for part in gpus.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if part == "native" {
                saw_native = true;
                continue;
            }
            let n: usize = part
                .parse()
                .map_err(|_| format!("bad GPU count '{part}'"))?;
            if n < 2 {
                return Err(format!("GPU count must be >= 2, got {n}"));
            }
            if !spec.gpu_counts.contains(&n) {
                spec.gpu_counts.push(n);
            }
        }
        if saw_native && !spec.gpu_counts.is_empty() {
            return Err(
                "cannot mix 'native' with explicit GPU counts in --gpus (native varies per \
                 machine; list the counts you want instead)"
                    .into(),
            );
        }

        if spec.scenarios.is_empty() {
            return Err("no scenarios selected".into());
        }
        if spec.kinds.is_empty() {
            return Err("no schedule kinds selected".into());
        }
        if spec.machines.is_empty() {
            return Err("no machines selected".into());
        }
        if spec.mechs.is_empty() {
            return Err("no mechanisms selected".into());
        }
        Ok(spec)
    }

    /// Requested kinds with the serial baseline first and duplicates
    /// removed (evaluation order within a cell).
    fn eval_kinds(&self) -> Vec<Kind> {
        let mut kinds = vec![Kind::Baseline];
        for &k in &self.kinds {
            if !kinds.contains(&k) {
                kinds.push(k);
            }
        }
        kinds
    }

    /// The effective skew axis: empty means balanced routing only.
    fn skew_axis(&self) -> Vec<f64> {
        if self.skews.is_empty() {
            vec![0.0]
        } else {
            self.skews.clone()
        }
    }

    /// Flatten the product into ordered evaluation cells:
    /// machine-major, then GPU count, then mechanism, then skew, then
    /// scenario. An axis skew is applied only to base scenarios with
    /// no intrinsic skew of their own (the `moe:` suite samples its
    /// own routing factors, which the axis must not clobber);
    /// intrinsically-skewed scenarios are emitted once per
    /// (machine, count, mech) — on the first axis value only — so a
    /// multi-valued `--skew` never duplicates their cells.
    pub fn cells(&self) -> Vec<Cell> {
        let kinds = self.eval_kinds();
        let mut cells = Vec::new();
        for (machine_name, machine) in &self.machines {
            let counts: Vec<usize> = if self.gpu_counts.is_empty() {
                vec![machine.ngpus()]
            } else {
                self.gpu_counts.clone()
            };
            for &ngpus in &counts {
                for &mech in &self.mechs {
                    for (si, &skew) in self.skew_axis().iter().enumerate() {
                        for base in &self.scenarios {
                            if base.skew != 0.0 && si > 0 {
                                continue;
                            }
                            let mut machine = machine.clone();
                            machine.topo.ngpus = ngpus;
                            let mut scenario = base.clone();
                            scenario.ngpus = ngpus;
                            scenario.mech = mech;
                            if scenario.skew == 0.0 {
                                scenario.skew = skew;
                                scenario.skew_seed = self.skew_seed;
                            }
                            cells.push(Cell {
                                index: cells.len(),
                                machine_name: machine_name.clone(),
                                machine,
                                scenario,
                                kinds: kinds.clone(),
                                search: self.search,
                                model: self.model.clone(),
                            });
                        }
                    }
                }
            }
        }
        cells
    }

    /// Number of evaluation cells, without materializing them.
    pub fn n_cells(&self) -> usize {
        let counts_per_machine = if self.gpu_counts.is_empty() {
            1
        } else {
            self.gpu_counts.len()
        };
        // Unskewed scenarios multiply by the skew axis; intrinsically
        // skewed ones appear once (see `cells`).
        let unskewed = self.scenarios.iter().filter(|s| s.skew == 0.0).count();
        let skewed = self.scenarios.len() - unskewed;
        self.machines.len()
            * counts_per_machine
            * self.mechs.len()
            * (self.skew_axis().len() * unskewed + skewed)
    }

    /// Number of (cell × kind) points the sweep will evaluate.
    pub fn n_points(&self) -> usize {
        self.n_cells() * self.eval_kinds().len()
    }
}

/// One evaluation unit: a scenario pinned to a machine, mechanism and
/// GPU count, measured across `kinds`.
#[derive(Debug, Clone)]
pub struct Cell {
    pub index: usize,
    pub machine_name: String,
    pub machine: Machine,
    pub scenario: Scenario,
    pub kinds: Vec<Kind>,
    /// Plan-space search configuration (None = fixed kinds only).
    pub search: Option<crate::search::SearchCfg>,
    /// Calibrated decision model for the static pick (None = the
    /// frozen Fig-12a rule, the bit-stable legacy path).
    pub model: Option<crate::heuristics::model::HeuristicModel>,
}

/// One schedule kind's measurements within a cell.
#[derive(Debug, Clone)]
pub struct KindRow {
    pub kind: Kind,
    pub makespan: f64,
    /// Baseline makespan / this makespan.
    pub speedup: f64,
    pub gemm_leg: f64,
    pub comm_leg: f64,
    pub gemm_cil: f64,
    pub comm_cil: f64,
    pub n_tasks: usize,
    /// This kind is the heuristic's static pick for the cell.
    pub is_pick: bool,
    /// This kind is the simulated-best FiCCO schedule for the cell.
    pub is_oracle: bool,
}

/// Deterministic result of one cell (plus its non-deterministic wall
/// time, which the emitters exclude).
#[derive(Debug, Clone)]
pub struct CellResult {
    pub index: usize,
    pub machine_name: String,
    pub topology: String,
    pub ngpus: usize,
    pub scenario: String,
    pub collective: String,
    pub mech: String,
    /// Expert-imbalance routing skew of the evaluated cell (0 =
    /// balanced legacy routing).
    pub skew: f64,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// Heuristic pick (recorded even when not among evaluated kinds).
    pub pick: Kind,
    /// Simulated-best FiCCO kind, when any FiCCO kind was evaluated.
    pub oracle: Option<Kind>,
    pub ideal_speedup: f64,
    pub rows: Vec<KindRow>,
    /// Best plan found by searching the parameterized plan space
    /// (None when the sweep ran without `--search`).
    pub best_plan: Option<BestPlan>,
    /// Full plan predicted by the calibrated model (None when the
    /// sweep ran without `--model`; then `pick` is the frozen rule's).
    pub model_plan: Option<String>,
    pub eval_seconds: f64,
}

/// The best-found plan-space point of one cell.
#[derive(Debug, Clone)]
pub struct BestPlan {
    /// Stable plan identifier (see [`crate::plan::Plan::id`]).
    pub id: String,
    /// Speedup over the cell's serial baseline.
    pub speedup: f64,
}

/// Evaluate one cell (generate → validate → simulate each kind) —
/// one-shot wrapper over [`eval_cell_in`].
pub fn eval_cell(cell: &Cell) -> CellResult {
    eval_cell_in(&mut Evaluator::new(), cell)
}

/// Evaluate one cell through a caller-owned reusable
/// [`Evaluator`] arena (the sweep workers pass one per worker
/// thread, so consecutive cells on a worker share the simulator
/// skeleton and its warmed scratch buffers).
pub fn eval_cell_in(ev: &mut Evaluator, cell: &Cell) -> CellResult {
    let t0 = Instant::now();
    ev.counters.cells += 1;
    let machine = &cell.machine;
    let sc = &cell.scenario;
    // Static pick: the calibrated model's full-plan prediction when
    // one is loaded, else the frozen Fig-12a rule (bit-identical to
    // the pre-model sweep artifacts).
    let (pick, pick_plan) = match &cell.model {
        Some(model) => {
            let d = model.predict(machine, sc);
            (d.kind, d.plan)
        }
        None => {
            let pick = crate::heuristics::pick(machine, sc).pick;
            (pick, crate::plan::Plan::preset(pick, sc))
        }
    };
    let model_plan = cell.model.as_ref().map(|_| pick_plan.id());
    let scev = ScenarioEval::run_in(ev, machine, sc, &cell.kinds);
    let oracle = scev.best_ficco().map(|(k, _)| k);
    // Optional plan-space search. The cache is per-cell (the emitted
    // best-plan values are cache-independent either way) but seeded
    // with the fixed-kind rows just measured: preset plans lower to
    // the exact schedules `ScenarioEval` simulated, so the search
    // never re-simulates them. The same rows seed the cell-scope
    // incumbent (they are true candidate makespans of this cell), and
    // the static pick seeds the warm search order.
    let best_plan = cell.search.as_ref().map(|cfg| {
        let space = crate::search::SpaceSpec::default_for(sc);
        let cache = crate::search::EvalCache::new();
        ev.begin_cell(sc);
        for r in &scev.results {
            let preset = crate::plan::Plan::preset(r.kind, sc);
            cache.insert(&cell.machine_name, sc, &preset, r.makespan);
            ev.note_cell_incumbent(preset, r.makespan);
        }
        let cfg = crate::search::SearchCfg {
            predicted: cfg.predicted.or(Some(pick_plan)),
            ..*cfg
        };
        let out =
            crate::search::search_in(ev, &cell.machine_name, machine, sc, &space, &cfg, &cache);
        // Robust selection (`--robust`): re-rank the nominal
        // survivors under the perturbation ensemble (inside the cell
        // scope, so perturbed lowering reuses the memoized
        // partitions) and report the robust winner as the cell's
        // best plan. With robust off the nominal arm below keeps the
        // artifact bytes unchanged.
        let best = match &cfg.robust {
            Some(rc) => {
                let rp = crate::search::robust_rerank(ev, machine, sc, &out, rc);
                BestPlan {
                    id: rp.plan.id(),
                    speedup: out.baseline / rp.nominal,
                }
            }
            None => BestPlan {
                id: out.best.plan.id(),
                speedup: out.best_speedup(),
            },
        };
        ev.end_cell();
        best
    });
    let rows = scev
        .results
        .iter()
        .map(|r| KindRow {
            kind: r.kind,
            makespan: r.makespan,
            speedup: scev.baseline / r.makespan,
            gemm_leg: r.gemm_leg,
            comm_leg: r.comm_leg,
            gemm_cil: r.gemm_cil,
            comm_cil: r.comm_cil,
            n_tasks: r.n_tasks,
            is_pick: r.kind == pick,
            is_oracle: oracle == Some(r.kind),
        })
        .collect();
    CellResult {
        index: cell.index,
        machine_name: cell.machine_name.clone(),
        topology: machine.topo.kind.name().to_string(),
        ngpus: sc.ngpus,
        scenario: sc.name.clone(),
        collective: sc.collective.name().to_string(),
        mech: sc.mech.name().to_string(),
        skew: sc.skew,
        m: sc.gemm.m,
        n: sc.gemm.n,
        k: sc.gemm.k,
        pick,
        oracle,
        ideal_speedup: scev.ideal_speedup(),
        rows,
        best_plan,
        model_plan,
        eval_seconds: t0.elapsed().as_secs_f64(),
    }
}

/// Timing and results of one sweep run.
#[derive(Debug)]
pub struct SweepReport {
    pub jobs: usize,
    /// Cell results in deterministic cell order.
    pub cells: Vec<CellResult>,
    /// Cells whose worker panicked, by original cell index: the rest
    /// of the sweep completed, the driver reports these and exits
    /// nonzero instead of tearing the whole run down.
    pub failures: Vec<crate::util::pool::ItemPanic>,
    pub wall_seconds: f64,
    /// Merged per-worker counters + timings (jobs-dependent; excluded
    /// from the byte-compared artifact body). Sweep cells use
    /// per-cell caches, so the shared-cache fields stay zero here.
    pub telemetry: Telemetry,
}

impl SweepReport {
    pub fn n_points(&self) -> usize {
        self.cells.iter().map(|c| c.rows.len()).sum()
    }

    /// Sum of per-cell evaluation times (the serial-work proxy the
    /// `sweep_throughput` bench compares wall time against).
    pub fn cpu_seconds(&self) -> f64 {
        self.cells.iter().map(|c| c.eval_seconds).sum()
    }
}

/// Run the sweep on `jobs` worker threads of the ordered pool
/// ([`crate::util::pool::run_ordered_stateful`], one reusable
/// evaluator arena per worker). `on_cell` is invoked once
/// per cell *in deterministic cell order* as soon as the ordered
/// prefix is complete — out-of-order completions are buffered — so
/// incremental emitters produce identical bytes for any `jobs`.
///
/// `on_cell` returns whether to continue: `false` cancels the sweep
/// (e.g. an emitter hit ENOSPC) — dispatch stops, in-flight cells
/// are allowed to finish but are discarded, and the report carries
/// exactly the cells that were delivered to `on_cell` (so a
/// cancelled report is as deterministic as a completed one).
pub fn run<F: FnMut(&CellResult) -> bool>(
    spec: &SweepSpec,
    jobs: usize,
    on_cell: F,
) -> SweepReport {
    run_cells(&spec.cells(), jobs, on_cell)
}

/// [`run`] over an explicit cell subset — the `--resume` path skips
/// journaled cells and sweeps only the remainder. `failures` (and the
/// journal records written by the caller) carry each cell's original
/// `Cell::index`, not its position in the subset.
pub fn run_cells<F: FnMut(&CellResult) -> bool>(
    cells: &[Cell],
    jobs: usize,
    mut on_cell: F,
) -> SweepReport {
    let merged = Mutex::new(Counters::default());
    let t0 = Instant::now();
    // One reusable evaluator arena per worker: cells on a worker
    // share the simulator skeleton and scratch (speed only — every
    // cell's numbers are a pure function of the cell). Each worker's
    // telemetry counters merge once, at join.
    let pool_run = crate::util::pool::run_ordered_with(
        cells,
        jobs,
        Evaluator::new,
        |ev, _, cell| eval_cell_in(ev, cell),
        |ev: Evaluator| merged.lock().unwrap().merge(&ev.counters),
        |_, result| on_cell(result),
    );
    let wall_seconds = t0.elapsed().as_secs_f64();
    let failures = pool_run
        .failures
        .iter()
        .map(|f| crate::util::pool::ItemPanic {
            index: cells[f.index].index,
            message: f.message.clone(),
        })
        .collect();
    let telemetry = Telemetry {
        jobs: pool_run.jobs,
        wall_seconds,
        counters: *merged.lock().unwrap(),
        cache_hits: 0,
        cache_misses: 0,
        cache_shards: Vec::new(),
        cell_seconds: pool_run.results.iter().map(|c| c.eval_seconds).collect(),
    };
    SweepReport {
        jobs: pool_run.jobs,
        cells: pool_run.results,
        failures,
        wall_seconds,
        telemetry,
    }
}

/// One tenant's measurements within a co-tenant cell (ISSUE 10).
#[derive(Debug, Clone)]
pub struct CotenantJobRow {
    /// Tenant index (admission order).
    pub job: usize,
    pub kind: Kind,
    pub plan_id: String,
    /// Virtual time the tenant was admitted at.
    pub offset: f64,
    /// Isolated (solo) makespan of the tenant's plan.
    pub isolated: f64,
    /// Co-tenant makespan (admission to last task finish).
    pub makespan: f64,
    /// Interference slowdown, `makespan / isolated`.
    pub slowdown: f64,
    pub n_tasks: usize,
}

/// Deterministic result of one co-tenant cell: N tenants of the same
/// scenario admitted at staggered offsets into one shared-machine
/// simulation, each measured against its isolated run.
#[derive(Debug, Clone)]
pub struct CotenantCellResult {
    pub index: usize,
    pub machine_name: String,
    pub topology: String,
    pub ngpus: usize,
    pub scenario: String,
    pub collective: String,
    pub mech: String,
    pub skew: f64,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub tenants: usize,
    /// Admission stagger as a fraction of tenant 0's isolated
    /// makespan (tenant k is admitted at `k * stagger * isolated_0`).
    pub stagger: f64,
    /// Joint span: virtual time the last tenant finished.
    pub span: f64,
    /// Events processed by the joint simulation.
    pub events: usize,
    pub jobs: Vec<CotenantJobRow>,
    /// Joint-span statistics under the perturbation ensemble
    /// (None when the run was nominal-only).
    pub robust: Option<crate::schedule::exec::RobustStats>,
    pub eval_seconds: f64,
}

/// The co-tenant job list one cell evaluates: per-tenant plans with
/// their schedule kinds, admitted at `k * stagger * isolated_0`.
/// The cell's requested kinds (baseline excluded — it is the speedup
/// reference, not a tenant) cycle across the `tenants` jobs; with a
/// calibrated model loaded, every tenant runs the model's predicted
/// plan instead. `stagger = 0` admits every tenant at t = 0 and
/// `stagger >= 1` serializes them.
pub fn cotenant_jobs_for(
    ev: &mut Evaluator,
    cell: &Cell,
    tenants: usize,
    stagger: f64,
) -> Vec<(Kind, crate::schedule::exec::CotenantJob)> {
    use crate::schedule::exec::CotenantJob;
    assert!(tenants >= 1, "co-tenant evaluation needs >= 1 tenant");
    assert!(
        stagger.is_finite() && stagger >= 0.0,
        "stagger must be finite and >= 0"
    );
    let machine = &cell.machine;
    let sc = &cell.scenario;
    let assigned: Vec<(Kind, crate::plan::Plan)> = match &cell.model {
        Some(model) => {
            let d = model.predict(machine, sc);
            (0..tenants).map(|_| (d.kind, d.plan.clone())).collect()
        }
        None => {
            let mut kinds: Vec<Kind> = cell
                .kinds
                .iter()
                .copied()
                .filter(|&k| k != Kind::Baseline)
                .collect();
            if kinds.is_empty() {
                kinds.push(Kind::Baseline);
            }
            (0..tenants)
                .map(|t| {
                    let k = kinds[t % kinds.len()];
                    (k, crate::plan::Plan::preset(k, sc))
                })
                .collect()
        }
    };
    let iso0 = ev.plan_makespan(machine, sc, &assigned[0].1);
    assigned
        .into_iter()
        .enumerate()
        .map(|(t, (kind, plan))| {
            (
                kind,
                CotenantJob {
                    scenario: sc.clone(),
                    plan,
                    offset: t as f64 * stagger * iso0,
                },
            )
        })
        .collect()
}

/// Evaluate one co-tenant cell through a reusable [`Evaluator`]
/// arena (see [`cotenant_jobs_for`] for how tenants get their plans
/// and admission offsets).
pub fn eval_cotenant_cell_in(
    ev: &mut Evaluator,
    cell: &Cell,
    tenants: usize,
    stagger: f64,
    robust: Option<&crate::hw::Perturbation>,
) -> CotenantCellResult {
    use crate::schedule::exec::CotenantJob;
    let t0 = Instant::now();
    ev.counters.cells += 1;
    let machine = &cell.machine;
    let tagged = cotenant_jobs_for(ev, cell, tenants, stagger);
    let jobs: Vec<CotenantJob> = tagged.iter().map(|(_, j)| j.clone()).collect();
    let co = ev.cotenant(machine, &jobs);
    let robust = robust.map(|ens| ev.cotenant_robust_span(machine, &jobs, ens, co.span));
    let sc = &cell.scenario;
    let rows = tagged
        .iter()
        .zip(&co.jobs)
        .enumerate()
        .map(|(t, ((kind, job), j))| CotenantJobRow {
            job: t,
            kind: *kind,
            plan_id: job.plan.id(),
            offset: j.offset,
            isolated: j.isolated,
            makespan: j.makespan,
            slowdown: j.slowdown,
            n_tasks: j.n_tasks,
        })
        .collect();
    CotenantCellResult {
        index: cell.index,
        machine_name: cell.machine_name.clone(),
        topology: machine.topo.kind.name().to_string(),
        ngpus: sc.ngpus,
        scenario: sc.name.clone(),
        collective: sc.collective.name().to_string(),
        mech: sc.mech.name().to_string(),
        skew: sc.skew,
        m: sc.gemm.m,
        n: sc.gemm.n,
        k: sc.gemm.k,
        tenants,
        stagger,
        span: co.span,
        events: co.events,
        jobs: rows,
        robust,
        eval_seconds: t0.elapsed().as_secs_f64(),
    }
}

/// Timing and results of one co-tenant run.
#[derive(Debug)]
pub struct CotenantReport {
    pub jobs: usize,
    pub cells: Vec<CotenantCellResult>,
    pub failures: Vec<crate::util::pool::ItemPanic>,
    pub wall_seconds: f64,
    pub telemetry: Telemetry,
}

/// Run co-tenant cells on the deterministic ordered worker pool —
/// same delivery/cancellation contract as [`run_cells`], so the
/// emitters produce identical bytes for any `jobs` value.
pub fn run_cotenant_cells<F: FnMut(&CotenantCellResult) -> bool>(
    cells: &[Cell],
    tenants: usize,
    stagger: f64,
    robust: Option<&crate::hw::Perturbation>,
    jobs: usize,
    mut on_cell: F,
) -> CotenantReport {
    let merged = Mutex::new(Counters::default());
    let t0 = Instant::now();
    let pool_run = crate::util::pool::run_ordered_with(
        cells,
        jobs,
        Evaluator::new,
        |ev, _, cell| eval_cotenant_cell_in(ev, cell, tenants, stagger, robust),
        |ev: Evaluator| merged.lock().unwrap().merge(&ev.counters),
        |_, result| on_cell(result),
    );
    let wall_seconds = t0.elapsed().as_secs_f64();
    let failures = pool_run
        .failures
        .iter()
        .map(|f| crate::util::pool::ItemPanic {
            index: cells[f.index].index,
            message: f.message.clone(),
        })
        .collect();
    let telemetry = Telemetry {
        jobs: pool_run.jobs,
        wall_seconds,
        counters: *merged.lock().unwrap(),
        cache_hits: 0,
        cache_misses: 0,
        cache_shards: Vec::new(),
        cell_seconds: pool_run.results.iter().map(|c| c.eval_seconds).collect(),
    };
    CotenantReport {
        jobs: pool_run.jobs,
        cells: pool_run.results,
        failures,
        wall_seconds,
        telemetry,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SweepSpec {
        SweepSpec {
            scenarios: vec![
                Scenario::new("a", 8192, 512, 1024),
                Scenario::new("b", 4096, 256, 8192),
            ],
            kinds: vec![Kind::UniformFused1D, Kind::UniformFused2D],
            machines: vec![("mi300x-8".into(), Machine::mi300x_8())],
            mechs: vec![CommMech::Dma, CommMech::Kernel],
            gpu_counts: Vec::new(),
            skews: Vec::new(),
            skew_seed: DEFAULT_SKEW_SEED,
            search: None,
            model: None,
        }
    }

    #[test]
    fn cells_enumerate_the_product_in_order() {
        let spec = tiny_spec();
        let cells = spec.cells();
        assert_eq!(cells.len(), 2 * 2);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
            // Baseline implied and always first.
            assert_eq!(c.kinds[0], Kind::Baseline);
            assert_eq!(c.kinds.len(), 3);
        }
        // Mechanism-major over scenarios.
        assert_eq!(cells[0].scenario.mech, CommMech::Dma);
        assert_eq!(cells[2].scenario.mech, CommMech::Kernel);
        assert_eq!(spec.n_cells(), cells.len());
        assert_eq!(spec.n_points(), 12);
    }

    #[test]
    fn gpu_count_override_resizes_machine_and_scenario() {
        let mut spec = tiny_spec();
        spec.gpu_counts = vec![4];
        for c in spec.cells() {
            assert_eq!(c.machine.ngpus(), 4);
            assert_eq!(c.scenario.ngpus, 4);
        }
    }

    #[test]
    fn eval_cell_marks_pick_and_oracle() {
        let spec = tiny_spec();
        let r = eval_cell(&spec.cells()[0]);
        assert_eq!(r.rows.len(), 3);
        assert!((r.rows[0].speedup - 1.0).abs() < 1e-12, "baseline speedup");
        assert_eq!(r.rows.iter().filter(|row| row.is_oracle).count(), 1);
        assert!(r.oracle.is_some());
        assert!(r.rows.iter().all(|row| row.makespan > 0.0));
    }

    #[test]
    fn model_drives_the_pick_column() {
        use crate::heuristics::model::{CountVal, Feature, HeuristicModel, Rule};
        let mut spec = tiny_spec();
        // Without a model the cell reports the frozen rule's pick and
        // no model plan.
        let legacy = eval_cell(&spec.cells()[0]);
        assert!(legacy.model_plan.is_none());
        // A loaded model fills the model_pick column with its full
        // plan prediction.
        spec.model = Some(HeuristicModel {
            pieces: Some(Rule {
                feature: Feature::Combined,
                cutoff: 0.0,
                below: CountVal::Keep,
                at_or_above: CountVal::TwiceGpus,
            }),
            ..HeuristicModel::default()
        });
        let cells = spec.cells();
        let cell = &cells[0];
        assert!(cell.model.is_some());
        let r = eval_cell(cell);
        let plan_id = r.model_plan.expect("model plan recorded");
        let plan = crate::plan::Plan::parse_id(&plan_id).expect("well-formed plan id");
        assert_eq!(plan.pieces, 2 * cell.scenario.ngpus);
        // The default model reproduces the legacy pick exactly.
        spec.model = Some(HeuristicModel::default());
        let d = eval_cell(&spec.cells()[0]);
        assert_eq!(d.pick, legacy.pick);
        assert!(d.model_plan.is_some());
    }

    #[test]
    fn run_delivers_cells_in_order() {
        let spec = tiny_spec();
        let mut seen = Vec::new();
        let report = run(&spec, 3, |c| {
            seen.push(c.index);
            true
        });
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert_eq!(report.cells.len(), 4);
        for (i, c) in report.cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    #[test]
    fn callback_false_cancels_the_sweep() {
        let spec = tiny_spec();
        let mut delivered = 0usize;
        let report = run(&spec, 2, |_| {
            delivered += 1;
            false
        });
        assert_eq!(delivered, 1, "no deliveries after cancellation");
        // The cancelled report carries exactly the delivered prefix —
        // completed-but-undelivered stragglers must not leak in, or
        // the report would depend on worker timing.
        assert_eq!(report.cells.len(), 1);
        assert_eq!(report.cells[0].index, 0);
    }

    #[test]
    fn run_cells_subset_keeps_original_indices() {
        let spec = tiny_spec();
        let cells = spec.cells();
        let full = run(&spec, 1, |_| true);
        assert!(full.failures.is_empty());
        // Resume-style subset: skip the already-journaled prefix.
        let partial = run_cells(&cells[2..], 1, |_| true);
        assert_eq!(partial.cells.len(), 2);
        assert_eq!(partial.cells[0].index, 2);
        assert_eq!(partial.cells[1].index, 3);
        for (a, b) in partial.cells.iter().zip(&full.cells[2..]) {
            assert_eq!(emit::csv_rows(a), emit::csv_rows(b));
        }
    }

    #[test]
    fn robust_sweep_reranks_and_keeps_nominal_rows_bitwise() {
        use crate::search::{RobustCfg, RobustObjective, SearchCfg};
        let mut spec = tiny_spec();
        spec.scenarios.truncate(1);
        spec.mechs.truncate(1);
        spec.search = Some(SearchCfg {
            beam: 2,
            prune: true,
            ..SearchCfg::default()
        });
        let nominal = run(&spec, 1, |_| true);
        spec.search = Some(SearchCfg {
            robust: Some(RobustCfg {
                objective: RobustObjective::Worst,
                top_k: 4,
                ensemble: crate::hw::Perturbation::defaults(3, 42),
            }),
            ..spec.search.unwrap()
        });
        let robust1 = run(&spec, 1, |_| true);
        let robust4 = run(&spec, 4, |_| true);
        // Robust selection is jobs-invariant to the byte.
        for (a, b) in robust1.cells.iter().zip(&robust4.cells) {
            assert_eq!(emit::csv_rows(a), emit::csv_rows(b));
            assert_eq!(emit::json_cell(a), emit::json_cell(b));
        }
        // The per-kind rows never depend on the robust re-rank; only
        // the best_plan column may move.
        for (n, r) in nominal.cells.iter().zip(&robust1.cells) {
            for (nr, rr) in n.rows.iter().zip(&r.rows) {
                assert_eq!(nr.makespan.to_bits(), rr.makespan.to_bits());
            }
            assert!(r.best_plan.is_some());
        }
        assert!(robust1.telemetry.counters.robust_reranks > 0);
    }

    #[test]
    fn filters_build_specs() {
        let spec = SweepSpec::from_filters("g1,g5", "ficco", "mi300x-8,pcie-gen4-4", "dma", "", "")
            .unwrap();
        assert_eq!(spec.scenarios.len(), 2);
        assert_eq!(spec.kinds.len(), 4);
        assert_eq!(spec.machines.len(), 2);
        // Native counts: 8 for the mesh, 4 for the PCIe box.
        let cells = spec.cells();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].scenario.ngpus, 8);
        assert_eq!(cells[2].scenario.ngpus, 4);

        assert!(SweepSpec::from_filters("gX", "all", "all", "dma", "", "").is_err());
        assert!(SweepSpec::from_filters("g1", "all", "all", "warp", "", "").is_err());
        assert!(SweepSpec::from_filters("g1", "all", "nope", "dma", "", "").is_err());
        assert!(SweepSpec::from_filters("g1", "all", "all", "dma", "1", "").is_err());
        assert!(
            SweepSpec::from_filters("g1", "all", "all", "dma", "native,4", "").is_err(),
            "mixing native with explicit counts must be rejected"
        );
        assert!(
            SweepSpec::from_filters("g1", "all", "all", "dma", "", "-0.5").is_err(),
            "negative skew must be rejected"
        );
        assert!(SweepSpec::from_filters("g1", "all", "all", "dma", "", "hot").is_err());
        let synth =
            SweepSpec::from_filters("synth:3:7", "all", "mi300x-8", "dma", "8", "").unwrap();
        assert_eq!(synth.scenarios.len(), 3);
    }

    #[test]
    fn filters_drop_duplicates_on_every_axis() {
        let spec =
            SweepSpec::from_filters("table1,g1", "all", "all,mi300x-8", "dma,dma", "8,8", "0,0")
                .unwrap();
        assert_eq!(spec.scenarios.len(), 16, "g1 must not be double-counted");
        assert_eq!(spec.machines.len(), Machine::preset_names().len());
        assert_eq!(spec.mechs.len(), 1);
        assert_eq!(spec.gpu_counts.len(), 1);
        assert_eq!(spec.skews.len(), 1, "skews deduped");
        // Distinct synthetic suites share names but differ in shape:
        // both survive.
        let two_suites =
            SweepSpec::from_filters("synth:2:1,synth:2:2", "all", "mi300x-8", "dma", "", "")
                .unwrap();
        assert_eq!(two_suites.scenarios.len(), 4);
    }

    #[test]
    fn skew_axis_multiplies_cells_and_tags_scenarios() {
        let spec =
            SweepSpec::from_filters("g5", "ficco", "mi300x-8", "dma", "", "0,0.6").unwrap();
        assert_eq!(spec.skews, vec![0.0, 0.6]);
        let cells = spec.cells();
        assert_eq!(cells.len(), 2);
        assert_eq!(spec.n_cells(), 2);
        assert_eq!(cells[0].scenario.skew, 0.0);
        assert_eq!(cells[1].scenario.skew, 0.6);
        assert_eq!(cells[1].scenario.skew_seed, DEFAULT_SKEW_SEED);
        // The emitted cell carries the skew.
        let r = eval_cell(&cells[1]);
        assert_eq!(r.skew, 0.6);
        assert!(r.rows.iter().all(|row| row.makespan > 0.0));
    }

    #[test]
    fn moe_scenarios_keep_their_intrinsic_skew() {
        let spec =
            SweepSpec::from_filters("moe:3:11", "ficco", "mi300x-8", "dma", "", "0").unwrap();
        assert_eq!(spec.scenarios.len(), 3);
        assert!(spec.scenarios.iter().all(|s| s.skew > 0.0));
        for cell in spec.cells() {
            let base = spec
                .scenarios
                .iter()
                .find(|s| s.name == cell.scenario.name)
                .unwrap();
            assert_eq!(
                cell.scenario.skew, base.skew,
                "axis must not clobber sampled MoE skew"
            );
        }
    }

    #[test]
    fn multi_skew_axis_never_duplicates_intrinsically_skewed_cells() {
        // moe scenarios ignore the axis, so a 3-value --skew must not
        // triple their cells; unskewed g5 still multiplies.
        let spec =
            SweepSpec::from_filters("moe:2:11,g5", "ficco", "mi300x-8", "dma", "", "0,0.6,1.2")
                .unwrap();
        let cells = spec.cells();
        assert_eq!(cells.len(), 2 + 3, "2 moe once + g5 x 3 skews");
        assert_eq!(spec.n_cells(), cells.len());
        let moe_cells = cells
            .iter()
            .filter(|c| c.scenario.name.starts_with("moe"))
            .count();
        assert_eq!(moe_cells, 2, "one cell per moe scenario");
        // No two cells share (name, skew).
        for (i, a) in cells.iter().enumerate() {
            for b in &cells[i + 1..] {
                assert!(
                    a.scenario.name != b.scenario.name || a.scenario.skew != b.scenario.skew,
                    "duplicate cell {} skew {}",
                    a.scenario.name,
                    a.scenario.skew
                );
            }
        }
    }

    #[test]
    fn cotenant_cells_are_jobs_invariant_and_slowdowns_sane() {
        let mut spec = tiny_spec();
        spec.mechs.truncate(1);
        let cells = spec.cells();
        let r1 = run_cotenant_cells(&cells, 2, 0.25, None, 1, |_| true);
        let r4 = run_cotenant_cells(&cells, 2, 0.25, None, 4, |_| true);
        assert!(r1.failures.is_empty());
        assert_eq!(r1.cells.len(), cells.len());
        for (a, b) in r1.cells.iter().zip(&r4.cells) {
            assert_eq!(a.span.to_bits(), b.span.to_bits());
            assert_eq!(a.events, b.events);
            for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
                assert_eq!(ja.makespan.to_bits(), jb.makespan.to_bits());
                assert_eq!(ja.slowdown.to_bits(), jb.slowdown.to_bits());
            }
        }
        for c in &r1.cells {
            assert_eq!(c.jobs.len(), 2);
            // Kinds cycle over the requested (non-baseline) kinds.
            assert_eq!(c.jobs[0].kind, Kind::UniformFused1D);
            assert_eq!(c.jobs[1].kind, Kind::UniformFused2D);
            for j in &c.jobs {
                assert!(j.isolated > 0.0 && j.makespan > 0.0);
                assert!(j.slowdown >= 1.0 - 1e-9, "co-tenancy cannot speed a job up");
                assert!(c.span >= j.offset + j.makespan - 1e-9 * c.span);
            }
            assert_eq!(c.jobs[0].offset, 0.0);
            assert!(c.jobs[1].offset > 0.0);
        }
    }

    #[test]
    fn cotenant_robust_column_fills_and_nominal_stays_bitwise() {
        let mut spec = tiny_spec();
        spec.scenarios.truncate(1);
        spec.mechs.truncate(1);
        let cells = spec.cells();
        let nominal = run_cotenant_cells(&cells, 2, 0.0, None, 1, |_| true);
        let ens = crate::hw::Perturbation::defaults(3, 42);
        let robust = run_cotenant_cells(&cells, 2, 0.0, Some(&ens), 1, |_| true);
        for (n, r) in nominal.cells.iter().zip(&robust.cells) {
            assert!(n.robust.is_none());
            let stats = r.robust.as_ref().expect("robust stats recorded");
            assert_eq!(n.span.to_bits(), r.span.to_bits());
            assert_eq!(stats.nominal.to_bits(), r.span.to_bits());
            assert!(stats.p50 <= stats.p95 && stats.p95 <= stats.worst);
            for (ja, jb) in n.jobs.iter().zip(&r.jobs) {
                assert_eq!(ja.makespan.to_bits(), jb.makespan.to_bits());
            }
        }
    }

    #[test]
    fn cotenant_model_assigns_the_predicted_plan_to_every_tenant() {
        let mut spec = tiny_spec();
        spec.scenarios.truncate(1);
        spec.mechs.truncate(1);
        spec.model = Some(crate::heuristics::model::HeuristicModel::default());
        let cells = spec.cells();
        let r = run_cotenant_cells(&cells, 3, 0.5, None, 1, |_| true);
        let c = &r.cells[0];
        assert_eq!(c.jobs.len(), 3);
        let first = &c.jobs[0].plan_id;
        assert!(c.jobs.iter().all(|j| &j.plan_id == first));
        assert!(c.jobs.iter().all(|j| j.kind == c.jobs[0].kind));
    }

    #[test]
    fn full_paper_suite_covers_acceptance_axes() {
        let spec = SweepSpec::full_paper_suite();
        assert_eq!(spec.scenarios.len(), 16);
        assert_eq!(spec.kinds.len(), 6);
        assert!(spec.machines.len() >= 3);
        assert_eq!(spec.mechs.len(), 2);
        // 16 scenarios x >=4 machines x 2 mechs x 6 kinds.
        assert!(spec.n_points() >= 16 * 3 * 2 * 6);
    }
}
