//! Schedule validation: proves structural correctness of any generated
//! schedule without executing it.
//!
//! Invariants (per GPU):
//! 1. **Compute coverage** — the union of all GEMM `covers` regions is
//!    an exact, non-overlapping partition of the global input `M×K`
//!    (every output element computed exactly once; for 2D schedules,
//!    every K block accumulated exactly once).
//! 2. **Communication coverage** — received transfer regions exactly
//!    partition the remote part of the input (`M×K` minus the local
//!    shard); nothing is sent twice, nothing is missing, and no GPU
//!    is sent its own data.
//! 3. **Sender ownership** — every transfer's region lies inside the
//!    sender's shard.
//! 4. **Data-before-compute** — every GEMM's remote coverage is
//!    contained in the union of transfer regions in its transitive
//!    dependency closure.
//! 5. **Topological order** — deps reference earlier nodes only.
//! 6. **Partition soundness** — the scenario's row partition (uniform
//!    or expert-skewed) tiles `[0, M)` contiguously, so the byte
//!    conservation and full-row-cover checks above hold against the
//!    *actual* per-GPU extents, not a recomputed uniform split.
//!
//! All shard extents come from the scenario's [`crate::plan::Partition`],
//! so every invariant is checked against the same (possibly skewed)
//! row layout the lowering used.

use super::{Node, OpKind, Region, Schedule};
use crate::plan::Partition;

#[derive(Debug)]
pub struct ValidationError(pub String);

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "schedule invalid: {}", self.0)
    }
}
impl std::error::Error for ValidationError {}

fn err<T>(msg: impl Into<String>) -> Result<T, ValidationError> {
    Err(ValidationError(msg.into()))
}

/// Run all invariants; `Ok(())` if the schedule is sound.
pub fn validate(s: &Schedule) -> Result<(), ValidationError> {
    let n = s.scenario.ngpus;
    let g = &s.scenario.gemm;
    let total_area = g.m * g.k;
    let part = s.scenario.partition(1);

    // 6: partition soundness — shards tile [0, M) contiguously.
    let mut prev_hi = 0u64;
    for q in 0..n {
        let (lo, hi) = part.shard_rows(q);
        if lo != prev_hi || hi < lo {
            return err(format!(
                "partition: shard {q} rows [{lo},{hi}) not contiguous after {prev_hi}"
            ));
        }
        prev_hi = hi;
    }
    if prev_hi != g.m {
        return err(format!("partition: shards cover {prev_hi} of {} rows", g.m));
    }

    // 5: topological order (also guards the closure walk below).
    for (i, node) in s.nodes.iter().enumerate() {
        for &d in &node.deps {
            if d >= i {
                return err(format!("node {i} ({}) deps on {d} (not earlier)", node.label));
            }
        }
        if node.gpu >= n {
            return err(format!("node {i} on unknown gpu {}", node.gpu));
        }
    }

    for gpu in 0..n {
        let shard = shard_region(s, &part, gpu);

        // 1: compute coverage.
        let mut covers: Vec<Region> = Vec::new();
        for node in s.nodes.iter().filter(|nd| nd.gpu == gpu) {
            if let OpKind::Gemm { covers: c, shape } = &node.kind {
                let area: u64 = c.iter().map(Region::area).sum();
                if area != shape.m * shape.k {
                    return err(format!(
                        "{}: covers area {} != gemm m*k {}",
                        node.label,
                        area,
                        shape.m * shape.k
                    ));
                }
                covers.extend_from_slice(c);
            }
        }
        check_partition(&covers, total_area, &format!("gpu{gpu} compute"))?;

        // 2: communication coverage.
        let mut rx: Vec<Region> = Vec::new();
        for node in s.nodes.iter().filter(|nd| nd.gpu == gpu) {
            if let OpKind::Xfer { src, region } = &node.kind {
                if *src == gpu {
                    return err(format!("{}: self-transfer", node.label));
                }
                if region.intersects(&shard) {
                    return err(format!("{}: received own shard data", node.label));
                }
                // 3: sender ownership.
                let src_shard = shard_region(s, &part, *src);
                if region.row_lo < src_shard.row_lo || region.row_hi > src_shard.row_hi {
                    return err(format!(
                        "{}: region rows [{},{}) outside sender shard [{},{})",
                        node.label, region.row_lo, region.row_hi, src_shard.row_lo, src_shard.row_hi
                    ));
                }
                rx.push(*region);
            }
        }
        check_partition(&rx, total_area - shard.area(), &format!("gpu{gpu} comm"))?;
    }

    // 4: data-before-compute via transitive dependency closure.
    for (i, node) in s.nodes.iter().enumerate() {
        if let OpKind::Gemm { covers, .. } = &node.kind {
            let shard = shard_region(s, &part, node.gpu);
            let closure_regions = closure_xfer_regions(&s.nodes, i);
            for c in covers {
                // Local shard data is always present; the rest must be
                // covered by dep-closure transfers. (Transfers are
                // pairwise disjoint per invariant 2, so intersection
                // areas add without double counting.)
                let covered: u64 = intersection_area(&shard, c)
                    + closure_regions
                        .iter()
                        .map(|r| intersection_area(r, c))
                        .sum::<u64>();
                if covered < c.area() {
                    return err(format!(
                        "{}: consumes remote region rows[{},{})×k[{},{}) but deps deliver only {}/{} cells",
                        node.label, c.row_lo, c.row_hi, c.k_lo, c.k_hi, covered, c.area()
                    ));
                }
            }
        }
    }
    Ok(())
}

fn shard_region(s: &Schedule, part: &Partition, gpu: usize) -> Region {
    let (lo, hi) = part.shard_rows(gpu);
    Region::rows(lo, hi, s.scenario.gemm.k)
}

fn intersection_area(a: &Region, b: &Region) -> u64 {
    let rl = a.row_lo.max(b.row_lo);
    let rh = a.row_hi.min(b.row_hi);
    let kl = a.k_lo.max(b.k_lo);
    let kh = a.k_hi.min(b.k_hi);
    if rl < rh && kl < kh {
        (rh - rl) * (kh - kl)
    } else {
        0
    }
}

/// Exact-partition check: pairwise disjoint and total area matches.
fn check_partition(regions: &[Region], want_area: u64, what: &str) -> Result<(), ValidationError> {
    let area: u64 = regions.iter().map(Region::area).sum();
    if area != want_area {
        return err(format!("{what}: covered area {area} != expected {want_area}"));
    }
    for (i, a) in regions.iter().enumerate() {
        for b in regions.iter().skip(i + 1) {
            if a.intersects(b) {
                return err(format!("{what}: overlapping regions {a:?} and {b:?}"));
            }
        }
    }
    Ok(())
}

/// All Xfer regions in the transitive dependency closure of node `i`.
fn closure_xfer_regions(nodes: &[Node], i: usize) -> Vec<Region> {
    let mut seen = vec![false; nodes.len()];
    let mut stack = vec![i];
    let mut out = Vec::new();
    while let Some(j) = stack.pop() {
        if seen[j] {
            continue;
        }
        seen[j] = true;
        if let OpKind::Xfer { region, .. } = &nodes[j].kind {
            out.push(*region);
        }
        stack.extend_from_slice(&nodes[j].deps);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{generate::generate, Kind, Scenario};

    #[test]
    fn all_kinds_validate_on_even_dims() {
        let sc = Scenario::new("even", 4096, 1024, 2048);
        for kind in Kind::ALL {
            validate(&generate(kind, &sc)).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        }
    }

    #[test]
    fn all_kinds_validate_on_awkward_dims() {
        // Primes and non-divisible splits stress the balanced-split
        // bookkeeping in every generator.
        for (m, n, k, g) in [
            (1009, 37, 977, 8),
            (64, 16, 64, 8),
            (129, 7, 65, 4),
            (17, 3, 1031, 3),
            (4096, 4096, 8, 2),
        ] {
            let sc = Scenario::new("odd", m, n, k).with_ngpus(g);
            for kind in Kind::ALL {
                validate(&generate(kind, &sc))
                    .unwrap_or_else(|e| panic!("{kind:?} m={m} k={k} g={g}: {e}"));
            }
        }
    }

    #[test]
    fn detects_missing_transfer() {
        let sc = Scenario::new("t", 4096, 1024, 2048);
        let mut sched = generate(Kind::Baseline, &sc);
        // Drop one transfer: comm coverage must fail.
        let victim = sched
            .nodes
            .iter()
            .position(|n| matches!(n.kind, OpKind::Xfer { .. }))
            .unwrap();
        // Replace by a zero-area transfer to keep indices stable.
        if let OpKind::Xfer { region, .. } = &mut sched.nodes[victim].kind {
            region.row_hi = region.row_lo;
        }
        assert!(validate(&sched).is_err());
    }

    #[test]
    fn detects_gemm_without_data() {
        let sc = Scenario::new("t", 4096, 1024, 2048);
        let mut sched = generate(Kind::Baseline, &sc);
        // Cut a GEMM's deps: data-before-compute must fail.
        let victim = sched
            .nodes
            .iter()
            .position(|n| matches!(n.kind, OpKind::Gemm { .. }))
            .unwrap();
        sched.nodes[victim].deps.clear();
        assert!(validate(&sched).is_err());
    }

    #[test]
    fn detects_double_compute() {
        let sc = Scenario::new("t", 4096, 1024, 2048);
        let mut sched = generate(Kind::Baseline, &sc);
        // Duplicate a GEMM node → overlap in compute coverage.
        let victim = sched
            .nodes
            .iter()
            .position(|n| matches!(n.kind, OpKind::Gemm { .. }))
            .unwrap();
        let dup = sched.nodes[victim].clone();
        sched.nodes.push(dup);
        assert!(validate(&sched).is_err());
    }
}
