//! Lower a [`Schedule`] onto the fluid cluster simulator and measure
//! it. This is where DIL (via `cost::gemm` isolated times) and CIL
//! (via resource sharing in `sim`) combine into end-to-end makespans —
//! the quantity behind Figs 12b, 13, and 14.
//!
//! The module is organized around the reusable [`Evaluator`]: one
//! evaluator owns a [`ClusterSim`] arena (resources, streams, and the
//! engine's scratch buffers) that is *reset*, not rebuilt, between
//! candidate schedules — the plan search simulates hundreds of
//! candidates per (machine, scenario) cell, and rebuilding the
//! machine skeleton and reallocating the task graph dominated its
//! wall-clock before this existed (see `DESIGN.md` §6). The one-shot
//! free functions ([`execute`], [`evaluate`], [`evaluate_plan`])
//! remain as thin wrappers that spin up a throwaway evaluator.

use super::{Kind, OpKind, Scenario, Schedule};
use crate::cost::gemm::GemmCost;
use crate::hw::{Machine, PerturbSample, Perturbation};
use crate::obs::{Counters, TimelineRecorder, TrackMap};
use crate::plan::{Partition, Plan};
use crate::sim::{ClusterSim, CommMech, Label, LeanReport, Report, SimError, TaskId};
use std::collections::HashMap;

/// Per-plan robustness statistics under a [`Perturbation`] ensemble
/// (ISSUE 9): the nominal makespan plus order statistics of the
/// ensemble's makespans. The fragility signature `p95 / nominal`
/// echoes the paper's inefficiency signatures — a plan whose p95
/// barely moves is robust; one whose tail blows up is fragile even if
/// it wins nominally.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RobustStats {
    /// Unperturbed makespan (bit-identical to the nominal search's).
    pub nominal: f64,
    /// Ensemble median makespan.
    pub p50: f64,
    /// Ensemble 95th-percentile makespan.
    pub p95: f64,
    /// Worst ensemble makespan.
    pub worst: f64,
}

impl RobustStats {
    /// Fragility signature: how far the tail (p95) sits above the
    /// nominal makespan the search optimized for.
    pub fn fragility(&self) -> f64 {
        self.p95 / self.nominal
    }
}

/// Order statistic of an ascending-sorted sample at quantile `q`
/// (nearest-rank on the closed index range — deterministic, no
/// interpolation, so the result is always one of the measured bits).
fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One co-tenant job (ISSUE 10): `plan` lowered for `scenario` and
/// admitted into the shared machine's live simulation at virtual time
/// `offset`. Jobs must be listed in nondecreasing-offset order (the
/// admission clock only moves forward).
#[derive(Debug, Clone)]
pub struct CotenantJob {
    pub scenario: Scenario,
    pub plan: Plan,
    pub offset: f64,
}

/// Per-job measurements of one co-tenant evaluation.
#[derive(Debug, Clone)]
pub struct CotenantJobEval {
    /// Isolated (solo) makespan of the job's plan on the same machine
    /// — bit-identical to [`Evaluator::plan_makespan`].
    pub isolated: f64,
    /// Co-tenant makespan: the job's admission to its last task
    /// finishing, while sharing every resource with the other jobs.
    pub makespan: f64,
    /// Cross-job interference slowdown, `makespan / isolated`.
    pub slowdown: f64,
    /// Virtual time the job was admitted at.
    pub offset: f64,
    pub n_tasks: usize,
}

/// Joint co-tenant evaluation: per-job results plus the joint span.
#[derive(Debug, Clone)]
pub struct CotenantEval {
    pub jobs: Vec<CotenantJobEval>,
    /// Virtual time the last job finished (the joint makespan,
    /// measured from t = 0).
    pub span: f64,
    /// Events processed by the joint simulation.
    pub events: usize,
}

/// Measured execution of one schedule.
#[derive(Debug, Clone)]
pub struct ExecResult {
    pub kind: Kind,
    pub makespan: f64,
    /// Σ isolated GEMM time per GPU (max over GPUs) — the compute leg.
    pub gemm_leg: f64,
    /// Serial-communication leg (critical path of transfers, isolated).
    pub comm_leg: f64,
    /// Mean slowdown of GEMM tasks vs isolation (measured CIL).
    pub gemm_cil: f64,
    /// Mean slowdown of transfer tasks vs isolation (measured CIL).
    pub comm_cil: f64,
    pub n_tasks: usize,
    pub sim_events: usize,
}

/// The communication mechanism a schedule's transfers ride: the plan
/// knob when the schedule was lowered from a plan; otherwise the
/// legacy rule — the serial baseline and shard-overlap (AsyncTP) are
/// the PyTorch-stack reference points with GPU-core-driven (RCCL /
/// SM-copy) communication, FiCCO schedules use the scenario's
/// mechanism (DMA by default; Kernel for the FiCCO-rccl ablation).
fn sched_mech(sched: &Schedule) -> CommMech {
    match sched.plan {
        Some(p) => p.mech,
        None => match sched.kind {
            Kind::Baseline | Kind::ShardOverlap => CommMech::Kernel,
            _ => sched.scenario.mech,
        },
    }
}

/// Cell-scoped lowering cache: the parts of plan lowering that are
/// invariant across the candidates of one (machine, scenario) search
/// cell. A [`Partition`] is a pure function of `(m, ngpus, pieces,
/// skew, skew_seed)` — within a cell only `pieces` varies, so the
/// scope memoizes one partition per decomposition degree and every
/// candidate at that degree reuses it (under skew this skips the
/// Zipf-weight + hotness-shuffle construction per candidate). The
/// scope also carries the cell's best-so-far `(plan, makespan)`
/// incumbent across search phases (presets → space → beam → pick
/// evaluation): a later phase may use it to tighten its pruning
/// cutoff, which can only skip work, never change a result (the
/// incumbent is a true candidate makespan, hence ≥ the cell optimum).
///
/// Keyed on exactly the partition inputs; [`Evaluator::begin_cell`]
/// pins them and any scenario that disagrees simply bypasses the
/// scope (see `DESIGN.md` §9).
struct CellScope {
    m: u64,
    ngpus: usize,
    /// `Scenario::skew` bits, normalized to 0 at `skew == 0` (same
    /// rule as [`crate::search::EvalKey`]).
    skew_bits: u64,
    skew_seed: u64,
    partitions: HashMap<usize, Partition>,
    incumbent: Option<(Plan, f64)>,
}

impl CellScope {
    fn matches(&self, sc: &Scenario) -> bool {
        let skew_bits = if sc.skew == 0.0 { 0 } else { sc.skew.to_bits() };
        let skew_seed = if sc.skew == 0.0 { 0 } else { sc.skew_seed };
        self.m == sc.gemm.m
            && self.ngpus == sc.ngpus
            && self.skew_bits == skew_bits
            && self.skew_seed == skew_seed
    }
}

/// Reusable schedule-evaluation arena. Holds a [`ClusterSim`] bound
/// to the last machine simulated (rebuilt only when the machine
/// changes) plus the per-load bookkeeping the metrics need — all
/// buffers persist across loads, so evaluating candidate after
/// candidate allocates only while capacities warm up.
///
/// Contract (`DESIGN.md` §6): a load fully overwrites every piece of
/// per-candidate state; nothing measured about candidate *k* depends
/// on candidates *1..k-1*, which is why threading one evaluator
/// through a search cannot change any reported number.
pub struct Evaluator {
    sim: Option<ClusterSim>,
    gemm_tasks: Vec<TaskId>,
    xfer_tasks: Vec<TaskId>,
    gemm_iso_per_gpu: Vec<f64>,
    task_of: Vec<TaskId>,
    dep_scratch: Vec<TaskId>,
    /// Keep human-readable node labels on loaded tasks even without
    /// `FICCO_SIM_TRACE` (used by trace capture, where the labels end
    /// up in the exported artifact).
    keep_labels: bool,
    /// Pipeline telemetry: incremented privately by the worker that
    /// owns this evaluator, merged at pool join (`crate::obs`).
    pub counters: Counters,
    /// Active tune-cell lowering scope, if any (see [`CellScope`]).
    cell: Option<CellScope>,
}

impl Evaluator {
    /// An unbound evaluator; the first load binds it to a machine.
    pub fn new() -> Evaluator {
        Evaluator {
            sim: None,
            gemm_tasks: Vec::new(),
            xfer_tasks: Vec::new(),
            gemm_iso_per_gpu: Vec::new(),
            task_of: Vec::new(),
            dep_scratch: Vec::new(),
            keep_labels: false,
            counters: Counters::default(),
            cell: None,
        }
    }

    /// Open a tune-cell scope for `sc`: every subsequent plan load
    /// whose scenario shares `sc`'s partition inputs (M, ngpus, skew,
    /// skew seed) reuses one memoized [`Partition`] per `pieces`
    /// value and, in release builds, skips the per-candidate
    /// structural re-validation of the lowered graph (the lowering
    /// generator is property-tested against `validate` directly; a
    /// debug build keeps the per-candidate check). Replaces any
    /// previously open scope.
    pub fn begin_cell(&mut self, sc: &Scenario) {
        self.cell = Some(CellScope {
            m: sc.gemm.m,
            ngpus: sc.ngpus,
            skew_bits: if sc.skew == 0.0 { 0 } else { sc.skew.to_bits() },
            skew_seed: if sc.skew == 0.0 { 0 } else { sc.skew_seed },
            partitions: HashMap::new(),
            incumbent: None,
        });
    }

    /// Close the tune-cell scope (drops memoized partitions and the
    /// carried incumbent). No-op when no scope is open.
    pub fn end_cell(&mut self) {
        self.cell = None;
    }

    /// The best `(plan, makespan)` recorded in the open cell scope,
    /// if any — a *true candidate makespan* from an earlier search
    /// phase of the same cell, safe to use as an initial pruning
    /// cutoff (never as a result).
    pub fn cell_incumbent(&self) -> Option<(Plan, f64)> {
        self.cell.as_ref().and_then(|c| c.incumbent)
    }

    /// Record a candidate's measured makespan in the open cell scope,
    /// keeping the tighter of the stored and offered values. No-op
    /// without an open scope.
    pub fn note_cell_incumbent(&mut self, plan: Plan, makespan: f64) {
        if let Some(cell) = self.cell.as_mut() {
            match cell.incumbent {
                Some((_, best)) if best <= makespan => {}
                _ => cell.incumbent = Some((plan, makespan)),
            }
        }
    }

    /// Force loaded tasks to carry their schedule node labels
    /// regardless of `FICCO_SIM_TRACE` (see [`Evaluator::new`]).
    pub fn set_keep_labels(&mut self, on: bool) {
        self.keep_labels = on;
    }

    /// Bind the sim arena to `machine`, rebuilding only on a machine
    /// change (a rebuild clears any installed perturbation — fresh
    /// [`ClusterSim`]s are nominal).
    fn ensure_sim(&mut self, machine: &Machine) {
        let rebuild = match &self.sim {
            Some(s) => s.machine != *machine,
            None => true,
        };
        if rebuild {
            self.sim = Some(ClusterSim::new(machine.clone()));
        }
    }

    /// Build the simulator task graph for `sched` into the (reset)
    /// arena without running it.
    fn load(&mut self, machine: &Machine, sched: &Schedule) {
        self.ensure_sim(machine);
        self.sim.as_mut().expect("sim bound above").reset();

        let ngpus = machine.ngpus();
        self.gemm_tasks.clear();
        self.xfer_tasks.clear();
        self.gemm_iso_per_gpu.clear();
        self.gemm_iso_per_gpu.resize(ngpus, 0.0);
        self.append_graph(machine, sched, None);
    }

    /// Append `sched`'s task graph onto the bound sim *without*
    /// resetting it — the building block [`Evaluator::load`] (reset +
    /// one graph) and the co-tenant joint run (one graph per admitted
    /// job) share. `job` tags trace labels with a `j<k>:` prefix so
    /// co-tenant timelines distinguish tenants; `None` is the one-shot
    /// path, byte-identical to the pre-factor loader.
    fn append_graph(&mut self, machine: &Machine, sched: &Schedule, job: Option<usize>) {
        let sim = self.sim.as_mut().expect("sim bound above");
        self.task_of.clear();

        let gcost = GemmCost::new(&machine.gpu);
        let mech = sched_mech(sched);
        let dtype = sched.scenario.dtype();
        // Tasks carry the schedule's node label only when tracing is
        // on (it is rendered nowhere else); the allocation-free
        // `n<index>` label otherwise — rerun with FICCO_SIM_TRACE=1
        // for named traces.
        let trace = self.keep_labels || crate::sim::trace_enabled();

        for (i, node) in sched.nodes.iter().enumerate() {
            self.dep_scratch.clear();
            for &d in &node.deps {
                self.dep_scratch.push(self.task_of[d]);
            }
            let label = if trace {
                match job {
                    Some(k) => Label::Owned(format!("j{k}:{}", node.label)),
                    None => Label::Owned(node.label.clone()),
                }
            } else {
                Label::indexed("n", i)
            };
            let tid = match &node.kind {
                OpKind::Gemm { shape, .. } => {
                    let t = gcost.time(shape);
                    self.gemm_iso_per_gpu[node.gpu] += t;
                    let id = sim.gemm_task(
                        node.gpu,
                        label,
                        t,
                        shape.bytes(),
                        gcost.cus_used(shape),
                        &self.dep_scratch,
                    );
                    self.gemm_tasks.push(id);
                    id
                }
                OpKind::Xfer { src, region } => {
                    let id = sim.transfer_task(
                        *src,
                        node.gpu,
                        node.slot,
                        label,
                        region.bytes(dtype),
                        mech,
                        &self.dep_scratch,
                    );
                    self.xfer_tasks.push(id);
                    id
                }
                OpKind::Gather { bytes } => sim.local_copy_task(
                    node.gpu,
                    label,
                    *bytes,
                    CommMech::Kernel,
                    &self.dep_scratch,
                ),
                OpKind::Scatter { bytes } => sim.local_copy_task(
                    node.gpu,
                    label,
                    *bytes,
                    CommMech::Kernel,
                    &self.dep_scratch,
                ),
            };
            self.task_of.push(tid);
        }
    }

    /// Analytic lower bound of the currently loaded graph.
    fn loaded_bound(&self) -> f64 {
        self.sim.as_ref().expect("graph loaded").engine.lower_bound()
    }

    /// Execute `sched` on `machine` with full per-task accounting;
    /// panics on simulator livelock (which would indicate a malformed
    /// schedule — run `validate` first).
    pub fn execute(&mut self, machine: &Machine, sched: &Schedule) -> ExecResult {
        self.load(machine, sched);
        let report = {
            let sim = self.sim.as_mut().expect("graph loaded");
            sim.engine.run_full().unwrap_or_else(|e| {
                panic!("simulating {} for {}: {e}", sched.kind.name(), sched.scenario.name)
            })
        };
        let gemm_cil = mean_slowdown(&report, &self.gemm_tasks);
        let comm_cil = mean_slowdown(&report, &self.xfer_tasks);
        let gemm_leg = self.gemm_iso_per_gpu.iter().cloned().fold(0.0, f64::max);
        let comm_leg = comm_leg_isolated(machine, &sched.scenario, sched.kind, sched_mech(sched));
        ExecResult {
            kind: sched.kind,
            makespan: report.makespan,
            gemm_leg,
            comm_leg,
            gemm_cil,
            comm_cil,
            n_tasks: sched.nodes.len(),
            sim_events: report.events,
        }
    }

    /// Lower → validate → load `plan`'s task graph without computing
    /// anything about it. Inside a matching cell scope the lowering
    /// reuses the scope's memoized partition, skips per-node label
    /// formatting when no consumer reads labels, and (release builds
    /// only) elides the per-candidate structural validation; all three
    /// are observationally pure — the built task graph's topology,
    /// shapes, and byte counts are identical either way, so every
    /// simulated number stays bit-equal (`rust/tests/search_ordering.rs`).
    fn load_plan_graph(&mut self, machine: &Machine, sc: &Scenario, plan: &Plan) {
        let with_labels = self.keep_labels || crate::sim::trace_enabled();
        let in_cell = self.cell.as_ref().map_or(false, |c| c.matches(sc));
        if in_cell {
            let cell = self.cell.as_mut().expect("cell checked above");
            let part = cell
                .partitions
                .entry(plan.pieces)
                .or_insert_with(|| sc.partition(plan.pieces));
            let sched = crate::plan::lower_opts(plan, sc, Some(part), with_labels);
            #[cfg(debug_assertions)]
            super::validate::validate(&sched)
                .unwrap_or_else(|e| panic!("plan {} for {}: {e}", plan.id(), sc.name));
            self.load(machine, &sched);
        } else {
            let sched = crate::plan::lower_opts(plan, sc, None, with_labels);
            super::validate::validate(&sched)
                .unwrap_or_else(|e| panic!("plan {} for {}: {e}", plan.id(), sc.name));
            self.load(machine, &sched);
        }
    }

    /// Lower → validate → load `plan`'s task graph; returns the
    /// analytic makespan lower bound of the loaded graph (orders of
    /// magnitude cheaper than simulating). Follow with
    /// [`Evaluator::run_loaded_lean`] to simulate the same graph —
    /// the search's bound-then-maybe-simulate path builds it once.
    pub fn load_plan(&mut self, machine: &Machine, sc: &Scenario, plan: &Plan) -> f64 {
        self.load_plan_graph(machine, sc, plan);
        self.loaded_bound()
    }

    /// Makespan-only simulation of the most recently loaded graph
    /// (see [`Engine::run_lean`](crate::sim::Engine::run_lean) — the
    /// makespan is bit-identical to the full run's).
    pub fn run_loaded_lean(&mut self) -> Result<LeanReport, SimError> {
        let sim = self.sim.as_mut().expect("graph loaded");
        sim.engine.run_lean()
    }

    /// Simulated makespan of `plan` on (machine, scenario): lower →
    /// validate → load → lean run, with no lower-bound computation
    /// (callers that want the bound use [`Evaluator::load_plan`]).
    /// The workhorse of the search hot path; bit-identical to
    /// `evaluate_plan(..).makespan`.
    pub fn plan_makespan(&mut self, machine: &Machine, sc: &Scenario, plan: &Plan) -> f64 {
        self.load_plan_graph(machine, sc, plan);
        self.run_loaded_lean()
            .unwrap_or_else(|e| panic!("plan {} for {}: {e}", plan.id(), sc.name))
            .makespan
    }

    /// Simulated makespan of `plan` on one *perturbed* machine
    /// (ISSUE 9): the sample's multipliers are installed on the sim
    /// arena for exactly this build+run and cleared before returning,
    /// so later nominal evaluations are untouched. Perturbed
    /// makespans must never enter the nominal `EvalCache` — its keys
    /// do not encode samples — which is why this lives beside, not
    /// inside, [`Evaluator::plan_makespan`].
    pub fn plan_makespan_perturbed(
        &mut self,
        machine: &Machine,
        sc: &Scenario,
        plan: &Plan,
        sample: &PerturbSample,
    ) -> f64 {
        // Bind (possibly rebuild) the arena first: a rebuild inside
        // `load` would discard a perturbation installed before it.
        self.ensure_sim(machine);
        self.sim
            .as_mut()
            .expect("sim bound above")
            .set_perturb(Some(sample.clone()));
        self.load_plan_graph(machine, sc, plan);
        let out = self.run_loaded_lean();
        self.sim
            .as_mut()
            .expect("sim bound above")
            .set_perturb(None);
        out.unwrap_or_else(|e| panic!("perturbed plan {} for {}: {e}", plan.id(), sc.name))
            .makespan
    }

    /// Robustness statistics of `plan` under ensemble `ens`, given its
    /// (already measured) nominal makespan. Ensemble members are
    /// generated by index — pure functions of `(seed, i)` — and the
    /// order statistics come from a sort, so the result is independent
    /// of evaluation order and of which worker runs it. A nominal
    /// (zero-magnitude or zero-sample) ensemble short-circuits to the
    /// nominal makespan without touching the simulator at all: bit
    /// identity with the nominal run holds by construction.
    pub fn plan_robust_stats(
        &mut self,
        machine: &Machine,
        sc: &Scenario,
        plan: &Plan,
        ens: &Perturbation,
        nominal: f64,
    ) -> RobustStats {
        if ens.is_nominal() {
            return RobustStats {
                nominal,
                p50: nominal,
                p95: nominal,
                worst: nominal,
            };
        }
        let ngpus = machine.ngpus();
        let nlinks = machine.topo.num_links();
        let mut spans: Vec<f64> = (0..ens.samples)
            .map(|i| {
                let sample = ens.sample(i, ngpus, nlinks);
                self.plan_makespan_perturbed(machine, sc, plan, &sample)
            })
            .collect();
        spans.sort_by(f64::total_cmp);
        RobustStats {
            nominal,
            p50: percentile_sorted(&spans, 0.50),
            p95: percentile_sorted(&spans, 0.95),
            worst: *spans.last().expect("samples >= 1"),
        }
    }

    /// Lower → validate → load `plan` (with human-readable node
    /// labels regardless of `FICCO_SIM_TRACE`) and simulate it under
    /// a [`TimelineRecorder`]: the structured timeline behind `ficco
    /// trace` and `--trace-out`. Returns the engine report
    /// (bit-identical to an unobserved `run_full` — the recorder only
    /// reads), the recorder, and the machine's Perfetto track layout.
    pub fn capture_plan(
        &mut self,
        machine: &Machine,
        sc: &Scenario,
        plan: &Plan,
    ) -> (Report, TimelineRecorder, TrackMap) {
        let keep = self.keep_labels;
        self.keep_labels = true;
        self.load_plan_graph(machine, sc, plan);
        self.keep_labels = keep;
        let mut rec = TimelineRecorder::new();
        let sim = self.sim.as_mut().expect("graph loaded");
        let report = sim
            .engine
            .run_full_recorded(&mut rec)
            .unwrap_or_else(|e| panic!("tracing plan {} for {}: {e}", plan.id(), sc.name));
        (report, rec, sim.track_map())
    }

    /// Lower → validate each co-tenant job's plan. Co-tenant lowering
    /// is not on the search hot path, so it runs outside any cell
    /// scope (no memoized partitions, full validation).
    fn lower_cotenant(&mut self, jobs: &[CotenantJob]) -> Vec<Schedule> {
        let with_labels = self.keep_labels || crate::sim::trace_enabled();
        jobs.iter()
            .map(|j| {
                let sched = crate::plan::lower_opts(&j.plan, &j.scenario, None, with_labels);
                super::validate::validate(&sched).unwrap_or_else(|e| {
                    panic!("co-tenant plan {} for {}: {e}", j.plan.id(), j.scenario.name)
                });
                sched
            })
            .collect()
    }

    /// Drive the joint co-tenant simulation over pre-lowered
    /// schedules: begin an empty resumable run, then for each job
    /// advance the virtual clock to its offset, build its graph onto a
    /// private stream bank, and admit it as a new engine instance —
    /// fair sharing against the already-running jobs falls out of the
    /// per-resource flow lists. Returns (per-job makespans, joint
    /// span, events).
    fn run_cotenant_joint(
        &mut self,
        machine: &Machine,
        jobs: &[CotenantJob],
        scheds: &[Schedule],
    ) -> (Vec<f64>, f64, usize) {
        self.ensure_sim(machine);
        self.gemm_tasks.clear();
        self.xfer_tasks.clear();
        self.gemm_iso_per_gpu.clear();
        self.gemm_iso_per_gpu.resize(machine.ngpus(), 0.0);
        {
            let sim = self.sim.as_mut().expect("sim bound above");
            sim.reset();
            sim.engine.begin_run_lean();
        }
        for (k, sched) in scheds.iter().enumerate() {
            {
                let sim = self.sim.as_mut().expect("sim bound above");
                sim.select_stream_bank(k);
                sim.engine
                    .advance_until(jobs[k].offset)
                    .unwrap_or_else(|e| panic!("co-tenant advance to t={}: {e}", jobs[k].offset));
            }
            self.append_graph(machine, sched, Some(k));
            self.sim
                .as_mut()
                .expect("sim bound above")
                .engine
                .admit_appended()
                .unwrap_or_else(|e| panic!("co-tenant admission at t={}: {e}", jobs[k].offset));
        }
        let sim = self.sim.as_mut().expect("sim bound above");
        let lean = sim
            .engine
            .finish_lean()
            .unwrap_or_else(|e| panic!("co-tenant joint run: {e}"));
        let spans = (0..scheds.len())
            .map(|k| sim.engine.instance_makespan(k))
            .collect();
        sim.select_stream_bank(0);
        (spans, lean.makespan, lean.events)
    }

    /// Evaluate `jobs` as co-tenants of one machine (ISSUE 10): every
    /// job's plan is lowered and admitted into a single shared live
    /// simulation at its offset, on a private stream bank, so jobs
    /// contend for CUs / HBM / links / DMA engines through max–min
    /// fair sharing exactly like the paper's intra-job kernels do.
    /// Reports each job's co-tenant makespan next to its isolated one
    /// (the slowdown-vs-isolated interference signature) plus the
    /// joint span. Deterministic: the result is a pure function of
    /// (machine, jobs), independent of evaluator history.
    pub fn cotenant(&mut self, machine: &Machine, jobs: &[CotenantJob]) -> CotenantEval {
        assert!(!jobs.is_empty(), "co-tenant evaluation needs >= 1 job");
        assert!(
            jobs.iter().all(|j| j.offset.is_finite() && j.offset >= 0.0),
            "co-tenant offsets must be finite and >= 0"
        );
        for w in jobs.windows(2) {
            assert!(
                w[1].offset >= w[0].offset,
                "co-tenant offsets must be nondecreasing (the admission clock only moves forward)"
            );
        }
        let isolated: Vec<f64> = jobs
            .iter()
            .map(|j| self.plan_makespan(machine, &j.scenario, &j.plan))
            .collect();
        let scheds = self.lower_cotenant(jobs);
        let (spans, span, events) = self.run_cotenant_joint(machine, jobs, &scheds);
        let jobs_out = jobs
            .iter()
            .enumerate()
            .map(|(k, j)| CotenantJobEval {
                isolated: isolated[k],
                makespan: spans[k],
                slowdown: spans[k] / isolated[k],
                offset: j.offset,
                n_tasks: scheds[k].nodes.len(),
            })
            .collect();
        CotenantEval {
            jobs: jobs_out,
            span,
            events,
        }
    }

    /// As [`Evaluator::cotenant`], additionally capturing the joint
    /// timeline under a [`TimelineRecorder`] (with human-readable,
    /// `j<k>:`-prefixed node labels) for co-tenant Perfetto traces —
    /// cross-job contention shows up as throttled windows on one job's
    /// spans while another job's are live. Returns the evaluation, the
    /// full engine report of the joint run, the recorder, and the
    /// track map covering every tenant stream bank.
    pub fn capture_cotenant(
        &mut self,
        machine: &Machine,
        jobs: &[CotenantJob],
    ) -> (CotenantEval, Report, TimelineRecorder, TrackMap) {
        assert!(!jobs.is_empty(), "co-tenant evaluation needs >= 1 job");
        let keep = self.keep_labels;
        self.keep_labels = true;
        let isolated: Vec<f64> = jobs
            .iter()
            .map(|j| self.plan_makespan(machine, &j.scenario, &j.plan))
            .collect();
        let scheds = self.lower_cotenant(jobs);
        let mut rec = TimelineRecorder::new();
        let (spans, report, track_map) =
            self.run_cotenant_joint_captured(machine, jobs, &scheds, &mut rec);
        self.keep_labels = keep;
        let jobs_out = jobs
            .iter()
            .enumerate()
            .map(|(k, j)| CotenantJobEval {
                isolated: isolated[k],
                makespan: spans[k],
                slowdown: spans[k] / isolated[k],
                offset: j.offset,
                n_tasks: scheds[k].nodes.len(),
            })
            .collect();
        let eval = CotenantEval {
            jobs: jobs_out,
            span: report.makespan,
            events: report.events,
        };
        (eval, report, rec, track_map)
    }

    /// The full-accounting, recorded companion of
    /// [`Evaluator::run_cotenant_joint`] — same admission sequence,
    /// driven through the `*_recorded` stepper calls so the recorder
    /// observes every structural event of the joint run.
    fn run_cotenant_joint_captured(
        &mut self,
        machine: &Machine,
        jobs: &[CotenantJob],
        scheds: &[Schedule],
        rec: &mut TimelineRecorder,
    ) -> (Vec<f64>, Report, TrackMap) {
        self.ensure_sim(machine);
        self.gemm_tasks.clear();
        self.xfer_tasks.clear();
        self.gemm_iso_per_gpu.clear();
        self.gemm_iso_per_gpu.resize(machine.ngpus(), 0.0);
        {
            let sim = self.sim.as_mut().expect("sim bound above");
            sim.reset();
            sim.engine.begin_run_recorded(rec);
        }
        for (k, sched) in scheds.iter().enumerate() {
            {
                let sim = self.sim.as_mut().expect("sim bound above");
                sim.select_stream_bank(k);
                sim.engine
                    .advance_until_recorded(jobs[k].offset, rec)
                    .unwrap_or_else(|e| panic!("co-tenant advance to t={}: {e}", jobs[k].offset));
            }
            self.append_graph(machine, sched, Some(k));
            self.sim
                .as_mut()
                .expect("sim bound above")
                .engine
                .admit_appended_recorded(rec)
                .unwrap_or_else(|e| panic!("co-tenant admission at t={}: {e}", jobs[k].offset));
        }
        let sim = self.sim.as_mut().expect("sim bound above");
        let report = sim
            .engine
            .finish_run_recorded(rec)
            .unwrap_or_else(|e| panic!("co-tenant joint run: {e}"));
        let spans = (0..scheds.len())
            .map(|k| sim.engine.instance_makespan(k))
            .collect();
        let track_map = sim.track_map();
        sim.select_stream_bank(0);
        (spans, report, track_map)
    }

    /// Robustness of the joint co-tenant span under a perturbation
    /// ensemble (`--robust` composing with `ficco cotenant`): the
    /// joint simulation re-runs per ensemble sample with the sample's
    /// multipliers installed at task-build time, mirroring
    /// [`Evaluator::plan_robust_stats`]. A nominal ensemble
    /// short-circuits to the nominal span without touching the sim.
    pub fn cotenant_robust_span(
        &mut self,
        machine: &Machine,
        jobs: &[CotenantJob],
        ens: &Perturbation,
        nominal_span: f64,
    ) -> RobustStats {
        if ens.is_nominal() {
            return RobustStats {
                nominal: nominal_span,
                p50: nominal_span,
                p95: nominal_span,
                worst: nominal_span,
            };
        }
        let scheds = self.lower_cotenant(jobs);
        let ngpus = machine.ngpus();
        let nlinks = machine.topo.num_links();
        self.ensure_sim(machine);
        let mut spans: Vec<f64> = (0..ens.samples)
            .map(|i| {
                let sample = ens.sample(i, ngpus, nlinks);
                self.sim
                    .as_mut()
                    .expect("sim bound above")
                    .set_perturb(Some(sample));
                let (_, span, _) = self.run_cotenant_joint(machine, jobs, &scheds);
                span
            })
            .collect();
        self.sim
            .as_mut()
            .expect("sim bound above")
            .set_perturb(None);
        spans.sort_by(f64::total_cmp);
        RobustStats {
            nominal: nominal_span,
            p50: percentile_sorted(&spans, 0.50),
            p95: percentile_sorted(&spans, 0.95),
            worst: *spans.last().expect("samples >= 1"),
        }
    }

    /// The currently loaded engine — exporters read task labels,
    /// streams and demands from it (panics before any graph is
    /// loaded).
    pub fn engine(&self) -> &crate::sim::Engine {
        &self.sim.as_ref().expect("graph loaded").engine
    }
}

impl Default for Evaluator {
    fn default() -> Self {
        Evaluator::new()
    }
}

/// Execute `sched` on `machine` (one-shot wrapper over a throwaway
/// [`Evaluator`]); panics on simulator livelock (which would indicate
/// a malformed schedule — run `validate` first).
pub fn execute(machine: &Machine, sched: &Schedule) -> ExecResult {
    Evaluator::new().execute(machine, sched)
}

/// Analytic lower bound on the simulated makespan of `sched`: the
/// maximum over per-stream serial work and per-resource total demand
/// of the task graph as built (see [`crate::sim::Engine::lower_bound`]).
/// Orders of magnitude cheaper than running the simulation — the
/// search subsystem uses it to prune plans whose bound already
/// exceeds the incumbent.
pub fn makespan_lower_bound(machine: &Machine, sched: &Schedule) -> f64 {
    let mut ev = Evaluator::new();
    ev.load(machine, sched);
    ev.loaded_bound()
}

fn mean_slowdown(report: &crate::sim::Report, tasks: &[TaskId]) -> f64 {
    if tasks.is_empty() {
        return 1.0;
    }
    let s: f64 = tasks.iter().map(|&t| report.slowdown(t)).sum();
    s / tasks.len() as f64
}

/// Isolated communication leg of a schedule kind (closed form), with
/// the mechanism its transfers actually ride. Skewed scenarios route
/// through the per-peer byte-vector forms; the uniform scalar path is
/// kept verbatim at `skew == 0` so the frozen goldens stay bit-stable.
fn comm_leg_isolated(machine: &Machine, sc: &Scenario, kind: Kind, mech: CommMech) -> f64 {
    use crate::cost::collective as cc;
    if sc.skew != 0.0 {
        let bytes = sc.shard_bytes_per_gpu();
        return match kind {
            Kind::Baseline => {
                cc::ag_all_to_all_time_vec(&machine.gpu, &machine.topo, &bytes, mech)
            }
            Kind::ShardOverlap => cc::ag_ring_time_vec(&machine.gpu, &machine.topo, &bytes, mech),
            _ => cc::ag_ficco_time_vec(&machine.gpu, &machine.topo, &bytes, sc.ngpus, mech),
        };
    }
    let shard = sc.shard_bytes();
    match kind {
        Kind::Baseline => cc::ag_all_to_all_time(&machine.gpu, &machine.topo, shard, mech),
        Kind::ShardOverlap => cc::ag_ring_time(&machine.gpu, &machine.topo, shard, mech),
        _ => cc::ag_ficco_time(&machine.gpu, &machine.topo, shard, mech),
    }
}

/// Evaluate one scenario under one schedule kind through a reusable
/// evaluator (generate → validate → simulate).
pub fn evaluate_in(
    ev: &mut Evaluator,
    machine: &Machine,
    sc: &Scenario,
    kind: Kind,
) -> ExecResult {
    let sched = super::generate::generate(kind, sc);
    super::validate::validate(&sched)
        .unwrap_or_else(|e| panic!("{} for {}: {e}", kind.name(), sc.name));
    ev.execute(machine, &sched)
}

/// Evaluate one scenario under one schedule kind (generate → validate
/// → simulate).
pub fn evaluate(machine: &Machine, sc: &Scenario, kind: Kind) -> ExecResult {
    evaluate_in(&mut Evaluator::new(), machine, sc, kind)
}

/// Evaluate one scenario under an arbitrary plan-space point (lower →
/// validate → simulate, full accounting).
pub fn evaluate_plan(machine: &Machine, sc: &Scenario, plan: &crate::plan::Plan) -> ExecResult {
    let sched = crate::plan::lower(plan, sc);
    super::validate::validate(&sched)
        .unwrap_or_else(|e| panic!("plan {} for {}: {e}", plan.id(), sc.name));
    execute(machine, &sched)
}

/// Scenario-level summary across all schedule kinds (the per-row data
/// behind Figs 12b/13/14).
#[derive(Debug, Clone)]
pub struct ScenarioEval {
    pub scenario: Scenario,
    pub results: Vec<ExecResult>,
    /// Serial reference (baseline makespan).
    pub baseline: f64,
    /// Perfect-overlap bound: max(compute leg, baseline comm leg).
    pub ideal: f64,
}

impl ScenarioEval {
    pub fn run(machine: &Machine, sc: &Scenario, kinds: &[Kind]) -> ScenarioEval {
        ScenarioEval::run_in(&mut Evaluator::new(), machine, sc, kinds)
    }

    /// As [`ScenarioEval::run`], through a caller-owned reusable
    /// [`Evaluator`] (one arena across all kinds — and across cells,
    /// when the caller is a sweep worker).
    pub fn run_in(
        ev: &mut Evaluator,
        machine: &Machine,
        sc: &Scenario,
        kinds: &[Kind],
    ) -> ScenarioEval {
        let results: Vec<ExecResult> = kinds
            .iter()
            .map(|&k| evaluate_in(ev, machine, sc, k))
            .collect();
        // The serial reference is always measured, even when the
        // baseline kind itself is filtered out of `kinds` (speedups
        // need it); when it *was* requested, reuse that measurement.
        let baseline = match results.iter().find(|r| r.kind == Kind::Baseline) {
            Some(r) => r.makespan,
            None => evaluate_in(ev, machine, sc, Kind::Baseline).makespan,
        };
        // Perfect-overlap bound from the closed-form legs, computed
        // unconditionally: the compute leg is the full per-GPU GEMM in
        // isolation, the comm leg the serial baseline collective (the
        // baseline is pinned to kernel-driven comm). Previously this
        // was copied off the baseline's ExecResult and stayed NaN when
        // that kind was filtered out; the values are identical when it
        // is present.
        let gemm_leg = GemmCost::new(&machine.gpu).time(&sc.gemm);
        let comm_leg = comm_leg_isolated(machine, sc, Kind::Baseline, CommMech::Kernel);
        let ideal = gemm_leg.max(comm_leg);
        ScenarioEval {
            scenario: sc.clone(),
            results,
            baseline,
            ideal,
        }
    }

    /// The measured result for one kind, or `None` when that kind was
    /// not among the evaluated set — the fallible companion to the
    /// panicking [`ScenarioEval::speedup`] for callers that evaluate
    /// a filtered subset of [`Kind`]s.
    pub fn result(&self, kind: Kind) -> Option<&ExecResult> {
        self.results.iter().find(|r| r.kind == kind)
    }

    pub fn speedup(&self, kind: Kind) -> f64 {
        let r = self
            .result(kind)
            .unwrap_or_else(|| panic!("{} not evaluated", kind.name()));
        self.baseline / r.makespan
    }

    pub fn ideal_speedup(&self) -> f64 {
        self.baseline / self.ideal
    }

    /// Best FiCCO schedule by measured makespan (the oracle the
    /// heuristic is scored against in §VI-D), or `None` when the
    /// evaluated kinds included no FiCCO schedule — callers that
    /// filter `kinds` must handle the empty family instead of
    /// panicking.
    pub fn best_ficco(&self) -> Option<(Kind, f64)> {
        self.results
            .iter()
            .filter(|r| r.kind.is_ficco())
            .map(|r| (r.kind, self.baseline / r.makespan))
            .max_by(|a, b| a.1.partial_cmp(&b.1).expect("finite speedups"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Scenario;

    fn machine() -> Machine {
        Machine::mi300x_8()
    }

    /// A comm-heavy Table-I-like scenario (g6 with N scaled down to
    /// keep unit tests fast; comm/compute balance preserved and shard
    /// sizes realistic so pieces stay off the small-message ramp).
    fn sc_comm_heavy() -> Scenario {
        Scenario::new("g6-like", 262144, 2048, 8192)
    }

    #[test]
    fn baseline_is_serial_sum() {
        let m = machine();
        let sc = sc_comm_heavy();
        let r = evaluate(&m, &sc, Kind::Baseline);
        // Serial: makespan ≈ comm leg + gemm leg (within overheads).
        let serial = r.comm_leg + r.gemm_leg;
        assert!(
            (r.makespan - serial).abs() / serial < 0.15,
            "makespan={} vs serial={}",
            r.makespan,
            serial
        );
    }

    #[test]
    fn shard_overlap_loses_on_mesh() {
        // Fig 13: P2P shard overlap under-utilizes mesh links and
        // fails to beat serial for comm-heavy scenarios.
        let m = machine();
        let ev = ScenarioEval::run(
            &m,
            &sc_comm_heavy(),
            &[Kind::Baseline, Kind::ShardOverlap],
        );
        assert!(
            ev.speedup(Kind::ShardOverlap) < 1.0,
            "shard-overlap speedup {}",
            ev.speedup(Kind::ShardOverlap)
        );
    }

    #[test]
    fn ficco_beats_baseline_on_balanced_scenario() {
        let m = machine();
        let ev = ScenarioEval::run(
            &m,
            &sc_comm_heavy(),
            &[Kind::Baseline, Kind::UniformFused1D],
        );
        let s = ev.speedup(Kind::UniformFused1D);
        assert!(s > 1.0, "uniform-fused-1D speedup {s}");
        // Hard lower bound: the compute leg (with its DIL) must still
        // execute serially on each GPU.
        let r = ev
            .results
            .iter()
            .find(|r| r.kind == Kind::UniformFused1D)
            .unwrap();
        assert!(
            r.makespan >= 0.95 * r.gemm_leg,
            "makespan {} below compute leg {}",
            r.makespan,
            r.gemm_leg
        );
    }

    #[test]
    fn all_kinds_execute() {
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        for kind in Kind::ALL {
            let r = evaluate(&m, &sc, kind);
            assert!(r.makespan > 0.0, "{kind:?}");
            assert!(r.gemm_cil >= 0.999, "{kind:?} gemm cil {}", r.gemm_cil);
        }
    }

    #[test]
    fn reused_evaluator_reports_identical_results() {
        // One evaluator across all kinds (and across machines) must
        // report bit-identical makespans and CILs to fresh one-shot
        // evaluations — the evaluator reuse contract of DESIGN.md §6.
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let mut ev = Evaluator::new();
        for kind in Kind::ALL {
            let reused = evaluate_in(&mut ev, &m, &sc, kind);
            let fresh = evaluate(&m, &sc, kind);
            assert_eq!(reused.makespan.to_bits(), fresh.makespan.to_bits(), "{kind:?}");
            assert_eq!(reused.sim_events, fresh.sim_events, "{kind:?}");
            assert_eq!(reused.gemm_cil.to_bits(), fresh.gemm_cil.to_bits(), "{kind:?}");
            assert_eq!(reused.comm_cil.to_bits(), fresh.comm_cil.to_bits(), "{kind:?}");
        }
        // Rebinding to a different machine mid-stream is safe too.
        let m2 = Machine::pcie_gen4_4();
        let sc2 = Scenario::new("small4", 4096, 512, 1024).with_ngpus(4);
        let reused = evaluate_in(&mut ev, &m2, &sc2, Kind::UniformFused1D);
        let fresh = evaluate(&m2, &sc2, Kind::UniformFused1D);
        assert_eq!(reused.makespan.to_bits(), fresh.makespan.to_bits());
        // And back.
        let again = evaluate_in(&mut ev, &m, &sc, Kind::Baseline);
        assert_eq!(again.makespan.to_bits(), evaluate(&m, &sc, Kind::Baseline).makespan.to_bits());
    }

    #[test]
    fn lean_plan_path_matches_full_evaluation() {
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let mut ev = Evaluator::new();
        for kind in Kind::ALL {
            let plan = Plan::preset(kind, &sc);
            let lean = ev.plan_makespan(&m, &sc, &plan);
            let full = evaluate_plan(&m, &sc, &plan).makespan;
            assert_eq!(lean.to_bits(), full.to_bits(), "{kind:?}");
        }
    }

    #[test]
    fn load_plan_bound_never_exceeds_lean_makespan() {
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let mut ev = Evaluator::new();
        let plan = Plan::preset(Kind::UniformFused1D, &sc);
        let bound = ev.load_plan(&m, &sc, &plan);
        let makespan = ev.run_loaded_lean().expect("loaded").makespan;
        assert!(bound <= makespan * (1.0 + 1e-9), "bound {bound} > {makespan}");
        assert_eq!(bound.to_bits(), makespan_lower_bound(&m, &plan.lower(&sc)).to_bits());
    }

    #[test]
    fn ideal_is_finite_for_filtered_kinds() {
        // Regression: `ideal` stayed NaN (and `baseline` panicked)
        // when the kinds filter dropped the baseline that used to
        // carry the closed-form legs.
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let ev = ScenarioEval::run(&m, &sc, &[Kind::UniformFused1D]);
        assert!(ev.ideal.is_finite() && ev.ideal > 0.0, "ideal {}", ev.ideal);
        assert!(ev.baseline.is_finite() && ev.baseline > 0.0);
        assert!(ev.ideal_speedup().is_finite());
        assert!(ev.speedup(Kind::UniformFused1D) > 0.0);
        // And the filtered evaluation agrees with the full one.
        let full = ScenarioEval::run(&m, &sc, &Kind::ALL);
        assert_eq!(ev.ideal, full.ideal, "ideal independent of the filter");
        assert_eq!(ev.baseline, full.baseline);
    }

    #[test]
    fn best_ficco_is_none_for_ficco_free_kinds() {
        // Regression: used to `.expect` ("no FiCCO kinds evaluated").
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let ev = ScenarioEval::run(&m, &sc, &[Kind::Baseline, Kind::ShardOverlap]);
        assert!(ev.best_ficco().is_none());
        let full = ScenarioEval::run(&m, &sc, &Kind::ALL);
        let (kind, speedup) = full.best_ficco().expect("FiCCO kinds evaluated");
        assert!(kind.is_ficco());
        assert!(speedup > 0.0);
    }

    #[test]
    fn cell_scope_is_observationally_pure() {
        // Loading plans inside a cell scope (memoized partitions, lean
        // labels, elided re-validation) must report bit-identical
        // makespans and bounds to scope-free loads — including under
        // skew, where the partition construction is the expensive
        // part being memoized.
        let m = machine();
        for sc in [
            Scenario::new("small", 4096, 512, 1024),
            Scenario::new("small-skew", 4096, 512, 1024).with_skew(0.8, 13),
        ] {
            let mut cold = Evaluator::new();
            let mut warm = Evaluator::new();
            warm.begin_cell(&sc);
            for kind in Kind::ALL {
                let plan = Plan::preset(kind, &sc);
                let cb = cold.load_plan(&m, &sc, &plan);
                let wb = warm.load_plan(&m, &sc, &plan);
                assert_eq!(cb.to_bits(), wb.to_bits(), "{kind:?} bound");
                let cm = cold.run_loaded_lean().expect("cold").makespan;
                let wm = warm.run_loaded_lean().expect("warm").makespan;
                assert_eq!(cm.to_bits(), wm.to_bits(), "{kind:?} makespan");
            }
            // A scenario with different partition inputs bypasses the
            // scope rather than reusing a stale partition.
            let other = sc.clone().with_ngpus(4);
            let m4 = Machine::pcie_gen4_4();
            let plan = Plan::preset(Kind::UniformFused1D, &other);
            let via_scope = warm.plan_makespan(&m4, &other, &plan);
            let fresh = Evaluator::new().plan_makespan(&m4, &other, &plan);
            assert_eq!(via_scope.to_bits(), fresh.to_bits());
            warm.end_cell();
            assert!(warm.cell_incumbent().is_none());
        }
    }

    #[test]
    fn cell_incumbent_keeps_the_tighter_makespan() {
        let sc = Scenario::new("small", 4096, 512, 1024);
        let mut ev = Evaluator::new();
        // Without a scope, noting is a no-op.
        let p = Plan::preset(Kind::UniformFused1D, &sc);
        ev.note_cell_incumbent(p, 1.0);
        assert!(ev.cell_incumbent().is_none());
        ev.begin_cell(&sc);
        assert!(ev.cell_incumbent().is_none());
        ev.note_cell_incumbent(p, 2.0);
        assert_eq!(ev.cell_incumbent().map(|(_, ms)| ms), Some(2.0));
        let q = Plan::preset(Kind::HeteroFused1D, &sc);
        ev.note_cell_incumbent(q, 3.0); // looser: ignored
        assert_eq!(ev.cell_incumbent(), Some((p, 2.0)));
        ev.note_cell_incumbent(q, 1.5); // tighter: replaces
        assert_eq!(ev.cell_incumbent(), Some((q, 1.5)));
    }

    #[test]
    fn robust_stats_of_a_nominal_ensemble_are_the_nominal_bits() {
        // Zero-magnitude ensembles must not even touch the simulator:
        // every statistic is the nominal makespan, bit for bit.
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let plan = Plan::preset(Kind::UniformFused1D, &sc);
        let mut ev = Evaluator::new();
        let nominal = ev.plan_makespan(&m, &sc, &plan);
        let ens = Perturbation {
            compute: 0.0,
            bandwidth: 0.0,
            setup: 0.0,
            samples: 8,
            seed: 3,
        };
        let st = ev.plan_robust_stats(&m, &sc, &plan, &ens, nominal);
        for v in [st.nominal, st.p50, st.p95, st.worst] {
            assert_eq!(v.to_bits(), nominal.to_bits());
        }
        assert_eq!(st.fragility().to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn robust_stats_are_ordered_and_leave_nominal_runs_untouched() {
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let plan = Plan::preset(Kind::UniformFused1D, &sc);
        let mut ev = Evaluator::new();
        let nominal = ev.plan_makespan(&m, &sc, &plan);
        let ens = Perturbation::defaults(6, 17);
        let st = ev.plan_robust_stats(&m, &sc, &plan, &ens, nominal);
        assert!(st.p50 <= st.p95 && st.p95 <= st.worst, "{st:?}");
        assert!(st.worst > nominal, "perturbation should cost something: {st:?}");
        // The ensemble evaluation must clear its sample: a nominal
        // makespan measured right after is bit-identical.
        let after = ev.plan_makespan(&m, &sc, &plan);
        assert_eq!(after.to_bits(), nominal.to_bits());
        // Determinism: a fresh evaluator reproduces the stats bitwise.
        let again = Evaluator::new().plan_robust_stats(&m, &sc, &plan, &ens, nominal);
        assert_eq!(st, again);
    }

    fn jobs_of(sc: &Scenario, kinds: &[Kind], offsets: &[f64]) -> Vec<CotenantJob> {
        kinds
            .iter()
            .zip(offsets)
            .map(|(&k, &off)| CotenantJob {
                scenario: sc.clone(),
                plan: Plan::preset(k, sc),
                offset: off,
            })
            .collect()
    }

    #[test]
    fn cotenant_single_job_matches_isolated_bitwise() {
        // One tenant admitted at t=0 takes the admission path through
        // bank 0's streams — the makespan must be bit-identical to the
        // one-shot lean run (and the slowdown exactly 1).
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let mut ev = Evaluator::new();
        for kind in [Kind::Baseline, Kind::UniformFused1D] {
            let jobs = jobs_of(&sc, &[kind], &[0.0]);
            let co = ev.cotenant(&m, &jobs);
            assert_eq!(co.jobs.len(), 1);
            assert_eq!(co.jobs[0].makespan.to_bits(), co.jobs[0].isolated.to_bits());
            assert_eq!(co.span.to_bits(), co.jobs[0].isolated.to_bits());
            assert_eq!(co.jobs[0].slowdown.to_bits(), 1.0f64.to_bits());
        }
    }

    #[test]
    fn cotenant_jobs_interfere_and_finish_later() {
        let m = machine();
        let sc = sc_comm_heavy();
        let mut ev = Evaluator::new();
        let jobs = jobs_of(
            &sc,
            &[Kind::UniformFused1D, Kind::UniformFused1D],
            &[0.0, 0.0],
        );
        let co = ev.cotenant(&m, &jobs);
        assert_eq!(co.jobs.len(), 2);
        for j in &co.jobs {
            assert!(j.isolated > 0.0 && j.makespan.is_finite());
            assert!(j.slowdown >= 1.0 - 1e-9, "slowdown {}", j.slowdown);
        }
        // Two copies of the same comm-heavy job on one machine must
        // contend somewhere (links/HBM): at least one slows down.
        assert!(
            co.jobs.iter().any(|j| j.slowdown > 1.01),
            "no interference visible: {co:?}"
        );
        // The joint span covers every job's absolute finish.
        for j in &co.jobs {
            assert!(co.span >= j.offset + j.makespan - 1e-12);
        }
        // Determinism: a fresh evaluator reproduces the bits.
        let again = Evaluator::new().cotenant(&m, &jobs);
        assert_eq!(co.span.to_bits(), again.span.to_bits());
        assert_eq!(co.events, again.events);
        for (a, b) in co.jobs.iter().zip(&again.jobs) {
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
            assert_eq!(a.slowdown.to_bits(), b.slowdown.to_bits());
        }
    }

    #[test]
    fn staggered_admission_orders_and_bounds_the_span() {
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let mut ev = Evaluator::new();
        let iso = ev.plan_makespan(&m, &sc, &Plan::preset(Kind::UniformFused1D, &sc));
        // Admit job 1 after job 0 completes: zero overlap, so both run
        // at isolated speed and the span is offset + isolated.
        let offset = 2.0 * iso;
        let jobs = jobs_of(
            &sc,
            &[Kind::UniformFused1D, Kind::UniformFused1D],
            &[0.0, offset],
        );
        let co = ev.cotenant(&m, &jobs);
        // Job 0 ran its entire life alone from t=0: the exact one-shot
        // event sequence, so its makespan is bit-identical to iso.
        assert_eq!(co.jobs[0].makespan.to_bits(), iso.to_bits());
        // Job 1 also runs alone but at a shifted absolute clock, where
        // time additions round differently — equal to tolerance only.
        assert!(
            (co.jobs[1].slowdown - 1.0).abs() < 1e-9,
            "late job slowed: {}",
            co.jobs[1].slowdown
        );
        assert!((co.span - (offset + iso)).abs() < 1e-9, "span {}", co.span);
    }

    #[test]
    fn cotenant_leaves_one_shot_evaluations_untouched() {
        // The joint run registers tenant stream banks on the shared
        // arena; a one-shot evaluation right after must still be
        // bit-identical to a fresh evaluator's.
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let plan = Plan::preset(Kind::UniformFused1D, &sc);
        let mut ev = Evaluator::new();
        let before = ev.plan_makespan(&m, &sc, &plan);
        let jobs = jobs_of(&sc, &[Kind::UniformFused1D, Kind::HeteroFused1D], &[0.0, 0.0]);
        let _ = ev.cotenant(&m, &jobs);
        let after = ev.plan_makespan(&m, &sc, &plan);
        assert_eq!(before.to_bits(), after.to_bits());
    }

    #[test]
    fn captured_cotenant_matches_lean_bitwise_and_covers_tracks() {
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let mut ev = Evaluator::new();
        let jobs = jobs_of(
            &sc,
            &[Kind::UniformFused1D, Kind::UniformFused1D],
            &[0.0, 0.001],
        );
        let lean = ev.cotenant(&m, &jobs);
        let (cap, report, rec, tm) = ev.capture_cotenant(&m, &jobs);
        assert_eq!(cap.span.to_bits(), lean.span.to_bits());
        assert_eq!(cap.events, lean.events);
        for (a, b) in cap.jobs.iter().zip(&lean.jobs) {
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
            assert_eq!(a.isolated.to_bits(), b.isolated.to_bits());
        }
        assert_eq!(report.makespan.to_bits(), lean.span.to_bits());
        assert_eq!(rec.end.to_bits(), lean.span.to_bits());
        // The track map covers every registered stream, including both
        // tenant banks, and the engine carries job-prefixed labels.
        assert_eq!(tm.streams.len(), ev.engine().n_streams());
        let eng = ev.engine();
        assert!((0..eng.n_tasks())
            .any(|t| eng.task_label(t).to_string().starts_with("j1:")));
        assert!(tm.streams.iter().any(|s| s.name.starts_with("j1:")));
    }

    #[test]
    fn cotenant_nominal_ensemble_is_the_nominal_bits() {
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let mut ev = Evaluator::new();
        let jobs = jobs_of(&sc, &[Kind::UniformFused1D, Kind::UniformFused1D], &[0.0, 0.0]);
        let co = ev.cotenant(&m, &jobs);
        let ens = Perturbation {
            compute: 0.0,
            bandwidth: 0.0,
            setup: 0.0,
            samples: 4,
            seed: 9,
        };
        let st = ev.cotenant_robust_span(&m, &jobs, &ens, co.span);
        for v in [st.nominal, st.p50, st.p95, st.worst] {
            assert_eq!(v.to_bits(), co.span.to_bits());
        }
        // A live ensemble orders its statistics and costs something.
        let ens = Perturbation::defaults(4, 21);
        let st = ev.cotenant_robust_span(&m, &jobs, &ens, co.span);
        assert!(st.p50 <= st.p95 && st.p95 <= st.worst, "{st:?}");
        assert!(st.worst > co.span, "{st:?}");
        // And it clears the sample: the nominal joint run reproduces.
        let back = ev.cotenant(&m, &jobs);
        assert_eq!(back.span.to_bits(), co.span.to_bits());
    }

    #[test]
    fn skewed_scenario_executes_and_costs_more_comm() {
        // A hot expert inflates the comm leg and the hot GPU's load;
        // at skew 0 the scenario is exactly the uniform one.
        let m = machine();
        let sc = sc_comm_heavy();
        let skewed = sc.clone().with_skew(1.0, 7);
        let base = evaluate(&m, &sc, Kind::UniformFused1D);
        let hot = evaluate(&m, &skewed, Kind::UniformFused1D);
        assert!(hot.makespan.is_finite() && hot.makespan > 0.0);
        assert!(
            hot.comm_leg > base.comm_leg,
            "skewed comm leg {} <= uniform {}",
            hot.comm_leg,
            base.comm_leg
        );
        let zero = evaluate(&m, &sc.clone().with_skew(0.0, 99), Kind::UniformFused1D);
        assert_eq!(zero.makespan, base.makespan, "skew 0 is bit-compatible");
        assert_eq!(zero.comm_leg, base.comm_leg);
    }
}
