//! Lower a [`Schedule`] onto the fluid cluster simulator and measure
//! it. This is where DIL (via `cost::gemm` isolated times) and CIL
//! (via resource sharing in `sim`) combine into end-to-end makespans —
//! the quantity behind Figs 12b, 13, and 14.

use super::{Kind, OpKind, Scenario, Schedule};
use crate::cost::gemm::GemmCost;
use crate::hw::Machine;
use crate::sim::{ClusterSim, CommMech, TaskId};

/// Measured execution of one schedule.
#[derive(Debug, Clone)]
pub struct ExecResult {
    pub kind: Kind,
    pub makespan: f64,
    /// Σ isolated GEMM time per GPU (max over GPUs) — the compute leg.
    pub gemm_leg: f64,
    /// Serial-communication leg (critical path of transfers, isolated).
    pub comm_leg: f64,
    /// Mean slowdown of GEMM tasks vs isolation (measured CIL).
    pub gemm_cil: f64,
    /// Mean slowdown of transfer tasks vs isolation (measured CIL).
    pub comm_cil: f64,
    pub n_tasks: usize,
    pub sim_events: usize,
}

/// Execute `sched` on `machine`; panics on simulator livelock (which
/// would indicate a malformed schedule — run `validate` first).
pub fn execute(machine: &Machine, sched: &Schedule) -> ExecResult {
    let mut sim = ClusterSim::new(machine.clone());
    let gcost = GemmCost::new(&machine.gpu);
    // The serial baseline and shard-overlap (AsyncTP) are the
    // PyTorch-stack reference points: GPU-core-driven (RCCL / SM-copy)
    // communication. FiCCO schedules use the scenario's mechanism
    // (DMA by default; Kernel for the FiCCO-rccl ablation).
    let mech = match sched.kind {
        Kind::Baseline | Kind::ShardOverlap => CommMech::Kernel,
        _ => sched.scenario.mech,
    };
    let dtype = sched.scenario.dtype();

    let mut task_of: Vec<TaskId> = Vec::with_capacity(sched.nodes.len());
    let mut gemm_tasks: Vec<TaskId> = Vec::new();
    let mut xfer_tasks: Vec<TaskId> = Vec::new();
    let mut gemm_iso_per_gpu = vec![0.0f64; machine.ngpus()];

    for node in &sched.nodes {
        let deps: Vec<TaskId> = node.deps.iter().map(|&d| task_of[d]).collect();
        let tid = match &node.kind {
            OpKind::Gemm { shape, .. } => {
                let t = gcost.time(shape);
                gemm_iso_per_gpu[node.gpu] += t;
                let id = sim.gemm_task(
                    node.gpu,
                    node.label.clone(),
                    t,
                    shape.bytes(),
                    gcost.cus_used(shape),
                    &deps,
                );
                gemm_tasks.push(id);
                id
            }
            OpKind::Xfer { src, region } => {
                let id = sim.transfer_task(
                    *src,
                    node.gpu,
                    node.slot,
                    node.label.clone(),
                    region.bytes(dtype),
                    mech,
                    &deps,
                );
                xfer_tasks.push(id);
                id
            }
            OpKind::Gather { bytes } => sim.local_copy_task(
                node.gpu,
                node.label.clone(),
                *bytes,
                CommMech::Kernel,
                &deps,
            ),
            OpKind::Scatter { bytes } => sim.local_copy_task(
                node.gpu,
                node.label.clone(),
                *bytes,
                CommMech::Kernel,
                &deps,
            ),
        };
        task_of.push(tid);
    }

    let n_tasks = sched.nodes.len();
    let report = sim.run().unwrap_or_else(|e| {
        panic!("simulating {} for {}: {e}", sched.kind.name(), sched.scenario.name)
    });

    let gemm_cil = mean_slowdown(&report, &gemm_tasks);
    let comm_cil = mean_slowdown(&report, &xfer_tasks);
    let gemm_leg = gemm_iso_per_gpu.iter().cloned().fold(0.0, f64::max);
    let comm_leg = comm_leg_isolated(machine, &sched.scenario, sched.kind);

    ExecResult {
        kind: sched.kind,
        makespan: report.makespan,
        gemm_leg,
        comm_leg,
        gemm_cil,
        comm_cil,
        n_tasks,
        sim_events: report.events,
    }
}

fn mean_slowdown(report: &crate::sim::Report, tasks: &[TaskId]) -> f64 {
    if tasks.is_empty() {
        return 1.0;
    }
    let s: f64 = tasks.iter().map(|&t| report.slowdown(t)).sum();
    s / tasks.len() as f64
}

/// Isolated communication leg of a schedule kind (closed form).
fn comm_leg_isolated(machine: &Machine, sc: &Scenario, kind: Kind) -> f64 {
    use crate::cost::collective as cc;
    let shard = sc.shard_bytes();
    match kind {
        Kind::Baseline => {
            cc::ag_all_to_all_time(&machine.gpu, &machine.topo, shard, CommMech::Kernel)
        }
        Kind::ShardOverlap => {
            cc::ag_ring_time(&machine.gpu, &machine.topo, shard, CommMech::Kernel)
        }
        _ => cc::ag_ficco_time(&machine.gpu, &machine.topo, shard, sc.mech),
    }
}

/// Evaluate one scenario under one schedule kind (generate → validate
/// → simulate).
pub fn evaluate(machine: &Machine, sc: &Scenario, kind: Kind) -> ExecResult {
    let sched = super::generate::generate(kind, sc);
    super::validate::validate(&sched)
        .unwrap_or_else(|e| panic!("{} for {}: {e}", kind.name(), sc.name));
    execute(machine, &sched)
}

/// Scenario-level summary across all schedule kinds (the per-row data
/// behind Figs 12b/13/14).
#[derive(Debug, Clone)]
pub struct ScenarioEval {
    pub scenario: Scenario,
    pub results: Vec<ExecResult>,
    /// Serial reference (baseline makespan).
    pub baseline: f64,
    /// Perfect-overlap bound: max(compute leg, baseline comm leg).
    pub ideal: f64,
}

impl ScenarioEval {
    pub fn run(machine: &Machine, sc: &Scenario, kinds: &[Kind]) -> ScenarioEval {
        let mut results = Vec::new();
        let mut baseline = f64::NAN;
        let mut ideal = f64::NAN;
        for &k in kinds {
            let r = evaluate(machine, sc, k);
            if k == Kind::Baseline {
                baseline = r.makespan;
                ideal = r.gemm_leg.max(r.comm_leg);
            }
            results.push(r);
        }
        assert!(
            !baseline.is_nan(),
            "ScenarioEval requires Kind::Baseline among kinds"
        );
        ScenarioEval {
            scenario: sc.clone(),
            results,
            baseline,
            ideal,
        }
    }

    /// The measured result for one kind, or `None` when that kind was
    /// not among the evaluated set — the fallible companion to the
    /// panicking [`ScenarioEval::speedup`] for callers that evaluate
    /// a filtered subset of [`Kind`]s.
    pub fn result(&self, kind: Kind) -> Option<&ExecResult> {
        self.results.iter().find(|r| r.kind == kind)
    }

    pub fn speedup(&self, kind: Kind) -> f64 {
        let r = self
            .result(kind)
            .unwrap_or_else(|| panic!("{} not evaluated", kind.name()));
        self.baseline / r.makespan
    }

    pub fn ideal_speedup(&self) -> f64 {
        self.baseline / self.ideal
    }

    /// Best FiCCO schedule by measured makespan (the oracle the
    /// heuristic is scored against in §VI-D).
    pub fn best_ficco(&self) -> (Kind, f64) {
        self.results
            .iter()
            .filter(|r| r.kind.is_ficco())
            .map(|r| (r.kind, self.baseline / r.makespan))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("no FiCCO kinds evaluated")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Scenario;

    fn machine() -> Machine {
        Machine::mi300x_8()
    }

    /// A comm-heavy Table-I-like scenario (g6 with N scaled down to
    /// keep unit tests fast; comm/compute balance preserved and shard
    /// sizes realistic so pieces stay off the small-message ramp).
    fn sc_comm_heavy() -> Scenario {
        Scenario::new("g6-like", 262144, 2048, 8192)
    }

    #[test]
    fn baseline_is_serial_sum() {
        let m = machine();
        let sc = sc_comm_heavy();
        let r = evaluate(&m, &sc, Kind::Baseline);
        // Serial: makespan ≈ comm leg + gemm leg (within overheads).
        let serial = r.comm_leg + r.gemm_leg;
        assert!(
            (r.makespan - serial).abs() / serial < 0.15,
            "makespan={} vs serial={}",
            r.makespan,
            serial
        );
    }

    #[test]
    fn shard_overlap_loses_on_mesh() {
        // Fig 13: P2P shard overlap under-utilizes mesh links and
        // fails to beat serial for comm-heavy scenarios.
        let m = machine();
        let ev = ScenarioEval::run(
            &m,
            &sc_comm_heavy(),
            &[Kind::Baseline, Kind::ShardOverlap],
        );
        assert!(
            ev.speedup(Kind::ShardOverlap) < 1.0,
            "shard-overlap speedup {}",
            ev.speedup(Kind::ShardOverlap)
        );
    }

    #[test]
    fn ficco_beats_baseline_on_balanced_scenario() {
        let m = machine();
        let ev = ScenarioEval::run(
            &m,
            &sc_comm_heavy(),
            &[Kind::Baseline, Kind::UniformFused1D],
        );
        let s = ev.speedup(Kind::UniformFused1D);
        assert!(s > 1.0, "uniform-fused-1D speedup {s}");
        // Hard lower bound: the compute leg (with its DIL) must still
        // execute serially on each GPU.
        let r = ev
            .results
            .iter()
            .find(|r| r.kind == Kind::UniformFused1D)
            .unwrap();
        assert!(
            r.makespan >= 0.95 * r.gemm_leg,
            "makespan {} below compute leg {}",
            r.makespan,
            r.gemm_leg
        );
    }

    #[test]
    fn all_kinds_execute() {
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        for kind in Kind::ALL {
            let r = evaluate(&m, &sc, kind);
            assert!(r.makespan > 0.0, "{kind:?}");
            assert!(r.gemm_cil >= 0.999, "{kind:?} gemm cil {}", r.gemm_cil);
        }
    }
}
