//! Lower a [`Schedule`] onto the fluid cluster simulator and measure
//! it. This is where DIL (via `cost::gemm` isolated times) and CIL
//! (via resource sharing in `sim`) combine into end-to-end makespans —
//! the quantity behind Figs 12b, 13, and 14.

use super::{Kind, OpKind, Scenario, Schedule};
use crate::cost::gemm::GemmCost;
use crate::hw::Machine;
use crate::sim::{ClusterSim, CommMech, TaskId};

/// Measured execution of one schedule.
#[derive(Debug, Clone)]
pub struct ExecResult {
    pub kind: Kind,
    pub makespan: f64,
    /// Σ isolated GEMM time per GPU (max over GPUs) — the compute leg.
    pub gemm_leg: f64,
    /// Serial-communication leg (critical path of transfers, isolated).
    pub comm_leg: f64,
    /// Mean slowdown of GEMM tasks vs isolation (measured CIL).
    pub gemm_cil: f64,
    /// Mean slowdown of transfer tasks vs isolation (measured CIL).
    pub comm_cil: f64,
    pub n_tasks: usize,
    pub sim_events: usize,
}

/// The communication mechanism a schedule's transfers ride: the plan
/// knob when the schedule was lowered from a plan; otherwise the
/// legacy rule — the serial baseline and shard-overlap (AsyncTP) are
/// the PyTorch-stack reference points with GPU-core-driven (RCCL /
/// SM-copy) communication, FiCCO schedules use the scenario's
/// mechanism (DMA by default; Kernel for the FiCCO-rccl ablation).
fn sched_mech(sched: &Schedule) -> CommMech {
    match sched.plan {
        Some(p) => p.mech,
        None => match sched.kind {
            Kind::Baseline | Kind::ShardOverlap => CommMech::Kernel,
            _ => sched.scenario.mech,
        },
    }
}

/// Simulator tasks of one schedule plus the bookkeeping the metrics
/// need (which tasks are GEMMs/transfers, isolated GEMM time per GPU).
struct Loaded {
    sim: ClusterSim,
    gemm_tasks: Vec<TaskId>,
    xfer_tasks: Vec<TaskId>,
    gemm_iso_per_gpu: Vec<f64>,
}

/// Build the simulator task graph for `sched` without running it —
/// shared by [`execute`] and the analytic [`makespan_lower_bound`].
fn load(machine: &Machine, sched: &Schedule) -> Loaded {
    let mut sim = ClusterSim::new(machine.clone());
    let gcost = GemmCost::new(&machine.gpu);
    let mech = sched_mech(sched);
    let dtype = sched.scenario.dtype();

    let mut task_of: Vec<TaskId> = Vec::with_capacity(sched.nodes.len());
    let mut gemm_tasks: Vec<TaskId> = Vec::new();
    let mut xfer_tasks: Vec<TaskId> = Vec::new();
    let mut gemm_iso_per_gpu = vec![0.0f64; machine.ngpus()];

    for node in &sched.nodes {
        let deps: Vec<TaskId> = node.deps.iter().map(|&d| task_of[d]).collect();
        let tid = match &node.kind {
            OpKind::Gemm { shape, .. } => {
                let t = gcost.time(shape);
                gemm_iso_per_gpu[node.gpu] += t;
                let id = sim.gemm_task(
                    node.gpu,
                    node.label.clone(),
                    t,
                    shape.bytes(),
                    gcost.cus_used(shape),
                    &deps,
                );
                gemm_tasks.push(id);
                id
            }
            OpKind::Xfer { src, region } => {
                let id = sim.transfer_task(
                    *src,
                    node.gpu,
                    node.slot,
                    node.label.clone(),
                    region.bytes(dtype),
                    mech,
                    &deps,
                );
                xfer_tasks.push(id);
                id
            }
            OpKind::Gather { bytes } => sim.local_copy_task(
                node.gpu,
                node.label.clone(),
                *bytes,
                CommMech::Kernel,
                &deps,
            ),
            OpKind::Scatter { bytes } => sim.local_copy_task(
                node.gpu,
                node.label.clone(),
                *bytes,
                CommMech::Kernel,
                &deps,
            ),
        };
        task_of.push(tid);
    }

    Loaded {
        sim,
        gemm_tasks,
        xfer_tasks,
        gemm_iso_per_gpu,
    }
}

/// Run an already-loaded task graph and assemble the metrics.
fn measure(machine: &Machine, sched: &Schedule, loaded: Loaded) -> ExecResult {
    let n_tasks = sched.nodes.len();
    let report = loaded.sim.run().unwrap_or_else(|e| {
        panic!("simulating {} for {}: {e}", sched.kind.name(), sched.scenario.name)
    });

    let gemm_cil = mean_slowdown(&report, &loaded.gemm_tasks);
    let comm_cil = mean_slowdown(&report, &loaded.xfer_tasks);
    let gemm_leg = loaded.gemm_iso_per_gpu.iter().cloned().fold(0.0, f64::max);
    let comm_leg = comm_leg_isolated(machine, &sched.scenario, sched.kind, sched_mech(sched));

    ExecResult {
        kind: sched.kind,
        makespan: report.makespan,
        gemm_leg,
        comm_leg,
        gemm_cil,
        comm_cil,
        n_tasks,
        sim_events: report.events,
    }
}

/// Execute `sched` on `machine`; panics on simulator livelock (which
/// would indicate a malformed schedule — run `validate` first).
pub fn execute(machine: &Machine, sched: &Schedule) -> ExecResult {
    let loaded = load(machine, sched);
    measure(machine, sched, loaded)
}

/// Analytic lower bound on the simulated makespan of `sched`: the
/// maximum over per-stream serial work and per-resource total demand
/// of the task graph as built (see [`crate::sim::Engine::lower_bound`]).
/// Orders of magnitude cheaper than running the simulation — the
/// search subsystem uses it to prune plans whose bound already
/// exceeds the incumbent.
pub fn makespan_lower_bound(machine: &Machine, sched: &Schedule) -> f64 {
    load(machine, sched).sim.engine.lower_bound()
}

fn mean_slowdown(report: &crate::sim::Report, tasks: &[TaskId]) -> f64 {
    if tasks.is_empty() {
        return 1.0;
    }
    let s: f64 = tasks.iter().map(|&t| report.slowdown(t)).sum();
    s / tasks.len() as f64
}

/// Isolated communication leg of a schedule kind (closed form), with
/// the mechanism its transfers actually ride. Skewed scenarios route
/// through the per-peer byte-vector forms; the uniform scalar path is
/// kept verbatim at `skew == 0` so the frozen goldens stay bit-stable.
fn comm_leg_isolated(machine: &Machine, sc: &Scenario, kind: Kind, mech: CommMech) -> f64 {
    use crate::cost::collective as cc;
    if sc.skew != 0.0 {
        let bytes = sc.shard_bytes_per_gpu();
        return match kind {
            Kind::Baseline => {
                cc::ag_all_to_all_time_vec(&machine.gpu, &machine.topo, &bytes, mech)
            }
            Kind::ShardOverlap => cc::ag_ring_time_vec(&machine.gpu, &machine.topo, &bytes, mech),
            _ => cc::ag_ficco_time_vec(&machine.gpu, &machine.topo, &bytes, sc.ngpus, mech),
        };
    }
    let shard = sc.shard_bytes();
    match kind {
        Kind::Baseline => cc::ag_all_to_all_time(&machine.gpu, &machine.topo, shard, mech),
        Kind::ShardOverlap => cc::ag_ring_time(&machine.gpu, &machine.topo, shard, mech),
        _ => cc::ag_ficco_time(&machine.gpu, &machine.topo, shard, mech),
    }
}

/// Evaluate one scenario under one schedule kind (generate → validate
/// → simulate).
pub fn evaluate(machine: &Machine, sc: &Scenario, kind: Kind) -> ExecResult {
    let sched = super::generate::generate(kind, sc);
    super::validate::validate(&sched)
        .unwrap_or_else(|e| panic!("{} for {}: {e}", kind.name(), sc.name));
    execute(machine, &sched)
}

/// Evaluate one scenario under an arbitrary plan-space point (lower →
/// validate → simulate).
pub fn evaluate_plan(machine: &Machine, sc: &Scenario, plan: &crate::plan::Plan) -> ExecResult {
    prepare_plan(machine, sc, plan).run()
}

/// A lowered, validated, loaded-but-not-yet-simulated plan evaluation:
/// the task graph is built exactly once and serves both the analytic
/// lower bound (cheap) and, if the bound does not rule the plan out,
/// the full simulation — so search pruning never constructs the graph
/// twice.
pub struct PreparedEval<'m> {
    machine: &'m Machine,
    sched: Schedule,
    loaded: Loaded,
}

impl<'m> PreparedEval<'m> {
    /// Analytic lower bound of the prepared graph (no simulation).
    pub fn lower_bound(&self) -> f64 {
        self.loaded.sim.engine.lower_bound()
    }

    /// Simulate the prepared graph.
    pub fn run(self) -> ExecResult {
        measure(self.machine, &self.sched, self.loaded)
    }
}

/// Lower → validate → load a plan's task graph, returning the
/// two-phase handle ([`PreparedEval`]).
pub fn prepare_plan<'m>(
    machine: &'m Machine,
    sc: &Scenario,
    plan: &crate::plan::Plan,
) -> PreparedEval<'m> {
    let sched = crate::plan::lower(plan, sc);
    super::validate::validate(&sched)
        .unwrap_or_else(|e| panic!("plan {} for {}: {e}", plan.id(), sc.name));
    let loaded = load(machine, &sched);
    PreparedEval {
        machine,
        sched,
        loaded,
    }
}

/// Scenario-level summary across all schedule kinds (the per-row data
/// behind Figs 12b/13/14).
#[derive(Debug, Clone)]
pub struct ScenarioEval {
    pub scenario: Scenario,
    pub results: Vec<ExecResult>,
    /// Serial reference (baseline makespan).
    pub baseline: f64,
    /// Perfect-overlap bound: max(compute leg, baseline comm leg).
    pub ideal: f64,
}

impl ScenarioEval {
    pub fn run(machine: &Machine, sc: &Scenario, kinds: &[Kind]) -> ScenarioEval {
        let results: Vec<ExecResult> = kinds.iter().map(|&k| evaluate(machine, sc, k)).collect();
        // The serial reference is always measured, even when the
        // baseline kind itself is filtered out of `kinds` (speedups
        // need it); when it *was* requested, reuse that measurement.
        let baseline = match results.iter().find(|r| r.kind == Kind::Baseline) {
            Some(r) => r.makespan,
            None => evaluate(machine, sc, Kind::Baseline).makespan,
        };
        // Perfect-overlap bound from the closed-form legs, computed
        // unconditionally: the compute leg is the full per-GPU GEMM in
        // isolation, the comm leg the serial baseline collective (the
        // baseline is pinned to kernel-driven comm). Previously this
        // was copied off the baseline's ExecResult and stayed NaN when
        // that kind was filtered out; the values are identical when it
        // is present.
        let gemm_leg = GemmCost::new(&machine.gpu).time(&sc.gemm);
        let comm_leg = comm_leg_isolated(machine, sc, Kind::Baseline, CommMech::Kernel);
        let ideal = gemm_leg.max(comm_leg);
        ScenarioEval {
            scenario: sc.clone(),
            results,
            baseline,
            ideal,
        }
    }

    /// The measured result for one kind, or `None` when that kind was
    /// not among the evaluated set — the fallible companion to the
    /// panicking [`ScenarioEval::speedup`] for callers that evaluate
    /// a filtered subset of [`Kind`]s.
    pub fn result(&self, kind: Kind) -> Option<&ExecResult> {
        self.results.iter().find(|r| r.kind == kind)
    }

    pub fn speedup(&self, kind: Kind) -> f64 {
        let r = self
            .result(kind)
            .unwrap_or_else(|| panic!("{} not evaluated", kind.name()));
        self.baseline / r.makespan
    }

    pub fn ideal_speedup(&self) -> f64 {
        self.baseline / self.ideal
    }

    /// Best FiCCO schedule by measured makespan (the oracle the
    /// heuristic is scored against in §VI-D), or `None` when the
    /// evaluated kinds included no FiCCO schedule — callers that
    /// filter `kinds` must handle the empty family instead of
    /// panicking.
    pub fn best_ficco(&self) -> Option<(Kind, f64)> {
        self.results
            .iter()
            .filter(|r| r.kind.is_ficco())
            .map(|r| (r.kind, self.baseline / r.makespan))
            .max_by(|a, b| a.1.partial_cmp(&b.1).expect("finite speedups"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Scenario;

    fn machine() -> Machine {
        Machine::mi300x_8()
    }

    /// A comm-heavy Table-I-like scenario (g6 with N scaled down to
    /// keep unit tests fast; comm/compute balance preserved and shard
    /// sizes realistic so pieces stay off the small-message ramp).
    fn sc_comm_heavy() -> Scenario {
        Scenario::new("g6-like", 262144, 2048, 8192)
    }

    #[test]
    fn baseline_is_serial_sum() {
        let m = machine();
        let sc = sc_comm_heavy();
        let r = evaluate(&m, &sc, Kind::Baseline);
        // Serial: makespan ≈ comm leg + gemm leg (within overheads).
        let serial = r.comm_leg + r.gemm_leg;
        assert!(
            (r.makespan - serial).abs() / serial < 0.15,
            "makespan={} vs serial={}",
            r.makespan,
            serial
        );
    }

    #[test]
    fn shard_overlap_loses_on_mesh() {
        // Fig 13: P2P shard overlap under-utilizes mesh links and
        // fails to beat serial for comm-heavy scenarios.
        let m = machine();
        let ev = ScenarioEval::run(
            &m,
            &sc_comm_heavy(),
            &[Kind::Baseline, Kind::ShardOverlap],
        );
        assert!(
            ev.speedup(Kind::ShardOverlap) < 1.0,
            "shard-overlap speedup {}",
            ev.speedup(Kind::ShardOverlap)
        );
    }

    #[test]
    fn ficco_beats_baseline_on_balanced_scenario() {
        let m = machine();
        let ev = ScenarioEval::run(
            &m,
            &sc_comm_heavy(),
            &[Kind::Baseline, Kind::UniformFused1D],
        );
        let s = ev.speedup(Kind::UniformFused1D);
        assert!(s > 1.0, "uniform-fused-1D speedup {s}");
        // Hard lower bound: the compute leg (with its DIL) must still
        // execute serially on each GPU.
        let r = ev
            .results
            .iter()
            .find(|r| r.kind == Kind::UniformFused1D)
            .unwrap();
        assert!(
            r.makespan >= 0.95 * r.gemm_leg,
            "makespan {} below compute leg {}",
            r.makespan,
            r.gemm_leg
        );
    }

    #[test]
    fn all_kinds_execute() {
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        for kind in Kind::ALL {
            let r = evaluate(&m, &sc, kind);
            assert!(r.makespan > 0.0, "{kind:?}");
            assert!(r.gemm_cil >= 0.999, "{kind:?} gemm cil {}", r.gemm_cil);
        }
    }

    #[test]
    fn ideal_is_finite_for_filtered_kinds() {
        // Regression: `ideal` stayed NaN (and `baseline` panicked)
        // when the kinds filter dropped the baseline that used to
        // carry the closed-form legs.
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let ev = ScenarioEval::run(&m, &sc, &[Kind::UniformFused1D]);
        assert!(ev.ideal.is_finite() && ev.ideal > 0.0, "ideal {}", ev.ideal);
        assert!(ev.baseline.is_finite() && ev.baseline > 0.0);
        assert!(ev.ideal_speedup().is_finite());
        assert!(ev.speedup(Kind::UniformFused1D) > 0.0);
        // And the filtered evaluation agrees with the full one.
        let full = ScenarioEval::run(&m, &sc, &Kind::ALL);
        assert_eq!(ev.ideal, full.ideal, "ideal independent of the filter");
        assert_eq!(ev.baseline, full.baseline);
    }

    #[test]
    fn best_ficco_is_none_for_ficco_free_kinds() {
        // Regression: used to `.expect` ("no FiCCO kinds evaluated").
        let m = machine();
        let sc = Scenario::new("small", 4096, 512, 1024);
        let ev = ScenarioEval::run(&m, &sc, &[Kind::Baseline, Kind::ShardOverlap]);
        assert!(ev.best_ficco().is_none());
        let full = ScenarioEval::run(&m, &sc, &Kind::ALL);
        let (kind, speedup) = full.best_ficco().expect("FiCCO kinds evaluated");
        assert!(kind.is_ficco());
        assert!(speedup > 0.0);
    }

    #[test]
    fn skewed_scenario_executes_and_costs_more_comm() {
        // A hot expert inflates the comm leg and the hot GPU's load;
        // at skew 0 the scenario is exactly the uniform one.
        let m = machine();
        let sc = sc_comm_heavy();
        let skewed = sc.clone().with_skew(1.0, 7);
        let base = evaluate(&m, &sc, Kind::UniformFused1D);
        let hot = evaluate(&m, &skewed, Kind::UniformFused1D);
        assert!(hot.makespan.is_finite() && hot.makespan > 0.0);
        assert!(
            hot.comm_leg > base.comm_leg,
            "skewed comm leg {} <= uniform {}",
            hot.comm_leg,
            base.comm_leg
        );
        let zero = evaluate(&m, &sc.clone().with_skew(0.0, 99), Kind::UniformFused1D);
        assert_eq!(zero.makespan, base.makespan, "skew 0 is bit-compatible");
        assert_eq!(zero.comm_leg, base.comm_leg);
    }
}
