//! The FiCCO schedule design space (§V) as an explicit IR.
//!
//! A [`Schedule`] is a DAG of per-GPU operations (GEMM pieces,
//! point-to-point transfers, gather/scatter copies) annotated with the
//! *region of the global computation* each op covers. Generators
//! ([`generate`]) produce the serial baseline, shard-based overlap
//! (PyTorch-AsyncTP-style, §II-B), and the four FiCCO schedules of
//! Fig 11b. The executor ([`exec`]) lowers a schedule onto the fluid
//! cluster simulator; the validator ([`validate`]) proves coverage
//! invariants (every output element computed exactly once, every
//! remote byte delivered exactly once) for *any* generated schedule —
//! the property tests fuzz scenario shapes through it.
//!
//! Semantics of a scenario (Fig 3a): the global activation matrix `I`
//! (`M×K`) is row-sharded over `n` GPUs (shard `r` = rows
//! `[r·M/n, (r+1)·M/n)`); each GPU holds a private weight block `W_r`
//! (`K×N`) and must compute `C_r = I · W_r` (`M×N`). The collective
//! (all-gather, or the volume-equivalent expert all-to-all) moves every
//! remote shard to every GPU; the schedules differ in decomposition
//! granularity and overlap structure.

pub mod exec;
pub mod generate;
pub mod validate;

use crate::cost::gemm::GemmShape;
use crate::hw::DType;
use crate::sim::CommMech;

/// Which collective feeds the GEMM (volume-equivalent structures;
/// kept distinct for reporting and for the MoE asymmetry knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Collective {
    /// Tensor-sequence parallel all-gather of activations (SP+TP).
    AllGather,
    /// Expert-parallel all-to-all token dispersal (EP/MoE).
    AllToAll,
}

impl Collective {
    pub fn name(self) -> &'static str {
        match self {
            Collective::AllGather => "all-gather",
            Collective::AllToAll => "all-to-all",
        }
    }
}

/// A data-dependent compute/communication scenario (one Table I row).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    /// The full per-GPU GEMM executed after the collective (Table I's
    /// (M, N, K)).
    pub gemm: GemmShape,
    pub collective: Collective,
    /// Communication mechanism (DMA offload is the paper's default).
    pub mech: CommMech,
    pub ngpus: usize,
    /// Expert-imbalance routing skew (Zipf-style hot-expert exponent;
    /// 0 = balanced routing, the uniform-shard legacy behaviour). See
    /// [`crate::plan::Partition`] and `DESIGN.md` §5.
    pub skew: f64,
    /// Seed for the deterministic hotness order of a skewed partition
    /// (unused at `skew == 0`).
    pub skew_seed: u64,
}

impl Scenario {
    pub fn new(name: impl Into<String>, m: u64, n: u64, k: u64) -> Scenario {
        Scenario {
            name: name.into(),
            gemm: GemmShape::new(m, n, k),
            collective: Collective::AllGather,
            mech: CommMech::Dma,
            ngpus: 8,
            skew: 0.0,
            skew_seed: 0,
        }
    }

    pub fn with_collective(mut self, c: Collective) -> Self {
        self.collective = c;
        self
    }

    pub fn with_mech(mut self, m: CommMech) -> Self {
        self.mech = m;
        self
    }

    pub fn with_ngpus(mut self, n: usize) -> Self {
        self.ngpus = n;
        self
    }

    /// Expert-imbalance routing skew (0 = balanced). The seed fixes
    /// the hotness order so the traffic pattern is reproducible.
    pub fn with_skew(mut self, skew: f64, seed: u64) -> Self {
        self.skew = skew;
        self.skew_seed = seed;
        self
    }

    pub fn dtype(&self) -> DType {
        self.gemm.dtype
    }

    /// The row partition this scenario's routing induces, at
    /// decomposition degree `pieces` (pure function of the scenario,
    /// see [`crate::plan::Partition`]).
    pub fn partition(&self, pieces: usize) -> crate::plan::Partition {
        crate::plan::Partition::skewed(self.gemm.m, self.ngpus, pieces, self.skew, self.skew_seed)
    }

    /// Row range of GPU `q`'s input shard under this scenario's
    /// partition.
    pub fn shard_rows(&self, q: usize) -> (u64, u64) {
        self.partition(1).shard_rows(q)
    }

    /// Mean bytes of one GPU's input shard (`M/n × K` activations) —
    /// the uniform per-shard value at `skew == 0`; under skew, the
    /// per-GPU sizes come from [`Scenario::shard_bytes_per_gpu`].
    pub fn shard_bytes(&self) -> f64 {
        self.partition(1)
            .mean_shard_bytes(self.gemm.k as f64, self.gemm.dtype.bytes() as f64)
    }

    /// Per-GPU input-shard bytes under this scenario's partition (all
    /// equal to [`Scenario::shard_bytes`] up to floor rounding when
    /// `skew == 0`).
    pub fn shard_bytes_per_gpu(&self) -> Vec<f64> {
        self.partition(1)
            .shard_bytes_per_gpu(self.gemm.k as f64 * self.gemm.dtype.bytes() as f64)
    }

    /// Mean total bytes each GPU must receive.
    pub fn rx_bytes_per_gpu(&self) -> f64 {
        (self.ngpus - 1) as f64 * self.shard_bytes()
    }

    /// Bytes GPU `q` must receive under this scenario's partition
    /// (everything outside its own shard).
    pub fn rx_bytes_of(&self, q: usize) -> f64 {
        self.partition(1).rx_rows(q) as f64
            * self.gemm.k as f64
            * self.gemm.dtype.bytes() as f64
    }
}

/// The execution schedules studied (Fig 11b plus baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kind {
    /// Serial: full collective, then the full GEMM (Fig 3b).
    Baseline,
    /// Shard-granular P2P overlap (PyTorch AsyncTP-like, Fig 3c).
    ShardOverlap,
    /// FiCCO: uniform steps, fused GEMM, row-sharded (1D) comm.
    UniformFused1D,
    /// FiCCO: local-shard head start, fused per-step GEMM, 1D comm.
    HeteroFused1D,
    /// FiCCO: head start, one GEMM per piece (no gather/scatter).
    HeteroUnfused1D,
    /// FiCCO: uniform steps, fused accumulating GEMM, column (2D) comm.
    UniformFused2D,
}

impl Kind {
    pub const FICCO: [Kind; 4] = [
        Kind::UniformFused1D,
        Kind::HeteroFused1D,
        Kind::HeteroUnfused1D,
        Kind::UniformFused2D,
    ];

    pub const ALL: [Kind; 6] = [
        Kind::Baseline,
        Kind::ShardOverlap,
        Kind::UniformFused1D,
        Kind::HeteroFused1D,
        Kind::HeteroUnfused1D,
        Kind::UniformFused2D,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Kind::Baseline => "baseline",
            Kind::ShardOverlap => "shard-overlap",
            Kind::UniformFused1D => "uniform-fused-1D",
            Kind::HeteroFused1D => "hetero-fused-1D",
            Kind::HeteroUnfused1D => "hetero-unfused-1D",
            Kind::UniformFused2D => "uniform-fused-2D",
        }
    }

    pub fn parse(s: &str) -> Option<Kind> {
        Kind::ALL.iter().copied().find(|k| k.name() == s)
    }

    pub fn is_ficco(self) -> bool {
        matches!(
            self,
            Kind::UniformFused1D
                | Kind::HeteroFused1D
                | Kind::HeteroUnfused1D
                | Kind::UniformFused2D
        )
    }
}

/// A rectangular region of the global input `I` (`M×K`): rows
/// `[row_lo, row_hi)` × reduction columns `[k_lo, k_hi)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub row_lo: u64,
    pub row_hi: u64,
    pub k_lo: u64,
    pub k_hi: u64,
}

impl Region {
    pub fn rows(row_lo: u64, row_hi: u64, k: u64) -> Region {
        Region {
            row_lo,
            row_hi,
            k_lo: 0,
            k_hi: k,
        }
    }

    pub fn area(&self) -> u64 {
        (self.row_hi - self.row_lo) * (self.k_hi - self.k_lo)
    }

    pub fn bytes(&self, dtype: DType) -> f64 {
        self.area() as f64 * dtype.bytes() as f64
    }

    pub fn intersects(&self, o: &Region) -> bool {
        self.row_lo < o.row_hi && o.row_lo < self.row_hi && self.k_lo < o.k_hi && o.k_lo < self.k_hi
    }
}

/// One operation in a schedule.
#[derive(Debug, Clone)]
pub enum OpKind {
    /// A GEMM piece on this GPU consuming `covers` of the global input
    /// against the local weight block. Fused FiCCO GEMMs consume
    /// pieces from several source shards at once, so coverage is a
    /// set of regions.
    Gemm {
        shape: GemmShape,
        covers: Vec<Region>,
    },
    /// Transfer of `region` of the global input from `src` (its owner)
    /// into this node's GPU.
    Xfer { src: usize, region: Region },
    /// Local assembly of received pieces into a contiguous GEMM input.
    Gather { bytes: f64 },
    /// Local placement of a GEMM output into the final output layout.
    Scatter { bytes: f64 },
}

/// A schedule node: an op on a GPU, with DAG dependencies (indices
/// into [`Schedule::nodes`]) and a step tag for reporting.
#[derive(Debug, Clone)]
pub struct Node {
    pub gpu: usize,
    pub kind: OpKind,
    pub deps: Vec<usize>,
    pub step: usize,
    /// Comm slot (peer lane) for Xfer ops — transfers on different
    /// slots of one GPU proceed in parallel.
    pub slot: usize,
    pub label: String,
}

/// A complete schedule for a scenario.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Legacy kind classification (exact for preset plans, nearest
    /// point for the rest of the plan space) — used for reporting and
    /// the isolated comm-leg closed form.
    pub kind: Kind,
    pub scenario: Scenario,
    /// The plan-space point this schedule was lowered from. All
    /// generator paths now run through [`crate::plan::lower`], so this
    /// is `Some` for generated schedules; `None` only for schedules
    /// built by the frozen legacy reference generators
    /// ([`generate::legacy`]) the parity tests compare against.
    pub plan: Option<crate::plan::Plan>,
    pub nodes: Vec<Node>,
}

impl Schedule {
    pub fn n_gemms(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::Gemm { .. }))
            .count()
    }

    pub fn n_xfers(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::Xfer { .. }))
            .count()
    }

    /// Total bytes moved between GPUs.
    pub fn comm_bytes(&self) -> f64 {
        let d = self.scenario.dtype();
        self.nodes
            .iter()
            .map(|n| match &n.kind {
                OpKind::Xfer { region, .. } => region.bytes(d),
                _ => 0.0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_geometry() {
        let r = Region::rows(0, 10, 4);
        assert_eq!(r.area(), 40);
        let s = Region {
            row_lo: 5,
            row_hi: 15,
            k_lo: 0,
            k_hi: 4,
        };
        assert!(r.intersects(&s));
        let t = Region {
            row_lo: 10,
            row_hi: 15,
            k_lo: 0,
            k_hi: 4,
        };
        assert!(!r.intersects(&t), "touching edges do not intersect");
    }

    #[test]
    fn scenario_bytes() {
        let s = Scenario::new("t", 1024, 512, 256);
        // shard = 128 rows × 256 k × 2B
        assert_eq!(s.shard_bytes(), 128.0 * 256.0 * 2.0);
        assert_eq!(s.rx_bytes_per_gpu(), 7.0 * 128.0 * 256.0 * 2.0);
        // Balanced routing: per-GPU bytes all equal the mean.
        let per = s.shard_bytes_per_gpu();
        assert_eq!(per.len(), 8);
        assert!(per.iter().all(|&b| b == s.shard_bytes()));
        assert_eq!(s.rx_bytes_of(3), s.rx_bytes_per_gpu());
    }

    #[test]
    fn skewed_scenario_bytes_conserve_total() {
        let s = Scenario::new("t", 1024, 512, 256).with_skew(1.0, 11);
        let per = s.shard_bytes_per_gpu();
        let total: f64 = per.iter().sum();
        assert_eq!(total, 1024.0 * 256.0 * 2.0, "all rows accounted for");
        let max = per.iter().cloned().fold(0.0, f64::max);
        assert!(max > s.shard_bytes(), "hot expert owns more than the mean");
        // rx = everything outside the own shard.
        for q in 0..8 {
            assert_eq!(s.rx_bytes_of(q), total - per[q]);
        }
        // The mean-based accessors are skew-independent.
        assert_eq!(s.shard_bytes(), 128.0 * 256.0 * 2.0);
    }

    #[test]
    fn kind_tables() {
        assert_eq!(Kind::ALL.len(), 6);
        assert!(Kind::FICCO.iter().all(|k| k.is_ficco()));
        assert!(!Kind::Baseline.is_ficco());
        assert_eq!(Kind::parse("uniform-fused-2D"), Some(Kind::UniformFused2D));
        assert_eq!(Kind::parse("nope"), None);
    }
}
