//! Schedule generators: the serial baseline, shard-based overlap, and
//! the four FiCCO schedules of Fig 11b.
//!
//! [`generate`] lowers each [`Kind`] through the parameterized plan
//! space ([`crate::plan`]): every legacy kind is a named preset
//! [`crate::plan::Plan`] and one generator ([`crate::plan::lower`])
//! subsumes all six. The original per-kind generators are kept below,
//! frozen, as the reference implementations ([`legacy`]) that the
//! makespan-parity tests (`rust/tests/plan_parity.rs`) compare the
//! plan lowering against.
//!
//! All generators handle non-divisible dimensions via balanced integer
//! splits, so the coverage invariants hold exactly for any (M, N, K,
//! ngpus) — the property tests exploit this.

use super::{Collective, Kind, Node, OpKind, Region, Scenario, Schedule};
use crate::cost::gemm::GemmShape;

/// Balanced split of `[0, total)` into `parts`: piece `i` gets
/// `[i·total/parts, (i+1)·total/parts)` (floor arithmetic — exact
/// partition, sizes differing by at most one).
pub fn split(total: u64, parts: u64, i: u64) -> (u64, u64) {
    assert!(i < parts);
    (i * total / parts, (i + 1) * total / parts)
}

/// Row range of GPU `q`'s input shard.
fn shard_rows(sc: &Scenario, q: usize) -> (u64, u64) {
    split(sc.gemm.m, sc.ngpus as u64, q as u64)
}

/// Row range of piece `p` within GPU `q`'s shard (1D decomposition).
fn piece_rows(sc: &Scenario, q: usize, p: usize) -> (u64, u64) {
    let (lo, hi) = shard_rows(sc, q);
    let (plo, phi) = split(hi - lo, sc.ngpus as u64, p as u64);
    (lo + plo, lo + phi)
}

/// K range of block `b` (2D decomposition).
fn k_block(sc: &Scenario, b: usize) -> (u64, u64) {
    split(sc.gemm.k, sc.ngpus as u64, b as u64)
}

/// Sender-side lane index for a (src → dst) transfer so that one
/// GPU's simultaneous sends to distinct peers ride distinct streams.
pub(crate) fn lane(src: usize, dst: usize, n: usize) -> usize {
    (dst + n - src - 1) % n
}

/// Generate the schedule of `kind` for `scenario` by lowering the
/// kind's preset point of the parameterized plan space.
pub fn generate(kind: Kind, scenario: &Scenario) -> Schedule {
    crate::plan::lower(&crate::plan::Plan::preset(kind, scenario), scenario)
}

/// The frozen legacy generator for `kind` — the original hand-written
/// per-kind implementation, kept verbatim as the reference the
/// plan-lowering parity tests compare against. Production paths use
/// [`generate`].
pub fn legacy(kind: Kind, scenario: &Scenario) -> Schedule {
    match kind {
        Kind::Baseline => baseline(scenario),
        Kind::ShardOverlap => shard_overlap(scenario),
        Kind::UniformFused1D => uniform_fused_1d(scenario),
        Kind::HeteroFused1D => hetero_1d(scenario, true),
        Kind::HeteroUnfused1D => hetero_1d(scenario, false),
        Kind::UniformFused2D => uniform_fused_2d(scenario),
    }
}

pub(crate) struct Builder {
    pub(crate) nodes: Vec<Node>,
    /// Render human-readable node labels. The search hot path lowers
    /// hundreds of candidates per cell and never reads the labels
    /// (the lean simulation names tasks `n<index>`), so cell-scoped
    /// lowering builds label-free (`String::new()` allocates nothing)
    /// — see [`crate::plan::lower_opts`]. Node structure, regions,
    /// deps and stream assignment are identical either way.
    labels: bool,
}

impl Builder {
    pub(crate) fn new() -> Builder {
        Builder {
            nodes: Vec::new(),
            labels: true,
        }
    }

    pub(crate) fn new_with_labels(labels: bool) -> Builder {
        Builder {
            nodes: Vec::new(),
            labels,
        }
    }

    fn push(&mut self, n: Node) -> usize {
        self.nodes.push(n);
        self.nodes.len() - 1
    }

    pub(crate) fn xfer(
        &mut self,
        dst: usize,
        src: usize,
        region: Region,
        step: usize,
        slot: usize,
        deps: Vec<usize>,
    ) -> usize {
        let label = if self.labels {
            format!("xfer[s{step}] g{src}->g{dst}")
        } else {
            String::new()
        };
        self.push(Node {
            gpu: dst,
            kind: OpKind::Xfer { src, region },
            deps,
            step,
            slot,
            label,
        })
    }

    pub(crate) fn gemm(
        &mut self,
        gpu: usize,
        shape: GemmShape,
        covers: Vec<Region>,
        step: usize,
        deps: Vec<usize>,
    ) -> usize {
        let label = if self.labels {
            format!("gemm[s{step}] g{gpu}")
        } else {
            String::new()
        };
        self.push(Node {
            gpu,
            kind: OpKind::Gemm { shape, covers },
            deps,
            step,
            slot: 0,
            label,
        })
    }

    pub(crate) fn gather(&mut self, gpu: usize, bytes: f64, step: usize, deps: Vec<usize>) -> usize {
        let label = if self.labels {
            format!("gather[s{step}] g{gpu}")
        } else {
            String::new()
        };
        self.push(Node {
            gpu,
            kind: OpKind::Gather { bytes },
            deps,
            step,
            slot: 0,
            label,
        })
    }

    pub(crate) fn scatter(&mut self, gpu: usize, bytes: f64, step: usize, deps: Vec<usize>) -> usize {
        let label = if self.labels {
            format!("scatter[s{step}] g{gpu}")
        } else {
            String::new()
        };
        self.push(Node {
            gpu,
            kind: OpKind::Scatter { bytes },
            deps,
            step,
            slot: 0,
            label,
        })
    }
}

pub(crate) fn region(rows: (u64, u64), ks: (u64, u64)) -> Region {
    Region {
        row_lo: rows.0,
        row_hi: rows.1,
        k_lo: ks.0,
        k_hi: ks.1,
    }
}

/// Serial baseline (Fig 3b): one-shot all-gather (every GPU sends its
/// whole shard to every peer on parallel lanes), then the full GEMM.
fn baseline(sc: &Scenario) -> Schedule {
    let n = sc.ngpus;
    let g = &sc.gemm;
    let mut b = Builder::new();
    for dst in 0..n {
        let mut xfers = Vec::new();
        for src in 0..n {
            if src == dst {
                continue;
            }
            let r = region(shard_rows(sc, src), (0, g.k));
            xfers.push(b.xfer(dst, src, r, 0, lane(src, dst, n), vec![]));
        }
        b.gemm(
            dst,
            *g,
            vec![Region::rows(0, g.m, g.k)],
            0,
            xfers,
        );
    }
    Schedule {
        kind: Kind::Baseline,
        scenario: sc.clone(),
        plan: None,
        nodes: b.nodes,
    }
}

/// Shard-based overlap (Fig 3c, PyTorch-AsyncTP-like): GEMM on the
/// local shard immediately; at step `s` GPU `r` fetches the shard of
/// peer `(r+s) mod n` over a single P2P lane (one link at a time — the
/// full-mesh under-utilization the paper measures) and GEMMs it when
/// it lands.
fn shard_overlap(sc: &Scenario) -> Schedule {
    let n = sc.ngpus;
    let g = &sc.gemm;
    let mut b = Builder::new();
    // Local shard first (free head start) on every GPU.
    for r in 0..n {
        let (lo, hi) = shard_rows(sc, r);
        b.gemm(
            r,
            GemmShape { m: hi - lo, ..*g },
            vec![region((lo, hi), (0, g.k))],
            0,
            vec![],
        );
    }
    // Steps are emitted step-major so each sender's single P2P lane
    // (slot 0 — "one peer at a time", the technique's defining
    // constraint) is queued in step order: at step s, GPU q sends its
    // shard to receiver (q-s) mod n — a perfect matching per step.
    let mut prev_xfer: Vec<Option<usize>> = vec![None; n];
    for s in 1..n {
        for r in 0..n {
            let src = (r + s) % n;
            let rows = shard_rows(sc, src);
            let deps = prev_xfer[r].map(|x| vec![x]).unwrap_or_default();
            let x = b.xfer(r, src, region(rows, (0, g.k)), s, 0, deps);
            prev_xfer[r] = Some(x);
            b.gemm(
                r,
                GemmShape {
                    m: rows.1 - rows.0,
                    ..*g
                },
                vec![region(rows, (0, g.k))],
                s,
                vec![x],
            );
        }
    }
    Schedule {
        kind: Kind::ShardOverlap,
        scenario: sc.clone(),
        plan: None,
        nodes: b.nodes,
    }
}

/// FiCCO uniform-fused-1D: shards split into `n` row pieces; at step
/// `s` every GPU broadcasts its piece `s` to all peers (steady-state
/// all-to-all, Fig 4c), gathers the `n` same-index pieces into a
/// contiguous buffer, runs ONE shard-sized GEMM, and scatters the
/// output rows. Low DIL (shard-sized GEMM), high CIL (comm + gather +
/// GEMM + scatter concurrent).
fn uniform_fused_1d(sc: &Scenario) -> Schedule {
    let n = sc.ngpus;
    let g = &sc.gemm;
    let e = g.dtype.bytes() as f64;
    let mut b = Builder::new();
    for r in 0..n {
        for s in 0..n {
            let mut xfers = Vec::new();
            let mut covers = Vec::new();
            let mut rows_total = 0u64;
            for q in 0..n {
                let rows = piece_rows(sc, q, s);
                rows_total += rows.1 - rows.0;
                covers.push(region(rows, (0, g.k)));
                if q != r {
                    xfers.push(b.xfer(r, q, region(rows, (0, g.k)), s, lane(q, r, n), vec![]));
                }
            }
            let gather_bytes = rows_total as f64 * g.k as f64 * e;
            let gather = b.gather(r, gather_bytes, s, xfers);
            let gemm = b.gemm(
                r,
                GemmShape { m: rows_total, ..*g },
                covers,
                s,
                vec![gather],
            );
            let scatter_bytes = rows_total as f64 * g.n as f64 * e;
            b.scatter(r, scatter_bytes, s, vec![gemm]);
        }
    }
    Schedule {
        kind: Kind::UniformFused1D,
        scenario: sc.clone(),
        plan: None,
        nodes: b.nodes,
    }
}

/// FiCCO hetero-{fused,unfused}-1D: GEMM on the local shard starts
/// immediately (heterogeneous first step) while pieces are exchanged
/// all-to-all; step `s ≥ 1` processes the `n-1` remote pieces of
/// comm-step `s-1` — fused as one gathered GEMM (+scatter), or
/// unfused as `n-1` piece-sized GEMMs writing their contiguous output
/// rows directly (no gather/scatter, at the cost of small GEMMs).
fn hetero_1d(sc: &Scenario, fused: bool) -> Schedule {
    let n = sc.ngpus;
    let g = &sc.gemm;
    let e = g.dtype.bytes() as f64;
    let mut b = Builder::new();
    for r in 0..n {
        // Step 0: local shard, contiguous rows — single fused GEMM,
        // no gather/scatter in either variant.
        let (lo, hi) = shard_rows(sc, r);
        b.gemm(
            r,
            GemmShape { m: hi - lo, ..*g },
            vec![region((lo, hi), (0, g.k))],
            0,
            vec![],
        );
        for s in 0..n {
            // Comm step s: receive piece s of every remote shard.
            let mut xfers = Vec::new();
            let mut pieces = Vec::new();
            for q in 0..n {
                if q == r {
                    continue;
                }
                let rows = piece_rows(sc, q, s);
                let reg = region(rows, (0, g.k));
                let x = b.xfer(r, q, reg, s, lane(q, r, n), vec![]);
                xfers.push(x);
                pieces.push((x, reg));
            }
            let step = s + 1; // consumed by compute step s+1
            if fused {
                let rows_total: u64 = pieces.iter().map(|(_, p)| p.row_hi - p.row_lo).sum();
                let covers = pieces.iter().map(|&(_, p)| p).collect();
                let gather_bytes = rows_total as f64 * g.k as f64 * e;
                let gather = b.gather(r, gather_bytes, step, xfers);
                let gemm = b.gemm(
                    r,
                    GemmShape { m: rows_total, ..*g },
                    covers,
                    step,
                    vec![gather],
                );
                let scatter_bytes = rows_total as f64 * g.n as f64 * e;
                b.scatter(r, scatter_bytes, step, vec![gemm]);
            } else {
                for (x, reg) in pieces {
                    b.gemm(
                        r,
                        GemmShape {
                            m: reg.row_hi - reg.row_lo,
                            ..*g
                        },
                        vec![reg],
                        step,
                        vec![x],
                    );
                }
            }
        }
    }
    Schedule {
        kind: if fused {
            Kind::HeteroFused1D
        } else {
            Kind::HeteroUnfused1D
        },
        scenario: sc.clone(),
        plan: None,
        nodes: b.nodes,
    }
}

/// FiCCO uniform-fused-2D: shards split along K; at step `s` every GPU
/// broadcasts its K-block `s`, gathers the full-M K-block, and runs an
/// accumulating GEMM `C += I[:, ks]·W[ks, :]`. Keeps M whole (the
/// right choice when M ≤ K, per the heuristic), no scatter, but pays
/// accumulator read-modify-write traffic.
///
/// 2D DMA copies are emulated with equal-sized 1D copies as in §VI-C.
fn uniform_fused_2d(sc: &Scenario) -> Schedule {
    let n = sc.ngpus;
    let g = &sc.gemm;
    let e = g.dtype.bytes() as f64;
    let mut b = Builder::new();
    for r in 0..n {
        for s in 0..n {
            let ks = k_block(sc, s);
            let mut xfers = Vec::new();
            let mut covers = Vec::new();
            for q in 0..n {
                let rows = shard_rows(sc, q);
                let reg = region(rows, ks);
                covers.push(reg);
                if q != r {
                    xfers.push(b.xfer(r, q, reg, s, lane(q, r, n), vec![]));
                }
            }
            let gather_bytes = g.m as f64 * (ks.1 - ks.0) as f64 * e;
            let gather = b.gather(r, gather_bytes, s, xfers);
            b.gemm(
                r,
                GemmShape {
                    m: g.m,
                    k: ks.1 - ks.0,
                    accumulate: s > 0,
                    ..*g
                },
                covers,
                s,
                vec![gather],
            );
        }
    }
    Schedule {
        kind: Kind::UniformFused2D,
        scenario: sc.clone(),
        plan: None,
        nodes: b.nodes,
    }
}

/// The paper's decomposition degree for a schedule (communication
/// pieces per shard): shard-level techniques = 1, FiCCO = ngpus.
pub fn comm_decomposition(kind: Kind, ngpus: usize) -> usize {
    match kind {
        Kind::Baseline | Kind::ShardOverlap => 1,
        _ => ngpus,
    }
}

/// EP/MoE scenarios are volume-equivalent to the AG structure (each
/// GPU keeps ~1/n of its tokens and receives (n-1)/n); this helper
/// tags the scenario but reuses the same generators. The structural
/// AG ↔ A2A equivalence is documented in `DESIGN.md` §1 (repository
/// root).
pub fn for_scenario(kind: Kind, sc: &Scenario) -> Schedule {
    let _ = Collective::AllToAll;
    generate(kind, sc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sc() -> Scenario {
        Scenario::new("t", 4096, 1024, 2048)
    }

    #[test]
    fn split_is_exact_partition() {
        for total in [1u64, 7, 100, 4097] {
            for parts in [1u64, 3, 8] {
                let mut covered = 0;
                let mut prev_hi = 0;
                for i in 0..parts {
                    let (lo, hi) = split(total, parts, i);
                    assert_eq!(lo, prev_hi);
                    covered += hi - lo;
                    prev_hi = hi;
                }
                assert_eq!(covered, total);
                assert_eq!(prev_hi, total);
            }
        }
    }

    #[test]
    fn baseline_counts() {
        let s = baseline(&sc());
        assert_eq!(s.n_xfers(), 8 * 7);
        assert_eq!(s.n_gemms(), 8);
    }

    #[test]
    fn shard_overlap_counts() {
        let s = shard_overlap(&sc());
        assert_eq!(s.n_xfers(), 8 * 7);
        assert_eq!(s.n_gemms(), 8 * 8);
    }

    #[test]
    fn ficco_comm_is_finer() {
        let base = baseline(&sc());
        let uf = uniform_fused_1d(&sc());
        // Same total bytes, 8x the transfer count.
        assert!((uf.comm_bytes() - base.comm_bytes()).abs() < 1.0);
        assert_eq!(uf.n_xfers(), 8 * base.n_xfers());
    }

    #[test]
    fn hetero_unfused_has_no_copies() {
        let s = hetero_1d(&sc(), false);
        assert!(!s
            .nodes
            .iter()
            .any(|n| matches!(n.kind, OpKind::Gather { .. } | OpKind::Scatter { .. })));
        // 1 local + 8 steps × 7 pieces per GPU
        assert_eq!(s.n_gemms(), 8 * (1 + 8 * 7));
    }

    #[test]
    fn uniform_2d_accumulates() {
        let s = uniform_fused_2d(&sc());
        let mut accums = 0;
        for n in &s.nodes {
            if let OpKind::Gemm { shape, .. } = &n.kind {
                assert_eq!(shape.m, 4096, "2D keeps M whole");
                if shape.accumulate {
                    accums += 1;
                }
            }
        }
        assert_eq!(accums, 8 * 7, "all but the first step accumulate");
    }

    #[test]
    fn deps_are_topologically_ordered() {
        for kind in Kind::ALL {
            let s = generate(kind, &sc());
            for (i, node) in s.nodes.iter().enumerate() {
                for &d in &node.deps {
                    assert!(d < i, "{:?}: node {i} deps on later node {d}", kind);
                }
            }
        }
    }

    #[test]
    fn works_with_non_divisible_dims() {
        let s = Scenario::new("odd", 1000, 300, 777).with_ngpus(3);
        for kind in Kind::ALL {
            let sched = generate(kind, &s);
            assert!(sched.nodes.len() > 3, "{kind:?}");
        }
    }
}
