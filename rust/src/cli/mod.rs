//! Command-line argument parsing.
//!
//! `clap` is unavailable offline, so this is a purpose-built parser
//! covering what the `ficco` binary needs: a subcommand, `--flag value`
//! and `--flag=value` options, boolean switches, and positional args,
//! with typed accessors and helpful errors.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, switches, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    ///
    /// `known_switches` lists boolean flags that never take a value;
    /// every other `--name` consumes the following token as its value
    /// unless written as `--name=value`.
    pub fn parse<I, S>(argv: I, known_switches: &[&str]) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let tokens: Vec<String> = argv.into_iter().map(Into::into).collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&name) {
                    args.switches.push(name.to_string());
                } else {
                    i += 1;
                    let v = tokens
                        .get(i)
                        .ok_or_else(|| CliError(format!("--{name} expects a value")))?;
                    if v.starts_with("--") {
                        return Err(CliError(format!(
                            "--{name} expects a value, got flag {v}"
                        )));
                    }
                    args.opts.insert(name.to_string(), v.clone());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env(known_switches: &[&str]) -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1), known_switches)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected number, got '{v}'"))),
        }
    }

    /// Reject unknown option names (call after reading all expected ones).
    pub fn expect_known(&self, known: &[&str]) -> Result<(), CliError> {
        for k in self.opts.keys() {
            if !known.contains(&k.as_str()) {
                return Err(CliError(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_opts_switches() {
        let a = Args::parse(
            vec!["simulate", "--gpus", "8", "--verbose", "--out=res.csv", "extra"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("gpus"), Some("8"));
        assert_eq!(a.get("out"), Some("res.csv"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn missing_value_is_error() {
        let e = Args::parse(vec!["run", "--gpus"], &[]).unwrap_err();
        assert!(e.0.contains("expects a value"));
    }

    #[test]
    fn flag_value_confusion_is_error() {
        let e = Args::parse(vec!["run", "--gpus", "--other"], &[]).unwrap_err();
        assert!(e.0.contains("expects a value"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(vec!["x", "--n", "12", "--f", "1.5"], &[]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 12);
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_usize("absent", 7).unwrap(), 7);
        assert!(a.get_usize("f", 0).is_err());
    }

    #[test]
    fn unknown_option_detected() {
        let a = Args::parse(vec!["x", "--bad", "1"], &[]).unwrap();
        assert!(a.expect_known(&["good"]).is_err());
        assert!(a.expect_known(&["bad"]).is_ok());
    }
}
