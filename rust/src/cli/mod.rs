//! Command-line argument parsing.
//!
//! `clap` is unavailable offline, so this is a purpose-built parser
//! covering what the `ficco` binary needs: a subcommand, `--flag value`
//! and `--flag=value` options, boolean switches, and positional args,
//! with typed accessors and helpful errors.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, switches, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    ///
    /// `known_switches` lists boolean flags that never take a value;
    /// every other `--name` consumes the following token as its value
    /// unless written as `--name=value`.
    pub fn parse<I, S>(argv: I, known_switches: &[&str]) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let tokens: Vec<String> = argv.into_iter().map(Into::into).collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    if known_switches.contains(&k) {
                        // Silently storing `--verbose=1` as an option
                        // would make `has("verbose")` false and strict
                        // subcommands report a misleading "unknown
                        // option" — reject it outright.
                        return Err(CliError(format!("switch --{k} does not take a value")));
                    }
                    args.opts.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&name) {
                    args.switches.push(name.to_string());
                } else {
                    i += 1;
                    let v = tokens
                        .get(i)
                        .ok_or_else(|| CliError(format!("--{name} expects a value")))?;
                    if v.starts_with("--") {
                        return Err(CliError(format!(
                            "--{name} expects a value, got flag {v}"
                        )));
                    }
                    args.opts.insert(name.to_string(), v.clone());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env(known_switches: &[&str]) -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1), known_switches)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected number, got '{v}'"))),
        }
    }

    /// Parse a worker-count option (`--jobs`-style): a positive
    /// integer, with `auto` or absence meaning the host's available
    /// parallelism.
    pub fn get_jobs(&self, name: &str) -> Result<usize, CliError> {
        match self.get(name) {
            None | Some("auto") => Ok(default_jobs()),
            Some(v) => match v.parse::<usize>() {
                Ok(0) => Err(CliError(format!("--{name}: must be >= 1"))),
                Ok(n) => Ok(n),
                Err(_) => Err(CliError(format!(
                    "--{name}: expected a worker count or 'auto', got '{v}'"
                ))),
            },
        }
    }

    /// Reject unknown option names (call after reading all expected ones).
    pub fn expect_known(&self, known: &[&str]) -> Result<(), CliError> {
        for k in self.opts.keys() {
            if !known.contains(&k.as_str()) {
                return Err(CliError(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }

    /// Reject switches a subcommand does not honor. Switch names are
    /// registered globally at parse time, so a strict subcommand must
    /// also reject the ones it would otherwise silently ignore.
    pub fn expect_switches(&self, known: &[&str]) -> Result<(), CliError> {
        for s in &self.switches {
            if !known.contains(&s.as_str()) {
                return Err(CliError(format!("switch --{s} is not valid here")));
            }
        }
        Ok(())
    }
}

/// Boolean switches the `ficco` binary registers at parse time
/// (switch names are global: parsing must know them before the
/// subcommand is dispatched).
pub const KNOWN_SWITCHES: &[&str] =
    &["all", "verbose", "csv", "no-overlap-report", "stats", "quiet", "resume"];

/// Every `ficco` subcommand, in help order.
pub const SUBCOMMANDS: &[&str] = &[
    "workloads",
    "simulate",
    "sweep",
    "tune",
    "trace",
    "heuristic",
    "characterize",
    "figures",
    "synth",
    "validate",
    "train",
    "calibrate",
    "cotenant",
];

/// The strict CLI contract: exactly the options and switches each
/// `ficco` subcommand honors. A typo'd flag (`--treshold 2`,
/// `--scenaro g5`) must fail loudly instead of silently running with
/// defaults, so [`validate_strict`] rejects anything not listed here.
pub fn subcommand_spec(sub: &str) -> Option<(&'static [&'static str], &'static [&'static str])> {
    match sub {
        "workloads" => Some((&[], &[])),
        "simulate" => Some((
            &[
                "config", "gpus", "scenario", "m", "n", "k", "mech", "skew", "skew-seed",
                "trace-out",
            ],
            &["quiet"],
        )),
        "sweep" => Some((
            &[
                "scenarios", "kinds", "machines", "mechs", "gpus", "skew", "skew-seed", "jobs",
                "out-dir", "search", "warm", "model", "robust", "robust-seed", "robust-mag",
            ],
            &["verbose", "csv", "stats", "quiet", "resume"],
        )),
        "tune" => Some((
            &[
                "scenarios", "machines", "mechs", "gpus", "skew", "skew-seed", "jobs", "out-dir",
                "beam", "warm", "pieces", "slots", "model", "trace-out", "robust", "robust-seed",
                "robust-mag",
            ],
            &["verbose", "csv", "stats", "quiet", "resume"],
        )),
        "trace" => Some((
            &[
                "scenario", "machine", "m", "n", "k", "mech", "skew", "skew-seed", "plan", "beam",
                "warm", "pieces", "slots", "jobs", "out-dir",
            ],
            &["stats", "quiet"],
        )),
        "heuristic" => Some((
            &[
                "config", "gpus", "scenario", "m", "n", "k", "mech", "skew", "skew-seed",
                "threshold", "model",
            ],
            &["all"],
        )),
        "characterize" => Some((&["config", "gpus", "what"], &[])),
        "figures" => Some((&["config", "gpus", "out-dir"], &["csv", "quiet"])),
        "synth" => Some((
            &["config", "gpus", "count", "seed", "threshold", "suite", "against", "beam", "model"],
            &[],
        )),
        "validate" => Some((&["artifacts", "m", "n", "k", "gpus"], &[])),
        "train" => Some((
            &["preset", "steps", "seed", "artifacts", "log-every", "loss-csv"],
            &["no-overlap-report"],
        )),
        "calibrate" => Some((
            &[
                "scenarios", "holdout", "machines", "mechs", "gpus", "skew", "skew-seed", "jobs",
                "beam", "pieces", "slots", "out",
            ],
            &["verbose"],
        )),
        "cotenant" => Some((
            &[
                "scenarios", "kinds", "machines", "mechs", "gpus", "skew", "skew-seed", "jobs",
                "out-dir", "tenants", "stagger", "model", "trace-out", "robust", "robust-seed",
                "robust-mag",
            ],
            &["verbose", "csv", "stats", "quiet"],
        )),
        _ => None,
    }
}

/// Enforce the strict CLI contract for the parsed subcommand: unknown
/// options, inapplicable switches, and stray positional arguments are
/// all errors. Unknown subcommands are left for the dispatcher (it
/// has the better error message).
pub fn validate_strict(args: &Args) -> Result<(), CliError> {
    let sub = match args.subcommand.as_deref() {
        Some(s) => s,
        None => {
            // Bare `ficco` prints the help banner, but `ficco --typo 2`
            // must not masquerade as a successful run (exit 0) — with
            // no subcommand, no option or switch is honored.
            args.expect_known(&[])?;
            args.expect_switches(&[])?;
            return Ok(());
        }
    };
    let (opts, switches) = match subcommand_spec(sub) {
        Some(spec) => spec,
        None => return Ok(()),
    };
    args.expect_known(opts)?;
    args.expect_switches(switches)?;
    if let Some(stray) = args.positional.first() {
        return Err(CliError(format!(
            "unexpected argument '{stray}' ({sub} takes only --options)"
        )));
    }
    Ok(())
}

/// The host's available parallelism (fallback 1), the default for
/// `--jobs`-style options.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_opts_switches() {
        let a = Args::parse(
            vec!["simulate", "--gpus", "8", "--verbose", "--out=res.csv", "extra"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("gpus"), Some("8"));
        assert_eq!(a.get("out"), Some("res.csv"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn missing_value_is_error() {
        let e = Args::parse(vec!["run", "--gpus"], &[]).unwrap_err();
        assert!(e.0.contains("expects a value"));
    }

    #[test]
    fn flag_value_confusion_is_error() {
        let e = Args::parse(vec!["run", "--gpus", "--other"], &[]).unwrap_err();
        assert!(e.0.contains("expects a value"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(vec!["x", "--n", "12", "--f", "1.5"], &[]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 12);
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_usize("absent", 7).unwrap(), 7);
        assert!(a.get_usize("f", 0).is_err());
    }

    #[test]
    fn jobs_option() {
        let a = Args::parse(vec!["sweep", "--jobs", "4"], &[]).unwrap();
        assert_eq!(a.get_jobs("jobs").unwrap(), 4);
        let auto = Args::parse(vec!["sweep", "--jobs", "auto"], &[]).unwrap();
        assert_eq!(auto.get_jobs("jobs").unwrap(), default_jobs());
        assert!(default_jobs() >= 1);
        let absent = Args::parse(vec!["sweep"], &[]).unwrap();
        assert_eq!(absent.get_jobs("jobs").unwrap(), default_jobs());
        let zero = Args::parse(vec!["sweep", "--jobs", "0"], &[]).unwrap();
        assert!(zero.get_jobs("jobs").is_err());
        let bad = Args::parse(vec!["sweep", "--jobs", "many"], &[]).unwrap();
        assert!(bad.get_jobs("jobs").is_err());
    }

    #[test]
    fn unknown_option_detected() {
        let a = Args::parse(vec!["x", "--bad", "1"], &[]).unwrap();
        assert!(a.expect_known(&["good"]).is_err());
        assert!(a.expect_known(&["bad"]).is_ok());
    }

    #[test]
    fn switch_with_value_rejected() {
        let e = Args::parse(vec!["x", "--verbose=1"], &["verbose"]).unwrap_err();
        assert!(e.0.contains("does not take a value"), "{}", e.0);
        // Non-switch options still accept the = form.
        let a = Args::parse(vec!["x", "--out=res.csv"], &["verbose"]).unwrap();
        assert_eq!(a.get("out"), Some("res.csv"));
    }

    #[test]
    fn inapplicable_switch_detected() {
        let a = Args::parse(vec!["x", "--all", "--verbose"], &["all", "verbose"]).unwrap();
        assert!(a.expect_switches(&["verbose"]).is_err());
        assert!(a.expect_switches(&["all", "verbose"]).is_ok());
        assert!(a.expect_switches(&[]).is_err());
    }

    fn strict(argv: Vec<&str>) -> Result<(), CliError> {
        validate_strict(&Args::parse(argv, KNOWN_SWITCHES).unwrap())
    }

    #[test]
    fn every_subcommand_has_a_strict_spec() {
        for &sub in SUBCOMMANDS {
            assert!(subcommand_spec(sub).is_some(), "{sub} missing from spec table");
        }
        assert!(subcommand_spec("nonsense").is_none());
        // Bare invocation (help banner) is fine; a flag with no
        // subcommand is not — it would exit 0 looking successful.
        assert!(strict(vec![]).is_ok());
        assert!(strict(vec!["--treshold", "2"]).is_err());
        assert!(strict(vec!["--verbose"]).is_err());
    }

    #[test]
    fn strict_rejects_unknown_options_on_every_subcommand() {
        // Regression: 7 of 10 subcommands used to silently drop
        // typo'd options and run with defaults.
        for &sub in SUBCOMMANDS {
            let e = strict(vec![sub, "--definitely-bogus", "1"]).unwrap_err();
            assert!(e.0.contains("definitely-bogus"), "{sub}: {}", e.0);
        }
    }

    #[test]
    fn strict_rejects_typod_options_per_subcommand() {
        // The exact typos from the bug report, plus one per remaining
        // subcommand.
        assert!(strict(vec!["heuristic", "--treshold", "2"]).is_err());
        assert!(strict(vec!["simulate", "--scenaro", "g5"]).is_err());
        assert!(strict(vec!["trace", "--pln", "row-d8-fused-hs-s7-dma"]).is_err());
        assert!(strict(vec!["characterize", "--waht", "dil"]).is_err());
        assert!(strict(vec!["figures", "--outdir", "r"]).is_err());
        assert!(strict(vec!["synth", "--cout", "4"]).is_err());
        assert!(strict(vec!["validate", "--artifact", "a"]).is_err());
        assert!(strict(vec!["train", "--step", "5"]).is_err());
        assert!(strict(vec!["workloads", "--anything", "x"]).is_err());
        assert!(strict(vec!["sweep", "--scenario", "g5"]).is_err(), "sweep takes --scenarios");
        assert!(strict(vec!["tune", "--kinds", "all"]).is_err(), "tune has no kinds filter");
        assert!(strict(vec!["calibrate", "--houldout", "x"]).is_err());
        assert!(strict(vec!["cotenant", "--tenant", "2"]).is_err(), "it is --tenants");
        assert!(strict(vec!["cotenant", "--stager", "0.5"]).is_err());
    }

    #[test]
    fn strict_accepts_each_subcommands_own_flags() {
        assert!(strict(vec!["workloads"]).is_ok());
        assert!(strict(vec!["simulate", "--scenario", "g5", "--mech", "dma"]).is_ok());
        assert!(strict(vec!["sweep", "--scenarios", "g1", "--jobs", "2", "--csv"]).is_ok());
        assert!(strict(vec!["tune", "--beam", "4", "--pieces", "1,8", "--verbose"]).is_ok());
        assert!(strict(vec!["tune", "--trace-out", "t.json", "--stats", "--quiet"]).is_ok());
        assert!(strict(vec!["tune", "--warm", "off"]).is_ok());
        assert!(strict(vec!["sweep", "--search", "exhaustive", "--warm", "off"]).is_ok());
        assert!(strict(vec!["trace", "--warm", "on", "--scenario", "g6"]).is_ok());
        assert!(strict(vec!["simulate", "--warm", "off"]).is_err(), "simulate has no search");
        assert!(strict(vec!["calibrate", "--warm", "off"]).is_err());
        assert!(strict(vec!["trace", "--scenario", "g6", "--machine", "mi300x-8"]).is_ok());
        assert!(strict(vec!["trace", "--plan", "row-d8-fused-hs-s7-dma", "--stats"]).is_ok());
        assert!(strict(vec!["heuristic", "--all", "--threshold", "2"]).is_ok());
        assert!(strict(vec!["characterize", "--what", "cil"]).is_ok());
        assert!(strict(vec!["figures", "--out-dir", "r", "--csv"]).is_ok());
        assert!(strict(vec!["synth", "--count", "8", "--against", "plans"]).is_ok());
        assert!(strict(vec!["validate", "--artifacts", "a", "--m", "64"]).is_ok());
        assert!(strict(vec!["train", "--preset", "tiny", "--no-overlap-report"]).is_ok());
        assert!(strict(vec!["calibrate", "--holdout", "holdout:4:7", "--out", "m.ficco"]).is_ok());
        assert!(strict(vec!["cotenant", "--tenants", "3", "--stagger", "0.5", "--csv"]).is_ok());
        assert!(strict(vec!["cotenant", "--scenarios", "g5", "--trace-out", "t.json"]).is_ok());
        assert!(strict(vec!["cotenant", "--resume"]).is_err(), "cotenant has no journal");
        assert!(strict(vec!["cotenant", "--search", "beam"]).is_err(), "cotenant has no search");
    }

    #[test]
    fn strict_knows_the_robustness_flags() {
        assert!(strict(vec!["tune", "--robust", "p95:8", "--robust-seed", "7"]).is_ok());
        assert!(strict(vec!["tune", "--robust", "worst:4", "--robust-mag", "0.1,0.2,0.5"]).is_ok());
        assert!(strict(vec!["tune", "--resume", "--out-dir", "r"]).is_ok());
        assert!(strict(vec!["sweep", "--search", "beam", "--robust", "p95:8"]).is_ok());
        assert!(strict(vec!["sweep", "--resume", "--out-dir", "r"]).is_ok());
        assert!(strict(vec!["cotenant", "--robust", "p95:8", "--robust-seed", "7"]).is_ok());
        // Only sweep/tune/cotenant honor them.
        assert!(strict(vec!["simulate", "--robust", "p95:8"]).is_err());
        assert!(strict(vec!["trace", "--robust", "p95:8"]).is_err());
        assert!(strict(vec!["calibrate", "--resume"]).is_err());
        assert!(strict(vec!["simulate", "--resume"]).is_err());
        // --resume is a switch: a value form must be rejected.
        assert!(Args::parse(vec!["tune", "--resume=1"], KNOWN_SWITCHES).is_err());
    }

    #[test]
    fn strict_rejects_inapplicable_switches_and_positionals() {
        // `--all` is a real switch, but only `heuristic` honors it.
        assert!(strict(vec!["simulate", "--all"]).is_err());
        assert!(strict(vec!["figures", "--verbose"]).is_err());
        assert!(strict(vec!["heuristic", "--csv"]).is_err());
        assert!(strict(vec!["workloads", "--quiet"]).is_err());
        assert!(strict(vec!["trace", "--verbose"]).is_err());
        // Stray positionals (e.g. a value after a switch) are errors.
        let e = strict(vec!["sweep", "stray"]).unwrap_err();
        assert!(e.0.contains("stray"), "{}", e.0);
        assert!(strict(vec!["simulate", "g5"]).is_err());
    }
}
