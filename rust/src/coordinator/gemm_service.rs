//! Compute service: a dedicated thread owning the PJRT client.
//!
//! `xla` handles wrap raw C++ pointers and are not `Send`, so rank
//! threads cannot call PJRT directly. Instead they submit plain-`f32`
//! GEMM requests over a channel; the service thread executes them —
//! through the Pallas artifact when one matches the shape (the L1
//! kernel on the L3 request path), otherwise through an
//! XlaBuilder-built executable — and replies on a per-request channel.

use crate::runtime::{gemm::GemmExecutor, literal_f32, to_f32, Runtime};
use anyhow::{anyhow, Result};
use std::sync::mpsc;

/// One GEMM request: `C (+)= A·B`.
pub struct GemmRequest {
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    /// Accumulator input for `C += A·B`; `None` for plain GEMM.
    pub c: Option<Vec<f32>>,
    pub reply: mpsc::Sender<Result<Vec<f32>>>,
}

enum Msg {
    Req(GemmRequest),
    /// Explicit stop: outstanding `GemmHandle` clones may outlive
    /// [`GemmService::shutdown`], so channel closure alone cannot end
    /// the loop.
    Stop,
}

/// Cloneable submitter handed to rank threads.
#[derive(Clone)]
pub struct GemmHandle {
    tx: mpsc::Sender<Msg>,
}

impl GemmHandle {
    pub fn matmul(&self, a: Vec<f32>, b: Vec<f32>, m: u64, n: u64, k: u64) -> Result<Vec<f32>> {
        self.submit(a, b, None, m, n, k)
    }

    pub fn matmul_acc(
        &self,
        c: Vec<f32>,
        a: Vec<f32>,
        b: Vec<f32>,
        m: u64,
        n: u64,
        k: u64,
    ) -> Result<Vec<f32>> {
        self.submit(a, b, Some(c), m, n, k)
    }

    fn submit(
        &self,
        a: Vec<f32>,
        b: Vec<f32>,
        c: Option<Vec<f32>>,
        m: u64,
        n: u64,
        k: u64,
    ) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Req(GemmRequest {
                m,
                n,
                k,
                a,
                b,
                c,
                reply,
            }))
            .map_err(|_| anyhow!("gemm service stopped"))?;
        rx.recv().map_err(|_| anyhow!("gemm service dropped reply"))?
    }
}

/// The service thread.
pub struct GemmService {
    tx: mpsc::Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl GemmService {
    /// Spawn the service. `artifacts` points at the AOT directory; if
    /// its manifest is missing, all requests use the builder fallback.
    pub fn spawn(artifacts: String) -> GemmService {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("gemm-service".into())
            .spawn(move || service_loop(rx, &artifacts))
            .expect("spawn gemm service");
        GemmService {
            tx,
            join: Some(join),
        }
    }

    pub fn handle(&self) -> GemmHandle {
        GemmHandle {
            tx: self.tx.clone(),
        }
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn service_loop(rx: mpsc::Receiver<Msg>, artifacts: &str) {
    // Artifact runtime when available (runs the L1 Pallas kernels at
    // their lowered shapes) + builder fallback for arbitrary shapes.
    let runtime = Runtime::load(artifacts).ok();
    let exec = GemmExecutor::with_cpu_client().expect("PJRT cpu client");

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Stop => break,
            Msg::Req(req) => {
                let result = run_one(&req, runtime.as_ref(), &exec);
                let _ = req.reply.send(result);
            }
        }
    }
}

fn run_one(req: &GemmRequest, runtime: Option<&Runtime>, exec: &GemmExecutor) -> Result<Vec<f32>> {
    let (m, n, k) = (req.m, req.n, req.k);
    // Prefer the Pallas artifact at this exact shape.
    if let Some(rt) = runtime {
        match &req.c {
            None => {
                let name = format!("pallas_gemm_{m}x{n}x{k}");
                if rt.manifest.get(&name).is_some() {
                    let la = literal_f32(&req.a, &[m as i64, k as i64])?;
                    let lb = literal_f32(&req.b, &[k as i64, n as i64])?;
                    let out = rt.execute(&name, &[la, lb])?;
                    return to_f32(&out[0]);
                }
            }
            Some(c) => {
                let name = format!("pallas_gemm_acc_{m}x{n}x{k}");
                if rt.manifest.get(&name).is_some() {
                    let lc = literal_f32(c, &[m as i64, n as i64])?;
                    let la = literal_f32(&req.a, &[m as i64, k as i64])?;
                    let lb = literal_f32(&req.b, &[k as i64, n as i64])?;
                    let out = rt.execute(&name, &[lc, la, lb])?;
                    return to_f32(&out[0]);
                }
            }
        }
    }
    match &req.c {
        None => exec.matmul(&req.a, &req.b, m, n, k),
        Some(c) => exec.matmul_acc(c, &req.a, &req.b, m, n, k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_round_trip() {
        let svc = GemmService::spawn("artifacts".into());
        let h = svc.handle();
        let out = h
            .matmul(vec![1.0, 2.0, 3.0, 4.0], vec![1.0, 0.0, 0.0, 1.0], 2, 2, 2)
            .unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
        svc.shutdown();
    }

    #[test]
    fn service_usable_from_many_threads() {
        let svc = GemmService::spawn("artifacts".into());
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let h = svc.handle();
                std::thread::spawn(move || {
                    let a = vec![i as f32 + 1.0; 6];
                    let b = vec![2.0f32; 6];
                    h.matmul(a, b, 2, 2, 3).unwrap()
                })
            })
            .collect();
        for (i, t) in handles.into_iter().enumerate() {
            let out = t.join().unwrap();
            let want = (i as f32 + 1.0) * 2.0 * 3.0;
            assert!(out.iter().all(|&x| (x - want).abs() < 1e-5));
        }
        svc.shutdown();
    }
}
