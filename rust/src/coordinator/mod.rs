//! Distributed coordinator: executes FiCCO schedules **numerically**
//! with real data, proving the decomposition/overlap logic (piece
//! routing, gather/scatter layout, 2D accumulation) is semantically
//! correct — every schedule must produce bit-comparable output to the
//! serial baseline.
//!
//! Topology of the implementation (mirrors the paper's Fig 3/4 setup):
//!
//! - one **worker thread per GPU rank**, owning its input shard,
//!   weight block and output buffer;
//! - **links** are FIFO channels per directed rank pair (the mesh);
//! - GEMMs execute on the PJRT CPU client via a dedicated **compute
//!   service** thread ([`gemm_service`]) because `xla` handles are not
//!   `Send`; workers exchange plain `f32` buffers with it. Piece
//!   shapes with a matching Pallas artifact (`pallas_gemm_*`) run the
//!   L1 kernel; other shapes use the XlaBuilder fallback
//!   ([`crate::runtime::gemm`]).
//!
//! This is the L3 "request path": after `make artifacts`, no Python.

pub mod gemm_service;
pub mod numeric;

pub use gemm_service::{GemmHandle, GemmRequest, GemmService};
pub use numeric::{execute_numeric, NumericResult};

use crate::schedule::{generate::generate, validate::validate, Kind, Scenario};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

/// Reference output: every GPU computes `C_r = I · W_r` serially.
fn reference_outputs(
    svc: &GemmHandle,
    input: &[f32],
    weights: &[Vec<f32>],
    m: u64,
    n: u64,
    k: u64,
) -> Result<Vec<Vec<f32>>> {
    weights
        .iter()
        .map(|w| svc.matmul(input.to_vec(), w.clone(), m, n, k))
        .collect()
}

/// Generate deterministic test data for a scenario.
pub fn test_data(m: u64, n: u64, k: u64, ngpus: usize, seed: u64) -> (Vec<f32>, Vec<Vec<f32>>) {
    let mut rng = Rng::new(seed);
    let input: Vec<f32> = (0..m * k).map(|_| rng.f32() - 0.5).collect();
    let weights: Vec<Vec<f32>> = (0..ngpus)
        .map(|_| (0..k * n).map(|_| rng.f32() - 0.5).collect())
        .collect();
    (input, weights)
}

/// Max |a-b| over two equal-length slices.
fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Execute every schedule kind numerically for a (m, n, k, ngpus)
/// scenario and check the outputs against the serial reference.
/// Prints a per-schedule report; errors if any mismatch exceeds tol.
pub fn validate_all_schedules(
    artifacts: &str,
    m: u64,
    n: u64,
    k: u64,
    ngpus: usize,
) -> Result<(), Box<dyn std::error::Error>> {
    let svc = GemmService::spawn(artifacts.to_string());
    let handle = svc.handle();

    let sc = Scenario::new(format!("validate-{m}x{n}x{k}"), m, n, k).with_ngpus(ngpus);
    let (input, weights) = test_data(m, n, k, ngpus, 0xF1CC0);
    println!(
        "numeric validation: GEMM ({m}, {n}, {k}) over {ngpus} ranks, \
         artifacts from '{artifacts}'"
    );
    let reference = reference_outputs(&handle, &input, &weights, m, n, k)?;

    // The reduction-splitting schedule (2D) reassociates float adds.
    let tol_of = |kind: Kind| match kind {
        Kind::UniformFused2D => 2e-3f32,
        _ => 1e-3f32,
    };

    let mut failures = Vec::new();
    for kind in Kind::ALL {
        let sched = generate(kind, &sc);
        validate(&sched).map_err(|e| anyhow!("{}: {e}", kind.name()))?;
        let res = execute_numeric(&sched, &input, &weights, &handle)?;
        let mut worst = 0.0f32;
        for (r, out) in res.outputs.iter().enumerate() {
            worst = worst.max(max_abs_diff(out, &reference[r]));
        }
        let ok = worst <= tol_of(kind);
        println!(
            "  {:<18} {} gemms, {} transfers ({} bytes moved), max |Δ| = {:.2e} {}",
            kind.name(),
            res.gemms,
            res.transfers,
            res.bytes_moved,
            worst,
            if ok { "OK" } else { "MISMATCH" }
        );
        if !ok {
            failures.push(format!("{}: max diff {worst}", kind.name()));
        }
    }
    svc.shutdown();
    if failures.is_empty() {
        println!("all schedules numerically equivalent to serial baseline");
        Ok(())
    } else {
        Err(anyhow!("numeric validation failed: {failures:?}").into())
    }
}
