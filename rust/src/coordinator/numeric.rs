//! Numeric execution of a [`Schedule`] across rank threads.
//!
//! Each rank thread owns its shard of the global input `I` (`M×K`,
//! row-major), its private weight block `W_r` (`K×N`), and its output
//! `C_r` (`M×N`). Transfers move real `f32` piece buffers over FIFO
//! channels (one per directed rank pair — the mesh links); GEMMs go to
//! the shared compute service. Every schedule kind — baseline, shard
//! overlap, and all four FiCCO schedules — runs through this one
//! executor, so producing the same `C_r` as the serial baseline proves
//! the decomposition/routing/accumulation logic of each schedule.

use super::gemm_service::GemmHandle;
use crate::schedule::{OpKind, Region, Schedule};
use anyhow::{anyhow, Result};
use std::sync::mpsc;

/// A piece in flight on a link: the region of global `I` it carries
/// and the data (row-major rows × k-slice).
struct Piece {
    region: Region,
    data: Vec<f32>,
}

/// Outcome of numeric execution.
#[derive(Debug)]
pub struct NumericResult {
    /// Per-rank final outputs (`M×N`, row-major).
    pub outputs: Vec<Vec<f32>>,
    pub gemms: usize,
    pub transfers: usize,
    pub bytes_moved: u64,
}

/// Extract `region` of the global input (rows × k-slice) from a rank's
/// view. `view` is the full `M×K` matrix, only partially valid; the
/// caller guarantees validity per the schedule's validated invariants.
fn extract(view: &[f32], k_total: usize, region: &Region) -> Vec<f32> {
    let kw = (region.k_hi - region.k_lo) as usize;
    let mut out = Vec::with_capacity(((region.row_hi - region.row_lo) as usize) * kw);
    for row in region.row_lo..region.row_hi {
        let base = row as usize * k_total + region.k_lo as usize;
        out.extend_from_slice(&view[base..base + kw]);
    }
    out
}

/// Write `data` (shaped as `region`) into a rank's `M×K` view.
fn place(view: &mut [f32], k_total: usize, region: &Region, data: &[f32]) {
    let kw = (region.k_hi - region.k_lo) as usize;
    for (i, row) in (region.row_lo..region.row_hi).enumerate() {
        let base = row as usize * k_total + region.k_lo as usize;
        view[base..base + kw].copy_from_slice(&data[i * kw..(i + 1) * kw]);
    }
}

/// Execute `sched` with real data. `input` is the full `M×K` matrix
/// (rank `r` starts holding only its shard rows); `weights[r]` is each
/// rank's `K×N` block.
pub fn execute_numeric(
    sched: &Schedule,
    input: &[f32],
    weights: &[Vec<f32>],
    gemm: &GemmHandle,
) -> Result<NumericResult> {
    let sc = &sched.scenario;
    let n_ranks = sc.ngpus;
    let (m, k) = (sc.gemm.m as usize, sc.gemm.k as usize);
    assert_eq!(input.len(), m * k);
    assert_eq!(weights.len(), n_ranks);

    // Links: FIFO channel per directed pair.
    let mut senders: Vec<Vec<Option<mpsc::Sender<Piece>>>> =
        (0..n_ranks).map(|_| (0..n_ranks).map(|_| None).collect()).collect();
    let mut receivers: Vec<Vec<Option<mpsc::Receiver<Piece>>>> =
        (0..n_ranks).map(|_| (0..n_ranks).map(|_| None).collect()).collect();
    for src in 0..n_ranks {
        for dst in 0..n_ranks {
            if src == dst {
                continue;
            }
            let (tx, rx) = mpsc::channel();
            senders[src][dst] = Some(tx);
            receivers[dst][src] = Some(rx);
        }
    }

    let sched = std::sync::Arc::new(sched.clone());
    let mut joins = Vec::new();
    for rank in 0..n_ranks {
        let sched = sched.clone();
        let gemm = gemm.clone();
        let my_senders: Vec<Option<mpsc::Sender<Piece>>> = senders[rank]
            .iter_mut()
            .map(|s| s.take())
            .collect();
        let my_receivers: Vec<Option<mpsc::Receiver<Piece>>> = receivers[rank]
            .iter_mut()
            .map(|r| r.take())
            .collect();
        // Rank r's initial view: only its shard rows are valid.
        let shard = shard_region(&sched, rank);
        let mut view = vec![0.0f32; m * k];
        place(
            &mut view,
            k,
            &shard,
            &extract(input, k, &shard),
        );
        let w = weights[rank].clone();
        joins.push(std::thread::Builder::new().name(format!("rank{rank}")).spawn(
            move || -> Result<(usize, usize, u64, Vec<f32>)> {
                rank_main(rank, &sched, view, &w, my_senders, my_receivers, &gemm)
            },
        )?);
    }

    let mut outputs = vec![Vec::new(); n_ranks];
    let mut gemms = 0;
    let mut transfers = 0;
    let mut bytes = 0u64;
    for (rank, j) in joins.into_iter().enumerate() {
        let (g, t, by, out) = j
            .join()
            .map_err(|_| anyhow!("rank {rank} panicked"))??;
        gemms += g;
        transfers += t;
        bytes += by;
        outputs[rank] = out;
    }
    Ok(NumericResult {
        outputs,
        gemms,
        transfers,
        bytes_moved: bytes,
    })
}

fn shard_region(sched: &Schedule, rank: usize) -> Region {
    // Partition-aware: skewed scenarios shard rows non-uniformly.
    let (lo, hi) = sched.scenario.shard_rows(rank);
    Region::rows(lo, hi, sched.scenario.gemm.k)
}

/// A rank's program: send every piece it owns (in node order), and
/// process its own nodes in order (receives block on the link FIFO).
#[allow(clippy::too_many_arguments)]
fn rank_main(
    rank: usize,
    sched: &Schedule,
    mut view: Vec<f32>,
    w: &[f32],
    senders: Vec<Option<mpsc::Sender<Piece>>>,
    receivers: Vec<Option<mpsc::Receiver<Piece>>>,
    gemm: &GemmHandle,
) -> Result<(usize, usize, u64, Vec<f32>)> {
    let sc = &sched.scenario;
    let (m, n, k) = (sc.gemm.m as usize, sc.gemm.n as usize, sc.gemm.k as usize);
    let mut c = vec![0.0f32; m * n];
    let mut gemms = 0usize;
    let mut transfers = 0usize;
    let mut bytes = 0u64;

    // Phase 1 is interleaved with phase 2 in node order; sends never
    // block (unbounded FIFO) so there is no deadlock: for every node
    // we either push (we are the source of a transfer targeting a
    // peer) or execute our own op.
    for node in &sched.nodes {
        match &node.kind {
            OpKind::Xfer { src, region } if *src == rank => {
                // We own this data; push it to the destination.
                let data = extract(&view, k, region);
                bytes += data.len() as u64 * 4;
                transfers += 1;
                senders[node.gpu]
                    .as_ref()
                    .ok_or_else(|| anyhow!("no link {rank}->{}", node.gpu))?
                    .send(Piece {
                        region: *region,
                        data,
                    })
                    .map_err(|_| anyhow!("link {rank}->{} closed", node.gpu))?;
            }
            _ if node.gpu != rank => {}
            OpKind::Xfer { src, region } => {
                // Receive into our view. Links are FIFO and the sender
                // emits in schedule order, so regions arrive in order.
                let piece = receivers[*src]
                    .as_ref()
                    .ok_or_else(|| anyhow!("no link {src}->{rank}"))?
                    .recv()
                    .map_err(|_| anyhow!("link {src}->{rank} hung up"))?;
                if piece.region != *region {
                    return Err(anyhow!(
                        "rank {rank}: out-of-order piece from {src}: got {:?} want {:?}",
                        piece.region,
                        region
                    ));
                }
                place(&mut view, k, region, &piece.data);
            }
            OpKind::Gemm { shape, covers } => {
                gemms += 1;
                if shape.k == sc.gemm.k {
                    // 1D piece(s): full-K GEMM over possibly disjoint
                    // row groups; write rows straight into C (the
                    // schedule's Gather/Scatter are layout copies the
                    // simulator costs; numerically the row mapping is
                    // what matters).
                    let rows: usize = covers.iter().map(|r| (r.row_hi - r.row_lo) as usize).sum();
                    let mut a = Vec::with_capacity(rows * k);
                    for r in covers {
                        a.extend_from_slice(&extract(&view, k, r));
                    }
                    let out = gemm.matmul(a, w.to_vec(), rows as u64, n as u64, k as u64)?;
                    let mut off = 0usize;
                    for r in covers {
                        for row in r.row_lo..r.row_hi {
                            c[row as usize * n..(row as usize + 1) * n]
                                .copy_from_slice(&out[off * n..(off + 1) * n]);
                            off += 1;
                        }
                    }
                } else {
                    // 2D K-block: C += I[:, ks] · W[ks, :] over all rows.
                    let (k_lo, k_hi) = (covers[0].k_lo, covers[0].k_hi);
                    debug_assert!(covers.iter().all(|r| r.k_lo == k_lo && r.k_hi == k_hi));
                    let kw = (k_hi - k_lo) as usize;
                    let full = Region {
                        row_lo: 0,
                        row_hi: m as u64,
                        k_lo,
                        k_hi,
                    };
                    let a = extract(&view, k, &full);
                    // W rows k_lo..k_hi.
                    let wb = w[k_lo as usize * n..k_hi as usize * n].to_vec();
                    c = gemm.matmul_acc(c, a, wb, m as u64, n as u64, kw as u64)?;
                }
            }
            // Gather/Scatter are data-layout copies; their timing cost
            // is modelled by the simulator, and their numeric effect
            // is subsumed by the explicit row/K-block indexing above.
            OpKind::Gather { .. } | OpKind::Scatter { .. } => {}
        }
    }
    Ok((gemms, transfers, bytes, c))
}

#[cfg(test)]
mod tests {
    // End-to-end numeric equivalence tests (need a PJRT client) live
    // in rust/tests/numeric_schedules.rs; helpers tested here.
    use super::*;

    #[test]
    fn extract_place_round_trip() {
        let k = 6;
        let src: Vec<f32> = (0..24).map(|x| x as f32).collect(); // 4x6
        let region = Region {
            row_lo: 1,
            row_hi: 3,
            k_lo: 2,
            k_hi: 5,
        };
        let piece = extract(&src, k, &region);
        assert_eq!(piece, vec![8.0, 9.0, 10.0, 14.0, 15.0, 16.0]);
        let mut dst = vec![0.0f32; 24];
        place(&mut dst, k, &region, &piece);
        assert_eq!(dst[8], 8.0);
        assert_eq!(dst[16], 16.0);
        assert_eq!(dst[0], 0.0);
    }
}
