//! Calibration: fit a [`HeuristicModel`] against `ficco tune`
//! searched optima (DESIGN.md §7).
//!
//! The fit is a deterministic grid + greedy coordinate search:
//!
//! 1. score the frozen default model on the training examples;
//! 2. grid-search the Fig-12a threshold scale (rule-free models);
//! 3. for each plan axis in fixed order (pieces, slots, fused,
//!    head-start, shape), try every candidate decision rule —
//!    feature × cutoff × (below, at-or-above) value pair — on top of
//!    the incumbent and keep the best strict improvement.
//!
//! The objective is the mean fraction of the searched-optimum speedup
//! lost over the suite (plan-level hits tie-break). Every candidate's
//! predicted plans are simulated through one shared [`EvalCache`] /
//! [`Evaluator`] pair, so repeated predictions cost a hash lookup.
//! Candidate order is fixed and scoring is sequential, so the fitted
//! model — and its serialized artifact — is byte-identical for any
//! `--jobs` used to produce the training examples.
//!
//! [`calibrate`] adds the **holdout gate** (the fallback semantics):
//! the fitted model ships only if it does not degrade the frozen
//! Fig-12a rule on a held-out suite — otherwise the default model is
//! returned — so the accepted model's holdout hit-rate is ≥ the
//! frozen rule's by construction.

use crate::plan::CommShape;
use crate::schedule::exec::Evaluator;
use crate::search::{CalExample, EvalCache};

use super::model::{CountVal, Feature, FlagVal, HeuristicModel, Rule, ShapeVal};

/// How a model scores on a calibration suite (plan-level).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SuiteScore {
    /// Scenarios where the predicted plan IS the searched optimum.
    pub plan_hits: usize,
    pub n: usize,
    /// Mean fraction of the searched-optimum speedup lost by the
    /// predictions, over the whole suite (0 contribution on hits).
    pub mean_loss: f64,
}

impl SuiteScore {
    /// Plan-level hit rate; an empty suite is vacuously accurate.
    pub fn hit_rate(&self) -> f64 {
        if self.n == 0 {
            1.0
        } else {
            self.plan_hits as f64 / self.n as f64
        }
    }
}

/// Score `model` on `examples`: each predicted plan is simulated
/// through the shared cache/evaluator (memoized across candidate
/// models) and compared against the example's searched optimum.
pub fn score_model(
    model: &HeuristicModel,
    examples: &[CalExample],
    cache: &EvalCache,
    ev: &mut Evaluator,
) -> SuiteScore {
    let mut hits = 0usize;
    let mut loss_sum = 0.0f64;
    for exm in examples {
        let d = model.predict(&exm.machine, &exm.scenario);
        if d.plan == exm.searched_plan {
            hits += 1;
            continue; // exact hit: zero loss, nothing to simulate
        }
        let ms = cache.makespan_in(ev, &exm.machine_name, &exm.machine, &exm.scenario, &d.plan);
        // Loss vs the searched optimum, clamped at 0: a prediction
        // outside the searched space can legitimately beat it. A
        // prediction that does not simulate to a positive finite
        // makespan is maximally wrong — scoring it 0 would let a
        // degenerate candidate flatter its way past every honest one
        // (and through the holdout gate).
        loss_sum += if ms.is_finite() && ms > 0.0 {
            (1.0 - exm.searched_makespan / ms).max(0.0)
        } else {
            1.0
        };
    }
    SuiteScore {
        plan_hits: hits,
        n: examples.len(),
        mean_loss: if examples.is_empty() {
            0.0
        } else {
            loss_sum / examples.len() as f64
        },
    }
}

/// Fit configuration: the threshold-scale grid. (Axis-rule candidates
/// — features, cutoffs, symbolic values — are fixed; see the module
/// consts.)
#[derive(Debug, Clone)]
pub struct FitCfg {
    pub threshold_grid: Vec<f64>,
}

impl Default for FitCfg {
    fn default() -> FitCfg {
        FitCfg {
            threshold_grid: vec![0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0],
        }
    }
}

/// Candidate cutoffs per feature (rough decision boundaries of each
/// metric's natural scale; the greedy fit picks among them).
fn cutoffs(feature: Feature) -> &'static [f64] {
    match feature {
        Feature::NormOtb => &[0.5, 1.0, 2.0],
        Feature::NormMt => &[0.5, 1.0, 2.0, 5.0],
        Feature::Combined => &[0.5, 1.0, 2.0, 5.0, 10.0],
        Feature::Imbalance => &[1.05, 1.25, 1.5, 2.0],
        Feature::HotShare => &[0.2, 0.3, 0.5],
        // Comm share of the critical path (fragility proxy) lives in
        // (0, 1); the interesting boundary is the comm-bound half.
        Feature::CommShare => &[0.1, 0.25, 0.5, 0.75],
    }
}

const PIECES_VALS: [CountVal; 6] = [
    CountVal::Keep,
    CountVal::Const(2),
    CountVal::Const(4),
    CountVal::HalfGpus,
    CountVal::Gpus,
    CountVal::TwiceGpus,
];

const SLOTS_VALS: [CountVal; 4] = [
    CountVal::Keep,
    CountVal::Const(1),
    CountVal::Const(2),
    CountVal::FullMesh,
];

const FLAG_VALS: [FlagVal; 3] = [FlagVal::Keep, FlagVal::Set(false), FlagVal::Set(true)];

const SHAPE_VALS: [ShapeVal; 3] = [
    ShapeVal::Keep,
    ShapeVal::Set(CommShape::Row),
    ShapeVal::Set(CommShape::Col),
];

/// All candidate rules over a value set, in deterministic order.
/// Pairs with `below == at_or_above` are feature-independent and
/// excluded (they are not decision rules).
fn rules_for<V: Copy + PartialEq>(vals: &[V]) -> Vec<Rule<V>> {
    let mut out = Vec::new();
    for feature in Feature::ALL {
        for &cutoff in cutoffs(feature) {
            for &below in vals {
                for &at_or_above in vals {
                    if below != at_or_above {
                        out.push(Rule {
                            feature,
                            cutoff,
                            below,
                            at_or_above,
                        });
                    }
                }
            }
        }
    }
    out
}

/// Is `a` strictly better than `b`? Primary: lower mean loss (beyond
/// a float-noise margin); tie-break: more plan hits. Strictness keeps
/// the fit deterministic and biased toward the earlier (simpler)
/// candidate.
fn better(a: &SuiteScore, b: &SuiteScore) -> bool {
    if a.mean_loss < b.mean_loss - 1e-12 {
        return true;
    }
    if a.mean_loss > b.mean_loss + 1e-12 {
        return false;
    }
    a.plan_hits > b.plan_hits
}

/// Result of [`fit`]: the best model found and how it compares to the
/// frozen default on the training suite.
#[derive(Debug, Clone)]
pub struct FitOutcome {
    pub model: HeuristicModel,
    pub train: SuiteScore,
    pub default_train: SuiteScore,
    /// Candidate models scored (diagnostic).
    pub candidates: usize,
}

fn try_axis<V: Copy + PartialEq>(
    best: &mut (HeuristicModel, SuiteScore),
    vals: &[V],
    set: impl Fn(&mut HeuristicModel, Rule<V>),
    train: &[CalExample],
    cache: &EvalCache,
    ev: &mut Evaluator,
    candidates: &mut usize,
) {
    for rule in rules_for(vals) {
        let mut m = best.0.clone();
        set(&mut m, rule);
        *candidates += 1;
        let s = score_model(&m, train, cache, ev);
        if better(&s, &best.1) {
            *best = (m, s);
        }
    }
}

/// Fit a model to the training examples (no holdout gate — see
/// [`calibrate`]). The default model is always a candidate, so the
/// fitted model never scores worse than the frozen rule on `train`.
pub fn fit(
    train: &[CalExample],
    cfg: &FitCfg,
    cache: &EvalCache,
    ev: &mut Evaluator,
) -> FitOutcome {
    let mut candidates = 0usize;
    let default_train = score_model(&HeuristicModel::default(), train, cache, ev);
    let mut best = (HeuristicModel::default(), default_train);

    for &scale in &cfg.threshold_grid {
        if !(scale.is_finite() && scale > 0.0) {
            continue;
        }
        let m = HeuristicModel {
            threshold_scale: scale,
            ..HeuristicModel::default()
        };
        candidates += 1;
        let s = score_model(&m, train, cache, ev);
        if better(&s, &best.1) {
            best = (m, s);
        }
    }

    try_axis(&mut best, &PIECES_VALS, |m, r| m.pieces = Some(r), train, cache, ev, &mut candidates);
    try_axis(&mut best, &SLOTS_VALS, |m, r| m.slots = Some(r), train, cache, ev, &mut candidates);
    try_axis(&mut best, &FLAG_VALS, |m, r| m.fused = Some(r), train, cache, ev, &mut candidates);
    try_axis(
        &mut best,
        &FLAG_VALS,
        |m, r| m.head_start = Some(r),
        train,
        cache,
        ev,
        &mut candidates,
    );
    try_axis(&mut best, &SHAPE_VALS, |m, r| m.shape = Some(r), train, cache, ev, &mut candidates);

    FitOutcome {
        model: best.0,
        train: best.1,
        default_train,
        candidates,
    }
}

/// Result of [`calibrate`]: the accepted model plus every score the
/// holdout gate weighed.
#[derive(Debug, Clone)]
pub struct CalibrationOutcome {
    /// The accepted model: the fitted one, or the frozen default when
    /// the holdout gate rejected the fit.
    pub model: HeuristicModel,
    /// What the fit produced before the gate.
    pub fitted: HeuristicModel,
    pub fell_back: bool,
    /// Training score of the fitted model.
    pub train: SuiteScore,
    pub default_train: SuiteScore,
    /// Holdout score of the **accepted** model.
    pub holdout: SuiteScore,
    /// Holdout score of the frozen default (the gate's reference).
    pub default_holdout: SuiteScore,
    /// Holdout score of the fitted model (== `holdout` unless the fit
    /// fell back).
    pub fitted_holdout: SuiteScore,
    pub candidates: usize,
}

/// Fit on `train`, then apply the holdout gate: accept the fitted
/// model only if its holdout plan-hit count is ≥ the frozen default's
/// and its holdout mean loss is no worse. The accepted model's
/// holdout hit-rate is therefore ≥ the Fig-12a rule's by
/// construction.
pub fn calibrate(
    train: &[CalExample],
    holdout: &[CalExample],
    cfg: &FitCfg,
) -> CalibrationOutcome {
    let cache = EvalCache::new();
    let mut ev = Evaluator::new();
    let out = fit(train, cfg, &cache, &mut ev);
    let default_model = HeuristicModel::default();
    let default_holdout = score_model(&default_model, holdout, &cache, &mut ev);
    let fitted_holdout = score_model(&out.model, holdout, &cache, &mut ev);
    let accept = fitted_holdout.plan_hits >= default_holdout.plan_hits
        && fitted_holdout.mean_loss <= default_holdout.mean_loss + 1e-9;
    CalibrationOutcome {
        model: if accept {
            out.model.clone()
        } else {
            default_model
        },
        fitted: out.model,
        fell_back: !accept,
        train: out.train,
        default_train: out.default_train,
        holdout: if accept { fitted_holdout } else { default_holdout },
        default_holdout,
        fitted_holdout,
        candidates: out.candidates,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_score_hit_rate() {
        let s = SuiteScore {
            plan_hits: 3,
            n: 4,
            mean_loss: 0.1,
        };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        let empty = SuiteScore {
            plan_hits: 0,
            n: 0,
            mean_loss: 0.0,
        };
        assert_eq!(empty.hit_rate(), 1.0, "vacuously accurate");
    }

    #[test]
    fn better_orders_by_loss_then_hits() {
        let a = SuiteScore { plan_hits: 1, n: 4, mean_loss: 0.05 };
        let b = SuiteScore { plan_hits: 3, n: 4, mean_loss: 0.10 };
        assert!(better(&a, &b), "lower loss wins despite fewer hits");
        assert!(!better(&b, &a));
        let c = SuiteScore { plan_hits: 2, n: 4, mean_loss: 0.05 };
        assert!(better(&c, &a), "equal loss, more hits wins");
        assert!(!better(&a, &c));
        assert!(!better(&a, &a), "strictness: a candidate never beats itself");
    }

    #[test]
    fn score_model_on_empty_suite() {
        let s = score_model(
            &HeuristicModel::default(),
            &[],
            &EvalCache::new(),
            &mut Evaluator::new(),
        );
        assert_eq!((s.plan_hits, s.n), (0, 0));
        assert_eq!(s.mean_loss, 0.0);
        assert_eq!(s.hit_rate(), 1.0);
    }

    #[test]
    fn rule_candidates_are_real_decision_rules() {
        let rules = rules_for(&FLAG_VALS);
        assert!(!rules.is_empty());
        assert!(rules.iter().all(|r| r.below != r.at_or_above));
        // Deterministic enumeration: two calls agree exactly.
        assert_eq!(rules, rules_for(&FLAG_VALS));
        // Every feature appears.
        for f in Feature::ALL {
            assert!(rules.iter().any(|r| r.feature == f), "{:?}", f);
        }
    }
}
