//! FiCCO schedule-selection heuristics (§V-C, Fig 12a).
//!
//! The decision procedure, verbatim from the paper:
//!
//! 1. If `M ≤ K`, row-sharding is unfavourable → the single 2D
//!    schedule, **uniform-fused-2D**.
//! 2. Otherwise (1D family), compare a *combined OTB·MT metric*
//!    against a machine-level threshold derived from hardware balance
//!    (`op-to-byte × memory bandwidth = FLOPs`):
//!    - below the threshold → **uniform-fused-1D** (low-DIL/high-CIL
//!      schedule for ops insensitive to CIL),
//!    - above 5× the threshold → **hetero-unfused-1D**
//!      (high-DIL/low-CIL schedule for ops insensitive to DIL),
//!    - in between → **hetero-fused-1D**.
//!
//! The oracle (argmin over simulated schedules) lives here too — it is
//! what the heuristic is scored against in §VI-D.

pub mod fit;
pub mod model;

use crate::hw::Machine;
use crate::schedule::exec::ScenarioEval;
use crate::schedule::{Kind, Scenario};

/// Tuned multiplier on the machine-balance threshold separating the
/// uniform-fused-1D regime; the hetero-unfused regime starts at
/// [`THRESHOLD_BAND`]× this.
pub const DEFAULT_THRESHOLD_SCALE: f64 = 1.0;

/// Width of the hetero-fused band: the hetero-unfused regime starts
/// at this multiple of the (scaled) threshold (the paper's "exceeds
/// the threshold by 5×"). Shared with the CLI so the printed decision
/// boundary can never drift from the rule.
pub const THRESHOLD_BAND: f64 = 5.0;

/// Static metrics the heuristic reads (Fig 12a inputs).
#[derive(Debug, Clone, Copy)]
pub struct StaticMetrics {
    /// GEMM op-to-byte ratio (FLOPs per byte).
    pub otb: f64,
    /// GEMM memory traffic (bytes, MK+KN+MN).
    pub mt: f64,
    /// OTB normalized by machine balance (peak FLOPs / HBM BW).
    pub norm_otb: f64,
    /// MT normalized by the last-level cache capacity.
    pub norm_mt: f64,
    /// The combined metric compared against the threshold.
    pub combined: f64,
    /// Traffic imbalance: max/mean shard-size ratio of the scenario's
    /// row partition (1.0 under balanced routing; grows with the
    /// expert skew). An input for skew-aware decision procedures —
    /// the frozen Fig-12a rule ignores it, so `skew == 0` picks are
    /// unchanged.
    pub imbalance: f64,
    /// The hot (largest) shard's rows as a fraction of M — `1/ngpus`
    /// under balanced routing.
    pub hot_share: f64,
    /// Static fragility proxy: estimated communication share of the
    /// unoverlapped critical path, `comm_t / (comm_t + compute_t)`
    /// with `compute_t = FLOPs / peak` and `comm_t = output bytes /
    /// link BW` (the ngpus factor cancels). Near 0 the plan is
    /// compute-bound and bandwidth jitter is hidden; near 1 it is
    /// comm-bound and any link degradation lands on the critical path
    /// — exactly the regime where the ensemble's fragility signature
    /// (p95/nominal) grows. Calibrated models may threshold on it;
    /// the frozen Fig-12a rule ignores it.
    pub comm_share: f64,
}

pub fn static_metrics(machine: &Machine, sc: &Scenario) -> StaticMetrics {
    let g = &sc.gemm;
    let otb = g.otb();
    let mt = g.mt();
    // Machine balance: the OTB at which compute and memory time equal
    // (the paper's "op-to-byte × memory bandwidth = FLOPs").
    let balance = machine.balance(g.dtype);
    let norm_otb = otb / balance;
    let norm_mt = mt / machine.gpu.llc_bytes as f64;
    let part = sc.partition(1);
    let compute_t = g.flops() / machine.gpu.peak_flops(g.dtype);
    let comm_t = g.m as f64 * g.n as f64 * g.dtype.bytes() as f64 / machine.topo.link_bw;
    StaticMetrics {
        otb,
        mt,
        norm_otb,
        norm_mt,
        combined: norm_otb * norm_mt,
        imbalance: part.imbalance(),
        hot_share: if g.m == 0 {
            0.0
        } else {
            part.max_shard() as f64 / g.m as f64
        },
        comm_share: if compute_t + comm_t > 0.0 {
            comm_t / (compute_t + comm_t)
        } else {
            0.0
        },
    }
}

/// The heuristic decision with its reasoning trace (for reporting).
#[derive(Debug, Clone)]
pub struct Decision {
    pub pick: Kind,
    pub metrics: StaticMetrics,
    pub reason: String,
}

/// Pick the bespoke FiCCO schedule for a scenario (Fig 12a).
pub fn pick(machine: &Machine, sc: &Scenario) -> Decision {
    pick_with_threshold(machine, sc, DEFAULT_THRESHOLD_SCALE)
}

/// As [`pick`], with an explicit threshold scale (calibration knob).
pub fn pick_with_threshold(machine: &Machine, sc: &Scenario, scale: f64) -> Decision {
    let m = static_metrics(machine, sc);
    let g = &sc.gemm;
    if g.m <= g.k {
        return Decision {
            pick: Kind::UniformFused2D,
            metrics: m,
            reason: format!("M={} <= K={} -> 2D (col) communication shape", g.m, g.k),
        };
    }
    let t = scale;
    let (pick, reason) = if m.combined < t {
        (
            Kind::UniformFused1D,
            format!("combined {:.3} < {:.3} (low OTB+MT): DIL-sensitive", m.combined, t),
        )
    } else if m.combined > THRESHOLD_BAND * t {
        (
            Kind::HeteroUnfused1D,
            format!(
                "combined {:.3} > {:.3} (high OTB+MT): CIL-sensitive",
                m.combined,
                THRESHOLD_BAND * t
            ),
        )
    } else {
        (
            Kind::HeteroFused1D,
            format!(
                "combined {:.3} in [{:.3}, {:.3}]: balanced",
                m.combined,
                t,
                THRESHOLD_BAND * t
            ),
        )
    };
    Decision {
        pick,
        metrics: m,
        reason,
    }
}

/// Oracle + heuristic agreement for one scenario.
#[derive(Debug, Clone)]
pub struct Scored {
    pub scenario_name: String,
    pub pick: Kind,
    pub oracle: Kind,
    pub pick_speedup: f64,
    pub oracle_speedup: f64,
    /// Best speedup found by searching the parameterized plan space
    /// (`None` when the scenario was scored against the 6-kind oracle
    /// only — see [`score_searched`]).
    pub searched_speedup: Option<f64>,
    /// Plan id of the searched optimum, when searched.
    pub searched_plan: Option<String>,
    /// Plan id the decision procedure predicted: the picked kind's
    /// preset for the kind-level rule, the model's full plan for a
    /// calibrated model. `None` when the plan space was not searched.
    pub pick_plan: Option<String>,
    /// Plan-level hit: the predicted plan IS the searched optimum
    /// (`None` when unsearched). Strictly harder than [`Scored::hit`]
    /// — a kind can be right while its knobs are not.
    pub plan_hit: Option<bool>,
}

/// Fraction of `reference` speedup lost by `pick_speedup`, guarded:
/// a non-finite or non-positive reference yields 0 loss rather than
/// NaN/∞ (a reference that cannot be computed cannot be lost to).
fn safe_loss(pick_speedup: f64, reference: f64) -> f64 {
    if !(reference.is_finite() && reference > 0.0) || !pick_speedup.is_finite() {
        return 0.0;
    }
    (1.0 - pick_speedup / reference).max(0.0)
}

impl Scored {
    pub fn hit(&self) -> bool {
        self.pick == self.oracle
    }

    /// Fraction of the oracle speedup lost by the heuristic pick
    /// (the paper reports ≈14% on mispredictions). Guarded against a
    /// degenerate zero/non-finite oracle speedup.
    pub fn loss(&self) -> f64 {
        safe_loss(self.pick_speedup, self.oracle_speedup)
    }

    /// Fraction of the *searched* optimum's speedup lost by the
    /// static pick — the honest accuracy number once the design space
    /// is wider than the six kinds. `None` when no search was run.
    pub fn searched_loss(&self) -> Option<f64> {
        self.searched_speedup
            .map(|s| safe_loss(self.pick_speedup, s))
    }
}

/// Score the heuristic against the simulated oracle on one scenario.
pub fn score(machine: &Machine, sc: &Scenario, threshold_scale: f64) -> Scored {
    let decision = pick_with_threshold(machine, sc, threshold_scale);
    let mut kinds = vec![Kind::Baseline];
    kinds.extend_from_slice(&Kind::FICCO);
    let ev = ScenarioEval::run(machine, sc, &kinds);
    let (oracle, oracle_speedup) = ev
        .best_ficco()
        .expect("score evaluates the full FiCCO family");
    Scored {
        scenario_name: sc.name.clone(),
        pick: decision.pick,
        oracle,
        pick_speedup: ev.speedup(decision.pick),
        oracle_speedup,
        searched_speedup: None,
        searched_plan: None,
        pick_plan: None,
        plan_hit: None,
    }
}

/// As [`score`], additionally searching the parameterized plan space
/// ([`crate::search`]) so the heuristic is measured against the
/// searched optimum, not just the 6-kind argmin. `cache` memoizes
/// plan evaluations — pass one shared [`crate::search::EvalCache`]
/// when scoring a whole suite so repeated (machine, shape, plan)
/// points are simulated once.
pub fn score_searched(
    machine: &Machine,
    sc: &Scenario,
    threshold_scale: f64,
    cfg: &crate::search::SearchCfg,
    cache: &crate::search::EvalCache,
) -> Scored {
    score_searched_in(
        &mut crate::schedule::exec::Evaluator::new(),
        machine,
        sc,
        threshold_scale,
        cfg,
        cache,
    )
}

/// As [`score_searched`], through a caller-owned reusable
/// [`crate::schedule::exec::Evaluator`] arena — suite scorers pass
/// one across all scenarios so candidate simulation reuses the
/// machine skeleton and scratch buffers.
fn score_searched_in(
    ev: &mut crate::schedule::exec::Evaluator,
    machine: &Machine,
    sc: &Scenario,
    threshold_scale: f64,
    cfg: &crate::search::SearchCfg,
    cache: &crate::search::EvalCache,
) -> Scored {
    let mut scored = score(machine, sc, threshold_scale);
    let space = crate::search::SpaceSpec::default_for(sc);
    // Key by a machine fingerprint, not a constant: a cache shared
    // across machines must never serve one machine's makespans for
    // another's.
    let machine_name = crate::search::machine_key(machine);
    let out = crate::search::search_in(ev, &machine_name, machine, sc, &space, cfg, cache);
    scored.searched_speedup = Some(out.best_speedup());
    scored.searched_plan = Some(out.best.plan.id());
    let preset = crate::plan::Plan::preset(scored.pick, sc);
    scored.pick_plan = Some(preset.id());
    scored.plan_hit = Some(out.best.plan == preset);
    scored
}

/// Accuracy of the heuristic over a suite: (hit-rate, mean loss on
/// misses) — the two numbers §VI-D reports (81%, ~14%). An empty
/// suite is vacuously accurate: (1.0, 0.0, []) rather than NaN.
pub fn accuracy(machine: &Machine, suite: &[Scenario], threshold_scale: f64) -> (f64, f64, Vec<Scored>) {
    if suite.is_empty() {
        return (1.0, 0.0, Vec::new());
    }
    let scored: Vec<Scored> = suite
        .iter()
        .map(|sc| score(machine, sc, threshold_scale))
        .collect();
    let hits = scored.iter().filter(|s| s.hit()).count();
    let losses: Vec<f64> = scored.iter().filter(|s| !s.hit()).map(Scored::loss).collect();
    let mean_loss = if losses.is_empty() {
        0.0
    } else {
        losses.iter().sum::<f64>() / losses.len() as f64
    };
    (hits as f64 / suite.len() as f64, mean_loss, scored)
}

/// Accuracy of the heuristic over a suite, scored against the
/// searched plan-space optimum: (kind-level hit rate, mean searched
/// loss over the whole suite, per-scenario details). Empty suites are
/// vacuously accurate, as in [`accuracy`].
pub fn searched_accuracy(
    machine: &Machine,
    suite: &[Scenario],
    threshold_scale: f64,
    cfg: &crate::search::SearchCfg,
) -> (f64, f64, Vec<Scored>) {
    if suite.is_empty() {
        return (1.0, 0.0, Vec::new());
    }
    // One cache across the whole suite: synthetic suites repeat GEMM
    // shapes often enough that cross-scenario memoization pays. One
    // evaluator arena likewise — every scenario shares the machine.
    let cache = crate::search::EvalCache::new();
    let mut ev = crate::schedule::exec::Evaluator::new();
    let scored: Vec<Scored> = suite
        .iter()
        .map(|sc| score_searched_in(&mut ev, machine, sc, threshold_scale, cfg, &cache))
        .collect();
    let hits = scored.iter().filter(|s| s.hit()).count();
    let mean_searched_loss = scored
        .iter()
        .filter_map(Scored::searched_loss)
        .sum::<f64>()
        / scored.len() as f64;
    (
        hits as f64 / suite.len() as f64,
        mean_searched_loss,
        scored,
    )
}

/// Score a calibrated full-plan model against the searched plan-space
/// optimum on one scenario: the kind-level oracle fields as in
/// [`score`], plus the model's predicted plan, its simulated speedup,
/// and the plan-level hit/loss vs the searched best.
fn score_model_searched_in(
    ev: &mut crate::schedule::exec::Evaluator,
    machine: &Machine,
    sc: &Scenario,
    decision_model: &model::HeuristicModel,
    cfg: &crate::search::SearchCfg,
    cache: &crate::search::EvalCache,
) -> Scored {
    let d = decision_model.predict(machine, sc);
    let mut kinds = vec![Kind::Baseline];
    kinds.extend_from_slice(&Kind::FICCO);
    let evr = ScenarioEval::run_in(ev, machine, sc, &kinds);
    let (oracle, oracle_speedup) = evr
        .best_ficco()
        .expect("full FiCCO family evaluated");
    let machine_name = crate::search::machine_key(machine);
    let space = crate::search::SpaceSpec::default_for(sc);
    let out = crate::search::search_in(ev, &machine_name, machine, sc, &space, cfg, cache);
    let pick_makespan = cache.makespan_in(ev, &machine_name, machine, sc, &d.plan);
    Scored {
        scenario_name: sc.name.clone(),
        pick: d.kind,
        oracle,
        pick_speedup: out.baseline / pick_makespan,
        oracle_speedup,
        searched_speedup: Some(out.best_speedup()),
        searched_plan: Some(out.best.plan.id()),
        pick_plan: Some(d.plan.id()),
        plan_hit: Some(out.best.plan == d.plan),
    }
}

/// Accuracy of a calibrated model over a suite, scored against the
/// searched plan-space optimum: (**plan-level** hit rate, mean
/// searched loss over the whole suite, per-scenario details). The
/// kind-level [`searched_accuracy`] keeps the frozen Fig-12a
/// semantics; this is its plan-space counterpart
/// (`ficco synth --model`). Empty suites are vacuously accurate.
pub fn model_searched_accuracy(
    machine: &Machine,
    suite: &[Scenario],
    decision_model: &model::HeuristicModel,
    cfg: &crate::search::SearchCfg,
) -> (f64, f64, Vec<Scored>) {
    if suite.is_empty() {
        return (1.0, 0.0, Vec::new());
    }
    let cache = crate::search::EvalCache::new();
    let mut ev = crate::schedule::exec::Evaluator::new();
    let scored: Vec<Scored> = suite
        .iter()
        .map(|sc| score_model_searched_in(&mut ev, machine, sc, decision_model, cfg, &cache))
        .collect();
    let hits = scored.iter().filter(|s| s.plan_hit == Some(true)).count();
    let mean_loss = scored
        .iter()
        .filter_map(Scored::searched_loss)
        .sum::<f64>()
        / scored.len() as f64;
    (hits as f64 / suite.len() as f64, mean_loss, scored)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn machine() -> Machine {
        Machine::mi300x_8()
    }

    #[test]
    fn m_le_k_always_2d() {
        let m = machine();
        for row in workloads::table1::m_le_k() {
            let d = pick(&m, &row.scenario());
            assert_eq!(d.pick, Kind::UniformFused2D, "{}", row.name);
        }
    }

    #[test]
    fn m_gt_k_picks_a_1d_schedule() {
        let m = machine();
        for row in workloads::table1::m_gt_k() {
            let d = pick(&m, &row.scenario());
            assert_ne!(d.pick, Kind::UniformFused2D, "{}", row.name);
            assert!(d.pick.is_ficco());
        }
    }

    #[test]
    fn threshold_moves_decisions() {
        let m = machine();
        let sc = workloads::by_name("g2").unwrap();
        let low = pick_with_threshold(&m, &sc, 1e-9).pick;
        let high = pick_with_threshold(&m, &sc, 1e9).pick;
        assert_eq!(low, Kind::HeteroUnfused1D);
        assert_eq!(high, Kind::UniformFused1D);
    }

    #[test]
    fn metrics_monotone_in_shape() {
        let m = machine();
        let small = Scenario::new("s", 16384, 1024, 1024);
        let big = Scenario::new("b", 1048576, 57344, 8192);
        let ms = static_metrics(&m, &small);
        let mb = static_metrics(&m, &big);
        assert!(mb.mt > ms.mt);
        assert!(mb.combined > ms.combined);
    }

    #[test]
    fn decision_has_reason() {
        let m = machine();
        let d = pick(&m, &workloads::by_name("g1").unwrap());
        assert!(!d.reason.is_empty());
    }

    #[test]
    fn imbalance_features_track_the_partition() {
        let m = machine();
        let uniform = Scenario::new("u", 65536, 1024, 4096);
        let mu = static_metrics(&m, &uniform);
        assert_eq!(mu.imbalance, 1.0, "balanced routing");
        assert_eq!(mu.hot_share, 1.0 / 8.0);
        let skewed = uniform.clone().with_skew(1.0, 3);
        let ms = static_metrics(&m, &skewed);
        assert!(ms.imbalance > 1.2, "imbalance {}", ms.imbalance);
        assert!(ms.hot_share > mu.hot_share);
        // The frozen Fig-12a rule reads only the shape metrics, so the
        // skew knob must not move skew-0-era picks.
        assert_eq!(
            pick(&m, &uniform).pick,
            pick(&m, &skewed).pick,
            "static pick is shape-driven"
        );
        assert_eq!(ms.combined, mu.combined);
    }

    #[test]
    fn comm_share_is_a_bandwidth_sensitive_fragility_proxy() {
        let sc = Scenario::new("t", 65536, 1024, 4096);
        // Same GPU, different fabric: the mesh's 64 GB/s links leave a
        // larger comm share than the 450 GB/s switch — the mesh run is
        // the more perturbation-fragile one.
        let mesh = static_metrics(&Machine::mi300x_8(), &sc);
        let fat = static_metrics(&Machine::switch_8(), &sc);
        assert!(mesh.comm_share > 0.0 && mesh.comm_share < 1.0);
        assert!(fat.comm_share > 0.0 && fat.comm_share < 1.0);
        assert!(
            mesh.comm_share > fat.comm_share,
            "slower links must raise the comm share ({} vs {})",
            mesh.comm_share,
            fat.comm_share
        );
        // The frozen Fig-12a rule reads only the shape metrics, so the
        // new proxy must not move legacy picks.
        assert_eq!(
            pick(&Machine::mi300x_8(), &sc).pick,
            pick(&Machine::switch_8(), &sc).pick
        );
    }

    #[test]
    fn accuracy_on_empty_suite_has_no_nan() {
        // Regression: hit-rate used to be 0/0 = NaN on an empty suite.
        let m = machine();
        let (hit_rate, mean_loss, scored) = accuracy(&m, &[], 1.0);
        assert!(hit_rate.is_finite() && mean_loss.is_finite());
        assert_eq!(hit_rate, 1.0, "vacuously accurate");
        assert_eq!(mean_loss, 0.0);
        assert!(scored.is_empty());
        let (h2, l2, s2) = searched_accuracy(&m, &[], 1.0, &crate::search::SearchCfg::default());
        assert_eq!((h2, l2, s2.len()), (1.0, 0.0, 0));
    }

    #[test]
    fn loss_guards_degenerate_oracle_speedup() {
        // Regression: a zero/non-finite oracle speedup used to yield
        // ±∞ or NaN loss.
        let base = Scored {
            scenario_name: "t".into(),
            pick: Kind::UniformFused1D,
            oracle: Kind::HeteroFused1D,
            pick_speedup: 1.2,
            oracle_speedup: 0.0,
            searched_speedup: None,
            searched_plan: None,
            pick_plan: None,
            plan_hit: None,
        };
        assert_eq!(base.loss(), 0.0);
        let nan = Scored {
            oracle_speedup: f64::NAN,
            ..base.clone()
        };
        assert_eq!(nan.loss(), 0.0);
        let normal = Scored {
            oracle_speedup: 1.5,
            ..base.clone()
        };
        assert!((normal.loss() - 0.2).abs() < 1e-12);
        // A pick beating the reference clamps to zero loss rather
        // than going negative.
        let beaten = Scored {
            oracle_speedup: 1.0,
            ..base
        };
        assert_eq!(beaten.loss(), 0.0);
        assert_eq!(beaten.searched_loss(), None);
    }

    #[test]
    fn searched_score_is_at_least_the_oracle() {
        // The plan space contains every legacy kind as a preset, so
        // the searched optimum can never fall below the 6-kind oracle.
        let m = machine();
        let sc = Scenario::new("t", 65536, 1024, 4096);
        let cfg = crate::search::SearchCfg {
            beam: 2,
            prune: true,
            ..Default::default()
        };
        let s = score_searched(&m, &sc, 1.0, &cfg, &crate::search::EvalCache::new());
        let searched = s.searched_speedup.expect("searched");
        assert!(
            searched >= s.oracle_speedup * (1.0 - 1e-12),
            "searched {searched} < oracle {}",
            s.oracle_speedup
        );
        assert!(s.searched_plan.is_some());
        let loss = s.searched_loss().expect("searched loss");
        assert!((0.0..=1.0).contains(&loss));
        // The searched score now also reports the plan-level verdict.
        assert_eq!(
            s.pick_plan.as_deref(),
            Some(crate::plan::Plan::preset(s.pick, &sc).id().as_str())
        );
        assert!(s.plan_hit.is_some());
        if s.plan_hit == Some(true) {
            assert_eq!(s.pick_plan, s.searched_plan);
        }
    }

    #[test]
    fn default_model_accuracy_matches_plan_level_semantics() {
        // The default model's predictions are the legacy picks'
        // presets, so its plan-level hit/loss must agree with the
        // kind-level searched scorer's new plan fields.
        let m = machine();
        let suite = vec![
            Scenario::new("a", 65536, 1024, 4096),
            Scenario::new("b", 16384, 1024, 65536),
        ];
        let cfg = crate::search::SearchCfg {
            beam: 2,
            prune: true,
            ..Default::default()
        };
        let (hit_rate, mean_loss, scored) =
            model_searched_accuracy(&m, &suite, &model::HeuristicModel::default(), &cfg);
        assert!(hit_rate.is_finite() && (0.0..=1.0).contains(&hit_rate));
        assert!(mean_loss.is_finite() && mean_loss >= 0.0);
        assert_eq!(scored.len(), 2);
        let (kh, kl, kscored) = searched_accuracy(&m, &suite, 1.0, &cfg);
        assert!(kh.is_finite() && kl.is_finite());
        for (ms, ks) in scored.iter().zip(&kscored) {
            assert_eq!(ms.pick, ks.pick, "{}", ms.scenario_name);
            assert_eq!(ms.pick_plan, ks.pick_plan, "{}", ms.scenario_name);
            assert_eq!(ms.plan_hit, ks.plan_hit, "{}", ms.scenario_name);
            assert_eq!(ms.searched_plan, ks.searched_plan);
        }
        // Empty suite stays NaN-free.
        let (eh, el, es) =
            model_searched_accuracy(&m, &[], &model::HeuristicModel::default(), &cfg);
        assert_eq!((eh, el, es.len()), (1.0, 0.0, 0));
    }
}
