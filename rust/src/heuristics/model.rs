//! The plan-space-aware heuristic model (DESIGN.md §7).
//!
//! The frozen Fig-12a rule ([`super::pick`]) chooses among the six
//! legacy [`Kind`]s; `ficco tune` searches the full parameterized
//! [`Plan`] space. A [`HeuristicModel`] closes that gap: it maps the
//! static metrics ([`super::StaticMetrics`], including the PR-3
//! `imbalance`/`hot_share` skew features the frozen rule ignores) to a
//! **full plan prediction** — pieces, shape, fused, head start, slots
//! — instead of just a kind.
//!
//! Structure: the Fig-12a decision procedure with a calibratable
//! threshold scale picks a preset plan, then optional per-axis
//! decision rules (one feature threshold per plan axis) override
//! individual knobs. Counts are symbolic ([`CountVal`]: `gpus`,
//! `2gpus`, `mesh`, ...) so a fitted model transfers across GPU
//! fan-outs. The **default model** (`HeuristicModel::default()`) has
//! no rules and the default threshold scale: its prediction is
//! exactly `Plan::preset(pick(machine, sc).pick, sc)`, which keeps
//! every skew-0 golden bit-identical on the uncalibrated path.
//!
//! Models serialize to a byte-stable line-oriented text artifact
//! ([`HeuristicModel::to_text`] / [`HeuristicModel::parse`]): floats
//! use Rust's shortest-round-trip `Display`, lines are emitted in a
//! fixed order, so a deterministic fit produces identical bytes for
//! any `--jobs` value. Fitting lives in [`super::fit`].

use crate::hw::Machine;
use crate::plan::{CommShape, Plan};
use crate::schedule::{Kind, Scenario};

use super::{pick_with_threshold, StaticMetrics, DEFAULT_THRESHOLD_SCALE};

/// Static scenario feature an axis rule can threshold on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Feature {
    /// OTB normalized by machine balance.
    NormOtb,
    /// Memory traffic normalized by LLC capacity.
    NormMt,
    /// The Fig-12a combined metric (`norm_otb * norm_mt`).
    Combined,
    /// Max/mean shard-size ratio of the routing partition.
    Imbalance,
    /// Hot shard's rows as a fraction of M.
    HotShare,
    /// Static fragility proxy: estimated comm share of the critical
    /// path (see [`StaticMetrics::comm_share`]). High values flag
    /// perturbation-fragile, comm-bound scenarios.
    CommShare,
}

impl Feature {
    pub const ALL: [Feature; 6] = [
        Feature::NormOtb,
        Feature::NormMt,
        Feature::Combined,
        Feature::Imbalance,
        Feature::HotShare,
        Feature::CommShare,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Feature::NormOtb => "norm-otb",
            Feature::NormMt => "norm-mt",
            Feature::Combined => "combined",
            Feature::Imbalance => "imbalance",
            Feature::HotShare => "hot-share",
            Feature::CommShare => "comm-share",
        }
    }

    pub fn parse(s: &str) -> Option<Feature> {
        Feature::ALL.iter().copied().find(|f| f.name() == s)
    }

    /// Read this feature out of the computed static metrics.
    pub fn of(self, m: &StaticMetrics) -> f64 {
        match self {
            Feature::NormOtb => m.norm_otb,
            Feature::NormMt => m.norm_mt,
            Feature::Combined => m.combined,
            Feature::Imbalance => m.imbalance,
            Feature::HotShare => m.hot_share,
            Feature::CommShare => m.comm_share,
        }
    }
}

/// Symbolic count for the `pieces`/`slots` axes, resolved against the
/// scenario's GPU fan-out so one fitted model transfers across
/// machine scales.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CountVal {
    /// Keep the Fig-12a preset's value.
    Keep,
    /// Absolute value.
    Const(usize),
    /// `ngpus / 2` (min 1).
    HalfGpus,
    /// `ngpus` (the paper's FiCCO decomposition point).
    Gpus,
    /// `2 * ngpus`.
    TwiceGpus,
    /// `ngpus - 1` (a transfer lane per peer).
    FullMesh,
}

impl CountVal {
    pub fn resolve(self, ngpus: usize, preset: usize) -> usize {
        match self {
            CountVal::Keep => preset,
            CountVal::Const(v) => v,
            CountVal::HalfGpus => (ngpus / 2).max(1),
            CountVal::Gpus => ngpus,
            CountVal::TwiceGpus => 2 * ngpus,
            CountVal::FullMesh => ngpus.saturating_sub(1).max(1),
        }
    }

    pub fn encode(self) -> String {
        match self {
            CountVal::Keep => "keep".to_string(),
            CountVal::Const(v) => format!("const:{v}"),
            CountVal::HalfGpus => "gpus/2".to_string(),
            CountVal::Gpus => "gpus".to_string(),
            CountVal::TwiceGpus => "2gpus".to_string(),
            CountVal::FullMesh => "mesh".to_string(),
        }
    }

    pub fn decode(s: &str) -> Result<CountVal, String> {
        match s {
            "keep" => Ok(CountVal::Keep),
            "gpus/2" => Ok(CountVal::HalfGpus),
            "gpus" => Ok(CountVal::Gpus),
            "2gpus" => Ok(CountVal::TwiceGpus),
            "mesh" => Ok(CountVal::FullMesh),
            other => other
                .strip_prefix("const:")
                .and_then(|v| v.parse().ok())
                .map(CountVal::Const)
                .ok_or_else(|| format!("unknown count value '{s}'")),
        }
    }
}

/// Boolean axis override (`fused`, `head_start`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FlagVal {
    Keep,
    Set(bool),
}

impl FlagVal {
    pub fn resolve(self, preset: bool) -> bool {
        match self {
            FlagVal::Keep => preset,
            FlagVal::Set(b) => b,
        }
    }

    pub fn encode(self) -> &'static str {
        match self {
            FlagVal::Keep => "keep",
            FlagVal::Set(true) => "on",
            FlagVal::Set(false) => "off",
        }
    }

    pub fn decode(s: &str) -> Result<FlagVal, String> {
        match s {
            "keep" => Ok(FlagVal::Keep),
            "on" => Ok(FlagVal::Set(true)),
            "off" => Ok(FlagVal::Set(false)),
            other => Err(format!("unknown flag value '{other}'")),
        }
    }
}

/// Communication-shape axis override.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShapeVal {
    Keep,
    Set(CommShape),
}

impl ShapeVal {
    pub fn resolve(self, preset: CommShape) -> CommShape {
        match self {
            ShapeVal::Keep => preset,
            ShapeVal::Set(s) => s,
        }
    }

    pub fn encode(self) -> &'static str {
        match self {
            ShapeVal::Keep => "keep",
            ShapeVal::Set(CommShape::Row) => "row",
            ShapeVal::Set(CommShape::Col) => "col",
        }
    }

    pub fn decode(s: &str) -> Result<ShapeVal, String> {
        match s {
            "keep" => Ok(ShapeVal::Keep),
            "row" => Ok(ShapeVal::Set(CommShape::Row)),
            "col" => Ok(ShapeVal::Set(CommShape::Col)),
            other => Err(format!("unknown shape value '{other}'")),
        }
    }
}

/// One per-axis decision rule: `feature >= cutoff` selects
/// `at_or_above`, otherwise `below`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rule<V> {
    pub feature: Feature,
    pub cutoff: f64,
    pub below: V,
    pub at_or_above: V,
}

impl<V: Copy> Rule<V> {
    pub fn value(&self, m: &StaticMetrics) -> V {
        if self.feature.of(m) >= self.cutoff {
            self.at_or_above
        } else {
            self.below
        }
    }
}

/// A deterministic, serializable mapping from static metrics to a
/// full [`Plan`] prediction. See the module docs for semantics.
#[derive(Debug, Clone, PartialEq)]
pub struct HeuristicModel {
    /// Fig-12a threshold multiplier (the kind-selection knob the
    /// legacy `--threshold` exposed).
    pub threshold_scale: f64,
    pub pieces: Option<Rule<CountVal>>,
    pub slots: Option<Rule<CountVal>>,
    pub fused: Option<Rule<FlagVal>>,
    pub head_start: Option<Rule<FlagVal>>,
    pub shape: Option<Rule<ShapeVal>>,
}

impl Default for HeuristicModel {
    /// The frozen Fig-12a rule lifted to plan space: no axis rules,
    /// default threshold — predictions are exactly the legacy pick's
    /// preset plan.
    fn default() -> Self {
        HeuristicModel {
            threshold_scale: DEFAULT_THRESHOLD_SCALE,
            pieces: None,
            slots: None,
            fused: None,
            head_start: None,
            shape: None,
        }
    }
}

/// A model's full prediction for one scenario.
#[derive(Debug, Clone)]
pub struct PlanDecision {
    /// The predicted plan (always structurally valid for the
    /// scenario's GPU count).
    pub plan: Plan,
    /// Legacy classification of the predicted plan (reporting).
    pub kind: Kind,
    pub metrics: StaticMetrics,
    pub reason: String,
}

impl HeuristicModel {
    /// True when this is the uncalibrated frozen-rule model.
    pub fn is_default(&self) -> bool {
        *self == HeuristicModel::default()
    }

    /// Predict the bespoke FiCCO plan for a scenario: Fig-12a (at this
    /// model's threshold scale) picks the preset, then the axis rules
    /// override individual knobs. Out-of-range resolved counts are
    /// clamped to the plan's validity range, so the returned plan
    /// always passes `Plan::check`.
    pub fn predict(&self, machine: &Machine, sc: &Scenario) -> PlanDecision {
        let d = pick_with_threshold(machine, sc, self.threshold_scale);
        let mut plan = Plan::preset(d.pick, sc);
        let mut reason = d.reason;
        let n = sc.ngpus;
        let m = d.metrics;
        if let Some(r) = &self.pieces {
            let v = r.value(&m).resolve(n, plan.pieces).clamp(1, Plan::MAX_PIECES);
            if v != plan.pieces {
                reason.push_str(&format!(
                    "; pieces {} -> {} ({} {} {})",
                    plan.pieces,
                    v,
                    r.feature.name(),
                    if r.feature.of(&m) >= r.cutoff { ">=" } else { "<" },
                    r.cutoff,
                ));
                plan.pieces = v;
            }
        }
        if let Some(r) = &self.slots {
            let full = n.saturating_sub(1).max(1);
            let v = r.value(&m).resolve(n, plan.slots).clamp(1, full);
            if v != plan.slots {
                reason.push_str(&format!("; slots {} -> {}", plan.slots, v));
                plan.slots = v;
            }
        }
        if let Some(r) = &self.fused {
            let v = r.value(&m).resolve(plan.fused);
            if v != plan.fused {
                reason.push_str(&format!("; fused {} -> {}", plan.fused, v));
                plan.fused = v;
            }
        }
        if let Some(r) = &self.head_start {
            let v = r.value(&m).resolve(plan.head_start);
            if v != plan.head_start {
                reason.push_str(&format!("; head-start {} -> {}", plan.head_start, v));
                plan.head_start = v;
            }
        }
        if let Some(r) = &self.shape {
            let v = r.value(&m).resolve(plan.shape);
            if v != plan.shape {
                reason.push_str(&format!("; shape {} -> {}", plan.shape.name(), v.name()));
                plan.shape = v;
            }
        }
        PlanDecision {
            kind: plan.kind(),
            plan,
            metrics: m,
            reason,
        }
    }

    /// Serialize to the byte-stable artifact format: a version header,
    /// the threshold scale, then one `rule <axis> <feature> <cutoff>
    /// <below> <at-or-above>` line per set axis, in fixed axis order
    /// (the same axis names [`HeuristicModel::parse`] matches on).
    pub fn to_text(&self) -> String {
        fn rule_line<V: Copy>(
            out: &mut String,
            axis: &str,
            rule: &Option<Rule<V>>,
            enc: impl Fn(V) -> String,
        ) {
            if let Some(r) = rule {
                out.push_str(&format!(
                    "rule {axis} {} {} {} {}\n",
                    r.feature.name(),
                    r.cutoff,
                    enc(r.below),
                    enc(r.at_or_above),
                ));
            }
        }
        let mut out = String::from("ficco-heuristic-model v1\n");
        out.push_str(&format!("threshold-scale {}\n", self.threshold_scale));
        rule_line(&mut out, "pieces", &self.pieces, CountVal::encode);
        rule_line(&mut out, "slots", &self.slots, CountVal::encode);
        rule_line(&mut out, "fused", &self.fused, |v| v.encode().to_string());
        rule_line(&mut out, "head-start", &self.head_start, |v| v.encode().to_string());
        rule_line(&mut out, "shape", &self.shape, |v| v.encode().to_string());
        out
    }

    /// Parse an artifact produced by [`HeuristicModel::to_text`]
    /// (blank lines and `#` comments tolerated).
    pub fn parse(text: &str) -> Result<HeuristicModel, String> {
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        let header = lines.next().ok_or("empty model file")?;
        if header != "ficco-heuristic-model v1" {
            return Err(format!("bad model header '{header}'"));
        }
        let mut model = HeuristicModel::default();
        let mut saw_threshold = false;
        for line in lines {
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts.as_slice() {
                ["threshold-scale", v] => {
                    model.threshold_scale = v
                        .parse()
                        .map_err(|_| format!("bad threshold-scale '{v}'"))?;
                    if !(model.threshold_scale.is_finite() && model.threshold_scale > 0.0) {
                        return Err(format!("threshold-scale must be positive, got '{v}'"));
                    }
                    saw_threshold = true;
                }
                ["rule", axis, feat, cutoff, below, above] => {
                    let feature =
                        Feature::parse(feat).ok_or_else(|| format!("unknown feature '{feat}'"))?;
                    let raw = cutoff;
                    let cutoff: f64 = cutoff
                        .parse()
                        .map_err(|_| format!("bad rule cutoff '{raw}'"))?;
                    // A NaN/inf cutoff would make the rule silently
                    // never (or always) fire — reject it like the
                    // threshold-scale line above.
                    if !cutoff.is_finite() {
                        return Err(format!("rule cutoff must be finite, got '{raw}'"));
                    }
                    match *axis {
                        "pieces" => {
                            model.pieces = Some(Rule {
                                feature,
                                cutoff,
                                below: CountVal::decode(below)?,
                                at_or_above: CountVal::decode(above)?,
                            })
                        }
                        "slots" => {
                            model.slots = Some(Rule {
                                feature,
                                cutoff,
                                below: CountVal::decode(below)?,
                                at_or_above: CountVal::decode(above)?,
                            })
                        }
                        "fused" => {
                            model.fused = Some(Rule {
                                feature,
                                cutoff,
                                below: FlagVal::decode(below)?,
                                at_or_above: FlagVal::decode(above)?,
                            })
                        }
                        "head-start" => {
                            model.head_start = Some(Rule {
                                feature,
                                cutoff,
                                below: FlagVal::decode(below)?,
                                at_or_above: FlagVal::decode(above)?,
                            })
                        }
                        "shape" => {
                            model.shape = Some(Rule {
                                feature,
                                cutoff,
                                below: ShapeVal::decode(below)?,
                                at_or_above: ShapeVal::decode(above)?,
                            })
                        }
                        other => return Err(format!("unknown rule axis '{other}'")),
                    }
                }
                _ => return Err(format!("unparseable model line '{line}'")),
            }
        }
        if !saw_threshold {
            return Err("model missing threshold-scale".into());
        }
        Ok(model)
    }

    /// Write the artifact to `path` (write-temp-then-rename, so an
    /// interrupted calibrate never leaves a truncated model).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        crate::util::atomic::write(path, self.to_text())
    }

    /// Load and parse an artifact from `path`.
    pub fn load(path: &str) -> Result<HeuristicModel, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading model {path}: {e}"))?;
        HeuristicModel::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn machine() -> Machine {
        Machine::mi300x_8()
    }

    fn full_model() -> HeuristicModel {
        HeuristicModel {
            threshold_scale: 2.5,
            pieces: Some(Rule {
                feature: Feature::Combined,
                cutoff: 5.0,
                below: CountVal::Keep,
                at_or_above: CountVal::TwiceGpus,
            }),
            slots: Some(Rule {
                feature: Feature::Imbalance,
                cutoff: 1.25,
                below: CountVal::FullMesh,
                at_or_above: CountVal::Const(2),
            }),
            fused: Some(Rule {
                feature: Feature::NormMt,
                cutoff: 1.0,
                below: FlagVal::Keep,
                at_or_above: FlagVal::Set(false),
            }),
            head_start: Some(Rule {
                feature: Feature::HotShare,
                cutoff: 0.3,
                below: FlagVal::Keep,
                at_or_above: FlagVal::Set(true),
            }),
            shape: Some(Rule {
                feature: Feature::NormOtb,
                cutoff: 0.5,
                below: ShapeVal::Set(CommShape::Row),
                at_or_above: ShapeVal::Keep,
            }),
        }
    }

    #[test]
    fn default_model_is_the_frozen_rule_lifted_to_plan_space() {
        let m = machine();
        let model = HeuristicModel::default();
        assert!(model.is_default());
        for row in workloads::table1() {
            let sc = row.scenario();
            let legacy = super::super::pick(&m, &sc);
            let d = model.predict(&m, &sc);
            assert_eq!(d.kind, legacy.pick, "{}", row.name);
            assert_eq!(d.plan, Plan::preset(legacy.pick, &sc), "{}", row.name);
            assert_eq!(d.reason, legacy.reason, "{}", row.name);
        }
    }

    #[test]
    fn threshold_scale_moves_the_kind_decision() {
        let m = machine();
        let sc = workloads::by_name("g2").unwrap();
        let low = HeuristicModel {
            threshold_scale: 1e-9,
            ..HeuristicModel::default()
        };
        let high = HeuristicModel {
            threshold_scale: 1e9,
            ..HeuristicModel::default()
        };
        assert_eq!(low.predict(&m, &sc).kind, Kind::HeteroUnfused1D);
        assert_eq!(high.predict(&m, &sc).kind, Kind::UniformFused1D);
    }

    #[test]
    fn axis_rules_fire_on_their_feature_side() {
        let m = machine();
        // g2 is a 1D pick with a large combined metric on mi300x-8.
        let sc = workloads::by_name("g2").unwrap();
        let base = HeuristicModel::default().predict(&m, &sc);
        let model = HeuristicModel {
            pieces: Some(Rule {
                feature: Feature::Combined,
                cutoff: 0.0, // always at-or-above
                below: CountVal::Keep,
                at_or_above: CountVal::TwiceGpus,
            }),
            ..HeuristicModel::default()
        };
        let d = model.predict(&m, &sc);
        assert_eq!(d.plan.pieces, 2 * sc.ngpus);
        assert_ne!(d.plan, base.plan);
        assert!(d.reason.contains("pieces"), "{}", d.reason);
        assert!(d.plan.check(sc.ngpus).is_ok());
        // The other side of the cutoff keeps the preset.
        let keep = HeuristicModel {
            pieces: Some(Rule {
                feature: Feature::Combined,
                cutoff: f64::INFINITY,
                below: CountVal::Keep,
                at_or_above: CountVal::TwiceGpus,
            }),
            ..HeuristicModel::default()
        };
        assert_eq!(keep.predict(&m, &sc).plan, base.plan);
    }

    #[test]
    fn resolved_counts_are_clamped_to_validity() {
        let m = machine();
        let sc = workloads::by_name("g2").unwrap();
        let model = HeuristicModel {
            slots: Some(Rule {
                feature: Feature::Combined,
                cutoff: 0.0,
                below: CountVal::Const(100),
                at_or_above: CountVal::Const(100),
            }),
            pieces: Some(Rule {
                feature: Feature::Combined,
                cutoff: 0.0,
                below: CountVal::Const(100_000),
                at_or_above: CountVal::Const(100_000),
            }),
            ..HeuristicModel::default()
        };
        let d = model.predict(&m, &sc);
        assert_eq!(d.plan.slots, sc.ngpus - 1, "slots clamp to the mesh width");
        assert_eq!(d.plan.pieces, Plan::MAX_PIECES, "pieces clamp to the cap");
        assert!(d.plan.check(sc.ngpus).is_ok());
    }

    #[test]
    fn skew_features_can_drive_the_prediction() {
        let m = machine();
        let uniform = Scenario::new("u", 65536, 1024, 4096);
        let skewed = uniform.clone().with_skew(1.0, 3);
        let model = HeuristicModel {
            slots: Some(Rule {
                feature: Feature::Imbalance,
                cutoff: 1.2,
                below: CountVal::Keep,
                at_or_above: CountVal::Const(1),
            }),
            ..HeuristicModel::default()
        };
        let du = model.predict(&m, &uniform);
        let ds = model.predict(&m, &skewed);
        assert_eq!(
            du.plan,
            Plan::preset(super::super::pick(&m, &uniform).pick, &uniform),
            "balanced routing keeps the preset"
        );
        assert_eq!(ds.plan.slots, 1, "hot-expert routing narrows the slots");
    }

    #[test]
    fn artifact_round_trips_byte_stably() {
        for model in [HeuristicModel::default(), full_model()] {
            let text = model.to_text();
            let back = HeuristicModel::parse(&text).expect("parse own artifact");
            assert_eq!(back, model);
            assert_eq!(back.to_text(), text, "re-serialization is byte-identical");
        }
        assert!(full_model().to_text().starts_with("ficco-heuristic-model v1\n"));
    }

    #[test]
    fn parse_rejects_malformed_artifacts() {
        assert!(HeuristicModel::parse("").is_err());
        assert!(HeuristicModel::parse("wrong header\nthreshold-scale 1\n").is_err());
        assert!(HeuristicModel::parse("ficco-heuristic-model v1\n").is_err(), "missing threshold");
        assert!(
            HeuristicModel::parse("ficco-heuristic-model v1\nthreshold-scale -2\n").is_err(),
            "non-positive threshold"
        );
        assert!(HeuristicModel::parse(
            "ficco-heuristic-model v1\nthreshold-scale 1\nrule pieces bogus 1 keep gpus\n"
        )
        .is_err());
        assert!(
            HeuristicModel::parse(
                "ficco-heuristic-model v1\nthreshold-scale 1\nrule pieces combined nan keep gpus\n"
            )
            .is_err(),
            "NaN cutoff must be rejected, not silently never fire"
        );
        assert!(HeuristicModel::parse(
            "ficco-heuristic-model v1\nthreshold-scale 1\nrule pieces combined inf keep gpus\n"
        )
        .is_err());
        assert!(HeuristicModel::parse(
            "ficco-heuristic-model v1\nthreshold-scale 1\nrule warp combined 1 keep gpus\n"
        )
        .is_err());
        assert!(HeuristicModel::parse(
            "ficco-heuristic-model v1\nthreshold-scale 1\nnonsense line\n"
        )
        .is_err());
        // Comments and blank lines are tolerated.
        let ok = HeuristicModel::parse(
            "ficco-heuristic-model v1\n# a comment\n\nthreshold-scale 2\n",
        )
        .unwrap();
        assert_eq!(ok.threshold_scale, 2.0);
    }
}
