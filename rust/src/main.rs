//! `ficco` — the FiCCO coordinator CLI.
//!
//! Subcommands:
//!   workloads                       list the Table I scenario suite
//!   simulate   --scenario g5 ...    run all schedules for one scenario
//!   sweep      [--jobs N] ...       parallel design-space sweep over
//!                                   scenario x schedule x machine x
//!                                   mech x GPU count, with
//!                                   deterministic CSV/JSON output
//!                                   (filters: --scenarios --kinds
//!                                   --machines --mechs --gpus --skew;
//!                                   --skew-seed fixes the hot-expert
//!                                   order; --out-dir results/sweep;
//!                                   --search off|exhaustive|beam:N
//!                                   fills the best-plan columns and
//!                                   --warm on|off picks the search
//!                                   order;
//!                                   switches: --verbose prints
//!                                   per-cell progress, --csv also
//!                                   writes <out-dir>/summary.csv)
//!   tune       [--beam N] ...       search the parameterized plan
//!                                   space per (machine x mech x GPU
//!                                   count x scenario) cell: legacy
//!                                   presets seed the search, beam or
//!                                   exhaustive (--beam 0) expansion,
//!                                   lower-bound pruning, warm-started
//!                                   bound-ordered visits (--warm
//!                                   on|off, bit-identical results
//!                                   either way), deterministic
//!                                   CSV/JSON artifacts (filters:
//!                                   --scenarios --machines --mechs
//!                                   --gpus --skew; space: --pieces
//!                                   --slots; --jobs, --out-dir
//!                                   results/tune, --verbose, --csv;
//!                                   --trace-out FILE writes a Perfetto
//!                                   trace of the first cell's best
//!                                   plan; --stats prints the search
//!                                   telemetry table)
//!   trace      --scenario g6 ...    flight-recorder export of one
//!                                   simulated cell: Chrome/Perfetto
//!                                   trace.json (loadable in
//!                                   ui.perfetto.dev) + timeline.csv
//!                                   under --out-dir results/trace
//!                                   (--machine preset, --mech --skew
//!                                   --skew-seed; --plan ID traces
//!                                   that exact plan, otherwise the
//!                                   plan space is searched first:
//!                                   --beam --warm --pieces --slots
//!                                   --jobs; --stats prints search
//!                                   telemetry)
//!   heuristic  [--all|--scenario g] show heuristic decisions
//!                                   (--threshold S scales the Fig-12a
//!                                   threshold; --model FILE predicts
//!                                   through a calibrated plan model)
//!   characterize --what dil|comm-dil|cil
//!   figures    [--out-dir results]  regenerate every paper exhibit
//!   synth      --count 16 --seed 7  heuristic accuracy on synthetic
//!                                   suite (--against plans scores the
//!                                   heuristic against the searched
//!                                   plan-space optimum; --model FILE
//!                                   scores a calibrated model
//!                                   plan-level)
//!   calibrate  [--scenarios ...]    fit the plan-space heuristic
//!                                   model against tune-searched
//!                                   optima (--holdout suite gates the
//!                                   fit; byte-stable artifact via
//!                                   --out results/model.ficco)
//!   cotenant   [--tenants N] ...    multi-job co-tenancy study: admit
//!                                   N schedule instances of each cell
//!                                   at staggered offsets into ONE
//!                                   shared simulated machine and
//!                                   report per-job makespan and
//!                                   slowdown vs isolated (filters as
//!                                   sweep: --scenarios --kinds
//!                                   --machines --mechs --gpus --skew
//!                                   --skew-seed; --stagger F spaces
//!                                   admissions at F x tenant 0's
//!                                   isolated makespan; --model runs
//!                                   the calibrated pick per tenant;
//!                                   --robust p95:N|worst:N adds
//!                                   perturbation-ensemble span
//!                                   statistics; --trace-out FILE
//!                                   writes a Perfetto trace of the
//!                                   first cell's co-tenant timeline;
//!                                   --jobs, --out-dir
//!                                   results/cotenant, --verbose,
//!                                   --csv, --stats, --quiet)
//!   validate   [--artifacts DIR]    numeric equivalence of all schedules
//!                                   (real data through PJRT)
//!   train      [--preset NAME]      end-to-end training driver
//!                                   (--steps --seed --artifacts
//!                                   --log-every --loss-csv,
//!                                   --no-overlap-report)
//!
//! Global flags (single-scenario subcommands): --config FILE (machine
//! preset), --gpus N, --mech dma|rccl. `sweep`/`tune` instead take the
//! list filters above (--machines/--mechs/--gpus accept comma lists).
//! Machine presets for sweeps: mi300x-8, h100-dgx-8, pcie-gen4-4, switch-8.
//! Progress and diagnostics go to stderr; stdout carries the
//! machine-readable output (tables, --stats telemetry), and --quiet
//! silences the stderr chatter (sweep/tune/trace/figures/simulate).
//! `simulate --trace-out FILE` writes a Perfetto trace of the
//! heuristic pick's preset plan.
//! Every subcommand is strict: unknown options, inapplicable switches
//! and stray positionals are errors, not silently ignored
//! (see `cli::subcommand_spec`).

use ficco::cli::Args;
use ficco::hw::Machine;
use ficco::schedule::{exec::ScenarioEval, Kind, Scenario};
use ficco::sim::CommMech;
use ficco::util::table::{f, x, Align, Table};
use ficco::workloads;

/// Progress/diagnostic line: stderr (stdout stays machine-readable),
/// suppressed by `--quiet`.
macro_rules! progress {
    ($($arg:tt)*) => {
        if !ficco::util::quiet() {
            eprintln!($($arg)*);
        }
    };
}

fn main() {
    let args = match Args::from_env(ficco::cli::KNOWN_SWITCHES) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn machine_from(args: &Args) -> Result<Machine, Box<dyn std::error::Error>> {
    let mut m = match args.get("config") {
        Some(path) => {
            let doc = ficco::config::Doc::load(path)?;
            Machine::from_config(&doc)?
        }
        None => Machine::mi300x_8(),
    };
    if let Some(g) = args.get("gpus") {
        m.topo.ngpus = g.parse()?;
    }
    // Schedules need at least two ranks (there is nothing to overlap
    // on one GPU); catching it here turns a would-be panic deep in
    // plan lowering into a clean CLI error for every subcommand.
    if m.topo.ngpus < 2 {
        return Err(format!("--gpus must be >= 2, got {}", m.topo.ngpus).into());
    }
    Ok(m)
}

fn scenario_from(args: &Args, machine: &Machine) -> Result<Scenario, Box<dyn std::error::Error>> {
    let mut sc = match args.get("scenario") {
        Some(name) => workloads::by_name(name)
            .ok_or_else(|| format!("unknown scenario '{name}' (try g1..g16)"))?,
        None => {
            let m = args.get_u64("m", 131072)?;
            let n = args.get_u64("n", 16384)?;
            let k = args.get_u64("k", 16384)?;
            Scenario::new(format!("custom-{m}x{n}x{k}"), m, n, k)
        }
    };
    sc.ngpus = machine.topo.ngpus;
    if let Some(mech) = args.get("mech") {
        sc.mech = CommMech::parse(mech).ok_or_else(|| format!("unknown --mech '{mech}'"))?;
    }
    let skew = args.get_f64("skew", 0.0)?;
    if !skew.is_finite() || skew < 0.0 {
        return Err(format!("--skew must be finite and >= 0, got {skew}").into());
    }
    if skew > 0.0 {
        sc = sc.with_skew(skew, args.get_u64("skew-seed", ficco::explore::DEFAULT_SKEW_SEED)?);
    }
    Ok(sc)
}

fn run(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    // Strict CLI contract: a typo'd flag must fail loudly on every
    // subcommand instead of silently running with defaults.
    ficco::cli::validate_strict(args)?;
    ficco::util::set_quiet(args.has("quiet"));
    match args.subcommand.as_deref() {
        Some("workloads") => cmd_workloads(),
        Some("simulate") => cmd_simulate(args),
        Some("sweep") => cmd_sweep(args),
        Some("tune") => cmd_tune(args),
        Some("trace") => cmd_trace(args),
        Some("heuristic") => cmd_heuristic(args),
        Some("characterize") => cmd_characterize(args),
        Some("figures") => cmd_figures(args),
        Some("synth") => cmd_synth(args),
        Some("calibrate") => cmd_calibrate(args),
        Some("validate") => cmd_validate(args),
        Some("train") => cmd_train(args),
        Some("cotenant") => cmd_cotenant(args),
        Some(other) => Err(format!("unknown subcommand '{other}'").into()),
        None => {
            println!("ficco {} — FiCCO: finer-grain compute-communication overlap", ficco::version());
            println!("subcommands: {}", ficco::cli::SUBCOMMANDS.join(" "));
            Ok(())
        }
    }
}

/// Load the calibrated heuristic model named by `--model`, when given.
fn model_opt_from(
    args: &Args,
) -> Result<Option<ficco::heuristics::model::HeuristicModel>, Box<dyn std::error::Error>> {
    match args.get("model") {
        Some(path) => Ok(Some(ficco::heuristics::model::HeuristicModel::load(path)?)),
        None => Ok(None),
    }
}

fn cmd_workloads() -> Result<(), Box<dyn std::error::Error>> {
    let mut t = Table::new(vec!["name", "parallelism", "model", "M", "N", "K", "OTB", "MT GiB"])
        .align(0, Align::Left)
        .align(1, Align::Left)
        .align(2, Align::Left);
    for r in workloads::table1() {
        let g = ficco::cost::GemmShape::new(r.m, r.n, r.k);
        t.row(vec![
            r.name.to_string(),
            r.parallelism.name().to_string(),
            r.model.to_string(),
            r.m.to_string(),
            r.n.to_string(),
            r.k.to_string(),
            f(g.otb(), 0),
            f(g.mt() / (1u64 << 30) as f64, 1),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let machine = machine_from(args)?;
    let sc = scenario_from(args, &machine)?;
    println!(
        "scenario {}: GEMM ({}, {}, {}), {} over {} GPUs, {} comm{}",
        sc.name, sc.gemm.m, sc.gemm.n, sc.gemm.k, sc.collective.name(), sc.ngpus,
        sc.mech.name(),
        if sc.skew > 0.0 {
            format!(
                ", skew {} (imbalance {})",
                sc.skew,
                x(sc.partition(1).imbalance())
            )
        } else {
            String::new()
        },
    );
    let ev = ScenarioEval::run(&machine, &sc, &Kind::ALL);
    let mut t = Table::new(vec![
        "schedule", "makespan", "speedup", "gemm leg", "comm leg", "gemm CIL", "comm CIL", "tasks",
    ])
    .align(0, Align::Left);
    for r in &ev.results {
        t.row(vec![
            r.kind.name().to_string(),
            ficco::util::human_time(r.makespan),
            x(ev.speedup(r.kind)),
            ficco::util::human_time(r.gemm_leg),
            ficco::util::human_time(r.comm_leg),
            x(r.gemm_cil),
            x(r.comm_cil),
            r.n_tasks.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("ideal overlap bound: {}", x(ev.ideal_speedup()));
    let d = ficco::heuristics::pick(&machine, &sc);
    println!("heuristic pick: {} ({})", d.pick.name(), d.reason);
    match ev.best_ficco() {
        Some((oracle, s)) => println!("oracle best:    {} ({})", oracle.name(), x(s)),
        None => println!("oracle best:    n/a (no FiCCO schedule evaluated)"),
    }
    if sc.skew > 0.0 {
        // Closed-form CIL under the *skewed* all-to-all: each GPU's
        // comm pressure is the sum of the rates its active peer lanes
        // sustain at their actual (imbalanced) transfer sizes, and
        // each receiver's overlapped GEMM covers its own (skewed)
        // shard rows. Report the receiver with the worst GEMM CIL
        // (with its comm CIL alongside).
        let part = sc.partition(1);
        let per_gpu = sc.shard_bytes_per_gpu();
        let mut worst = (1.0f64, 1.0f64);
        for r in 0..sc.ngpus {
            let rows = part.shard_len(r);
            if rows == 0 {
                continue;
            }
            let shard_gemm = ficco::cost::GemmShape { m: rows, ..sc.gemm };
            let peers: Vec<f64> = (0..sc.ngpus)
                .filter(|&q| q != r)
                .map(|q| per_gpu[q])
                .collect();
            let (g_cil, c_cil) = ficco::cost::contention::gemm_cil_under_a2a_vec(
                &machine.gpu,
                &machine.topo,
                &shard_gemm,
                sc.mech,
                &peers,
            );
            if g_cil > worst.0 {
                worst = (g_cil, c_cil);
            }
        }
        println!(
            "closed-form CIL under skewed all-to-all (worst receiver by GEMM CIL): gemm {} comm {}",
            x(worst.0),
            x(worst.1)
        );
    }
    // `--trace-out FILE`: flight-recorder export of the heuristic
    // pick's preset plan for this scenario.
    if let Some(path) = args.get("trace-out") {
        let plan = ficco::plan::Plan::preset(d.pick, &sc);
        write_trace(&machine, args.get_or("config", "mi300x-8"), &sc, &plan, path)?;
    }
    Ok(())
}

/// `ficco sweep`: evaluate the scenario × schedule × machine ×
/// mechanism × GPU-count design space on a worker pool, streaming
/// deterministic CSV/JSON to `--out-dir` and printing a geomean
/// summary per machine. Defaults cover the full Table I suite on
/// every machine preset with both mechanisms. Switches: `--verbose`
/// prints per-cell progress with timings; `--csv` also writes the
/// summary exhibit to `<out-dir>/summary.csv`.
fn cmd_sweep(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let mut spec = ficco::explore::SweepSpec::from_filters(
        args.get_or("scenarios", "table1"),
        args.get_or("kinds", "all"),
        args.get_or("machines", "all"),
        args.get_or("mechs", "dma,rccl"),
        args.get_or("gpus", "native"),
        args.get_or("skew", "0"),
    )?;
    spec.skew_seed = args.get_u64("skew-seed", ficco::explore::DEFAULT_SKEW_SEED)?;
    let robust = parse_robust(args)?;
    spec.search = parse_search(args.get_or("search", "off"))?;
    match spec.search.as_mut() {
        Some(cfg) => {
            cfg.warm = parse_warm(args)?;
            cfg.robust = robust;
        }
        None if robust.is_some() => {
            return Err(
                "--robust requires --search (robust selection re-ranks searched plans)".into(),
            )
        }
        None => {}
    }
    spec.model = model_opt_from(args)?;
    let out_dir = args.get_or("out-dir", "results/sweep");
    std::fs::create_dir_all(out_dir)?;
    let csv_path = format!("{out_dir}/sweep.csv");
    let json_path = format!("{out_dir}/sweep.json");
    let journal_path = format!("{out_dir}/sweep.journal");

    // `--resume`: replay the journal's complete prefix, keeping only
    // records that still match the spec's cell identity (a changed
    // filter re-runs the mismatched cells instead of trusting stale
    // results).
    let cells = spec.cells();
    let mut done: Vec<ficco::explore::CellResult> = Vec::new();
    if args.has("resume") {
        for e in ficco::util::journal::read(&journal_path) {
            let Some(r) = ficco::explore::emit::parse_cell_record(&e.payload) else {
                continue;
            };
            let Some(cell) = cells.get(r.index) else { continue };
            if r.index != e.index
                || r.scenario != cell.scenario.name
                || r.machine_name != cell.machine_name
                || r.mech != cell.scenario.mech.name()
                || r.ngpus != cell.scenario.ngpus
                || done.iter().any(|d| d.index == r.index)
            {
                continue;
            }
            done.push(r);
        }
    }
    let done_idx: std::collections::HashSet<usize> = done.iter().map(|r| r.index).collect();
    let todo: Vec<ficco::explore::Cell> = cells
        .into_iter()
        .filter(|c| !done_idx.contains(&c.index))
        .collect();
    let jobs = ficco::explore::clamp_jobs(args.get_jobs("jobs")?, todo.len());

    progress!(
        "sweep: {} cells / {} schedule points on {} worker thread{}{}",
        spec.n_cells(),
        spec.n_points(),
        jobs,
        if jobs == 1 { "" } else { "s" },
        if done.is_empty() {
            String::new()
        } else {
            format!(" ({} journaled cells resumed)", done.len())
        },
    );

    let mut journal = if args.has("resume") {
        ficco::util::journal::Journal::append(&journal_path)?
    } else {
        ficco::util::journal::Journal::create(&journal_path)?
    };
    let verbose = args.has("verbose");
    // Journal I/O failures (e.g. ENOSPC) cancel the sweep — no point
    // evaluating cells whose results cannot be recorded — and are
    // reported through the normal CLI error path.
    let mut write_err: Option<std::io::Error> = None;
    let report = ficco::explore::run_cells(&todo, jobs, |c| {
        if let Err(e) = journal.record(c.index, &ficco::explore::emit::cell_record(c)) {
            write_err = Some(e);
            return false;
        }
        if verbose {
            let best = c
                .rows
                .iter()
                .map(|r| r.speedup)
                .fold(f64::NEG_INFINITY, f64::max);
            progress!(
                "  [{:>4}] {:<8} {:<12} {:<5} {}g: best {} pick {} ({})",
                c.index,
                c.scenario,
                c.machine_name,
                c.mech,
                c.ngpus,
                x(best),
                c.pick.name(),
                ficco::util::human_time(c.eval_seconds),
            );
        }
        true
    });
    if let Some(e) = write_err {
        return Err(format!("writing sweep journal under {out_dir}: {e}").into());
    }
    // A panicked cell is a per-cell failure, not a wasted run: the
    // other cells finished and are journaled, so a `--resume` after
    // the fix re-evaluates only the failed ones. No artifact is
    // emitted (it would silently miss rows) and the exit is nonzero.
    if !report.failures.is_empty() {
        for f in &report.failures {
            eprintln!("sweep: cell {} failed: {}", f.index, f.message);
        }
        return Err(format!(
            "{} of {} cells failed; completed cells are journaled — rerun with --resume",
            report.failures.len(),
            spec.n_cells(),
        )
        .into());
    }

    let mut all = done;
    all.extend(report.cells);
    all.sort_by_key(|c| c.index);

    // Artifacts are written whole, write-temp-then-rename: a kill
    // mid-emit leaves the previous complete artifact (or none), never
    // a truncated one.
    let mut csv = ficco::explore::emit::CsvEmitter::new(ficco::util::atomic::AtomicFile::create(
        &csv_path,
    )?)?;
    let mut json = ficco::explore::emit::JsonEmitter::new(
        ficco::util::atomic::AtomicFile::create(&json_path)?,
    )?;
    for c in &all {
        csv.cell(c)?;
        json.cell(c)?;
    }
    csv.finish()?.commit()?;
    json.finish(&report.telemetry)?.commit()?;

    let exhibit = ficco::explore::emit::summary(&all);
    exhibit.print();
    if args.has("csv") {
        let summary_path = format!("{out_dir}/summary.csv");
        exhibit.write_csv(&summary_path)?;
        progress!("  -> {summary_path}");
    }
    if args.has("stats") {
        println!("== telemetry ==");
        print!("{}", report.telemetry.table().render());
    }
    let n_points: usize = all.iter().map(|c| c.rows.len()).sum();
    let cpu_seconds: f64 = all.iter().map(|c| c.eval_seconds).sum();
    progress!(
        "{} points in {:.2}s wall ({:.2}s of evaluation across {} workers, {:.1} points/s)",
        n_points,
        report.wall_seconds,
        cpu_seconds,
        report.jobs,
        n_points as f64 / report.wall_seconds.max(1e-9),
    );
    progress!("  -> {csv_path}");
    progress!("  -> {json_path}");
    Ok(())
}

/// Parse `--search off|exhaustive|beam:N` into a search config.
fn parse_search(s: &str) -> Result<Option<ficco::search::SearchCfg>, Box<dyn std::error::Error>> {
    match s {
        "off" => Ok(None),
        "exhaustive" => Ok(Some(ficco::search::SearchCfg::default())),
        other => match other.strip_prefix("beam:") {
            Some(b) => {
                let beam: usize = b
                    .parse()
                    .map_err(|_| format!("bad beam width in --search '{other}'"))?;
                if beam == 0 {
                    return Err("--search beam:N needs N >= 1 (use 'exhaustive' for 0)".into());
                }
                Ok(Some(ficco::search::SearchCfg {
                    beam,
                    ..Default::default()
                }))
            }
            None => Err(format!("unknown --search '{other}' (off|exhaustive|beam:N)").into()),
        },
    }
}

/// Parse `--warm on|off` (default on): warm-started, incumbent-
/// ordered plan search vs the cold enumeration-order reference. Both
/// report bit-identical plans/makespans; `off` exists for the
/// determinism cross-check and for measuring the ordering's effect.
fn parse_warm(args: &Args) -> Result<bool, Box<dyn std::error::Error>> {
    match args.get_or("warm", "on") {
        "on" => Ok(true),
        "off" => Ok(false),
        other => Err(format!("unknown --warm '{other}' (on|off)").into()),
    }
}

/// Parse `--robust off|p95:N|worst:N` plus its companions
/// `--robust-seed SEED` and `--robust-mag M` / `--robust-mag C,B,S`
/// (compute straggler, bandwidth degradation, setup inflation
/// fractions) into a robust-selection config. `off` (the default)
/// returns `None` and keeps every artifact byte-identical to the
/// nominal path.
fn parse_robust(
    args: &Args,
) -> Result<Option<ficco::search::RobustCfg>, Box<dyn std::error::Error>> {
    let spec = args.get_or("robust", "off");
    if spec == "off" {
        if args.get("robust-seed").is_some() || args.get("robust-mag").is_some() {
            return Err("--robust-seed/--robust-mag require --robust p95:N or worst:N".into());
        }
        return Ok(None);
    }
    let (obj, n) = spec
        .split_once(':')
        .ok_or_else(|| format!("unknown --robust '{spec}' (off|p95:N|worst:N)"))?;
    let objective = ficco::search::RobustObjective::parse(obj)
        .ok_or_else(|| format!("unknown --robust objective '{obj}' (p95|worst)"))?;
    let samples: usize = n
        .parse()
        .map_err(|_| format!("bad ensemble size in --robust '{spec}'"))?;
    if samples == 0 {
        return Err("--robust needs an ensemble of at least 1 sample".into());
    }
    let seed = args.get_u64("robust-seed", ficco::hw::Perturbation::DEFAULT_SEED)?;
    let mut ensemble = ficco::hw::Perturbation::defaults(samples, seed);
    if let Some(mag) = args.get("robust-mag") {
        let parts = parse_f64_list("robust-mag", mag)?;
        match parts[..] {
            [all] => {
                ensemble.compute = all;
                ensemble.bandwidth = all;
                ensemble.setup = all;
            }
            [compute, bandwidth, setup] => {
                ensemble.compute = compute;
                ensemble.bandwidth = bandwidth;
                ensemble.setup = setup;
            }
            _ => {
                return Err(
                    "--robust-mag takes one fraction or three (compute,bandwidth,setup)".into(),
                )
            }
        }
    }
    ensemble.check()?;
    Ok(Some(ficco::search::RobustCfg {
        objective,
        top_k: ficco::search::RobustCfg::DEFAULT_TOP_K,
        ensemble,
    }))
}

/// Parse a comma-separated list of numbers (e.g. `--robust-mag
/// 0.1,0.2,0.5`).
fn parse_f64_list(name: &str, s: &str) -> Result<Vec<f64>, Box<dyn std::error::Error>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        out.push(
            part.parse::<f64>()
                .map_err(|_| format!("--{name}: expected number, got '{part}'"))?,
        );
    }
    if out.is_empty() {
        return Err(format!("--{name}: empty list").into());
    }
    Ok(out)
}

/// Parse a comma-separated list of positive integers (e.g. `--pieces
/// 1,2,8`).
fn parse_usize_list(name: &str, s: &str) -> Result<Vec<usize>, Box<dyn std::error::Error>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let v: usize = part
            .parse()
            .map_err(|_| format!("--{name}: expected integer, got '{part}'"))?;
        if v == 0 {
            return Err(format!("--{name}: values must be >= 1").into());
        }
        out.push(v);
    }
    if out.is_empty() {
        return Err(format!("--{name}: empty list").into());
    }
    Ok(out)
}

/// Parse the `--pieces`/`--slots` plan-space overrides (shared by
/// `tune` and `calibrate`).
fn space_overrides_from(
    args: &Args,
) -> Result<ficco::search::SpaceOverrides, Box<dyn std::error::Error>> {
    let mut ov = ficco::search::SpaceOverrides::default();
    if let Some(pieces) = args.get("pieces") {
        let pieces = parse_usize_list("pieces", pieces)?;
        if let Some(&bad) = pieces.iter().find(|&&p| p > ficco::plan::Plan::MAX_PIECES) {
            return Err(format!(
                "--pieces {bad} exceeds the decomposition cap {}",
                ficco::plan::Plan::MAX_PIECES
            )
            .into());
        }
        ov.pieces = Some(pieces);
    }
    if let Some(slots) = args.get("slots") {
        ov.slots = Some(parse_usize_list("slots", slots)?);
    }
    Ok(ov)
}

/// Reject specs whose overridden plan space is empty on any cell.
/// Out-of-range values for *some* machines are filtered per cell
/// (e.g. --slots 7 is valid on an 8-GPU mesh but not a 4-GPU box); a
/// space left empty on any swept cell would silently "search" nothing
/// there, so reject it up front like any other bad filter.
fn ensure_searchable_space(
    spec: &ficco::explore::SweepSpec,
    ov: &ficco::search::SpaceOverrides,
) -> Result<(), Box<dyn std::error::Error>> {
    for cell in spec.cells() {
        let space = ficco::search::space_for(&cell.scenario, ov);
        if space.plans(&cell.scenario).is_empty() {
            return Err(format!(
                "empty plan space on machine {} ({} GPUs): no --pieces/--slots value is \
                 valid there (slots must be 1..={})",
                cell.machine_name,
                cell.scenario.ngpus,
                cell.scenario.ngpus - 1
            )
            .into());
        }
    }
    Ok(())
}

/// `ficco tune`: search the parameterized plan space per (machine ×
/// mech × GPU count × scenario) cell on a worker pool, streaming
/// deterministic CSV/JSON to `--out-dir` and printing a summary per
/// machine. `--beam 0` (default) enumerates the space exhaustively
/// with lower-bound pruning — warm-started and best-bound-first by
/// default, `--warm off` for the cold enumeration-order reference
/// (bit-identical plans/makespans; only the evaluated/pruned effort
/// split differs); `--beam N` runs a beam local search
/// seeded by the six legacy presets. `--pieces`/`--slots` override the
/// default space axes.
fn cmd_tune(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let mut spec = ficco::explore::SweepSpec::from_filters(
        args.get_or("scenarios", "table1"),
        "all", // kinds are irrelevant to tune; presets are always searched
        args.get_or("machines", "all"),
        args.get_or("mechs", "dma"),
        args.get_or("gpus", "native"),
        args.get_or("skew", "0"),
    )?;
    spec.skew_seed = args.get_u64("skew-seed", ficco::explore::DEFAULT_SKEW_SEED)?;
    spec.model = model_opt_from(args)?;
    let cfg = ficco::search::SearchCfg {
        beam: args.get_usize("beam", 0)?,
        warm: parse_warm(args)?,
        robust: parse_robust(args)?,
        ..Default::default()
    };
    let ov = space_overrides_from(args)?;
    ensure_searchable_space(&spec, &ov)?;
    let out_dir = args.get_or("out-dir", "results/tune");
    std::fs::create_dir_all(out_dir)?;
    let csv_path = format!("{out_dir}/tune.csv");
    let json_path = format!("{out_dir}/tune.json");
    let journal_path = format!("{out_dir}/tune.journal");

    // `--resume`: replay the journal's complete prefix. A record is
    // trusted only if its cell identity still matches the spec AND its
    // robust block's presence matches this run's `--robust` — resuming
    // a nominal journal under `--robust` (or vice versa) re-runs the
    // cells instead of mixing artifact shapes.
    let cells = spec.cells();
    let mut done: Vec<ficco::search::TuneResult> = Vec::new();
    if args.has("resume") {
        for e in ficco::util::journal::read(&journal_path) {
            let Some(r) = ficco::search::emit::parse_tune_record(&e.payload) else {
                continue;
            };
            let Some(cell) = cells.get(r.index) else { continue };
            if r.index != e.index
                || r.scenario != cell.scenario.name
                || r.machine_name != cell.machine_name
                || r.mech != cell.scenario.mech.name()
                || r.ngpus != cell.scenario.ngpus
                || r.robust.is_some() != cfg.robust.is_some()
                || done.iter().any(|d| d.index == r.index)
            {
                continue;
            }
            done.push(r);
        }
    }
    let done_idx: std::collections::HashSet<usize> = done.iter().map(|r| r.index).collect();
    let todo: Vec<ficco::explore::Cell> = cells
        .into_iter()
        .filter(|c| !done_idx.contains(&c.index))
        .collect();
    let jobs = ficco::explore::clamp_jobs(args.get_jobs("jobs")?, todo.len());

    progress!(
        "tune: {} cells ({}) on {} worker thread{}{}",
        spec.n_cells(),
        if cfg.beam == 0 {
            "exhaustive + pruning".to_string()
        } else {
            format!("beam {}", cfg.beam)
        },
        jobs,
        if jobs == 1 { "" } else { "s" },
        if done.is_empty() {
            String::new()
        } else {
            format!(" ({} journaled cells resumed)", done.len())
        },
    );

    let mut journal = if args.has("resume") {
        ficco::util::journal::Journal::append(&journal_path)?
    } else {
        ficco::util::journal::Journal::create(&journal_path)?
    };
    let verbose = args.has("verbose");
    let mut write_err: Option<std::io::Error> = None;
    let report = ficco::search::tune_cells(&todo, &ov, &cfg, jobs, |r| {
        if let Err(e) = journal.record(r.index, &ficco::search::emit::tune_record(r)) {
            write_err = Some(e);
            return false;
        }
        if verbose {
            progress!(
                "  [{:>4}] {:<8} {:<12} {:<5} best {} ({}) gain {} over {} ({})",
                r.index,
                r.scenario,
                r.machine_name,
                r.mech,
                r.best_plan,
                x(r.best_speedup),
                x(r.plan_gain),
                r.best_legacy_kind.name(),
                ficco::util::human_time(r.eval_seconds),
            );
        }
        true
    });
    if let Some(e) = write_err {
        return Err(format!("writing tune journal under {out_dir}: {e}").into());
    }
    // Panicked cells: report each, keep the journal (a `--resume`
    // re-runs only the failures), emit no artifact, exit nonzero.
    if !report.failures.is_empty() {
        for f in &report.failures {
            eprintln!("tune: cell {} failed: {}", f.index, f.message);
        }
        return Err(format!(
            "{} of {} cells failed; completed cells are journaled — rerun with --resume",
            report.failures.len(),
            spec.n_cells(),
        )
        .into());
    }

    let mut all = done;
    all.extend(report.results);
    all.sort_by_key(|r| r.index);

    // Whole-file, write-temp-then-rename artifacts: a kill mid-emit
    // never leaves a truncated tune.csv/tune.json.
    let mut csv = ficco::search::emit::TuneCsvEmitter::with_robust(
        ficco::util::atomic::AtomicFile::create(&csv_path)?,
        cfg.robust.is_some(),
    )?;
    let mut json = ficco::search::emit::TuneJsonEmitter::new(
        ficco::util::atomic::AtomicFile::create(&json_path)?,
    )?;
    for r in &all {
        csv.result(r)?;
        json.result(r)?;
    }
    csv.finish()?.commit()?;
    json.finish(&report.telemetry)?.commit()?;

    let exhibit = ficco::search::emit::summary(&all);
    exhibit.print();
    if args.has("csv") {
        let summary_path = format!("{out_dir}/summary.csv");
        exhibit.write_csv(&summary_path)?;
        progress!("  -> {summary_path}");
    }
    if args.has("stats") {
        println!("== telemetry ==");
        print!("{}", report.telemetry.table().render());
    }
    // `--trace-out FILE`: flight-recorder export of the first cell's
    // searched-best plan (the same plan tune just reported).
    if let Some(path) = args.get("trace-out") {
        match (spec.cells().first(), all.first()) {
            (Some(cell), Some(best)) => {
                let plan = ficco::plan::Plan::parse_id(&best.best_plan)
                    .ok_or_else(|| format!("searched plan id '{}' did not parse", best.best_plan))?;
                write_trace(&cell.machine, &cell.machine_name, &cell.scenario, &plan, path)?;
            }
            _ => return Err("--trace-out: tune produced no cells to trace".into()),
        }
    }
    let evaluations: usize = all.iter().map(|r| r.evaluated).sum();
    let pruned: usize = all.iter().map(|r| r.pruned).sum();
    let cpu_seconds: f64 = all.iter().map(|r| r.eval_seconds).sum();
    progress!(
        "{} plan evaluations ({} pruned) across {} cells in {:.2}s wall ({:.2}s of search on {} workers)",
        evaluations,
        pruned,
        all.len(),
        report.wall_seconds,
        cpu_seconds,
        report.jobs,
    );
    progress!("  -> {csv_path}");
    progress!("  -> {json_path}");
    Ok(())
}

/// Trace header metadata: run identity plus plan axes and scenario
/// shape, rendered into the `ficco` header object and the `plan`
/// instant event's args.
fn trace_meta(
    machine_name: &str,
    sc: &Scenario,
    plan: &ficco::plan::Plan,
) -> ficco::obs::TraceMeta {
    ficco::obs::TraceMeta {
        scenario: sc.name.clone(),
        machine: machine_name.to_string(),
        mech: plan.mech.name().to_string(),
        plan: plan.id(),
        args: vec![
            ("m".into(), sc.gemm.m.to_string()),
            ("n".into(), sc.gemm.n.to_string()),
            ("k".into(), sc.gemm.k.to_string()),
            ("ngpus".into(), sc.ngpus.to_string()),
            ("skew".into(), sc.skew.to_string()),
            ("pieces".into(), plan.pieces.to_string()),
            ("shape".into(), plan.shape.name().to_string()),
            ("fused".into(), plan.fused.to_string()),
            ("head_start".into(), plan.head_start.to_string()),
            ("slots".into(), plan.slots.to_string()),
        ],
    }
}

/// Simulate (machine, scenario, plan) under the timeline recorder and
/// write the Perfetto trace to `path` (used by `--trace-out`; `ficco
/// trace` writes the CSV sibling too).
fn write_trace(
    machine: &Machine,
    machine_name: &str,
    sc: &Scenario,
    plan: &ficco::plan::Plan,
    path: &str,
) -> Result<(), Box<dyn std::error::Error>> {
    let mut ev = ficco::schedule::exec::Evaluator::new();
    let (report, rec, tracks) = ev.capture_plan(machine, sc, plan);
    let meta = trace_meta(machine_name, sc, plan);
    ficco::util::atomic::write(path, ficco::obs::perfetto_json(ev.engine(), &rec, &tracks, &meta))?;
    progress!(
        "trace: {} on {} plan {} makespan {}",
        sc.name,
        machine_name,
        plan.id(),
        ficco::util::human_time(report.makespan),
    );
    progress!("  -> {path}");
    Ok(())
}

/// `ficco trace`: flight-recorder export of one simulated cell. With
/// `--plan ID` the exact plan is traced; otherwise the plan space is
/// searched first (same machinery as `tune`, so the traced plan is
/// the searched best) and `--stats` reports the search telemetry.
/// Emits `trace.json` (Chrome/Perfetto, loadable in ui.perfetto.dev)
/// and `timeline.csv` under `--out-dir`.
fn cmd_trace(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let machine_name = args.get_or("machine", "mi300x-8");
    let machine = Machine::preset(machine_name).ok_or_else(|| {
        format!(
            "unknown --machine '{machine_name}' (presets: {})",
            Machine::preset_names().join(", ")
        )
    })?;
    let sc = scenario_from(args, &machine)?;
    let (plan, telemetry) = match args.get("plan") {
        Some(id) => {
            let plan = ficco::plan::Plan::parse_id(id).ok_or_else(|| {
                format!("bad --plan '{id}' (expected e.g. row-d8-fused-hs-s7-dma)")
            })?;
            plan.check(sc.ngpus).map_err(|e| format!("--plan '{id}': {e}"))?;
            (plan, None)
        }
        None => {
            // Search the plan space for this one cell, exactly as
            // `tune` would; the search is deterministic, so the
            // traced plan (and the trace bytes) are identical for
            // any --jobs value.
            let spec = ficco::explore::SweepSpec {
                scenarios: vec![sc.clone()],
                kinds: Vec::new(),
                machines: vec![(machine_name.to_string(), machine.clone())],
                mechs: vec![sc.mech],
                gpu_counts: Vec::new(),
                skews: Vec::new(),
                skew_seed: args.get_u64("skew-seed", ficco::explore::DEFAULT_SKEW_SEED)?,
                search: None,
                model: None,
            };
            let cfg = ficco::search::SearchCfg {
                beam: args.get_usize("beam", 0)?,
                warm: parse_warm(args)?,
                ..Default::default()
            };
            let ov = space_overrides_from(args)?;
            ensure_searchable_space(&spec, &ov)?;
            let jobs = ficco::explore::clamp_jobs(args.get_jobs("jobs")?, spec.n_cells());
            let report = ficco::search::tune(&spec, &ov, &cfg, jobs, |_| true);
            let best = report.results.first().ok_or("trace: search produced no result")?;
            progress!(
                "trace: searched {} plans ({} pruned), best {} ({})",
                best.evaluated,
                best.pruned,
                best.best_plan,
                x(best.best_speedup),
            );
            let plan = ficco::plan::Plan::parse_id(&best.best_plan)
                .ok_or_else(|| format!("searched plan id '{}' did not parse", best.best_plan))?;
            (plan, Some(report.telemetry))
        }
    };

    let out_dir = args.get_or("out-dir", "results/trace");
    std::fs::create_dir_all(out_dir)?;
    let mut ev = ficco::schedule::exec::Evaluator::new();
    let (report, rec, tracks) = ev.capture_plan(&machine, &sc, &plan);
    let meta = trace_meta(machine_name, &sc, &plan);
    let trace_path = format!("{out_dir}/trace.json");
    let csv_path = format!("{out_dir}/timeline.csv");
    ficco::util::atomic::write(&trace_path, ficco::obs::perfetto_json(ev.engine(), &rec, &tracks, &meta))?;
    ficco::util::atomic::write(&csv_path, ficco::obs::timeline_csv(ev.engine(), &rec, &tracks))?;
    progress!(
        "trace: {} on {} plan {} makespan {}",
        sc.name,
        machine_name,
        plan.id(),
        ficco::util::human_time(report.makespan),
    );
    progress!("  -> {trace_path}");
    progress!("  -> {csv_path}");
    if args.has("stats") {
        println!("== telemetry ==");
        print!("{}", telemetry.unwrap_or_default().table().render());
    }
    Ok(())
}

fn cmd_heuristic(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let machine = machine_from(args)?;
    // Decision procedure: the frozen Fig-12a rule lifted to plan
    // space by default, a calibrated model with --model. --threshold
    // overrides the (possibly calibrated) threshold scale either way
    // — it used to be parsed and then ignored.
    let mut decision_model = match model_opt_from(args)? {
        Some(m) => {
            println!("model: {} (calibrated)", args.get("model").unwrap_or("?"));
            m
        }
        None => ficco::heuristics::model::HeuristicModel::default(),
    };
    decision_model.threshold_scale = args.get_f64("threshold", decision_model.threshold_scale)?;
    if !(decision_model.threshold_scale.is_finite() && decision_model.threshold_scale > 0.0) {
        return Err(format!(
            "--threshold must be positive, got {}",
            decision_model.threshold_scale
        )
        .into());
    }
    println!(
        "threshold scale: {} (hetero-unfused beyond {})",
        decision_model.threshold_scale,
        ficco::heuristics::THRESHOLD_BAND * decision_model.threshold_scale,
    );
    if args.has("all") || args.get("scenario").is_none() {
        let mut t = Table::new(vec!["scenario", "M>K", "combined", "pick", "plan", "reason"])
            .align(0, Align::Left)
            .align(3, Align::Left)
            .align(4, Align::Left)
            .align(5, Align::Left);
        for r in workloads::table1() {
            let sc = r.scenario();
            let d = decision_model.predict(&machine, &sc);
            t.row(vec![
                r.name.to_string(),
                (r.m > r.k).to_string(),
                f(d.metrics.combined, 3),
                d.kind.name().to_string(),
                d.plan.id(),
                d.reason,
            ]);
        }
        print!("{}", t.render());
    } else {
        let sc = scenario_from(args, &machine)?;
        let d = decision_model.predict(&machine, &sc);
        println!(
            "{}: pick {} (plan {}) — {}",
            sc.name,
            d.kind.name(),
            d.plan.id(),
            d.reason
        );
    }
    Ok(())
}

fn cmd_characterize(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let machine = machine_from(args)?;
    match args.get_or("what", "dil") {
        "dil" => ficco::metrics::fig7_gemm_dil(&machine).print(),
        "comm-dil" => ficco::metrics::fig8_comm_dil(&machine).print(),
        "cil" => ficco::metrics::fig9_cil(&machine).print(),
        "proportions" => ficco::metrics::fig10_proportions(&machine).print(),
        other => return Err(format!("unknown --what '{other}'").into()),
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let machine = machine_from(args)?;
    let out_dir = args.get_or("out-dir", "results");
    let exhibits = [
        ("fig7", ficco::metrics::fig7_gemm_dil(&machine)),
        ("fig8", ficco::metrics::fig8_comm_dil(&machine)),
        ("fig9", ficco::metrics::fig9_cil(&machine)),
        ("fig10", ficco::metrics::fig10_proportions(&machine)),
        ("fig12b", ficco::metrics::fig12b_schedules(&machine)),
        ("fig13", ficco::metrics::fig13_shard_overlap(&machine)),
        ("fig14", ficco::metrics::fig14_comparison(&machine)),
    ];
    for (name, e) in exhibits {
        e.print();
        if args.has("csv") {
            let path = format!("{out_dir}/{name}.csv");
            e.write_csv(&path)?;
            progress!("  -> {path}");
        }
    }
    Ok(())
}

fn cmd_synth(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let machine = machine_from(args)?;
    let count = args.get_usize("count", 16)?;
    let seed = args.get_u64("seed", 2025)?;
    let scale = args.get_f64("threshold", ficco::heuristics::DEFAULT_THRESHOLD_SCALE)?;
    if !(scale.is_finite() && scale > 0.0) {
        return Err(format!("--threshold must be positive, got {scale}").into());
    }
    let suite = match args.get_or("suite", "synth") {
        "synth" => workloads::synthetic_scenarios(seed, count),
        "moe" => workloads::synthetic_moe_scenarios(seed, count),
        "holdout" => workloads::holdout_scenarios(seed, count),
        other => return Err(format!("unknown --suite '{other}' (synth|moe|holdout)").into()),
    };
    let decision_model = model_opt_from(args)?;
    // A calibrated model predicts full plans, so it is scored against
    // the searched plan space; `--against kinds` only makes sense for
    // the kind-level rule.
    let against = match args.get("against") {
        Some(a) => a,
        None if decision_model.is_some() => "plans",
        None => "kinds",
    };
    let (hit_rate, mean_loss, scored) = match (against, &decision_model) {
        ("kinds", None) => ficco::heuristics::accuracy(&machine, &suite, scale),
        ("kinds", Some(_)) => {
            return Err("--model predicts full plans; use --against plans".into())
        }
        ("plans", m) => {
            let cfg = ficco::search::SearchCfg {
                beam: args.get_usize("beam", 4)?,
                ..Default::default()
            };
            match m {
                None => ficco::heuristics::searched_accuracy(&machine, &suite, scale, &cfg),
                Some(model) => {
                    let mut model = model.clone();
                    if args.get("threshold").is_some() {
                        model.threshold_scale = scale;
                    }
                    ficco::heuristics::model_searched_accuracy(&machine, &suite, &model, &cfg)
                }
            }
        }
        (other, _) => return Err(format!("unknown --against '{other}' (kinds|plans)").into()),
    };
    let searched = against == "plans";
    let modeled = decision_model.is_some();
    let mut headers = vec!["scenario", "pick", "oracle", "pick speedup", "oracle speedup"];
    if modeled {
        headers.insert(2, "pick plan");
    }
    if searched {
        headers.push("searched best");
        headers.push("searched loss %");
    }
    headers.push("hit");
    let mut t = Table::new(headers)
        .align(0, Align::Left)
        .align(1, Align::Left)
        .align(2, Align::Left);
    if modeled {
        t = t.align(3, Align::Left);
    }
    for s in &scored {
        let mut row = vec![
            s.scenario_name.clone(),
            s.pick.name().to_string(),
            s.oracle.name().to_string(),
            x(s.pick_speedup),
            x(s.oracle_speedup),
        ];
        if modeled {
            row.insert(2, s.pick_plan.clone().unwrap_or_else(|| "-".to_string()));
        }
        if searched {
            row.push(match s.searched_speedup {
                Some(v) => x(v),
                None => "-".to_string(),
            });
            row.push(match s.searched_loss() {
                Some(v) => ficco::util::table::f(100.0 * v, 1),
                None => "-".to_string(),
            });
        }
        let hit = if modeled {
            s.plan_hit == Some(true)
        } else {
            s.hit()
        };
        row.push(if hit { "*".to_string() } else { "miss".to_string() });
        t.row(row);
    }
    print!("{}", t.render());
    if modeled {
        println!(
            "model plan-level hit rate vs searched optimum: {:.0}% ({} scenarios); mean loss vs searched plan-space optimum: {:.1}%",
            100.0 * hit_rate,
            count,
            100.0 * mean_loss
        );
    } else if searched {
        println!(
            "heuristic accuracy vs 6-kind oracle: {:.0}% ({} scenarios); mean loss vs searched plan-space optimum: {:.1}%",
            100.0 * hit_rate,
            count,
            100.0 * mean_loss
        );
    } else {
        println!(
            "heuristic accuracy: {:.0}% ({} scenarios); mean loss on miss: {:.1}%",
            100.0 * hit_rate,
            count,
            100.0 * mean_loss
        );
    }
    Ok(())
}

/// `ficco calibrate`: fit the plan-space heuristic model against
/// tune-searched optima over a seeded training suite, gate it on a
/// held-out suite (fall back to the frozen Fig-12a rule if the fit
/// degrades there), and write the byte-stable model artifact.
fn cmd_calibrate(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let machines = args.get_or("machines", "mi300x-8");
    let mechs = args.get_or("mechs", "dma");
    let gpus = args.get_or("gpus", "native");
    let mut train_spec = ficco::explore::SweepSpec::from_filters(
        args.get_or("scenarios", "synth:12:2025"),
        "all", // kinds are irrelevant: the plan space is searched
        machines,
        mechs,
        gpus,
        args.get_or("skew", "0"),
    )?;
    train_spec.skew_seed = args.get_u64("skew-seed", ficco::explore::DEFAULT_SKEW_SEED)?;
    let mut holdout_spec = ficco::explore::SweepSpec::from_filters(
        args.get_or("holdout", "holdout:8:2025"),
        "all",
        machines,
        mechs,
        gpus,
        "", // the holdout suite carries its own intrinsic skews
    )?;
    holdout_spec.skew_seed = train_spec.skew_seed;
    let cfg = ficco::search::SearchCfg {
        beam: args.get_usize("beam", 4)?,
        ..Default::default()
    };
    let ov = space_overrides_from(args)?;
    ensure_searchable_space(&train_spec, &ov)?;
    ensure_searchable_space(&holdout_spec, &ov)?;
    let jobs = ficco::explore::clamp_jobs(
        args.get_jobs("jobs")?,
        train_spec.n_cells().max(holdout_spec.n_cells()),
    );
    println!(
        "calibrate: {} training + {} holdout cells ({}) on {} worker thread{}",
        train_spec.n_cells(),
        holdout_spec.n_cells(),
        if cfg.beam == 0 {
            "exhaustive + pruning".to_string()
        } else {
            format!("beam {}", cfg.beam)
        },
        jobs,
        if jobs == 1 { "" } else { "s" },
    );

    let train = ficco::search::calibration_examples(&train_spec, &ov, &cfg, jobs)?;
    let holdout = ficco::search::calibration_examples(&holdout_spec, &ov, &cfg, jobs)?;
    if args.has("verbose") {
        for e in &train {
            println!(
                "  train {:<10} {:<12} searched best {} ({})",
                e.scenario.name,
                e.machine_name,
                e.searched_plan.id(),
                x(e.searched_speedup()),
            );
        }
    }
    let out = ficco::heuristics::fit::calibrate(
        &train,
        &holdout,
        &ficco::heuristics::fit::FitCfg::default(),
    );

    let mut t = Table::new(vec![
        "model",
        "threshold",
        "train hit %",
        "train loss %",
        "holdout hit %",
        "holdout loss %",
    ])
    .align(0, Align::Left);
    let mut row = |name: &str,
                   scale: f64,
                   train: &ficco::heuristics::fit::SuiteScore,
                   hold: &ficco::heuristics::fit::SuiteScore| {
        t.row(vec![
            name.to_string(),
            scale.to_string(),
            f(100.0 * train.hit_rate(), 0),
            f(100.0 * train.mean_loss, 1),
            f(100.0 * hold.hit_rate(), 0),
            f(100.0 * hold.mean_loss, 1),
        ]);
    };
    row(
        "fig12a-default",
        ficco::heuristics::DEFAULT_THRESHOLD_SCALE,
        &out.default_train,
        &out.default_holdout,
    );
    row(
        "fitted",
        out.fitted.threshold_scale,
        &out.train,
        &out.fitted_holdout,
    );
    drop(row);
    print!("{}", t.render());
    println!(
        "{} candidate models scored; holdout gate: {}",
        out.candidates,
        if out.fell_back {
            "fitted model degraded the frozen rule on holdout — falling back to the default model"
        } else {
            "fitted model accepted"
        },
    );

    let out_path = args.get_or("out", "results/model.ficco");
    if let Some(parent) = std::path::Path::new(out_path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    out.model.save(out_path)?;
    println!("model -> {out_path}");
    Ok(())
}

/// `ficco cotenant`: the multi-job co-tenancy study riding the
/// resumable sim core (ISSUE 10). Per cell, `--tenants` schedule
/// instances are admitted at staggered virtual times into one shared
/// `ClusterSim` (each tenant on its own stream bank, contending only
/// through max–min fair sharing), and each tenant's makespan is
/// reported against its isolated run. Output is byte-identical for
/// any `--jobs` value (deterministic ordered pool + shortest-round-
/// trip float formatting), which the CI co-tenant smoke job verifies.
fn cmd_cotenant(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let mut spec = ficco::explore::SweepSpec::from_filters(
        args.get_or("scenarios", "table1"),
        args.get_or("kinds", "ficco"),
        args.get_or("machines", "mi300x-8"),
        args.get_or("mechs", "dma"),
        args.get_or("gpus", "native"),
        args.get_or("skew", "0"),
    )?;
    spec.skew_seed = args.get_u64("skew-seed", ficco::explore::DEFAULT_SKEW_SEED)?;
    spec.model = model_opt_from(args)?;
    let tenants = args.get_usize("tenants", 2)?;
    if tenants == 0 {
        return Err("--tenants must be >= 1".into());
    }
    let stagger = args.get_f64("stagger", 0.25)?;
    if !(stagger.is_finite() && stagger >= 0.0) {
        return Err(format!("--stagger must be finite and >= 0, got {stagger}").into());
    }
    let robust = parse_robust(args)?;
    let ensemble = robust.as_ref().map(|rc| rc.ensemble.clone());

    let out_dir = args.get_or("out-dir", "results/cotenant");
    std::fs::create_dir_all(out_dir)?;
    let csv_path = format!("{out_dir}/cotenant.csv");
    let json_path = format!("{out_dir}/cotenant.json");

    let cells = spec.cells();
    let jobs = ficco::explore::clamp_jobs(args.get_jobs("jobs")?, cells.len());
    progress!(
        "cotenant: {} cells x {} tenants (stagger {}) on {} worker thread{}",
        cells.len(),
        tenants,
        stagger,
        jobs,
        if jobs == 1 { "" } else { "s" },
    );

    let verbose = args.has("verbose");
    let report = ficco::explore::run_cotenant_cells(
        &cells,
        tenants,
        stagger,
        ensemble.as_ref(),
        jobs,
        |c| {
            if verbose {
                let worst = c
                    .jobs
                    .iter()
                    .map(|j| j.slowdown)
                    .fold(f64::NEG_INFINITY, f64::max);
                progress!(
                    "  [{:>4}] {:<8} {:<12} {:<5} {}g: span {} worst slowdown {}",
                    c.index,
                    c.scenario,
                    c.machine_name,
                    c.mech,
                    c.ngpus,
                    ficco::util::human_time(c.span),
                    x(worst),
                );
            }
            true
        },
    );
    if !report.failures.is_empty() {
        for f in &report.failures {
            eprintln!("cotenant: cell {} failed: {}", f.index, f.message);
        }
        return Err(format!(
            "{} of {} cells failed; no artifact emitted",
            report.failures.len(),
            cells.len(),
        )
        .into());
    }

    // Whole-file, write-temp-then-rename artifacts, like sweep/tune.
    let mut csv = ficco::explore::emit::CotenantCsvEmitter::new(
        ficco::util::atomic::AtomicFile::create(&csv_path)?,
    )?;
    let mut json = ficco::explore::emit::CotenantJsonEmitter::new(
        ficco::util::atomic::AtomicFile::create(&json_path)?,
    )?;
    for c in &report.cells {
        csv.cell(c)?;
        json.cell(c)?;
    }
    csv.finish()?.commit()?;
    json.finish(&report.telemetry)?.commit()?;

    let exhibit = ficco::explore::emit::cotenant_summary(&report.cells);
    exhibit.print();
    if args.has("csv") {
        let summary_path = format!("{out_dir}/summary.csv");
        exhibit.write_csv(&summary_path)?;
        progress!("  -> {summary_path}");
    }
    if args.has("stats") {
        println!("== telemetry ==");
        print!("{}", report.telemetry.table().render());
    }

    // `--trace-out FILE`: Perfetto trace of the first cell's joint
    // co-tenant timeline — every tenant's tasks on its own stream
    // bank (track names prefixed j1:, j2:, ... past tenant 0).
    if let Some(path) = args.get("trace-out") {
        let cell = cells.first().ok_or("--trace-out: no cells to trace")?;
        let mut ev = ficco::schedule::exec::Evaluator::new();
        let tagged = ficco::explore::cotenant_jobs_for(&mut ev, cell, tenants, stagger);
        let jobs: Vec<ficco::schedule::exec::CotenantJob> =
            tagged.into_iter().map(|(_, j)| j).collect();
        let (co, _report, rec, tracks) = ev.capture_cotenant(&cell.machine, &jobs);
        let meta = trace_meta(&cell.machine_name, &cell.scenario, &jobs[0].plan);
        ficco::util::atomic::write(
            path,
            ficco::obs::perfetto_json(ev.engine(), &rec, &tracks, &meta),
        )?;
        progress!(
            "trace: {} on {} x{} tenants span {}",
            cell.scenario.name,
            cell.machine_name,
            tenants,
            ficco::util::human_time(co.span),
        );
        progress!("  -> {path}");
    }

    let n_rows: usize = report.cells.iter().map(|c| c.jobs.len()).sum();
    let cpu_seconds: f64 = report.cells.iter().map(|c| c.eval_seconds).sum();
    progress!(
        "{} tenant rows across {} cells in {:.2}s wall ({:.2}s of evaluation on {} workers)",
        n_rows,
        report.cells.len(),
        report.wall_seconds,
        cpu_seconds,
        report.jobs,
    );
    progress!("  -> {csv_path}");
    progress!("  -> {json_path}");
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let dir = args.get_or("artifacts", "artifacts");
    let m = args.get_u64("m", 256)?;
    let n = args.get_u64("n", 128)?;
    let k = args.get_u64("k", 192)?;
    let ngpus = args.get_usize("gpus", 8)?;
    ficco::coordinator::validate_all_schedules(dir, m, n, k, ngpus)?;
    Ok(())
}

fn cmd_train(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let cfg = ficco::train::TrainConfig::from_args(args)?;
    ficco::train::run(&cfg)?;
    Ok(())
}
