//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path —
//! Python is never invoked here (the three-layer contract).
//!
//! - [`manifest`] parses `artifacts/manifest.txt` (names, files, typed
//!   I/O specs).
//! - [`Runtime`] owns the PJRT client and a compiled-executable cache.
//! - [`gemm`] provides XlaBuilder-built GEMM executables for arbitrary
//!   shapes — the coordinator's numeric schedule validation uses these
//!   for piece shapes that have no dedicated artifact, keeping the
//!   whole validation in Rust.

pub mod gemm;
pub mod manifest;

pub use manifest::{Artifact, Manifest, Spec};

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// Lazily-compiling executor over an artifact directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: std::path::PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and read the manifest in `dir`.
    pub fn load(dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(&format!("{dir}/manifest.txt"))
            .with_context(|| format!("loading manifest from {dir} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            dir: dir.into(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch cached) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let art = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with literal inputs; returns the flattened
    /// tuple outputs (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let art = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        if inputs.len() != art.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                art.inputs.len(),
                inputs.len()
            ));
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("literal shape {dims:?} != data len {}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("literal shape {dims:?} != data len {}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Extract an f32 vector from a literal.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

// Runtime integration tests (needing artifacts/ and a PJRT client)
// live in rust/tests/; manifest and gemm units are in their modules.
