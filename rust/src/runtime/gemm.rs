//! XlaBuilder-built GEMM executables for arbitrary shapes.
//!
//! The coordinator validates FiCCO schedules *numerically* at
//! arbitrary piece shapes; fixed-shape Pallas artifacts exist for the
//! default validation geometry, but odd shards (balanced splits of
//! non-divisible dims) need on-the-fly executables. These are built
//! directly with the XLA builder — still no Python on the request
//! path — and cached per shape.

use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Cached GEMM executor: `C = A·B` and `C += A·B` at f32.
pub struct GemmExecutor {
    client: Arc<xla::PjRtClient>,
    plain: Mutex<HashMap<(u64, u64, u64), Arc<xla::PjRtLoadedExecutable>>>,
    acc: Mutex<HashMap<(u64, u64, u64), Arc<xla::PjRtLoadedExecutable>>>,
}

impl GemmExecutor {
    pub fn new(client: Arc<xla::PjRtClient>) -> GemmExecutor {
        GemmExecutor {
            client,
            plain: Mutex::new(HashMap::new()),
            acc: Mutex::new(HashMap::new()),
        }
    }

    pub fn with_cpu_client() -> Result<GemmExecutor> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(GemmExecutor::new(Arc::new(client)))
    }

    fn build_plain(&self, m: u64, n: u64, k: u64) -> Result<xla::PjRtLoadedExecutable> {
        let b = xla::XlaBuilder::new(&format!("gemm_{m}x{n}x{k}"));
        let a_p = b
            .parameter(0, xla::ElementType::F32, &[m as i64, k as i64], "a")
            .map_err(|e| anyhow!("{e:?}"))?;
        let b_p = b
            .parameter(1, xla::ElementType::F32, &[k as i64, n as i64], "b")
            .map_err(|e| anyhow!("{e:?}"))?;
        let c = a_p
            .dot_general(&b_p, &[1], &[0], &[], &[])
            .map_err(|e| anyhow!("{e:?}"))?;
        let comp = c.build().map_err(|e| anyhow!("{e:?}"))?;
        self.client.compile(&comp).map_err(|e| anyhow!("{e:?}"))
    }

    fn build_acc(&self, m: u64, n: u64, k: u64) -> Result<xla::PjRtLoadedExecutable> {
        let b = xla::XlaBuilder::new(&format!("gemm_acc_{m}x{n}x{k}"));
        let c_p = b
            .parameter(0, xla::ElementType::F32, &[m as i64, n as i64], "c")
            .map_err(|e| anyhow!("{e:?}"))?;
        let a_p = b
            .parameter(1, xla::ElementType::F32, &[m as i64, k as i64], "a")
            .map_err(|e| anyhow!("{e:?}"))?;
        let b_p = b
            .parameter(2, xla::ElementType::F32, &[k as i64, n as i64], "b")
            .map_err(|e| anyhow!("{e:?}"))?;
        let prod = a_p
            .dot_general(&b_p, &[1], &[0], &[], &[])
            .map_err(|e| anyhow!("{e:?}"))?;
        let sum = (c_p + prod).map_err(|e| anyhow!("{e:?}"))?;
        let comp = sum.build().map_err(|e| anyhow!("{e:?}"))?;
        self.client.compile(&comp).map_err(|e| anyhow!("{e:?}"))
    }

    fn get(
        &self,
        cache: &Mutex<HashMap<(u64, u64, u64), Arc<xla::PjRtLoadedExecutable>>>,
        key: (u64, u64, u64),
        build: impl FnOnce() -> Result<xla::PjRtLoadedExecutable>,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let exe = Arc::new(build()?);
        cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// `C[m,n] = A[m,k] · B[k,n]` over row-major f32 slices.
    pub fn matmul(&self, a: &[f32], b: &[f32], m: u64, n: u64, k: u64) -> Result<Vec<f32>> {
        assert_eq!(a.len() as u64, m * k, "A size");
        assert_eq!(b.len() as u64, k * n, "B size");
        let exe = self.get(&self.plain, (m, n, k), || self.build_plain(m, n, k))?;
        let la = super::literal_f32(a, &[m as i64, k as i64])?;
        let lb = super::literal_f32(b, &[k as i64, n as i64])?;
        let out = exe
            .execute::<xla::Literal>(&[la, lb])
            .map_err(|e| anyhow!("{e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        super::to_f32(&out)
    }

    /// `C[m,n] += A[m,k] · B[k,n]` (returns the new C).
    pub fn matmul_acc(
        &self,
        c: &[f32],
        a: &[f32],
        b: &[f32],
        m: u64,
        n: u64,
        k: u64,
    ) -> Result<Vec<f32>> {
        assert_eq!(c.len() as u64, m * n, "C size");
        assert_eq!(a.len() as u64, m * k, "A size");
        assert_eq!(b.len() as u64, k * n, "B size");
        let exe = self.get(&self.acc, (m, n, k), || self.build_acc(m, n, k))?;
        let lc = super::literal_f32(c, &[m as i64, n as i64])?;
        let la = super::literal_f32(a, &[m as i64, k as i64])?;
        let lb = super::literal_f32(b, &[k as i64, n as i64])?;
        let out = exe
            .execute::<xla::Literal>(&[lc, la, lb])
            .map_err(|e| anyhow!("{e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        super::to_f32(&out)
    }

    pub fn cached_shapes(&self) -> usize {
        self.plain.lock().unwrap().len() + self.acc.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for l in 0..k {
                let av = a[i * k + l];
                for j in 0..n {
                    c[i * n + j] += av * b[l * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let ex = GemmExecutor::with_cpu_client().expect("pjrt cpu");
        let (m, n, k) = (5usize, 4usize, 3usize);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.25 - 1.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32).sin()).collect();
        let got = ex.matmul(&a, &b, m as u64, n as u64, k as u64).unwrap();
        let want = naive(&a, &b, m, n, k);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn accumulate_adds() {
        let ex = GemmExecutor::with_cpu_client().expect("pjrt cpu");
        let (m, n, k) = (3u64, 2u64, 4u64);
        let c0 = vec![1.0f32; 6];
        let a = vec![0.5f32; 12];
        let b = vec![2.0f32; 8];
        let got = ex.matmul_acc(&c0, &a, &b, m, n, k).unwrap();
        // each output = 1 + sum_k 0.5*2 = 1 + 4
        for g in got {
            assert!((g - 5.0).abs() < 1e-5);
        }
    }

    #[test]
    fn caches_by_shape() {
        let ex = GemmExecutor::with_cpu_client().expect("pjrt cpu");
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 4];
        ex.matmul(&a, &b, 2, 2, 2).unwrap();
        ex.matmul(&a, &b, 2, 2, 2).unwrap();
        ex.matmul(&a[..1], &b, 1, 4, 1).unwrap();
        assert_eq!(ex.cached_shapes(), 2);
    }
}
