//! Parser for `artifacts/manifest.txt` — the contract between
//! `python/compile/aot.py` (writer) and the Rust runtime (reader).
//!
//! Format: one record per line, tab-separated:
//! `name \t file \t in_specs \t out_specs`, where specs are
//! comma-separated `dtype:shape` items like `f32:256x192`, `i32:4x32`,
//! or `f32:scalar`.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Element type of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemType {
    F32,
    I32,
    U32,
}

impl ElemType {
    fn parse(s: &str) -> Result<ElemType> {
        match s {
            "f32" => Ok(ElemType::F32),
            "i32" => Ok(ElemType::I32),
            "u32" => Ok(ElemType::U32),
            other => Err(anyhow!("unknown dtype '{other}'")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ElemType::F32 => "f32",
            ElemType::I32 => "i32",
            ElemType::U32 => "u32",
        }
    }
}

/// A typed shape spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Spec {
    pub dtype: ElemType,
    pub dims: Vec<i64>,
}

impl Spec {
    pub fn parse(s: &str) -> Result<Spec> {
        let (dt, shape) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("bad spec '{s}' (want dtype:shape)"))?;
        let dtype = ElemType::parse(dt)?;
        let dims = if shape == "scalar" {
            Vec::new()
        } else {
            shape
                .split('x')
                .map(|d| d.parse::<i64>().map_err(|_| anyhow!("bad dim in '{s}'")))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(Spec { dtype, dims })
    }

    pub fn elements(&self) -> i64 {
        self.dims.iter().product()
    }
}

/// One artifact record.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub inputs: Vec<Spec>,
    pub outputs: Vec<Spec>,
}

/// The full manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    records: BTreeMap<String, Artifact>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut records = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 4 {
                return Err(anyhow!(
                    "manifest line {}: expected 4 tab-separated fields, got {}",
                    lineno + 1,
                    fields.len()
                ));
            }
            let parse_specs = |s: &str| -> Result<Vec<Spec>> {
                if s.is_empty() {
                    return Ok(Vec::new());
                }
                s.split(',').map(Spec::parse).collect()
            };
            let art = Artifact {
                name: fields[0].to_string(),
                file: fields[1].to_string(),
                inputs: parse_specs(fields[2])?,
                outputs: parse_specs(fields[3])?,
            };
            if records.insert(art.name.clone(), art).is_some() {
                return Err(anyhow!("duplicate artifact '{}'", fields[0]));
            }
        }
        Ok(Manifest { records })
    }

    pub fn load(path: &str) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Manifest::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&Artifact> {
        self.records.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.records.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# name\tfile\tinputs\toutputs\n\
        gemm\tgemm.hlo.txt\tf32:8x4,f32:4x2\tf32:8x2\n\
        step\tstep.hlo.txt\tf32:2,i32:4x32,f32:scalar\tf32:2,f32:scalar\n";

    #[test]
    fn parses_records() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let g = m.get("gemm").unwrap();
        assert_eq!(g.file, "gemm.hlo.txt");
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[0].dims, vec![8, 4]);
        assert_eq!(g.inputs[0].dtype, ElemType::F32);
        assert_eq!(g.outputs[0].dims, vec![8, 2]);
    }

    #[test]
    fn scalar_and_int_specs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let s = m.get("step").unwrap();
        assert_eq!(s.inputs[1].dtype, ElemType::I32);
        assert!(s.inputs[2].dims.is_empty());
        assert_eq!(s.inputs[2].elements(), 1);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("onlyname\n").is_err());
        assert!(Manifest::parse("a\tb\tbad-spec\tf32:1\n").is_err());
        assert!(Manifest::parse("a\tb\tf32:2\tf32:1\na\tb\tf32:2\tf32:1\n").is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        if let Ok(m) = Manifest::load("artifacts/manifest.txt") {
            assert!(m.get("train_step_tiny").is_some());
        }
    }
}
