//! Deterministic tune-result emitters, mirroring the sweep emitters
//! ([`crate::explore::emit`]): one CSV row / JSON object per searched
//! cell, streamed in cell order, every number in Rust's
//! shortest-round-trip `Display` — a byte-identical `"results"` body
//! for any `--jobs` value, followed by a jobs-dependent `"telemetry"`
//! tail that byte-compares strip via
//! [`crate::obs::canonical_artifact_view`].

use std::io::{self, Write};

use super::{RobustObjective, RobustReport, TuneResult};
use crate::explore::emit::{csv_escape, fbits, json_escape, parse_fbits};
use crate::metrics::Exhibit;
use crate::obs::Telemetry;
use crate::schedule::Kind;
use crate::util::stats;
use crate::util::table::{f, Align, Table};

/// Column header shared by the tune CSV emitter and its tests.
pub const TUNE_CSV_HEADER: &str = "scenario,machine,topology,ngpus,mech,collective,skew,m,n,k,\
space,evaluated,pruned,baseline_makespan,best_plan,best_makespan,best_speedup,\
best_legacy_kind,best_legacy_speedup,plan_gain,heuristic_pick,heuristic_speedup,heuristic_loss";

/// Extra columns appended (header and rows) only when the tune ran
/// with `--robust`; a `--robust off` run keeps the legacy 23-column
/// shape byte-for-byte.
pub const TUNE_ROBUST_COLS: &str = "robust_plan,robust_objective,robust_nominal,robust_p50,\
robust_p95,robust_worst,robust_fragility,robust_flip";

/// One tune result as a CSV row.
pub fn tune_csv_row(r: &TuneResult) -> String {
    let mut out = format!(
        "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
        csv_escape(&r.scenario),
        csv_escape(&r.machine_name),
        r.topology,
        r.ngpus,
        r.mech,
        r.collective,
        r.skew,
        r.m,
        r.n,
        r.k,
        r.space_size,
        r.evaluated,
        r.pruned,
        r.baseline_makespan,
        r.best_plan,
        r.best_makespan,
        r.best_speedup,
        r.best_legacy_kind.name(),
        r.best_legacy_speedup,
        r.plan_gain,
        r.pick.name(),
        r.pick_speedup,
        r.pick_loss,
    );
    if let Some(rb) = &r.robust {
        out.push_str(&format!(
            ",{},{},{},{},{},{},{},{}",
            csv_escape(&rb.plan),
            rb.objective.name(),
            rb.nominal,
            rb.p50,
            rb.p95,
            rb.worst,
            rb.fragility,
            rb.flipped,
        ));
    }
    out.push('\n');
    out
}

/// One tune result as a JSON object.
pub fn tune_json(r: &TuneResult) -> String {
    let mut out = format!(
        "{{\"scenario\":\"{}\",\"machine\":\"{}\",\"topology\":\"{}\",\"ngpus\":{},\
         \"mech\":\"{}\",\"collective\":\"{}\",\"skew\":{},\"m\":{},\"n\":{},\"k\":{},\
         \"space\":{},\"evaluated\":{},\"pruned\":{},\"baseline_makespan\":{},\
         \"best_plan\":\"{}\",\"best_makespan\":{},\"best_speedup\":{},\
         \"best_legacy_kind\":\"{}\",\"best_legacy_speedup\":{},\"plan_gain\":{},\
         \"heuristic_pick\":\"{}\",\"heuristic_speedup\":{},\"heuristic_loss\":{}",
        json_escape(&r.scenario),
        json_escape(&r.machine_name),
        r.topology,
        r.ngpus,
        r.mech,
        r.collective,
        r.skew,
        r.m,
        r.n,
        r.k,
        r.space_size,
        r.evaluated,
        r.pruned,
        r.baseline_makespan,
        json_escape(&r.best_plan),
        r.best_makespan,
        r.best_speedup,
        r.best_legacy_kind.name(),
        r.best_legacy_speedup,
        r.plan_gain,
        r.pick.name(),
        r.pick_speedup,
        r.pick_loss,
    );
    if let Some(rb) = &r.robust {
        out.push_str(&format!(
            ",\"robust\":{{\"plan\":\"{}\",\"objective\":\"{}\",\"nominal\":{},\
             \"p50\":{},\"p95\":{},\"worst\":{},\"fragility\":{},\"flipped\":{}}}",
            json_escape(&rb.plan),
            rb.objective.name(),
            rb.nominal,
            rb.p50,
            rb.p95,
            rb.worst,
            rb.fragility,
            rb.flipped,
        ));
    }
    out.push('}');
    out
}

/// Serialize one [`TuneResult`] as a resume-journal record: one field
/// per line in struct order, floats as bit-exact hex (see
/// [`crate::explore::emit::fbits`]) so a `--resume`d tune reproduces
/// the original artifact byte-for-byte. The final line is `-` for a
/// `--robust off` result, else the space-joined robust block.
pub fn tune_record(r: &TuneResult) -> String {
    let mut out = String::from("ficco-tune-v1\n");
    out.push_str(&format!("{}\n", r.index));
    out.push_str(&format!("{}\n", r.machine_name));
    out.push_str(&format!("{}\n", r.topology));
    out.push_str(&format!("{}\n", r.ngpus));
    out.push_str(&format!("{}\n", r.scenario));
    out.push_str(&format!("{}\n", r.collective));
    out.push_str(&format!("{}\n", r.mech));
    out.push_str(&format!("{}\n", fbits(r.skew)));
    out.push_str(&format!("{}\n{}\n{}\n", r.m, r.n, r.k));
    out.push_str(&format!("{}\n", r.space_size));
    out.push_str(&format!("{}\n", r.evaluated));
    out.push_str(&format!("{}\n", r.pruned));
    out.push_str(&format!("{}\n", fbits(r.baseline_makespan)));
    out.push_str(&format!("{}\n", r.best_plan));
    out.push_str(&format!("{}\n", fbits(r.best_makespan)));
    out.push_str(&format!("{}\n", fbits(r.best_speedup)));
    out.push_str(&format!("{}\n", r.best_legacy_kind.name()));
    out.push_str(&format!("{}\n", fbits(r.best_legacy_speedup)));
    out.push_str(&format!("{}\n", fbits(r.plan_gain)));
    out.push_str(&format!("{}\n", r.pick.name()));
    out.push_str(&format!("{}\n", fbits(r.pick_speedup)));
    out.push_str(&format!("{}\n", fbits(r.pick_loss)));
    out.push_str(&format!("{}\n", fbits(r.eval_seconds)));
    match &r.robust {
        Some(rb) => out.push_str(&format!(
            "{} {} {} {} {} {} {} {}",
            rb.plan,
            rb.objective.name(),
            fbits(rb.nominal),
            fbits(rb.p50),
            fbits(rb.p95),
            fbits(rb.worst),
            fbits(rb.fragility),
            rb.flipped,
        )),
        None => out.push('-'),
    }
    out
}

/// Parse a [`tune_record`] payload. Malformed/truncated records yield
/// `None` — resume re-runs such cells rather than trusting them.
pub fn parse_tune_record(s: &str) -> Option<TuneResult> {
    let mut lines = s.lines();
    if lines.next()? != "ficco-tune-v1" {
        return None;
    }
    let index = lines.next()?.parse().ok()?;
    let machine_name = lines.next()?.to_string();
    let topology = lines.next()?.to_string();
    let ngpus = lines.next()?.parse().ok()?;
    let scenario = lines.next()?.to_string();
    let collective = lines.next()?.to_string();
    let mech = lines.next()?.to_string();
    let skew = parse_fbits(lines.next()?)?;
    let m = lines.next()?.parse().ok()?;
    let n = lines.next()?.parse().ok()?;
    let k = lines.next()?.parse().ok()?;
    let space_size = lines.next()?.parse().ok()?;
    let evaluated = lines.next()?.parse().ok()?;
    let pruned = lines.next()?.parse().ok()?;
    let baseline_makespan = parse_fbits(lines.next()?)?;
    let best_plan = lines.next()?.to_string();
    let best_makespan = parse_fbits(lines.next()?)?;
    let best_speedup = parse_fbits(lines.next()?)?;
    let best_legacy_kind = Kind::parse(lines.next()?)?;
    let best_legacy_speedup = parse_fbits(lines.next()?)?;
    let plan_gain = parse_fbits(lines.next()?)?;
    let pick = Kind::parse(lines.next()?)?;
    let pick_speedup = parse_fbits(lines.next()?)?;
    let pick_loss = parse_fbits(lines.next()?)?;
    let eval_seconds = parse_fbits(lines.next()?)?;
    let robust = match lines.next()? {
        "-" => None,
        line => {
            let mut fld = line.split(' ');
            let rb = RobustReport {
                plan: fld.next()?.to_string(),
                objective: RobustObjective::parse(fld.next()?)?,
                nominal: parse_fbits(fld.next()?)?,
                p50: parse_fbits(fld.next()?)?,
                p95: parse_fbits(fld.next()?)?,
                worst: parse_fbits(fld.next()?)?,
                fragility: parse_fbits(fld.next()?)?,
                flipped: fld.next()?.parse().ok()?,
            };
            if fld.next().is_some() {
                return None;
            }
            Some(rb)
        }
    };
    if lines.next().is_some() {
        return None;
    }
    Some(TuneResult {
        index,
        machine_name,
        topology,
        ngpus,
        scenario,
        collective,
        mech,
        skew,
        m,
        n,
        k,
        space_size,
        evaluated,
        pruned,
        baseline_makespan,
        best_plan,
        best_makespan,
        best_speedup,
        best_legacy_kind,
        best_legacy_speedup,
        plan_gain,
        pick,
        pick_speedup,
        pick_loss,
        robust,
        eval_seconds,
    })
}

/// Streams tune CSV rows cell by cell (header on construction).
pub struct TuneCsvEmitter<W: Write> {
    w: W,
}

impl<W: Write> TuneCsvEmitter<W> {
    /// Legacy 23-column emitter — byte-identical to pre-robust
    /// artifacts; use for `--robust off` runs.
    pub fn new(w: W) -> io::Result<TuneCsvEmitter<W>> {
        TuneCsvEmitter::with_robust(w, false)
    }

    /// Emitter whose header matches the rows `tune_csv_row` will
    /// produce: pass `robust = true` iff the run attaches
    /// [`RobustReport`]s to its results.
    pub fn with_robust(mut w: W, robust: bool) -> io::Result<TuneCsvEmitter<W>> {
        if robust {
            writeln!(w, "{TUNE_CSV_HEADER},{TUNE_ROBUST_COLS}")?;
        } else {
            writeln!(w, "{TUNE_CSV_HEADER}")?;
        }
        Ok(TuneCsvEmitter { w })
    }

    pub fn result(&mut self, r: &TuneResult) -> io::Result<()> {
        self.w.write_all(tune_csv_row(r).as_bytes())
    }

    pub fn finish(mut self) -> io::Result<W> {
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Streams `{"results":[...],"telemetry":{...}}`: a deterministic
/// array of tune-result objects plus the run's [`Telemetry`] tail
/// (supplied at [`finish`](TuneJsonEmitter::finish) time, after the
/// pool has joined).
pub struct TuneJsonEmitter<W: Write> {
    w: W,
    count: usize,
}

impl<W: Write> TuneJsonEmitter<W> {
    pub fn new(mut w: W) -> io::Result<TuneJsonEmitter<W>> {
        w.write_all(b"{\"results\":[")?;
        Ok(TuneJsonEmitter { w, count: 0 })
    }

    pub fn result(&mut self, r: &TuneResult) -> io::Result<()> {
        if self.count > 0 {
            self.w.write_all(b",")?;
        }
        self.w.write_all(b"\n")?;
        self.w.write_all(tune_json(r).as_bytes())?;
        self.count += 1;
        Ok(())
    }

    pub fn finish(mut self, telemetry: &Telemetry) -> io::Result<W> {
        self.w.write_all(b"\n],\n\"telemetry\":")?;
        self.w.write_all(telemetry.to_json().as_bytes())?;
        self.w.write_all(b"\n}\n")?;
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Condense a finished tune into an exhibit: per machine, geomean
/// searched-best and best-legacy speedups, the geomean plan gain
/// (searched over legacy), and the mean heuristic loss against the
/// searched optimum.
pub fn summary(results: &[TuneResult]) -> Exhibit {
    let mut machines: Vec<String> = Vec::new();
    for r in results {
        if !machines.contains(&r.machine_name) {
            machines.push(r.machine_name.clone());
        }
    }
    let mut table = Table::new(vec![
        "machine".to_string(),
        "cells".to_string(),
        "best plan".to_string(),
        "best legacy".to_string(),
        "plan gain".to_string(),
        "pick loss %".to_string(),
    ])
    .align(0, Align::Left);
    let mut summaries = Vec::new();
    for mach in &machines {
        let group: Vec<&TuneResult> = results.iter().filter(|r| &r.machine_name == mach).collect();
        let best: Vec<f64> = group.iter().map(|r| r.best_speedup).collect();
        let legacy: Vec<f64> = group.iter().map(|r| r.best_legacy_speedup).collect();
        let gain: Vec<f64> = group.iter().map(|r| r.plan_gain).collect();
        let loss = group.iter().map(|r| r.pick_loss).sum::<f64>() / group.len().max(1) as f64;
        // Degenerate (zero/NaN) cells are dropped from the geomeans —
        // every geomean cell flags the drop, and a `geomean_skipped_*`
        // summary records the total, instead of hiding it.
        let (g_best, best_skipped, best_cell) = stats::geomean_summary(&best);
        let (_, legacy_skipped, legacy_cell) = stats::geomean_summary(&legacy);
        let (g_gain, gain_skipped, gain_cell) = stats::geomean_summary(&gain);
        table.row(vec![
            mach.clone(),
            group.len().to_string(),
            best_cell,
            legacy_cell,
            gain_cell,
            f(100.0 * loss, 1),
        ]);
        summaries.push((format!("geomean_best_{mach}"), g_best));
        summaries.push((format!("geomean_gain_{mach}"), g_gain));
        summaries.push((format!("mean_pick_loss_{mach}"), loss));
        let skipped = best_skipped + legacy_skipped + gain_skipped;
        if skipped > 0 {
            summaries.push((format!("geomean_skipped_{mach}"), skipped as f64));
        }
    }
    Exhibit {
        title: "Tune summary: searched plan space vs legacy kinds",
        table,
        summaries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::SweepSpec;
    use crate::hw::Machine;
    use crate::schedule::{Kind, Scenario};
    use crate::search::{tune, SearchCfg, SpaceOverrides};
    use crate::sim::CommMech;

    fn tiny_results() -> Vec<TuneResult> {
        let spec = SweepSpec {
            scenarios: vec![Scenario::new("t", 8192, 512, 1024)],
            kinds: vec![Kind::UniformFused1D],
            machines: vec![("mi300x-8".into(), Machine::mi300x_8())],
            mechs: vec![CommMech::Dma],
            gpu_counts: Vec::new(),
            skews: Vec::new(),
            skew_seed: crate::explore::DEFAULT_SKEW_SEED,
            search: None,
            model: None,
        };
        // Narrow space so the test stays fast.
        let ov = SpaceOverrides {
            pieces: Some(vec![1, 8]),
            slots: Some(vec![1, 7]),
            mechs: None,
        };
        let cfg = SearchCfg {
            beam: 2,
            prune: true,
            ..SearchCfg::default()
        };
        tune(&spec, &ov, &cfg, 1, |_| true).results
    }

    #[test]
    fn csv_shape_matches_header() {
        let rs = tiny_results();
        assert_eq!(rs.len(), 1);
        let ncols = TUNE_CSV_HEADER.split(',').count();
        for line in tune_csv_row(&rs[0]).lines() {
            assert_eq!(line.split(',').count(), ncols, "{line}");
        }
    }

    #[test]
    fn emitters_stream_and_terminate() {
        let rs = tiny_results();
        let mut csv = TuneCsvEmitter::new(Vec::new()).unwrap();
        let mut json = TuneJsonEmitter::new(Vec::new()).unwrap();
        for r in &rs {
            csv.result(r).unwrap();
            json.result(r).unwrap();
        }
        let csv = String::from_utf8(csv.finish().unwrap()).unwrap();
        let json = String::from_utf8(json.finish(&Telemetry::default()).unwrap()).unwrap();
        assert!(csv.starts_with("scenario,machine"));
        assert_eq!(csv.lines().count(), 1 + rs.len());
        assert!(json.starts_with("{\"results\":["));
        assert!(json.trim_end().ends_with('}'));
        assert!(json.contains("\n],\n\"telemetry\":"));
        assert!(json.contains("\"best_plan\""));
        assert!(json.contains("\"plan_gain\""));
        // The canonical view strips exactly the telemetry tail.
        let canon = crate::obs::canonical_artifact_view(&json);
        assert!(canon.ends_with("\n]"));
        assert!(!canon.contains("telemetry"));
    }

    fn with_robust_block(mut r: TuneResult) -> TuneResult {
        r.robust = Some(RobustReport {
            plan: "row-d8-fused-hs-s7-dma".to_string(),
            objective: RobustObjective::P95,
            nominal: 1.25e-3,
            p50: 1.30e-3,
            p95: 1.45e-3,
            worst: 1.50e-3,
            fragility: 1.16,
            flipped: true,
        });
        r
    }

    #[test]
    fn robust_rows_extend_the_header_by_exactly_the_robust_cols() {
        let r = with_robust_block(tiny_results().remove(0));
        let header = format!("{TUNE_CSV_HEADER},{TUNE_ROBUST_COLS}");
        let ncols = header.split(',').count();
        assert_eq!(
            ncols,
            TUNE_CSV_HEADER.split(',').count() + TUNE_ROBUST_COLS.split(',').count()
        );
        for line in tune_csv_row(&r).lines() {
            assert_eq!(line.split(',').count(), ncols, "{line}");
        }
        let mut csv = TuneCsvEmitter::with_robust(Vec::new(), true).unwrap();
        csv.result(&r).unwrap();
        let text = String::from_utf8(csv.finish().unwrap()).unwrap();
        assert!(text.starts_with(&header));
        let json = tune_json(&r);
        assert!(json.contains("\"robust\":{\"plan\":\"row-d8-fused-hs-s7-dma\""));
        assert!(json.contains("\"objective\":\"p95\""));
        assert!(json.contains("\"flipped\":true"));
        assert!(json.ends_with("}}"));
        // A robust-off result keeps the legacy bytes exactly.
        let off = tiny_results().remove(0);
        assert!(!tune_csv_row(&off).contains("row-d8"));
        assert!(!tune_json(&off).contains("robust"));
    }

    #[test]
    fn tune_record_round_trips_to_identical_emitter_bytes() {
        let plain = tiny_results().remove(0);
        let robust = with_robust_block(tiny_results().remove(0));
        for r in [&plain, &robust] {
            let rec = tune_record(r);
            let back = parse_tune_record(&rec).expect("record parses");
            assert_eq!(tune_csv_row(&back), tune_csv_row(r));
            assert_eq!(tune_json(&back), tune_json(r));
            assert_eq!(back.index, r.index);
            assert_eq!(back.eval_seconds.to_bits(), r.eval_seconds.to_bits());
            assert_eq!(back.robust, r.robust);
        }
    }

    #[test]
    fn malformed_tune_records_parse_to_none() {
        let rec = tune_record(&tiny_results().remove(0));
        assert!(parse_tune_record("").is_none());
        assert!(parse_tune_record("nonsense").is_none());
        assert!(parse_tune_record(&rec[..rec.len() / 2]).is_none());
        assert!(parse_tune_record(&format!("{rec}\nextra")).is_none());
        let wrong = rec.replacen("ficco-tune-v1", "ficco-tune-v9", 1);
        assert!(parse_tune_record(&wrong).is_none());
    }

    #[test]
    fn summary_reports_gain_at_least_one() {
        let rs = tiny_results();
        let e = summary(&rs);
        assert_eq!(e.table.n_rows(), 1);
        assert!(e.summary("geomean_gain_mi300x-8") >= 1.0 - 1e-12);
        assert!(e.summary("geomean_best_mi300x-8") > 0.0);
    }
}
