//! Deterministic tune-result emitters, mirroring the sweep emitters
//! ([`crate::explore::emit`]): one CSV row / JSON object per searched
//! cell, streamed in cell order, every number in Rust's
//! shortest-round-trip `Display` — a byte-identical `"results"` body
//! for any `--jobs` value, followed by a jobs-dependent `"telemetry"`
//! tail that byte-compares strip via
//! [`crate::obs::canonical_artifact_view`].

use std::io::{self, Write};

use super::TuneResult;
use crate::explore::emit::{csv_escape, json_escape};
use crate::metrics::Exhibit;
use crate::obs::Telemetry;
use crate::util::stats;
use crate::util::table::{f, Align, Table};

/// Column header shared by the tune CSV emitter and its tests.
pub const TUNE_CSV_HEADER: &str = "scenario,machine,topology,ngpus,mech,collective,skew,m,n,k,\
space,evaluated,pruned,baseline_makespan,best_plan,best_makespan,best_speedup,\
best_legacy_kind,best_legacy_speedup,plan_gain,heuristic_pick,heuristic_speedup,heuristic_loss";

/// One tune result as a CSV row.
pub fn tune_csv_row(r: &TuneResult) -> String {
    format!(
        "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
        csv_escape(&r.scenario),
        csv_escape(&r.machine_name),
        r.topology,
        r.ngpus,
        r.mech,
        r.collective,
        r.skew,
        r.m,
        r.n,
        r.k,
        r.space_size,
        r.evaluated,
        r.pruned,
        r.baseline_makespan,
        r.best_plan,
        r.best_makespan,
        r.best_speedup,
        r.best_legacy_kind.name(),
        r.best_legacy_speedup,
        r.plan_gain,
        r.pick.name(),
        r.pick_speedup,
        r.pick_loss,
    )
}

/// One tune result as a JSON object.
pub fn tune_json(r: &TuneResult) -> String {
    format!(
        "{{\"scenario\":\"{}\",\"machine\":\"{}\",\"topology\":\"{}\",\"ngpus\":{},\
         \"mech\":\"{}\",\"collective\":\"{}\",\"skew\":{},\"m\":{},\"n\":{},\"k\":{},\
         \"space\":{},\"evaluated\":{},\"pruned\":{},\"baseline_makespan\":{},\
         \"best_plan\":\"{}\",\"best_makespan\":{},\"best_speedup\":{},\
         \"best_legacy_kind\":\"{}\",\"best_legacy_speedup\":{},\"plan_gain\":{},\
         \"heuristic_pick\":\"{}\",\"heuristic_speedup\":{},\"heuristic_loss\":{}}}",
        json_escape(&r.scenario),
        json_escape(&r.machine_name),
        r.topology,
        r.ngpus,
        r.mech,
        r.collective,
        r.skew,
        r.m,
        r.n,
        r.k,
        r.space_size,
        r.evaluated,
        r.pruned,
        r.baseline_makespan,
        json_escape(&r.best_plan),
        r.best_makespan,
        r.best_speedup,
        r.best_legacy_kind.name(),
        r.best_legacy_speedup,
        r.plan_gain,
        r.pick.name(),
        r.pick_speedup,
        r.pick_loss,
    )
}

/// Streams tune CSV rows cell by cell (header on construction).
pub struct TuneCsvEmitter<W: Write> {
    w: W,
}

impl<W: Write> TuneCsvEmitter<W> {
    pub fn new(mut w: W) -> io::Result<TuneCsvEmitter<W>> {
        writeln!(w, "{TUNE_CSV_HEADER}")?;
        Ok(TuneCsvEmitter { w })
    }

    pub fn result(&mut self, r: &TuneResult) -> io::Result<()> {
        self.w.write_all(tune_csv_row(r).as_bytes())
    }

    pub fn finish(mut self) -> io::Result<W> {
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Streams `{"results":[...],"telemetry":{...}}`: a deterministic
/// array of tune-result objects plus the run's [`Telemetry`] tail
/// (supplied at [`finish`](TuneJsonEmitter::finish) time, after the
/// pool has joined).
pub struct TuneJsonEmitter<W: Write> {
    w: W,
    count: usize,
}

impl<W: Write> TuneJsonEmitter<W> {
    pub fn new(mut w: W) -> io::Result<TuneJsonEmitter<W>> {
        w.write_all(b"{\"results\":[")?;
        Ok(TuneJsonEmitter { w, count: 0 })
    }

    pub fn result(&mut self, r: &TuneResult) -> io::Result<()> {
        if self.count > 0 {
            self.w.write_all(b",")?;
        }
        self.w.write_all(b"\n")?;
        self.w.write_all(tune_json(r).as_bytes())?;
        self.count += 1;
        Ok(())
    }

    pub fn finish(mut self, telemetry: &Telemetry) -> io::Result<W> {
        self.w.write_all(b"\n],\n\"telemetry\":")?;
        self.w.write_all(telemetry.to_json().as_bytes())?;
        self.w.write_all(b"\n}\n")?;
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Condense a finished tune into an exhibit: per machine, geomean
/// searched-best and best-legacy speedups, the geomean plan gain
/// (searched over legacy), and the mean heuristic loss against the
/// searched optimum.
pub fn summary(results: &[TuneResult]) -> Exhibit {
    let mut machines: Vec<String> = Vec::new();
    for r in results {
        if !machines.contains(&r.machine_name) {
            machines.push(r.machine_name.clone());
        }
    }
    let mut table = Table::new(vec![
        "machine".to_string(),
        "cells".to_string(),
        "best plan".to_string(),
        "best legacy".to_string(),
        "plan gain".to_string(),
        "pick loss %".to_string(),
    ])
    .align(0, Align::Left);
    let mut summaries = Vec::new();
    for mach in &machines {
        let group: Vec<&TuneResult> = results.iter().filter(|r| &r.machine_name == mach).collect();
        let best: Vec<f64> = group.iter().map(|r| r.best_speedup).collect();
        let legacy: Vec<f64> = group.iter().map(|r| r.best_legacy_speedup).collect();
        let gain: Vec<f64> = group.iter().map(|r| r.plan_gain).collect();
        let loss = group.iter().map(|r| r.pick_loss).sum::<f64>() / group.len().max(1) as f64;
        // Degenerate (zero/NaN) cells are dropped from the geomeans —
        // every geomean cell flags the drop, and a `geomean_skipped_*`
        // summary records the total, instead of hiding it.
        let (g_best, best_skipped, best_cell) = stats::geomean_summary(&best);
        let (_, legacy_skipped, legacy_cell) = stats::geomean_summary(&legacy);
        let (g_gain, gain_skipped, gain_cell) = stats::geomean_summary(&gain);
        table.row(vec![
            mach.clone(),
            group.len().to_string(),
            best_cell,
            legacy_cell,
            gain_cell,
            f(100.0 * loss, 1),
        ]);
        summaries.push((format!("geomean_best_{mach}"), g_best));
        summaries.push((format!("geomean_gain_{mach}"), g_gain));
        summaries.push((format!("mean_pick_loss_{mach}"), loss));
        let skipped = best_skipped + legacy_skipped + gain_skipped;
        if skipped > 0 {
            summaries.push((format!("geomean_skipped_{mach}"), skipped as f64));
        }
    }
    Exhibit {
        title: "Tune summary: searched plan space vs legacy kinds",
        table,
        summaries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::SweepSpec;
    use crate::hw::Machine;
    use crate::schedule::{Kind, Scenario};
    use crate::search::{tune, SearchCfg, SpaceOverrides};
    use crate::sim::CommMech;

    fn tiny_results() -> Vec<TuneResult> {
        let spec = SweepSpec {
            scenarios: vec![Scenario::new("t", 8192, 512, 1024)],
            kinds: vec![Kind::UniformFused1D],
            machines: vec![("mi300x-8".into(), Machine::mi300x_8())],
            mechs: vec![CommMech::Dma],
            gpu_counts: Vec::new(),
            skews: Vec::new(),
            skew_seed: crate::explore::DEFAULT_SKEW_SEED,
            search: None,
            model: None,
        };
        // Narrow space so the test stays fast.
        let ov = SpaceOverrides {
            pieces: Some(vec![1, 8]),
            slots: Some(vec![1, 7]),
            mechs: None,
        };
        let cfg = SearchCfg {
            beam: 2,
            prune: true,
            ..SearchCfg::default()
        };
        tune(&spec, &ov, &cfg, 1, |_| true).results
    }

    #[test]
    fn csv_shape_matches_header() {
        let rs = tiny_results();
        assert_eq!(rs.len(), 1);
        let ncols = TUNE_CSV_HEADER.split(',').count();
        for line in tune_csv_row(&rs[0]).lines() {
            assert_eq!(line.split(',').count(), ncols, "{line}");
        }
    }

    #[test]
    fn emitters_stream_and_terminate() {
        let rs = tiny_results();
        let mut csv = TuneCsvEmitter::new(Vec::new()).unwrap();
        let mut json = TuneJsonEmitter::new(Vec::new()).unwrap();
        for r in &rs {
            csv.result(r).unwrap();
            json.result(r).unwrap();
        }
        let csv = String::from_utf8(csv.finish().unwrap()).unwrap();
        let json = String::from_utf8(json.finish(&Telemetry::default()).unwrap()).unwrap();
        assert!(csv.starts_with("scenario,machine"));
        assert_eq!(csv.lines().count(), 1 + rs.len());
        assert!(json.starts_with("{\"results\":["));
        assert!(json.trim_end().ends_with('}'));
        assert!(json.contains("\n],\n\"telemetry\":"));
        assert!(json.contains("\"best_plan\""));
        assert!(json.contains("\"plan_gain\""));
        // The canonical view strips exactly the telemetry tail.
        let canon = crate::obs::canonical_artifact_view(&json);
        assert!(canon.ends_with("\n]"));
        assert!(!canon.contains("telemetry"));
    }

    #[test]
    fn summary_reports_gain_at_least_one() {
        let rs = tiny_results();
        let e = summary(&rs);
        assert_eq!(e.table.n_rows(), 1);
        assert!(e.summary("geomean_gain_mi300x-8") >= 1.0 - 1e-12);
        assert!(e.summary("geomean_best_mi300x-8") > 0.0);
    }
}
