//! Plan-space search: evaluate the parameterized FiCCO schedule space
//! ([`crate::plan`]) against the fluid simulator and find the best
//! plan per (machine, scenario) cell.
//!
//! Components:
//!
//! - [`SpaceSpec`] — the candidate axes (decomposition degrees, slot
//!   widths, shapes, fused/unfused, head start, mechanisms);
//!   [`SpaceSpec::plans`] enumerates the valid cartesian product for
//!   a scenario in deterministic order.
//! - [`search`] — evaluates a cell: the six legacy presets are always
//!   evaluated first (so the result is never worse than the best
//!   legacy kind and the serial baseline is measured as a reference),
//!   then either the whole space (exhaustive, `beam == 0`) or a beam
//!   local search over single-knob mutations. Candidates whose
//!   analytic lower bound ([`crate::schedule::exec::makespan_lower_bound`])
//!   already exceeds the incumbent makespan are pruned without
//!   simulating. All simulation goes through a reusable
//!   [`exec::Evaluator`] arena ([`search_in`]) — candidates share the
//!   machine's simulator skeleton and scratch buffers instead of
//!   rebuilding them, and run in the engine's makespan-only lean mode.
//! - [`EvalCache`] — memoized plan evaluations keyed by
//!   (machine, scenario shape, plan), sharded so concurrently
//!   searched cells do not serialize on one lock. The simulated
//!   makespan is a pure function of the key, so sharing a cache
//!   across cells (or runs) never changes results, only skips work.
//! - [`tune`] — the `ficco tune` driver: (machine × mech × GPU-count
//!   × scenario) cells searched concurrently on the deterministic
//!   ordered worker pool ([`crate::util::pool`]) with one evaluator
//!   arena per worker, and byte-stable artifacts via [`emit`].
//!
//! See `DESIGN.md` §2–3 for the space semantics and search contract,
//! §6 for the evaluator/scratch contract.

pub mod emit;
pub mod training;

pub use training::{calibration_examples, CalExample};

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::explore::{Cell, SweepSpec};
use crate::hw::{DType, Machine, Perturbation};
use crate::obs::{Counters, Telemetry};
use crate::plan::{CommShape, Plan};
use crate::schedule::exec::{Evaluator, RobustStats};
use crate::schedule::{Kind, Scenario};
use crate::sim::CommMech;

/// Search strategy configuration.
#[derive(Debug, Clone, Copy)]
pub struct SearchCfg {
    /// Beam width for the local search; 0 = exhaustive enumeration.
    pub beam: usize,
    /// Skip candidates whose analytic lower bound already exceeds the
    /// incumbent makespan.
    pub prune: bool,
    /// Warm-started, incumbent-ordered search (`--warm on`, the
    /// default). Exhaustive mode evaluates the seed set first (the
    /// six presets plus the model-predicted plan when it is a space
    /// member), then visits the remaining space best-lower-bound
    /// first instead of enumeration order, mass-pruning the sorted
    /// tail once a bound crosses the cutoff. Beam mode additionally
    /// seeds its frontier from the predicted plan's single-knob
    /// neighborhood. Exhaustive results are bit-identical to the
    /// `--warm off` enumeration-order walk (the canonical-index
    /// tie-break pins float-equal optima to the same plan; see
    /// `DESIGN.md` §9) — only the evaluated/pruned effort split
    /// changes. Requires `prune`; with pruning off the order cannot
    /// skip anything and the enumeration walk is used as-is.
    pub warm: bool,
    /// The heuristic/model-predicted plan seeding the warm order.
    /// Ignored when `warm` is off, and membership-gated: a prediction
    /// outside the presets and the candidate space never enters the
    /// search (so a calibrated model cannot perturb search results —
    /// its pick is still reported through the tune `pick` columns).
    pub predicted: Option<Plan>,
    /// Robust plan selection (`--robust p95:N` / `--robust worst:N`):
    /// after the nominal search, its top candidates are re-ranked
    /// under a perturbation ensemble and the robust winner is
    /// reported next to the nominal best. `None` (`--robust off`,
    /// the default) leaves every artifact byte-identical to a
    /// robust-unaware build.
    pub robust: Option<RobustCfg>,
}

impl Default for SearchCfg {
    fn default() -> Self {
        SearchCfg {
            beam: 0,
            prune: true,
            warm: true,
            predicted: None,
            robust: None,
        }
    }
}

/// Which ensemble statistic robust selection minimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RobustObjective {
    /// 95th-percentile makespan over the ensemble.
    P95,
    /// Worst-case makespan over the ensemble.
    Worst,
}

impl RobustObjective {
    pub fn name(&self) -> &'static str {
        match self {
            RobustObjective::P95 => "p95",
            RobustObjective::Worst => "worst",
        }
    }

    pub fn parse(s: &str) -> Option<RobustObjective> {
        match s {
            "p95" => Some(RobustObjective::P95),
            "worst" => Some(RobustObjective::Worst),
            _ => None,
        }
    }
}

/// Robust-selection configuration: the nominal search stays the
/// prefilter (only its evaluated survivors — presets, the predicted
/// plan, and every space candidate that escaped pruning — are
/// re-ranked; see `DESIGN.md` §10 for why that is sound), and the
/// `top_k` best of them by nominal makespan are re-evaluated under
/// the ensemble.
#[derive(Debug, Clone, Copy)]
pub struct RobustCfg {
    pub objective: RobustObjective,
    /// Nominal-best candidates re-evaluated under the ensemble.
    pub top_k: usize,
    /// The seeded perturbation ensemble.
    pub ensemble: Perturbation,
}

impl RobustCfg {
    /// Candidates re-ranked per cell unless overridden.
    pub const DEFAULT_TOP_K: usize = 8;
}

/// Candidate axes of one search. The per-scenario valid product is
/// what [`SpaceSpec::plans`] enumerates.
#[derive(Debug, Clone)]
pub struct SpaceSpec {
    pub pieces: Vec<usize>,
    pub slots: Vec<usize>,
    pub shapes: Vec<CommShape>,
    pub fused: Vec<bool>,
    pub head_start: Vec<bool>,
    pub mechs: Vec<CommMech>,
}

impl SpaceSpec {
    /// The default space for a scenario: decomposition degrees around
    /// the paper's `ngpus` point (shard-level, halves, `n`, `2n`),
    /// single-lane vs two-lane vs full-width slots, both shapes, both
    /// fusion modes, both head-start modes, the scenario's mechanism.
    pub fn default_for(sc: &Scenario) -> SpaceSpec {
        let n = sc.ngpus;
        let pieces = dedup_sorted(vec![1, 2, 4, n, 2 * n]);
        let full = n.saturating_sub(1).max(1);
        let slots = dedup_sorted(
            [1usize, 2, full]
                .iter()
                .copied()
                .filter(|&w| w >= 1 && w <= full)
                .collect(),
        );
        SpaceSpec {
            pieces,
            slots,
            shapes: vec![CommShape::Row, CommShape::Col],
            fused: vec![true, false],
            head_start: vec![false, true],
            mechs: vec![sc.mech],
        }
    }

    /// All valid plans of this space for `sc`, deterministic order,
    /// duplicates removed (hash-set membership — the emission order
    /// is first occurrence, exactly as the old `O(n²)` scan-dedup
    /// emitted it).
    pub fn plans(&self, sc: &Scenario) -> Vec<Plan> {
        let n = sc.ngpus;
        let mut out: Vec<Plan> = Vec::new();
        let mut seen: HashSet<Plan> = HashSet::new();
        for &shape in &self.shapes {
            for &pieces in &self.pieces {
                for &fused in &self.fused {
                    for &head_start in &self.head_start {
                        for &slots in &self.slots {
                            for &mech in &self.mechs {
                                let p = Plan {
                                    pieces,
                                    shape,
                                    fused,
                                    head_start,
                                    mech,
                                    slots,
                                };
                                if p.check(n).is_ok() && seen.insert(p) {
                                    out.push(p);
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

fn dedup_sorted(mut v: Vec<usize>) -> Vec<usize> {
    v.sort_unstable();
    v.dedup();
    v
}

/// Optional CLI-driven overrides narrowing/widening the default space.
#[derive(Debug, Clone, Default)]
pub struct SpaceOverrides {
    pub pieces: Option<Vec<usize>>,
    pub slots: Option<Vec<usize>>,
    pub mechs: Option<Vec<CommMech>>,
}

/// The search space for `sc` with `ov` applied over the default axes.
pub fn space_for(sc: &Scenario, ov: &SpaceOverrides) -> SpaceSpec {
    let mut space = SpaceSpec::default_for(sc);
    if let Some(pieces) = &ov.pieces {
        space.pieces = dedup_sorted(pieces.clone());
    }
    if let Some(slots) = &ov.slots {
        space.slots = dedup_sorted(slots.clone());
    }
    if let Some(mechs) = &ov.mechs {
        space.mechs = mechs.clone();
    }
    space
}

/// Cache key: everything the simulated makespan of a plan depends on.
/// The collective tag is volume-equivalent (AG ↔ A2A at `skew == 0`,
/// `DESIGN.md` §1) and deliberately not part of the key; the routing
/// skew and its hotness seed ARE part of the key — skewed partitions
/// change piece sizes, so two scenarios differing only in skew must
/// never share a memoized makespan.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct EvalKey {
    pub machine: String,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub dtype: DType,
    pub ngpus: usize,
    /// `Scenario::skew` as raw bits (f64 is not `Eq`/`Hash`; bit
    /// identity is exactly what partition determinism guarantees).
    pub skew_bits: u64,
    /// Hotness seed; irrelevant (normalized to 0) at `skew == 0`.
    pub skew_seed: u64,
    pub plan: Plan,
}

/// A machine identity string for [`EvalKey`]s when no preset name is
/// at hand: the GPU part, topology shape/scale and the bandwidth/
/// latency figures the cost models read. Callers with a preset
/// registry name should prefer that (shorter, guaranteed unique);
/// this fingerprint keeps a shared cache safe across machines that
/// were never given names.
pub fn machine_key(machine: &Machine) -> String {
    format!(
        "{}-{}-{}x-l{:.3e}-h{:.3e}-d{:.3e}-u{:.3e}",
        machine.gpu.name,
        machine.topo.kind.name(),
        machine.ngpus(),
        machine.topo.link_bw,
        machine.gpu.hbm_bw,
        machine.gpu.dma_engine_bw,
        machine.topo.latency,
    )
}

/// Lock shards per map. Sixteen is comfortably above the worker
/// counts `--jobs` realistically sees while keeping the cache small;
/// contention was measurable with the previous single
/// `Mutex<HashMap>` once every worker's search hammered one lock.
const CACHE_SHARDS: usize = 16;

/// Memoized plan evaluations keyed by (machine, scenario, plan).
/// Thread-safe and lock-sharded (shard = hash of the key, so a given
/// key always meets the same lock); sharing across concurrently
/// searched cells never changes any result (both the makespan and the
/// analytic bound are pure functions of the key), it only skips work.
pub struct EvalCache {
    map: Vec<Mutex<HashMap<EvalKey, f64>>>,
    /// Memoized analytic lower bounds (see [`EvalCache::makespan_bounded`]).
    bounds: Vec<Mutex<HashMap<EvalKey, f64>>>,
    /// Per-shard hit/miss counters (a hit/miss is attributed to the
    /// shard its key hashes to, so the telemetry block can show how
    /// the sharded locks spread).
    hits: Vec<AtomicUsize>,
    misses: Vec<AtomicUsize>,
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache {
            map: (0..CACHE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            bounds: (0..CACHE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: (0..CACHE_SHARDS).map(|_| AtomicUsize::new(0)).collect(),
            misses: (0..CACHE_SHARDS).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    fn shard_of(key: &EvalKey) -> usize {
        // Cheap integer mix over the key's scalar fields — not a
        // second SipHash pass over the whole key (the shard's HashMap
        // already pays that once). Shard choice only distributes
        // locks; results never depend on it. The machine name is
        // deliberately excluded: a search hammers one machine at a
        // time, and the scenario/plan knobs carry the spread.
        let p = &key.plan;
        let knobs = (p.pieces as u64)
            ^ ((p.slots as u64) << 10)
            ^ ((p.fused as u64) << 20)
            ^ ((p.head_start as u64) << 21)
            ^ (((p.shape == CommShape::Col) as u64) << 22)
            ^ (((p.mech == CommMech::Kernel) as u64) << 23);
        let h = key
            .m
            .wrapping_add(key.n.rotate_left(17))
            .wrapping_add(key.k.rotate_left(34))
            .wrapping_add(key.skew_bits.rotate_left(5))
            .wrapping_add(key.skew_seed.rotate_left(47))
            .wrapping_add((key.ngpus as u64).rotate_left(27))
            .wrapping_add(knobs);
        (h.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % CACHE_SHARDS
    }

    pub fn len(&self) -> usize {
        self.map.iter().map(|m| m.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache-hit count summed over shards (telemetry only — excluded
    /// from byte-compared artifact bodies, since hit/miss splits
    /// depend on cross-cell timing).
    pub fn hits(&self) -> usize {
        self.hits.iter().map(|h| h.load(Ordering::Relaxed)).sum()
    }

    pub fn misses(&self) -> usize {
        self.misses.iter().map(|m| m.load(Ordering::Relaxed)).sum()
    }

    /// Per-shard `(hits, misses)`, indexed by shard — the telemetry
    /// block's view of how lookups spread over the sharded locks.
    pub fn shard_stats(&self) -> Vec<(u64, u64)> {
        self.hits
            .iter()
            .zip(&self.misses)
            .map(|(h, m)| {
                (
                    h.load(Ordering::Relaxed) as u64,
                    m.load(Ordering::Relaxed) as u64,
                )
            })
            .collect()
    }

    fn key(&self, machine_name: &str, sc: &Scenario, plan: &Plan) -> EvalKey {
        EvalKey {
            machine: machine_name.to_string(),
            m: sc.gemm.m,
            n: sc.gemm.n,
            k: sc.gemm.k,
            dtype: sc.gemm.dtype,
            ngpus: sc.ngpus,
            // At skew 0 neither the seed nor the sign of zero can
            // affect the partition; normalize both so balanced cells
            // share cache entries.
            skew_bits: if sc.skew == 0.0 { 0 } else { sc.skew.to_bits() },
            skew_seed: if sc.skew == 0.0 { 0 } else { sc.skew_seed },
            plan: *plan,
        }
    }

    fn lookup(&self, key: &EvalKey) -> Option<f64> {
        self.map[Self::shard_of(key)].lock().unwrap().get(key).copied()
    }

    fn store(&self, key: EvalKey, makespan: f64) {
        self.map[Self::shard_of(&key)].lock().unwrap().insert(key, makespan);
    }

    fn lookup_bound(&self, key: &EvalKey) -> Option<f64> {
        self.bounds[Self::shard_of(key)].lock().unwrap().get(key).copied()
    }

    fn store_bound(&self, key: EvalKey, bound: f64) {
        self.bounds[Self::shard_of(&key)].lock().unwrap().insert(key, bound);
    }

    /// Pre-load a known makespan (e.g. a preset the caller already
    /// simulated through `ScenarioEval`) so the search will not
    /// re-simulate it. The value must be the plan's true simulated
    /// makespan on that machine/scenario.
    pub fn insert(&self, machine_name: &str, sc: &Scenario, plan: &Plan, makespan: f64) {
        let key = self.key(machine_name, sc, plan);
        self.store(key, makespan);
    }

    /// Simulated makespan of `plan` on (machine, scenario), memoized
    /// — one-shot wrapper over [`EvalCache::makespan_in`].
    pub fn makespan(
        &self,
        machine_name: &str,
        machine: &Machine,
        sc: &Scenario,
        plan: &Plan,
    ) -> f64 {
        self.makespan_in(&mut Evaluator::new(), machine_name, machine, sc, plan)
    }

    /// Simulated makespan of `plan` on (machine, scenario), memoized;
    /// misses simulate through the caller's reusable evaluator arena
    /// (makespan-only lean mode).
    pub fn makespan_in(
        &self,
        ev: &mut Evaluator,
        machine_name: &str,
        machine: &Machine,
        sc: &Scenario,
        plan: &Plan,
    ) -> f64 {
        let key = self.key(machine_name, sc, plan);
        if let Some(v) = self.lookup(&key) {
            self.hits[Self::shard_of(&key)].fetch_add(1, Ordering::Relaxed);
            return v;
        }
        // Evaluate outside the lock; a racing duplicate evaluation
        // computes the identical value.
        let makespan = ev.plan_makespan(machine, sc, plan);
        self.misses[Self::shard_of(&key)].fetch_add(1, Ordering::Relaxed);
        self.store(key, makespan);
        makespan
    }

    /// Memoized analytic lower bound of `plan` (graph build only, no
    /// simulation). Deliberately outside the hit/miss accounting: the
    /// warm search order reads every pending candidate's bound as
    /// ordering metadata before deciding what to evaluate, and
    /// counting those reads as cache traffic would drown the
    /// evaluation-path statistics the telemetry block is for.
    pub fn bound_in(
        &self,
        ev: &mut Evaluator,
        machine_name: &str,
        machine: &Machine,
        sc: &Scenario,
        plan: &Plan,
    ) -> f64 {
        let key = self.key(machine_name, sc, plan);
        if let Some(b) = self.lookup_bound(&key) {
            return b;
        }
        let bound = ev.load_plan(machine, sc, plan);
        self.store_bound(key, bound);
        bound
    }

    /// As [`EvalCache::makespan_in`], but with lower-bound pruning:
    /// `Err(bound)` when the plan's analytic bound exceeds `cutoff`.
    ///
    /// On a cold key the task graph is built once in the evaluator and
    /// shared between the bound and the simulation
    /// ([`Evaluator::load_plan`] + [`Evaluator::run_loaded_lean`]);
    /// both results are memoized, so a repeated key pays neither a
    /// graph build nor a simulation. The pruning decision depends only
    /// on the memoized-or-recomputed bound — a pure function of the
    /// key — so a search's evaluated/pruned counts are a pure function
    /// of its inputs and cross-cell cache sharing can only skip work,
    /// never change what a cell reports.
    pub fn makespan_bounded(
        &self,
        ev: &mut Evaluator,
        machine_name: &str,
        machine: &Machine,
        sc: &Scenario,
        plan: &Plan,
        cutoff: Option<f64>,
    ) -> Result<f64, f64> {
        let key = self.key(machine_name, sc, plan);
        let c = match cutoff {
            None => return Ok(self.makespan_in(ev, machine_name, machine, sc, plan)),
            Some(c) => c,
        };
        match self.lookup_bound(&key) {
            Some(bound) => {
                if bound > c {
                    return Err(bound);
                }
                if let Some(v) = self.lookup(&key) {
                    self.hits[Self::shard_of(&key)].fetch_add(1, Ordering::Relaxed);
                    return Ok(v);
                }
                let makespan = ev.plan_makespan(machine, sc, plan);
                self.misses[Self::shard_of(&key)].fetch_add(1, Ordering::Relaxed);
                self.store(key, makespan);
                Ok(makespan)
            }
            None => {
                let bound = ev.load_plan(machine, sc, plan);
                self.store_bound(key.clone(), bound);
                if bound > c {
                    return Err(bound);
                }
                if let Some(v) = self.lookup(&key) {
                    self.hits[Self::shard_of(&key)].fetch_add(1, Ordering::Relaxed);
                    return Ok(v);
                }
                // The graph is already loaded — simulate it without
                // rebuilding.
                let makespan = ev
                    .run_loaded_lean()
                    .unwrap_or_else(|e| panic!("plan {} for {}: {e}", plan.id(), sc.name))
                    .makespan;
                self.misses[Self::shard_of(&key)].fetch_add(1, Ordering::Relaxed);
                self.store(key, makespan);
                Ok(makespan)
            }
        }
    }
}

impl Default for EvalCache {
    fn default() -> Self {
        EvalCache::new()
    }
}

/// Analytic lower bound on a plan's simulated makespan (lower the
/// plan, bound the task graph — no simulation).
pub fn plan_lower_bound(machine: &Machine, sc: &Scenario, plan: &Plan) -> f64 {
    Evaluator::new().load_plan(machine, sc, plan)
}

/// One evaluated plan-space point.
#[derive(Debug, Clone, Copy)]
pub struct PlanEval {
    pub plan: Plan,
    pub makespan: f64,
}

/// Result of searching one (machine, scenario) cell.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Serial baseline makespan (the speedup reference).
    pub baseline: f64,
    /// Best plan found (never worse than the best legacy preset).
    pub best: PlanEval,
    /// Best of the six legacy presets, by simulated makespan.
    pub best_legacy: (Kind, f64),
    /// Plans actually simulated (cache hits included).
    pub evaluated: usize,
    /// Candidates skipped by lower-bound pruning.
    pub pruned: usize,
    /// Every evaluated candidate with its canonical index, in
    /// evaluation order. The canonical index is the enumeration-order
    /// position (presets `0..6`, then space plans), so downstream
    /// re-rankings (robust selection) can break float ties exactly
    /// like the incumbent did, independent of visit order.
    pub evals: Vec<(usize, PlanEval)>,
}

impl SearchOutcome {
    pub fn best_speedup(&self) -> f64 {
        self.baseline / self.best.makespan
    }

    pub fn best_legacy_speedup(&self) -> f64 {
        self.baseline / self.best_legacy.1
    }

    /// How much faster the searched best is than the best legacy kind
    /// (≥ 1 by construction — presets seed the search).
    pub fn plan_gain(&self) -> f64 {
        self.best_legacy.1 / self.best.makespan
    }
}

/// Single-knob mutations of `plan` within `space`, deterministic
/// order, invalid points dropped.
fn neighbors(plan: &Plan, space: &SpaceSpec, ngpus: usize) -> Vec<Plan> {
    let mut out: Vec<Plan> = Vec::new();
    for &pieces in &space.pieces {
        if pieces != plan.pieces {
            out.push(Plan { pieces, ..*plan });
        }
    }
    for &slots in &space.slots {
        if slots != plan.slots {
            out.push(Plan { slots, ..*plan });
        }
    }
    for &shape in &space.shapes {
        if shape != plan.shape {
            out.push(Plan { shape, ..*plan });
        }
    }
    for &fused in &space.fused {
        if fused != plan.fused {
            out.push(Plan { fused, ..*plan });
        }
    }
    for &head_start in &space.head_start {
        if head_start != plan.head_start {
            out.push(Plan { head_start, ..*plan });
        }
    }
    for &mech in &space.mechs {
        if mech != plan.mech {
            out.push(Plan { mech, ..*plan });
        }
    }
    out.retain(|p| p.check(ngpus).is_ok());
    out
}

/// Number of legacy presets seeding every search; canonical indices
/// `0..PRESETS` name them, space candidates continue from there.
const PRESETS: usize = Kind::ALL.len();

/// Search incumbent: the lexicographic `(makespan, canonical index)`
/// minimum over the evaluated set. The canonical index of a candidate
/// is its position in the *enumeration* order (presets `0..6`, then
/// the deduped space plans in first-occurrence order), independent of
/// the order the search actually visits them in — so a warm
/// best-bound-first walk and the cold enumeration walk resolve
/// float-equal makespan ties to the same plan, which is what makes
/// their artifacts bit-identical (`rust/tests/search_ordering.rs`).
/// Cold walks visit in canonical order, where this rule degenerates
/// to the historical first-minimum-wins.
#[derive(Clone, Copy)]
struct Incumbent {
    eval: PlanEval,
    canon: usize,
}

impl Incumbent {
    fn offer(&mut self, plan: Plan, makespan: f64, canon: usize) {
        if makespan < self.eval.makespan
            || (makespan == self.eval.makespan && canon < self.canon)
        {
            self.eval = PlanEval { plan, makespan };
            self.canon = canon;
        }
    }
}

/// Evaluate one unseen candidate against the incumbent, with optional
/// lower-bound pruning. The strict `1 + 1e-9` margin on the cutoff
/// absorbs ulp drift between the analytic bound and the event-driven
/// simulation (they accumulate the same sums in different orders), so
/// a mathematically tight bound can never prune the true optimum.
#[allow(clippy::too_many_arguments)]
fn consider(
    ev: &mut Evaluator,
    cache: &EvalCache,
    machine_name: &str,
    machine: &Machine,
    sc: &Scenario,
    prune: bool,
    plan: Plan,
    canon: usize,
    incumbent: &mut Incumbent,
    evals: &mut Vec<(usize, PlanEval)>,
    evaluated: &mut usize,
    pruned: &mut usize,
) {
    let cutoff = if prune {
        Some(incumbent.eval.makespan * (1.0 + 1e-9))
    } else {
        None
    };
    match cache.makespan_bounded(ev, machine_name, machine, sc, &plan, cutoff) {
        Err(_bound) => {
            *pruned += 1;
        }
        Ok(makespan) => {
            *evaluated += 1;
            evals.push((canon, PlanEval { plan, makespan }));
            incumbent.offer(plan, makespan, canon);
        }
    }
}

/// Search the plan space for one (machine, scenario) cell (one-shot
/// wrapper over [`search_in`] with a throwaway evaluator).
pub fn search(
    machine_name: &str,
    machine: &Machine,
    sc: &Scenario,
    space: &SpaceSpec,
    cfg: &SearchCfg,
    cache: &EvalCache,
) -> SearchOutcome {
    search_in(&mut Evaluator::new(), machine_name, machine, sc, space, cfg, cache)
}

/// Search the plan space for one (machine, scenario) cell through a
/// caller-owned reusable [`Evaluator`] arena.
///
/// The six legacy presets are evaluated unconditionally: they seed the
/// incumbent (so the result is at least as good as the best legacy
/// kind), measure the serial baseline, and — under beam search — form
/// the initial frontier. Exhaustive mode then walks every remaining
/// space candidate — in enumeration order when `cfg.warm` is off, in
/// best-lower-bound-first order (with the model-predicted seed and a
/// sorted-tail mass prune) when it is on; both report bit-identical
/// outcomes. Beam mode repeatedly expands single-knob neighborhoods of
/// the current best `beam` plans until no unseen neighbor remains.
/// Fully deterministic for a given input: the evaluator and cache only
/// skip work, they never change results.
pub fn search_in(
    ev: &mut Evaluator,
    machine_name: &str,
    machine: &Machine,
    sc: &Scenario,
    space: &SpaceSpec,
    cfg: &SearchCfg,
    cache: &EvalCache,
) -> SearchOutcome {
    let n = sc.ngpus;
    let mut evaluated = 0usize;
    let mut pruned = 0usize;
    let mut seen: HashSet<Plan> = HashSet::new();
    let mut evals: Vec<(usize, PlanEval)> = Vec::new();
    let mut baseline = f64::NAN;
    let mut best_legacy: Option<(Kind, f64)> = None;
    // The warm seed set: presets plus the evaluated predicted plan —
    // a final best inside it means the whole space walk only confirmed
    // the seed incumbent (`warm_hits` telemetry).
    let mut seeds: Vec<Plan> = Vec::with_capacity(PRESETS + 1);

    for (ci, kind) in Kind::ALL.into_iter().enumerate() {
        let plan = Plan::preset(kind, sc);
        ev.counters.candidates += 1;
        let makespan = cache.makespan_in(ev, machine_name, machine, sc, &plan);
        evaluated += 1;
        seen.insert(plan);
        seeds.push(plan);
        evals.push((ci, PlanEval { plan, makespan }));
        if kind == Kind::Baseline {
            baseline = makespan;
        }
        let better = match best_legacy {
            Some((_, b)) => makespan < b,
            None => true,
        };
        if better {
            best_legacy = Some((kind, makespan));
        }
    }
    let best_legacy = best_legacy.expect("six presets evaluated");
    // Incumbent: lexicographic (makespan, canonical index) minimum —
    // over the presets alone this is the historical first-minimum.
    let mut incumbent = Incumbent {
        eval: evals[0].1,
        canon: evals[0].0,
    };
    for &(c, e) in evals.iter().skip(1) {
        incumbent.offer(e.plan, e.makespan, c);
    }

    if cfg.beam == 0 {
        // Canonical numbering of the deduped space (presets occupy
        // 0..PRESETS): assigned in enumeration order in both modes so
        // the tie-break is order-independent.
        let mut pending: Vec<(usize, Plan)> = Vec::new();
        let mut canon = PRESETS;
        for plan in space.plans(sc) {
            ev.counters.candidates += 1;
            if !seen.insert(plan) {
                continue;
            }
            pending.push((canon, plan));
            canon += 1;
        }
        if cfg.warm && cfg.prune {
            // Seed phase: the predicted plan, evaluated up front and
            // unconditionally when it is a space member (a preset
            // prediction is already evaluated; anything else is
            // ignored — see `SearchCfg::predicted`).
            if let Some(pred) = cfg.predicted {
                if let Some(pos) = pending.iter().position(|&(_, p)| p == pred) {
                    let (c, p) = pending.remove(pos);
                    let makespan = cache.makespan_in(ev, machine_name, machine, sc, &p);
                    evaluated += 1;
                    seeds.push(p);
                    evals.push((c, PlanEval { plan: p, makespan }));
                    incumbent.offer(p, makespan, c);
                }
            }
            // A carried incumbent from an earlier phase of the same
            // cell tightens the cutoff — but only when its plan is a
            // candidate of *this* search, so every makespan that can
            // tie the reported best is still evaluated here (the
            // bit-identity argument of DESIGN.md §9 needs the carried
            // makespan to be ≥ this search's optimum).
            let mut carried = f64::INFINITY;
            if let Some((p, ms)) = ev.cell_incumbent() {
                if seen.contains(&p) {
                    carried = ms;
                }
            }
            // Order phase: best lower bound first, canonical index as
            // the deterministic tie-break.
            let mut ordered: Vec<(f64, usize, Plan)> = pending
                .iter()
                .map(|&(c, p)| (cache.bound_in(ev, machine_name, machine, sc, &p), c, p))
                .collect();
            ordered.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            // Walk phase: in ascending-bound order the first bound
            // above the cutoff proves every remaining bound is too
            // (the cutoff only tightens) — prune the whole tail
            // without further per-candidate checks.
            for (i, &(bound, c, p)) in ordered.iter().enumerate() {
                let cutoff = incumbent.eval.makespan.min(carried) * (1.0 + 1e-9);
                if bound > cutoff {
                    let remaining = ordered.len() - i;
                    pruned += remaining;
                    ev.counters.bound_skips_early += (remaining - 1) as u64;
                    break;
                }
                let makespan =
                    match cache.makespan_bounded(ev, machine_name, machine, sc, &p, Some(cutoff)) {
                        Ok(ms) => ms,
                        // The memoized bound was checked against the
                        // same cutoff above.
                        Err(b) => unreachable!("bound {b} rechecked above {cutoff}"),
                    };
                evaluated += 1;
                evals.push((c, PlanEval { plan: p, makespan }));
                incumbent.offer(p, makespan, c);
            }
        } else {
            for (c, plan) in pending {
                consider(
                    ev,
                    cache,
                    machine_name,
                    machine,
                    sc,
                    cfg.prune,
                    plan,
                    c,
                    &mut incumbent,
                    &mut evals,
                    &mut evaluated,
                    &mut pruned,
                );
            }
        }
    } else {
        // Beam canonical indices are arrival-order (beam outcomes are
        // a deterministic function of the frontier dynamics and are
        // not cross-mode byte-compared).
        let mut canon = PRESETS;
        // Warm beam: expand the predicted plan and its single-knob
        // neighborhood into the frontier before the first round.
        if cfg.warm {
            if let Some(pred) = cfg.predicted {
                if pred.check(n).is_ok() {
                    if seen.insert(pred) {
                        ev.counters.candidates += 1;
                        let makespan = cache.makespan_in(ev, machine_name, machine, sc, &pred);
                        evaluated += 1;
                        seeds.push(pred);
                        evals.push((canon, PlanEval { plan: pred, makespan }));
                        incumbent.offer(pred, makespan, canon);
                        canon += 1;
                    }
                    for nb in neighbors(&pred, space, n) {
                        ev.counters.candidates += 1;
                        if !seen.insert(nb) {
                            continue;
                        }
                        let c = canon;
                        canon += 1;
                        consider(
                            ev,
                            cache,
                            machine_name,
                            machine,
                            sc,
                            cfg.prune,
                            nb,
                            c,
                            &mut incumbent,
                            &mut evals,
                            &mut evaluated,
                            &mut pruned,
                        );
                    }
                }
            }
        }
        // Beam local search: expand single-knob neighborhoods of the
        // best `beam` plans until nothing unseen remains (finite space
        // + seen-set ⇒ termination; cap as a backstop).
        for _round in 0..64 {
            let mut order: Vec<usize> = (0..evals.len()).collect();
            order.sort_by(|&a, &b| {
                evals[a]
                    .1
                    .makespan
                    .partial_cmp(&evals[b].1.makespan)
                    .expect("finite makespans")
                    .then(a.cmp(&b))
            });
            let frontier: Vec<Plan> = order
                .iter()
                .take(cfg.beam)
                .map(|&i| evals[i].1.plan)
                .collect();
            let mut new_any = false;
            for plan in &frontier {
                for nb in neighbors(plan, space, n) {
                    ev.counters.candidates += 1;
                    if !seen.insert(nb) {
                        continue;
                    }
                    new_any = true;
                    let c = canon;
                    canon += 1;
                    consider(
                        ev,
                        cache,
                        machine_name,
                        machine,
                        sc,
                        cfg.prune,
                        nb,
                        c,
                        &mut incumbent,
                        &mut evals,
                        &mut evaluated,
                        &mut pruned,
                    );
                }
            }
            if !new_any {
                break;
            }
            ev.counters.beam_expansions += 1;
        }
    }

    if cfg.warm && seeds.contains(&incumbent.eval.plan) {
        ev.counters.warm_hits += 1;
    }
    // Record the cell incumbent for later phases of the same cell
    // (no-op without an open Evaluator cell scope).
    ev.note_cell_incumbent(incumbent.eval.plan, incumbent.eval.makespan);
    ev.counters.evaluated += evaluated as u64;
    ev.counters.pruned += pruned as u64;
    SearchOutcome {
        baseline,
        best: incumbent.eval,
        best_legacy,
        evaluated,
        pruned,
        evals,
    }
}

/// Outcome of robust re-ranking one searched cell (see
/// [`robust_rerank`]).
#[derive(Debug, Clone)]
pub struct RobustPick {
    /// The robust winner.
    pub plan: Plan,
    /// Its *nominal* makespan (reported speedups stay relative to the
    /// nominal serial baseline).
    pub nominal: f64,
    /// Its ensemble statistics.
    pub stats: RobustStats,
    /// The robust pick differs from the nominal best.
    pub flipped: bool,
    /// Candidates re-evaluated under the ensemble.
    pub reranked: usize,
}

/// Re-rank the nominal search's best candidates under a perturbation
/// ensemble and pick the lexicographic `(objective, nominal makespan,
/// canonical index)` minimum.
///
/// The candidate universe is exactly [`SearchOutcome::evals`] — the
/// nominal-search survivors. That prefilter is deliberate (ensemble
/// evaluation costs `samples` simulations per candidate; the full
/// space would multiply search cost by the ensemble size) and sound
/// in the sense documented in `DESIGN.md` §10: the presets, the
/// predicted plan, and every candidate that escaped lower-bound
/// pruning are all in the universe, so the robust pick can never be
/// worse *nominally* than a plan the nominal search itself would have
/// discarded unseen.
///
/// Candidates are ranked by nominal `(makespan, canon)` before the
/// cut, and each candidate's ensemble statistics are a pure function
/// of `(machine, scenario, plan, ensemble)` — so the robust pick is
/// independent of evaluation order, worker count, and cache state.
pub fn robust_rerank(
    ev: &mut Evaluator,
    machine: &Machine,
    sc: &Scenario,
    out: &SearchOutcome,
    rc: &RobustCfg,
) -> RobustPick {
    let mut ranked: Vec<(usize, PlanEval)> = out.evals.clone();
    ranked.sort_by(|a, b| a.1.makespan.total_cmp(&b.1.makespan).then(a.0.cmp(&b.0)));
    let top_k = rc.top_k.max(1);
    let mut seen: HashSet<Plan> = HashSet::new();
    let mut best: Option<(f64, f64, usize, Plan, RobustStats)> = None;
    let mut reranked = 0usize;
    for &(canon, e) in &ranked {
        if !seen.insert(e.plan) {
            continue;
        }
        if reranked == top_k {
            break;
        }
        reranked += 1;
        let stats = ev.plan_robust_stats(machine, sc, &e.plan, &rc.ensemble, e.makespan);
        let objective = match rc.objective {
            RobustObjective::P95 => stats.p95,
            RobustObjective::Worst => stats.worst,
        };
        let better = match &best {
            None => true,
            Some((o, nom, c, _, _)) => {
                objective < *o
                    || (objective == *o
                        && (e.makespan < *nom || (e.makespan == *nom && canon < *c)))
            }
        };
        if better {
            best = Some((objective, e.makespan, canon, e.plan, stats));
        }
    }
    let (_, nominal, _, plan, stats) =
        best.expect("search evaluated at least the presets");
    let flipped = plan != out.best.plan;
    ev.counters.robust_reranks += reranked as u64;
    if flipped {
        ev.counters.pick_flips += 1;
    }
    RobustPick {
        plan,
        nominal,
        stats,
        flipped,
        reranked,
    }
}

/// Deterministic per-cell outcome of a `ficco tune` run (wall time is
/// measured but excluded from the emitted artifacts).
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub index: usize,
    pub machine_name: String,
    pub topology: String,
    pub ngpus: usize,
    pub scenario: String,
    pub collective: String,
    pub mech: String,
    /// Expert-imbalance routing skew of the searched cell.
    pub skew: f64,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// Size of the enumerated candidate space (before search/pruning).
    pub space_size: usize,
    pub evaluated: usize,
    pub pruned: usize,
    pub baseline_makespan: f64,
    pub best_plan: String,
    pub best_makespan: f64,
    pub best_speedup: f64,
    pub best_legacy_kind: Kind,
    pub best_legacy_speedup: f64,
    /// Best legacy makespan / best plan makespan (≥ 1).
    pub plan_gain: f64,
    /// The static heuristic's pick and how it fares against the
    /// searched optimum.
    pub pick: Kind,
    pub pick_speedup: f64,
    /// Fraction of the searched-best speedup the static pick loses.
    pub pick_loss: f64,
    /// Robust selection of this cell (`None` when the tune ran with
    /// `--robust off`, keeping the artifact bytes unchanged).
    pub robust: Option<RobustReport>,
    pub eval_seconds: f64,
}

/// Per-cell robust-selection block of a [`TuneResult`]: the robust
/// winner's id, its ensemble statistics, and whether it diverged from
/// the nominal best.
#[derive(Debug, Clone, PartialEq)]
pub struct RobustReport {
    /// Robust winner's plan id ([`crate::plan::Plan::id`]).
    pub plan: String,
    /// The statistic robust selection minimized.
    pub objective: RobustObjective,
    /// Nominal makespan of the robust winner.
    pub nominal: f64,
    /// Ensemble percentiles / worst case of the robust winner.
    pub p50: f64,
    pub p95: f64,
    pub worst: f64,
    /// `p95 / nominal` (≥ 1 in practice; 1 = insensitive to the
    /// ensemble).
    pub fragility: f64,
    /// The robust pick differs from the nominal best plan.
    pub flipped: bool,
}

/// Search one sweep cell of the plan space (one-shot wrapper over
/// [`tune_cell_in`]).
pub fn tune_cell(
    cell: &Cell,
    ov: &SpaceOverrides,
    cfg: &SearchCfg,
    cache: &EvalCache,
) -> TuneResult {
    tune_cell_in(&mut Evaluator::new(), cell, ov, cfg, cache)
}

/// Search one sweep cell of the plan space through a caller-owned
/// reusable [`Evaluator`] arena (the tune workers pass one per worker
/// thread).
pub fn tune_cell_in(
    ev: &mut Evaluator,
    cell: &Cell,
    ov: &SpaceOverrides,
    cfg: &SearchCfg,
    cache: &EvalCache,
) -> TuneResult {
    let t0 = Instant::now();
    ev.counters.cells += 1;
    let sc = &cell.scenario;
    let machine = &cell.machine;
    let space = space_for(sc, ov);
    let space_size = space.plans(sc).len();
    // The static pick: a calibrated model predicts a full plan; the
    // default path keeps the frozen Fig-12a kind and its preset plan
    // (bit-identical to the pre-model tune artifacts). Evaluated
    // *before* the search so its makespan can seed the warm order and
    // the carried cell incumbent — every value involved is memoized
    // and pure, so the reordering cannot change any reported number.
    let (pick, pick_plan) = match &cell.model {
        Some(model) => {
            let d = model.predict(machine, sc);
            (d.kind, d.plan)
        }
        None => {
            let pick = crate::heuristics::pick(machine, sc).pick;
            (pick, Plan::preset(pick, sc))
        }
    };
    // Cell scope: all lowering below (pick + every search candidate)
    // shares one memoized partition per decomposition degree.
    ev.begin_cell(sc);
    let pick_makespan = cache.makespan_in(ev, &cell.machine_name, machine, sc, &pick_plan);
    ev.note_cell_incumbent(pick_plan, pick_makespan);
    let cfg = SearchCfg {
        predicted: cfg.predicted.or(Some(pick_plan)),
        ..*cfg
    };
    let out = search_in(ev, &cell.machine_name, machine, sc, &space, &cfg, cache);
    // Robust re-rank inside the cell scope, so ensemble evaluations
    // reuse the memoized partitions of the nominal search.
    let robust = cfg.robust.as_ref().map(|rc| {
        let rp = robust_rerank(ev, machine, sc, &out, rc);
        RobustReport {
            plan: rp.plan.id(),
            objective: rc.objective,
            nominal: rp.stats.nominal,
            p50: rp.stats.p50,
            p95: rp.stats.p95,
            worst: rp.stats.worst,
            fragility: rp.stats.fragility(),
            flipped: rp.flipped,
        }
    });
    ev.end_cell();
    let pick_speedup = out.baseline / pick_makespan;
    TuneResult {
        index: cell.index,
        machine_name: cell.machine_name.clone(),
        topology: machine.topo.kind.name().to_string(),
        ngpus: sc.ngpus,
        scenario: sc.name.clone(),
        collective: sc.collective.name().to_string(),
        mech: sc.mech.name().to_string(),
        skew: sc.skew,
        m: sc.gemm.m,
        n: sc.gemm.n,
        k: sc.gemm.k,
        space_size,
        evaluated: out.evaluated,
        pruned: out.pruned,
        baseline_makespan: out.baseline,
        best_plan: out.best.plan.id(),
        best_makespan: out.best.makespan,
        best_speedup: out.best_speedup(),
        best_legacy_kind: out.best_legacy.0,
        best_legacy_speedup: out.best_legacy_speedup(),
        plan_gain: out.plan_gain(),
        pick,
        pick_speedup,
        pick_loss: (1.0 - out.best.makespan / pick_makespan).max(0.0),
        robust,
        eval_seconds: t0.elapsed().as_secs_f64(),
    }
}

/// Timing and results of one tune run.
#[derive(Debug)]
pub struct TuneReport {
    pub jobs: usize,
    /// Results in deterministic cell order.
    pub results: Vec<TuneResult>,
    /// Cells whose worker panicked, by original cell index (empty on
    /// a clean run). Healthy cells still deliver; the driver reports
    /// these and exits nonzero instead of silently dropping rows.
    pub failures: Vec<crate::util::pool::ItemPanic>,
    pub wall_seconds: f64,
    /// Merged per-worker counters + cache statistics + timings
    /// (jobs-dependent; excluded from the byte-compared artifact
    /// body — see [`crate::obs::canonical_artifact_view`]).
    pub telemetry: Telemetry,
}

impl TuneReport {
    /// Sum of per-cell search times (serial-work proxy for the
    /// `search_throughput` bench).
    pub fn cpu_seconds(&self) -> f64 {
        self.results.iter().map(|r| r.eval_seconds).sum()
    }

    pub fn evaluations(&self) -> usize {
        self.results.iter().map(|r| r.evaluated).sum()
    }

    pub fn pruned(&self) -> usize {
        self.results.iter().map(|r| r.pruned).sum()
    }
}

/// Run a tune over the sweep spec's (machine × mech × GPU-count ×
/// scenario) cells on `jobs` workers of the ordered pool, one
/// reusable [`Evaluator`] arena per worker. `on_result` is invoked in
/// deterministic cell order (reorder-buffered), so the tune emitters
/// are byte-stable for any `jobs`; returning `false` cancels the run,
/// keeping exactly the delivered prefix. One [`EvalCache`] is shared
/// across cells — it memoizes duplicate (machine, scenario, plan)
/// evaluations (e.g. kernel-mech presets re-appearing across
/// mechanism cells) without affecting any reported number.
pub fn tune<F: FnMut(&TuneResult) -> bool>(
    spec: &SweepSpec,
    ov: &SpaceOverrides,
    cfg: &SearchCfg,
    jobs: usize,
    on_result: F,
) -> TuneReport {
    tune_cells(&spec.cells(), ov, cfg, jobs, on_result)
}

/// As [`tune`], over an explicit cell list. The `--resume` driver
/// passes the not-yet-journaled subset of [`SweepSpec::cells`]; each
/// [`Cell`] carries its original index, so resumed results merge back
/// into the full deterministic order.
pub fn tune_cells<F: FnMut(&TuneResult) -> bool>(
    cells: &[Cell],
    ov: &SpaceOverrides,
    cfg: &SearchCfg,
    jobs: usize,
    mut on_result: F,
) -> TuneReport {
    let cache = EvalCache::new();
    // Per-worker counters merge under this mutex exactly once per
    // worker, at pool join — the search hot path itself never touches
    // a shared counter.
    let merged = Mutex::new(Counters::default());
    let t0 = Instant::now();
    let pool_run = crate::util::pool::run_ordered_with(
        cells,
        jobs,
        Evaluator::new,
        |ev, _, cell| tune_cell_in(ev, cell, ov, cfg, &cache),
        |ev: Evaluator| merged.lock().unwrap().merge(&ev.counters),
        |_, result| on_result(result),
    );
    let wall_seconds = t0.elapsed().as_secs_f64();
    let telemetry = Telemetry {
        jobs: pool_run.jobs,
        wall_seconds,
        counters: *merged.lock().unwrap(),
        cache_hits: cache.hits() as u64,
        cache_misses: cache.misses() as u64,
        cache_shards: cache.shard_stats(),
        cell_seconds: pool_run.results.iter().map(|r| r.eval_seconds).collect(),
    };
    // Pool failure indices are positions in the submitted slice;
    // translate to original cell indices for the driver's summary.
    let failures = pool_run
        .failures
        .iter()
        .map(|f| crate::util::pool::ItemPanic {
            index: cells[f.index].index,
            message: f.message.clone(),
        })
        .collect();
    TuneReport {
        jobs: pool_run.jobs,
        results: pool_run.results,
        failures,
        wall_seconds,
        telemetry,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> Machine {
        Machine::mi300x_8()
    }

    fn sc() -> Scenario {
        Scenario::new("t", 65536, 1024, 4096)
    }

    /// Narrowed space so unit tests stay fast in debug builds (the
    /// full default space is exercised by the integration tests and
    /// the CI tune smoke).
    fn small_space(sc: &Scenario) -> SpaceSpec {
        space_for(
            sc,
            &SpaceOverrides {
                pieces: Some(vec![1, 4, 8]),
                slots: Some(vec![1, 7]),
                mechs: None,
            },
        )
    }

    #[test]
    fn default_space_is_valid_and_contains_shard_level() {
        let sc = sc();
        let space = SpaceSpec::default_for(&sc);
        let plans = space.plans(&sc);
        assert!(plans.len() > 10, "space too small: {}", plans.len());
        assert!(plans.iter().all(|p| p.check(sc.ngpus).is_ok()));
        assert!(plans.iter().any(|p| p.pieces == 1));
        assert!(plans.iter().any(|p| p.pieces == sc.ngpus));
        assert!(plans.iter().any(|p| p.slots == 1));
        // No duplicates.
        for (i, a) in plans.iter().enumerate() {
            assert!(!plans[i + 1..].contains(a), "dup {}", a.id());
        }
    }

    #[test]
    fn plans_dedup_preserves_first_occurrence_order() {
        // A space whose axes collide heavily (pieces duplicated via
        // overrides is impossible — dedup_sorted — so collide via the
        // mech axis instead): emission must be first-occurrence order.
        let sc = sc();
        let mut space = small_space(&sc);
        space.mechs = vec![sc.mech, sc.mech];
        let doubled = space.plans(&sc);
        space.mechs = vec![sc.mech];
        let single = space.plans(&sc);
        assert_eq!(doubled, single, "duplicate axis values must not leak");
    }

    #[test]
    fn exhaustive_search_is_at_least_as_good_as_every_preset() {
        let m = machine();
        let sc = sc();
        let space = small_space(&sc);
        let cache = EvalCache::new();
        let out = search("mi300x-8", &m, &sc, &space, &SearchCfg::default(), &cache);
        assert!(out.baseline > 0.0);
        assert!(out.best.makespan <= out.best_legacy.1, "search regressed below legacy");
        assert!(out.plan_gain() >= 1.0);
        for kind in Kind::ALL {
            let p = Plan::preset(kind, &sc);
            let ms = cache.makespan("mi300x-8", &m, &sc, &p);
            assert!(
                out.best.makespan <= ms * (1.0 + 1e-12),
                "{kind:?} beats searched best"
            );
        }
    }

    #[test]
    fn beam_search_never_loses_to_legacy_and_is_deterministic() {
        let m = machine();
        let sc = sc();
        let space = small_space(&sc);
        let cfg = SearchCfg {
            beam: 3,
            ..SearchCfg::default()
        };
        let a = search("mi300x-8", &m, &sc, &space, &cfg, &EvalCache::new());
        let b = search("mi300x-8", &m, &sc, &space, &cfg, &EvalCache::new());
        assert!(a.best.makespan <= a.best_legacy.1);
        assert_eq!(a.best.plan, b.best.plan);
        assert_eq!(a.evaluated, b.evaluated);
        assert_eq!(a.pruned, b.pruned);
        assert!(a.best.makespan == b.best.makespan);
    }

    #[test]
    fn shared_evaluator_matches_throwaway_evaluators() {
        // Threading one arena through consecutive searches (as every
        // tune worker now does) must not change any reported number.
        let m = machine();
        let sc = sc();
        let space = small_space(&sc);
        let cfg = SearchCfg::default();
        let mut ev = Evaluator::new();
        let a = search_in(&mut ev, "mi300x-8", &m, &sc, &space, &cfg, &EvalCache::new());
        let b = search("mi300x-8", &m, &sc, &space, &cfg, &EvalCache::new());
        assert_eq!(a.best.plan, b.best.plan);
        assert_eq!(a.best.makespan.to_bits(), b.best.makespan.to_bits());
        assert_eq!(a.baseline.to_bits(), b.baseline.to_bits());
        assert_eq!(a.evaluated, b.evaluated);
        assert_eq!(a.pruned, b.pruned);
        // And again through the same (now warm) arena.
        let c = search_in(&mut ev, "mi300x-8", &m, &sc, &space, &cfg, &EvalCache::new());
        assert_eq!(c.best.makespan.to_bits(), b.best.makespan.to_bits());
        assert_eq!(c.evaluated, b.evaluated);
        assert_eq!(c.pruned, b.pruned);
    }

    #[test]
    fn pruning_never_changes_the_best() {
        let m = machine();
        let sc = sc();
        let space = small_space(&sc);
        let pruned_run = search(
            "mi300x-8",
            &m,
            &sc,
            &space,
            &SearchCfg::default(),
            &EvalCache::new(),
        );
        let full_run = search(
            "mi300x-8",
            &m,
            &sc,
            &space,
            &SearchCfg {
                prune: false,
                ..SearchCfg::default()
            },
            &EvalCache::new(),
        );
        assert_eq!(full_run.pruned, 0);
        assert!(
            pruned_run.best.makespan == full_run.best.makespan,
            "pruning changed the optimum: {} vs {}",
            pruned_run.best.makespan,
            full_run.best.makespan
        );
    }

    #[test]
    fn cache_memoizes_across_searches() {
        let m = machine();
        let sc = sc();
        let space = small_space(&sc);
        let cache = EvalCache::new();
        let cfg = SearchCfg::default();
        let a = search("mi300x-8", &m, &sc, &space, &cfg, &cache);
        let misses_after_first = cache.misses();
        let b = search("mi300x-8", &m, &sc, &space, &cfg, &cache);
        assert_eq!(a.best.plan, b.best.plan);
        assert_eq!(a.evaluated, b.evaluated, "counts are cache-independent");
        assert_eq!(
            cache.misses(),
            misses_after_first,
            "second search must be all cache hits"
        );
        assert!(cache.hits() > 0);
        assert!(cache.len() > 0 && !cache.is_empty());
    }

    #[test]
    fn cache_never_mixes_skews() {
        let m = machine();
        let sc = sc();
        let skewed = sc.clone().with_skew(1.0, 7);
        let cache = EvalCache::new();
        let plan = Plan::preset(Kind::UniformFused1D, &sc);
        let a = cache.makespan("mi300x-8", &m, &sc, &plan);
        let b = cache.makespan("mi300x-8", &m, &skewed, &plan);
        assert_eq!(cache.misses(), 2, "distinct keys, no false sharing");
        assert!(a != b, "skew must change the simulated makespan");
        // Same skew, different seed: also distinct keys.
        let reseeded = sc.clone().with_skew(1.0, 8);
        let _ = cache.makespan("mi300x-8", &m, &reseeded, &plan);
        assert_eq!(cache.misses(), 3);
        // Skew 0 normalizes the seed away.
        let zero = sc.clone().with_skew(0.0, 99);
        let z = cache.makespan("mi300x-8", &m, &zero, &plan);
        assert_eq!(cache.misses(), 3, "skew-0 seed variants share the entry");
        assert_eq!(z, a);
    }

    #[test]
    fn skewed_search_still_never_loses_to_presets() {
        let m = machine();
        let sc = sc().with_skew(0.8, 5);
        let space = small_space(&sc);
        let cache = EvalCache::new();
        let out = search("mi300x-8", &m, &sc, &space, &SearchCfg::default(), &cache);
        assert!(out.best.makespan <= out.best_legacy.1);
        assert!(out.plan_gain() >= 1.0);
        assert!(out.baseline.is_finite() && out.baseline > 0.0);
    }

    /// Cold reference: enumeration-order search, as before warm
    /// ordering existed.
    fn cold() -> SearchCfg {
        SearchCfg {
            warm: false,
            ..SearchCfg::default()
        }
    }

    #[test]
    fn warm_order_is_bit_identical_to_enumeration_order() {
        let m = machine();
        for sc in [sc(), sc().with_skew(0.8, 5)] {
            let space = small_space(&sc);
            let w = search("mi300x-8", &m, &sc, &space, &SearchCfg::default(), &EvalCache::new());
            let c = search("mi300x-8", &m, &sc, &space, &cold(), &EvalCache::new());
            assert_eq!(w.best.plan, c.best.plan, "{}", sc.name);
            assert_eq!(w.best.makespan.to_bits(), c.best.makespan.to_bits());
            assert_eq!(w.baseline.to_bits(), c.baseline.to_bits());
            assert_eq!(w.best_legacy.0, c.best_legacy.0);
            assert_eq!(w.best_legacy.1.to_bits(), c.best_legacy.1.to_bits());
            // Same candidate universe, never more simulation work.
            assert_eq!(w.evaluated + w.pruned, c.evaluated + c.pruned);
            assert!(
                w.evaluated <= c.evaluated,
                "warm evaluated {} > cold {}",
                w.evaluated,
                c.evaluated
            );
        }
    }

    #[test]
    fn predicted_seed_costs_at_most_one_extra_evaluation() {
        let m = machine();
        let sc = sc();
        let space = small_space(&sc);
        let c = search("mi300x-8", &m, &sc, &space, &cold(), &EvalCache::new());
        // Predict an in-space non-preset plan: it is evaluated
        // unconditionally in the seed phase, and nothing else changes.
        let pred = *space
            .plans(&sc)
            .iter()
            .find(|p| !Plan::presets(&sc).contains(p))
            .expect("space larger than the presets");
        let w = search(
            "mi300x-8",
            &m,
            &sc,
            &space,
            &SearchCfg {
                predicted: Some(pred),
                ..SearchCfg::default()
            },
            &EvalCache::new(),
        );
        assert_eq!(w.best.plan, c.best.plan);
        assert_eq!(w.best.makespan.to_bits(), c.best.makespan.to_bits());
        assert!(
            w.evaluated <= c.evaluated + 1,
            "seeding must cost at most the seed itself: {} vs {}",
            w.evaluated,
            c.evaluated
        );
    }

    #[test]
    fn out_of_space_prediction_never_enters_the_search() {
        let m = machine();
        let sc = sc();
        let space = small_space(&sc);
        // pieces=2 is outside the narrowed space and not a preset.
        let stray = Plan {
            pieces: 2,
            shape: CommShape::Row,
            fused: true,
            head_start: true,
            mech: sc.mech,
            slots: 7,
        };
        assert!(!space.plans(&sc).contains(&stray));
        let cache = EvalCache::new();
        let w = search(
            "mi300x-8",
            &m,
            &sc,
            &space,
            &SearchCfg {
                predicted: Some(stray),
                ..SearchCfg::default()
            },
            &cache,
        );
        let c = search("mi300x-8", &m, &sc, &space, &cold(), &EvalCache::new());
        assert_eq!(w.best.plan, c.best.plan);
        assert_eq!(w.best.makespan.to_bits(), c.best.makespan.to_bits());
        assert_ne!(w.best.plan, stray);
    }

    #[test]
    fn warm_beam_never_loses_to_presets_and_is_deterministic() {
        let m = machine();
        let sc = sc();
        let space = small_space(&sc);
        let pred = *space
            .plans(&sc)
            .iter()
            .find(|p| !Plan::presets(&sc).contains(p))
            .unwrap();
        let cfg = SearchCfg {
            beam: 3,
            predicted: Some(pred),
            ..SearchCfg::default()
        };
        let a = search("mi300x-8", &m, &sc, &space, &cfg, &EvalCache::new());
        let b = search("mi300x-8", &m, &sc, &space, &cfg, &EvalCache::new());
        assert!(a.best.makespan <= a.best_legacy.1);
        assert_eq!(a.best.plan, b.best.plan);
        assert_eq!(a.evaluated, b.evaluated);
        assert_eq!(a.pruned, b.pruned);
    }

    #[test]
    fn bound_in_is_memoized_and_off_the_books() {
        let m = machine();
        let sc = sc();
        let cache = EvalCache::new();
        let mut ev = Evaluator::new();
        let plan = Plan::preset(Kind::UniformFused1D, &sc);
        let b1 = cache.bound_in(&mut ev, "mi300x-8", &m, &sc, &plan);
        let b2 = cache.bound_in(&mut ev, "mi300x-8", &m, &sc, &plan);
        assert_eq!(b1.to_bits(), b2.to_bits());
        assert_eq!(b1.to_bits(), plan_lower_bound(&m, &sc, &plan).to_bits());
        assert_eq!(cache.hits(), 0, "bound reads are ordering metadata");
        assert_eq!(cache.misses(), 0);
        // The bound stays a true lower bound of the simulation.
        let ms = cache.makespan_in(&mut ev, "mi300x-8", &m, &sc, &plan);
        assert!(b1 <= ms * (1.0 + 1e-9));
    }

    #[test]
    fn overrides_narrow_the_space() {
        let sc = sc();
        let ov = SpaceOverrides {
            pieces: Some(vec![1, 8]),
            slots: Some(vec![7]),
            mechs: None,
        };
        let space = space_for(&sc, &ov);
        assert_eq!(space.pieces, vec![1, 8]);
        assert_eq!(space.slots, vec![7]);
        let plans = space.plans(&sc);
        assert!(plans.iter().all(|p| p.slots == 7));
        assert!(plans.iter().all(|p| p.pieces == 1 || p.pieces == 8));
    }

    #[test]
    fn outcome_exposes_every_evaluated_candidate_with_its_canon() {
        let m = machine();
        let sc = sc();
        let space = small_space(&sc);
        let out = search("mi300x-8", &m, &sc, &space, &SearchCfg::default(), &EvalCache::new());
        assert_eq!(out.evals.len(), out.evaluated);
        // Presets occupy canonical indices 0..6, space plans follow.
        assert!(out.evals.iter().take(PRESETS).enumerate().all(|(i, &(c, _))| c == i));
        // The incumbent is the lexicographic (makespan, canon) min of
        // the exposed set.
        let min = out
            .evals
            .iter()
            .min_by(|a, b| a.1.makespan.total_cmp(&b.1.makespan).then(a.0.cmp(&b.0)))
            .unwrap();
        assert_eq!(min.1.plan, out.best.plan);
        assert_eq!(min.1.makespan.to_bits(), out.best.makespan.to_bits());
    }

    #[test]
    fn zero_magnitude_robust_rerank_keeps_the_nominal_best_bitwise() {
        let m = machine();
        let sc = sc();
        let space = small_space(&sc);
        let mut ev = Evaluator::new();
        let out = search_in(&mut ev, "mi300x-8", &m, &sc, &space, &SearchCfg::default(), &EvalCache::new());
        let rc = RobustCfg {
            objective: RobustObjective::Worst,
            top_k: RobustCfg::DEFAULT_TOP_K,
            ensemble: Perturbation {
                compute: 0.0,
                bandwidth: 0.0,
                setup: 0.0,
                samples: 4,
                seed: 1,
            },
        };
        let rp = robust_rerank(&mut ev, &m, &sc, &out, &rc);
        assert_eq!(rp.plan, out.best.plan);
        assert!(!rp.flipped);
        assert_eq!(rp.nominal.to_bits(), out.best.makespan.to_bits());
        assert_eq!(rp.stats.p95.to_bits(), out.best.makespan.to_bits());
        assert_eq!(rp.stats.worst.to_bits(), out.best.makespan.to_bits());
        assert_eq!(ev.counters.pick_flips, 0);
        assert_eq!(ev.counters.robust_reranks, rp.reranked as u64);
        assert!(rp.reranked >= 1 && rp.reranked <= RobustCfg::DEFAULT_TOP_K);
    }

    fn two_cell_spec() -> SweepSpec {
        SweepSpec {
            scenarios: vec![
                Scenario::new("ra", 8192, 512, 1024),
                Scenario::new("rb", 4096, 256, 8192),
            ],
            kinds: vec![Kind::UniformFused1D],
            machines: vec![("mi300x-8".into(), Machine::mi300x_8())],
            mechs: vec![CommMech::Dma],
            gpu_counts: Vec::new(),
            skews: Vec::new(),
            skew_seed: crate::explore::DEFAULT_SKEW_SEED,
            search: None,
            model: None,
        }
    }

    fn small_ov() -> SpaceOverrides {
        SpaceOverrides {
            pieces: Some(vec![1, 4, 8]),
            slots: Some(vec![1, 7]),
            mechs: None,
        }
    }

    #[test]
    fn robust_tune_is_jobs_invariant_and_leaves_nominal_columns_untouched() {
        let spec = two_cell_spec();
        let ov = small_ov();
        let plain = SearchCfg::default();
        let robust = SearchCfg {
            robust: Some(RobustCfg {
                objective: RobustObjective::P95,
                top_k: 4,
                ensemble: Perturbation::defaults(3, 42),
            }),
            ..SearchCfg::default()
        };
        let base = tune(&spec, &ov, &plain, 1, |_| true);
        let r1 = tune(&spec, &ov, &robust, 1, |_| true);
        let r4 = tune(&spec, &ov, &robust, 4, |_| true);
        assert!(base.failures.is_empty());
        assert_eq!(base.results.len(), r1.results.len());
        for ((b, a), c) in base.results.iter().zip(&r1.results).zip(&r4.results) {
            // Robust mode must not change any nominal number.
            assert!(b.robust.is_none());
            assert_eq!(a.best_plan, b.best_plan);
            assert_eq!(a.best_makespan.to_bits(), b.best_makespan.to_bits());
            assert_eq!(a.baseline_makespan.to_bits(), b.baseline_makespan.to_bits());
            assert_eq!(a.evaluated, b.evaluated);
            assert_eq!(a.pruned, b.pruned);
            // And the robust block is jobs-invariant, bit for bit.
            let x = a.robust.as_ref().expect("robust block present");
            let y = c.robust.as_ref().expect("robust block present");
            assert_eq!(x.plan, y.plan);
            assert_eq!(x.nominal.to_bits(), y.nominal.to_bits());
            assert_eq!(x.p50.to_bits(), y.p50.to_bits());
            assert_eq!(x.p95.to_bits(), y.p95.to_bits());
            assert_eq!(x.worst.to_bits(), y.worst.to_bits());
            assert_eq!(x.fragility.to_bits(), y.fragility.to_bits());
            assert_eq!(x.flipped, y.flipped);
            // Ensemble stats are ordered and anchored at the nominal.
            assert!(x.p50 <= x.p95 && x.p95 <= x.worst);
            assert!(x.worst > x.nominal, "perturbed ensemble must degrade");
        }
        assert_eq!(
            r1.telemetry.counters.robust_reranks,
            r4.telemetry.counters.robust_reranks
        );
        assert_eq!(r1.telemetry.counters.pick_flips, r4.telemetry.counters.pick_flips);
        assert!(r1.telemetry.counters.robust_reranks > 0);
    }

    #[test]
    fn tune_cells_subset_keeps_original_indices() {
        let spec = two_cell_spec();
        let ov = small_ov();
        let cfg = SearchCfg::default();
        let full = tune(&spec, &ov, &cfg, 1, |_| true);
        let cells = spec.cells();
        let tail = tune_cells(&cells[1..], &ov, &cfg, 1, |_| true);
        assert_eq!(tail.results.len(), 1);
        assert_eq!(tail.results[0].index, 1, "original cell index survives");
        assert_eq!(
            tail.results[0].best_makespan.to_bits(),
            full.results[1].best_makespan.to_bits()
        );
        assert_eq!(tail.results[0].best_plan, full.results[1].best_plan);
    }
}
