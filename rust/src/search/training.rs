//! Training-data extraction for heuristic calibration.
//!
//! `ficco calibrate` fits a [`crate::heuristics::model::HeuristicModel`]
//! against plan-space searched optima. This module turns a tune run
//! ([`super::tune`] over a [`SweepSpec`]'s cells) into supervised
//! [`CalExample`]s: each cell's scenario (pinned to its machine, mech
//! and GPU count) paired with the best plan the search found there.
//! The extraction inherits the tune driver's determinism — ordered
//! worker pool, pure search — so the example list is identical for
//! any `jobs` value, which is what makes the fitted model artifact
//! byte-stable.

use crate::explore::SweepSpec;
use crate::hw::Machine;
use crate::plan::Plan;
use crate::schedule::Scenario;

use super::{tune, SearchCfg, SpaceOverrides};

/// One supervised calibration example: a scenario and the plan-space
/// optimum `ficco tune`'s search found for it.
#[derive(Debug, Clone)]
pub struct CalExample {
    /// Machine preset name (the cache key the fit scores under).
    pub machine_name: String,
    pub machine: Machine,
    pub scenario: Scenario,
    /// Serial-baseline makespan of the cell (speedup reference).
    pub baseline: f64,
    /// The searched optimum (never worse than the best legacy kind).
    pub searched_plan: Plan,
    pub searched_makespan: f64,
}

impl CalExample {
    /// Speedup of the searched optimum over the serial baseline.
    pub fn searched_speedup(&self) -> f64 {
        self.baseline / self.searched_makespan
    }
}

/// Search every cell of `spec` and extract the calibration examples
/// from the [`super::TuneResult`]s, in deterministic cell order.
pub fn calibration_examples(
    spec: &SweepSpec,
    ov: &SpaceOverrides,
    cfg: &SearchCfg,
    jobs: usize,
) -> Result<Vec<CalExample>, String> {
    let cells = spec.cells();
    let report = tune(spec, ov, cfg, jobs, |_| true);
    if report.results.len() != cells.len() {
        return Err(format!(
            "tune delivered {} of {} cells",
            report.results.len(),
            cells.len()
        ));
    }
    report
        .results
        .iter()
        .zip(&cells)
        .map(|(r, cell)| {
            debug_assert_eq!(r.index, cell.index);
            let plan = Plan::parse_id(&r.best_plan)
                .ok_or_else(|| format!("unparseable searched plan id '{}'", r.best_plan))?;
            Ok(CalExample {
                machine_name: cell.machine_name.clone(),
                machine: cell.machine.clone(),
                scenario: cell.scenario.clone(),
                baseline: r.baseline_makespan,
                searched_plan: plan,
                searched_makespan: r.best_makespan,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Kind;
    use crate::sim::CommMech;

    #[test]
    fn examples_mirror_the_tune_cells() {
        let spec = SweepSpec {
            scenarios: vec![Scenario::new("t", 8192, 512, 1024)],
            kinds: Kind::ALL.to_vec(),
            machines: vec![("mi300x-8".into(), Machine::mi300x_8())],
            mechs: vec![CommMech::Dma],
            gpu_counts: Vec::new(),
            skews: Vec::new(),
            skew_seed: crate::explore::DEFAULT_SKEW_SEED,
            search: None,
            model: None,
        };
        let ov = SpaceOverrides {
            pieces: Some(vec![1, 8]),
            slots: Some(vec![1, 7]),
            mechs: None,
        };
        let cfg = SearchCfg {
            beam: 2,
            prune: true,
            ..SearchCfg::default()
        };
        let examples = calibration_examples(&spec, &ov, &cfg, 2).unwrap();
        assert_eq!(examples.len(), 1);
        let e = &examples[0];
        assert_eq!(e.machine_name, "mi300x-8");
        assert_eq!(e.scenario.name, "t");
        assert!(e.searched_plan.check(e.scenario.ngpus).is_ok());
        assert!(e.baseline > 0.0 && e.searched_makespan > 0.0);
        assert!(e.searched_speedup() >= 1.0 - 1e-12, "search never loses to baseline");
    }
}
