//! GEMM cost model with decomposition-inefficiency (DIL) effects.
//!
//! The paper characterizes DIL as GEMMs are sharded 8-way and 64-way in
//! the row (M) or column (K) dimension (§IV-C1, Fig 7). Their empirical
//! observations, which this model reproduces structurally:
//!
//! 1. 64-way sharding has higher DIL than 8-way;
//! 2. row-sharding hurts more when M < K, column-sharding when M > K;
//! 3. DIL rises as the GEMM's static op-to-byte (OTB) falls.
//!
//! Mechanisms modelled (all well documented for GPU GEMM [Osama et al.
//! PPoPP'23, Triton MAPL'19]): macro-tile/wave quantization over the CU
//! array, per-tile efficiency shrinking with tile size, short-K
//! pipeline startup, the extra C-matrix read-modify-write traffic of
//! accumulating (column-sharded) GEMMs, a fixed kernel overhead, and
//! the HBM roofline.

use crate::hw::{DType, GpuSpec};

/// Which GEMM input dimension a decomposition shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Sharding {
    /// Shard activations' rows (1D buffers). Output rows partition.
    Row,
    /// Shard the inner reduction dimension (2D buffers). Requires an
    /// accumulating GEMM (`C += A·B`).
    Col,
}

impl Sharding {
    pub fn name(self) -> &'static str {
        match self {
            Sharding::Row => "row(M)",
            Sharding::Col => "col(K)",
        }
    }
}

/// A GEMM problem: `C[M,N] (+)= A[M,K] · B[K,N]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmShape {
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub dtype: DType,
    /// True for `C += A·B` partial-K kernels (adds C read traffic).
    pub accumulate: bool,
}

impl GemmShape {
    pub fn new(m: u64, n: u64, k: u64) -> GemmShape {
        GemmShape {
            m,
            n,
            k,
            dtype: DType::Bf16,
            accumulate: false,
        }
    }

    pub fn accumulating(mut self) -> GemmShape {
        self.accumulate = true;
        self
    }

    pub fn with_dtype(mut self, d: DType) -> GemmShape {
        self.dtype = d;
        self
    }

    /// Multiply–add FLOPs (2·M·N·K).
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Ideal streaming HBM traffic in bytes: read A and B once, write
    /// C once; accumulating kernels also read C.
    pub fn bytes(&self) -> f64 {
        let e = self.dtype.bytes() as f64;
        let a = self.m as f64 * self.k as f64 * e;
        let b = self.k as f64 * self.n as f64 * e;
        // Output (and accumulator) kept in f32 as standard for bf16.
        let c_elem = 4.0f64.max(e);
        let c = self.m as f64 * self.n as f64 * c_elem;
        a + b + if self.accumulate { 2.0 * c } else { c }
    }

    /// Static op-to-byte ratio (arithmetic intensity), the paper's OTB
    /// axis for DIL (§IV-C1).
    pub fn otb(&self) -> f64 {
        self.flops() / self.bytes()
    }

    /// Static memory-traffic metric, the paper's MT axis for CIL
    /// (§IV-D1): MK + KN + MN elements, in bytes.
    pub fn mt(&self) -> f64 {
        let e = self.dtype.bytes() as f64;
        (self.m as f64 * self.k as f64 + self.k as f64 * self.n as f64
            + self.m as f64 * self.n as f64)
            * e
    }

    /// Shard this GEMM `ways`-way along `dim`, yielding the per-piece
    /// shape. Row shards divide M; column shards divide K and become
    /// accumulating. Remainders round up (worst piece governs).
    pub fn shard(&self, dim: Sharding, ways: u64) -> GemmShape {
        assert!(ways >= 1);
        match dim {
            Sharding::Row => GemmShape {
                m: div_up(self.m, ways),
                ..*self
            },
            Sharding::Col => GemmShape {
                k: div_up(self.k, ways),
                accumulate: ways > 1 || self.accumulate,
                ..*self
            },
        }
    }
}

fn div_up(a: u64, b: u64) -> u64 {
    (a + b - 1) / b
}

/// GEMM timing model over a [`GpuSpec`].
#[derive(Debug, Clone)]
pub struct GemmCost<'a> {
    pub gpu: &'a GpuSpec,
    /// Macro-tile palette the library (hipblaslt-like) selects from.
    /// (tile_m, tile_n, per-tile MFMA efficiency at long K).
    pub tile_palette: Vec<(u64, u64, f64)>,
    /// K extent at which the MFMA pipeline reaches half efficiency.
    pub k_half: f64,
    /// Fraction of HBM peak a GEMM's streaming accesses achieve.
    pub hbm_eff: f64,
}

impl<'a> GemmCost<'a> {
    pub fn new(gpu: &'a GpuSpec) -> GemmCost<'a> {
        GemmCost {
            gpu,
            tile_palette: vec![
                (256, 256, 1.00),
                (256, 128, 0.97),
                (128, 128, 0.93),
                (128, 64, 0.85),
                (64, 64, 0.76),
                (64, 32, 0.62),
                (32, 32, 0.48),
                (16, 16, 0.30),
            ],
            k_half: 384.0,
            hbm_eff: 0.85,
        }
    }

    /// Isolated execution time of one GEMM kernel, seconds, including
    /// fixed launch overhead. This is `max(compute, memory)` with the
    /// utilization model applied to the compute leg.
    pub fn time(&self, g: &GemmShape) -> f64 {
        let (t_compute, _tile) = self.compute_time(g);
        let t_memory = g.bytes() / (self.hbm_eff * self.gpu.hbm_bw);
        self.gpu.kernel_launch + t_compute.max(t_memory)
    }

    /// Compute-leg time and the selected macro tile.
    pub fn compute_time(&self, g: &GemmShape) -> (f64, (u64, u64)) {
        let peak = self.gpu.peak_flops(g.dtype);
        let mut best = f64::INFINITY;
        let mut best_tile = (0, 0);
        for &(tm, tn, tile_eff) in &self.tile_palette {
            let tiles_m = div_up(g.m, tm);
            let tiles_n = div_up(g.n, tn);
            let tiles = tiles_m * tiles_n;
            // Wave quantization: tiles round up to multiples of the CU
            // count; the last wave is partially filled.
            let waves = div_up(tiles, self.gpu.cus as u64);
            let occupancy = tiles as f64 / (waves * self.gpu.cus as u64) as f64;
            // Partial edge tiles still occupy a full CU-tile of time.
            let padded_flops =
                2.0 * (tiles_m * tm) as f64 * (tiles_n * tn) as f64 * g.k as f64;
            // Short-K startup: the MFMA pipeline + prologue amortizes
            // over the K loop.
            let k_eff = g.k as f64 / (g.k as f64 + self.k_half);
            let eff = tile_eff * occupancy * k_eff;
            let t = padded_flops / (peak * eff.max(1e-3));
            if t < best {
                best = t;
                best_tile = (tm, tn);
            }
        }
        (best, best_tile)
    }

    /// Achieved fraction of peak for this shape (diagnostic).
    pub fn efficiency(&self, g: &GemmShape) -> f64 {
        let t = self.time(g);
        g.flops() / (t * self.gpu.peak_flops(g.dtype))
    }

    /// CUs a GEMM kernel occupies (it fills the machine unless there
    /// are fewer tiles than CUs — small decomposed GEMMs leave CUs
    /// idle, which is exactly what lets unfused FiCCO schedules run
    /// several small GEMMs concurrently).
    pub fn cus_used(&self, g: &GemmShape) -> usize {
        let (_, (tm, tn)) = self.compute_time(g);
        if tm == 0 {
            return self.gpu.cus;
        }
        let tiles = div_up(g.m, tm) * div_up(g.n, tn);
        (tiles as usize).min(self.gpu.cus)
    }

    /// Aggregate DIL of decomposing `g` into `ways` shards along `dim`
    /// and executing them back-to-back on one GPU (the paper's Fig 7
    /// metric): Σ t(shard) / t(whole).
    pub fn dil(&self, g: &GemmShape, dim: Sharding, ways: u64) -> f64 {
        let whole = self.time(g);
        let piece = g.shard(dim, ways);
        let pieces_time = ways as f64 * self.time(&piece);
        pieces_time / whole
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::GpuSpec;

    fn cost(gpu: &GpuSpec) -> GemmCost<'_> {
        GemmCost::new(gpu)
    }

    #[test]
    fn flops_bytes_otb() {
        let g = GemmShape::new(1024, 512, 2048);
        assert_eq!(g.flops(), 2.0 * 1024.0 * 512.0 * 2048.0);
        assert!(g.otb() > 0.0);
        // accumulate adds C read traffic
        let acc = g.accumulating();
        assert!(acc.bytes() > g.bytes());
    }

    #[test]
    fn shard_row_divides_m() {
        let g = GemmShape::new(1000, 512, 2048);
        let s = g.shard(Sharding::Row, 8);
        assert_eq!(s.m, 125);
        assert!(!s.accumulate);
        let s64 = g.shard(Sharding::Row, 64);
        assert_eq!(s64.m, 16); // ceil(1000/64)
    }

    #[test]
    fn shard_col_divides_k_and_accumulates() {
        let g = GemmShape::new(1024, 512, 2048);
        let s = g.shard(Sharding::Col, 8);
        assert_eq!(s.k, 256);
        assert!(s.accumulate);
    }

    #[test]
    fn big_gemm_near_peak() {
        let gpu = GpuSpec::mi300x();
        let c = cost(&gpu);
        let g = GemmShape::new(16384, 16384, 16384);
        let eff = c.efficiency(&g);
        assert!(eff > 0.75, "large-GEMM efficiency {eff}");
    }

    #[test]
    fn tiny_gemm_low_efficiency() {
        let gpu = GpuSpec::mi300x();
        let c = cost(&gpu);
        let g = GemmShape::new(256, 256, 512);
        let eff = c.efficiency(&g);
        assert!(eff < 0.3, "tiny-GEMM efficiency {eff}");
    }

    #[test]
    fn dil_at_least_one_and_grows_with_ways() {
        let gpu = GpuSpec::mi300x();
        let c = cost(&gpu);
        // paper's g1
        let g = GemmShape::new(16384, 16384, 131072);
        for dim in [Sharding::Row, Sharding::Col] {
            let d8 = c.dil(&g, dim, 8);
            let d64 = c.dil(&g, dim, 64);
            assert!(d8 >= 0.999, "{dim:?} d8={d8}");
            assert!(d64 >= d8 * 0.999, "{dim:?} d8={d8} d64={d64}");
        }
    }

    #[test]
    fn row_shard_hurts_when_m_lt_k() {
        // paper observation 2 (Fig 7): g1-like (M << K) row sharding
        // is worse than col sharding; g2-like (M >> K) the reverse.
        let gpu = GpuSpec::mi300x();
        let c = cost(&gpu);
        let g1 = GemmShape::new(16384, 16384, 131072); // M < K
        assert!(
            c.dil(&g1, Sharding::Row, 64) > c.dil(&g1, Sharding::Col, 64),
            "row {} col {}",
            c.dil(&g1, Sharding::Row, 64),
            c.dil(&g1, Sharding::Col, 64)
        );
        let g2 = GemmShape::new(131072, 16384, 16384); // M > K
        assert!(
            c.dil(&g2, Sharding::Col, 64) > c.dil(&g2, Sharding::Row, 64),
            "row {} col {}",
            c.dil(&g2, Sharding::Row, 64),
            c.dil(&g2, Sharding::Col, 64)
        );
    }

    #[test]
    fn memory_bound_gemm_hits_roofline() {
        let gpu = GpuSpec::mi300x();
        let c = cost(&gpu);
        // Skinny K → memory bound.
        let g = GemmShape::new(65536, 128, 128);
        let t = c.time(&g);
        let t_mem = g.bytes() / (c.hbm_eff * gpu.hbm_bw);
        assert!(t >= t_mem);
        assert!(t < 3.0 * t_mem, "should be near memory roofline");
    }

    #[test]
    fn cus_used_small_gemm_partial() {
        let gpu = GpuSpec::mi300x();
        let c = cost(&gpu);
        let small = GemmShape::new(256, 256, 8192);
        assert!(c.cus_used(&small) < gpu.cus);
        let big = GemmShape::new(16384, 16384, 8192);
        assert_eq!(c.cus_used(&big), gpu.cus);
    }
}
