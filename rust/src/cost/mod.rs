//! Analytical cost models for the operators the paper overlaps.
//!
//! - [`gemm`] — GEMM execution time with a utilization model that
//!   produces the paper's *Decomposition-Inefficiency Loss* (DIL,
//!   §IV-C1) from static (M, N, K): tile/wave quantization on the CU
//!   array, short-K pipeline startup, accumulate-GEMM extra traffic,
//!   and the roofline memory bound.
//! - [`collective`] — closed-form collective times over a topology
//!   (ring vs all-to-all all-gather, all-to-all dispersal), kernel- vs
//!   DMA-driven; produces communication DIL (§IV-C2).
//! - [`contention`] — closed-form proportional-share CIL estimates
//!   (§IV-D) used to cross-check the fluid simulator.

pub mod collective;
pub mod contention;
pub mod gemm;

pub use collective::{ag_all_to_all_time, ag_ring_time, p2p_time, CollectiveCost};
pub use gemm::{GemmCost, GemmShape, Sharding};
