//! Closed-form collective communication costs over a topology.
//!
//! These formulas serve three purposes: (a) the serial-baseline
//! communication leg in every figure, (b) communication-DIL
//! characterization (Fig 8) without running the full simulator, and
//! (c) cross-checks of the simulator's emergent behaviour
//! (`rust/tests/sim_vs_closed_form.rs`).
//!
//! Conventions: `shard_bytes` is the per-GPU contribution (what each
//! rank holds before an all-gather / what it must send in total for an
//! all-to-all). Times are for the whole collective across all ranks,
//! all ranks starting simultaneously.

use crate::hw::{GpuSpec, Topology};
use crate::sim::CommMech;

/// Per-transfer fixed overhead for a mechanism (issue + sync).
pub fn xfer_overhead(gpu: &GpuSpec, topo: &Topology, mech: CommMech) -> f64 {
    match mech {
        CommMech::Kernel => topo.latency + gpu.kernel_launch,
        CommMech::Dma => topo.latency + 0.25 * gpu.kernel_launch,
    }
}

/// Sustained rate of one transfer (matches `sim::cluster`'s model).
pub fn link_rate(gpu: &GpuSpec, topo: &Topology, bytes: f64, mech: CommMech) -> f64 {
    match mech {
        CommMech::Kernel => topo.effective_bw(bytes) * gpu.kernel_link_eff,
        CommMech::Dma => (topo.effective_bw(bytes) * gpu.dma_link_eff).min(gpu.dma_engine_bw),
    }
}

/// Single point-to-point transfer time (isolated).
pub fn p2p_time(gpu: &GpuSpec, topo: &Topology, bytes: f64, mech: CommMech) -> f64 {
    xfer_overhead(gpu, topo, mech) + bytes / link_rate(gpu, topo, bytes, mech)
}

/// All-gather via simultaneous direct exchange ("one-shot"): every GPU
/// sends its full shard to every peer on dedicated links. This is the
/// bandwidth-optimal algorithm on a full mesh and what the serial
/// RCCL/DMA baseline achieves.
pub fn ag_all_to_all_time(gpu: &GpuSpec, topo: &Topology, shard_bytes: f64, mech: CommMech) -> f64 {
    match topo.kind {
        crate::hw::TopologyKind::Switch => {
            // NIC carries (n-1) shards out of each GPU serially.
            let total = (topo.ngpus - 1) as f64 * shard_bytes;
            xfer_overhead(gpu, topo, mech) + total / link_rate(gpu, topo, shard_bytes, mech)
        }
        _ => p2p_time(gpu, topo, shard_bytes, mech),
    }
}

/// All-gather via a ring of peer-to-peer shard hops — the pattern
/// shard-based overlap (PyTorch AsyncTP-like) induces: `n-1` serial
/// steps, each moving one shard over ONE link per GPU. On a full mesh
/// this leaves `n-2` links idle per GPU (the paper's Fig 13 problem);
/// on a switch it runs at full NIC rate.
pub fn ag_ring_time(gpu: &GpuSpec, topo: &Topology, shard_bytes: f64, mech: CommMech) -> f64 {
    let steps = (topo.ngpus - 1) as f64;
    steps * p2p_time(gpu, topo, shard_bytes, mech)
}

/// FiCCO's finer-grain all-gather: each shard split into `n` pieces;
/// at each of `n` steps every GPU broadcasts one piece to all peers on
/// parallel links (steady-state all-to-all, Fig 4c). Returns the total
/// serial-communication time (the denominator for comm DIL).
pub fn ag_ficco_time(gpu: &GpuSpec, topo: &Topology, shard_bytes: f64, mech: CommMech) -> f64 {
    let n = topo.ngpus as f64;
    let piece = shard_bytes / n;
    n * p2p_time(gpu, topo, piece, mech)
}

/// All-to-all dispersal (expert parallelism): every GPU sends
/// `shard_bytes/n` to each peer simultaneously on its dedicated links.
pub fn a2a_time(gpu: &GpuSpec, topo: &Topology, shard_bytes: f64, mech: CommMech) -> f64 {
    let n = topo.ngpus as f64;
    p2p_time(gpu, topo, shard_bytes / n, mech)
}

/// Communication DIL of FiCCO's decomposition (Fig 8 metric):
/// finer-grain AG time / baseline one-shot AG time.
pub fn comm_dil(gpu: &GpuSpec, topo: &Topology, shard_bytes: f64, mech: CommMech) -> f64 {
    ag_ficco_time(gpu, topo, shard_bytes, mech) / ag_all_to_all_time(gpu, topo, shard_bytes, mech)
}

// ---------------------------------------------------------------------
// Per-peer (non-uniform traffic) closed forms. Skewed expert routing
// makes per-GPU shard sizes differ, so the scalar `shard_bytes`
// formulas above no longer describe the collective; these variants
// take the per-GPU byte vector instead. With all entries equal they
// reduce to the scalar forms (the scalar paths are kept verbatim for
// `skew == 0` so frozen goldens stay bit-stable).
// ---------------------------------------------------------------------

/// One-shot all-gather with per-GPU shard sizes: on a mesh every
/// (src, dst) pair has a dedicated lane, so the largest shard's
/// point-to-point time dominates; on a switch each NIC serializes its
/// `(n-1)` outgoing shard copies against its incoming remote total —
/// every message is priced at the rate *its own* size sustains (a
/// cold GPU's tiny shard must not drag the rate applied to the bytes
/// arriving from hot peers).
pub fn ag_all_to_all_time_vec(
    gpu: &GpuSpec,
    topo: &Topology,
    shard_bytes: &[f64],
    mech: CommMech,
) -> f64 {
    let n = shard_bytes.len();
    match topo.kind {
        crate::hw::TopologyKind::Switch => {
            // Per-message wire time at that message's own rate.
            let msg_time =
                |b: f64| -> f64 { b / link_rate(gpu, topo, b, mech) };
            let rx_all: f64 = shard_bytes.iter().map(|&b| msg_time(b)).sum();
            shard_bytes
                .iter()
                .map(|&b| {
                    let tx = (n - 1) as f64 * b / link_rate(gpu, topo, b, mech);
                    let rx = rx_all - msg_time(b);
                    xfer_overhead(gpu, topo, mech) + tx.max(rx)
                })
                .fold(0.0, f64::max)
        }
        _ => shard_bytes
            .iter()
            .map(|&b| p2p_time(gpu, topo, b, mech))
            .fold(0.0, f64::max),
    }
}

/// Ring all-gather with per-GPU shard sizes: `n-1` serial hops per
/// receiver, each moving one remote shard — the worst receiver pays
/// the sum over all remote shards' point-to-point times.
pub fn ag_ring_time_vec(
    gpu: &GpuSpec,
    topo: &Topology,
    shard_bytes: &[f64],
    mech: CommMech,
) -> f64 {
    (0..shard_bytes.len())
        .map(|r| {
            shard_bytes
                .iter()
                .enumerate()
                .filter(|&(q, _)| q != r)
                .map(|(_, &b)| p2p_time(gpu, topo, b, mech))
                .sum::<f64>()
        })
        .fold(0.0, f64::max)
}

/// FiCCO finer-grain all-gather with per-GPU shard sizes, each shard
/// split into `pieces`: every step moves one piece of every shard on
/// parallel lanes, so each step is paced by the largest piece.
pub fn ag_ficco_time_vec(
    gpu: &GpuSpec,
    topo: &Topology,
    shard_bytes: &[f64],
    pieces: usize,
    mech: CommMech,
) -> f64 {
    let max_piece = shard_bytes.iter().fold(0.0, |a: f64, &b| a.max(b)) / pieces as f64;
    pieces as f64 * p2p_time(gpu, topo, max_piece, mech)
}

/// Bundle of the collective legs a scenario can need.
#[derive(Debug, Clone, Copy)]
pub struct CollectiveCost {
    pub serial_baseline: f64,
    pub shard_overlap_total: f64,
    pub ficco_total: f64,
}

impl CollectiveCost {
    pub fn all_gather(gpu: &GpuSpec, topo: &Topology, shard_bytes: f64, mech: CommMech) -> Self {
        CollectiveCost {
            serial_baseline: ag_all_to_all_time(gpu, topo, shard_bytes, mech),
            shard_overlap_total: ag_ring_time(gpu, topo, shard_bytes, mech),
            ficco_total: ag_ficco_time(gpu, topo, shard_bytes, mech),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::Machine;

    fn m8() -> Machine {
        Machine::mi300x_8()
    }

    #[test]
    fn ring_is_about_7x_one_shot_on_mesh() {
        // The paper's observed ~7x communication slowdown for
        // shard-overlap P2P on the 8-GPU mesh (§VI-B).
        let m = m8();
        let shard = 512e6;
        let ratio = ag_ring_time(&m.gpu, &m.topo, shard, CommMech::Dma)
            / ag_all_to_all_time(&m.gpu, &m.topo, shard, CommMech::Dma);
        assert!((6.5..7.5).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn ring_fine_on_switch() {
        // Kernel-driven transfers can use the full NIC rate (a single
        // DMA engine could not — it is engine-capped at 64 GB/s).
        let m = Machine::switch_8();
        let shard = 512e6;
        let ring = ag_ring_time(&m.gpu, &m.topo, shard, CommMech::Kernel);
        let oneshot = ag_all_to_all_time(&m.gpu, &m.topo, shard, CommMech::Kernel);
        // On a switch both move (n-1)·shard through the NIC.
        assert!(ring / oneshot < 1.2, "ring={ring} oneshot={oneshot}");
    }

    #[test]
    fn comm_dil_positive_and_shrinks_with_size() {
        let m = m8();
        let small = comm_dil(&m.gpu, &m.topo, 16e6, CommMech::Dma);
        let large = comm_dil(&m.gpu, &m.topo, 1024e6, CommMech::Dma);
        assert!(small > large, "small={small} large={large}");
        assert!(large >= 1.0);
        assert!(small > 1.05, "fine grains of a 16MB shard should pay >5%");
    }

    #[test]
    fn comm_dil_geomean_near_paper() {
        // Fig 8: geomean comm DIL ≈ 1.10 over the studied shard sizes.
        let m = m8();
        // Shard sizes (bytes) spanning Table I scenarios' AG inputs.
        let sizes = [150e6, 235e6, 335e6, 537e6, 537e6, 805e6, 1.74e9, 2.15e9, 3.3e9];
        let dils: Vec<f64> = sizes
            .iter()
            .map(|&s| comm_dil(&m.gpu, &m.topo, s, CommMech::Dma))
            .collect();
        let g = crate::util::stats::geomean(&dils);
        assert!((1.03..1.30).contains(&g), "geomean comm DIL {g}");
    }

    #[test]
    fn a2a_faster_than_ag() {
        let m = m8();
        let s = 256e6;
        assert!(
            a2a_time(&m.gpu, &m.topo, s, CommMech::Dma)
                < ag_all_to_all_time(&m.gpu, &m.topo, s, CommMech::Dma)
        );
    }

    #[test]
    fn vec_forms_reduce_to_scalar_on_uniform_traffic() {
        let m = m8();
        let shard = 256e6;
        let uniform = vec![shard; 8];
        for mech in [CommMech::Dma, CommMech::Kernel] {
            let one = ag_all_to_all_time_vec(&m.gpu, &m.topo, &uniform, mech);
            assert!(
                (one - ag_all_to_all_time(&m.gpu, &m.topo, shard, mech)).abs() / one < 1e-12,
                "one-shot"
            );
            let ring = ag_ring_time_vec(&m.gpu, &m.topo, &uniform, mech);
            assert!(
                (ring - ag_ring_time(&m.gpu, &m.topo, shard, mech)).abs() / ring < 1e-9,
                "ring"
            );
            let ficco = ag_ficco_time_vec(&m.gpu, &m.topo, &uniform, 8, mech);
            assert!(
                (ficco - ag_ficco_time(&m.gpu, &m.topo, shard, mech)).abs() / ficco < 1e-12,
                "ficco"
            );
        }
        // Switch topology one-shot reduction too.
        let sw = Machine::switch_8();
        let one = ag_all_to_all_time_vec(&sw.gpu, &sw.topo, &uniform, CommMech::Kernel);
        let scalar = ag_all_to_all_time(&sw.gpu, &sw.topo, shard, CommMech::Kernel);
        assert!((one - scalar).abs() / one < 1e-12, "switch one-shot");
    }

    #[test]
    fn skewed_traffic_is_paced_by_the_hot_shard() {
        let m = m8();
        let mut skewed = vec![128e6; 8];
        skewed[3] = 1024e6;
        let uniform = vec![240e6; 8]; // same total bytes
        for mech in [CommMech::Dma, CommMech::Kernel] {
            assert!(
                ag_all_to_all_time_vec(&m.gpu, &m.topo, &skewed, mech)
                    > ag_all_to_all_time_vec(&m.gpu, &m.topo, &uniform, mech),
                "hot shard must dominate the one-shot exchange"
            );
            assert!(
                ag_ficco_time_vec(&m.gpu, &m.topo, &skewed, 8, mech)
                    > ag_ficco_time_vec(&m.gpu, &m.topo, &uniform, 8, mech),
                "hot pieces pace every FiCCO step"
            );
        }
        // Switch: the hot NIC's serialized sends pace the exchange; a
        // cold GPU's tiny own-shard rate must not poison the pricing
        // of the bytes arriving from hot peers — the skewed time stays
        // within the hot GPU's own send envelope, far from the
        // pathological cold-rate blowup.
        let sw = Machine::switch_8();
        let t_skew = ag_all_to_all_time_vec(&sw.gpu, &sw.topo, &skewed, CommMech::Kernel);
        let t_hot_uniform =
            ag_all_to_all_time_vec(&sw.gpu, &sw.topo, &vec![1024e6; 8], CommMech::Kernel);
        assert!(
            t_skew <= t_hot_uniform * (1.0 + 1e-12),
            "skewed switch exchange {t_skew} above all-hot envelope {t_hot_uniform}"
        );
        assert!(
            t_skew > ag_all_to_all_time_vec(&sw.gpu, &sw.topo, &uniform, CommMech::Kernel),
            "hot NIC must still pace the switch exchange"
        );
    }

    #[test]
    fn dma_capped_by_engine_rate() {
        let mut m = m8();
        m.gpu.dma_engine_bw = 16e9; // slow engines
        let t_dma = p2p_time(&m.gpu, &m.topo, 64e6, CommMech::Dma);
        let t_krn = p2p_time(&m.gpu, &m.topo, 64e6, CommMech::Kernel);
        assert!(t_dma > t_krn);
    }
}
