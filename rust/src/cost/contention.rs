//! Closed-form contention (CIL) estimates.
//!
//! The fluid simulator produces CIL emergently; these proportional-
//! share formulas predict the same quantities analytically. They are
//! used (a) as cross-checks in `rust/tests/`, and (b) by the heuristic
//! calibration, which needs thousands of cheap evaluations.
//!
//! Model (matches `sim::cluster`'s resource demands): a GEMM and a
//! communication stream overlap; both demand HBM bandwidth, and
//! core-driven comm additionally demands CUs and inflates its HBM
//! traffic by a cache-pollution factor. Under proportional sharing the
//! GEMM's rate is `min(cu_share/cu_need, hbm_share/hbm_need, 1)`.

use crate::hw::{GpuSpec, Topology};
use crate::sim::CommMech;

use super::gemm::{GemmCost, GemmShape};

/// Inputs: a GEMM kernel overlapped with a sustained communication
/// stream moving `comm_bw` bytes/s through this GPU's HBM.
#[derive(Debug, Clone, Copy)]
pub struct OverlapPoint {
    /// GEMM isolated time (s).
    pub gemm_time: f64,
    /// GEMM HBM demand while running, bytes/s.
    pub gemm_hbm: f64,
    /// GEMM CU occupancy (0..=cus).
    pub gemm_cus: f64,
    /// Communication link-rate through this GPU, bytes/s (aggregate
    /// over all active streams).
    pub comm_bw: f64,
    /// Number of concurrent transfer streams (kernel comm occupies
    /// `comm_kernel_cus` CUs per stream).
    pub comm_streams: usize,
    pub mech: CommMech,
}

/// Closed-form slowdown factors (GEMM CIL, comm CIL) for an overlap
/// point under proportional sharing of CUs and HBM.
pub fn cil(gpu: &GpuSpec, p: &OverlapPoint) -> (f64, f64) {
    let (comm_cus, pollution) = match p.mech {
        CommMech::Kernel => (
            (p.comm_streams * gpu.comm_kernel_cus) as f64,
            gpu.comm_cache_pollution,
        ),
        CommMech::Dma => (0.0, 1.0),
    };
    // src read + dst write sides, amplified at the memory subsystem
    // (see GpuSpec::comm_hbm_amp).
    let comm_hbm = p.comm_bw * 2.0 * pollution * gpu.comm_hbm_amp;

    // Proportional share on each resource, capped at demand.
    let cu_total = p.gemm_cus + comm_cus;
    let gemm_cu_share = if cu_total <= gpu.cus as f64 {
        1.0
    } else {
        (p.gemm_cus / cu_total * gpu.cus as f64) / p.gemm_cus
    };
    let comm_cu_share = if comm_cus == 0.0 {
        1.0
    } else if cu_total <= gpu.cus as f64 {
        1.0
    } else {
        (comm_cus / cu_total * gpu.cus as f64) / comm_cus
    };

    let hbm_total = p.gemm_hbm + comm_hbm;
    let (gemm_hbm_share, comm_hbm_share) = if hbm_total <= gpu.hbm_bw {
        (1.0, 1.0)
    } else {
        (
            (p.gemm_hbm / hbm_total * gpu.hbm_bw) / p.gemm_hbm.max(1e-9),
            (comm_hbm / hbm_total * gpu.hbm_bw) / comm_hbm.max(1e-9),
        )
    };

    let gemm_rate = gemm_cu_share.min(gemm_hbm_share).min(1.0);
    let comm_rate = comm_cu_share.min(comm_hbm_share).min(1.0);
    (1.0 / gemm_rate.max(1e-9), 1.0 / comm_rate.max(1e-9))
}

/// Convenience: CIL of a GEMM shape overlapped with FiCCO-style
/// all-to-all traffic at full aggregate link rate.
pub fn gemm_cil_under_a2a(
    gpu: &GpuSpec,
    topo: &Topology,
    shape: &GemmShape,
    mech: CommMech,
) -> (f64, f64) {
    let cost = GemmCost::new(gpu);
    let t = cost.time(shape);
    // Effective per-link rate (mechanism-dependent), all peers active.
    let per_link = crate::cost::collective::link_rate(gpu, topo, 1e12, mech);
    let p = OverlapPoint {
        gemm_time: t,
        gemm_hbm: gpu.hbm_burst * shape.bytes() / t,
        gemm_cus: cost.cus_used(shape) as f64,
        comm_bw: (topo.ngpus - 1) as f64 * per_link,
        comm_streams: topo.ngpus - 1,
        mech,
    };
    cil(gpu, &p)
}

/// Aggregate sustained comm bandwidth through one GPU when per-peer
/// transfer sizes differ (skewed expert routing): each active peer
/// lane runs at the rate its own transfer size sustains; zero-byte
/// peers (empty shards) hold no lane at all.
pub fn peer_comm_bw(gpu: &GpuSpec, topo: &Topology, peer_bytes: &[f64], mech: CommMech) -> f64 {
    peer_bytes
        .iter()
        .filter(|&&b| b > 0.0)
        .map(|&b| crate::cost::collective::link_rate(gpu, topo, b, mech))
        .sum()
}

/// As [`gemm_cil_under_a2a`], with a per-peer byte vector instead of
/// the all-equal assumption: the comm pressure on this GPU is the sum
/// of the rates its *active* peer lanes sustain.
pub fn gemm_cil_under_a2a_vec(
    gpu: &GpuSpec,
    topo: &Topology,
    shape: &GemmShape,
    mech: CommMech,
    peer_bytes: &[f64],
) -> (f64, f64) {
    let cost = GemmCost::new(gpu);
    let t = cost.time(shape);
    let streams = peer_bytes.iter().filter(|&&b| b > 0.0).count();
    let p = OverlapPoint {
        gemm_time: t,
        gemm_hbm: gpu.hbm_burst * shape.bytes() / t,
        gemm_cus: cost.cus_used(shape) as f64,
        comm_bw: peer_comm_bw(gpu, topo, peer_bytes, mech),
        comm_streams: streams,
        mech,
    };
    cil(gpu, &p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::Machine;

    #[test]
    fn dma_cil_below_kernel_cil_for_compute_bound() {
        // Instantaneous closed form: for compute-bound GEMMs the CU
        // steal of core-driven comm dominates, so kernel CIL > DMA CIL.
        // (For memory-bound GEMMs the *instantaneous* DMA pressure can
        // exceed the slower kernel stream's; the duration-integrated
        // comparison — where RCCL is strictly worse, Fig 9 — is
        // exercised in `metrics::fig9_cil`.)
        let m = Machine::mi300x_8();
        let shape = GemmShape::new(16384, 16384, 131072);
        let (g_dma, _) = gemm_cil_under_a2a(&m.gpu, &m.topo, &shape, CommMech::Dma);
        let (g_krn, _) = gemm_cil_under_a2a(&m.gpu, &m.topo, &shape, CommMech::Kernel);
        assert!(g_krn >= g_dma, "kernel {g_krn} < dma {g_dma}");
        assert!(g_dma >= 1.0);
    }

    #[test]
    fn cil_grows_with_memory_traffic() {
        // Fig 9: CIL positively correlates with GEMM memory traffic.
        let m = Machine::mi300x_8();
        let light = GemmShape::new(8192, 8192, 65536); // compute-bound
        let heavy = GemmShape::new(1048576, 8192, 1024); // memory-bound
        let (g_l, _) = gemm_cil_under_a2a(&m.gpu, &m.topo, &light, CommMech::Dma);
        let (g_h, _) = gemm_cil_under_a2a(&m.gpu, &m.topo, &heavy, CommMech::Dma);
        assert!(g_h > g_l, "heavy {g_h} <= light {g_l}");
    }

    #[test]
    fn compute_bound_gemm_mildly_affected_by_dma_comm() {
        let m = Machine::mi300x_8();
        // Huge-K compute-bound GEMM: HBM demand small → only the
        // residual memory interference of DMA traffic (§II-B) shows.
        let shape = GemmShape::new(16384, 16384, 131072);
        let (g, _) = gemm_cil_under_a2a(&m.gpu, &m.topo, &shape, CommMech::Dma);
        assert!(g < 1.15, "cil={g}");
    }

    #[test]
    fn per_peer_cil_matches_uniform_and_drops_with_idle_lanes() {
        let m = Machine::mi300x_8();
        let shape = GemmShape::new(1048576, 8192, 1024); // memory-bound
        // Large equal transfers: per-peer aggregation reproduces the
        // uniform convenience form.
        let uniform = vec![1e12; m.topo.ngpus - 1];
        let (g_vec, c_vec) =
            gemm_cil_under_a2a_vec(&m.gpu, &m.topo, &shape, CommMech::Kernel, &uniform);
        let (g_uni, c_uni) = gemm_cil_under_a2a(&m.gpu, &m.topo, &shape, CommMech::Kernel);
        assert!((g_vec - g_uni).abs() < 1e-9 && (c_vec - c_uni).abs() < 1e-9);
        // Skew that empties some peers' shards idles their lanes: less
        // aggregate pressure, so GEMM CIL cannot grow.
        let sparse = vec![1e12, 0.0, 0.0, 1e12, 0.0, 0.0, 0.0];
        let (g_sparse, _) =
            gemm_cil_under_a2a_vec(&m.gpu, &m.topo, &shape, CommMech::Kernel, &sparse);
        assert!(g_sparse <= g_uni + 1e-12, "sparse {g_sparse} vs full {g_uni}");
        assert!(
            peer_comm_bw(&m.gpu, &m.topo, &sparse, CommMech::Dma)
                < peer_comm_bw(&m.gpu, &m.topo, &uniform, CommMech::Dma)
        );
    }

    #[test]
    fn kernel_comm_suffers_when_gemm_fills_machine() {
        let m = Machine::mi300x_8();
        let shape = GemmShape::new(131072, 16384, 16384);
        let (_, c_krn) = gemm_cil_under_a2a(&m.gpu, &m.topo, &shape, CommMech::Kernel);
        assert!(c_krn > 1.0);
    }
}
