//! Figure/table renderers: one function per paper exhibit.
//!
//! Each returns both the raw series (for assertions in tests and for
//! CSV export) and a formatted [`Table`] matching the rows the paper
//! plots. The bench binaries (`rust/benches/fig*.rs`) are thin wrappers
//! over these so `cargo bench` regenerates every exhibit.

use crate::cost::collective as cc;
use crate::cost::gemm::{GemmCost, GemmShape, Sharding};
use crate::hw::Machine;
use crate::schedule::exec::ScenarioEval;
use crate::schedule::{Kind, Scenario};
use crate::sim::{ClusterSim, CommMech};
use crate::util::stats;
use crate::util::table::{f, x, Align, Table};
use crate::workloads::{table1, Table1Row};

/// Raw + rendered exhibit.
pub struct Exhibit {
    pub title: &'static str,
    pub table: Table,
    /// Named scalar summaries (e.g. geomeans) for tests/EXPERIMENTS.md.
    pub summaries: Vec<(String, f64)>,
}

impl Exhibit {
    pub fn summary(&self, name: &str) -> f64 {
        self.summaries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("no summary '{name}'"))
    }

    /// Render to *stderr* — stdout is reserved for machine-readable
    /// output (`figures --csv` pipes CSV there), and `--quiet`
    /// suppresses exhibits entirely.
    pub fn print(&self) {
        if crate::util::quiet() {
            return;
        }
        eprintln!("== {} ==", self.title);
        eprint!("{}", self.table.render());
        for (n, v) in &self.summaries {
            eprintln!("  {n}: {v:.4}");
        }
        eprintln!();
    }

    /// Write the exhibit's table as CSV (used by `figures --csv` and
    /// the sweep summary).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        self.table.write_csv(path)
    }
}

/// Fig 7 — GEMM DIL under 8-way / 64-way row- and column-sharding.
pub fn fig7_gemm_dil(machine: &Machine) -> Exhibit {
    let cost = GemmCost::new(&machine.gpu);
    let mut table = Table::new(vec![
        "gemm", "M", "N", "K", "OTB", "row8", "col8", "row64", "col64",
    ])
    .align(0, Align::Left);
    let mut rows8 = Vec::new();
    let mut rows64 = Vec::new();
    // The paper's observation 3 correlates DIL with the *resultant*
    // (sharded) GEMM's static OTB; collect every sharded point.
    let mut piece_otbs = Vec::new();
    let mut piece_dils = Vec::new();
    for r in table1() {
        let g = GemmShape::new(r.m, r.n, r.k);
        let mut d = |dim, ways: u64| {
            let dil = cost.dil(&g, dim, ways);
            piece_otbs.push(g.shard(dim, ways).otb());
            piece_dils.push(dil);
            dil
        };
        let (r8, c8) = (d(Sharding::Row, 8), d(Sharding::Col, 8));
        let (r64, c64) = (d(Sharding::Row, 64), d(Sharding::Col, 64));
        table.row(vec![
            r.name.to_string(),
            r.m.to_string(),
            r.n.to_string(),
            r.k.to_string(),
            f(g.otb(), 0),
            x(r8),
            x(c8),
            x(r64),
            x(c64),
        ]);
        rows8.push(r8.min(c8));
        rows64.push(r64.min(c64));
    }
    let corr = stats::spearman(&piece_otbs, &piece_dils);
    Exhibit {
        title: "Fig 7: GEMM decomposition-inefficiency loss (DIL)",
        table,
        summaries: vec![
            ("geomean_dil_8way_best".into(), stats::geomean(&rows8)),
            ("geomean_dil_64way_best".into(), stats::geomean(&rows64)),
            ("spearman_otb_vs_dil64".into(), corr),
        ],
    }
}

/// Fig 8 — communication DIL for the DMA all-gather at FiCCO grain.
pub fn fig8_comm_dil(machine: &Machine) -> Exhibit {
    let mut table = Table::new(vec!["gemm", "shard MiB", "piece MiB", "comm DIL"])
        .align(0, Align::Left);
    let mut dils = Vec::new();
    let mut sizes = Vec::new();
    for r in table1() {
        let sc = r.scenario();
        let shard = sc.shard_bytes();
        let dil = cc::comm_dil(&machine.gpu, &machine.topo, shard, CommMech::Dma);
        table.row(vec![
            r.name.to_string(),
            f(shard / (1 << 20) as f64, 1),
            f(shard / sc.ngpus as f64 / (1 << 20) as f64, 1),
            x(dil),
        ]);
        dils.push(dil);
        sizes.push(shard);
    }
    Exhibit {
        title: "Fig 8: communication DIL (DMA all-gather, 8x finer grain)",
        table,
        summaries: vec![
            ("geomean_comm_dil".into(), stats::geomean(&dils)),
            ("spearman_size_vs_dil".into(), stats::spearman(&sizes, &dils)),
        ],
    }
}

/// The Fig 9 protocol: an 8-way M-sharded GEMM runs concurrently with
/// an all-gather of the scenario input; report (GEMM slowdown, comm
/// slowdown) vs isolated execution.
pub fn cil_point(machine: &Machine, row: &Table1Row, mech: CommMech) -> (f64, f64) {
    let sc = row.scenario();
    let n = sc.ngpus;
    let cost = GemmCost::new(&machine.gpu);
    let piece = sc.gemm.shard(Sharding::Row, n as u64);

    let mut sim = ClusterSim::new(machine.clone());
    let mut gemms = Vec::new();
    let mut xfers = Vec::new();
    let t = cost.time(&piece);
    for gpu in 0..n {
        gemms.push(sim.gemm_task(
            gpu,
            format!("gemm g{gpu}"),
            t,
            piece.bytes(),
            cost.cus_used(&piece),
            &[],
        ));
        for (slot, dst) in (0..n).filter(|&d| d != gpu).enumerate() {
            xfers.push(sim.transfer_task(
                gpu,
                dst,
                slot,
                format!("ag {gpu}->{dst}"),
                sc.shard_bytes(),
                mech,
                &[],
            ));
        }
    }
    let rep = sim.run().expect("cil sim");
    let g: f64 = gemms.iter().map(|&t| rep.slowdown(t)).sum::<f64>() / gemms.len() as f64;
    let c: f64 = xfers.iter().map(|&t| rep.slowdown(t)).sum::<f64>() / xfers.len() as f64;
    (g, c)
}

/// Fig 9 — contention-inefficiency loss for GEMM (left) and the
/// all-gather (right), RCCL-style vs DMA.
pub fn fig9_cil(machine: &Machine) -> Exhibit {
    let mut table = Table::new(vec![
        "gemm", "MT GiB", "gemm CIL (rccl)", "gemm CIL (dma)", "comm CIL (dma)",
    ])
    .align(0, Align::Left);
    let mut g_rccl = Vec::new();
    let mut g_dma = Vec::new();
    let mut c_dma = Vec::new();
    let mut mts = Vec::new();
    for r in table1() {
        let (gr, _) = cil_point(machine, &r, CommMech::Kernel);
        let (gd, cd) = cil_point(machine, &r, CommMech::Dma);
        let mt = GemmShape::new(r.m, r.n, r.k).mt();
        table.row(vec![
            r.name.to_string(),
            f(mt / (1u64 << 30) as f64, 1),
            x(gr),
            x(gd),
            x(cd),
        ]);
        g_rccl.push(gr);
        g_dma.push(gd);
        c_dma.push(cd);
        mts.push(mt);
    }
    Exhibit {
        title: "Fig 9: contention-inefficiency loss (CIL), RCCL vs DMA",
        table,
        summaries: vec![
            ("geomean_gemm_cil_rccl".into(), stats::geomean(&g_rccl)),
            ("geomean_gemm_cil_dma".into(), stats::geomean(&g_dma)),
            ("geomean_comm_cil_dma".into(), stats::geomean(&c_dma)),
            ("spearman_mt_vs_gemm_cil".into(), stats::spearman(&mts, &g_dma)),
        ],
    }
}

/// Fig 10 — proportion of DIL vs CIL per scenario (8-way GEMM, 64-way
/// GEMM, and the all-gather).
pub fn fig10_proportions(machine: &Machine) -> Exhibit {
    let cost = GemmCost::new(&machine.gpu);
    let mut table = Table::new(vec![
        "gemm",
        "DIL% (g8)",
        "CIL% (g8)",
        "DIL% (g64)",
        "CIL% (g64)",
        "DIL% (ag)",
        "CIL% (ag)",
    ])
    .align(0, Align::Left);
    let mut sums = Vec::new();
    for r in table1() {
        let g = GemmShape::new(r.m, r.n, r.k);
        let dil8 = cost.dil(&g, Sharding::Row, 8) - 1.0;
        let dil64 = cost.dil(&g, Sharding::Row, 64) - 1.0;
        let (cil_g, cil_c) = cil_point(machine, &r, CommMech::Dma);
        let (cil_g, cil_c) = (cil_g - 1.0, cil_c - 1.0);
        let sc = r.scenario();
        let dil_c =
            cc::comm_dil(&machine.gpu, &machine.topo, sc.shard_bytes(), CommMech::Dma) - 1.0;
        let pct = |d: f64, c: f64| {
            let t = (d + c).max(1e-12);
            (100.0 * d / t, 100.0 * c / t)
        };
        let (d8, c8) = pct(dil8, cil_g);
        let (d64, c64) = pct(dil64, cil_g);
        let (dc, cc_) = pct(dil_c, cil_c);
        table.row(vec![
            r.name.to_string(),
            f(d8, 0),
            f(c8, 0),
            f(d64, 0),
            f(c64, 0),
            f(dc, 0),
            f(cc_, 0),
        ]);
        sums.push(d64);
    }
    Exhibit {
        title: "Fig 10: DIL vs CIL proportioning",
        table,
        summaries: vec![("mean_dil_share_64way_pct".into(), stats::mean(&sums))],
    }
}

/// Evaluate one scenario across all kinds (shared by Figs 12b/13/14).
pub fn eval_scenario(machine: &Machine, sc: &Scenario) -> ScenarioEval {
    ScenarioEval::run(machine, sc, &Kind::ALL)
}

/// Fig 12b — FiCCO schedule speedups per scenario with the heuristic
/// pick overlaid.
pub fn fig12b_schedules(machine: &Machine) -> Exhibit {
    let mut table = Table::new(vec![
        "gemm", "uf-1D", "hf-1D", "hu-1D", "uf-2D", "heuristic", "oracle", "hit",
    ])
    .align(0, Align::Left)
    .align(5, Align::Left)
    .align(6, Align::Left);
    let mut best = Vec::new();
    let mut hits = 0usize;
    let rows = table1();
    for r in &rows {
        let sc = r.scenario();
        let ev = eval_scenario(machine, &sc);
        let pick = crate::heuristics::pick(machine, &sc).pick;
        let (oracle, oracle_speedup) = ev
            .best_ficco()
            .expect("fig12b evaluates every FiCCO kind");
        if pick == oracle {
            hits += 1;
        }
        table.row(vec![
            r.name.to_string(),
            x(ev.speedup(Kind::UniformFused1D)),
            x(ev.speedup(Kind::HeteroFused1D)),
            x(ev.speedup(Kind::HeteroUnfused1D)),
            x(ev.speedup(Kind::UniformFused2D)),
            pick.name().to_string(),
            oracle.name().to_string(),
            if pick == oracle { "*".into() } else { "miss".to_string() },
        ]);
        best.push(oracle_speedup);
    }
    Exhibit {
        title: "Fig 12b: FiCCO schedule speedups over serial baseline",
        table,
        summaries: vec![
            ("max_ficco_speedup".into(), best.iter().cloned().fold(0.0, f64::max)),
            ("geomean_best_ficco".into(), stats::geomean(&best)),
            ("heuristic_hit_rate_table1".into(), hits as f64 / rows.len() as f64),
        ],
    }
}

/// Fig 13 — ideal-overlap bell curve vs shard-overlap on the mesh,
/// sorted by GEMM/communication time ratio.
pub fn fig13_shard_overlap(machine: &Machine) -> Exhibit {
    let mut table = Table::new(vec![
        "gemm", "gemm/comm", "ideal", "shard-overlap", "comm slowdown",
    ])
    .align(0, Align::Left);
    let mut rows: Vec<(f64, String, f64, f64, f64)> = Vec::new();
    for r in table1() {
        let sc = r.scenario();
        let ev = ScenarioEval::run(machine, &sc, &[Kind::Baseline, Kind::ShardOverlap]);
        let base = &ev.results[0];
        let ratio = base.gemm_leg / base.comm_leg;
        let shard = &ev.results[1];
        let comm_slow = shard.comm_leg / base.comm_leg;
        rows.push((
            ratio,
            r.name.to_string(),
            ev.ideal_speedup(),
            ev.speedup(Kind::ShardOverlap),
            comm_slow,
        ));
    }
    rows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut ideals = Vec::new();
    let mut shards = Vec::new();
    for (ratio, name, ideal, shard, comm_slow) in rows {
        table.row(vec![name, f(ratio, 2), x(ideal), x(shard), x(comm_slow)]);
        ideals.push(ideal);
        shards.push(shard);
    }
    Exhibit {
        title: "Fig 13: shard-overlap deficiencies on the full mesh",
        table,
        summaries: vec![
            ("max_ideal_speedup".into(), ideals.iter().cloned().fold(0.0, f64::max)),
            ("max_shard_speedup".into(), shards.iter().cloned().fold(0.0, f64::max)),
            ("geomean_shard_speedup".into(), stats::geomean(&shards)),
        ],
    }
}

/// Fig 14 — geomean speedups: shard-overlap, FiCCO-rccl, FiCCO-1D,
/// FiCCO-2D(emulated) across all Table I scenarios.
pub fn fig14_comparison(machine: &Machine) -> Exhibit {
    let mut shard = Vec::new();
    let mut ficco_rccl = Vec::new();
    let mut ficco_1d = Vec::new();
    let mut ficco_2d = Vec::new();
    for r in table1() {
        let sc = r.scenario();
        let ev = eval_scenario(machine, &sc);
        shard.push(ev.speedup(Kind::ShardOverlap));
        // Best 1D schedule (the paper's FiCCO-1D reports the bespoke pick).
        let best1d = [Kind::UniformFused1D, Kind::HeteroFused1D, Kind::HeteroUnfused1D]
            .iter()
            .map(|&k| ev.speedup(k))
            .fold(0.0, f64::max);
        ficco_1d.push(best1d);
        ficco_2d.push(ev.speedup(Kind::UniformFused2D).max(best1d));
        // FiCCO with core-driven (RCCL) communication.
        let sc_rccl = sc.clone().with_mech(CommMech::Kernel);
        let ev_rccl = ScenarioEval::run(
            machine,
            &sc_rccl,
            &[Kind::Baseline, Kind::UniformFused1D, Kind::HeteroFused1D, Kind::HeteroUnfused1D],
        );
        let best_rccl = [Kind::UniformFused1D, Kind::HeteroFused1D, Kind::HeteroUnfused1D]
            .iter()
            .map(|&k| ev_rccl.speedup(k))
            .fold(0.0, f64::max);
        ficco_rccl.push(best_rccl);
    }
    let mut table = Table::new(vec!["technique", "geomean speedup"]).align(0, Align::Left);
    // `geomean_summary` flags any degenerate (zero/NaN) speedup
    // sample dropped from the geomean — both in the rendered cell and
    // as a `*_skipped` summary — so the exhibit never silently
    // shrinks its sample set (the old stats assert used to abort).
    let rows = [
        ("shard-overlap (AsyncTP)", "geomean_shard", &shard),
        ("FiCCO-rccl", "geomean_ficco_rccl", &ficco_rccl),
        ("FiCCO-1D", "geomean_ficco_1d", &ficco_1d),
        ("FiCCO-2D (emulated)", "geomean_ficco_2d", &ficco_2d),
    ];
    let mut summaries = Vec::new();
    for (label, key, samples) in rows {
        let (g, skipped, cell) = stats::geomean_summary(samples);
        table.row(vec![label.to_string(), cell]);
        summaries.push((key.to_string(), g));
        if skipped > 0 {
            summaries.push((format!("{key}_skipped"), skipped as f64));
        }
    }
    Exhibit {
        title: "Fig 14: FiCCO vs other overlap techniques (geomean)",
        table,
        summaries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> Machine {
        Machine::mi300x_8()
    }

    #[test]
    fn fig7_structure() {
        let e = fig7_gemm_dil(&machine());
        assert_eq!(e.table.n_rows(), 16);
        assert!(e.summary("geomean_dil_64way_best") >= e.summary("geomean_dil_8way_best"));
        assert!(
            e.summary("spearman_otb_vs_dil64") < 0.0,
            "DIL should fall as OTB rises: rho={}",
            e.summary("spearman_otb_vs_dil64")
        );
    }

    #[test]
    fn fig8_geomean_near_paper() {
        let e = fig8_comm_dil(&machine());
        let g = e.summary("geomean_comm_dil");
        assert!((1.02..1.25).contains(&g), "comm DIL geomean {g} (paper ~1.10)");
        assert!(e.summary("spearman_size_vs_dil") < 0.0);
    }

    #[test]
    fn fig9_orderings() {
        let e = fig9_cil(&machine());
        assert!(e.summary("geomean_gemm_cil_rccl") > e.summary("geomean_gemm_cil_dma"));
        assert!(e.summary("geomean_gemm_cil_dma") >= 1.0);
    }
}
