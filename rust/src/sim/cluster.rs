//! Cluster-level wrapper: instantiates simulator resources for a
//! [`Machine`] and provides typed task builders for the four operation
//! classes the paper overlaps — GEMM kernels, GPU-core-driven
//! communication (RCCL-style), DMA-engine copies, and local
//! gather/scatter kernels (FiCCO's steady-state `Gather`/`Scatter`,
//! §III-B).
//!
//! The wrapper is reusable: [`ClusterSim::reset`] drops the task
//! graph while keeping the machine's resource/stream skeleton (and
//! the engine's warmed scratch buffers), so an
//! [`crate::schedule::exec::Evaluator`] loads hundreds of candidate
//! schedules without re-registering resources or reallocating.

use super::engine::{Engine, Label, Report, ResourceId, SimError, StreamId, TaskId};
use crate::hw::{Machine, PerturbSample};
use crate::obs::{StreamTrack, TrackMap};

/// How a byte stream is moved: by a GPU-core kernel (contends for CUs
/// and pollutes caches) or by a DMA engine (the paper's offload).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommMech {
    /// GPU-core-driven copy kernel (RCCL-like).
    Kernel,
    /// SDMA engine offload (`hipMemcpyDtoDAsync`-like).
    Dma,
}

impl CommMech {
    pub fn name(self) -> &'static str {
        match self {
            CommMech::Kernel => "rccl",
            CommMech::Dma => "dma",
        }
    }

    /// Parse a mechanism name as accepted by the CLI (`dma`, `rccl`,
    /// alias `kernel`).
    pub fn parse(s: &str) -> Option<CommMech> {
        match s {
            "dma" => Some(CommMech::Dma),
            "rccl" | "kernel" => Some(CommMech::Kernel),
            _ => None,
        }
    }
}

/// One tenant's private FIFO issue queues: compute/copy/comm streams
/// per GPU, mirroring the construction-time layout. Co-tenant jobs
/// each own a bank so they contend on *resources* (fair sharing)
/// instead of serializing behind one another's stream queues.
#[derive(Debug, Clone)]
struct StreamBank {
    compute: Vec<StreamId>,
    copy: Vec<StreamId>,
    comm: Vec<Vec<StreamId>>,
}

/// Simulator instantiated over a machine: resource ids, stream ids,
/// and task builders. Wraps an [`Engine`]; call [`ClusterSim::run`]
/// (or run the engine in place) when the task graph is complete.
pub struct ClusterSim {
    pub machine: Machine,
    pub engine: Engine,
    cu: Vec<ResourceId>,
    hbm: Vec<ResourceId>,
    dma: Vec<ResourceId>,
    links: Vec<ResourceId>,
    compute_streams: Vec<StreamId>,
    copy_streams: Vec<StreamId>,
    /// comm_streams[gpu][slot] — one stream per peer slot so a GPU can
    /// drive all its links concurrently (FiCCO's all-to-all pattern).
    comm_streams: Vec<Vec<StreamId>>,
    /// Registered tenant stream banks; bank 0 is the construction-time
    /// default every one-shot evaluation uses. Extra banks are
    /// registered lazily by [`ClusterSim::select_stream_bank`] and
    /// persist across [`ClusterSim::reset`] (stream registrations are
    /// part of the engine skeleton), so co-tenant evaluations reuse
    /// them without re-registering.
    banks: Vec<StreamBank>,
    /// Index of the bank the task builders currently enqueue onto.
    active_bank: usize,
    /// Active hardware perturbation (ISSUE 9): multipliers applied at
    /// task-build time. `None` is the nominal machine and takes the
    /// exact pre-perturbation code path, so nominal runs stay
    /// bit-identical by construction.
    perturb: Option<PerturbSample>,
}

impl ClusterSim {
    pub fn new(machine: Machine) -> ClusterSim {
        let n = machine.ngpus();
        let mut engine = Engine::new();
        let cu = (0..n)
            .map(|_| engine.add_resource(machine.gpu.cus as f64))
            .collect();
        let hbm = (0..n).map(|_| engine.add_resource(machine.gpu.hbm_bw)).collect();
        let dma = (0..n)
            .map(|_| engine.add_resource(machine.gpu.dma_engines as f64))
            .collect();
        let links = (0..machine.topo.num_links())
            .map(|_| engine.add_resource(machine.topo.link_bw))
            .collect();
        let compute_streams = (0..n).map(|_| engine.add_stream()).collect();
        let copy_streams = (0..n).map(|_| engine.add_stream()).collect();
        let comm_streams = (0..n)
            .map(|_| (0..n.max(2) - 1).map(|_| engine.add_stream()).collect())
            .collect();
        let banks = vec![StreamBank {
            compute: compute_streams.clone(),
            copy: copy_streams.clone(),
            comm: comm_streams.clone(),
        }];
        ClusterSim {
            machine,
            engine,
            cu,
            hbm,
            dma,
            links,
            compute_streams,
            copy_streams,
            comm_streams,
            banks,
            active_bank: 0,
            perturb: None,
        }
    }

    /// Drop the task graph, keeping the machine's resource/stream
    /// skeleton and the engine's scratch capacity. Also restores the
    /// default stream bank, so a one-shot load after a co-tenant
    /// evaluation builds onto the exact streams it always did.
    pub fn reset(&mut self) {
        self.engine.reset_tasks();
        self.select_stream_bank(0);
    }

    /// Switch the task builders onto tenant `k`'s private stream bank,
    /// registering its streams on the engine the first time bank `k`
    /// is requested. Bank 0 is the construction-time default; the
    /// co-tenant driver gives each admitted job its own bank so jobs
    /// contend through max–min fair sharing on the machine's resources
    /// rather than serializing behind one another's FIFO queues. Banks
    /// survive [`ClusterSim::reset`] and are reused bit-identically.
    pub fn select_stream_bank(&mut self, k: usize) {
        let n = self.ngpus();
        while self.banks.len() <= k {
            let compute: Vec<StreamId> = (0..n).map(|_| self.engine.add_stream()).collect();
            let copy: Vec<StreamId> = (0..n).map(|_| self.engine.add_stream()).collect();
            let comm: Vec<Vec<StreamId>> = (0..n)
                .map(|_| (0..n.max(2) - 1).map(|_| self.engine.add_stream()).collect())
                .collect();
            self.banks.push(StreamBank {
                compute,
                copy,
                comm,
            });
        }
        if self.active_bank != k {
            let b = &self.banks[k];
            self.compute_streams.clone_from(&b.compute);
            self.copy_streams.clone_from(&b.copy);
            self.comm_streams.clone_from(&b.comm);
            self.active_bank = k;
        }
    }

    /// Number of registered tenant stream banks (≥ 1; bank 0 is the
    /// default).
    pub fn n_stream_banks(&self) -> usize {
        self.banks.len()
    }

    /// Install (or clear) the hardware perturbation applied to tasks
    /// built *after* this call. The sample must match the machine's
    /// shape; `None` restores the nominal machine.
    pub fn set_perturb(&mut self, sample: Option<PerturbSample>) {
        if let Some(s) = &sample {
            debug_assert_eq!(s.gpu_work.len(), self.machine.ngpus());
            debug_assert_eq!(s.link_rate.len(), self.machine.topo.num_links());
        }
        self.perturb = sample;
    }

    pub fn ngpus(&self) -> usize {
        self.machine.ngpus()
    }

    pub fn compute_stream(&self, gpu: usize) -> StreamId {
        self.compute_streams[gpu]
    }

    pub fn copy_stream(&self, gpu: usize) -> StreamId {
        self.copy_streams[gpu]
    }

    /// Per-peer communication stream; `slot` identifies the peer so
    /// transfers to different peers proceed concurrently while
    /// transfers to the same peer stay ordered.
    pub fn comm_stream(&self, gpu: usize, slot: usize) -> StreamId {
        self.comm_streams[gpu][slot % self.comm_streams[gpu].len()]
    }

    pub fn hbm_resource(&self, gpu: usize) -> ResourceId {
        self.hbm[gpu]
    }
    pub fn cu_resource(&self, gpu: usize) -> ResourceId {
        self.cu[gpu]
    }

    /// Add a compute kernel (GEMM) on `gpu`'s compute stream.
    ///
    /// `time_iso` is the kernel's isolated execution time (DIL baked
    /// in, from `cost::gemm`); `bytes` its HBM traffic; `cus` how many
    /// CUs it occupies at full rate.
    pub fn gemm_task(
        &mut self,
        gpu: usize,
        label: impl Into<Label>,
        time_iso: f64,
        bytes: f64,
        cus: usize,
        deps: &[TaskId],
    ) -> TaskId {
        // A straggler GPU runs its kernels proportionally slower (the
        // nominal path leaves `time_iso` untouched, bit for bit).
        let t = match &self.perturb {
            Some(p) => (time_iso * p.gpu_work[gpu]).max(1e-9),
            None => time_iso.max(1e-9),
        };
        // HBM demand carries the burstiness factor: GEMM memory phases
        // hit the memory subsystem far above the kernel's average rate.
        let burst = self.machine.gpu.hbm_burst;
        let launch = self.machine.gpu.kernel_launch;
        let cu = self.cu[gpu];
        let hbm = self.hbm[gpu];
        let stream = self.compute_streams[gpu];
        self.engine
            .task(label, stream)
            .deps(deps)
            .work(t)
            .setup(launch)
            .demand(cu, cus as f64)
            .demand(hbm, burst * bytes / t)
            .finish()
    }

    /// Add a point-to-point transfer src→dst of `bytes`, on the given
    /// comm stream slot, via kernel or DMA.
    pub fn transfer_task(
        &mut self,
        src: usize,
        dst: usize,
        slot: usize,
        label: impl Into<Label>,
        bytes: f64,
        mech: CommMech,
        deps: &[TaskId],
    ) -> TaskId {
        let g = &self.machine.gpu;
        let topo = &self.machine.topo;
        // Finer-grain transfers ride the small-message ramp — the
        // source of communication DIL (Fig 8).
        let link_bw = topo.p2p_bw(src, dst).min(topo.effective_bw(bytes));
        let (rate, setup, cus, pollution, dma_engines) = match mech {
            CommMech::Kernel => (
                link_bw * g.kernel_link_eff,
                topo.latency + g.kernel_launch,
                g.comm_kernel_cus as f64,
                g.comm_cache_pollution,
                0.0,
            ),
            CommMech::Dma => (
                (link_bw * g.dma_link_eff).min(g.dma_engine_bw),
                topo.latency + 0.25 * g.kernel_launch,
                0.0,
                1.0,
                1.0,
            ),
        };
        // Perturbed fabric: a degraded link serves this transfer at a
        // reduced rate (min over the links the route crosses) and the
        // comm-setup latency inflates. Nominal keeps the exact values
        // computed above.
        let (rate, setup) = match &self.perturb {
            Some(p) => {
                let (la, lb) = topo.link_pair(src, dst);
                let mut mult = p.link_rate[la];
                if let Some(lb) = lb {
                    mult = mult.min(p.link_rate[lb]);
                }
                (rate * mult, setup * p.setup_mult)
            }
            None => (rate, setup),
        };
        let work = bytes / rate;
        // Fabric traffic is amplified at the memory subsystem
        // (row-conflict/turnaround interference); core-driven comm
        // additionally thrashes caches (pollution ≥ 1).
        let amp = g.comm_hbm_amp;
        let (link_a, link_b) = topo.link_pair(src, dst);
        let stream = self.comm_stream(src, slot);
        let hbm_src = self.hbm[src];
        let hbm_dst = self.hbm[dst];
        let cu_src = self.cu[src];
        let dma_src = self.dma[src];
        let link_a = self.links[link_a];
        let link_b = link_b.map(|l| self.links[l]);
        let mut b = self
            .engine
            .task(label, stream)
            .deps(deps)
            .work(work.max(1e-9))
            .setup(setup)
            .demand(hbm_src, rate * pollution * amp)
            .demand(hbm_dst, rate * pollution * amp)
            .demand(link_a, rate);
        if let Some(l) = link_b {
            b = b.demand(l, rate);
        }
        if cus > 0.0 {
            b = b.demand(cu_src, cus);
        }
        if dma_engines > 0.0 {
            b = b.demand(dma_src, dma_engines);
        }
        b.finish()
    }

    /// Add a local gather/scatter copy of `bytes` on `gpu` (reads and
    /// writes HBM). FiCCO's uniform schedules need these to assemble
    /// finer-grain receive buffers / scatter outputs (§III-B).
    pub fn local_copy_task(
        &mut self,
        gpu: usize,
        label: impl Into<Label>,
        bytes: f64,
        mech: CommMech,
        deps: &[TaskId],
    ) -> TaskId {
        let g = &self.machine.gpu;
        // A well-written copy kernel streams at ~80% of HBM; traffic is
        // read + write. A DMA local copy runs at engine rate.
        let (bw, cus, dma_engines, setup) = match mech {
            CommMech::Kernel => (
                0.8 * g.hbm_bw / 2.0,
                g.copy_kernel_cus as f64,
                0.0,
                g.kernel_launch,
            ),
            CommMech::Dma => (g.dma_engine_bw, 0.0, 1.0, 0.25 * g.kernel_launch),
        };
        // A straggler's local copies slow with its compute (kernel and
        // DMA local engines share the slowed clock domain); setup
        // inflates with the comm-setup multiplier.
        let (work, setup) = match &self.perturb {
            Some(p) => (bytes / bw * p.gpu_work[gpu], setup * p.setup_mult),
            None => (bytes / bw, setup),
        };
        let stream = self.copy_streams[gpu];
        let hbm = self.hbm[gpu];
        let cu = self.cu[gpu];
        let dma = self.dma[gpu];
        let mut b = self
            .engine
            .task(label, stream)
            .deps(deps)
            .work(work.max(1e-9))
            .setup(setup)
            .demand(hbm, 2.0 * bw);
        if cus > 0.0 {
            b = b.demand(cu, cus);
        }
        if dma_engines > 0.0 {
            b = b.demand(dma, dma_engines);
        }
        b.finish()
    }

    /// Zero-cost synchronization marker on a stream (hipStreamWrite/
    /// hipStreamWait-style lightweight signal, §VI-A).
    pub fn sync_task(
        &mut self,
        gpu: usize,
        label: impl Into<Label>,
        deps: &[TaskId],
    ) -> TaskId {
        let stream = self.compute_streams[gpu];
        self.engine.task(label, stream).deps(deps).finish()
    }

    pub fn run(mut self) -> Result<Report, SimError> {
        self.engine.run_full()
    }

    /// Perfetto track layout for this machine: one process per GPU
    /// (compute/copy/comm streams as threads, cu/hbm/dma counters)
    /// plus a `fabric` process carrying the per-link counters. Track
    /// indices follow the engine's stream/resource registration order
    /// in [`ClusterSim::new`], which is what lets the exporter index
    /// by `StreamId.0` / `ResourceId.0` directly. Tenant stream banks
    /// registered by [`ClusterSim::select_stream_bank`] append their
    /// threads after bank 0's, named `j<bank>:compute` / `j<bank>:copy`
    /// / `j<bank>:comm<slot>` — bank 0 keeps the unprefixed legacy
    /// names, so single-job traces are byte-identical to before.
    pub fn track_map(&self) -> TrackMap {
        let n = self.ngpus();
        let mut processes: Vec<String> = (0..n).map(|g| format!("gpu{g}")).collect();
        processes.push("fabric".to_string());
        let mut streams = Vec::with_capacity(self.engine.n_streams());
        let threads_per_gpu = 2 + self.banks[0].comm[0].len();
        for (b, bank) in self.banks.iter().enumerate() {
            let base = b * threads_per_gpu;
            let prefix = |name: String| {
                if b == 0 {
                    name
                } else {
                    format!("j{b}:{name}")
                }
            };
            for g in 0..n {
                debug_assert_eq!(streams.len(), bank.compute[g].0);
                streams.push(StreamTrack {
                    pid: g,
                    tid: base,
                    name: prefix("compute".to_string()),
                });
            }
            for g in 0..n {
                debug_assert_eq!(streams.len(), bank.copy[g].0);
                streams.push(StreamTrack {
                    pid: g,
                    tid: base + 1,
                    name: prefix("copy".to_string()),
                });
            }
            for (g, slots) in bank.comm.iter().enumerate() {
                for k in 0..slots.len() {
                    debug_assert_eq!(streams.len(), slots[k].0);
                    streams.push(StreamTrack {
                        pid: g,
                        tid: base + 2 + k,
                        name: prefix(format!("comm{k}")),
                    });
                }
            }
        }
        debug_assert_eq!(streams.len(), self.engine.n_streams());
        let mut counters = Vec::with_capacity(self.engine.n_resources());
        for g in 0..n {
            counters.push((g, "cu".to_string()));
        }
        for g in 0..n {
            counters.push((g, "hbm".to_string()));
        }
        for g in 0..n {
            counters.push((g, "dma".to_string()));
        }
        for l in 0..self.links.len() {
            counters.push((n, format!("link{l}")));
        }
        debug_assert_eq!(counters.len(), self.engine.n_resources());
        TrackMap {
            processes,
            streams,
            counters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::Machine;

    #[test]
    fn isolated_dma_transfer_time() {
        let m = Machine::mi300x_8();
        let bytes = 64e9 * 0.01; // ~10 ms at raw link rate
        let rate = (m.topo.effective_bw(bytes) * m.gpu.dma_link_eff).min(m.gpu.dma_engine_bw);
        let expected = bytes / rate;
        let mut c = ClusterSim::new(m);
        c.transfer_task(0, 1, 0, "x", bytes, CommMech::Dma, &[]);
        let rep = c.run().unwrap();
        assert!(
            (rep.makespan - expected).abs() / expected < 0.01,
            "makespan={} expected={}",
            rep.makespan,
            expected
        );
    }

    #[test]
    fn parallel_transfers_to_distinct_peers_overlap() {
        let m = Machine::mi300x_8();
        let mut c = ClusterSim::new(m);
        let bytes = 64e9 * 0.01;
        for (slot, dst) in (1..8).enumerate() {
            c.transfer_task(0, dst, slot, format!("to{dst}"), bytes, CommMech::Dma, &[]);
        }
        let rep = c.run().unwrap();
        // 7 transfers on 7 distinct links: ~same time as one.
        assert!(rep.makespan < 0.012, "makespan={}", rep.makespan);
    }

    #[test]
    fn serial_transfers_same_peer_queue() {
        let m = Machine::mi300x_8();
        let mut c = ClusterSim::new(m);
        let bytes = 64e9 * 0.01;
        c.transfer_task(0, 1, 0, "a", bytes, CommMech::Dma, &[]);
        c.transfer_task(0, 1, 0, "b", bytes, CommMech::Dma, &[]);
        let rep = c.run().unwrap();
        assert!(rep.makespan > 0.019, "makespan={}", rep.makespan);
    }

    #[test]
    fn rccl_comm_slows_gemm_more_than_dma() {
        // The paper's core contention claim (Fig 9): core-driven comm
        // inflicts higher GEMM CIL than DMA comm.
        let slowdown_with = |mech: CommMech| {
            let m = Machine::mi300x_8();
            let mut c = ClusterSim::new(m);
            let gflop_time = 0.02;
            // Moderate memory appetite: 20% of HBM when isolated, so
            // the GEMM does not self-saturate through the burst factor.
            let bytes = 0.2 * 5.3e12 * gflop_time;
            let g = c.gemm_task(0, "gemm", gflop_time, bytes, 304, &[]);
            // Long-running comm from gpu0 (src side contends).
            c.transfer_task(0, 1, 0, "comm", 64e9 * 0.05, mech, &[]);
            let rep = c.run().unwrap();
            rep.slowdown(g)
        };
        let s_rccl = slowdown_with(CommMech::Kernel);
        let s_dma = slowdown_with(CommMech::Dma);
        // Core-driven comm steals CUs (compute interference, Fig 3d);
        // DMA comm leaves the GEMM's cores alone. (A single P2P kernel
        // occupies comm_kernel_cus CUs; the full-collective case is
        // covered by metrics::fig9_cil.)
        assert!(s_rccl > s_dma, "rccl={s_rccl} dma={s_dma}");
        assert!(s_dma >= 1.0 - 1e-9);
        assert!(s_rccl > 1.02, "CU steal should be visible: {s_rccl}");
    }

    #[test]
    fn local_copy_costs_hbm() {
        let m = Machine::mi300x_8();
        let hbm = m.gpu.hbm_bw;
        let mut c = ClusterSim::new(m);
        let bytes = hbm * 0.01; // big copy
        c.local_copy_task(0, "gather", bytes, CommMech::Kernel, &[]);
        let rep = c.run().unwrap();
        // read+write at 80% of HBM → ≥ 2x/0.8 the one-pass time
        assert!(rep.makespan > 0.024, "makespan={}", rep.makespan);
    }

    #[test]
    fn track_map_covers_every_stream_and_resource() {
        let c = ClusterSim::new(Machine::mi300x_8());
        let tm = c.track_map();
        assert_eq!(tm.streams.len(), c.engine.n_streams());
        assert_eq!(tm.counters.len(), c.engine.n_resources());
        for st in &tm.streams {
            assert!(st.pid < tm.processes.len());
        }
        for &(pid, _) in &tm.counters {
            assert!(pid < tm.processes.len());
        }
    }

    #[test]
    fn perturbed_build_slows_and_clearing_restores_bitwise() {
        use crate::hw::Perturbation;
        let m = Machine::mi300x_8();
        let bytes = 64e9 * 0.01;
        let graph = |c: &mut ClusterSim| {
            let g = c.gemm_task(0, "g", 0.01, 1e6, 304, &[]);
            c.transfer_task(0, 1, 0, "x", bytes, CommMech::Dma, &[g]);
        };
        let mut c = ClusterSim::new(m);
        graph(&mut c);
        let nominal = c.engine.run_lean().unwrap().makespan;
        let ens = Perturbation::defaults(1, 11);
        let sample = ens.sample(0, c.ngpus(), c.machine.topo.num_links());
        c.reset();
        c.set_perturb(Some(sample));
        graph(&mut c);
        let perturbed = c.engine.run_lean().unwrap().makespan;
        // Work multipliers ≥ 1 and rate multipliers ≤ 1: never faster.
        assert!(perturbed > nominal, "perturbed={perturbed} nominal={nominal}");
        // Clearing the sample restores the nominal bits exactly.
        c.reset();
        c.set_perturb(None);
        graph(&mut c);
        let back = c.engine.run_lean().unwrap().makespan;
        assert_eq!(nominal.to_bits(), back.to_bits());
    }

    #[test]
    fn reset_reuses_the_machine_skeleton_bitwise() {
        // Two identical graphs through one ClusterSim, reset between:
        // same makespan bits as a fresh ClusterSim.
        let m = Machine::mi300x_8();
        let bytes = 64e9 * 0.01;
        let mut c = ClusterSim::new(m.clone());
        c.transfer_task(0, 1, 0, "a", bytes, CommMech::Dma, &[]);
        let first = c.engine.run_lean().unwrap().makespan;
        c.reset();
        c.transfer_task(0, 1, 0, "a", bytes, CommMech::Dma, &[]);
        let second = c.engine.run_lean().unwrap().makespan;
        assert_eq!(first.to_bits(), second.to_bits());
        let fresh = {
            let mut c2 = ClusterSim::new(m);
            c2.transfer_task(0, 1, 0, "a", bytes, CommMech::Dma, &[]);
            c2.run().unwrap().makespan
        };
        assert_eq!(first.to_bits(), fresh.to_bits());
    }
}
