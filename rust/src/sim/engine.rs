//! Generic fluid discrete-event engine.
//!
//! Tasks form a DAG and are additionally serialized by *streams*
//! (in-order queues, modelling GPU streams/DMA queues). A task that is
//! dependency-ready waits out its fixed `setup` latency (kernel launch,
//! link latency), then progresses at a rate in `[0, 1]` determined by
//! max–min fair sharing of the resources it demands. `work` is the
//! task's duration at rate 1 (its isolated execution time).
//!
//! The engine is built for **reuse** (`DESIGN.md` §6): task
//! descriptions live in flat arenas (deps and demands are ranges into
//! shared arrays, labels are lazy [`Label`]s — no per-task heap
//! allocation once capacity is warm), the event loop runs entirely out
//! of persistent scratch buffers ([`RunScratch`]), and
//! [`Engine::reset_tasks`] drops the task graph while keeping the
//! registered resources, streams, and scratch capacity. A search
//! evaluating hundreds of candidate schedules per cell therefore
//! allocates while warming up and then runs allocation-free
//! (`rust/tests/zero_alloc.rs` asserts this with a counting
//! allocator). [`Engine::run_lean`] additionally skips every
//! per-task/per-resource output the caller does not need when only the
//! makespan matters.
//!
//! The event-loop *algorithm* is unchanged from the original
//! implementation — kept verbatim in [`super::reference`] for
//! differential testing — and every floating-point operation is
//! performed on the same values in the same order, so reported
//! makespans and event counts are bit-for-bit identical.
//!
//! The loop is exposed as a **resumable stepper** (DESIGN.md §11):
//! [`Engine::begin_run`] / [`Engine::step`] /
//! [`Engine::advance_until`] / [`Engine::finish_run`] process the same
//! event sequence one event at a time with the virtual clock owned by
//! the caller, [`Engine::admit_tasks`] injects new work mid-run as a
//! new *instance* (per-instance id namespace and makespan, fair
//! sharing against running instances via the ordinary flow lists), and
//! `run_full`/`run_lean` are thin run-to-completion drivers over it.

use crate::obs::{NullRecorder, Recorder, StderrRecorder};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Index of a resource (capacity-limited, e.g. a link or a CU pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceId(pub usize);

/// Index of a task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub usize);

/// Index of a stream (in-order issue queue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamId(pub usize);

/// Process-wide `FICCO_SIM_TRACE` switch, read once per process. The
/// env lookup used to run in every `Engine::new` — once per search
/// candidate, thousands of times per tune cell.
pub fn trace_enabled() -> bool {
    static TRACE: OnceLock<bool> = OnceLock::new();
    *TRACE.get_or_init(|| std::env::var("FICCO_SIM_TRACE").is_ok())
}

/// Which fair-sharing implementation an engine runs (`DESIGN.md` §6).
///
/// Both produce **bit-identical** rates; `Slow` is the kept-verbatim
/// from-scratch recompute retained as the differential baseline (and
/// as the cross-check oracle), `Incremental` is the default hot path
/// that maintains per-resource aggregates across events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FairMode {
    /// Maintain per-resource flow lists and cached demand aggregates
    /// across events; a task start/finish only touches the resources
    /// it demands.
    Incremental,
    /// Recompute progressive filling from scratch over the whole
    /// running set on every event (the pre-ISSUE-6 algorithm, kept
    /// verbatim as [`Engine::fill_fair_rates_slow`]).
    Slow,
}

/// Process default for [`FairMode`]: 0 = incremental, 1 = slow,
/// 2 = uninitialized (resolve from `FICCO_SIM_SLOW_FAIR` on first use).
static FAIR_MODE_DEFAULT: AtomicU8 = AtomicU8::new(2);

/// The process-wide default fair-sharing mode new engines start in.
/// Resolved from the `FICCO_SIM_SLOW_FAIR` env var on first call
/// unless [`set_default_fair_mode`] ran earlier.
pub fn default_fair_mode() -> FairMode {
    match FAIR_MODE_DEFAULT.load(Ordering::Relaxed) {
        0 => FairMode::Incremental,
        1 => FairMode::Slow,
        _ => {
            let slow = std::env::var("FICCO_SIM_SLOW_FAIR").is_ok();
            FAIR_MODE_DEFAULT.store(u8::from(slow), Ordering::Relaxed);
            if slow {
                FairMode::Slow
            } else {
                FairMode::Incremental
            }
        }
    }
}

/// Override the process default fair-sharing mode (picked up by every
/// subsequently constructed [`Engine`], e.g. deep inside an
/// `exec::Evaluator`). The `perf_hotpath` bench uses this to measure
/// old-vs-new on identical workloads in one process.
pub fn set_default_fair_mode(mode: FairMode) {
    FAIR_MODE_DEFAULT.store(u8::from(mode == FairMode::Slow), Ordering::Relaxed);
}

/// Process-wide `FICCO_SIM_CHECK_RATES` switch: when set, every engine
/// runs **both** fair-sharing implementations on every rate fill and
/// panics if any rate differs bitwise (the cross-check mode the
/// `sim-differential` CI job turns on).
pub fn check_rates_enabled() -> bool {
    static CHECK: OnceLock<bool> = OnceLock::new();
    *CHECK.get_or_init(|| std::env::var("FICCO_SIM_CHECK_RATES").is_ok())
}

/// Lazily rendered task label: building a `String` per task was a
/// measurable share of candidate-construction cost, and the label is
/// only ever *read* on trace/error paths. `Static` and `Indexed`
/// labels are allocation-free.
#[derive(Debug, Clone)]
pub enum Label {
    Static(&'static str),
    Owned(String),
    /// `prefix` + decimal index, rendered on demand (e.g. `n17` for
    /// schedule node 17).
    Indexed(&'static str, u32),
}

impl Label {
    pub fn indexed(prefix: &'static str, i: usize) -> Label {
        Label::Indexed(prefix, i as u32)
    }
}

impl fmt::Display for Label {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Label::Static(s) => f.write_str(s),
            Label::Owned(s) => f.write_str(s),
            Label::Indexed(p, i) => write!(f, "{p}{i}"),
        }
    }
}

impl From<&'static str> for Label {
    fn from(s: &'static str) -> Label {
        Label::Static(s)
    }
}

impl From<String> for Label {
    fn from(s: String) -> Label {
        Label::Owned(s)
    }
}

/// Task description handed to [`Engine::add_task`]. Retained as the
/// convenient owned-`Vec` builder for tests and one-off graphs; bulk
/// loaders should prefer [`Engine::task`], which writes deps/demands
/// straight into the engine's flat arenas without intermediate
/// allocation.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub label: Label,
    pub stream: StreamId,
    pub deps: Vec<TaskId>,
    /// Seconds of execution at rate 1.0 (isolated time, DIL included).
    pub work: f64,
    /// Fixed pre-work latency once ready (launch overhead, wire latency).
    pub setup: f64,
    /// Resource consumption per unit rate: at rate ρ the task uses
    /// `ρ·demand` of each listed resource.
    pub demands: Vec<(ResourceId, f64)>,
}

impl TaskSpec {
    pub fn new(label: impl Into<Label>, stream: StreamId) -> TaskSpec {
        TaskSpec {
            label: label.into(),
            stream,
            deps: Vec::new(),
            work: 0.0,
            setup: 0.0,
            demands: Vec::new(),
        }
    }
    pub fn dep(mut self, t: TaskId) -> Self {
        self.deps.push(t);
        self
    }
    pub fn deps(mut self, ts: &[TaskId]) -> Self {
        self.deps.extend_from_slice(ts);
        self
    }
    pub fn work(mut self, w: f64) -> Self {
        self.work = w;
        self
    }
    pub fn setup(mut self, s: f64) -> Self {
        self.setup = s;
        self
    }
    pub fn demand(mut self, r: ResourceId, d: f64) -> Self {
        assert!(d >= 0.0);
        self.demands.push((r, d));
        self
    }
}

/// Execution phase of one task during a run. The setup deadline lives
/// in [`RunScratch::setup_until`] (and the deadline heap), not in the
/// phase itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Waiting on deps / stream order.
    Blocked,
    /// Deps met; absorbing fixed setup latency.
    Setup,
    /// Progressing under fair-shared rates.
    Running,
    Done,
}

/// One task's immutable description: scalar fields inline, deps and
/// demands as `[start, end)` ranges into the engine's flat arenas.
#[derive(Debug, Clone)]
struct TaskNode {
    label: Label,
    stream: StreamId,
    work: f64,
    setup: f64,
    deps_at: (usize, usize),
    demands_at: (usize, usize),
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct Report {
    /// Total simulated time until the last task completes.
    pub makespan: f64,
    /// Per-task (ready/queue-exit time, finish time).
    pub task_spans: Vec<(f64, f64)>,
    /// Per-task time actually spent in Running phase.
    pub task_run_time: Vec<f64>,
    /// Per-resource integral of consumption (capacity-units × seconds).
    pub resource_busy: Vec<f64>,
    /// Number of scheduling events processed.
    pub events: usize,
    /// Isolated work per task (copied from specs for slowdown calc).
    pub ideal_work: Vec<f64>,
}

impl Report {
    /// Contention slowdown of one task: running time / isolated work.
    /// 1.0 means the task never shared a bottleneck resource.
    pub fn slowdown(&self, t: TaskId) -> f64 {
        let i = t.0;
        let work = self.task_run_time[i];
        if work <= 0.0 {
            1.0
        } else {
            work / self.ideal_work[i].max(1e-30)
        }
    }

    /// Average utilization of a resource over the makespan.
    pub fn utilization(&self, r: ResourceId, capacity: f64) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.resource_busy[r.0] / (capacity * self.makespan)
    }
}

/// Makespan-only simulation output of [`Engine::run_lean`]: no
/// per-task spans, no per-resource busy integrals — none of those
/// sums are even accumulated.
#[derive(Debug, Clone, Copy)]
pub struct LeanReport {
    pub makespan: f64,
    pub events: usize,
}

/// One admitted batch of tasks: a contiguous id range plus the
/// virtual time it entered the run. Instance 0 is the graph present at
/// [`Engine::begin_run`]; later instances come from
/// [`Engine::admit_tasks`] / [`Engine::admit_appended`].
#[derive(Debug, Clone, Copy)]
struct Instance {
    first: usize,
    end: usize,
    admitted_at: f64,
}

/// What one [`Engine::step`] (or a bounded [`Engine::advance_until`])
/// did: the virtual time afterwards, how many tasks entered `Running`,
/// how many completed, and whether every admitted task is now done.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepReport {
    /// Virtual time after the step.
    pub now: f64,
    /// Tasks that transitioned Setup → Running.
    pub started: usize,
    /// Tasks that completed.
    pub completed: usize,
    /// True once every admitted task is `Done` — further steps are
    /// no-ops until more tasks are admitted.
    pub finished: bool,
}

/// Persistent per-run working state. Every buffer is sized (not
/// reallocated) at the start of a run, so a reused engine's steady
/// state performs no heap allocation inside the event loop.
#[derive(Debug, Clone, Default)]
struct RunScratch {
    phase: Vec<Phase>,
    remaining: Vec<f64>,
    /// Setup deadline per task (valid while `phase == Setup`).
    setup_until: Vec<f64>,
    start: Vec<f64>,
    run_start: Vec<f64>,
    finish: Vec<f64>,
    deps_left: Vec<usize>,
    /// Dependents in CSR form: task `i`'s dependents are
    /// `dep_list[dep_heads[i]..dep_heads[i + 1]]`.
    dep_heads: Vec<usize>,
    dep_cursor: Vec<usize>,
    dep_list: Vec<TaskId>,
    stream_cursor: Vec<usize>,
    /// Running task indices, kept sorted ascending — the iteration
    /// order every floating-point reduction in the loop depends on.
    running: Vec<usize>,
    /// Fair rates parallel to `running` (recomputed only when the
    /// running set changes — rates are a pure function of the set).
    rates: Vec<f64>,
    frozen: Vec<bool>,
    rem: Vec<f64>,
    sum: Vec<f64>,
    /// Min-heap of pending setup deadlines as (deadline bits, task).
    /// Deadlines are non-negative finite f64s, for which the bit
    /// pattern is order-preserving.
    setup_heap: BinaryHeap<Reverse<(u64, usize)>>,
    completed: Vec<usize>,
    resource_busy: Vec<f64>,

    // --- incremental fair-sharing state (DESIGN.md §6) ---
    /// Per-resource running flows as (task, demand), ascending by task
    /// id with a task's duplicate demands in declaration order — the
    /// exact order the slow path's per-round sums accumulate in.
    flows: Vec<Vec<(u32, f64)>>,
    /// Cached full-running-set demand aggregate per resource, valid
    /// while `agg_dirty` is false: reusable bitwise because the flow
    /// list (and therefore the addition sequence) is unchanged.
    agg_sum: Vec<f64>,
    agg_dirty: Vec<bool>,
    /// Resources with at least one running flow (arbitrary order;
    /// nothing order-dependent is computed over it).
    active_res: Vec<u32>,
    /// Position of each resource in `active_res` (`u32::MAX` = absent).
    active_pos: Vec<u32>,
    /// Per-fill: resources whose remainder crossed the saturation
    /// threshold (monotone within a fill — rem never grows).
    saturated: Vec<bool>,
    newly_saturated: Vec<u32>,
    /// Per-round: resources whose unfrozen membership changed and need
    /// a fresh ascending-order sum next round.
    refresh_res: Vec<u32>,
    refresh_mark: Vec<bool>,
    /// Separate buffers for the env-gated slow-path cross-check so the
    /// oracle never aliases the incremental path's working state.
    check_rates: Vec<f64>,
    check_frozen: Vec<bool>,
    check_rem: Vec<f64>,
    check_sum: Vec<f64>,

    // --- resumable-stepper state (DESIGN.md §11) ---
    /// Virtual clock; owned by the caller between stepper calls.
    now: f64,
    events: usize,
    done_count: usize,
    /// Rates are a pure function of the running set (demands and
    /// capacities are fixed per run), so they are recomputed only
    /// when that set changes.
    rates_dirty: bool,
    /// Tasks covered by the run so far (== `tasks.len()` after every
    /// begin/admission; guards against stepping a graph that grew
    /// without being admitted).
    n_admitted: usize,
    lean: bool,
    /// True between `begin_run*` and `finish_*` (or a run error).
    active: bool,
    instances: Vec<Instance>,
}

/// The engine. Build tasks, then [`Engine::run_full`] /
/// [`Engine::run_lean`] (or the consuming [`Engine::run`]).
#[derive(Debug, Clone)]
pub struct Engine {
    capacities: Vec<f64>,
    tasks: Vec<TaskNode>,
    deps_flat: Vec<TaskId>,
    demands_flat: Vec<(ResourceId, f64)>,
    streams: Vec<Vec<TaskId>>,
    trace: bool,
    fair_mode: FairMode,
    check_rates: bool,
    scratch: RunScratch,
}

#[derive(Debug)]
pub struct SimError(pub String);

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "sim error: {}", self.0)
    }
}
impl std::error::Error for SimError {}

const EPS: f64 = 1e-12;

/// In-place task construction writing deps/demands directly into the
/// engine's flat arenas. Obtain via [`Engine::task`], configure, then
/// call [`TaskBuilder::finish`] — a builder must not be abandoned
/// mid-task (its arena entries would leak into the next task).
pub struct TaskBuilder<'e> {
    engine: &'e mut Engine,
    label: Label,
    stream: StreamId,
    work: f64,
    setup: f64,
    deps_start: usize,
    demands_start: usize,
}

impl<'e> TaskBuilder<'e> {
    pub fn dep(mut self, t: TaskId) -> Self {
        self.engine.deps_flat.push(t);
        self
    }
    pub fn deps(mut self, ts: &[TaskId]) -> Self {
        self.engine.deps_flat.extend_from_slice(ts);
        self
    }
    pub fn work(mut self, w: f64) -> Self {
        self.work = w;
        self
    }
    pub fn setup(mut self, s: f64) -> Self {
        self.setup = s;
        self
    }
    pub fn demand(mut self, r: ResourceId, d: f64) -> Self {
        assert!(d >= 0.0);
        self.engine.demands_flat.push((r, d));
        self
    }

    /// Validate and register the task; returns its id.
    pub fn finish(self) -> TaskId {
        let engine = self.engine;
        let id = TaskId(engine.tasks.len());
        for &(r, _) in &engine.demands_flat[self.demands_start..] {
            assert!(r.0 < engine.capacities.len(), "unknown resource");
        }
        for &d in &engine.deps_flat[self.deps_start..] {
            assert!(d.0 < id.0, "dep {:?} not earlier than task {:?}", d, id);
        }
        assert!(self.work >= 0.0 && self.setup >= 0.0);
        engine.streams[self.stream.0].push(id);
        engine.tasks.push(TaskNode {
            label: self.label,
            stream: self.stream,
            work: self.work,
            setup: self.setup,
            deps_at: (self.deps_start, engine.deps_flat.len()),
            demands_at: (self.demands_start, engine.demands_flat.len()),
        });
        id
    }
}

impl Engine {
    pub fn new() -> Engine {
        Engine {
            capacities: Vec::new(),
            tasks: Vec::new(),
            deps_flat: Vec::new(),
            demands_flat: Vec::new(),
            streams: Vec::new(),
            trace: trace_enabled(),
            fair_mode: default_fair_mode(),
            check_rates: check_rates_enabled(),
            scratch: RunScratch::default(),
        }
    }

    /// Select which fair-sharing implementation this engine runs. Both
    /// produce bit-identical rates; `Slow` exists as the measurable
    /// baseline and cross-check oracle.
    pub fn set_fair_mode(&mut self, mode: FairMode) {
        self.fair_mode = mode;
    }

    pub fn fair_mode(&self) -> FairMode {
        self.fair_mode
    }

    /// Enable/disable the per-event slow-vs-incremental rate
    /// cross-check on this engine (panics on any bitwise divergence).
    /// Process-wide default comes from `FICCO_SIM_CHECK_RATES`.
    pub fn set_check_rates(&mut self, on: bool) {
        self.check_rates = on;
    }

    /// Register a resource with the given capacity; returns its id.
    pub fn add_resource(&mut self, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        self.capacities.push(capacity);
        ResourceId(self.capacities.len() - 1)
    }

    /// Register a stream (in-order issue queue); returns its id.
    pub fn add_stream(&mut self) -> StreamId {
        self.streams.push(Vec::new());
        StreamId(self.streams.len() - 1)
    }

    pub fn capacity(&self, r: ResourceId) -> f64 {
        self.capacities[r.0]
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn n_resources(&self) -> usize {
        self.capacities.len()
    }

    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// Display label of task `tid` (flight-recorder accessor).
    pub fn task_label(&self, tid: usize) -> &Label {
        &self.tasks[tid].label
    }

    /// Stream task `tid` was registered on.
    pub fn task_stream(&self, tid: usize) -> StreamId {
        self.tasks[tid].stream
    }

    /// Work (duration at rate 1) of task `tid`.
    pub fn task_work(&self, tid: usize) -> f64 {
        self.tasks[tid].work
    }

    /// Fixed setup latency of task `tid`.
    pub fn task_setup(&self, tid: usize) -> f64 {
        self.tasks[tid].setup
    }

    /// Resource demands of task `tid`, in declaration order — the
    /// order the engine's busy integration iterates, which is what
    /// lets a recorder replay the accounting bit-exactly.
    pub fn task_demands(&self, tid: usize) -> &[(ResourceId, f64)] {
        self.demands_of(tid)
    }

    /// Drop all tasks (and their stream queues) but keep the
    /// registered resources, streams, and every scratch buffer's
    /// capacity — the skeleton an evaluator reuses across candidate
    /// schedules.
    pub fn reset_tasks(&mut self) {
        self.tasks.clear();
        self.deps_flat.clear();
        self.demands_flat.clear();
        for s in &mut self.streams {
            s.clear();
        }
        // A paused run cannot survive its graph being dropped.
        self.scratch.active = false;
    }

    /// Start building a task in place (no intermediate allocation);
    /// the stream must be registered. See [`TaskBuilder`].
    pub fn task(&mut self, label: impl Into<Label>, stream: StreamId) -> TaskBuilder<'_> {
        assert!(stream.0 < self.streams.len(), "unknown stream");
        let deps_start = self.deps_flat.len();
        let demands_start = self.demands_flat.len();
        TaskBuilder {
            engine: self,
            label: label.into(),
            stream,
            work: 0.0,
            setup: 0.0,
            deps_start,
            demands_start,
        }
    }

    /// Add a task from an owned spec. Demands must reference
    /// registered resources; the stream must be registered; deps must
    /// be earlier task ids.
    pub fn add_task(&mut self, spec: TaskSpec) -> TaskId {
        let TaskSpec {
            label,
            stream,
            deps,
            work,
            setup,
            demands,
        } = spec;
        let mut b = self.task(label, stream).deps(&deps).work(work).setup(setup);
        for &(r, d) in &demands {
            b = b.demand(r, d);
        }
        b.finish()
    }

    fn deps_of(&self, i: usize) -> &[TaskId] {
        let (a, b) = self.tasks[i].deps_at;
        &self.deps_flat[a..b]
    }

    fn demands_of(&self, i: usize) -> &[(ResourceId, f64)] {
        let (a, b) = self.tasks[i].demands_at;
        &self.demands_flat[a..b]
    }

    /// Analytic lower bound on the makespan of the task graph as
    /// currently built, without running the simulation:
    ///
    /// - **stream bound** — tasks on one stream are issued strictly
    ///   in order, each paying its fixed `setup` and then at least
    ///   `work` (rates never exceed 1), so the makespan is at least
    ///   `Σ (setup + work)` over any single stream;
    /// - **resource bound** — a task running at rate ρ consumes
    ///   `ρ·demand` of a resource, integrating to `work·demand`
    ///   capacity-seconds over its life, so the makespan is at least
    ///   `Σ work·demand / capacity` for any single resource.
    ///
    /// Both are true lower bounds under the fluid model (contention
    /// only lowers rates), which is what makes incumbent-based
    /// pruning in the plan search sound.
    pub fn lower_bound(&self) -> f64 {
        let mut bound = 0.0f64;
        for stream in &self.streams {
            let serial: f64 = stream
                .iter()
                .map(|&tid| {
                    let t = &self.tasks[tid.0];
                    t.setup + t.work
                })
                .sum();
            bound = bound.max(serial);
        }
        let mut usage = vec![0.0f64; self.capacities.len()];
        for t in &self.tasks {
            for &(r, demand) in &self.demands_flat[t.demands_at.0..t.demands_at.1] {
                usage[r.0] += t.work * demand;
            }
        }
        for (u, &cap) in usage.iter().zip(&self.capacities) {
            bound = bound.max(u / cap);
        }
        bound
    }

    /// Run to completion, consuming the engine (compatibility shim
    /// over [`Engine::run_full`]).
    pub fn run(mut self) -> Result<Report, SimError> {
        self.run_full()
    }

    /// Run to completion with full per-task/per-resource accounting.
    /// The engine (graph and scratch) stays usable afterwards.
    ///
    /// With `FICCO_SIM_TRACE` set this installs a
    /// [`StderrRecorder`], reproducing the legacy trace stream;
    /// otherwise the [`NullRecorder`] compiles the hooks away.
    pub fn run_full(&mut self) -> Result<Report, SimError> {
        if self.trace {
            self.run_full_recorded(&mut StderrRecorder)
        } else {
            self.run_full_recorded(&mut NullRecorder)
        }
    }

    /// As [`Engine::run_full`], with an explicit [`Recorder`]
    /// observing every structural event — this is how the flight
    /// recorder (`crate::obs::TimelineRecorder`) captures a full
    /// timeline without perturbing the simulation: the recorder only
    /// reads, so makespans and busy integrals are bit-identical to an
    /// unobserved run.
    pub fn run_full_recorded<R: Recorder>(&mut self, rec: &mut R) -> Result<Report, SimError> {
        let mut s = std::mem::take(&mut self.scratch);
        let res = self.run_core(&mut s, false, rec);
        let out = res.map(|(makespan, events)| self.package_report(&s, makespan, events));
        self.scratch = s;
        out
    }

    /// Package the post-run scratch into a full [`Report`].
    fn package_report(&self, s: &RunScratch, makespan: f64, events: usize) -> Report {
        let n = self.tasks.len();
        let task_spans = (0..n).map(|i| (s.start[i], s.finish[i])).collect();
        let task_run_time = (0..n)
            .map(|i| {
                if s.run_start[i].is_nan() {
                    0.0
                } else {
                    s.finish[i] - s.run_start[i]
                }
            })
            .collect();
        let ideal_work = self.tasks.iter().map(|t| t.work).collect();
        Report {
            makespan,
            task_spans,
            task_run_time,
            resource_busy: s.resource_busy.clone(),
            events,
            ideal_work,
        }
    }

    /// Run to completion reporting only the makespan and event count:
    /// per-task spans/run times and per-resource busy integrals are
    /// not accumulated at all. The makespan is bit-identical to
    /// [`Engine::run_full`]'s (those sums never feed back into rates
    /// or event times).
    pub fn run_lean(&mut self) -> Result<LeanReport, SimError> {
        let mut s = std::mem::take(&mut self.scratch);
        let res = if self.trace {
            self.run_core(&mut s, true, &mut StderrRecorder)
        } else {
            self.run_core(&mut s, true, &mut NullRecorder)
        };
        self.scratch = s;
        res.map(|(makespan, events)| LeanReport { makespan, events })
    }

    // --- resumable stepper API (DESIGN.md §11) ---
    //
    // `begin_run*` / `step` / `advance_until` / `admit_*` / `finish_*`
    // expose the event loop one event at a time with the virtual clock
    // owned by the caller. Driving `begin_run` + `step`-to-completion
    // + `finish_run` is bit-identical to `run_full` (the one-shot
    // paths are thin drivers over the same core), and steady-state
    // stepping allocates nothing once scratch is warm — arenas grow
    // only at admission.

    /// Begin a resumable full-accounting run over the currently built
    /// graph (the counterpart of [`Engine::run_full`]). Follow with
    /// [`Engine::step`] / [`Engine::advance_until`] /
    /// [`Engine::admit_tasks`], then [`Engine::finish_run`].
    pub fn begin_run(&mut self) {
        if self.trace {
            self.begin_run_recorded(&mut StderrRecorder)
        } else {
            self.begin_run_recorded(&mut NullRecorder)
        }
    }

    /// As [`Engine::begin_run`] with an explicit [`Recorder`]. The
    /// recorder is passed per stepper call (not stored), so pass the
    /// same one to every call of this run for a coherent timeline.
    pub fn begin_run_recorded<R: Recorder>(&mut self, rec: &mut R) {
        let mut s = std::mem::take(&mut self.scratch);
        self.begin_core(&mut s, false, rec);
        self.scratch = s;
    }

    /// Begin a resumable makespan-only run (the counterpart of
    /// [`Engine::run_lean`]): busy integrals are not accumulated, and
    /// the run must end with [`Engine::finish_lean`].
    pub fn begin_run_lean(&mut self) {
        let mut s = std::mem::take(&mut self.scratch);
        if self.trace {
            self.begin_core(&mut s, true, &mut StderrRecorder);
        } else {
            self.begin_core(&mut s, true, &mut NullRecorder);
        }
        self.scratch = s;
    }

    /// Process exactly one event of the active run. A step on a run
    /// whose admitted tasks are all done is a no-op reporting
    /// `finished`. The event sequence (and every float) is identical
    /// to the one the one-shot paths process.
    pub fn step(&mut self) -> Result<StepReport, SimError> {
        if self.trace {
            self.step_recorded(&mut StderrRecorder)
        } else {
            self.step_recorded(&mut NullRecorder)
        }
    }

    /// As [`Engine::step`] with an explicit [`Recorder`].
    pub fn step_recorded<R: Recorder>(&mut self, rec: &mut R) -> Result<StepReport, SimError> {
        let mut s = std::mem::take(&mut self.scratch);
        assert!(s.active, "step: no active run (call begin_run first)");
        if s.done_count >= s.n_admitted {
            let rep = StepReport {
                now: s.now,
                started: 0,
                completed: 0,
                finished: true,
            };
            self.scratch = s;
            return Ok(rep);
        }
        let res = self.step_core(&mut s, rec);
        if res.is_err() {
            s.active = false;
        }
        let rep = res.map(|(started, completed)| StepReport {
            now: s.now,
            started,
            completed,
            finished: s.done_count >= s.n_admitted,
        });
        self.scratch = s;
        rep
    }

    /// Process events until the virtual clock reaches `t`. If the next
    /// event lies beyond `t`, running tasks advance over the exact
    /// partial interval (exact under the fluid model) and the event
    /// stays pending; if the run finishes before `t`, the idle clock
    /// jumps to `t` (the parking spot for the next admission).
    pub fn advance_until(&mut self, t: f64) -> Result<StepReport, SimError> {
        if self.trace {
            self.advance_until_recorded(t, &mut StderrRecorder)
        } else {
            self.advance_until_recorded(t, &mut NullRecorder)
        }
    }

    /// As [`Engine::advance_until`] with an explicit [`Recorder`].
    pub fn advance_until_recorded<R: Recorder>(
        &mut self,
        t: f64,
        rec: &mut R,
    ) -> Result<StepReport, SimError> {
        let mut s = std::mem::take(&mut self.scratch);
        assert!(s.active, "advance_until: no active run");
        let res = self.advance_until_core(&mut s, t, rec);
        let rep = res.map(|(started, completed)| StepReport {
            now: s.now,
            started,
            completed,
            finished: s.done_count >= s.n_admitted,
        });
        self.scratch = s;
        rep
    }

    /// Admit every task appended since the last begin/admission into
    /// the active run at the current virtual time, as a new
    /// *instance*: the tasks re-enter the ready/fair-rate machinery
    /// through the same promotion path the one-shot run uses, and fair
    /// sharing against already-running instances falls out of the
    /// per-resource flow lists. This is the allocation-lean admission
    /// path: build tasks with [`Engine::task`], then call this.
    pub fn admit_appended(&mut self) -> Result<(), SimError> {
        if self.trace {
            self.admit_appended_recorded(&mut StderrRecorder)
        } else {
            self.admit_appended_recorded(&mut NullRecorder)
        }
    }

    /// As [`Engine::admit_appended`] with an explicit [`Recorder`].
    pub fn admit_appended_recorded<R: Recorder>(&mut self, rec: &mut R) -> Result<(), SimError> {
        let mut s = std::mem::take(&mut self.scratch);
        assert!(s.active, "admit: no active run (call begin_run first)");
        let res = self.admit_appended_core(&mut s, rec);
        if res.is_err() {
            s.active = false;
        }
        self.scratch = s;
        res
    }

    /// Advance the clock to `at`, add `tasks`, and admit them as one
    /// instance. Convenience over [`Engine::advance_until`] +
    /// [`Engine::add_task`] + [`Engine::admit_appended`]; returns the
    /// new task ids. `at` must not be behind the virtual clock.
    pub fn admit_tasks(
        &mut self,
        at: f64,
        tasks: impl IntoIterator<Item = TaskSpec>,
    ) -> Result<Vec<TaskId>, SimError> {
        assert!(
            at >= self.scratch.now,
            "admit_tasks: admission time {at} behind virtual clock {}",
            self.scratch.now
        );
        self.advance_until(at)?;
        let ids: Vec<TaskId> = tasks.into_iter().map(|t| self.add_task(t)).collect();
        self.admit_appended()?;
        Ok(ids)
    }

    /// Drive the active full-accounting run to completion and package
    /// the [`Report`] (the stepper counterpart of
    /// [`Engine::run_full`]'s return).
    pub fn finish_run(&mut self) -> Result<Report, SimError> {
        if self.trace {
            self.finish_run_recorded(&mut StderrRecorder)
        } else {
            self.finish_run_recorded(&mut NullRecorder)
        }
    }

    /// As [`Engine::finish_run`] with an explicit [`Recorder`].
    pub fn finish_run_recorded<R: Recorder>(&mut self, rec: &mut R) -> Result<Report, SimError> {
        let mut s = std::mem::take(&mut self.scratch);
        assert!(s.active, "finish_run: no active run");
        assert!(
            !s.lean,
            "finish_run on a lean run (begin_run_lean): use finish_lean"
        );
        let res = self.finish_core(&mut s, rec);
        let out = res.map(|(makespan, events)| self.package_report(&s, makespan, events));
        self.scratch = s;
        out
    }

    /// Drive the active run to completion reporting only makespan and
    /// event count. Works for both lean and full runs.
    pub fn finish_lean(&mut self) -> Result<LeanReport, SimError> {
        let mut s = std::mem::take(&mut self.scratch);
        assert!(s.active, "finish_lean: no active run");
        let res = if self.trace {
            self.finish_core(&mut s, &mut StderrRecorder)
        } else {
            self.finish_core(&mut s, &mut NullRecorder)
        };
        self.scratch = s;
        res.map(|(makespan, events)| LeanReport { makespan, events })
    }

    /// Virtual time of the active (or just-finished) run.
    pub fn virtual_now(&self) -> f64 {
        self.scratch.now
    }

    /// True between `begin_run*` and `finish_*` (or a run error).
    pub fn run_active(&self) -> bool {
        self.scratch.active
    }

    /// Tasks completed so far in the current run.
    pub fn tasks_done(&self) -> usize {
        self.scratch.done_count
    }

    /// Events processed so far in the current run.
    pub fn events_so_far(&self) -> usize {
        self.scratch.events
    }

    /// Number of admitted instances (task batches) in the current run.
    /// Instance 0 is the graph present at `begin_run`; each admission
    /// appends one.
    pub fn n_instances(&self) -> usize {
        self.scratch.instances.len()
    }

    /// Virtual time instance `k` was admitted at.
    pub fn instance_admitted_at(&self, k: usize) -> f64 {
        self.scratch.instances[k].admitted_at
    }

    /// Task-id range of instance `k` — instances own contiguous,
    /// disjoint id namespaces in admission order.
    pub fn instance_tasks(&self, k: usize) -> std::ops::Range<usize> {
        let ins = self.scratch.instances[k];
        ins.first..ins.end
    }

    /// Which instance task `tid` belongs to.
    pub fn instance_of_task(&self, tid: usize) -> usize {
        let ins = &self.scratch.instances;
        ins.partition_point(|i| i.end <= tid)
    }

    /// Completion span of instance `k`: time from its admission to its
    /// last task finishing. NaN while any of its tasks is unfinished.
    pub fn instance_makespan(&self, k: usize) -> f64 {
        let ins = self.scratch.instances[k];
        let mut last = f64::NEG_INFINITY;
        for i in ins.first..ins.end {
            let f = self.scratch.finish[i];
            if f.is_nan() {
                return f64::NAN;
            }
            if f > last {
                last = f;
            }
        }
        if last == f64::NEG_INFINITY {
            0.0
        } else {
            last - ins.admitted_at
        }
    }

    /// (start, finish) of task `tid` in the current/last run (NaN
    /// until the respective transition happened).
    pub fn task_span(&self, tid: usize) -> (f64, f64) {
        (self.scratch.start[tid], self.scratch.finish[tid])
    }

    /// Promote `tid` Blocked → Setup if its deps are met and it heads
    /// its stream's queue. Called exactly when one of those conditions
    /// may have just become true, replacing the reference engine's
    /// all-streams rescan; the promoted set per event is identical.
    fn try_promote<R: Recorder>(&self, s: &mut RunScratch, rec: &mut R, tid: usize, now: f64) {
        if s.phase[tid] != Phase::Blocked || s.deps_left[tid] != 0 {
            return;
        }
        let st = self.tasks[tid].stream.0;
        let c = s.stream_cursor[st];
        if c >= self.streams[st].len() || self.streams[st][c].0 != tid {
            return;
        }
        s.start[tid] = now;
        let until = now + self.tasks[tid].setup;
        s.setup_until[tid] = until;
        s.phase[tid] = Phase::Setup;
        s.setup_heap.push(Reverse((until.to_bits(), tid)));
        rec.on_ready(self, now, tid);
    }

    /// Progressive-filling max–min fair rates for the current running
    /// set, written into `s.rates` (parallel to `s.running`),
    /// dispatched to the configured [`FairMode`]. Under cross-check,
    /// the slow oracle additionally runs into separate buffers and any
    /// bitwise rate divergence panics with the offending tasks.
    fn fill_fair_rates(&self, s: &mut RunScratch) {
        match self.fair_mode {
            FairMode::Incremental => {
                self.fill_fair_rates_incremental(s);
                if self.check_rates {
                    self.cross_check_rates(s);
                }
            }
            FairMode::Slow => {
                let RunScratch {
                    running,
                    rates,
                    frozen,
                    rem,
                    sum,
                    ..
                } = s;
                self.fill_fair_rates_slow(running, rates, frozen, rem, sum);
            }
        }
    }

    /// From-scratch progressive filling over the whole running set —
    /// the pre-incremental algorithm, kept **verbatim** (same float
    /// ops, same order) as the baseline `FairMode::Slow` runs and the
    /// oracle the cross-check mode compares against. Buffers are
    /// caller-supplied so the oracle never aliases incremental state.
    fn fill_fair_rates_slow(
        &self,
        running: &[usize],
        rates: &mut Vec<f64>,
        frozen: &mut Vec<bool>,
        rem: &mut Vec<f64>,
        sum: &mut Vec<f64>,
    ) {
        let m = running.len();
        rates.clear();
        rates.resize(m, 0.0);
        if m == 0 {
            return;
        }
        frozen.clear();
        frozen.resize(m, false);
        rem.clear();
        rem.extend_from_slice(&self.capacities);

        loop {
            // Aggregate unfrozen demand per resource.
            sum.clear();
            sum.resize(rem.len(), 0.0);
            let mut any_unfrozen = false;
            for (j, &i) in running.iter().enumerate() {
                if frozen[j] {
                    continue;
                }
                any_unfrozen = true;
                for &(r, d) in self.demands_of(i) {
                    sum[r.0] += d;
                }
            }
            if !any_unfrozen {
                break;
            }
            // Max uniform rate increment.
            let mut delta = f64::INFINITY;
            for j in 0..m {
                if !frozen[j] {
                    delta = delta.min(1.0 - rates[j]);
                }
            }
            for r in 0..rem.len() {
                if sum[r] > EPS {
                    delta = delta.min(rem[r] / sum[r]);
                }
            }
            if !delta.is_finite() || delta < 0.0 {
                break;
            }
            // Apply increment.
            for j in 0..m {
                if !frozen[j] {
                    rates[j] += delta;
                }
            }
            for r in 0..rem.len() {
                if sum[r] > EPS {
                    rem[r] -= delta * sum[r];
                }
            }
            // Freeze saturated tasks.
            let mut progressed = false;
            for (j, &i) in running.iter().enumerate() {
                if frozen[j] {
                    continue;
                }
                if rates[j] >= 1.0 - EPS {
                    frozen[j] = true;
                    progressed = true;
                    continue;
                }
                let saturated = self
                    .demands_of(i)
                    .iter()
                    .any(|&(r, d)| d > EPS && rem[r.0] <= EPS * self.capacities[r.0].max(1.0));
                if saturated {
                    frozen[j] = true;
                    progressed = true;
                }
            }
            if !progressed {
                // delta was limited by the 1.0 cap of a task that was
                // just frozen, or nothing changed: avoid spinning.
                break;
            }
        }
    }

    /// Incremental progressive filling — bit-identical rates to
    /// [`Engine::fill_fair_rates_slow`] at a fraction of the work:
    ///
    /// - **Round-1 sums** come from `agg_sum`, the cached
    ///   full-running-set aggregate per resource; only resources whose
    ///   membership changed since the last fill (`agg_dirty`, set by
    ///   task start/finish) are re-summed — bitwise safe because an
    ///   unchanged flow list replays the identical addition sequence.
    /// - **The uniform rate** of all never-frozen tasks is a single
    ///   accumulator `lambda` (the slow path adds the same delta to
    ///   every unfrozen task, so all those rates share one bit
    ///   pattern); a task's final rate is the value of `lambda` when
    ///   it froze.
    /// - **Freezing propagates through flow lists**: when a resource's
    ///   remainder crosses the saturation threshold, exactly its
    ///   running flows with demand > EPS freeze — no all-task scan.
    /// - **Per-round sums refresh only where membership changed**:
    ///   resources untouched by this round's freezes keep last round's
    ///   sum (same unfrozen flows ⇒ same addition sequence).
    ///
    /// The per-event slow-vs-incremental bitwise equivalence is
    /// asserted by the cross-check mode, `tests/fair_sharing.rs`, and
    /// the differential suite vs `sim::reference`.
    fn fill_fair_rates_incremental(&self, s: &mut RunScratch) {
        let m = s.running.len();
        s.rates.clear();
        s.rates.resize(m, 0.0);
        if m == 0 {
            return;
        }
        s.frozen.clear();
        s.frozen.resize(m, false);
        s.rem.clear();
        s.rem.extend_from_slice(&self.capacities);
        s.saturated.clear();
        s.saturated.resize(self.capacities.len(), false);

        // Round-1 sums: refresh dirty aggregates, reuse the rest.
        for k in 0..s.active_res.len() {
            let r = s.active_res[k] as usize;
            if s.agg_dirty[r] {
                let mut acc = 0.0f64;
                for &(_, d) in &s.flows[r] {
                    acc += d;
                }
                s.agg_sum[r] = acc;
                s.agg_dirty[r] = false;
            }
            s.sum[r] = s.agg_sum[r];
        }

        // Common rate of every never-frozen task (all grow in lockstep).
        let mut lambda = 0.0f64;
        let mut n_unfrozen = m;

        loop {
            if n_unfrozen == 0 {
                break;
            }
            // Max uniform rate increment: the 1.0 cap and resource
            // headroom over resources with unfrozen demand.
            let mut delta = 1.0 - lambda;
            for k in 0..s.active_res.len() {
                let r = s.active_res[k] as usize;
                if s.sum[r] > EPS {
                    delta = delta.min(s.rem[r] / s.sum[r]);
                }
            }
            if !delta.is_finite() || delta < 0.0 {
                break;
            }
            lambda += delta;
            for k in 0..s.active_res.len() {
                let r = s.active_res[k] as usize;
                if s.sum[r] > EPS {
                    s.rem[r] -= delta * s.sum[r];
                }
            }

            let mut progressed = false;
            if lambda >= 1.0 - EPS {
                // Every unfrozen task hits the rate cap together.
                for j in 0..m {
                    if !s.frozen[j] {
                        s.frozen[j] = true;
                        s.rates[j] = lambda;
                    }
                }
                n_unfrozen = 0;
                progressed = true;
            } else {
                // Saturation freezing via the flow lists of resources
                // that just crossed the threshold.
                s.newly_saturated.clear();
                for k in 0..s.active_res.len() {
                    let r = s.active_res[k] as usize;
                    if !s.saturated[r] && s.rem[r] <= EPS * self.capacities[r].max(1.0) {
                        s.saturated[r] = true;
                        s.newly_saturated.push(r as u32);
                    }
                }
                s.refresh_res.clear();
                for si in 0..s.newly_saturated.len() {
                    let r = s.newly_saturated[si] as usize;
                    for fi in 0..s.flows[r].len() {
                        let (t, d) = s.flows[r][fi];
                        if d <= EPS {
                            continue;
                        }
                        let j = s.running.partition_point(|&x| x < t as usize);
                        if s.frozen[j] {
                            continue;
                        }
                        s.frozen[j] = true;
                        s.rates[j] = lambda;
                        n_unfrozen -= 1;
                        progressed = true;
                        // This task's resources lose a term next round.
                        for &(rr, _) in self.demands_of(t as usize) {
                            if !s.refresh_mark[rr.0] {
                                s.refresh_mark[rr.0] = true;
                                s.refresh_res.push(rr.0 as u32);
                            }
                        }
                    }
                }
                // Fresh ascending-order sums where membership changed.
                for ri in 0..s.refresh_res.len() {
                    let r = s.refresh_res[ri] as usize;
                    s.refresh_mark[r] = false;
                    let mut acc = 0.0f64;
                    for fi in 0..s.flows[r].len() {
                        let (t, d) = s.flows[r][fi];
                        let j = s.running.partition_point(|&x| x < t as usize);
                        if !s.frozen[j] {
                            acc += d;
                        }
                    }
                    s.sum[r] = acc;
                }
            }
            if !progressed {
                break;
            }
        }
        // Tasks never frozen end at the final common rate.
        if n_unfrozen > 0 {
            for j in 0..m {
                if !s.frozen[j] {
                    s.rates[j] = lambda;
                }
            }
        }
    }

    /// Cross-check: run the slow oracle into separate buffers and
    /// panic if any rate differs bitwise from the incremental result.
    fn cross_check_rates(&self, s: &mut RunScratch) {
        let RunScratch {
            running,
            check_rates,
            check_frozen,
            check_rem,
            check_sum,
            ..
        } = s;
        self.fill_fair_rates_slow(running, check_rates, check_frozen, check_rem, check_sum);
        for (j, (&a, &b)) in s.rates.iter().zip(s.check_rates.iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                panic!(
                    "fair-rate cross-check: task {} ({}) incremental {:?} ({:#x}) \
                     != slow {:?} ({:#x}) over running set {:?}",
                    s.running[j],
                    self.tasks[s.running[j]].label,
                    a,
                    a.to_bits(),
                    b,
                    b.to_bits(),
                    s.running
                );
            }
        }
    }

    /// Register a task that just entered `Running` with the
    /// incremental fair-sharing state: insert its demands into the
    /// per-resource flow lists (ascending task order, duplicates in
    /// declaration order) and mark those resources membership-dirty.
    fn flows_add(&self, s: &mut RunScratch, i: usize) {
        for &(r, d) in self.demands_of(i) {
            let list = &mut s.flows[r.0];
            let pos = list.partition_point(|e| e.0 <= i as u32);
            list.insert(pos, (i as u32, d));
            s.agg_dirty[r.0] = true;
            if s.active_pos[r.0] == u32::MAX {
                s.active_pos[r.0] = s.active_res.len() as u32;
                s.active_res.push(r.0 as u32);
            }
        }
    }

    /// Remove a finished task's flows; resources left with no running
    /// flow leave the active set (order there is arbitrary, so a
    /// swap-remove is fine).
    fn flows_remove(&self, s: &mut RunScratch, i: usize) {
        for &(r, _) in self.demands_of(i) {
            let list = &mut s.flows[r.0];
            let a = list.partition_point(|e| e.0 < i as u32);
            let b = list.partition_point(|e| e.0 <= i as u32);
            if a < b {
                list.drain(a..b);
            }
            s.agg_dirty[r.0] = true;
            if list.is_empty() && s.active_pos[r.0] != u32::MAX {
                let p = s.active_pos[r.0] as usize;
                s.active_res.swap_remove(p);
                if p < s.active_res.len() {
                    s.active_pos[s.active_res[p] as usize] = p as u32;
                }
                s.active_pos[r.0] = u32::MAX;
            }
        }
    }

    /// Size and reset the cross-event incremental fair-sharing state
    /// for a run over the currently registered resources.
    fn init_fair_state(&self, s: &mut RunScratch) {
        let nr = self.capacities.len();
        if s.flows.len() < nr {
            s.flows.resize_with(nr, Vec::new);
        }
        for f in &mut s.flows {
            f.clear();
        }
        s.agg_sum.clear();
        s.agg_sum.resize(nr, 0.0);
        s.agg_dirty.clear();
        s.agg_dirty.resize(nr, false);
        s.active_res.clear();
        s.active_pos.clear();
        s.active_pos.resize(nr, u32::MAX);
        s.sum.clear();
        s.sum.resize(nr, 0.0);
        s.refresh_mark.clear();
        s.refresh_mark.resize(nr, false);
    }

    /// Fair rates for a hypothetical running set, computed by the
    /// given implementation without running the event loop — the probe
    /// `tests/fair_sharing.rs` drives its invariant properties
    /// through. Returns rates parallel to `running` (which may be in
    /// any order; duplicates are not allowed).
    pub fn probe_fair_rates(&mut self, running: &[TaskId], mode: FairMode) -> Vec<f64> {
        assert!(
            !self.scratch.active,
            "probe_fair_rates would clobber an active run's state"
        );
        let mut s = std::mem::take(&mut self.scratch);
        s.running.clear();
        for t in running {
            assert!(t.0 < self.tasks.len(), "probe: unknown task {:?}", t);
            s.running.push(t.0);
        }
        s.running.sort_unstable();
        debug_assert!(
            s.running.windows(2).all(|w| w[0] < w[1]),
            "probe: duplicate task in running set"
        );
        match mode {
            FairMode::Incremental => {
                self.init_fair_state(&mut s);
                for k in 0..s.running.len() {
                    let i = s.running[k];
                    self.flows_add(&mut s, i);
                }
                self.fill_fair_rates_incremental(&mut s);
            }
            FairMode::Slow => {
                let RunScratch {
                    running,
                    rates,
                    frozen,
                    rem,
                    sum,
                    ..
                } = &mut s;
                self.fill_fair_rates_slow(running, rates, frozen, rem, sum);
            }
        }
        let out = running
            .iter()
            .map(|t| {
                let j = s.running.partition_point(|&x| x < t.0);
                s.rates[j]
            })
            .collect();
        self.scratch = s;
        out
    }

    /// Initialize a resumable run over the currently built graph: size
    /// the scratch state, build the dependents CSR, reset the virtual
    /// clock, and promote head-of-stream tasks with no deps. The loop
    /// locals of the old run-to-completion core (`now`, `events`,
    /// `done_count`, `rates_dirty`) live in the scratch so the run can
    /// pause between events.
    fn begin_core<R: Recorder>(&self, s: &mut RunScratch, lean: bool, rec: &mut R) {
        let n = self.tasks.len();
        rec.on_begin(self);

        // Size and initialize the scratch state for this graph.
        s.phase.clear();
        s.phase.resize(n, Phase::Blocked);
        s.remaining.clear();
        s.remaining.extend(self.tasks.iter().map(|t| t.work));
        s.setup_until.clear();
        s.setup_until.resize(n, 0.0);
        s.start.clear();
        s.start.resize(n, f64::NAN);
        s.run_start.clear();
        s.run_start.resize(n, f64::NAN);
        s.finish.clear();
        s.finish.resize(n, f64::NAN);
        s.deps_left.clear();
        s.deps_left
            .extend(self.tasks.iter().map(|t| t.deps_at.1 - t.deps_at.0));
        s.stream_cursor.clear();
        s.stream_cursor.resize(self.streams.len(), 0);
        s.running.clear();
        s.setup_heap.clear();
        s.resource_busy.clear();
        s.resource_busy.resize(self.capacities.len(), 0.0);

        // Incremental fair-sharing bookkeeping is maintained only when
        // the incremental path will read it — the slow baseline must
        // not pay (or be credited for) its upkeep.
        if self.fair_mode == FairMode::Incremental {
            self.init_fair_state(s);
        }

        self.build_dependents(s);

        s.done_count = 0;
        s.now = 0.0;
        s.events = 0;
        s.rates_dirty = true;
        s.n_admitted = n;
        s.lean = lean;
        s.active = true;
        s.instances.clear();
        if n > 0 {
            s.instances.push(Instance {
                first: 0,
                end: n,
                admitted_at: 0.0,
            });
        }

        // Initial promotion: head-of-stream tasks with no deps.
        let now = s.now;
        for st in 0..self.streams.len() {
            if let Some(&tid) = self.streams[st].first() {
                self.try_promote(s, rec, tid.0, now);
            }
        }
    }

    /// (Re)build the dependents CSR over the whole graph
    /// (counts → prefix offsets → fill). Admission rebuilds it so new
    /// tasks' edges land in the arrays; buffers only grow then.
    fn build_dependents(&self, s: &mut RunScratch) {
        let n = self.tasks.len();
        s.dep_heads.clear();
        s.dep_heads.resize(n + 1, 0);
        for t in &self.tasks {
            for d in &self.deps_flat[t.deps_at.0..t.deps_at.1] {
                s.dep_heads[d.0 + 1] += 1;
            }
        }
        for i in 1..=n {
            s.dep_heads[i] += s.dep_heads[i - 1];
        }
        s.dep_cursor.clear();
        s.dep_cursor.extend_from_slice(&s.dep_heads[..n]);
        s.dep_list.clear();
        s.dep_list.resize(self.deps_flat.len(), TaskId(0));
        for (i, t) in self.tasks.iter().enumerate() {
            for &d in &self.deps_flat[t.deps_at.0..t.deps_at.1] {
                let c = s.dep_cursor[d.0];
                s.dep_list[c] = TaskId(i);
                s.dep_cursor[d.0] = c + 1;
            }
        }
    }

    /// Move Setup tasks whose latency elapsed into Running. The heap
    /// holds exactly the Setup-phase tasks, so popping every deadline
    /// ≤ now + EPS transitions the same set the reference engine finds
    /// by scanning all tasks.
    #[inline]
    fn pop_due_setups<R: Recorder>(&self, s: &mut RunScratch, rec: &mut R) {
        let inc = self.fair_mode == FairMode::Incremental;
        let threshold = s.now + EPS;
        while let Some(&Reverse((bits, tid))) = s.setup_heap.peek() {
            if f64::from_bits(bits) > threshold {
                break;
            }
            s.setup_heap.pop();
            s.phase[tid] = Phase::Running;
            s.run_start[tid] = s.now;
            let pos = s.running.partition_point(|&x| x < tid);
            s.running.insert(pos, tid);
            if inc {
                self.flows_add(s, tid);
            }
            s.rates_dirty = true;
            rec.on_start(self, s.now, tid);
        }
        // The heap pops deadline ties in ascending task order and the
        // sorted insert keeps `running` strictly ascending — the order
        // every float reduction in the loop depends on.
        debug_assert!(s.running.windows(2).all(|w| w[0] < w[1]));
    }

    #[inline]
    fn refill_rates_if_dirty<R: Recorder>(&self, s: &mut RunScratch, rec: &mut R) {
        if s.rates_dirty {
            self.fill_fair_rates(s);
            s.rates_dirty = false;
            rec.on_rates(self, s.now, &s.running, &s.rates);
        }
    }

    /// Time to the next event: earliest of (a) a running task
    /// finishing at its current rate, (b) a setup deadline expiring.
    #[inline]
    fn next_dt(&self, s: &RunScratch) -> f64 {
        let mut dt = f64::INFINITY;
        for (j, &i) in s.running.iter().enumerate() {
            if s.remaining[i] <= EPS {
                dt = 0.0;
                break;
            }
            if s.rates[j] > EPS {
                dt = dt.min(s.remaining[i] / s.rates[j]);
            }
        }
        if let Some(&Reverse((bits, _))) = s.setup_heap.peek() {
            // min over Setup tasks of (until - now).max(0) equals the
            // same expression at the smallest `until` — subtraction by
            // a common `now` is monotone.
            dt = dt.min((f64::from_bits(bits) - s.now).max(0.0));
        }
        dt
    }

    fn stuck_error(&self, s: &RunScratch) -> SimError {
        let now = s.now;
        let stuck: Vec<String> = (0..self.tasks.len())
            .filter(|&i| s.phase[i] != Phase::Done)
            .map(|i| self.tasks[i].label.to_string())
            .take(8)
            .collect();
        SimError(format!(
            "no runnable progress at t={now}; blocked tasks (cycle or zero-rate): {stuck:?}"
        ))
    }

    /// Integrate progress (and, in full mode, resource usage) over dt.
    #[inline]
    fn integrate<R: Recorder>(&self, s: &mut RunScratch, dt: f64, rec: &mut R) {
        rec.on_advance(self, s.now, dt, &s.running, &s.rates);
        let lean = s.lean;
        for (j, &i) in s.running.iter().enumerate() {
            let rate = s.rates[j];
            s.remaining[i] -= rate * dt;
            if !lean {
                for &(r, d) in self.demands_of(i) {
                    s.resource_busy[r.0] += rate * d * dt;
                }
            }
        }
        s.now += dt;
    }

    /// Complete tasks that hit zero remaining, then do the dependency
    /// and stream bookkeeping for the completed set, promoting newly
    /// eligible tasks at the same `now` the reference engine's
    /// end-of-event rescan would. Returns the completion count.
    #[inline]
    fn complete_and_promote<R: Recorder>(&self, s: &mut RunScratch, rec: &mut R) -> usize {
        let inc = self.fair_mode == FairMode::Incremental;
        let now = s.now;
        s.completed.clear();
        for &i in &s.running {
            if s.remaining[i] <= EPS {
                s.phase[i] = Phase::Done;
                s.finish[i] = now;
                s.completed.push(i);
                s.done_count += 1;
                rec.on_finish(self, now, i);
            }
        }
        if !s.completed.is_empty() {
            s.rates_dirty = true;
            let phase = &s.phase;
            s.running.retain(|&i| phase[i] == Phase::Running);
            // `completed` was collected by scanning the ascending
            // running set, so same-instant (float-equal) finishes are
            // processed in deterministic ascending task order — on
            // ties the incremental update order can never diverge from
            // the reference engine's rescan.
            debug_assert!(s.completed.windows(2).all(|w| w[0] < w[1]));
            if inc {
                for ci in 0..s.completed.len() {
                    let c = s.completed[ci];
                    self.flows_remove(s, c);
                }
            }
        }
        for ci in 0..s.completed.len() {
            let c = s.completed[ci];
            let (a, b) = (s.dep_heads[c], s.dep_heads[c + 1]);
            for k in a..b {
                let dep = s.dep_list[k].0;
                s.deps_left[dep] -= 1;
                if s.deps_left[dep] == 0 {
                    self.try_promote(s, rec, dep, now);
                }
            }
            // Advance the stream cursor past the completed prefix;
            // the newly exposed head may have become eligible.
            let st = self.tasks[c].stream.0;
            while s.stream_cursor[st] < self.streams[st].len() {
                let head = self.streams[st][s.stream_cursor[st]].0;
                if s.phase[head] == Phase::Done {
                    s.stream_cursor[st] += 1;
                } else {
                    self.try_promote(s, rec, head, now);
                    break;
                }
            }
        }
        s.completed.len()
    }

    /// Process exactly one event — one iteration of the old
    /// run-to-completion loop, same floating-point operations in the
    /// same order. Returns (started, completed) counts.
    fn step_core<R: Recorder>(
        &self,
        s: &mut RunScratch,
        rec: &mut R,
    ) -> Result<(usize, usize), SimError> {
        s.events += 1;
        if s.events > 200 * s.n_admitted + 1000 {
            return Err(SimError(format!(
                "event budget exceeded ({} events for {} tasks) — livelock?",
                s.events, s.n_admitted
            )));
        }

        let running_before = s.running.len();
        self.pop_due_setups(s, rec);
        let started = s.running.len() - running_before;

        self.refill_rates_if_dirty(s, rec);

        let dt = self.next_dt(s);
        if !dt.is_finite() {
            return Err(self.stuck_error(s));
        }

        if dt > 0.0 {
            self.integrate(s, dt, rec);
        }

        let completed = self.complete_and_promote(s, rec);
        Ok((started, completed))
    }

    /// Drive the stepper until every admitted task is done, fire
    /// `on_end`, and deactivate the run. Returns (makespan, events).
    fn finish_core<R: Recorder>(
        &self,
        s: &mut RunScratch,
        rec: &mut R,
    ) -> Result<(f64, usize), SimError> {
        while s.done_count < s.n_admitted {
            if let Err(e) = self.step_core(s, rec) {
                s.active = false;
                return Err(e);
            }
        }
        rec.on_end(self, s.now);
        s.active = false;
        Ok((s.now, s.events))
    }

    /// Process events until the virtual clock reaches `t` (or the run
    /// finishes first, in which case the clock jumps idle to `t`). If
    /// the next event lies beyond `t`, running tasks are integrated
    /// over the partial interval up to exactly `t` — exact under the
    /// fluid model — and the event itself stays pending; zero-dt
    /// cascades due exactly at `t` may also stay pending until the
    /// next stepper call at the same virtual time. Returns
    /// (started, completed) totals.
    fn advance_until_core<R: Recorder>(
        &self,
        s: &mut RunScratch,
        t: f64,
        rec: &mut R,
    ) -> Result<(usize, usize), SimError> {
        let mut started = 0usize;
        let mut completed = 0usize;
        loop {
            if s.done_count >= s.n_admitted {
                // Idle engine: the caller owns the clock and may park
                // it at `t` (e.g. to admit the next job there).
                if t > s.now {
                    s.now = t;
                }
                return Ok((started, completed));
            }
            if s.now >= t {
                return Ok((started, completed));
            }
            s.events += 1;
            if s.events > 200 * s.n_admitted + 1000 {
                s.active = false;
                return Err(SimError(format!(
                    "event budget exceeded ({} events for {} tasks) — livelock?",
                    s.events, s.n_admitted
                )));
            }
            let running_before = s.running.len();
            self.pop_due_setups(s, rec);
            started += s.running.len() - running_before;
            self.refill_rates_if_dirty(s, rec);
            let dt = self.next_dt(s);
            if !dt.is_finite() {
                s.active = false;
                return Err(self.stuck_error(s));
            }
            if s.now + dt > t {
                // Next event is beyond the horizon: advance exactly to
                // `t` and leave the event pending for the next call.
                let partial = t - s.now;
                if partial > 0.0 {
                    self.integrate(s, partial, rec);
                }
                s.now = t;
                return Ok((started, completed));
            }
            if dt > 0.0 {
                self.integrate(s, dt, rec);
            }
            completed += self.complete_and_promote(s, rec);
        }
    }

    /// Admit every task appended (via [`Engine::task`] /
    /// [`Engine::add_task`]) since the last begin/admission into the
    /// active run at the current virtual time: size the per-task
    /// scratch for the new ids, count their unmet deps, rebuild the
    /// dependents CSR, and re-enter the ready machinery through
    /// [`Engine::try_promote`]. Arenas grow only here, never per step.
    fn admit_appended_core<R: Recorder>(
        &self,
        s: &mut RunScratch,
        rec: &mut R,
    ) -> Result<(), SimError> {
        let n0 = s.n_admitted;
        let n = self.tasks.len();
        debug_assert!(n0 <= n);
        if n == n0 {
            return Ok(());
        }
        if self.capacities.len() != s.resource_busy.len() {
            return Err(SimError(
                "admit: resources must be registered before begin_run".to_string(),
            ));
        }
        // New streams may have been registered for the new tasks.
        if s.stream_cursor.len() < self.streams.len() {
            s.stream_cursor.resize(self.streams.len(), 0);
        }
        for i in n0..n {
            s.phase.push(Phase::Blocked);
            s.remaining.push(self.tasks[i].work);
            s.setup_until.push(0.0);
            s.start.push(f64::NAN);
            s.run_start.push(f64::NAN);
            s.finish.push(f64::NAN);
            // Deps on already-finished tasks are already met.
            let mut left = 0usize;
            for d in self.deps_of(i) {
                if s.phase[d.0] != Phase::Done {
                    left += 1;
                }
            }
            s.deps_left.push(left);
        }
        self.build_dependents(s);
        s.instances.push(Instance {
            first: n0,
            end: n,
            admitted_at: s.now,
        });
        s.n_admitted = n;
        // Promote eligible new tasks (dep-free stream heads). Only
        // Setup entries are created here; they enter Running — and
        // dirty the fair rates — when the next step pops them, exactly
        // as the one-shot path's initial promotion does.
        let now = s.now;
        for i in n0..n {
            self.try_promote(s, rec, i, now);
        }
        Ok(())
    }

    /// The one-shot event loop: begin, step to completion. Returns
    /// (makespan, events); per-task state is left in `s` for
    /// [`Engine::run_full`] to package. Bit-identical to the
    /// pre-stepper run-to-completion core.
    fn run_core<R: Recorder>(
        &self,
        s: &mut RunScratch,
        lean: bool,
        rec: &mut R,
    ) -> Result<(f64, usize), SimError> {
        self.begin_core(s, lean, rec);
        self.finish_core(s, rec)
    }
}

impl Default for Engine {
    fn default() -> Self {
        Engine::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(engine: Engine) -> Report {
        engine.run().expect("sim should complete")
    }

    #[test]
    fn single_task_runs_isolated() {
        let mut e = Engine::new();
        let r = e.add_resource(100.0);
        let s = e.add_stream();
        e.add_task(TaskSpec::new("t", s).work(2.0).demand(r, 100.0));
        let rep = quick(e);
        assert!((rep.makespan - 2.0).abs() < 1e-9);
    }

    #[test]
    fn setup_latency_adds() {
        let mut e = Engine::new();
        let s = e.add_stream();
        e.add_task(TaskSpec::new("t", s).work(1.0).setup(0.5));
        let rep = quick(e);
        assert!((rep.makespan - 1.5).abs() < 1e-9);
    }

    #[test]
    fn lower_bound_never_exceeds_makespan() {
        // Stream-serial chain plus a contended resource: the analytic
        // bound must stay at or below the simulated makespan, and the
        // stream bound must be exact when one stream dominates.
        let mut e = Engine::new();
        let r = e.add_resource(4.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(1.0).setup(0.25).demand(r, 2.0));
        e.add_task(TaskSpec::new("b", s1).work(2.0).demand(r, 2.0));
        e.add_task(TaskSpec::new("c", s2).work(0.5).demand(r, 4.0));
        let bound = e.lower_bound();
        assert!((bound - 3.25).abs() < 1e-9, "stream bound, got {bound}");
        let rep = quick(e);
        assert!(
            bound <= rep.makespan * (1.0 + 1e-9),
            "bound {bound} > makespan {}",
            rep.makespan
        );
    }

    #[test]
    fn lower_bound_sees_resource_totals() {
        // Two independent streams hammering one resource: the resource
        // bound (Σ work·demand / capacity) dominates the stream bound.
        let mut e = Engine::new();
        let r = e.add_resource(1.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(1.0).demand(r, 1.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r, 1.0));
        let bound = e.lower_bound();
        assert!((bound - 2.0).abs() < 1e-9, "resource bound, got {bound}");
        let rep = quick(e);
        assert!(bound <= rep.makespan * (1.0 + 1e-9));
    }

    #[test]
    fn two_tasks_share_resource_proportionally() {
        // Both demand the full resource: each runs at 0.5 → both take 2s.
        let mut e = Engine::new();
        let r = e.add_resource(10.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(1.0).demand(r, 10.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r, 10.0));
        let rep = quick(e);
        assert!((rep.makespan - 2.0).abs() < 1e-9, "makespan={}", rep.makespan);
    }

    #[test]
    fn unequal_demands_share_proportionally() {
        // a demands 8, b demands 2 of cap 5: uniform rate λ: 10λ=5 → 0.5
        // both at 0.5; b is NOT capped (its demand at rate 1 would be 2
        // ≤ spare? after freeze of a at 0.5... a frozen on saturation,
        // b also uses the saturated resource → frozen too at 0.5.
        let mut e = Engine::new();
        let r = e.add_resource(5.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(1.0).demand(r, 8.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r, 2.0));
        let rep = quick(e);
        assert!((rep.makespan - 2.0).abs() < 1e-9);
    }

    #[test]
    fn non_bottlenecked_task_runs_full_rate() {
        // Tasks on disjoint resources do not interfere.
        let mut e = Engine::new();
        let r1 = e.add_resource(1.0);
        let r2 = e.add_resource(1.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(3.0).demand(r1, 1.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r2, 1.0));
        let rep = quick(e);
        assert!((rep.makespan - 3.0).abs() < 1e-9);
        assert!((rep.task_spans[1].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn max_min_redistributes_leftover() {
        // a: needs r1 (cap 1, demand 1) and r2 (cap 10, demand 1).
        // b: needs r2 only, demand 10.
        // Uniform growth to λ where r2: λ(1+10)=10 → λ=0.909…? but r1
        // caps a at rate 1.0 first (λ=0.909 < 1) — r2 saturates first;
        // both end at 0.909.
        let mut e = Engine::new();
        let r1 = e.add_resource(1.0);
        let r2 = e.add_resource(10.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(1.0).demand(r1, 1.0).demand(r2, 1.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r2, 10.0));
        let rep = quick(e);
        let expected = 1.0 / (10.0 / 11.0);
        assert!(
            (rep.makespan - expected).abs() < 1e-6,
            "makespan={} expected={}",
            rep.makespan,
            expected
        );
    }

    #[test]
    fn stream_serializes() {
        let mut e = Engine::new();
        let r = e.add_resource(1.0);
        let s = e.add_stream();
        e.add_task(TaskSpec::new("a", s).work(1.0).demand(r, 1.0));
        e.add_task(TaskSpec::new("b", s).work(1.0).demand(r, 1.0));
        let rep = quick(e);
        // Same stream → serial even though the resource could only fit
        // one at a time anyway; check b starts after a ends.
        assert!((rep.makespan - 2.0).abs() < 1e-9);
        assert!(rep.task_spans[1].0 >= rep.task_spans[0].1 - 1e-9);
    }

    #[test]
    fn deps_respected_across_streams() {
        let mut e = Engine::new();
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        let a = e.add_task(TaskSpec::new("a", s1).work(1.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).dep(a));
        let rep = quick(e);
        assert!((rep.makespan - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_work_task_completes() {
        let mut e = Engine::new();
        let s = e.add_stream();
        let a = e.add_task(TaskSpec::new("sync", s).work(0.0));
        e.add_task(TaskSpec::new("b", s).work(1.0).dep(a));
        let rep = quick(e);
        assert!((rep.makespan - 1.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_accounting() {
        let mut e = Engine::new();
        let r = e.add_resource(4.0);
        let s = e.add_stream();
        e.add_task(TaskSpec::new("t", s).work(2.0).demand(r, 2.0));
        let rep = quick(e);
        // Uses 2 of 4 for 2 s → 50% utilization.
        assert!((rep.utilization(ResourceId(0), 4.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn contention_slowdown_reported() {
        let mut e = Engine::new();
        let r = e.add_resource(1.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        let a = e.add_task(TaskSpec::new("a", s1).work(1.0).demand(r, 1.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r, 1.0));
        let rep = quick(e);
        assert!((rep.slowdown(a) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn diamond_dag() {
        let mut e = Engine::new();
        let s: Vec<StreamId> = (0..4).map(|_| e.add_stream()).collect();
        let a = e.add_task(TaskSpec::new("a", s[0]).work(1.0));
        let b = e.add_task(TaskSpec::new("b", s[1]).work(2.0).dep(a));
        let c = e.add_task(TaskSpec::new("c", s[2]).work(1.0).dep(a));
        e.add_task(TaskSpec::new("d", s[3]).work(1.0).deps(&[b, c]));
        let rep = quick(e);
        assert!((rep.makespan - 4.0).abs() < 1e-9);
    }

    #[test]
    fn many_tasks_throughput() {
        // Sanity: engine handles thousands of tasks quickly.
        let mut e = Engine::new();
        let r = e.add_resource(100.0);
        let streams: Vec<StreamId> = (0..8).map(|_| e.add_stream()).collect();
        for i in 0..4000 {
            e.add_task(
                TaskSpec::new(format!("t{i}"), streams[i % 8])
                    .work(0.001)
                    .demand(r, 20.0),
            );
        }
        let rep = quick(e);
        assert!(rep.makespan > 0.0);
    }

    #[test]
    fn lean_run_matches_full_run_bitwise() {
        let mut e = Engine::new();
        let r1 = e.add_resource(3.0);
        let r2 = e.add_resource(7.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        let a = e.add_task(TaskSpec::new("a", s1).work(0.7).setup(0.1).demand(r1, 2.0));
        e.add_task(
            TaskSpec::new("b", s2)
                .work(1.3)
                .dep(a)
                .demand(r1, 2.5)
                .demand(r2, 6.0),
        );
        e.add_task(TaskSpec::new("c", s1).work(0.4).demand(r2, 7.0));
        let full = e.run_full().expect("full run");
        let lean = e.run_lean().expect("lean run");
        assert_eq!(full.makespan.to_bits(), lean.makespan.to_bits());
        assert_eq!(full.events, lean.events);
    }

    #[test]
    fn reset_and_rebuild_reuses_the_skeleton() {
        let mut e = Engine::new();
        let r = e.add_resource(2.0);
        let s = e.add_stream();
        e.add_task(TaskSpec::new("a", s).work(1.0).demand(r, 2.0));
        let first = e.run_lean().expect("first run").makespan;
        // Same graph again through the builder API after a reset: the
        // resources and streams survive, the makespan is identical.
        e.reset_tasks();
        assert_eq!(e.n_tasks(), 0);
        e.task("a", s).work(1.0).demand(r, 2.0).finish();
        let second = e.run_lean().expect("second run").makespan;
        assert_eq!(first.to_bits(), second.to_bits());
        // And a different graph sees the new tasks, not stale ones.
        e.reset_tasks();
        let t0 = e.task("x", s).work(1.0).demand(r, 2.0).finish();
        e.task("y", s).work(1.0).dep(t0).demand(r, 2.0).finish();
        let rep = e.run_full().expect("third run");
        assert_eq!(rep.task_spans.len(), 2);
        assert!((rep.makespan - 2.0).abs() < 1e-9);
    }

    #[test]
    fn builder_and_spec_produce_identical_graphs() {
        let build = |via_spec: bool| {
            let mut e = Engine::new();
            let r = e.add_resource(5.0);
            let s1 = e.add_stream();
            let s2 = e.add_stream();
            if via_spec {
                let a = e.add_task(TaskSpec::new("a", s1).work(0.5).setup(0.25).demand(r, 4.0));
                e.add_task(TaskSpec::new("b", s2).work(1.0).dep(a).demand(r, 3.0));
            } else {
                let a = e.task("a", s1).work(0.5).setup(0.25).demand(r, 4.0).finish();
                e.task("b", s2).work(1.0).dep(a).demand(r, 3.0).finish();
            }
            e.run_full().expect("run")
        };
        let a = build(true);
        let b = build(false);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.events, b.events);
        assert_eq!(a.task_spans, b.task_spans);
    }

    #[test]
    fn labels_render_lazily() {
        assert_eq!(Label::Static("gemm").to_string(), "gemm");
        assert_eq!(Label::indexed("n", 17).to_string(), "n17");
        assert_eq!(Label::from("x".to_string()).to_string(), "x");
    }

    #[test]
    fn stepper_replay_matches_run_full_bitwise() {
        let mut e = Engine::new();
        let r1 = e.add_resource(3.0);
        let r2 = e.add_resource(7.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        let a = e.add_task(TaskSpec::new("a", s1).work(0.7).setup(0.1).demand(r1, 2.0));
        e.add_task(
            TaskSpec::new("b", s2)
                .work(1.3)
                .dep(a)
                .demand(r1, 2.5)
                .demand(r2, 6.0),
        );
        e.add_task(TaskSpec::new("c", s1).work(0.4).demand(r2, 7.0));
        let full = e.run_full().expect("full run");
        e.begin_run();
        assert!(e.run_active());
        let mut steps = 0;
        loop {
            let st = e.step().expect("step");
            steps += 1;
            assert!(steps < 10_000, "stepper failed to converge");
            if st.finished {
                break;
            }
        }
        let rep = e.finish_run().expect("finish");
        assert!(!e.run_active());
        assert_eq!(full.makespan.to_bits(), rep.makespan.to_bits());
        assert_eq!(full.events, rep.events);
        assert_eq!(steps, rep.events);
        assert_eq!(full.task_spans, rep.task_spans);
        assert_eq!(full.resource_busy, rep.resource_busy);
    }

    #[test]
    fn lean_stepper_matches_run_lean_bitwise() {
        let mut e = Engine::new();
        let r = e.add_resource(5.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        let a = e.add_task(TaskSpec::new("a", s1).work(0.5).setup(0.25).demand(r, 4.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).dep(a).demand(r, 3.0));
        let lean = e.run_lean().expect("lean run");
        e.begin_run_lean();
        while !e.step().expect("step").finished {}
        let rep = e.finish_lean().expect("finish");
        assert_eq!(lean.makespan.to_bits(), rep.makespan.to_bits());
        assert_eq!(lean.events, rep.events);
    }

    #[test]
    fn advance_until_pauses_mid_task() {
        let mut e = Engine::new();
        let r = e.add_resource(1.0);
        let s = e.add_stream();
        e.add_task(TaskSpec::new("t", s).work(4.0).demand(r, 1.0));
        e.begin_run();
        let st = e.advance_until(1.5).expect("advance");
        assert_eq!(st.now.to_bits(), 1.5f64.to_bits());
        assert!(!st.finished);
        assert_eq!(e.virtual_now().to_bits(), 1.5f64.to_bits());
        let rep = e.finish_run().expect("finish");
        // 1.5 + 2.5 at rate 1 is exact: the pause must not move the
        // finish time.
        assert_eq!(rep.makespan.to_bits(), 4.0f64.to_bits());
    }

    #[test]
    fn midrun_admission_contends_like_a_joint_run() {
        // Instance 0 runs alone at rate 1 for 1s, then shares the
        // resource 50/50 with the instance admitted at t=1.
        let mut e = Engine::new();
        let r = e.add_resource(1.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(2.0).demand(r, 1.0));
        e.begin_run();
        e.admit_tasks(1.0, [TaskSpec::new("b", s2).work(1.0).demand(r, 1.0)])
            .expect("admit");
        let rep = e.finish_run().expect("finish");
        assert!((rep.makespan - 3.0).abs() < 1e-9, "makespan={}", rep.makespan);
        assert_eq!(e.n_instances(), 2);
        assert_eq!(e.instance_tasks(0), 0..1);
        assert_eq!(e.instance_tasks(1), 1..2);
        assert_eq!(e.instance_of_task(0), 0);
        assert_eq!(e.instance_of_task(1), 1);
        assert_eq!(e.instance_admitted_at(1).to_bits(), 1.0f64.to_bits());
        assert!((e.instance_makespan(0) - 3.0).abs() < 1e-9);
        assert!((e.instance_makespan(1) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn advance_parks_idle_clock_for_admission() {
        // A run begun over an empty graph is the co-tenant driver's
        // starting state: the clock parks wherever the first admission
        // wants it.
        let mut e = Engine::new();
        let r = e.add_resource(1.0);
        let s = e.add_stream();
        e.begin_run();
        assert_eq!(e.n_instances(), 0);
        e.admit_tasks(5.0, [TaskSpec::new("late", s).work(1.0).demand(r, 1.0)])
            .expect("admit");
        assert_eq!(e.virtual_now().to_bits(), 5.0f64.to_bits());
        let rep = e.finish_run().expect("finish");
        assert!((rep.makespan - 6.0).abs() < 1e-9);
        let (start, fin) = e.task_span(0);
        assert!((start - 5.0).abs() < 1e-9);
        assert!((fin - 6.0).abs() < 1e-9);
        assert!((e.instance_makespan(0) - 1.0).abs() < 1e-9);
    }
}
