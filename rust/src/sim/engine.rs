//! Generic fluid discrete-event engine.
//!
//! Tasks form a DAG and are additionally serialized by *streams*
//! (in-order queues, modelling GPU streams/DMA queues). A task that is
//! dependency-ready waits out its fixed `setup` latency (kernel launch,
//! link latency), then progresses at a rate in `[0, 1]` determined by
//! max–min fair sharing of the resources it demands. `work` is the
//! task's duration at rate 1 (its isolated execution time).

/// Index of a resource (capacity-limited, e.g. a link or a CU pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceId(pub usize);

/// Index of a task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub usize);

/// Index of a stream (in-order issue queue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamId(pub usize);

/// Task description handed to [`Engine::add_task`].
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub label: String,
    pub stream: StreamId,
    pub deps: Vec<TaskId>,
    /// Seconds of execution at rate 1.0 (isolated time, DIL included).
    pub work: f64,
    /// Fixed pre-work latency once ready (launch overhead, wire latency).
    pub setup: f64,
    /// Resource consumption per unit rate: at rate ρ the task uses
    /// `ρ·demand` of each listed resource.
    pub demands: Vec<(ResourceId, f64)>,
}

impl TaskSpec {
    pub fn new(label: impl Into<String>, stream: StreamId) -> TaskSpec {
        TaskSpec {
            label: label.into(),
            stream,
            deps: Vec::new(),
            work: 0.0,
            setup: 0.0,
            demands: Vec::new(),
        }
    }
    pub fn dep(mut self, t: TaskId) -> Self {
        self.deps.push(t);
        self
    }
    pub fn deps(mut self, ts: &[TaskId]) -> Self {
        self.deps.extend_from_slice(ts);
        self
    }
    pub fn work(mut self, w: f64) -> Self {
        self.work = w;
        self
    }
    pub fn setup(mut self, s: f64) -> Self {
        self.setup = s;
        self
    }
    pub fn demand(mut self, r: ResourceId, d: f64) -> Self {
        assert!(d >= 0.0);
        self.demands.push((r, d));
        self
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    /// Waiting on deps / stream order.
    Blocked,
    /// Deps met; absorbing fixed setup latency until the given time.
    Setup(f64),
    /// Progressing under fair-shared rates.
    Running,
    Done,
}

#[derive(Debug, Clone)]
struct Task {
    spec: TaskSpec,
    phase: Phase,
    remaining: f64,
    start: f64,
    run_start: f64,
    finish: f64,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct Report {
    /// Total simulated time until the last task completes.
    pub makespan: f64,
    /// Per-task (ready/queue-exit time, finish time).
    pub task_spans: Vec<(f64, f64)>,
    /// Per-task time actually spent in Running phase.
    pub task_run_time: Vec<f64>,
    /// Per-resource integral of consumption (capacity-units × seconds).
    pub resource_busy: Vec<f64>,
    /// Number of scheduling events processed.
    pub events: usize,
    /// Isolated work per task (copied from specs for slowdown calc).
    pub ideal_work: Vec<f64>,
}

impl Report {
    /// Contention slowdown of one task: running time / isolated work.
    /// 1.0 means the task never shared a bottleneck resource.
    pub fn slowdown(&self, t: TaskId) -> f64 {
        let i = t.0;
        let work = self.task_run_time[i];
        if work <= 0.0 {
            1.0
        } else {
            work / self.ideal_work[i].max(1e-30)
        }
    }

    /// Average utilization of a resource over the makespan.
    pub fn utilization(&self, r: ResourceId, capacity: f64) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.resource_busy[r.0] / (capacity * self.makespan)
    }
}

/// The engine. Build tasks, then [`Engine::run`].
#[derive(Debug, Clone)]
pub struct Engine {
    capacities: Vec<f64>,
    tasks: Vec<Task>,
    streams: Vec<Vec<TaskId>>,
    trace: bool,
}

#[derive(Debug)]
pub struct SimError(pub String);

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "sim error: {}", self.0)
    }
}
impl std::error::Error for SimError {}

const EPS: f64 = 1e-12;

impl Engine {
    pub fn new() -> Engine {
        Engine {
            capacities: Vec::new(),
            tasks: Vec::new(),
            streams: Vec::new(),
            trace: std::env::var("FICCO_SIM_TRACE").is_ok(),
        }
    }

    /// Register a resource with the given capacity; returns its id.
    pub fn add_resource(&mut self, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        self.capacities.push(capacity);
        ResourceId(self.capacities.len() - 1)
    }

    /// Register a stream (in-order issue queue); returns its id.
    pub fn add_stream(&mut self) -> StreamId {
        self.streams.push(Vec::new());
        StreamId(self.streams.len() - 1)
    }

    pub fn capacity(&self, r: ResourceId) -> f64 {
        self.capacities[r.0]
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Add a task. Demands must reference registered resources; the
    /// stream must be registered; deps must be earlier task ids.
    pub fn add_task(&mut self, spec: TaskSpec) -> TaskId {
        let id = TaskId(self.tasks.len());
        assert!(spec.stream.0 < self.streams.len(), "unknown stream");
        for &(r, _) in &spec.demands {
            assert!(r.0 < self.capacities.len(), "unknown resource");
        }
        for &d in &spec.deps {
            assert!(d.0 < id.0, "dep {:?} not earlier than task {:?}", d, id);
        }
        assert!(spec.work >= 0.0 && spec.setup >= 0.0);
        self.streams[spec.stream.0].push(id);
        self.tasks.push(Task {
            remaining: spec.work,
            spec,
            phase: Phase::Blocked,
            start: f64::NAN,
            run_start: f64::NAN,
            finish: f64::NAN,
        });
        id
    }

    /// Analytic lower bound on the makespan of the task graph as
    /// currently built, without running the simulation:
    ///
    /// - **stream bound** — tasks on one stream are issued strictly
    ///   in order, each paying its fixed `setup` and then at least
    ///   `work` (rates never exceed 1), so the makespan is at least
    ///   `Σ (setup + work)` over any single stream;
    /// - **resource bound** — a task running at rate ρ consumes
    ///   `ρ·demand` of a resource, integrating to `work·demand`
    ///   capacity-seconds over its life, so the makespan is at least
    ///   `Σ work·demand / capacity` for any single resource.
    ///
    /// Both are true lower bounds under the fluid model (contention
    /// only lowers rates), which is what makes incumbent-based
    /// pruning in the plan search sound.
    pub fn lower_bound(&self) -> f64 {
        let mut bound = 0.0f64;
        for stream in &self.streams {
            let serial: f64 = stream
                .iter()
                .map(|&tid| {
                    let spec = &self.tasks[tid.0].spec;
                    spec.setup + spec.work
                })
                .sum();
            bound = bound.max(serial);
        }
        let mut usage = vec![0.0f64; self.capacities.len()];
        for task in &self.tasks {
            for &(r, demand) in &task.spec.demands {
                usage[r.0] += task.spec.work * demand;
            }
        }
        for (u, &cap) in usage.iter().zip(&self.capacities) {
            bound = bound.max(u / cap);
        }
        bound
    }

    /// Run to completion.
    pub fn run(mut self) -> Result<Report, SimError> {
        let n = self.tasks.len();
        let mut done_count = 0usize;
        let mut now = 0.0f64;
        let mut events = 0usize;
        let mut resource_busy = vec![0.0f64; self.capacities.len()];
        // Per-stream cursor: next task index in the stream not yet done.
        let mut stream_cursor = vec![0usize; self.streams.len()];
        // Dep completion counting.
        let mut deps_left: Vec<usize> = self.tasks.iter().map(|t| t.spec.deps.len()).collect();
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (i, t) in self.tasks.iter().enumerate() {
            for &d in &t.spec.deps {
                dependents[d.0].push(TaskId(i));
            }
        }

        // Promote Blocked → Setup for every task whose deps and stream
        // predecessor are satisfied.
        let promote = |tasks: &mut Vec<Task>,
                           deps_left: &Vec<usize>,
                           stream_cursor: &Vec<usize>,
                           streams: &Vec<Vec<TaskId>>,
                           now: f64,
                           trace: bool| {
            for s in 0..streams.len() {
                let c = stream_cursor[s];
                if c >= streams[s].len() {
                    continue;
                }
                let tid = streams[s][c];
                let t = &mut tasks[tid.0];
                if t.phase == Phase::Blocked && deps_left[tid.0] == 0 {
                    t.start = now;
                    t.phase = Phase::Setup(now + t.spec.setup);
                    if trace {
                        eprintln!("[{now:.9}] ready  {}", t.spec.label);
                    }
                }
            }
        };

        promote(
            &mut self.tasks,
            &deps_left,
            &stream_cursor,
            &self.streams,
            now,
            self.trace,
        );

        while done_count < n {
            events += 1;
            if events > 200 * n + 1000 {
                return Err(SimError(format!(
                    "event budget exceeded ({} events for {} tasks) — livelock?",
                    events, n
                )));
            }

            // Move Setup tasks whose latency elapsed into Running.
            for t in self.tasks.iter_mut() {
                if let Phase::Setup(until) = t.phase {
                    if until <= now + EPS {
                        t.phase = Phase::Running;
                        t.run_start = now;
                    }
                }
            }

            // Collect running tasks and compute fair-share rates.
            let running: Vec<usize> = (0..n)
                .filter(|&i| self.tasks[i].phase == Phase::Running)
                .collect();
            let rates = self.fair_rates(&running);

            // Next event: earliest of (a) a running task finishing at
            // its current rate, (b) a setup deadline expiring.
            let mut dt = f64::INFINITY;
            for (j, &i) in running.iter().enumerate() {
                let t = &self.tasks[i];
                if t.remaining <= EPS {
                    dt = 0.0;
                    break;
                }
                if rates[j] > EPS {
                    dt = dt.min(t.remaining / rates[j]);
                }
            }
            for t in &self.tasks {
                if let Phase::Setup(until) = t.phase {
                    dt = dt.min((until - now).max(0.0));
                }
            }
            if !dt.is_finite() {
                let stuck: Vec<&str> = self
                    .tasks
                    .iter()
                    .filter(|t| t.phase != Phase::Done)
                    .map(|t| t.spec.label.as_str())
                    .take(8)
                    .collect();
                return Err(SimError(format!(
                    "no runnable progress at t={now}; blocked tasks (cycle or zero-rate): {stuck:?}"
                )));
            }

            // Integrate progress and resource usage over dt.
            if dt > 0.0 {
                for (j, &i) in running.iter().enumerate() {
                    let rate = rates[j];
                    self.tasks[i].remaining -= rate * dt;
                    for &(r, d) in &self.tasks[i].spec.demands {
                        resource_busy[r.0] += rate * d * dt;
                    }
                }
                now += dt;
            }

            // Complete tasks that hit zero remaining.
            let mut completed: Vec<TaskId> = Vec::new();
            for &i in &running {
                if self.tasks[i].remaining <= EPS {
                    self.tasks[i].phase = Phase::Done;
                    self.tasks[i].finish = now;
                    completed.push(TaskId(i));
                    done_count += 1;
                    if self.trace {
                        eprintln!("[{now:.9}] done   {}", self.tasks[i].spec.label);
                    }
                }
            }
            // Also complete zero-work tasks sitting in Setup with
            // elapsed deadline and no work (they became Running above).

            for c in &completed {
                for &dep in &dependents[c.0] {
                    deps_left[dep.0] -= 1;
                }
                let s = self.tasks[c.0].spec.stream.0;
                // Advance the stream cursor past completed prefix.
                while stream_cursor[s] < self.streams[s].len()
                    && self.tasks[self.streams[s][stream_cursor[s]].0].phase == Phase::Done
                {
                    stream_cursor[s] += 1;
                }
            }
            promote(
                &mut self.tasks,
                &deps_left,
                &stream_cursor,
                &self.streams,
                now,
                self.trace,
            );
        }

        let task_spans = self.tasks.iter().map(|t| (t.start, t.finish)).collect();
        let task_run_time = self
            .tasks
            .iter()
            .map(|t| {
                if t.run_start.is_nan() {
                    0.0
                } else {
                    t.finish - t.run_start
                }
            })
            .collect();
        let ideal_work = self.tasks.iter().map(|t| t.spec.work).collect();
        Ok(Report {
            makespan: now,
            task_spans,
            task_run_time,
            resource_busy,
            events,
            ideal_work,
        })
    }

    /// Progressive-filling max–min fair rates for the running set.
    /// All rates grow uniformly until a resource saturates (its tasks
    /// freeze) or a task reaches rate 1.0; repeats on the remainder.
    fn fair_rates(&self, running: &[usize]) -> Vec<f64> {
        let m = running.len();
        let mut rates = vec![0.0f64; m];
        if m == 0 {
            return rates;
        }
        let mut frozen = vec![false; m];
        let mut rem: Vec<f64> = self.capacities.clone();

        loop {
            // Aggregate unfrozen demand per resource.
            let mut sum = vec![0.0f64; rem.len()];
            let mut any_unfrozen = false;
            for (j, &i) in running.iter().enumerate() {
                if frozen[j] {
                    continue;
                }
                any_unfrozen = true;
                for &(r, d) in &self.tasks[i].spec.demands {
                    sum[r.0] += d;
                }
            }
            if !any_unfrozen {
                break;
            }
            // Max uniform rate increment.
            let mut delta = f64::INFINITY;
            for j in 0..m {
                if !frozen[j] {
                    delta = delta.min(1.0 - rates[j]);
                }
            }
            for r in 0..rem.len() {
                if sum[r] > EPS {
                    delta = delta.min(rem[r] / sum[r]);
                }
            }
            if !delta.is_finite() || delta < 0.0 {
                break;
            }
            // Apply increment.
            for (j, &i) in running.iter().enumerate() {
                if frozen[j] {
                    continue;
                }
                rates[j] += delta;
                let _ = i;
            }
            for r in 0..rem.len() {
                if sum[r] > EPS {
                    rem[r] -= delta * sum[r];
                }
            }
            // Freeze saturated tasks.
            let mut progressed = false;
            for (j, &i) in running.iter().enumerate() {
                if frozen[j] {
                    continue;
                }
                if rates[j] >= 1.0 - EPS {
                    frozen[j] = true;
                    progressed = true;
                    continue;
                }
                let saturated = self.tasks[i]
                    .spec
                    .demands
                    .iter()
                    .any(|&(r, d)| d > EPS && rem[r.0] <= EPS * self.capacities[r.0].max(1.0));
                if saturated {
                    frozen[j] = true;
                    progressed = true;
                }
            }
            if !progressed {
                // delta was limited by the 1.0 cap of a task that was
                // just frozen, or nothing changed: avoid spinning.
                break;
            }
        }
        rates
    }
}

impl Default for Engine {
    fn default() -> Self {
        Engine::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(engine: Engine) -> Report {
        engine.run().expect("sim should complete")
    }

    #[test]
    fn single_task_runs_isolated() {
        let mut e = Engine::new();
        let r = e.add_resource(100.0);
        let s = e.add_stream();
        e.add_task(TaskSpec::new("t", s).work(2.0).demand(r, 100.0));
        let rep = quick(e);
        assert!((rep.makespan - 2.0).abs() < 1e-9);
    }

    #[test]
    fn setup_latency_adds() {
        let mut e = Engine::new();
        let s = e.add_stream();
        e.add_task(TaskSpec::new("t", s).work(1.0).setup(0.5));
        let rep = quick(e);
        assert!((rep.makespan - 1.5).abs() < 1e-9);
    }

    #[test]
    fn lower_bound_never_exceeds_makespan() {
        // Stream-serial chain plus a contended resource: the analytic
        // bound must stay at or below the simulated makespan, and the
        // stream bound must be exact when one stream dominates.
        let mut e = Engine::new();
        let r = e.add_resource(4.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(1.0).setup(0.25).demand(r, 2.0));
        e.add_task(TaskSpec::new("b", s1).work(2.0).demand(r, 2.0));
        e.add_task(TaskSpec::new("c", s2).work(0.5).demand(r, 4.0));
        let bound = e.lower_bound();
        assert!((bound - 3.25).abs() < 1e-9, "stream bound, got {bound}");
        let rep = quick(e);
        assert!(
            bound <= rep.makespan * (1.0 + 1e-9),
            "bound {bound} > makespan {}",
            rep.makespan
        );
    }

    #[test]
    fn lower_bound_sees_resource_totals() {
        // Two independent streams hammering one resource: the resource
        // bound (Σ work·demand / capacity) dominates the stream bound.
        let mut e = Engine::new();
        let r = e.add_resource(1.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(1.0).demand(r, 1.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r, 1.0));
        let bound = e.lower_bound();
        assert!((bound - 2.0).abs() < 1e-9, "resource bound, got {bound}");
        let rep = quick(e);
        assert!(bound <= rep.makespan * (1.0 + 1e-9));
    }

    #[test]
    fn two_tasks_share_resource_proportionally() {
        // Both demand the full resource: each runs at 0.5 → both take 2s.
        let mut e = Engine::new();
        let r = e.add_resource(10.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(1.0).demand(r, 10.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r, 10.0));
        let rep = quick(e);
        assert!((rep.makespan - 2.0).abs() < 1e-9, "makespan={}", rep.makespan);
    }

    #[test]
    fn unequal_demands_share_proportionally() {
        // a demands 8, b demands 2 of cap 5: uniform rate λ: 10λ=5 → 0.5
        // both at 0.5; b is NOT capped (its demand at rate 1 would be 2
        // ≤ spare? after freeze of a at 0.5... a frozen on saturation,
        // b also uses the saturated resource → frozen too at 0.5.
        let mut e = Engine::new();
        let r = e.add_resource(5.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(1.0).demand(r, 8.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r, 2.0));
        let rep = quick(e);
        assert!((rep.makespan - 2.0).abs() < 1e-9);
    }

    #[test]
    fn non_bottlenecked_task_runs_full_rate() {
        // Tasks on disjoint resources do not interfere.
        let mut e = Engine::new();
        let r1 = e.add_resource(1.0);
        let r2 = e.add_resource(1.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(3.0).demand(r1, 1.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r2, 1.0));
        let rep = quick(e);
        assert!((rep.makespan - 3.0).abs() < 1e-9);
        assert!((rep.task_spans[1].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn max_min_redistributes_leftover() {
        // a: needs r1 (cap 1, demand 1) and r2 (cap 10, demand 1).
        // b: needs r2 only, demand 10.
        // Uniform growth to λ where r2: λ(1+10)=10 → λ=0.909…? but r1
        // caps a at rate 1.0 first (λ=0.909 < 1) — r2 saturates first;
        // both end at 0.909.
        let mut e = Engine::new();
        let r1 = e.add_resource(1.0);
        let r2 = e.add_resource(10.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        e.add_task(TaskSpec::new("a", s1).work(1.0).demand(r1, 1.0).demand(r2, 1.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r2, 10.0));
        let rep = quick(e);
        let expected = 1.0 / (10.0 / 11.0);
        assert!(
            (rep.makespan - expected).abs() < 1e-6,
            "makespan={} expected={}",
            rep.makespan,
            expected
        );
    }

    #[test]
    fn stream_serializes() {
        let mut e = Engine::new();
        let r = e.add_resource(1.0);
        let s = e.add_stream();
        e.add_task(TaskSpec::new("a", s).work(1.0).demand(r, 1.0));
        e.add_task(TaskSpec::new("b", s).work(1.0).demand(r, 1.0));
        let rep = quick(e);
        // Same stream → serial even though the resource could only fit
        // one at a time anyway; check b starts after a ends.
        assert!((rep.makespan - 2.0).abs() < 1e-9);
        assert!(rep.task_spans[1].0 >= rep.task_spans[0].1 - 1e-9);
    }

    #[test]
    fn deps_respected_across_streams() {
        let mut e = Engine::new();
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        let a = e.add_task(TaskSpec::new("a", s1).work(1.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).dep(a));
        let rep = quick(e);
        assert!((rep.makespan - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_work_task_completes() {
        let mut e = Engine::new();
        let s = e.add_stream();
        let a = e.add_task(TaskSpec::new("sync", s).work(0.0));
        e.add_task(TaskSpec::new("b", s).work(1.0).dep(a));
        let rep = quick(e);
        assert!((rep.makespan - 1.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_accounting() {
        let mut e = Engine::new();
        let r = e.add_resource(4.0);
        let s = e.add_stream();
        e.add_task(TaskSpec::new("t", s).work(2.0).demand(r, 2.0));
        let rep = quick(e);
        // Uses 2 of 4 for 2 s → 50% utilization.
        assert!((rep.utilization(ResourceId(0), 4.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn contention_slowdown_reported() {
        let mut e = Engine::new();
        let r = e.add_resource(1.0);
        let s1 = e.add_stream();
        let s2 = e.add_stream();
        let a = e.add_task(TaskSpec::new("a", s1).work(1.0).demand(r, 1.0));
        e.add_task(TaskSpec::new("b", s2).work(1.0).demand(r, 1.0));
        let rep = quick(e);
        assert!((rep.slowdown(a) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn diamond_dag() {
        let mut e = Engine::new();
        let s: Vec<StreamId> = (0..4).map(|_| e.add_stream()).collect();
        let a = e.add_task(TaskSpec::new("a", s[0]).work(1.0));
        let b = e.add_task(TaskSpec::new("b", s[1]).work(2.0).dep(a));
        let c = e.add_task(TaskSpec::new("c", s[2]).work(1.0).dep(a));
        e.add_task(TaskSpec::new("d", s[3]).work(1.0).deps(&[b, c]));
        let rep = quick(e);
        assert!((rep.makespan - 4.0).abs() < 1e-9);
    }

    #[test]
    fn many_tasks_throughput() {
        // Sanity: engine handles thousands of tasks quickly.
        let mut e = Engine::new();
        let r = e.add_resource(100.0);
        let streams: Vec<StreamId> = (0..8).map(|_| e.add_stream()).collect();
        for i in 0..4000 {
            e.add_task(
                TaskSpec::new(format!("t{i}"), streams[i % 8])
                    .work(0.001)
                    .demand(r, 20.0),
            );
        }
        let rep = quick(e);
        assert!(rep.makespan > 0.0);
    }
}
