//! Kept-verbatim reference implementation of the fluid engine.
//!
//! This is the pre-optimization event loop of [`super::engine`],
//! frozen so the differential property tests
//! (`rust/tests/engine_differential.rs`) can assert that the
//! scratch-buffer rewrite reports **bit-identical** makespans and
//! event counts on arbitrary DAGs. Debug/test builds only — it is
//! compiled out of release binaries. Do not "fix" or optimize this
//! module: its entire value is that it stays exactly the algorithm
//! the frozen goldens were recorded against.
//!
//! The only deliberate differences from the original file are
//! cosmetic: the shared id types ([`ResourceId`], [`StreamId`],
//! [`TaskId`]) and [`SimError`] are imported from the live engine so
//! a test can drive both engines with one DAG description, and labels
//! stay plain `String`s (the live engine's lazy [`super::Label`] is
//! part of the optimization under test).

use super::engine::{ResourceId, SimError, StreamId, TaskId};

/// Task description handed to [`Engine::add_task`] (original form,
/// with an eagerly built `String` label).
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub label: String,
    pub stream: StreamId,
    pub deps: Vec<TaskId>,
    /// Seconds of execution at rate 1.0 (isolated time, DIL included).
    pub work: f64,
    /// Fixed pre-work latency once ready (launch overhead, wire latency).
    pub setup: f64,
    /// Resource consumption per unit rate: at rate ρ the task uses
    /// `ρ·demand` of each listed resource.
    pub demands: Vec<(ResourceId, f64)>,
}

impl TaskSpec {
    pub fn new(label: impl Into<String>, stream: StreamId) -> TaskSpec {
        TaskSpec {
            label: label.into(),
            stream,
            deps: Vec::new(),
            work: 0.0,
            setup: 0.0,
            demands: Vec::new(),
        }
    }
    pub fn dep(mut self, t: TaskId) -> Self {
        self.deps.push(t);
        self
    }
    pub fn deps(mut self, ts: &[TaskId]) -> Self {
        self.deps.extend_from_slice(ts);
        self
    }
    pub fn work(mut self, w: f64) -> Self {
        self.work = w;
        self
    }
    pub fn setup(mut self, s: f64) -> Self {
        self.setup = s;
        self
    }
    pub fn demand(mut self, r: ResourceId, d: f64) -> Self {
        assert!(d >= 0.0);
        self.demands.push((r, d));
        self
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    /// Waiting on deps / stream order.
    Blocked,
    /// Deps met; absorbing fixed setup latency until the given time.
    Setup(f64),
    /// Progressing under fair-shared rates.
    Running,
    Done,
}

#[derive(Debug, Clone)]
struct Task {
    spec: TaskSpec,
    phase: Phase,
    remaining: f64,
    start: f64,
    run_start: f64,
    finish: f64,
}

/// Simulation output (reference form).
#[derive(Debug, Clone)]
pub struct Report {
    /// Total simulated time until the last task completes.
    pub makespan: f64,
    /// Per-task (ready/queue-exit time, finish time).
    pub task_spans: Vec<(f64, f64)>,
    /// Per-task time actually spent in Running phase.
    pub task_run_time: Vec<f64>,
    /// Per-resource integral of consumption (capacity-units × seconds).
    pub resource_busy: Vec<f64>,
    /// Number of scheduling events processed.
    pub events: usize,
    /// Isolated work per task (copied from specs for slowdown calc).
    pub ideal_work: Vec<f64>,
}

/// The reference engine. Build tasks, then [`Engine::run`].
#[derive(Debug, Clone)]
pub struct Engine {
    capacities: Vec<f64>,
    tasks: Vec<Task>,
    streams: Vec<Vec<TaskId>>,
    trace: bool,
}

const EPS: f64 = 1e-12;

impl Engine {
    pub fn new() -> Engine {
        Engine {
            capacities: Vec::new(),
            tasks: Vec::new(),
            streams: Vec::new(),
            trace: std::env::var("FICCO_SIM_TRACE").is_ok(),
        }
    }

    /// Register a resource with the given capacity; returns its id.
    pub fn add_resource(&mut self, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        self.capacities.push(capacity);
        ResourceId(self.capacities.len() - 1)
    }

    /// Register a stream (in-order issue queue); returns its id.
    pub fn add_stream(&mut self) -> StreamId {
        self.streams.push(Vec::new());
        StreamId(self.streams.len() - 1)
    }

    /// Add a task. Demands must reference registered resources; the
    /// stream must be registered; deps must be earlier task ids.
    pub fn add_task(&mut self, spec: TaskSpec) -> TaskId {
        let id = TaskId(self.tasks.len());
        assert!(spec.stream.0 < self.streams.len(), "unknown stream");
        for &(r, _) in &spec.demands {
            assert!(r.0 < self.capacities.len(), "unknown resource");
        }
        for &d in &spec.deps {
            assert!(d.0 < id.0, "dep {:?} not earlier than task {:?}", d, id);
        }
        assert!(spec.work >= 0.0 && spec.setup >= 0.0);
        self.streams[spec.stream.0].push(id);
        self.tasks.push(Task {
            remaining: spec.work,
            spec,
            phase: Phase::Blocked,
            start: f64::NAN,
            run_start: f64::NAN,
            finish: f64::NAN,
        });
        id
    }

    /// Run to completion.
    pub fn run(mut self) -> Result<Report, SimError> {
        let n = self.tasks.len();
        let mut done_count = 0usize;
        let mut now = 0.0f64;
        let mut events = 0usize;
        let mut resource_busy = vec![0.0f64; self.capacities.len()];
        // Per-stream cursor: next task index in the stream not yet done.
        let mut stream_cursor = vec![0usize; self.streams.len()];
        // Dep completion counting.
        let mut deps_left: Vec<usize> = self.tasks.iter().map(|t| t.spec.deps.len()).collect();
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (i, t) in self.tasks.iter().enumerate() {
            for &d in &t.spec.deps {
                dependents[d.0].push(TaskId(i));
            }
        }

        // Promote Blocked → Setup for every task whose deps and stream
        // predecessor are satisfied.
        let promote = |tasks: &mut Vec<Task>,
                           deps_left: &Vec<usize>,
                           stream_cursor: &Vec<usize>,
                           streams: &Vec<Vec<TaskId>>,
                           now: f64,
                           trace: bool| {
            for s in 0..streams.len() {
                let c = stream_cursor[s];
                if c >= streams[s].len() {
                    continue;
                }
                let tid = streams[s][c];
                let t = &mut tasks[tid.0];
                if t.phase == Phase::Blocked && deps_left[tid.0] == 0 {
                    t.start = now;
                    t.phase = Phase::Setup(now + t.spec.setup);
                    if trace {
                        crate::obs::print_ready(now, &t.spec.label);
                    }
                }
            }
        };

        promote(
            &mut self.tasks,
            &deps_left,
            &stream_cursor,
            &self.streams,
            now,
            self.trace,
        );

        while done_count < n {
            events += 1;
            if events > 200 * n + 1000 {
                return Err(SimError(format!(
                    "event budget exceeded ({} events for {} tasks) — livelock?",
                    events, n
                )));
            }

            // Move Setup tasks whose latency elapsed into Running.
            for t in self.tasks.iter_mut() {
                if let Phase::Setup(until) = t.phase {
                    if until <= now + EPS {
                        t.phase = Phase::Running;
                        t.run_start = now;
                    }
                }
            }

            // Collect running tasks and compute fair-share rates.
            let running: Vec<usize> = (0..n)
                .filter(|&i| self.tasks[i].phase == Phase::Running)
                .collect();
            let rates = self.fair_rates(&running);

            // Next event: earliest of (a) a running task finishing at
            // its current rate, (b) a setup deadline expiring.
            let mut dt = f64::INFINITY;
            for (j, &i) in running.iter().enumerate() {
                let t = &self.tasks[i];
                if t.remaining <= EPS {
                    dt = 0.0;
                    break;
                }
                if rates[j] > EPS {
                    dt = dt.min(t.remaining / rates[j]);
                }
            }
            for t in &self.tasks {
                if let Phase::Setup(until) = t.phase {
                    dt = dt.min((until - now).max(0.0));
                }
            }
            if !dt.is_finite() {
                let stuck: Vec<&str> = self
                    .tasks
                    .iter()
                    .filter(|t| t.phase != Phase::Done)
                    .map(|t| t.spec.label.as_str())
                    .take(8)
                    .collect();
                return Err(SimError(format!(
                    "no runnable progress at t={now}; blocked tasks (cycle or zero-rate): {stuck:?}"
                )));
            }

            // Integrate progress and resource usage over dt.
            if dt > 0.0 {
                for (j, &i) in running.iter().enumerate() {
                    let rate = rates[j];
                    self.tasks[i].remaining -= rate * dt;
                    for &(r, d) in &self.tasks[i].spec.demands {
                        resource_busy[r.0] += rate * d * dt;
                    }
                }
                now += dt;
            }

            // Complete tasks that hit zero remaining.
            let mut completed: Vec<TaskId> = Vec::new();
            for &i in &running {
                if self.tasks[i].remaining <= EPS {
                    self.tasks[i].phase = Phase::Done;
                    self.tasks[i].finish = now;
                    completed.push(TaskId(i));
                    done_count += 1;
                    if self.trace {
                        crate::obs::print_done(now, &self.tasks[i].spec.label);
                    }
                }
            }
            // Also complete zero-work tasks sitting in Setup with
            // elapsed deadline and no work (they became Running above).

            for c in &completed {
                for &dep in &dependents[c.0] {
                    deps_left[dep.0] -= 1;
                }
                let s = self.tasks[c.0].spec.stream.0;
                // Advance the stream cursor past completed prefix.
                while stream_cursor[s] < self.streams[s].len()
                    && self.tasks[self.streams[s][stream_cursor[s]].0].phase == Phase::Done
                {
                    stream_cursor[s] += 1;
                }
            }
            promote(
                &mut self.tasks,
                &deps_left,
                &stream_cursor,
                &self.streams,
                now,
                self.trace,
            );
        }

        let task_spans = self.tasks.iter().map(|t| (t.start, t.finish)).collect();
        let task_run_time = self
            .tasks
            .iter()
            .map(|t| {
                if t.run_start.is_nan() {
                    0.0
                } else {
                    t.finish - t.run_start
                }
            })
            .collect();
        let ideal_work = self.tasks.iter().map(|t| t.spec.work).collect();
        Ok(Report {
            makespan: now,
            task_spans,
            task_run_time,
            resource_busy,
            events,
            ideal_work,
        })
    }

    /// Progressive-filling max–min fair rates for the running set.
    /// All rates grow uniformly until a resource saturates (its tasks
    /// freeze) or a task reaches rate 1.0; repeats on the remainder.
    fn fair_rates(&self, running: &[usize]) -> Vec<f64> {
        let m = running.len();
        let mut rates = vec![0.0f64; m];
        if m == 0 {
            return rates;
        }
        let mut frozen = vec![false; m];
        let mut rem: Vec<f64> = self.capacities.clone();

        loop {
            // Aggregate unfrozen demand per resource.
            let mut sum = vec![0.0f64; rem.len()];
            let mut any_unfrozen = false;
            for (j, &i) in running.iter().enumerate() {
                if frozen[j] {
                    continue;
                }
                any_unfrozen = true;
                for &(r, d) in &self.tasks[i].spec.demands {
                    sum[r.0] += d;
                }
            }
            if !any_unfrozen {
                break;
            }
            // Max uniform rate increment.
            let mut delta = f64::INFINITY;
            for j in 0..m {
                if !frozen[j] {
                    delta = delta.min(1.0 - rates[j]);
                }
            }
            for r in 0..rem.len() {
                if sum[r] > EPS {
                    delta = delta.min(rem[r] / sum[r]);
                }
            }
            if !delta.is_finite() || delta < 0.0 {
                break;
            }
            // Apply increment.
            for (j, &i) in running.iter().enumerate() {
                if frozen[j] {
                    continue;
                }
                rates[j] += delta;
                let _ = i;
            }
            for r in 0..rem.len() {
                if sum[r] > EPS {
                    rem[r] -= delta * sum[r];
                }
            }
            // Freeze saturated tasks.
            let mut progressed = false;
            for (j, &i) in running.iter().enumerate() {
                if frozen[j] {
                    continue;
                }
                if rates[j] >= 1.0 - EPS {
                    frozen[j] = true;
                    progressed = true;
                    continue;
                }
                let saturated = self.tasks[i]
                    .spec
                    .demands
                    .iter()
                    .any(|&(r, d)| d > EPS && rem[r.0] <= EPS * self.capacities[r.0].max(1.0));
                if saturated {
                    frozen[j] = true;
                    progressed = true;
                }
            }
            if !progressed {
                // delta was limited by the 1.0 cap of a task that was
                // just frozen, or nothing changed: avoid spinning.
                break;
            }
        }
        rates
    }
}

impl Default for Engine {
    fn default() -> Self {
        Engine::new()
    }
}
