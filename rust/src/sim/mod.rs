//! Fluid discrete-event simulation of a multi-GPU node.
//!
//! The paper measures concurrent GPU kernels contending for compute
//! units, HBM bandwidth, network links and DMA engines (§IV, Fig 3d).
//! We model each of those as a capacity-limited *resource* and each
//! kernel/copy as a *task* that demands a vector of resources per unit
//! of progress. Between events, rates are constant and set by
//! progressive-filling max–min fair sharing — the standard fluid
//! approximation of hardware arbitration. Contention losses (CIL)
//! *emerge* from this sharing; decomposition losses (DIL) enter
//! through each task's isolated-time `work`, computed by `cost`.
//!
//! [`engine`] is the generic simulator; [`cluster`] instantiates the
//! resource set for a [`crate::hw::Machine`] and provides typed task
//! builders for GEMMs, core-driven comm, DMA copies and local
//! gather/scatter kernels.

pub mod cluster;
pub mod engine;

pub use cluster::{ClusterSim, CommMech};
pub use engine::{Engine, Report, ResourceId, StreamId, TaskId, TaskSpec};
