//! Fluid discrete-event simulation of a multi-GPU node.
//!
//! The paper measures concurrent GPU kernels contending for compute
//! units, HBM bandwidth, network links and DMA engines (§IV, Fig 3d).
//! We model each of those as a capacity-limited *resource* and each
//! kernel/copy as a *task* that demands a vector of resources per unit
//! of progress. Between events, rates are constant and set by
//! progressive-filling max–min fair sharing — the standard fluid
//! approximation of hardware arbitration. Contention losses (CIL)
//! *emerge* from this sharing; decomposition losses (DIL) enter
//! through each task's isolated-time `work`, computed by `cost`.
//!
//! [`engine`] is the generic simulator — zero-allocation in steady
//! state and reusable across task graphs (see `DESIGN.md` §6);
//! [`cluster`] instantiates the resource set for a
//! [`crate::hw::Machine`] and provides typed task builders for GEMMs,
//! core-driven comm, DMA copies and local gather/scatter kernels.
//! [`reference`] (debug/test builds only) keeps the pre-optimization
//! event loop verbatim for the differential property tests.

pub mod cluster;
pub mod engine;
#[cfg(debug_assertions)]
pub mod reference;

pub use cluster::{ClusterSim, CommMech};
pub use engine::{
    check_rates_enabled, default_fair_mode, set_default_fair_mode, trace_enabled, Engine, FairMode,
    Label, LeanReport, Report, ResourceId, SimError, StepReport, StreamId, TaskId, TaskSpec,
};
