//! Line-oriented parser for the TOML subset described in [`super`].

use super::{Doc, Value};
use std::collections::BTreeMap;

/// Parse failure with 1-based line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for ParseError {}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError {
        line,
        msg: msg.into(),
    })
}

/// Parse a document.
pub fn parse(text: &str) -> Result<Doc, ParseError> {
    let mut doc = Doc::default();
    let mut current = String::new();
    doc.sections.insert(current.clone(), BTreeMap::new());

    for (lineno, raw) in text.lines().enumerate() {
        let line = lineno + 1;
        let trimmed = strip_comment(raw).trim();
        if trimmed.is_empty() {
            continue;
        }
        if let Some(rest) = trimmed.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                return err(line, "unterminated section header");
            };
            let name = name.trim();
            if name.is_empty() {
                return err(line, "empty section name");
            }
            current = name.to_string();
            doc.sections.entry(current.clone()).or_default();
            continue;
        }
        let Some((key, value)) = trimmed.split_once('=') else {
            return err(line, format!("expected 'key = value', got '{trimmed}'"));
        };
        let key = key.trim();
        if key.is_empty() {
            return err(line, "empty key");
        }
        let value = parse_value(value.trim(), line)?;
        let section = doc.sections.get_mut(&current).unwrap();
        if section.insert(key.to_string(), value).is_some() {
            return err(line, format!("duplicate key '{key}' in section '[{current}]'"));
        }
    }
    Ok(doc)
}

/// Strip a trailing `#` comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    if s.is_empty() {
        return err(line, "empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            return err(line, "unterminated string");
        };
        if inner.contains('"') {
            return err(line, "embedded quote in string (escapes unsupported)");
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let Some(inner) = rest.strip_suffix(']') else {
            return err(line, "unterminated array");
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let mut out = Vec::new();
        for elem in split_array_elems(inner) {
            out.push(parse_value(elem.trim(), line)?);
        }
        return Ok(Value::Array(out));
    }
    // Numbers: allow underscores for readability (TOML-style).
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    err(line, format!("unparseable value '{s}'"))
}

/// Split a flat array body on commas outside string literals.
fn split_array_elems(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_types() {
        let d = parse(
            "s = \"hi\"\ni = 42\nneg = -3\nf = 1.5\nexp = 1e9\nb = true\narr = [1, 2, 3]\nsarr = [\"a\", \"b,c\"]\nu = 1_000_000\n",
        )
        .unwrap();
        assert_eq!(d.get("", "s"), Some(&Value::Str("hi".into())));
        assert_eq!(d.get("", "i"), Some(&Value::Int(42)));
        assert_eq!(d.get("", "neg"), Some(&Value::Int(-3)));
        assert_eq!(d.get("", "f"), Some(&Value::Float(1.5)));
        assert_eq!(d.get("", "exp"), Some(&Value::Float(1e9)));
        assert_eq!(d.get("", "b"), Some(&Value::Bool(true)));
        assert_eq!(d.get("", "u"), Some(&Value::Int(1_000_000)));
        assert_eq!(
            d.get("", "arr"),
            Some(&Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)]))
        );
        assert_eq!(
            d.get("", "sarr"),
            Some(&Value::Array(vec![
                Value::Str("a".into()),
                Value::Str("b,c".into())
            ]))
        );
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let d = parse("# header\n\nx = 1 # trailing\ny = \"a # not comment\"\n").unwrap();
        assert_eq!(d.get("", "x"), Some(&Value::Int(1)));
        assert_eq!(d.get("", "y"), Some(&Value::Str("a # not comment".into())));
    }

    #[test]
    fn sections() {
        let d = parse("[a]\nx = 1\n[b.c]\nx = 2\n").unwrap();
        assert_eq!(d.get("a", "x"), Some(&Value::Int(1)));
        assert_eq!(d.get("b.c", "x"), Some(&Value::Int(2)));
    }

    #[test]
    fn error_reporting() {
        for (text, frag) in [
            ("[open\n", "unterminated section"),
            ("novalue\n", "expected 'key = value'"),
            ("x = \"open\n", "unterminated string"),
            ("x = [1, 2\n", "unterminated array"),
            ("x = @@@\n", "unparseable value"),
            ("x = 1\nx = 2\n", "duplicate key"),
        ] {
            let e = parse(text).unwrap_err();
            assert!(e.msg.contains(frag), "'{text}' → {e}");
        }
    }

    #[test]
    fn duplicate_across_sections_ok() {
        let d = parse("[a]\nx = 1\n[b]\nx = 2\n").unwrap();
        assert_eq!(d.get("a", "x"), Some(&Value::Int(1)));
        assert_eq!(d.get("b", "x"), Some(&Value::Int(2)));
    }
}
