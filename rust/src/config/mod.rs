//! Configuration system: a TOML-subset parser with typed accessors.
//!
//! `serde`/`toml` are unavailable offline, so we implement the subset
//! the framework's config files need:
//!
//! - `[section]` headers (one level),
//! - `key = value` with value types: string (`"..."`), integer, float,
//!   boolean, and homogeneous arrays (`[1, 2, 3]`, `["a", "b"]`),
//! - `#` comments and blank lines.
//!
//! System presets live in `configs/*.toml`; `hw`, `workloads`, and
//! `train` build their typed structs from a parsed [`Doc`].

mod parse;

pub use parse::{parse, ParseError};

use std::collections::BTreeMap;

/// A configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`x = 5` reads as 5.0).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed config document: sections of key→value maps. Keys given
/// before any `[section]` land in the `""` (root) section.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

/// Typed access error with the offending `section.key` path.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}
impl std::error::Error for ConfigError {}

impl Doc {
    /// Load and parse a config file.
    pub fn load(path: &str) -> Result<Doc, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError(format!("reading {path}: {e}")))?;
        parse(&text).map_err(|e| ConfigError(format!("{path}: {e}")))
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str(&self, section: &str, key: &str) -> Result<&str, ConfigError> {
        self.get(section, key)
            .and_then(Value::as_str)
            .ok_or_else(|| ConfigError(format!("missing or non-string {section}.{key}")))
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn i64(&self, section: &str, key: &str) -> Result<i64, ConfigError> {
        self.get(section, key)
            .and_then(Value::as_i64)
            .ok_or_else(|| ConfigError(format!("missing or non-integer {section}.{key}")))
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn f64(&self, section: &str, key: &str) -> Result<f64, ConfigError> {
        self.get(section, key)
            .and_then(Value::as_f64)
            .ok_or_else(|| ConfigError(format!("missing or non-numeric {section}.{key}")))
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Array of i64 (e.g. GEMM dims `[16384, 16384, 131072]`).
    pub fn i64_array(&self, section: &str, key: &str) -> Result<Vec<i64>, ConfigError> {
        let arr = self
            .get(section, key)
            .and_then(Value::as_array)
            .ok_or_else(|| ConfigError(format!("missing or non-array {section}.{key}")))?;
        arr.iter()
            .map(|v| {
                v.as_i64()
                    .ok_or_else(|| ConfigError(format!("non-integer element in {section}.{key}")))
            })
            .collect()
    }

    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }

    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# system preset
name = "mi300x"

[gpu]
cus = 304
peak_bf16_tflops = 1307.4
hbm_gbps = 5300.0
dma_engines = 16
enable_dma = true

[topology]
kind = "full_mesh"
link_gbps = 64.0

[workload.g1]
gemm = [16384, 16384, 131072]
"#;

    #[test]
    fn typed_access() {
        let d = parse(SAMPLE).unwrap();
        assert_eq!(d.str("", "name").unwrap(), "mi300x");
        assert_eq!(d.i64("gpu", "cus").unwrap(), 304);
        assert!((d.f64("gpu", "peak_bf16_tflops").unwrap() - 1307.4).abs() < 1e-9);
        // int literal readable as f64
        assert_eq!(d.f64("gpu", "cus").unwrap(), 304.0);
        assert!(d.bool_or("gpu", "enable_dma", false));
        assert_eq!(d.str("topology", "kind").unwrap(), "full_mesh");
        assert_eq!(
            d.i64_array("workload.g1", "gemm").unwrap(),
            vec![16384, 16384, 131072]
        );
    }

    #[test]
    fn defaults_and_errors() {
        let d = parse(SAMPLE).unwrap();
        assert_eq!(d.i64_or("gpu", "absent", 7), 7);
        assert!(d.str("gpu", "cus").is_err()); // wrong type
        assert!(d.i64("nope", "nothing").is_err());
    }
}
