//! Structured timeline capture from a live simulation run.
//!
//! [`TimelineRecorder`] subscribes to every [`super::Recorder`] hook
//! and reconstructs the full schedule: per-task `ready → start →
//! finish` spans, per-resource busy integrals (replayed with the
//! *exact* accumulation expression and order the engine uses, so the
//! integrals are bit-identical to `Report::resource_busy`), per-
//! resource demand-rate segments at every fair-share refill, and the
//! derived inefficiency annotations the paper reads off timelines:
//! contention-throttled windows (fair-share rate below the task's
//! solo rate) and exposed-communication gaps (idle time between
//! consecutive tasks on a stream, derived at export time from the
//! spans).

use super::Recorder;
use crate::sim::Engine;

/// Matches the engine's internal epsilon so window/gap thresholds
/// agree with its event arithmetic.
const EPS: f64 = 1e-12;

/// Captures a full structured timeline from one `run_full_recorded`
/// call. All vectors are sized in [`Recorder::on_begin`]; a recorder
/// can be reused across runs (each `on_begin` resets it).
#[derive(Debug, Default)]
pub struct TimelineRecorder {
    /// Per-task time the task became ready (entered setup); NaN if
    /// never promoted.
    pub ready: Vec<f64>,
    /// Per-task time setup completed and work started.
    pub start: Vec<f64>,
    /// Per-task completion time.
    pub finish: Vec<f64>,
    /// Per-resource busy integral, bit-identical to the engine's
    /// `Report::resource_busy` accounting.
    pub busy: Vec<f64>,
    /// One entry per fair-share refill: `(time, per-resource total
    /// demand rate Σ rate_j · d_j)` over the running set at that
    /// instant.
    pub segments: Vec<(f64, Vec<f64>)>,
    /// Per-task solo rate: the rate the task would run at alone on
    /// the machine, `min(1, min_r capacity_r / demand_r)`.
    pub solo: Vec<f64>,
    /// Per-task contention-throttled windows `(t0, t1)` during which
    /// the task's fair-share rate was below its solo rate.
    pub throttled: Vec<Vec<(f64, f64)>>,
    /// Makespan reported by `on_end`; NaN until the run completes.
    pub end: f64,
    /// Open-window start per task (NaN = not currently throttled).
    throttle_since: Vec<f64>,
}

impl TimelineRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow the per-task buffers to the engine's current inventory.
    /// Mid-run admission (`Engine::admit_tasks`) appends tasks after
    /// `on_begin` sized everything; the first hook a new task fires is
    /// `on_ready`, which lands here. One-shot runs never take this
    /// path, so their capture is untouched bit for bit.
    fn ensure_tasks(&mut self, eng: &Engine, tid: usize) {
        if tid < self.ready.len() {
            return;
        }
        let n = eng.n_tasks();
        self.ready.resize(n, f64::NAN);
        self.start.resize(n, f64::NAN);
        self.finish.resize(n, f64::NAN);
        self.throttled.resize(n, Vec::new());
        self.throttle_since.resize(n, f64::NAN);
        let from = self.solo.len();
        self.solo.extend((from..n).map(|t| {
            let mut rate = 1.0f64;
            for &(r, d) in eng.task_demands(t) {
                if d > EPS {
                    rate = rate.min(eng.capacity(r) / d);
                }
            }
            rate
        }));
    }

    fn close_throttle(&mut self, tid: usize, now: f64) {
        let t0 = self.throttle_since[tid];
        self.throttle_since[tid] = f64::NAN;
        if now - t0 > EPS {
            self.throttled[tid].push((t0, now));
        }
    }

    /// Exposed gaps per stream, derived from the recorded spans: idle
    /// windows between one task's finish and the next task's ready on
    /// the same stream. Tasks are scanned in id order, which is
    /// execution order within a stream (streams are FIFO). The lead-in
    /// before a stream's first task is not counted — it is pipeline
    /// fill, not an exposed gap.
    pub fn stream_gaps(&self, eng: &Engine) -> Vec<Vec<(f64, f64)>> {
        let mut gaps = vec![Vec::new(); eng.n_streams()];
        let mut last_finish = vec![f64::NAN; eng.n_streams()];
        for tid in 0..eng.n_tasks() {
            // Admitted-but-never-promoted tasks may lie past the
            // captured range.
            if tid >= self.ready.len() || self.ready[tid].is_nan() {
                continue;
            }
            let s = eng.task_stream(tid).0;
            let prev = last_finish[s];
            if !prev.is_nan() && self.ready[tid] - prev > EPS {
                gaps[s].push((prev, self.ready[tid]));
            }
            last_finish[s] = self.finish[tid];
        }
        gaps
    }

    /// Total exposed-gap time summed over all streams.
    pub fn total_gap_time(&self, eng: &Engine) -> f64 {
        self.stream_gaps(eng)
            .iter()
            .flatten()
            .map(|&(t0, t1)| t1 - t0)
            .sum()
    }

    /// Total contention-throttled window time summed over all tasks.
    pub fn total_throttled_time(&self) -> f64 {
        self.throttled
            .iter()
            .flatten()
            .map(|&(t0, t1)| t1 - t0)
            .sum()
    }
}

impl Recorder for TimelineRecorder {
    fn on_begin(&mut self, eng: &Engine) {
        let n = eng.n_tasks();
        self.ready.clear();
        self.ready.resize(n, f64::NAN);
        self.start.clear();
        self.start.resize(n, f64::NAN);
        self.finish.clear();
        self.finish.resize(n, f64::NAN);
        self.busy.clear();
        self.busy.resize(eng.n_resources(), 0.0);
        self.segments.clear();
        self.throttled.clear();
        self.throttled.resize(n, Vec::new());
        self.throttle_since.clear();
        self.throttle_since.resize(n, f64::NAN);
        self.end = f64::NAN;
        self.solo.clear();
        self.solo.extend((0..n).map(|tid| {
            let mut rate = 1.0f64;
            for &(r, d) in eng.task_demands(tid) {
                if d > EPS {
                    rate = rate.min(eng.capacity(r) / d);
                }
            }
            rate
        }));
    }

    fn on_ready(&mut self, eng: &Engine, now: f64, tid: usize) {
        self.ensure_tasks(eng, tid);
        self.ready[tid] = now;
    }

    fn on_start(&mut self, _eng: &Engine, now: f64, tid: usize) {
        self.start[tid] = now;
    }

    fn on_rates(&mut self, eng: &Engine, now: f64, running: &[usize], rates: &[f64]) {
        let mut seg = vec![0.0; eng.n_resources()];
        for (j, &tid) in running.iter().enumerate() {
            for &(r, d) in eng.task_demands(tid) {
                seg[r.0] += rates[j] * d;
            }
        }
        self.segments.push((now, seg));
        for (j, &tid) in running.iter().enumerate() {
            let is_throttled = rates[j] < self.solo[tid] - EPS;
            let is_open = !self.throttle_since[tid].is_nan();
            if is_throttled && !is_open {
                self.throttle_since[tid] = now;
            } else if !is_throttled && is_open {
                self.close_throttle(tid, now);
            }
        }
    }

    fn on_advance(&mut self, eng: &Engine, _now: f64, dt: f64, running: &[usize], rates: &[f64]) {
        // Bit-exact replay of the engine's busy integration: same
        // expression, same (running-index, demand-declaration) order,
        // same 0.0 starting point — so `busy` matches the engine's
        // `resource_busy` to the last bit.
        for (j, &tid) in running.iter().enumerate() {
            let rate = rates[j];
            for &(r, d) in eng.task_demands(tid) {
                self.busy[r.0] += rate * d * dt;
            }
        }
    }

    fn on_finish(&mut self, _eng: &Engine, now: f64, tid: usize) {
        self.finish[tid] = now;
        if !self.throttle_since[tid].is_nan() {
            self.close_throttle(tid, now);
        }
    }

    fn on_end(&mut self, _eng: &Engine, now: f64) {
        self.end = now;
        for tid in 0..self.throttle_since.len() {
            if !self.throttle_since[tid].is_nan() {
                self.close_throttle(tid, now);
            }
        }
    }
}
