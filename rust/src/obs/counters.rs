//! Search/pipeline telemetry: lock-free per-worker counters merged at
//! pool join, surfaced as the `telemetry` block in `tune.json` /
//! `sweep.json` and the `--stats` summary table.
//!
//! The counters are plain `u64` fields on each worker's
//! [`crate::schedule::exec::Evaluator`] — no atomics in the hot path;
//! each worker increments privately and the pool's join-time `fini`
//! callback merges them under a mutex touched once per worker.
//!
//! Because cache hit/miss splits and wall-clock timings depend on
//! cross-cell scheduling, the whole telemetry block is *excluded*
//! from the jobs=1-vs-4 byte-determinism contract:
//! [`canonical_artifact_view`] strips it, and the determinism tests
//! compare that canonical view.

use crate::util::table::Table;
use std::fmt::Write as _;

/// Counts of work performed by one evaluation pipeline. `Default` is
/// all-zero; per-worker instances are summed with [`Counters::merge`]
/// when the pool joins.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Counters {
    /// Sweep/tune cells evaluated.
    pub cells: u64,
    /// Plan candidates enumerated by search (presets + space plans +
    /// beam neighbors considered).
    pub candidates: u64,
    /// Candidates actually simulated (cache misses included).
    pub evaluated: u64,
    /// Candidates discarded by the cost-model lower bound before
    /// simulation.
    pub pruned: u64,
    /// Beam-search rounds that expanded a frontier.
    pub beam_expansions: u64,
    /// Cells whose searched optimum was already in the warm seed set
    /// (a legacy preset or the model-predicted plan) — the whole
    /// remaining space only confirmed the seed incumbent.
    pub warm_hits: u64,
    /// Candidates pruned by the bound-sorted tail cut without an
    /// individual bound-vs-cutoff check: once the best-lower-bound-
    /// first order meets a bound above the cutoff, every remaining
    /// candidate's bound is at least as large (subset of `pruned`).
    pub bound_skips_early: u64,
    /// Candidates re-evaluated under a perturbation ensemble by
    /// robust selection (`--robust`); each costs `samples` extra
    /// simulations.
    pub robust_reranks: u64,
    /// Cells whose robust pick diverged from the nominal best plan —
    /// the headline robustness telemetry.
    pub pick_flips: u64,
}

impl Counters {
    pub fn merge(&mut self, other: &Counters) {
        self.cells += other.cells;
        self.candidates += other.candidates;
        self.evaluated += other.evaluated;
        self.pruned += other.pruned;
        self.beam_expansions += other.beam_expansions;
        self.warm_hits += other.warm_hits;
        self.bound_skips_early += other.bound_skips_early;
        self.robust_reranks += other.robust_reranks;
        self.pick_flips += other.pick_flips;
    }
}

/// The full telemetry block attached to a sweep/tune report: merged
/// worker counters, shared-cache statistics, and the wall-clock
/// measurements that used to leak into the byte-compared artifact
/// body.
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    /// Worker threads actually used.
    pub jobs: usize,
    /// End-to-end driver wall time.
    pub wall_seconds: f64,
    /// Merged per-worker pipeline counters.
    pub counters: Counters,
    /// Shared eval-cache hits, summed over shards.
    pub cache_hits: u64,
    /// Shared eval-cache misses, summed over shards.
    pub cache_misses: u64,
    /// Per-shard `(hits, misses)` of the sharded eval cache; empty
    /// when the run used no shared cache.
    pub cache_shards: Vec<(u64, u64)>,
    /// Per-cell evaluation wall time, in cell order.
    pub cell_seconds: Vec<f64>,
}

impl Telemetry {
    /// Sum of per-cell evaluation times (CPU-seconds across workers).
    pub fn cell_seconds_total(&self) -> f64 {
        self.cell_seconds.iter().sum()
    }

    /// Render as a single-line JSON object (the value of the
    /// artifact's `"telemetry"` key).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        write!(
            out,
            "{{\"jobs\":{},\"wall_seconds\":{},\"cells\":{},\"candidates\":{},\
             \"evaluated\":{},\"pruned\":{},\"beam_expansions\":{},\
             \"warm_hits\":{},\"bound_skips_early\":{},\
             \"robust_reranks\":{},\"pick_flips\":{}",
            self.jobs,
            self.wall_seconds,
            self.counters.cells,
            self.counters.candidates,
            self.counters.evaluated,
            self.counters.pruned,
            self.counters.beam_expansions,
            self.counters.warm_hits,
            self.counters.bound_skips_early,
            self.counters.robust_reranks,
            self.counters.pick_flips
        )
        .unwrap();
        write!(
            out,
            ",\"cache\":{{\"hits\":{},\"misses\":{},\"shards\":[",
            self.cache_hits, self.cache_misses
        )
        .unwrap();
        for (i, (h, m)) in self.cache_shards.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write!(out, "[{h},{m}]").unwrap();
        }
        out.push_str("]},\"cell_seconds\":[");
        for (i, s) in self.cell_seconds.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write!(out, "{s}").unwrap();
        }
        out.push_str("]}");
        out
    }

    /// Render as the `--stats` summary table.
    pub fn table(&self) -> Table {
        use crate::util::table::Align;
        let mut t = Table::new(vec!["metric", "value"]).align(0, Align::Left);
        t.row(vec!["jobs".to_string(), format!("{}", self.jobs)]);
        t.row(vec!["wall seconds".to_string(), format!("{:.3}", self.wall_seconds)]);
        t.row(vec![
            "cell eval seconds".to_string(),
            format!("{:.3}", self.cell_seconds_total()),
        ]);
        t.row(vec!["cells".to_string(), format!("{}", self.counters.cells)]);
        t.row(vec![
            "plan candidates".to_string(),
            format!("{}", self.counters.candidates),
        ]);
        t.row(vec![
            "plans evaluated".to_string(),
            format!("{}", self.counters.evaluated),
        ]);
        t.row(vec![
            "lower-bound prunes".to_string(),
            format!("{}", self.counters.pruned),
        ]);
        t.row(vec![
            "beam expansions".to_string(),
            format!("{}", self.counters.beam_expansions),
        ]);
        t.row(vec![
            "warm-seed hits".to_string(),
            format!("{}", self.counters.warm_hits),
        ]);
        t.row(vec![
            "early bound skips".to_string(),
            format!("{}", self.counters.bound_skips_early),
        ]);
        t.row(vec![
            "robust re-ranks".to_string(),
            format!("{}", self.counters.robust_reranks),
        ]);
        t.row(vec![
            "robust pick flips".to_string(),
            format!("{}", self.counters.pick_flips),
        ]);
        t.row(vec!["cache hits".to_string(), format!("{}", self.cache_hits)]);
        t.row(vec!["cache misses".to_string(), format!("{}", self.cache_misses)]);
        let lookups = self.cache_hits + self.cache_misses;
        let rate = if lookups > 0 {
            self.cache_hits as f64 / lookups as f64
        } else {
            0.0
        };
        t.row(vec!["cache hit rate".to_string(), format!("{:.1}%", rate * 100.0)]);
        t
    }
}

/// The determinism-comparable view of a sweep/tune JSON artifact:
/// everything up to and including the close of the `"results"` array,
/// with the trailing `"telemetry"` block (wall-clock timings, cache
/// splits — legitimately jobs-dependent) stripped. Artifacts without
/// a telemetry block pass through whole.
pub fn canonical_artifact_view(json: &str) -> &str {
    match json.find("\n],\n\"telemetry\":") {
        Some(pos) => &json[..pos + 2],
        None => json,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums_every_field() {
        let mut a = Counters {
            cells: 1,
            candidates: 2,
            evaluated: 3,
            pruned: 4,
            beam_expansions: 5,
            warm_hits: 6,
            bound_skips_early: 7,
            robust_reranks: 8,
            pick_flips: 9,
        };
        let b = Counters {
            cells: 10,
            candidates: 20,
            evaluated: 30,
            pruned: 40,
            beam_expansions: 50,
            warm_hits: 60,
            bound_skips_early: 70,
            robust_reranks: 80,
            pick_flips: 90,
        };
        a.merge(&b);
        assert_eq!(
            a,
            Counters {
                cells: 11,
                candidates: 22,
                evaluated: 33,
                pruned: 44,
                beam_expansions: 55,
                warm_hits: 66,
                bound_skips_early: 77,
                robust_reranks: 88,
                pick_flips: 99,
            }
        );
    }

    #[test]
    fn telemetry_json_is_one_well_formed_object() {
        let t = Telemetry {
            jobs: 4,
            wall_seconds: 0.5,
            counters: Counters {
                cells: 2,
                candidates: 9,
                evaluated: 7,
                pruned: 2,
                beam_expansions: 1,
                warm_hits: 2,
                bound_skips_early: 3,
                robust_reranks: 5,
                pick_flips: 1,
            },
            cache_hits: 3,
            cache_misses: 4,
            cache_shards: vec![(1, 2), (2, 2)],
            cell_seconds: vec![0.25, 0.25],
        };
        let json = t.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"jobs\":4"));
        assert!(json.contains("\"candidates\":9"));
        assert!(json.contains("\"warm_hits\":2"));
        assert!(json.contains("\"bound_skips_early\":3"));
        assert!(json.contains("\"robust_reranks\":5"));
        assert!(json.contains("\"pick_flips\":1"));
        assert!(json.contains("\"shards\":[[1,2],[2,2]]"));
        assert!(json.contains("\"cell_seconds\":[0.25,0.25]"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn canonical_view_strips_only_the_telemetry_tail() {
        let a = "{\"results\":[\n{\"x\":1}\n],\n\"telemetry\":{\"wall_seconds\":1.5}\n}\n";
        let b = "{\"results\":[\n{\"x\":1}\n],\n\"telemetry\":{\"wall_seconds\":9.9}\n}\n";
        assert_ne!(a, b);
        assert_eq!(canonical_artifact_view(a), canonical_artifact_view(b));
        assert!(canonical_artifact_view(a).ends_with("\n]"));
        let plain = "[\n{\"x\":1}\n]\n";
        assert_eq!(canonical_artifact_view(plain), plain);
    }

    #[test]
    fn stats_table_renders() {
        let t = Telemetry {
            jobs: 1,
            ..Default::default()
        };
        let table = t.table();
        assert!(table.n_rows() >= 8);
        let text = table.render();
        assert!(text.contains("cache hit rate"));
    }
}
