//! Byte-stable trace exporters: Chrome/Perfetto `trace.json` and a
//! flat `timeline.csv`.
//!
//! The JSON renderer emits one event per line in a fixed order
//! (metadata, plan instant, task spans ascending by task id, gap and
//! throttle windows, counter series), with all floats printed via
//! Rust's shortest-round-trip `Display` — so the artifact is
//! byte-identical for identical runs regardless of `--jobs`, and the
//! determinism tests can `cmp` it directly. Timestamps are exported
//! in microseconds (`ts = t · 1e6`) with `"displayTimeUnit": "ms"`,
//! which is what `ui.perfetto.dev` expects of Chrome-format traces.

use super::timeline::TimelineRecorder;
use crate::explore::emit::{csv_escape, json_escape};
use crate::sim::Engine;
use std::fmt::Write as _;

/// Where one simulation stream renders in the trace: a Perfetto
/// process/thread pair plus a human-readable thread name.
#[derive(Debug, Clone)]
pub struct StreamTrack {
    pub pid: usize,
    pub tid: usize,
    pub name: String,
}

/// Maps engine stream/resource indices onto Perfetto tracks.
///
/// Indexed by `StreamId.0` / `ResourceId.0` in engine registration
/// order. Cluster topologies get a GPU-per-process layout from
/// `ClusterSim::track_map`; anything else can use
/// [`TrackMap::generic`].
#[derive(Debug, Clone)]
pub struct TrackMap {
    /// Process names, indexed by pid.
    pub processes: Vec<String>,
    /// One track per engine stream, indexed by `StreamId.0`.
    pub streams: Vec<StreamTrack>,
    /// One `(pid, counter name)` per engine resource, indexed by
    /// `ResourceId.0`.
    pub counters: Vec<(usize, String)>,
}

impl TrackMap {
    /// Fallback layout for engines built outside `sim::cluster`: one
    /// process, one thread per stream, one counter per resource.
    pub fn generic(n_streams: usize, n_resources: usize) -> Self {
        TrackMap {
            processes: vec!["sim".to_string()],
            streams: (0..n_streams)
                .map(|s| StreamTrack {
                    pid: 0,
                    tid: s,
                    name: format!("stream{s}"),
                })
                .collect(),
            counters: (0..n_resources).map(|r| (0, format!("res{r}"))).collect(),
        }
    }

    /// Fully-qualified `process/name` label for a stream track.
    pub fn stream_label(&self, s: usize) -> String {
        format!("{}/{}", self.processes[self.streams[s].pid], self.streams[s].name)
    }

    /// Fully-qualified `process/name` label for a resource counter.
    pub fn counter_label(&self, r: usize) -> String {
        format!("{}/{}", self.processes[self.counters[r].0], self.counters[r].1)
    }
}

/// Run identity carried into the trace header and the `plan` instant
/// event: which cell was simulated and with which plan, plus
/// free-form `(key, value)` args for plan axes and scenario shape.
#[derive(Debug, Clone, Default)]
pub struct TraceMeta {
    pub scenario: String,
    pub machine: String,
    pub mech: String,
    pub plan: String,
    /// Extra args (plan axes, scenario shape), emitted in order.
    pub args: Vec<(String, String)>,
}

fn push_kv_str(out: &mut String, key: &str, val: &str, first: &mut bool) {
    if !*first {
        out.push(',');
    }
    *first = false;
    write!(out, "\"{}\":\"{}\"", json_escape(key), json_escape(val)).unwrap();
}

/// Render the captured timeline as a Chrome/Perfetto JSON trace.
pub fn perfetto_json(
    eng: &Engine,
    rec: &TimelineRecorder,
    tracks: &TrackMap,
    meta: &TraceMeta,
) -> String {
    let us = |t: f64| t * 1e6;
    let mut out = String::new();
    out.push_str("{\n\"ficco\":{");
    let mut first = true;
    push_kv_str(&mut out, "scenario", &meta.scenario, &mut first);
    push_kv_str(&mut out, "machine", &meta.machine, &mut first);
    push_kv_str(&mut out, "mech", &meta.mech, &mut first);
    push_kv_str(&mut out, "plan", &meta.plan, &mut first);
    for (k, v) in &meta.args {
        push_kv_str(&mut out, k, v, &mut first);
    }
    write!(
        out,
        ",\"makespan\":{},\"gap_time\":{},\"throttled_time\":{}",
        rec.end,
        rec.total_gap_time(eng),
        rec.total_throttled_time()
    )
    .unwrap();
    out.push_str("},\n\"displayTimeUnit\":\"ms\",\n\"traceEvents\":[\n");

    let mut events: Vec<String> = Vec::new();
    // Track-naming metadata: one process_name per pid, one
    // thread_name per stream track.
    for (pid, pname) in tracks.processes.iter().enumerate() {
        events.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
             \"args\":{{\"name\":\"{}\"}}}}",
            json_escape(pname)
        ));
    }
    for st in &tracks.streams {
        events.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{},\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            st.pid,
            st.tid,
            json_escape(&st.name)
        ));
    }
    // Plan instant: the run's identity, visible at t=0 in the UI.
    {
        let mut args = String::new();
        let mut first = true;
        push_kv_str(&mut args, "scenario", &meta.scenario, &mut first);
        push_kv_str(&mut args, "machine", &meta.machine, &mut first);
        push_kv_str(&mut args, "mech", &meta.mech, &mut first);
        push_kv_str(&mut args, "plan", &meta.plan, &mut first);
        for (k, v) in &meta.args {
            push_kv_str(&mut args, k, v, &mut first);
        }
        events.push(format!(
            "{{\"name\":\"plan\",\"ph\":\"I\",\"s\":\"g\",\"ts\":0,\"pid\":0,\"tid\":0,\
             \"args\":{{{args}}}}}"
        ));
    }
    // Task spans, ascending id: a "setup" complete event over
    // [ready, start] when setup took time, and a "work" complete
    // event over [start, finish] always ("X" rather than "B"/"E" so
    // zero-duration sync tasks cannot unbalance begin/end pairing).
    for tid in 0..eng.n_tasks() {
        if rec.ready[tid].is_nan() {
            continue;
        }
        let st = &tracks.streams[eng.task_stream(tid).0];
        let label = json_escape(&eng.task_label(tid).to_string());
        if rec.start[tid] > rec.ready[tid] {
            events.push(format!(
                "{{\"name\":\"{label}\",\"cat\":\"setup\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":{},\"tid\":{}}}",
                us(rec.ready[tid]),
                us(rec.start[tid] - rec.ready[tid]),
                st.pid,
                st.tid
            ));
        }
        let mut demands = String::new();
        for (k, &(r, d)) in eng.task_demands(tid).iter().enumerate() {
            if k > 0 {
                demands.push(';');
            }
            write!(demands, "{}={}", tracks.counter_label(r.0), d).unwrap();
        }
        events.push(format!(
            "{{\"name\":\"{label}\",\"cat\":\"work\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
             \"pid\":{},\"tid\":{},\"args\":{{\"work\":{},\"setup\":{},\"demands\":\"{}\"}}}}",
            us(rec.start[tid]),
            us(rec.finish[tid] - rec.start[tid]),
            st.pid,
            st.tid,
            eng.task_work(tid),
            eng.task_setup(tid),
            json_escape(&demands)
        ));
    }
    // Inefficiency annotations as begin/end pairs: exposed-comm gaps
    // per stream, then contention-throttled windows per task. Windows
    // on one track are disjoint, so pairing stays balanced.
    let gaps = rec.stream_gaps(eng);
    for (s, windows) in gaps.iter().enumerate() {
        let st = &tracks.streams[s];
        for &(t0, t1) in windows {
            events.push(format!(
                "{{\"name\":\"exposed-comm\",\"cat\":\"gap\",\"ph\":\"B\",\"ts\":{},\
                 \"pid\":{},\"tid\":{}}}",
                us(t0),
                st.pid,
                st.tid
            ));
            events.push(format!(
                "{{\"name\":\"exposed-comm\",\"cat\":\"gap\",\"ph\":\"E\",\"ts\":{},\
                 \"pid\":{},\"tid\":{}}}",
                us(t1),
                st.pid,
                st.tid
            ));
        }
    }
    for tid in 0..eng.n_tasks() {
        let st = &tracks.streams[eng.task_stream(tid).0];
        let label = json_escape(&eng.task_label(tid).to_string());
        for &(t0, t1) in &rec.throttled[tid] {
            events.push(format!(
                "{{\"name\":\"throttled\",\"cat\":\"contention\",\"ph\":\"B\",\"ts\":{},\
                 \"pid\":{},\"tid\":{},\"args\":{{\"task\":\"{label}\"}}}}",
                us(t0),
                st.pid,
                st.tid
            ));
            events.push(format!(
                "{{\"name\":\"throttled\",\"cat\":\"contention\",\"ph\":\"E\",\"ts\":{},\
                 \"pid\":{},\"tid\":{}}}",
                us(t1),
                st.pid,
                st.tid
            ));
        }
    }
    // Resource demand-rate counters: one series per resource, a
    // sample at each refill where the value actually changed, closed
    // with an explicit zero at the makespan.
    for r in 0..eng.n_resources() {
        let (pid, name) = (&tracks.counters[r].0, &tracks.counters[r].1);
        let name = json_escape(name);
        let mut last_bits = 0.0f64.to_bits();
        let mut emitted_any = false;
        for (t, seg) in &rec.segments {
            let v = seg[r];
            if emitted_any && v.to_bits() == last_bits {
                continue;
            }
            events.push(format!(
                "{{\"name\":\"{name}\",\"ph\":\"C\",\"ts\":{},\"pid\":{pid},\"tid\":0,\
                 \"args\":{{\"value\":{v}}}}}",
                us(*t)
            ));
            last_bits = v.to_bits();
            emitted_any = true;
        }
        if emitted_any && last_bits != 0.0f64.to_bits() {
            events.push(format!(
                "{{\"name\":\"{name}\",\"ph\":\"C\",\"ts\":{},\"pid\":{pid},\"tid\":0,\
                 \"args\":{{\"value\":0}}}}",
                us(rec.end)
            ));
        }
    }

    out.push_str(&events.join(",\n"));
    out.push_str("\n]\n}\n");
    out
}

/// Render the captured timeline as a flat CSV: task spans, gap and
/// throttle windows, and per-resource busy integrals.
pub fn timeline_csv(eng: &Engine, rec: &TimelineRecorder, tracks: &TrackMap) -> String {
    let mut out = String::from("record,track,label,t_ready,t_start,t_end,value\n");
    for tid in 0..eng.n_tasks() {
        if rec.ready[tid].is_nan() {
            continue;
        }
        writeln!(
            out,
            "task,{},{},{},{},{},{}",
            csv_escape(&tracks.stream_label(eng.task_stream(tid).0)),
            csv_escape(&eng.task_label(tid).to_string()),
            rec.ready[tid],
            rec.start[tid],
            rec.finish[tid],
            eng.task_work(tid)
        )
        .unwrap();
    }
    let gaps = rec.stream_gaps(eng);
    for (s, windows) in gaps.iter().enumerate() {
        for &(t0, t1) in windows {
            writeln!(
                out,
                "gap,{},exposed-comm,,{},{},{}",
                csv_escape(&tracks.stream_label(s)),
                t0,
                t1,
                t1 - t0
            )
            .unwrap();
        }
    }
    for tid in 0..eng.n_tasks() {
        for &(t0, t1) in &rec.throttled[tid] {
            writeln!(
                out,
                "throttled,{},{},,{},{},{}",
                csv_escape(&tracks.stream_label(eng.task_stream(tid).0)),
                csv_escape(&eng.task_label(tid).to_string()),
                t0,
                t1,
                t1 - t0
            )
            .unwrap();
        }
    }
    for r in 0..eng.n_resources() {
        writeln!(
            out,
            "busy,{},,,,{},{}",
            csv_escape(&tracks.counter_label(r)),
            rec.end,
            rec.busy[r]
        )
        .unwrap();
    }
    out
}
