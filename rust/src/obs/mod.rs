//! Flight-recorder observability layer.
//!
//! The paper's methodology is reading *inefficiency signatures* off
//! execution timelines — exposed-communication gaps, DMA-vs-SM
//! contention windows, DIL/CIL losses (PAPER.md §4–5) — but a fluid
//! simulation only reports a final makespan unless someone watches it
//! run. This module is that watcher, in three parts:
//!
//! - [`Recorder`] — a hook trait the simulator core calls at every
//!   structural event (task ready/start/finish, rate refill, time
//!   advance). The default implementation of every hook is empty and
//!   `#[inline]`, so the [`NullRecorder`] monomorphizes to *nothing*:
//!   the recorder-off `run_lean` path stays zero-allocation and
//!   bit-identical (enforced by `tests/zero_alloc.rs` and the frozen
//!   goldens). [`StderrRecorder`] reproduces the legacy
//!   `FICCO_SIM_TRACE` eprintln stream byte-for-byte.
//! - [`timeline::TimelineRecorder`] — captures per-task spans, busy
//!   integrals (bit-exact replay of the engine's accounting), fair-
//!   share rate segments, and contention-throttled windows.
//! - [`export`] — byte-stable Chrome/Perfetto `trace.json` and
//!   `timeline.csv` renderers; [`counters`] — search/cache telemetry
//!   merged per-worker at pool join.
//!
//! Contract details live in `DESIGN.md` §8.

pub mod counters;
pub mod export;
pub mod timeline;

pub use counters::{canonical_artifact_view, Counters, Telemetry};
pub use export::{perfetto_json, timeline_csv, StreamTrack, TraceMeta, TrackMap};
pub use timeline::TimelineRecorder;

use crate::sim::Engine;

/// Simulation observer: the engine core calls these hooks at each
/// structural event. Every hook defaults to an empty `#[inline]`
/// body, so an implementor pays only for what it overrides and
/// [`NullRecorder`] compiles away entirely.
///
/// Hook order within one `run`: `on_begin` once; then per event-loop
/// iteration any number of `on_ready` (promotion), `on_start` (setup
/// elapsed), one `on_rates` after each fair-share refill, `on_advance`
/// *before* the engine integrates progress over a `dt > 0` step (so
/// `now` is the pre-advance clock), and `on_finish` per completion;
/// finally `on_end` with the makespan.
pub trait Recorder {
    /// Called once at the top of a run, before any task is promoted.
    /// `eng` exposes the full task/resource inventory for buffer
    /// sizing.
    #[inline]
    fn on_begin(&mut self, _eng: &Engine) {}

    /// Task `tid` became ready (deps + stream predecessor satisfied)
    /// and entered its setup phase at time `now`.
    #[inline]
    fn on_ready(&mut self, _eng: &Engine, _now: f64, _tid: usize) {}

    /// Task `tid` finished setup and started running at time `now`.
    #[inline]
    fn on_start(&mut self, _eng: &Engine, _now: f64, _tid: usize) {}

    /// Fair-share rates were recomputed at time `now`: `rates[j]` is
    /// the rate of task `running[j]`.
    #[inline]
    fn on_rates(&mut self, _eng: &Engine, _now: f64, _running: &[usize], _rates: &[f64]) {}

    /// The clock is about to advance from `now` to `now + dt`
    /// (`dt > 0`) with the given running set and rates, *before* the
    /// engine's own integration loop runs.
    #[inline]
    fn on_advance(
        &mut self,
        _eng: &Engine,
        _now: f64,
        _dt: f64,
        _running: &[usize],
        _rates: &[f64],
    ) {
    }

    /// Task `tid` completed at time `now`.
    #[inline]
    fn on_finish(&mut self, _eng: &Engine, _now: f64, _tid: usize) {}

    /// The run completed at makespan `now`.
    #[inline]
    fn on_end(&mut self, _eng: &Engine, _now: f64) {}
}

/// The zero-overhead default: every hook inherits the empty inline
/// body, so `run_core::<NullRecorder>` is the exact pre-recorder hot
/// loop after monomorphization.
pub struct NullRecorder;

impl Recorder for NullRecorder {}

/// Reproduces the legacy `FICCO_SIM_TRACE` stderr stream: one line
/// per task-ready and task-done event, in the engine's event order.
/// Installed automatically when the env var is set, so the alias
/// keeps working with the bespoke `trace` branches gone from the hot
/// loop.
pub struct StderrRecorder;

impl Recorder for StderrRecorder {
    fn on_ready(&mut self, eng: &Engine, now: f64, tid: usize) {
        print_ready(now, eng.task_label(tid));
    }

    fn on_finish(&mut self, eng: &Engine, now: f64, tid: usize) {
        print_done(now, eng.task_label(tid));
    }
}

/// The canonical trace line for a task entering setup. Shared with
/// the debug-only reference simulator so both streams stay
/// byte-compatible.
pub fn print_ready(now: f64, label: impl std::fmt::Display) {
    eprintln!("[{now:.9}] ready  {label}");
}

/// The canonical trace line for a task completing.
pub fn print_done(now: f64, label: impl std::fmt::Display) {
    eprintln!("[{now:.9}] done   {label}");
}
