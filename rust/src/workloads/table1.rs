//! Table I: the 16 GEMM/communication scenarios from real ML
//! deployments the paper studies (SP+TP on llama-2-70b/llama-3-405b,
//! EP on DeepSeek/Mixtral), verbatim (M, N, K).

use super::Parallelism;
use crate::schedule::Scenario;

/// One row of the paper's Table I.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub name: &'static str,
    pub parallelism: Parallelism,
    pub model: &'static str,
    pub m: u64,
    pub n: u64,
    pub k: u64,
}

impl Table1Row {
    pub fn scenario(&self) -> Scenario {
        Scenario::new(self.name, self.m, self.n, self.k)
            .with_collective(self.parallelism.collective())
    }
}

/// The 16 rows of Table I.
pub fn table1() -> Vec<Table1Row> {
    use Parallelism::*;
    let rows = [
        ("g1", SpTp, "llama-3-405b", 16384u64, 16384u64, 131072u64),
        ("g2", SpTp, "llama-3-405b", 131072, 16384, 16384),
        ("g3", SpTp, "llama-3-405b", 53248, 16384, 131072),
        ("g4", SpTp, "llama-3-405b", 131072, 53248, 16384),
        ("g5", SpTp, "llama-2-70b", 8192, 8192, 262144),
        ("g6", SpTp, "llama-2-70b", 262144, 8192, 8192),
        ("g7", SpTp, "llama-2-70b", 28672, 8192, 262144),
        ("g8", SpTp, "llama-2-70b", 262144, 28672, 8192),
        ("g9", SpTp, "llama-3-405b", 196608, 18432, 16384),
        ("g10", SpTp, "llama-3-405b", 196608, 106496, 16384),
        ("g11", SpTp, "llama-2-70b", 1048576, 10240, 8192),
        ("g12", SpTp, "llama-2-70b", 1048576, 57344, 8192),
        ("g13", Ep, "DeepSeek", 1607680, 57344, 8192),
        ("g14", Ep, "Mixtral", 147456, 28672, 4096),
        ("g15", Ep, "Mixtral", 327680, 28672, 4096),
        ("g16", Ep, "Mixtral", 229376, 28672, 4096),
    ];
    rows.iter()
        .map(|&(name, parallelism, model, m, n, k)| Table1Row {
            name,
            parallelism,
            model,
            m,
            n,
            k,
        })
        .collect()
}

/// The subset with M > K (the heuristic's 1D branch) — useful for
/// focused characterization runs.
pub fn m_gt_k() -> Vec<Table1Row> {
    table1().into_iter().filter(|r| r.m > r.k).collect()
}

/// The subset with M ≤ K (the heuristic's 2D branch).
pub fn m_le_k() -> Vec<Table1Row> {
    table1().into_iter().filter(|r| r.m <= r.k).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_rows() {
        let t = table1();
        assert_eq!(t.len(), 16);
        assert_eq!(t[0].name, "g1");
        assert_eq!(t[15].name, "g16");
    }

    #[test]
    fn verbatim_paper_dims() {
        let t = table1();
        // Spot-check against the paper's Table I.
        assert_eq!((t[4].m, t[4].n, t[4].k), (8192, 8192, 262144)); // g5
        assert_eq!((t[12].m, t[12].n, t[12].k), (1607680, 57344, 8192)); // g13
        assert_eq!(t[12].model, "DeepSeek");
        assert_eq!(t[13].model, "Mixtral");
    }

    #[test]
    fn split_covers_table() {
        assert_eq!(m_gt_k().len() + m_le_k().len(), 16);
        // The paper notes g1, g3, g5, g7 have M < K (row-sharding
        // unfavourable): all land in the 2D branch.
        let le: Vec<&str> = m_le_k().iter().map(|r| r.name).collect();
        for g in ["g1", "g3", "g5", "g7"] {
            assert!(le.contains(&g), "{g} should have M<=K");
        }
    }

    #[test]
    fn ep_rows_use_all_to_all() {
        for r in table1() {
            let sc = r.scenario();
            match r.parallelism {
                Parallelism::Ep => {
                    assert_eq!(sc.collective, crate::schedule::Collective::AllToAll)
                }
                Parallelism::SpTp => {
                    assert_eq!(sc.collective, crate::schedule::Collective::AllGather)
                }
            }
        }
    }
}
