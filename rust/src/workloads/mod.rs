//! Workloads: the paper's Table I scenario suite and the synthetic
//! scenario generator used for heuristic evaluation (§VI-D).

pub mod synthetic;
pub mod table1;

pub use synthetic::{holdout_scenarios, synthetic_moe_scenarios, synthetic_scenarios};
pub use table1::{table1, Table1Row};

use crate::schedule::{Collective, Scenario};

/// Parallelization technique a scenario comes from (Table I column 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parallelism {
    /// Tensor + sequence parallel (all-gather of activations).
    SpTp,
    /// Expert parallel (all-to-all token dispersal).
    Ep,
}

impl Parallelism {
    pub fn name(self) -> &'static str {
        match self {
            Parallelism::SpTp => "SP+TP",
            Parallelism::Ep => "EP",
        }
    }

    pub fn collective(self) -> Collective {
        match self {
            Parallelism::SpTp => Collective::AllGather,
            Parallelism::Ep => Collective::AllToAll,
        }
    }
}

/// Find a Table I scenario by name ("g1".."g16").
pub fn by_name(name: &str) -> Option<Scenario> {
    table1()
        .into_iter()
        .find(|r| r.name == name)
        .map(|r| r.scenario())
}

/// All Table I scenario names, in table order (sweep filters and
/// error messages).
pub fn names() -> Vec<&'static str> {
    table1().iter().map(|r| r.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        let g1 = by_name("g1").unwrap();
        assert_eq!(g1.gemm.m, 16384);
        assert_eq!(g1.gemm.k, 131072);
        assert!(by_name("g99").is_none());
    }

    #[test]
    fn names_cover_table() {
        let ns = names();
        assert_eq!(ns.len(), 16);
        assert_eq!(ns[0], "g1");
        assert!(ns.iter().all(|n| by_name(n).is_some()));
    }
}
