//! Synthetic scenario generation for heuristic evaluation.
//!
//! §VI-D scores the heuristic on sixteen synthetic scenarios "with
//! diverse OTB and MT combinations". We reproduce that protocol:
//! sample (M, N, K) log-uniformly over the ranges Table I spans,
//! stratified so the suite covers the OTB×MT plane (low/low, low/high,
//! high/low, high/high quadrants), which is what exercises all three
//! 1D heuristic outcomes plus the 2D branch.

use crate::cost::gemm::GemmShape;
use crate::schedule::Scenario;
use crate::util::rng::Rng;

/// Sampling space (powers of two, like real transformer dims).
const M_RANGE: (f64, f64) = (8192.0, 2_097_152.0);
const N_RANGE: (f64, f64) = (1024.0, 65536.0);
const K_RANGE: (f64, f64) = (1024.0, 262144.0);

fn round_pow2ish(x: f64) -> u64 {
    // Round to the nearest multiple of 1024 (transformer dims are
    // 1024-aligned in practice; also keeps shards divisible).
    let q = (x / 1024.0).round().max(1.0);
    (q as u64) * 1024
}

/// Draw one synthetic scenario.
pub fn sample(rng: &mut Rng, idx: usize) -> Scenario {
    // Stratify across the four OTB/MT quadrants by index.
    let quadrant = idx % 4;
    let (m_rng, k_rng) = match quadrant {
        // low OTB, low MT: modest dims, skinny K
        0 => ((M_RANGE.0, 131072.0), (K_RANGE.0, 16384.0)),
        // low OTB, high MT: huge M, skinny K
        1 => ((262144.0, M_RANGE.1), (K_RANGE.0, 8192.0)),
        // high OTB, low MT: modest M, deep K
        2 => ((M_RANGE.0, 65536.0), (32768.0, K_RANGE.1)),
        // high OTB, high MT: large everything
        _ => ((131072.0, M_RANGE.1), (16384.0, 131072.0)),
    };
    let m = round_pow2ish(rng.log_uniform(m_rng.0, m_rng.1));
    let n = round_pow2ish(rng.log_uniform(N_RANGE.0, N_RANGE.1));
    let k = round_pow2ish(rng.log_uniform(k_rng.0, k_rng.1));
    Scenario::new(format!("syn{idx}"), m, n, k)
}

/// The sixteen-scenario synthetic suite (seeded, reproducible).
pub fn synthetic_scenarios(seed: u64, count: usize) -> Vec<Scenario> {
    let mut rng = Rng::new(seed);
    (0..count).map(|i| sample(&mut rng, i)).collect()
}

/// Skewed MoE-dispatch suite: expert-parallel all-to-all scenarios
/// whose routing is hot-expert imbalanced. Shapes are drawn from the
/// same stratified sampler as [`synthetic_scenarios`]; each scenario
/// additionally samples a routing skew (Zipf hotness exponent in
/// `[0.25, 1.5)`, so every scenario is genuinely non-uniform) and its
/// own hotness seed. Seeded and reproducible like the base suite.
pub fn synthetic_moe_scenarios(seed: u64, count: usize) -> Vec<Scenario> {
    let mut rng = Rng::new(seed ^ 0x4D4F_45); // "MOE"
    (0..count)
        .map(|i| {
            let mut sc = sample(&mut rng, i);
            sc.name = format!("moe{i}");
            sc.collective = crate::schedule::Collective::AllToAll;
            sc.skew = rng.range_f64(0.25, 1.5);
            sc.skew_seed = rng.next_u64();
            sc
        })
        .collect()
}

/// Held-out evaluation suite for `ficco calibrate`: shapes from the
/// same stratified OTB×MT sampler, drawn from a decorrelated stream
/// (so a model fitted on `synthetic_scenarios(seed, ..)` never sees
/// these shapes), with every odd-indexed scenario turned into a
/// skewed EP dispatch — the holdout gate must see both balanced and
/// hot-expert regimes. Seeded and reproducible.
pub fn holdout_scenarios(seed: u64, count: usize) -> Vec<Scenario> {
    let mut rng = Rng::new(seed ^ 0x484F_4C44); // "HOLD"
    (0..count)
        .map(|i| {
            let mut sc = sample(&mut rng, i);
            sc.name = format!("hold{i}");
            if i % 2 == 1 {
                sc.collective = crate::schedule::Collective::AllToAll;
                let skew = rng.range_f64(0.25, 1.0);
                let hot_seed = rng.next_u64();
                sc = sc.with_skew(skew, hot_seed);
            }
            sc
        })
        .collect()
}

/// Diversity diagnostic: (min, max) of log10(OTB) and log10(MT bytes)
/// across a suite.
pub fn diversity(scenarios: &[Scenario]) -> ((f64, f64), (f64, f64)) {
    let mut otb = (f64::INFINITY, f64::NEG_INFINITY);
    let mut mt = (f64::INFINITY, f64::NEG_INFINITY);
    for s in scenarios {
        let g: &GemmShape = &s.gemm;
        let o = g.otb().log10();
        let m = g.mt().log10();
        otb = (otb.0.min(o), otb.1.max(o));
        mt = (mt.0.min(m), mt.1.max(m));
    }
    (otb, mt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproducible() {
        let a = synthetic_scenarios(7, 16);
        let b = synthetic_scenarios(7, 16);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.gemm, y.gemm);
        }
    }

    #[test]
    fn dims_in_range_and_aligned() {
        for s in synthetic_scenarios(42, 32) {
            assert!(s.gemm.m % 1024 == 0 && s.gemm.n % 1024 == 0 && s.gemm.k % 1024 == 0);
            assert!(s.gemm.m >= 8192);
            assert!(s.gemm.n >= 1024);
        }
    }

    #[test]
    fn suite_is_diverse() {
        let suite = synthetic_scenarios(1, 16);
        let ((otb_lo, otb_hi), (mt_lo, mt_hi)) = diversity(&suite);
        assert!(otb_hi - otb_lo > 0.8, "OTB span {otb_lo}..{otb_hi}");
        assert!(mt_hi - mt_lo > 0.8, "MT span {mt_lo}..{mt_hi}");
    }

    #[test]
    fn moe_suite_is_skewed_reproducible_and_a2a() {
        let a = synthetic_moe_scenarios(7, 8);
        let b = synthetic_moe_scenarios(7, 8);
        assert_eq!(a.len(), 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.gemm, y.gemm);
            assert_eq!(x.skew, y.skew);
            assert_eq!(x.skew_seed, y.skew_seed);
        }
        for sc in &a {
            assert_eq!(sc.collective, crate::schedule::Collective::AllToAll);
            assert!((0.25..1.5).contains(&sc.skew), "skew {}", sc.skew);
            assert!(
                sc.partition(1).imbalance() > 1.0,
                "{}: sampled routing must be imbalanced",
                sc.name
            );
        }
        // Independent of the base suite's draws for the same seed.
        let base = synthetic_scenarios(7, 8);
        assert!(a.iter().zip(&base).any(|(x, y)| x.gemm != y.gemm));
    }

    #[test]
    fn holdout_suite_is_reproducible_and_mixes_regimes() {
        let a = holdout_scenarios(7, 8);
        let b = holdout_scenarios(7, 8);
        assert_eq!(a.len(), 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.gemm, y.gemm);
            assert_eq!(x.skew, y.skew);
            assert_eq!(x.skew_seed, y.skew_seed);
        }
        for (i, sc) in a.iter().enumerate() {
            if i % 2 == 1 {
                assert_eq!(sc.collective, crate::schedule::Collective::AllToAll);
                assert!((0.25..1.0).contains(&sc.skew), "{}: skew {}", sc.name, sc.skew);
            } else {
                assert_eq!(sc.skew, 0.0, "{}: even indices stay balanced", sc.name);
            }
        }
        // Decorrelated from the training stream at the same seed.
        let train = synthetic_scenarios(7, 8);
        assert!(a.iter().zip(&train).any(|(x, y)| x.gemm != y.gemm));
    }

    #[test]
    fn both_heuristic_branches_present() {
        let suite = synthetic_scenarios(1, 16);
        let gt = suite.iter().filter(|s| s.gemm.m > s.gemm.k).count();
        assert!(gt >= 4, "M>K scenarios: {gt}");
        assert!(gt <= 14, "M<=K scenarios: {}", 16 - gt);
    }
}
