//! Synthetic-but-learnable corpus for the end-to-end trainer.
//!
//! A pure-noise token stream would pin the loss at `ln(vocab)`; to
//! make the loss curve meaningful the generator mixes:
//!
//! - a **Zipfian unigram distribution** (natural token frequencies),
//! - a first-order **Markov chain** (each token has a small set of
//!   likely successors, derived from a hashed transition table),
//! - occasional uniform noise (so the entropy floor is nonzero).
//!
//! A model that learns the bigram structure drops well below the
//! unigram entropy — visible within tens of steps on the tiny preset.

use crate::util::rng::Rng;

/// Streaming corpus generator.
pub struct Corpus {
    vocab: usize,
    rng: Rng,
    last: usize,
    /// Probability of following the Markov edge vs sampling unigram.
    pub markov_p: f64,
    /// Probability of uniform noise.
    pub noise_p: f64,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        Corpus {
            vocab,
            rng: Rng::new(seed),
            last: 0,
            markov_p: 0.75,
            noise_p: 0.05,
        }
    }

    /// Deterministic successor set of a token (hashed transition table
    /// with 4 likely successors per token).
    fn successor(&mut self, t: usize) -> usize {
        let slot = self.rng.range(0, 4);
        let mut h = (t as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ (slot as u64) << 32;
        h ^= h >> 29;
        h = h.wrapping_mul(0xBF58476D1CE4E5B9);
        (h % self.vocab as u64) as usize
    }

    /// Next token in the stream.
    pub fn next_token(&mut self) -> usize {
        let u = self.rng.f64();
        let t = if u < self.noise_p {
            self.rng.range(0, self.vocab)
        } else if u < self.noise_p + self.markov_p {
            self.successor(self.last)
        } else {
            self.rng.zipf(self.vocab, 1.1)
        };
        self.last = t;
        t
    }

    /// A (tokens, targets) LM batch: targets are tokens shifted by one
    /// within a contiguous stream.
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut prev = self.next_token();
            for _ in 0..seq {
                let next = self.next_token();
                tokens.push(prev as i32);
                targets.push(next as i32);
                prev = next;
            }
        }
        (tokens, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range() {
        let mut c = Corpus::new(512, 1);
        for _ in 0..10_000 {
            assert!(c.next_token() < 512);
        }
    }

    #[test]
    fn batch_shapes_and_shift() {
        let mut c = Corpus::new(512, 2);
        let (toks, tgts) = c.batch(3, 16);
        assert_eq!(toks.len(), 48);
        assert_eq!(tgts.len(), 48);
        // Within a row, target[i] == token[i+1].
        for row in 0..3 {
            for i in 0..15 {
                assert_eq!(tgts[row * 16 + i], toks[row * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn bigram_structure_exists() {
        // The same predecessor should reuse successors far more often
        // than uniform chance.
        let mut c = Corpus::new(1024, 3);
        let mut succ_of_7 = std::collections::HashMap::new();
        let mut count = 0;
        let mut prev = c.next_token();
        for _ in 0..200_000 {
            let t = c.next_token();
            if prev == 7 {
                *succ_of_7.entry(t).or_insert(0usize) += 1;
                count += 1;
            }
            prev = t;
        }
        if count >= 30 {
            // ≤4 hashed successors + noise: top-4 should dominate.
            let mut counts: Vec<usize> = succ_of_7.values().cloned().collect();
            counts.sort_unstable_by(|a, b| b.cmp(a));
            let top4: usize = counts.iter().take(4).sum();
            assert!(
                top4 as f64 > 0.5 * count as f64,
                "no bigram structure: top4 {top4}/{count}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(256, 9);
        let mut b = Corpus::new(256, 9);
        for _ in 0..100 {
            assert_eq!(a.next_token(), b.next_token());
        }
    }
}
