//! End-to-end training driver (DESIGN.md §6).
//!
//! Loads the AOT `init_*` / `train_step_*` artifacts, generates a
//! synthetic-but-learnable corpus ([`data`]), and runs the training
//! loop through PJRT — Python never runs here. Alongside the real
//! numerics it reports what the 8-GPU FiCCO deployment of each model
//! GEMM would look like (heuristic pick + simulated speedup), tying
//! the training example to the paper's contribution.

pub mod data;

use crate::cli::Args;
use crate::hw::Machine;
use crate::runtime::{literal_i32, Runtime};
use crate::schedule::{exec::ScenarioEval, Kind, Scenario};
use anyhow::{anyhow, Context, Result};

/// Model presets mirrored from python/compile/model.py (PRESETS).
#[derive(Debug, Clone)]
pub struct Preset {
    pub name: &'static str,
    pub vocab: u64,
    pub d_model: u64,
    pub n_layers: u64,
    pub seq: usize,
    pub batch: usize,
}

pub const PRESETS: [Preset; 3] = [
    Preset { name: "tiny", vocab: 512, d_model: 64, n_layers: 2, seq: 32, batch: 4 },
    Preset { name: "small", vocab: 4096, d_model: 256, n_layers: 4, seq: 64, batch: 8 },
    Preset { name: "m100", vocab: 16384, d_model: 768, n_layers: 12, seq: 128, batch: 4 },
];

pub fn preset(name: &str) -> Option<&'static Preset> {
    PRESETS.iter().find(|p| p.name == name)
}

/// Training run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub preset: String,
    pub steps: usize,
    pub seed: u64,
    pub artifacts: String,
    pub log_every: usize,
    pub loss_csv: Option<String>,
    /// Print the simulated FiCCO deployment report for the model's
    /// GEMMs at datacenter batch.
    pub overlap_report: bool,
}

impl TrainConfig {
    pub fn from_args(args: &Args) -> Result<TrainConfig, Box<dyn std::error::Error>> {
        Ok(TrainConfig {
            preset: args.get_or("preset", "small").to_string(),
            steps: args.get_usize("steps", 100)?,
            seed: args.get_u64("seed", 42)?,
            artifacts: args.get_or("artifacts", "artifacts").to_string(),
            log_every: args.get_usize("log-every", 10)?,
            loss_csv: args.get("loss-csv").map(String::from),
            overlap_report: !args.has("no-overlap-report"),
        })
    }
}

/// Result of a training run (returned for tests / examples).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub step_seconds_mean: f64,
    pub tokens_per_second: f64,
}

/// Run the training loop; prints progress and returns the loss curve.
pub fn run(cfg: &TrainConfig) -> Result<TrainReport, Box<dyn std::error::Error>> {
    let p = preset(&cfg.preset)
        .ok_or_else(|| anyhow!("unknown preset '{}' (tiny|small|m100)", cfg.preset))?;
    println!(
        "training {} (vocab {}, d_model {}, {} layers, seq {}, batch {}) for {} steps",
        p.name, p.vocab, p.d_model, p.n_layers, p.seq, p.batch, cfg.steps
    );

    let rt = Runtime::load(&cfg.artifacts)?;
    let init_name = format!("init_{}", p.name);
    let step_name = format!("train_step_{}", p.name);
    let step_art = rt
        .manifest
        .get(&step_name)
        .ok_or_else(|| anyhow!("artifact {step_name} missing — run `make artifacts`"))?
        .clone();
    let n_state = step_art.inputs.len() - 2;

    // Initialize state through the AOT init artifact.
    let key = xla::Literal::vec1(&[cfg.seed as u32, (cfg.seed >> 32) as u32]);
    let t0 = std::time::Instant::now();
    let mut state = rt.execute(&init_name, &[key])?;
    println!(
        "  init: {} state tensors in {:.1}s (compile+run)",
        state.len(),
        t0.elapsed().as_secs_f64()
    );
    if state.len() != n_state {
        return Err(anyhow!("init produced {} tensors, step wants {n_state}", state.len()).into());
    }

    // Pre-compile the step (first execute pays XLA compilation).
    let mut corpus = data::Corpus::new(p.vocab as usize, cfg.seed ^ 0xC0FFEE);
    let mut losses = Vec::with_capacity(cfg.steps);
    let mut step_times = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let (tokens, targets) = corpus.batch(p.batch, p.seq);
        let lt = literal_i32(&tokens, &[p.batch as i64, p.seq as i64])?;
        let lg = literal_i32(&targets, &[p.batch as i64, p.seq as i64])?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(n_state + 2);
        inputs.append(&mut state);
        inputs.push(lt);
        inputs.push(lg);

        let t = std::time::Instant::now();
        let mut out = rt.execute(&step_name, &inputs)?;
        let dt = t.elapsed().as_secs_f64();
        let loss = out
            .pop()
            .ok_or_else(|| anyhow!("empty step output"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?[0];
        state = out;

        losses.push(loss);
        if step > 0 {
            step_times.push(dt); // step 0 includes XLA compile
        }
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            println!("  step {step:>5}  loss {loss:.4}  ({dt:.3}s)");
        }
        if !loss.is_finite() {
            return Err(anyhow!("loss diverged at step {step}").into());
        }
    }

    let mean_dt = if step_times.is_empty() {
        0.0
    } else {
        step_times.iter().sum::<f64>() / step_times.len() as f64
    };
    let tps = (p.batch * p.seq) as f64 / mean_dt.max(1e-9);
    println!(
        "done: loss {:.4} -> {:.4}; {:.3}s/step, {:.0} tokens/s",
        losses.first().unwrap_or(&f32::NAN),
        losses.last().unwrap_or(&f32::NAN),
        mean_dt,
        tps
    );

    if let Some(path) = &cfg.loss_csv {
        let mut csv = String::from("step,loss\n");
        for (i, l) in losses.iter().enumerate() {
            csv.push_str(&format!("{i},{l}\n"));
        }
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent).ok();
        }
        std::fs::write(path, csv).with_context(|| format!("writing {path}"))?;
        println!("loss curve -> {path}");
    }

    if cfg.overlap_report {
        overlap_report(p);
    }

    Ok(TrainReport {
        losses,
        step_seconds_mean: mean_dt,
        tokens_per_second: tps,
    })
}

/// What the paper's system would do with this model's GEMMs on the
/// 8×MI300X testbed: for each TP-sharded layer GEMM at datacenter
/// batch, the heuristic pick and its simulated speedup over serial.
pub fn overlap_report(p: &Preset) {
    let machine = Machine::mi300x_8();
    // Datacenter deployment: global batch scaled to saturate the node
    // (the paper's Table I uses M up to ~1.6M tokens).
    let m_tokens = 131_072u64;
    let d = p.d_model;
    let gemms = [
        ("attn qkv (SP+TP)", m_tokens, 3 * d / 8, d),
        ("attn out (SP+TP)", m_tokens, d / 8, d),
        ("mlp up (SP+TP)", m_tokens, 4 * d / 8, d),
        ("mlp down (SP+TP)", m_tokens, d / 8, 4 * d),
    ];
    println!("\nFiCCO deployment report ({} on 8x MI300X, M={} tokens):", p.name, m_tokens);
    for (name, m, n, k) in gemms {
        let sc = Scenario::new(name, m, n.max(1), k);
        let pick = crate::heuristics::pick(&machine, &sc).pick;
        let ev = ScenarioEval::run(&machine, &sc, &[Kind::Baseline, pick]);
        println!(
            "  {name:<20} ({m}, {n}, {k}) -> {} ({} vs serial)",
            pick.name(),
            crate::util::table::x(ev.speedup(pick)),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_python() {
        // Mirrors python/compile/model.py PRESETS — drift here breaks
        // the artifact contract, caught by runtime integration tests.
        let m = preset("m100").unwrap();
        assert_eq!(m.d_model, 768);
        assert_eq!(m.n_layers, 12);
        assert!(preset("nope").is_none());
    }
}
