//! Summary statistics used by the characterization benches and the
//! figure renderers (the paper reports geomeans throughout §IV/§VI).

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean over the positive finite inputs. Non-positive or
/// non-finite samples are skipped rather than aborting the whole
/// summary (one zero-speedup cell must not kill a sweep); use
/// [`geomean_pos`] when the caller wants to know how many were
/// dropped. Returns 0.0 when no sample qualifies (including empty).
pub fn geomean(xs: &[f64]) -> f64 {
    geomean_pos(xs).0
}

/// As [`geomean`], additionally reporting how many samples were
/// skipped for being non-positive or non-finite (the flag callers can
/// surface next to the summary).
pub fn geomean_pos(xs: &[f64]) -> (f64, usize) {
    let mut log_sum = 0.0f64;
    let mut n = 0usize;
    for &x in xs {
        if x.is_finite() && x > 0.0 {
            log_sum += x.ln();
            n += 1;
        }
    }
    if n == 0 {
        (0.0, xs.len())
    } else {
        ((log_sum / n as f64).exp(), xs.len() - n)
    }
}

/// One-pass geomean for summary emitters: the value, the number of
/// degenerate (non-positive/non-finite) samples dropped, and the
/// rendered table cell (`"<g>x"` or `"<g>x [N skipped]"`). Shared by
/// the sweep/tune/figure emitters so the flagging never drifts
/// between them.
pub fn geomean_summary(xs: &[f64]) -> (f64, usize, String) {
    let (g, skipped) = geomean_pos(xs);
    let cell = if skipped == 0 {
        crate::util::table::x(g)
    } else {
        format!("{} [{skipped} skipped]", crate::util::table::x(g))
    };
    (g, skipped, cell)
}

/// Just the rendered cell of [`geomean_summary`].
pub fn geomean_cell(xs: &[f64]) -> String {
    geomean_summary(xs).2
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Normalize a sample for total-order sorting: every NaN becomes the
/// positive quiet NaN (fixed bit pattern — `f64::NAN`'s sign is
/// documented as unspecified). IEEE total order puts *negative* NaN
/// (what `0.0 / 0.0` produces on x86-64) before every finite value,
/// which would corrupt the low percentiles / first ranks; after
/// normalization all NaNs deterministically sort last.
fn nan_last(x: f64) -> f64 {
    if x.is_nan() {
        f64::from_bits(0x7FF8_0000_0000_0000)
    } else {
        x
    }
}

/// Linear-interpolated percentile, `p` in [0, 100]. NaN samples sort
/// last (sign-normalized `total_cmp`) instead of aborting, so a
/// poisoned series degrades to a NaN-adjacent top percentile rather
/// than a panic mid-sweep.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.iter().map(|&x| nan_last(x)).collect();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Pearson correlation coefficient of two equal-length series.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx2 = 0.0;
    let mut dy2 = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        num += dx * dy;
        dx2 += dx * dx;
        dy2 += dy * dy;
    }
    if dx2 == 0.0 || dy2 == 0.0 {
        return 0.0;
    }
    num / (dx2 * dy2).sqrt()
}

/// Spearman rank correlation (what "positively correlates" means in the
/// paper's DIL/CIL observations — monotone association, not linearity).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    // Sign-normalized `total_cmp`: any NaN sample gets the last rank
    // deterministically instead of aborting the correlation.
    idx.sort_by(|&a, &b| nan_last(xs[a]).total_cmp(&nan_last(xs[b])));
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // average ranks over ties
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Online accumulator for timing loops (used by the bench harness).
#[derive(Debug, Default, Clone)]
pub struct Accum {
    samples: Vec<f64>,
}

impl Accum {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }
    pub fn len(&self) -> usize {
        self.samples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }
    pub fn median(&self) -> f64 {
        median(&self.samples)
    }
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
    pub fn stddev(&self) -> f64 {
        stddev(&self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_geomean() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone() {
        // Monotone but non-linear → spearman 1, pearson < 1.
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [1.0, 10.0, 100.0, 1000.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
        assert!(pearson(&xs, &ys) < 1.0);
    }

    #[test]
    fn spearman_ties() {
        let xs = [1.0, 1.0, 2.0];
        let ys = [3.0, 3.0, 5.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // Regression: `partial_cmp().unwrap()` used to abort the whole
        // process on the first NaN sample.
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        let p0 = percentile(&xs, 0.0);
        assert_eq!(p0, 1.0, "finite samples sort ahead of NaN");
        assert!(median(&xs).is_finite());
        // Negative NaN (what 0.0/0.0 yields on x86-64) must also sort
        // last, not corrupt the low percentiles.
        let neg = [2.0, f64::NAN.copysign(-1.0), 1.0, 3.0];
        assert_eq!(percentile(&neg, 0.0), 1.0, "-NaN sorts last too");
        assert!(median(&neg).is_finite());
        // All-NaN degrades to NaN, not a panic.
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn ranks_and_spearman_tolerate_nan() {
        // Regression: a NaN in either series used to panic in `ranks`.
        let xs = [1.0, f64::NAN, 2.0];
        let ys = [3.0, 4.0, 5.0];
        let r = spearman(&xs, &ys);
        assert!(r.is_finite(), "spearman over NaN-bearing series: {r}");
        // Either NaN sign ranks last; finite samples keep their order.
        let neg = [1.0, f64::NAN.copysign(-1.0), 2.0];
        let rk = ranks(&neg);
        assert_eq!(rk[1], 2.0, "-NaN takes the last rank: {rk:?}");
        assert!(rk[0] < rk[2]);
    }

    #[test]
    fn geomean_skips_non_positive_inputs() {
        // Regression: one zero-speedup cell used to assert-abort the
        // whole sweep summary.
        assert!((geomean(&[2.0, 0.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[2.0, -1.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[2.0, f64::NAN, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[0.0]), 0.0);
        assert_eq!(geomean(&[f64::INFINITY]), 0.0);
        let (g, skipped) = geomean_pos(&[2.0, 0.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert_eq!(skipped, 1);
        assert_eq!(geomean_pos(&[]), (0.0, 0));
        assert_eq!(geomean_pos(&[-1.0, 0.0]), (0.0, 2));
    }

    #[test]
    fn geomean_cell_flags_skips() {
        assert_eq!(geomean_cell(&[2.0, 8.0]), crate::util::table::x(4.0));
        let flagged = geomean_cell(&[2.0, 0.0, 8.0]);
        assert!(flagged.contains("[1 skipped]"), "{flagged}");
        assert!(flagged.starts_with(&crate::util::table::x(4.0)), "{flagged}");
    }

    #[test]
    fn accum_basics() {
        let mut a = Accum::new();
        for x in [3.0, 1.0, 2.0] {
            a.push(x);
        }
        assert_eq!(a.len(), 3);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 3.0);
        assert_eq!(a.mean(), 2.0);
        assert_eq!(a.median(), 2.0);
    }
}
