//! Summary statistics used by the characterization benches and the
//! figure renderers (the paper reports geomeans throughout §IV/§VI).

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean. All inputs must be positive; returns 0.0 for empty.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean over non-positive value {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Pearson correlation coefficient of two equal-length series.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx2 = 0.0;
    let mut dy2 = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        num += dx * dy;
        dx2 += dx * dx;
        dy2 += dy * dy;
    }
    if dx2 == 0.0 || dy2 == 0.0 {
        return 0.0;
    }
    num / (dx2 * dy2).sqrt()
}

/// Spearman rank correlation (what "positively correlates" means in the
/// paper's DIL/CIL observations — monotone association, not linearity).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // average ranks over ties
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Online accumulator for timing loops (used by the bench harness).
#[derive(Debug, Default, Clone)]
pub struct Accum {
    samples: Vec<f64>,
}

impl Accum {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }
    pub fn len(&self) -> usize {
        self.samples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }
    pub fn median(&self) -> f64 {
        median(&self.samples)
    }
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
    pub fn stddev(&self) -> f64 {
        stddev(&self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_geomean() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone() {
        // Monotone but non-linear → spearman 1, pearson < 1.
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [1.0, 10.0, 100.0, 1000.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
        assert!(pearson(&xs, &ys) < 1.0);
    }

    #[test]
    fn spearman_ties() {
        let xs = [1.0, 1.0, 2.0];
        let ys = [3.0, 3.0, 5.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn accum_basics() {
        let mut a = Accum::new();
        for x in [3.0, 1.0, 2.0] {
            a.push(x);
        }
        assert_eq!(a.len(), 3);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 3.0);
        assert_eq!(a.mean(), 2.0);
        assert_eq!(a.median(), 2.0);
    }
}
