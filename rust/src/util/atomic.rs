//! Crash-safe file writes: write-to-temp-then-rename (ISSUE 9).
//!
//! Every artifact emitter (sweep/tune CSV+JSON, trace/timeline
//! exports, the calibrated model, summary CSVs) routes through here so
//! a killed run can never leave a truncated artifact: readers either
//! see the previous complete file or the new complete file, never a
//! prefix. The temp file lives next to the target (`<path>.tmp`) so
//! the final `rename` stays within one filesystem and is atomic on
//! POSIX.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};

/// Sibling temp path for `path` (`<path>.tmp`).
fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// A buffered writer that only materializes the target on
/// [`AtomicFile::commit`]. Dropping without committing removes the
/// temp file and leaves any pre-existing target untouched.
pub struct AtomicFile {
    tmp: PathBuf,
    target: PathBuf,
    // `None` after commit/abort so Drop knows nothing is pending.
    writer: Option<BufWriter<File>>,
}

impl AtomicFile {
    /// Start writing `<path>.tmp`; the target appears only on commit.
    pub fn create(path: impl AsRef<Path>) -> io::Result<AtomicFile> {
        let target = path.as_ref().to_path_buf();
        let tmp = tmp_path(&target);
        let writer = Some(BufWriter::new(File::create(&tmp)?));
        Ok(AtomicFile { tmp, target, writer })
    }

    /// Flush, sync, and atomically rename the temp file over the
    /// target. Consumes the writer; after this the target holds the
    /// complete contents.
    pub fn commit(mut self) -> io::Result<()> {
        let writer = self.writer.take().expect("commit called once");
        let file = writer
            .into_inner()
            .map_err(|e| io::Error::new(io::ErrorKind::Other, e.to_string()))?;
        // Durability before visibility: the rename must not expose a
        // file whose bytes are still in the page cache of a dying box.
        file.sync_all()?;
        drop(file);
        std::fs::rename(&self.tmp, &self.target)
    }
}

impl Write for AtomicFile {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.writer
            .as_mut()
            .expect("write before commit")
            .write(buf)
    }
    fn flush(&mut self) -> io::Result<()> {
        self.writer.as_mut().expect("flush before commit").flush()
    }
}

impl Drop for AtomicFile {
    fn drop(&mut self) {
        if self.writer.take().is_some() {
            // Abort path (error or interrupted run): discard the
            // partial temp file; the target was never touched.
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

/// One-shot crash-safe replacement for `std::fs::write`.
pub fn write(path: impl AsRef<Path>, contents: impl AsRef<[u8]>) -> io::Result<()> {
    let mut f = AtomicFile::create(&path)?;
    f.write_all(contents.as_ref())?;
    f.commit()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ficco-atomic-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn commit_materializes_full_contents() {
        let d = tdir("commit");
        let p = d.join("out.csv");
        let mut f = AtomicFile::create(&p).unwrap();
        f.write_all(b"header\nrow\n").unwrap();
        assert!(!p.exists(), "target must not exist before commit");
        f.commit().unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"header\nrow\n");
        assert!(!tmp_path(&p).exists(), "temp cleaned after commit");
    }

    #[test]
    fn interrupted_write_leaves_previous_artifact_intact() {
        // Simulates a kill mid-write: the writer is dropped without
        // commit. The pre-existing artifact must survive unchanged and
        // no temp debris may remain.
        let d = tdir("interrupt");
        let p = d.join("out.json");
        std::fs::write(&p, b"{\"complete\": true}\n").unwrap();
        {
            let mut f = AtomicFile::create(&p).unwrap();
            f.write_all(b"{\"partial\":").unwrap();
            // dropped here, uncommitted
        }
        assert_eq!(std::fs::read(&p).unwrap(), b"{\"complete\": true}\n");
        assert!(!tmp_path(&p).exists(), "temp cleaned after abort");
    }

    #[test]
    fn one_shot_write_replaces_atomically() {
        let d = tdir("oneshot");
        let p = d.join("model.ficco");
        write(&p, b"v1").unwrap();
        write(&p, b"v2-longer").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"v2-longer");
    }
}
