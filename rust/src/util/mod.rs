//! Small self-contained utilities used across the workspace.
//!
//! The offline crate set available to this build has no `rand`,
//! `serde`, or `prettytable`, so the substrates live here: a
//! deterministic PRNG ([`rng`]), summary statistics ([`stats`]),
//! table/CSV rendering ([`table`]), a miniature property-based
//! testing driver ([`prop`]), and the deterministic ordered worker
//! pool ([`pool`]) behind the parallel sweep/tune drivers.

pub mod atomic;
pub mod journal;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

use std::sync::atomic::{AtomicBool, Ordering};

/// Process-global quiet switch (`--quiet`): when set, progress and
/// exhibit printing is suppressed so machine-readable stdout (piped
/// CSV, `--stats` tables) stays uncontaminated.
static QUIET: AtomicBool = AtomicBool::new(false);

/// Flip the process-global quiet switch (set once by the CLI parser).
pub fn set_quiet(on: bool) {
    QUIET.store(on, Ordering::Relaxed);
}

/// Whether `--quiet` is in effect.
pub fn quiet() -> bool {
    QUIET.load(Ordering::Relaxed)
}

/// Format a byte count with binary units (KiB/MiB/GiB).
pub fn human_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{:.0} {}", v, UNITS[u])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in seconds with an auto-selected unit.
pub fn human_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(2048.0), "2.00 KiB");
        assert_eq!(human_bytes(3.5 * 1024.0 * 1024.0), "3.50 MiB");
        assert!(human_bytes(1e13).ends_with("TiB"));
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(2.5), "2.500 s");
        assert_eq!(human_time(0.0025), "2.500 ms");
        assert_eq!(human_time(2.5e-6), "2.500 us");
        assert!(human_time(5e-9).ends_with("ns"));
    }
}
