//! Miniature property-based testing driver.
//!
//! `proptest` is not in the offline crate set, so this provides the
//! small subset the test suite needs: run a property over `n` random
//! cases drawn from a caller-supplied generator, and on failure report
//! the seed + a greedily shrunk counterexample.

use super::rng::Rng;

/// Outcome of a property check over one case.
pub type CaseResult = Result<(), String>;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Max shrink attempts on failure.
    pub shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 256,
            // Allow overriding for CI reproduction: FICCO_PROP_SEED=...
            seed: std::env::var("FICCO_PROP_SEED")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0xF1CC0),
            shrink_iters: 200,
        }
    }
}

/// Run `prop` over `cfg.cases` values drawn by `gen`; panic with the
/// seed, case index, and (optionally shrunk) counterexample on failure.
///
/// `shrink` proposes a simpler candidate from a failing one (return
/// `None` when no simpler candidate exists). Shrinking is greedy: a
/// candidate is kept only if it still fails the property.
pub fn check<T, G, P, S>(name: &str, cfg: &Config, mut gen: G, mut prop: P, mut shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> CaseResult,
    S: FnMut(&T, &mut Rng) -> Option<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut srng = Rng::new(cfg.seed ^ 0x5111);
            for _ in 0..cfg.shrink_iters {
                match shrink(&best, &mut srng) {
                    Some(candidate) => {
                        if let Err(m) = prop(&candidate) {
                            best = candidate;
                            best_msg = m;
                        }
                    }
                    None => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={:#x}, case={case}):\n  input: {best:?}\n  error: {best_msg}",
                cfg.seed
            );
        }
    }
}

/// Convenience: property check with no shrinking.
pub fn check_no_shrink<T, G, P>(name: &str, cfg: &Config, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> CaseResult,
{
    check(name, cfg, gen, prop, |_, _| None);
}

/// Assert an approximate equality inside a property.
pub fn approx_eq(a: f64, b: f64, rtol: f64, what: &str) -> CaseResult {
    let denom = a.abs().max(b.abs()).max(1e-30);
    if ((a - b) / denom).abs() <= rtol {
        Ok(())
    } else {
        Err(format!("{what}: {a} !~ {b} (rtol {rtol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_no_shrink(
            "add-commutes",
            &Config::default(),
            |r| (r.range(0, 100) as i64, r.range(0, 100) as i64),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("non-commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check_no_shrink(
            "always-fails",
            &Config {
                cases: 1,
                ..Config::default()
            },
            |r| r.range(0, 10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn shrink_reduces_counterexample() {
        // Property: x < 50. Failing inputs are 50..100; shrink by
        // halving toward 50 should land at exactly 50.
        let result = std::panic::catch_unwind(|| {
            check(
                "lt-50",
                &Config {
                    cases: 500,
                    seed: 3,
                    shrink_iters: 500,
                },
                |r| r.range(0, 100),
                |&x| if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) },
                |&x, _| if x > 0 { Some(x - 1) } else { None },
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("input: 50"), "shrunk message: {msg}");
    }

    #[test]
    fn approx_eq_tolerates() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(approx_eq(1.0, 1.1, 1e-6, "x").is_err());
    }
}
