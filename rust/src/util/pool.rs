//! Deterministic ordered worker pool — the sweep engine's
//! worker-pool / reorder-buffer pattern factored out so every parallel
//! driver (`ficco sweep`, `ficco tune`) shares one implementation.
//!
//! Items are evaluated concurrently on `jobs` std threads; results
//! return over an mpsc channel and are buffered until the ordered
//! prefix is complete, so the delivery callback observes results in
//! item order regardless of parallelism — which is what makes
//! incremental emitters byte-stable under any `--jobs` value.
//! Evaluation must be a pure function of the item for that guarantee
//! to mean anything; wall-clock measurements belong outside the
//! emitted artifacts.

//! Worker panics (ISSUE 9): a panicking `eval` used to unwind the
//! worker thread with its claimed item unsent, so the ordered join
//! either hung on the missing slot or lost results silently. Workers
//! now wrap each evaluation in `catch_unwind`; a panic becomes a
//! structured [`ItemPanic`] recorded on the run (delivery of the
//! healthy items continues in order), and the worker rebuilds its
//! scratch state before taking the next item, since a mid-panic state
//! may be arbitrarily poisoned.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

/// One item whose evaluation panicked: which item, and the panic
/// payload rendered to text (the usual `panic!`/`assert!` message).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ItemPanic {
    pub index: usize,
    pub message: String,
}

impl std::fmt::Display for ItemPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "item {}: {}", self.index, self.message)
    }
}

/// Render a `catch_unwind` payload as text (`&str` and `String`
/// payloads cover `panic!`, `assert!`, `unwrap`, and friends).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic payload of unknown type".to_string()
    }
}

/// Hard ceiling on worker threads: far above any useful host
/// parallelism, low enough that a huge `--jobs` cannot exhaust OS
/// thread limits (each worker is a real `std::thread`).
pub const MAX_JOBS: usize = 256;

/// Worker count actually used for `n_items` items: at least one
/// thread, never more threads than items, capped at [`MAX_JOBS`].
pub fn clamp_jobs(jobs: usize, n_items: usize) -> usize {
    jobs.max(1).min(n_items.max(1)).min(MAX_JOBS)
}

/// Outcome of one pool run: results in item order (the delivered
/// prefix only, when cancelled).
pub struct OrderedRun<R> {
    /// Worker threads actually used (after clamping).
    pub jobs: usize,
    pub results: Vec<R>,
    pub cancelled: bool,
    /// Items whose evaluation panicked, in item order. `results`
    /// carries the healthy items only; drivers surface these and exit
    /// nonzero instead of pretending the run was complete.
    pub failures: Vec<ItemPanic>,
}

/// Evaluate `items` on `jobs` workers, invoking `on_result` once per
/// item *in item order* as soon as the ordered prefix is complete.
///
/// `on_result` returns whether to continue: `false` cancels the run —
/// dispatch stops, in-flight items are allowed to finish but are
/// discarded, and the returned results carry exactly the delivered
/// prefix (so a cancelled run is as deterministic as a completed one).
pub fn run_ordered<T, R, F, G>(items: &[T], jobs: usize, eval: F, on_result: G) -> OrderedRun<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
    G: FnMut(usize, &R) -> bool,
{
    run_ordered_stateful(items, jobs, || (), |_, i, t| eval(i, t), on_result)
}

/// As [`run_ordered`], with a per-worker scratch state: each worker
/// thread builds one `S` via `init` at startup and threads it through
/// every item it evaluates. This is how the sweep/tune drivers give
/// each worker a reusable [`crate::schedule::exec::Evaluator`] arena
/// instead of rebuilding simulator state per cell.
///
/// Determinism contract: `eval` must return a value that is a pure
/// function of the *item* — worker state may only affect speed (cache
/// reuse, buffer warmth), never results. Everything [`run_ordered`]
/// guarantees about ordering and cancellation holds unchanged,
/// because which worker evaluates which item remains unobservable.
pub fn run_ordered_stateful<T, R, S, I, F, G>(
    items: &[T],
    jobs: usize,
    init: I,
    eval: F,
    on_result: G,
) -> OrderedRun<R>
where
    T: Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
    G: FnMut(usize, &R) -> bool,
{
    run_ordered_with(items, jobs, init, eval, |_| (), on_result)
}

/// As [`run_ordered_stateful`], with a `fini` callback invoked once
/// per worker thread (on that thread) with the worker's final state
/// as it exits its dispatch loop. This is how the sweep/tune drivers
/// harvest per-worker telemetry counters: each worker increments
/// plain fields privately and the merge happens exactly `jobs` times,
/// at join — no shared counter in the evaluation hot path. `fini`
/// must not affect results (it runs after every result is sent).
pub fn run_ordered_with<T, R, S, I, F, X, G>(
    items: &[T],
    jobs: usize,
    init: I,
    eval: F,
    fini: X,
    mut on_result: G,
) -> OrderedRun<R>
where
    T: Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
    X: Fn(S) + Sync,
    G: FnMut(usize, &R) -> bool,
{
    let n = items.len();
    let jobs = clamp_jobs(jobs, n);
    let cursor = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);

    let mut slots: Vec<Option<Result<R, String>>> = (0..n).map(|_| None).collect();
    let mut cancelled = false;
    let mut next = 0usize;
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::channel::<(usize, Result<R, String>)>();
        for _ in 0..jobs {
            let tx = tx.clone();
            let cursor = &cursor;
            let stop = &stop;
            let eval = &eval;
            let init = &init;
            let fini = &fini;
            s.spawn(move || {
                let mut state = init();
                loop {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // A panicking evaluation must not take the worker
                    // (and its claimed item) down with it: catch it,
                    // report the item as failed, and rebuild the
                    // worker scratch state, which the unwind may have
                    // left half-mutated.
                    let outcome =
                        std::panic::catch_unwind(AssertUnwindSafe(|| eval(&mut state, i, &items[i])));
                    let payload = match outcome {
                        Ok(r) => Ok(r),
                        Err(p) => {
                            state = init();
                            Err(panic_message(p.as_ref()))
                        }
                    };
                    if tx.send((i, payload)).is_err() {
                        // Receiver bailed: the run was cancelled.
                        break;
                    }
                }
                fini(state);
            });
        }
        drop(tx);

        'recv: for (idx, result) in rx {
            slots[idx] = Some(result);
            while next < n {
                // Borrow rather than take: the slot stays filled for
                // the final ordered collection below.
                match &slots[next] {
                    Some(Ok(ready)) => {
                        let keep_going = on_result(next, ready);
                        next += 1;
                        if !keep_going {
                            cancelled = true;
                            // Stop workers before they dispatch
                            // another (discarded) item; dropping the
                            // receiver below backstops the in-flight
                            // sends.
                            stop.store(true, Ordering::Relaxed);
                            break 'recv;
                        }
                    }
                    // A failed item completes its slot (the ordered
                    // prefix advances past it) but is not delivered;
                    // it surfaces in `failures` below.
                    Some(Err(_)) => next += 1,
                    None => break,
                }
            }
        }
        // Leaving the loop drops the receiver; workers stop taking
        // new items on their next send. The scope joins them.
    });

    let mut results = Vec::new();
    let mut failures = Vec::new();
    // Cancelled runs keep exactly the delivered prefix (completed-but-
    // undelivered stragglers are discarded so a cancelled run does not
    // depend on worker timing); completed runs must have filled every
    // slot — the panic path above keeps that invariant even when an
    // evaluation blows up.
    let keep = if cancelled { next } else { n };
    for (index, slot) in slots.into_iter().take(keep).enumerate() {
        match slot {
            Some(Ok(r)) => results.push(r),
            Some(Err(message)) => failures.push(ItemPanic { index, message }),
            None => {
                if !cancelled {
                    unreachable!("every pool item completes");
                }
            }
        }
    }
    OrderedRun {
        jobs,
        results,
        cancelled,
        failures,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that swap the process-global panic hook.
    static HOOK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn delivers_in_order_at_any_parallelism() {
        let items: Vec<usize> = (0..17).collect();
        for jobs in [1, 3, 8] {
            let mut seen = Vec::new();
            let run = run_ordered(
                &items,
                jobs,
                |i, &x| {
                    assert_eq!(i, x);
                    x * 10
                },
                |i, &r| {
                    seen.push((i, r));
                    true
                },
            );
            assert!(!run.cancelled);
            assert_eq!(run.results, (0..17).map(|x| x * 10).collect::<Vec<_>>());
            assert_eq!(seen.len(), 17);
            for (k, &(i, r)) in seen.iter().enumerate() {
                assert_eq!(i, k);
                assert_eq!(r, k * 10);
            }
        }
    }

    #[test]
    fn cancellation_keeps_the_delivered_prefix() {
        let items: Vec<usize> = (0..12).collect();
        let mut delivered = 0usize;
        let run = run_ordered(
            &items,
            4,
            |_, &x| x,
            |_, _| {
                delivered += 1;
                delivered < 3
            },
        );
        assert!(run.cancelled);
        assert_eq!(delivered, 3);
        assert_eq!(run.results, vec![0, 1, 2]);
    }

    #[test]
    fn clamping() {
        assert_eq!(clamp_jobs(0, 10), 1);
        assert_eq!(clamp_jobs(4, 2), 2);
        assert_eq!(clamp_jobs(9999, 9999), MAX_JOBS);
        assert_eq!(clamp_jobs(3, 0), 1);
    }

    #[test]
    fn worker_state_persists_within_a_worker_and_results_stay_ordered() {
        // The per-worker state is a cache: results must not depend on
        // it. Here each worker counts its own items; results are the
        // item values, delivered in order regardless.
        let items: Vec<usize> = (0..23).collect();
        for jobs in [1, 2, 5] {
            let run = run_ordered_stateful(
                &items,
                jobs,
                || 0usize,
                |seen: &mut usize, _, &x| {
                    *seen += 1;
                    assert!(*seen <= items.len(), "state leaked across workers");
                    x * 3
                },
                |_, _| true,
            );
            assert_eq!(run.results, (0..23).map(|x| x * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn fini_merges_every_worker_exactly_once() {
        use std::sync::Mutex;
        let items: Vec<usize> = (0..40).collect();
        for jobs in [1, 4] {
            // (fini invocations, items counted across workers)
            let merged = Mutex::new((0usize, 0usize));
            let run = run_ordered_with(
                &items,
                jobs,
                || 0usize,
                |seen: &mut usize, _, &x| {
                    *seen += 1;
                    x
                },
                |seen| {
                    let mut m = merged.lock().unwrap();
                    m.0 += 1;
                    m.1 += seen;
                },
                |_, _| true,
            );
            assert_eq!(run.results, items);
            let m = merged.lock().unwrap();
            assert_eq!(m.0, run.jobs, "fini once per worker");
            assert_eq!(m.1, items.len(), "every item counted exactly once");
        }
    }

    #[test]
    fn empty_items_complete_immediately() {
        let items: Vec<u32> = Vec::new();
        let run = run_ordered(&items, 4, |_, &x| x, |_, _| true);
        assert!(run.results.is_empty());
        assert!(!run.cancelled);
        assert!(run.failures.is_empty());
    }

    /// Regression (ISSUE 9): a panicking worker used to disconnect the
    /// channel with its claimed item unsent, so the ordered join lost
    /// results silently (or died on the "every pool item completes"
    /// expect). Panics must now surface as per-item failures while
    /// every healthy item is still delivered, in order.
    #[test]
    fn panicking_item_reports_a_failure_and_healthy_items_survive() {
        // Quiet the default panic hook's per-panic backtrace chatter
        // for this test; restore it afterwards. (HOOK serializes the
        // two hook-swapping tests so they cannot interleave.)
        let _guard = HOOK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| ()));
        let items: Vec<usize> = (0..20).collect();
        for jobs in [1, 2, 4] {
            let mut seen = Vec::new();
            let run = run_ordered(
                &items,
                jobs,
                |_, &x| {
                    if x == 7 || x == 13 {
                        panic!("poisoned cell {x}");
                    }
                    x * 2
                },
                |_, &r| {
                    seen.push(r);
                    true
                },
            );
            assert!(!run.cancelled);
            let expect: Vec<usize> = (0..20).filter(|&x| x != 7 && x != 13).map(|x| x * 2).collect();
            assert_eq!(run.results, expect, "jobs={jobs}");
            assert_eq!(seen, expect, "delivery skips failed items in order (jobs={jobs})");
            assert_eq!(
                run.failures,
                vec![
                    ItemPanic { index: 7, message: "poisoned cell 7".into() },
                    ItemPanic { index: 13, message: "poisoned cell 13".into() },
                ],
                "jobs={jobs}"
            );
        }
        std::panic::set_hook(prev);
    }

    /// After a panic the worker's scratch state may be half-mutated;
    /// the pool must rebuild it via `init` before the next item.
    #[test]
    fn worker_state_is_rebuilt_after_a_panic() {
        let _guard = HOOK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| ()));
        let items: Vec<usize> = (0..10).collect();
        let run = run_ordered_stateful(
            &items,
            1,
            || 0usize,
            |poisoned: &mut usize, _, &x| {
                assert_eq!(*poisoned, 0, "state from a panicked evaluation leaked");
                if x == 4 {
                    *poisoned = 1; // half-mutated state, then the panic
                    panic!("boom");
                }
                x
            },
            |_, _| true,
        );
        assert_eq!(run.failures.len(), 1);
        assert_eq!(run.results.len(), 9);
        std::panic::set_hook(prev);
    }
}
