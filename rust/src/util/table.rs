//! Plain-text and CSV table rendering for the figure/table benches.
//!
//! Every bench regenerates a paper exhibit as rows; this module gives
//! them a consistent, aligned text rendering plus CSV export so results
//! can be diffed/plotted downstream.

use std::fmt::Write as _;

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given headers; numeric columns are
    /// right-aligned by default when rendered (see [`Table::align`]).
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        let headers: Vec<String> = headers.into_iter().map(Into::into).collect();
        let aligns = vec![Align::Right; headers.len()];
        Table {
            headers,
            aligns,
            rows: Vec::new(),
        }
    }

    /// Set alignment for a column.
    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    /// Append a row (must match the header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, cells: &[String], widths: &[usize], aligns: &[Align]| {
            for i in 0..ncol {
                if i > 0 {
                    out.push_str("  ");
                }
                match aligns[i] {
                    Align::Left => write!(out, "{:<w$}", cells[i], w = widths[i]).unwrap(),
                    Align::Right => write!(out, "{:>w$}", cells[i], w = widths[i]).unwrap(),
                }
            }
            out.push('\n');
        };
        fmt_row(&mut out, &self.headers, &widths, &self.aligns);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(&mut out, row, &widths, &self.aligns);
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting for cells with commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV rendering to `path` (creating parent dirs).
    /// Crash-safe: the file appears whole or not at all.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        crate::util::atomic::write(path, self.to_csv())
    }
}

/// Format an f64 with `prec` decimals (helper for bench rows).
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Format a speedup/slowdown like the paper, e.g. "1.62x".
pub fn x(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "val"]).align(0, Align::Left);
        t.row(vec!["a", "1.0"]);
        t.row(vec!["long-name", "12.5"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a "));
        // right-aligned value column lines up on the right edge
        assert!(lines[3].ends_with("12.5"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "pl\"ain"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"pl\"\"ain\""));
    }

    #[test]
    fn format_helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(x(1.6), "1.60x");
    }
}
