//! Cell-completion journal for resumable sweep/tune runs (ISSUE 9).
//!
//! The artifact files themselves are written atomically at the end of
//! a run (see [`crate::util::atomic`]), so a killed run leaves no
//! truncated artifact — but it also leaves no artifact at all. The
//! journal is the incremental side-channel: one length-prefixed record
//! per *completed* cell, flushed as the cell finishes, holding a
//! byte-exact serialization of the cell's result. `--resume` replays
//! the journal's complete prefix, re-evaluates only the missing
//! cells, and emits artifacts that are byte-identical to a
//! straight-through run.
//!
//! Record framing:
//!
//! ```text
//! cell <index> <payload_len>\n
//! <payload bytes>\n
//! ```
//!
//! The length prefix makes truncation detection exact: a record whose
//! header or payload is cut short (the kill arrived mid-write) is
//! dropped along with everything after it, and the reader returns the
//! longest complete prefix. Payload contents are owned by the drivers
//! (`search::emit::tune_record` / `explore::emit::cell_record`).

use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Write};
use std::path::Path;

/// Append-only journal writer. Each [`Journal::record`] is flushed to
/// the OS before returning, so a killed process loses at most the
/// record it was writing — which the reader's framing check drops.
pub struct Journal {
    w: BufWriter<File>,
}

impl Journal {
    /// Start a fresh journal, truncating any previous one.
    pub fn create(path: impl AsRef<Path>) -> io::Result<Journal> {
        Ok(Journal {
            w: BufWriter::new(File::create(path)?),
        })
    }

    /// Open an existing journal for appending (the `--resume` path);
    /// creates it if missing.
    pub fn append(path: impl AsRef<Path>) -> io::Result<Journal> {
        Ok(Journal {
            w: BufWriter::new(OpenOptions::new().create(true).append(true).open(path)?),
        })
    }

    /// Record one completed cell.
    pub fn record(&mut self, index: usize, payload: &str) -> io::Result<()> {
        write!(self.w, "cell {} {}\n{}\n", index, payload.len(), payload)?;
        self.w.flush()
    }
}

/// One journaled cell.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Entry {
    pub index: usize,
    pub payload: String,
}

/// Read the longest complete prefix of a journal. A missing file, a
/// malformed header, a cut-short payload, or non-UTF-8 payload bytes
/// all end the prefix there — nothing after the first damage is
/// trusted, so a mid-run kill can never smuggle a half-written record
/// into the resumed run.
pub fn read(path: impl AsRef<Path>) -> Vec<Entry> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(_) => return Vec::new(),
    };
    let mut entries = Vec::new();
    let mut pos = 0usize;
    while pos < bytes.len() {
        let nl = match bytes[pos..].iter().position(|&b| b == b'\n') {
            Some(k) => pos + k,
            None => break, // header cut short
        };
        let header = match std::str::from_utf8(&bytes[pos..nl]) {
            Ok(h) => h,
            Err(_) => break,
        };
        let mut fields = header.split(' ');
        let (index, len) = match (fields.next(), fields.next(), fields.next(), fields.next()) {
            (Some("cell"), Some(i), Some(l), None) => {
                match (i.parse::<usize>(), l.parse::<usize>()) {
                    (Ok(i), Ok(l)) => (i, l),
                    _ => break,
                }
            }
            _ => break,
        };
        let start = nl + 1;
        // Payload plus its trailing newline must be fully present.
        if bytes.len() < start + len + 1 || bytes[start + len] != b'\n' {
            break;
        }
        let payload = match std::str::from_utf8(&bytes[start..start + len]) {
            Ok(p) => p.to_string(),
            Err(_) => break,
        };
        entries.push(Entry { index, payload });
        pos = start + len + 1;
    }
    entries
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tpath(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ficco-journal-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn roundtrip_preserves_every_record() {
        let p = tpath("roundtrip.journal");
        let mut j = Journal::create(&p).unwrap();
        j.record(0, "alpha,1,2\n{\"a\":1}").unwrap();
        j.record(3, "").unwrap();
        j.record(5, "multi\nline\npayload").unwrap();
        drop(j);
        let got = read(&p);
        assert_eq!(
            got,
            vec![
                Entry { index: 0, payload: "alpha,1,2\n{\"a\":1}".into() },
                Entry { index: 3, payload: "".into() },
                Entry { index: 5, payload: "multi\nline\npayload".into() },
            ]
        );
    }

    #[test]
    fn truncation_at_any_byte_keeps_only_the_complete_prefix() {
        let p = tpath("truncate.journal");
        let mut j = Journal::create(&p).unwrap();
        j.record(0, "first").unwrap();
        j.record(1, "second-record").unwrap();
        drop(j);
        let full = std::fs::read(&p).unwrap();
        let whole = read(&p);
        assert_eq!(whole.len(), 2);
        for cut in 0..full.len() {
            let q = tpath("truncate-cut.journal");
            std::fs::write(&q, &full[..cut]).unwrap();
            let got = read(&q);
            // Every cut yields a complete prefix of the full read —
            // never a damaged or invented record.
            assert!(got.len() <= whole.len());
            assert_eq!(got[..], whole[..got.len()], "cut at byte {cut}");
            // Cutting inside record 1 must still keep record 0.
            let rec0_len = full.iter().position(|&b| b == b'\n').unwrap() + "first".len() + 2;
            if cut >= rec0_len {
                assert!(!got.is_empty(), "cut at byte {cut} lost the complete record 0");
            }
        }
    }

    #[test]
    fn append_resumes_after_the_existing_records() {
        let p = tpath("append.journal");
        let mut j = Journal::create(&p).unwrap();
        j.record(0, "a").unwrap();
        drop(j);
        let mut j = Journal::append(&p).unwrap();
        j.record(1, "b").unwrap();
        drop(j);
        let got = read(&p);
        assert_eq!(got.len(), 2);
        assert_eq!(got[1], Entry { index: 1, payload: "b".into() });
    }

    #[test]
    fn missing_or_garbage_file_reads_as_empty() {
        assert!(read(tpath("never-written.journal")).is_empty());
        let p = tpath("garbage.journal");
        std::fs::write(&p, b"not a journal at all\n\xff\xfe").unwrap();
        assert!(read(&p).is_empty());
    }
}
