//! Deterministic pseudo-random number generation.
//!
//! The simulator, the synthetic workload generator, and the property
//! tests all need reproducible randomness. We implement splitmix64 (for
//! seeding) and xoshiro256** (for the stream) — both public-domain
//! algorithms — rather than depending on the `rand` crate, which is not
//! in the offline crate set.

/// splitmix64 step: used to expand a single `u64` seed into state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Deterministic, seedable, fast.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "range_u64: empty range [{lo}, {hi})");
        // Rejection-free Lemire-style bounded generation is overkill for
        // our uses; modulo bias over u64 is negligible for span << 2^64.
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Log-uniform f64 in `[lo, hi)`; lo and hi must be positive.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.range_f64(lo.ln(), hi.ln())).exp()
    }

    /// Bernoulli trial with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (used by the
    /// synthetic training corpus so token frequencies look natural).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on the generalized harmonic numbers would need a
        // table; for corpus generation a simple rejection scheme on the
        // continuous envelope is accurate enough and O(1) expected.
        loop {
            let u = self.f64();
            let x = ((n as f64).powf(1.0 - s) * u + (1.0 - u)).powf(1.0 / (1.0 - s));
            let k = x.floor() as usize;
            if k >= 1 && k <= n {
                return k - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range(3, 17);
            assert!((3..17).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(17);
        let n = 1000;
        let mut low = 0usize;
        for _ in 0..20_000 {
            let k = r.zipf(n, 1.1);
            assert!(k < n);
            if k < 10 {
                low += 1;
            }
        }
        // Zipf(1.1) concentrates heavily on low ranks.
        assert!(low > 5_000, "low-rank draws: {low}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(23);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn log_uniform_in_bounds() {
        let mut r = Rng::new(29);
        for _ in 0..1000 {
            let x = r.log_uniform(1.0, 1e6);
            assert!((1.0..1e6).contains(&x));
        }
    }
}
