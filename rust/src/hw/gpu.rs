//! Single-GPU specification.

use crate::config::{ConfigError, Doc};

/// Element datatype of a GEMM (determines peak FLOP/s and byte width).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    Bf16,
    F16,
    F32,
}

impl DType {
    pub fn bytes(self) -> u64 {
        match self {
            DType::Bf16 | DType::F16 => 2,
            DType::F32 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::Bf16 => "bf16",
            DType::F16 => "f16",
            DType::F32 => "f32",
        }
    }
}

/// GPU hardware parameters. Defaults model the AMD Instinct MI300X as
/// described in the paper's §IV-B methodology (public spec numbers).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Compute units (MI300X: 304). The simulator's compute resource.
    pub cus: usize,
    /// Peak dense matrix FLOP/s at bf16/f16.
    pub peak_bf16: f64,
    /// Peak dense matrix FLOP/s at f32.
    pub peak_f32: f64,
    /// HBM bandwidth, bytes/s (MI300X: 5.3 TB/s).
    pub hbm_bw: f64,
    /// Last-level (Infinity) cache capacity in bytes (MI300X: 256 MiB).
    pub llc_bytes: u64,
    /// Number of SDMA engines usable for peer copies.
    pub dma_engines: usize,
    /// Sustained bandwidth of a single DMA engine, bytes/s. A single
    /// engine cannot saturate a 64 GB/s IF link by itself on older
    /// parts; on MI300X-class hardware it can, so default = link rate.
    pub dma_engine_bw: f64,
    /// Fixed host-side kernel launch + prologue overhead, seconds
    /// (the "Other Inefficiency Losses" of §IV-A).
    pub kernel_launch: f64,
    /// CUs occupied by a GPU-core-driven (RCCL-style) communication
    /// kernel — the source of *compute interference* (Fig 3d).
    pub comm_kernel_cus: usize,
    /// Extra HBM-traffic multiplier for core-driven communication,
    /// modelling cache pollution that DMA offload avoids (§II-B: DMA
    /// eliminates compute interference and *part of* cache
    /// interference; memory interference remains).
    pub comm_cache_pollution: f64,
    /// Per-CU share of HBM bandwidth achievable by a memcpy-like kernel
    /// is not modelled; local gather/scatter kernels occupy this many
    /// CUs instead.
    pub copy_kernel_cus: usize,
    /// GEMM HBM-demand burstiness: a GEMM's memory accesses arrive in
    /// bursts at far above its average rate, so its *contention
    /// pressure* on the memory subsystem exceeds bytes/time. Average
    /// demand is multiplied by this factor for sharing purposes.
    pub hbm_burst: f64,
    /// Memory-subsystem interference amplification of inter-GPU
    /// traffic: each fabric byte costs more than one byte of HBM
    /// service (row-buffer conflicts, read/write turnaround, fabric
    /// stop sharing). Calibrated so overlapped execution reproduces
    /// the paper's Fig 9 CIL levels (geomean ≈1.11 GEMM / ≈1.12 comm
    /// under DMA all-to-all).
    pub comm_hbm_amp: f64,
    /// Fraction of raw link bandwidth a GPU-core-driven (RCCL-style)
    /// transfer sustains per link. Collective libraries pay protocol,
    /// channel-scheduling and SM-copy overheads — this is why the
    /// serial RCCL baseline leaves the 1.7x overlap opportunity the
    /// paper targets, and why FiCCO's DMA all-to-all has headroom.
    pub kernel_link_eff: f64,
    /// Fraction of raw link bandwidth a single SDMA engine sustains.
    pub dma_link_eff: f64,
}

impl GpuSpec {
    /// AMD Instinct MI300X (public numbers; bf16 peak 1307.4 TFLOP/s,
    /// 5.3 TB/s HBM3, 304 CUs, 256 MiB Infinity Cache).
    pub fn mi300x() -> GpuSpec {
        GpuSpec {
            name: "mi300x".into(),
            cus: 304,
            peak_bf16: 1307.4e12,
            peak_f32: 163.4e12,
            hbm_bw: 5.3e12,
            llc_bytes: 256 << 20,
            dma_engines: 16,
            dma_engine_bw: 64e9,
            kernel_launch: 8e-6,
            comm_kernel_cus: 12,
            comm_cache_pollution: 2.5,
            copy_kernel_cus: 24,
            hbm_burst: 2.5,
            comm_hbm_amp: 6.5,
            kernel_link_eff: 0.35,
            dma_link_eff: 0.9,
        }
    }

    /// NVIDIA H100 SXM (public numbers; bf16 dense tensor peak
    /// 989.4 TFLOP/s, 3.35 TB/s HBM3, 132 SMs modelled as CUs, 50 MiB
    /// L2). Copy engines stand in for SDMA; a single engine cannot
    /// saturate the 450 GB/s NVLink pipe, so DMA transfers are
    /// engine-capped — the switch-topology counterpoint to the MI300X
    /// mesh in §VIII-A.
    pub fn h100() -> GpuSpec {
        GpuSpec {
            name: "h100".into(),
            cus: 132,
            peak_bf16: 989.4e12,
            peak_f32: 66.9e12,
            hbm_bw: 3.35e12,
            llc_bytes: 50 << 20,
            dma_engines: 7,
            dma_engine_bw: 64e9,
            kernel_launch: 6e-6,
            comm_kernel_cus: 16,
            comm_cache_pollution: 2.5,
            copy_kernel_cus: 24,
            hbm_burst: 2.5,
            comm_hbm_amp: 6.0,
            kernel_link_eff: 0.6,
            dma_link_eff: 0.9,
        }
    }

    /// AMD Instinct MI210-class part for the PCIe-attached box (bf16
    /// peak 181 TFLOP/s, 1.6 TB/s HBM2e, 104 CUs, 8 MiB L2): a
    /// low-bandwidth machine whose balance point sits far below the
    /// MI300X's, moving the heuristic threshold the sweep explores.
    pub fn mi210() -> GpuSpec {
        GpuSpec {
            name: "mi210".into(),
            cus: 104,
            peak_bf16: 181.0e12,
            peak_f32: 22.6e12,
            hbm_bw: 1.6e12,
            llc_bytes: 8 << 20,
            dma_engines: 8,
            dma_engine_bw: 25e9,
            kernel_launch: 10e-6,
            comm_kernel_cus: 8,
            comm_cache_pollution: 2.5,
            copy_kernel_cus: 16,
            hbm_burst: 2.5,
            comm_hbm_amp: 6.5,
            kernel_link_eff: 0.35,
            dma_link_eff: 0.9,
        }
    }

    pub fn peak_flops(&self, dtype: DType) -> f64 {
        match dtype {
            DType::Bf16 | DType::F16 => self.peak_bf16,
            DType::F32 => self.peak_f32,
        }
    }

    /// Aggregate DMA bandwidth available for peer copies.
    pub fn dma_total_bw(&self) -> f64 {
        self.dma_engines as f64 * self.dma_engine_bw
    }

    /// Build from `[gpu]` section of a config; missing keys fall back
    /// to the MI300X preset.
    pub fn from_config(doc: &Doc) -> Result<GpuSpec, ConfigError> {
        let d = GpuSpec::mi300x();
        Ok(GpuSpec {
            name: doc.str_or("gpu", "name", &d.name).to_string(),
            cus: doc.i64_or("gpu", "cus", d.cus as i64) as usize,
            peak_bf16: doc.f64_or("gpu", "peak_bf16_tflops", d.peak_bf16 / 1e12) * 1e12,
            peak_f32: doc.f64_or("gpu", "peak_f32_tflops", d.peak_f32 / 1e12) * 1e12,
            hbm_bw: doc.f64_or("gpu", "hbm_gbps", d.hbm_bw / 1e9) * 1e9,
            llc_bytes: (doc.i64_or("gpu", "llc_mib", (d.llc_bytes >> 20) as i64) as u64) << 20,
            dma_engines: doc.i64_or("gpu", "dma_engines", d.dma_engines as i64) as usize,
            dma_engine_bw: doc.f64_or("gpu", "dma_engine_gbps", d.dma_engine_bw / 1e9) * 1e9,
            kernel_launch: doc.f64_or("gpu", "kernel_launch_us", d.kernel_launch * 1e6) * 1e-6,
            comm_kernel_cus: doc.i64_or("gpu", "comm_kernel_cus", d.comm_kernel_cus as i64)
                as usize,
            comm_cache_pollution: doc.f64_or("gpu", "comm_cache_pollution", d.comm_cache_pollution),
            copy_kernel_cus: doc.i64_or("gpu", "copy_kernel_cus", d.copy_kernel_cus as i64)
                as usize,
            hbm_burst: doc.f64_or("gpu", "hbm_burst", d.hbm_burst),
            comm_hbm_amp: doc.f64_or("gpu", "comm_hbm_amp", d.comm_hbm_amp),
            kernel_link_eff: doc.f64_or("gpu", "kernel_link_eff", d.kernel_link_eff),
            dma_link_eff: doc.f64_or("gpu", "dma_link_eff", d.dma_link_eff),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::Bf16.bytes(), 2);
        assert_eq!(DType::F32.bytes(), 4);
    }

    #[test]
    fn preset_gpus_have_distinct_balance_points() {
        // The sweep relies on the presets spanning the balance axis
        // the heuristic thresholds on (FLOP per HBM byte at bf16).
        let balance = |g: &GpuSpec| g.peak_flops(DType::Bf16) / g.hbm_bw;
        let mi300x = balance(&GpuSpec::mi300x());
        let h100 = balance(&GpuSpec::h100());
        let mi210 = balance(&GpuSpec::mi210());
        assert!(mi210 < mi300x, "mi210 {mi210} vs mi300x {mi300x}");
        assert!((100.0..500.0).contains(&h100), "h100 balance {h100}");
        assert!(GpuSpec::mi210().llc_bytes < GpuSpec::h100().llc_bytes);
    }

    #[test]
    fn mi300x_numbers() {
        let g = GpuSpec::mi300x();
        assert_eq!(g.cus, 304);
        assert!((g.peak_flops(DType::Bf16) - 1307.4e12).abs() < 1e6);
        assert!(g.peak_flops(DType::F32) < g.peak_flops(DType::Bf16));
        assert_eq!(g.llc_bytes, 256 << 20);
        assert!(g.dma_total_bw() >= 7.0 * 64e9, "DMA pool must cover all mesh links");
    }
}
