//! Hardware model: GPU specification and inter-GPU network topology.
//!
//! The paper's testbed (8× AMD Instinct MI300X, fully-connected
//! Infinity Fabric, 64 GB/s unidirectional per link) is modelled
//! analytically. All figures in the paper are ratios over this machine,
//! so what matters is that the model exposes the same *balance points*:
//! peak matrix FLOP/s vs HBM bandwidth (the roofline knee the heuristic
//! thresholds on), per-link vs aggregate network bandwidth (the
//! shard-overlap-vs-FiCCO distinction), and DMA engines as a resource
//! distinct from compute cores (the contention distinction).

mod gpu;
mod topology;

pub use gpu::{DType, GpuSpec};
pub use topology::{Topology, TopologyKind};

use crate::config::Doc;

/// A machine = one GPU spec replicated over a topology.
#[derive(Debug, Clone)]
pub struct Machine {
    pub gpu: GpuSpec,
    pub topo: Topology,
}

impl Machine {
    /// The paper's testbed: 8× MI300X on a full mesh.
    pub fn mi300x_8() -> Machine {
        Machine {
            gpu: GpuSpec::mi300x(),
            topo: Topology::full_mesh(8, 64e9, 2.0e-6),
        }
    }

    /// NVLink-switch-style machine (for §VIII-A topology discussion and
    /// the shard-overlap baselines' home turf).
    pub fn switch_8() -> Machine {
        Machine {
            gpu: GpuSpec::mi300x(),
            topo: Topology::switch(8, 450e9, 2.0e-6),
        }
    }

    pub fn ngpus(&self) -> usize {
        self.topo.ngpus
    }

    /// Machine balance (FLOP per HBM byte) at a given dtype — the knee
    /// of the roofline; the heuristic's machine-level threshold unit.
    pub fn balance(&self, dtype: DType) -> f64 {
        self.gpu.peak_flops(dtype) / self.gpu.hbm_bw
    }

    /// Build from a config document (see `configs/mi300x.toml`).
    pub fn from_config(doc: &Doc) -> Result<Machine, crate::config::ConfigError> {
        let gpu = GpuSpec::from_config(doc)?;
        let topo = Topology::from_config(doc)?;
        Ok(Machine { gpu, topo })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mi300x_preset_sane() {
        let m = Machine::mi300x_8();
        assert_eq!(m.ngpus(), 8);
        // MI300X balance point is a few hundred bf16 FLOPs per byte.
        let b = m.balance(DType::Bf16);
        assert!(b > 100.0 && b < 500.0, "balance={b}");
    }

    #[test]
    fn from_config_roundtrip() {
        let doc = crate::config::parse(
            r#"
[gpu]
name = "test"
cus = 100
peak_bf16_tflops = 1000.0
peak_f32_tflops = 250.0
hbm_gbps = 4000.0
llc_mib = 128
dma_engines = 8
dma_engine_gbps = 64.0
kernel_launch_us = 8.0
comm_kernel_cus = 32

[topology]
kind = "full_mesh"
ngpus = 4
link_gbps = 50.0
latency_us = 2.0
"#,
        )
        .unwrap();
        let m = Machine::from_config(&doc).unwrap();
        assert_eq!(m.gpu.cus, 100);
        assert_eq!(m.topo.ngpus, 4);
        assert!((m.topo.link_bw - 50e9).abs() < 1.0);
    }
}
