//! Hardware model: GPU specification and inter-GPU network topology.
//!
//! The paper's testbed (8× AMD Instinct MI300X, fully-connected
//! Infinity Fabric, 64 GB/s unidirectional per link) is modelled
//! analytically. All figures in the paper are ratios over this machine,
//! so what matters is that the model exposes the same *balance points*:
//! peak matrix FLOP/s vs HBM bandwidth (the roofline knee the heuristic
//! thresholds on), per-link vs aggregate network bandwidth (the
//! shard-overlap-vs-FiCCO distinction), and DMA engines as a resource
//! distinct from compute cores (the contention distinction).
//!
//! Beyond the paper's MI300X-8 testbed, the preset registry
//! ([`Machine::preset`]/[`Machine::preset_names`]) exposes an
//! H100-DGX-like switched machine and a PCIe-Gen4-class box, so the
//! `ficco sweep` design-space exploration exercises the topology and
//! machine-balance axes the schedule-selection heuristic derives its
//! threshold from.

mod gpu;
mod perturb;
mod topology;

pub use gpu::{DType, GpuSpec};
pub use perturb::{PerturbSample, Perturbation};
pub use topology::{Topology, TopologyKind};

use crate::config::Doc;

/// A machine = one GPU spec replicated over a topology.
/// `PartialEq` lets a reusable evaluator detect whether its cached
/// resource/stream skeleton still matches the machine it is asked to
/// simulate (all fields are plain values, so equality is exact).
#[derive(Debug, Clone, PartialEq)]
pub struct Machine {
    pub gpu: GpuSpec,
    pub topo: Topology,
}

impl Machine {
    /// The paper's testbed: 8× MI300X on a full mesh.
    pub fn mi300x_8() -> Machine {
        Machine {
            gpu: GpuSpec::mi300x(),
            topo: Topology::full_mesh(8, 64e9, 2.0e-6),
        }
    }

    /// NVLink-switch-style machine (for §VIII-A topology discussion and
    /// the shard-overlap baselines' home turf).
    pub fn switch_8() -> Machine {
        Machine {
            gpu: GpuSpec::mi300x(),
            topo: Topology::switch(8, 450e9, 2.0e-6),
        }
    }

    /// H100-DGX-like machine: 8 GPUs behind an NVSwitch-style fabric
    /// (450 GB/s per-GPU pipe). A single P2P stream gets the full NIC
    /// rate, but DMA transfers are copy-engine-capped — the opposite
    /// trade-off to the MI300X mesh.
    pub fn h100_dgx_8() -> Machine {
        Machine {
            gpu: GpuSpec::h100(),
            topo: Topology::switch(8, 450e9, 1.5e-6),
        }
    }

    /// Low-bandwidth PCIe-Gen4-class box: 4 MI210-class GPUs peering
    /// through the root complex at ~25 GB/s with high latency. Comm
    /// legs dominate here, stressing the DIL-tolerant schedules.
    pub fn pcie_gen4_4() -> Machine {
        Machine {
            gpu: GpuSpec::mi210(),
            topo: Topology::switch(4, 25e9, 5.0e-6),
        }
    }

    /// Names accepted by [`Machine::preset`], in sweep order.
    pub fn preset_names() -> &'static [&'static str] {
        &["mi300x-8", "h100-dgx-8", "pcie-gen4-4", "switch-8"]
    }

    /// Look up a machine preset by name (see [`Machine::preset_names`]).
    pub fn preset(name: &str) -> Option<Machine> {
        match name {
            "mi300x-8" => Some(Machine::mi300x_8()),
            "h100-dgx-8" => Some(Machine::h100_dgx_8()),
            "pcie-gen4-4" => Some(Machine::pcie_gen4_4()),
            "switch-8" => Some(Machine::switch_8()),
            _ => None,
        }
    }

    pub fn ngpus(&self) -> usize {
        self.topo.ngpus
    }

    /// Machine balance (FLOP per HBM byte) at a given dtype — the knee
    /// of the roofline; the heuristic's machine-level threshold unit.
    pub fn balance(&self, dtype: DType) -> f64 {
        self.gpu.peak_flops(dtype) / self.gpu.hbm_bw
    }

    /// Build from a config document (see `configs/mi300x.toml`).
    pub fn from_config(doc: &Doc) -> Result<Machine, crate::config::ConfigError> {
        let gpu = GpuSpec::from_config(doc)?;
        let topo = Topology::from_config(doc)?;
        Ok(Machine { gpu, topo })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mi300x_preset_sane() {
        let m = Machine::mi300x_8();
        assert_eq!(m.ngpus(), 8);
        // MI300X balance point is a few hundred bf16 FLOPs per byte.
        let b = m.balance(DType::Bf16);
        assert!(b > 100.0 && b < 500.0, "balance={b}");
    }

    #[test]
    fn preset_registry_resolves_all_names() {
        for name in Machine::preset_names() {
            let m = Machine::preset(name).unwrap_or_else(|| panic!("preset {name}"));
            assert!(m.ngpus() >= 2, "{name}");
            let b = m.balance(DType::Bf16);
            assert!(b > 50.0 && b < 1000.0, "{name} balance {b}");
        }
        assert!(Machine::preset("nope").is_none());
    }

    #[test]
    fn new_presets_span_the_design_axes() {
        let mesh = Machine::mi300x_8();
        let dgx = Machine::h100_dgx_8();
        let pcie = Machine::pcie_gen4_4();
        // Topology axis: mesh P2P idles links, switch does not.
        assert!(mesh.topo.p2p_utilization() < 1.0);
        assert!((dgx.topo.p2p_utilization() - 1.0).abs() < 1e-12);
        // Bandwidth axis: the PCIe box is an order of magnitude slower.
        assert!(pcie.topo.link_bw < mesh.topo.link_bw);
        assert_eq!(pcie.ngpus(), 4);
        // Balance axis: the PCIe part's knee sits below the MI300X's.
        assert!(pcie.balance(DType::Bf16) < mesh.balance(DType::Bf16));
    }

    #[test]
    fn from_config_roundtrip() {
        let doc = crate::config::parse(
            r#"
[gpu]
name = "test"
cus = 100
peak_bf16_tflops = 1000.0
peak_f32_tflops = 250.0
hbm_gbps = 4000.0
llc_mib = 128
dma_engines = 8
dma_engine_gbps = 64.0
kernel_launch_us = 8.0
comm_kernel_cus = 32

[topology]
kind = "full_mesh"
ngpus = 4
link_gbps = 50.0
latency_us = 2.0
"#,
        )
        .unwrap();
        let m = Machine::from_config(&doc).unwrap();
        assert_eq!(m.gpu.cus, 100);
        assert_eq!(m.topo.ngpus, 4);
        assert!((m.topo.link_bw - 50e9).abs() < 1.0);
    }
}
