//! Deterministic hardware perturbation ensembles (ISSUE 9).
//!
//! The paper selects schedules against a *nominal* cost model, but its
//! own contention characterization (and the variability measured by
//! the related overlap work) shows real deployments see straggler
//! GPUs, bandwidth jitter, and inflated comm-setup latencies that can
//! flip which schedule wins. A [`Perturbation`] describes a seeded
//! ensemble of such perturbed machines; each member is a
//! [`PerturbSample`] of pure *multipliers* applied at task-build time
//! in [`crate::sim::ClusterSim`], so the `sim::Engine` hot path (and
//! its zero-alloc arenas) is untouched and a zero-magnitude ensemble
//! is bit-for-bit identical to today's nominal run (the `None` sample
//! path is literally the pre-existing code).
//!
//! Determinism contract: sample `i` of an ensemble depends only on
//! `(seed, i, ngpus, num_links)` — never on evaluation order, worker
//! count, or which plans were evaluated before. That is what makes
//! robust ranking byte-stable across `--jobs 1` vs `--jobs 4`.

use crate::util::rng::Rng;

/// Seeded ensemble specification: magnitudes are *fractions* (0.10 =
/// up to 10% perturbation, sampled uniformly per GPU / link / run).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Perturbation {
    /// Max fractional compute slowdown per GPU (straggler): sample
    /// work multipliers lie in `[1, 1 + compute]`.
    pub compute: f64,
    /// Max fractional per-link bandwidth degradation: sample rate
    /// multipliers lie in `[1 - bandwidth, 1]`. Must be `< 1`.
    pub bandwidth: f64,
    /// Max fractional comm-setup latency inflation: the sample's setup
    /// multiplier lies in `[1, 1 + setup]`.
    pub setup: f64,
    /// Ensemble size (number of perturbed machines evaluated).
    pub samples: usize,
    /// PRNG seed; the whole ensemble is a pure function of it.
    pub seed: u64,
}

impl Perturbation {
    /// Default magnitudes: mild stragglers (10%), moderate bandwidth
    /// jitter (20%), strong setup inflation (50%) — setup latency is
    /// the noisiest quantity in the overlap measurements.
    pub const DEFAULT_COMPUTE: f64 = 0.10;
    pub const DEFAULT_BANDWIDTH: f64 = 0.20;
    pub const DEFAULT_SETUP: f64 = 0.50;
    /// Default ensemble seed (matches the repo-wide sweep seed era).
    pub const DEFAULT_SEED: u64 = 2025;

    /// An ensemble of `samples` members at the default magnitudes.
    pub fn defaults(samples: usize, seed: u64) -> Perturbation {
        Perturbation {
            compute: Self::DEFAULT_COMPUTE,
            bandwidth: Self::DEFAULT_BANDWIDTH,
            setup: Self::DEFAULT_SETUP,
            samples,
            seed,
        }
    }

    /// True when the ensemble cannot perturb anything: robust
    /// evaluation of such an ensemble must be bit-identical to the
    /// nominal run (enforced by passing `None` samples to the sim).
    pub fn is_nominal(&self) -> bool {
        (self.compute == 0.0 && self.bandwidth == 0.0 && self.setup == 0.0) || self.samples == 0
    }

    /// Validate magnitudes: finite, non-negative, bandwidth strictly
    /// below 1 (a link cannot degrade to or past zero rate).
    pub fn check(&self) -> Result<(), String> {
        for (name, v) in [
            ("compute", self.compute),
            ("bandwidth", self.bandwidth),
            ("setup", self.setup),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("perturbation {name} magnitude must be finite and >= 0, got {v}"));
            }
        }
        if self.bandwidth >= 1.0 {
            return Err(format!(
                "perturbation bandwidth magnitude must be < 1 (links keep positive rate), got {}",
                self.bandwidth
            ));
        }
        Ok(())
    }

    /// Draw ensemble member `index` for a machine with `ngpus` GPUs
    /// and `num_links` fabric links. Pure function of
    /// `(seed, index, ngpus, num_links)`.
    pub fn sample(&self, index: usize, ngpus: usize, num_links: usize) -> PerturbSample {
        // Per-member stream: splitmix64 inside Rng::new decorrelates
        // consecutive seeds, and the golden-ratio stride keeps member
        // streams disjoint for any ensemble size.
        let mut rng = Rng::new(
            self.seed
                .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(index as u64 + 1)),
        );
        let gpu_work = (0..ngpus)
            .map(|_| 1.0 + self.compute * rng.f64())
            .collect();
        let link_rate = (0..num_links)
            .map(|_| 1.0 - self.bandwidth * rng.f64())
            .collect();
        let setup_mult = 1.0 + self.setup * rng.f64();
        PerturbSample {
            gpu_work,
            link_rate,
            setup_mult,
        }
    }
}

/// One ensemble member: multipliers applied at task-build time.
#[derive(Debug, Clone, PartialEq)]
pub struct PerturbSample {
    /// Per-GPU compute work multiplier, `>= 1` (straggler slows its
    /// kernels and local copies).
    pub gpu_work: Vec<f64>,
    /// Per-link achievable-rate multiplier, `(0, 1]` (degraded link
    /// serves transfers slower).
    pub link_rate: Vec<f64>,
    /// Comm-setup latency multiplier, `>= 1`.
    pub setup_mult: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ens() -> Perturbation {
        Perturbation::defaults(8, 42)
    }

    #[test]
    fn samples_are_deterministic_per_index() {
        let e = ens();
        let a = e.sample(3, 8, 56);
        let b = e.sample(3, 8, 56);
        assert_eq!(a, b);
        // Distinct members actually differ.
        let c = e.sample(4, 8, 56);
        assert_ne!(a, c);
    }

    #[test]
    fn sample_bounds_hold() {
        let e = ens();
        for i in 0..e.samples {
            let s = e.sample(i, 8, 56);
            assert_eq!(s.gpu_work.len(), 8);
            assert_eq!(s.link_rate.len(), 56);
            for &w in &s.gpu_work {
                assert!((1.0..=1.0 + e.compute).contains(&w), "gpu_work={w}");
            }
            for &r in &s.link_rate {
                assert!(r > 0.0 && r <= 1.0 && r >= 1.0 - e.bandwidth, "link_rate={r}");
            }
            assert!(s.setup_mult >= 1.0 && s.setup_mult <= 1.0 + e.setup);
        }
    }

    #[test]
    fn zero_magnitude_is_nominal_and_exactly_one() {
        let e = Perturbation {
            compute: 0.0,
            bandwidth: 0.0,
            setup: 0.0,
            samples: 4,
            seed: 7,
        };
        assert!(e.is_nominal());
        let s = e.sample(0, 4, 12);
        assert!(s.gpu_work.iter().all(|&w| w == 1.0));
        assert!(s.link_rate.iter().all(|&r| r == 1.0));
        assert_eq!(s.setup_mult, 1.0);
        assert!(!ens().is_nominal());
        assert!(Perturbation { samples: 0, ..ens() }.is_nominal());
    }

    #[test]
    fn check_rejects_bad_magnitudes() {
        assert!(ens().check().is_ok());
        assert!(Perturbation { compute: -0.1, ..ens() }.check().is_err());
        assert!(Perturbation { bandwidth: 1.0, ..ens() }.check().is_err());
        assert!(Perturbation { setup: f64::NAN, ..ens() }.check().is_err());
    }
}
