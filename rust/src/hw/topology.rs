//! Inter-GPU network topology.
//!
//! The distinction the paper turns on (§III, Fig 5, Fig 13): with a
//! *direct/full-mesh* topology each GPU has a dedicated link per peer,
//! so a peer-to-peer schedule that talks to one peer at a time leaves
//! `ngpus-2` links idle; a *switch* topology pools per-GPU bandwidth
//! and can give a single P2P stream the full NIC rate.

use crate::config::{ConfigError, Doc};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    /// Direct connection GPU↔GPU (MI300X Infinity Fabric mesh):
    /// a dedicated `link_bw` link per ordered pair.
    FullMesh,
    /// Switched (NVSwitch-style): each GPU has one egress and one
    /// ingress pipe of `link_bw`, flexibly allocated across peers.
    Switch,
    /// Unidirectional ring: each GPU has a single link to (r+1)%n.
    Ring,
}

impl TopologyKind {
    pub fn parse(s: &str) -> Option<TopologyKind> {
        match s {
            "full_mesh" | "mesh" => Some(TopologyKind::FullMesh),
            "switch" => Some(TopologyKind::Switch),
            "ring" => Some(TopologyKind::Ring),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TopologyKind::FullMesh => "full_mesh",
            TopologyKind::Switch => "switch",
            TopologyKind::Ring => "ring",
        }
    }
}

/// Network topology over `ngpus` GPUs.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub kind: TopologyKind,
    pub ngpus: usize,
    /// Unidirectional bandwidth (bytes/s) of one link (mesh/ring: per
    /// peer link; switch: per-GPU NIC pipe).
    pub link_bw: f64,
    /// Per-message latency (seconds): launch-to-first-byte.
    pub latency: f64,
    /// Message size at which a single transfer reaches half of link
    /// bandwidth (packetization/pipelining ramp). Small transfers—the
    /// finer grains FiCCO creates—achieve lower effective bandwidth;
    /// this is the source of communication DIL (§IV-C2, Fig 8).
    pub msg_half: f64,
}

impl Topology {
    pub const DEFAULT_MSG_HALF: f64 = 8.0 * 1024.0 * 1024.0;

    pub fn full_mesh(ngpus: usize, link_bw: f64, latency: f64) -> Topology {
        Topology {
            kind: TopologyKind::FullMesh,
            ngpus,
            link_bw,
            latency,
            msg_half: Self::DEFAULT_MSG_HALF,
        }
    }

    pub fn switch(ngpus: usize, nic_bw: f64, latency: f64) -> Topology {
        Topology {
            kind: TopologyKind::Switch,
            ngpus,
            link_bw: nic_bw,
            latency,
            msg_half: Self::DEFAULT_MSG_HALF,
        }
    }

    pub fn ring(ngpus: usize, link_bw: f64, latency: f64) -> Topology {
        Topology {
            kind: TopologyKind::Ring,
            ngpus,
            link_bw,
            latency,
            msg_half: Self::DEFAULT_MSG_HALF,
        }
    }

    /// Effective bandwidth of a single transfer of `bytes`, accounting
    /// for the small-message ramp: `link_bw · s/(s + msg_half)`.
    pub fn effective_bw(&self, bytes: f64) -> f64 {
        assert!(bytes >= 0.0);
        if bytes <= 0.0 {
            return self.link_bw;
        }
        self.link_bw * bytes / (bytes + self.msg_half)
    }

    /// Is (src → dst) directly connected?
    pub fn connected(&self, src: usize, dst: usize) -> bool {
        if src == dst {
            return false;
        }
        match self.kind {
            TopologyKind::FullMesh | TopologyKind::Switch => true,
            TopologyKind::Ring => dst == (src + 1) % self.ngpus,
        }
    }

    /// Aggregate egress bandwidth a single GPU can drive when talking
    /// to *all* peers simultaneously.
    pub fn aggregate_egress(&self, _gpu: usize) -> f64 {
        match self.kind {
            TopologyKind::FullMesh => (self.ngpus - 1) as f64 * self.link_bw,
            TopologyKind::Switch => self.link_bw,
            TopologyKind::Ring => self.link_bw,
        }
    }

    /// Bandwidth available to a single peer-to-peer stream src→dst.
    pub fn p2p_bw(&self, src: usize, dst: usize) -> f64 {
        assert!(self.connected(src, dst), "no link {src}→{dst}");
        self.link_bw
    }

    /// Fraction of a GPU's aggregate egress a single-peer P2P stream
    /// uses — the paper's shard-overlap link-idling problem. 1.0 on a
    /// switch; 1/(n-1) on a full mesh.
    pub fn p2p_utilization(&self) -> f64 {
        match self.kind {
            TopologyKind::FullMesh => 1.0 / (self.ngpus - 1) as f64,
            TopologyKind::Switch => 1.0,
            TopologyKind::Ring => 1.0,
        }
    }

    /// Number of directed links in the fabric (simulator resources).
    pub fn num_links(&self) -> usize {
        match self.kind {
            TopologyKind::FullMesh => self.ngpus * (self.ngpus - 1),
            // Switch: modelled as one egress + one ingress pipe per GPU.
            TopologyKind::Switch => 2 * self.ngpus,
            TopologyKind::Ring => self.ngpus,
        }
    }

    /// Simulator resource index for the capacity constraining a
    /// src→dst transfer. Returns one or two indices into the link
    /// resource array (switch transfers consume egress *and* ingress).
    pub fn link_indices(&self, src: usize, dst: usize) -> Vec<usize> {
        let (a, b) = self.link_pair(src, dst);
        match b {
            Some(b) => vec![a, b],
            None => vec![a],
        }
    }

    /// Allocation-free form of [`Topology::link_indices`]: every
    /// topology constrains a transfer by one or two link resources,
    /// returned as `(first, second)`. The task loader building
    /// hundreds of transfers per candidate schedule uses this to
    /// avoid a `Vec` per transfer.
    pub fn link_pair(&self, src: usize, dst: usize) -> (usize, Option<usize>) {
        assert!(self.connected(src, dst), "no link {src}→{dst}");
        match self.kind {
            TopologyKind::FullMesh => {
                // Dense index over ordered pairs, skipping the diagonal.
                let col = if dst > src { dst - 1 } else { dst };
                (src * (self.ngpus - 1) + col, None)
            }
            TopologyKind::Switch => (2 * src, Some(2 * dst + 1)),
            TopologyKind::Ring => (src, None),
        }
    }

    pub fn from_config(doc: &Doc) -> Result<Topology, ConfigError> {
        let kind_s = doc.str_or("topology", "kind", "full_mesh");
        let kind = TopologyKind::parse(kind_s)
            .ok_or_else(|| ConfigError(format!("unknown topology.kind '{kind_s}'")))?;
        Ok(Topology {
            kind,
            ngpus: doc.i64_or("topology", "ngpus", 8) as usize,
            link_bw: doc.f64_or("topology", "link_gbps", 64.0) * 1e9,
            latency: doc.f64_or("topology", "latency_us", 2.0) * 1e-6,
            msg_half: doc.f64_or("topology", "msg_half_mib", 8.0) * 1024.0 * 1024.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_links_unique_and_dense() {
        let t = Topology::full_mesh(8, 64e9, 2e-6);
        assert_eq!(t.num_links(), 56);
        let mut seen = std::collections::HashSet::new();
        for s in 0..8 {
            for d in 0..8 {
                if s != d {
                    let idx = t.link_indices(s, d);
                    assert_eq!(idx.len(), 1);
                    assert!(idx[0] < t.num_links());
                    assert!(seen.insert(idx[0]), "collision at {s}->{d}");
                }
            }
        }
        assert_eq!(seen.len(), 56);
    }

    #[test]
    fn mesh_p2p_wastes_links() {
        let t = Topology::full_mesh(8, 64e9, 2e-6);
        assert!((t.p2p_utilization() - 1.0 / 7.0).abs() < 1e-12);
        assert!((t.aggregate_egress(0) - 7.0 * 64e9).abs() < 1.0);
    }

    #[test]
    fn switch_p2p_full_rate() {
        let t = Topology::switch(8, 450e9, 2e-6);
        assert_eq!(t.p2p_utilization(), 1.0);
        assert_eq!(t.aggregate_egress(3), 450e9);
        // switch transfer consumes egress of src and ingress of dst
        let idx = t.link_indices(1, 5);
        assert_eq!(idx, vec![2, 11]);
    }

    #[test]
    fn ring_connectivity() {
        let t = Topology::ring(4, 64e9, 2e-6);
        assert!(t.connected(0, 1));
        assert!(!t.connected(0, 2));
        assert!(t.connected(3, 0));
        assert_eq!(t.num_links(), 4);
    }

    #[test]
    #[should_panic]
    fn no_self_link() {
        let t = Topology::full_mesh(8, 64e9, 2e-6);
        t.link_indices(3, 3);
    }
}
