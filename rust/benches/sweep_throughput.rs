//! Bench: sweep-engine throughput and parallel scaling.
//!
//! Runs a fixed synthetic design-space sweep (Table-I-spanning
//! scenario shapes × three machine presets × both mechanisms) at
//! increasing `--jobs`-style worker counts and reports wall time,
//! speedup over the single-worker run, and parallel efficiency. The
//! fluid simulator is pure and cells are independent, so scaling
//! should stay near-linear until the host runs out of cores.
//!
//! Run: `cargo bench --bench sweep_throughput`

use ficco::explore::{run, SweepSpec};
use ficco::hw::Machine;
use ficco::schedule::Kind;
use ficco::sim::CommMech;
use ficco::workloads;

fn spec() -> SweepSpec {
    SweepSpec {
        scenarios: workloads::synthetic_scenarios(2025, 8),
        kinds: Kind::ALL.to_vec(),
        machines: vec![
            ("mi300x-8".into(), Machine::mi300x_8()),
            ("h100-dgx-8".into(), Machine::h100_dgx_8()),
            ("pcie-gen4-4".into(), Machine::pcie_gen4_4()),
        ],
        mechs: vec![CommMech::Dma, CommMech::Kernel],
        gpu_counts: Vec::new(),
        skews: Vec::new(),
        skew_seed: ficco::explore::DEFAULT_SKEW_SEED,
        search: None,
        model: None,
    }
}

fn main() {
    let spec = spec();
    let cells = spec.cells().len();
    let points = spec.n_points();
    let host = ficco::cli::default_jobs();
    println!("== perf: sweep engine ({cells} cells, {points} points, host parallelism {host}) ==");

    // Warm-up pass (first run pays allocator/page-fault noise).
    let _ = run(&spec, host, |_| true);

    let mut jobs_axis = vec![1usize, 2, 4];
    if host > 4 {
        jobs_axis.push(host);
    }
    let mut base = f64::NAN;
    for &jobs in &jobs_axis {
        let report = run(&spec, jobs, |_| true);
        if jobs == 1 {
            base = report.wall_seconds;
        }
        let speedup = base / report.wall_seconds;
        println!(
            "jobs {jobs:>3}: {:>8.3}s wall  {:>8.3}s cpu  speedup {speedup:>5.2}x  efficiency {:>5.1}%  ({:.1} points/s)",
            report.wall_seconds,
            report.cpu_seconds(),
            100.0 * speedup / jobs as f64,
            points as f64 / report.wall_seconds.max(1e-9),
        );
    }
}
