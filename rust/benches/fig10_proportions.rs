//! Bench: regenerates the paper's Fig 10 on the modelled 8x MI300X
//! machine and reports wall time. Run: `cargo bench --bench fig10_proportions`.
use std::time::Instant;

fn main() {
    let machine = ficco::hw::Machine::mi300x_8();
    let t0 = Instant::now();
    let exhibit = ficco::metrics::fig10_proportions(&machine);
    let dt = t0.elapsed();
    exhibit.print();
    let _ = exhibit.table.write_csv("results/fig10_proportions.csv");
    println!("[bench] fig10_proportions generated in {dt:?} -> results/fig10_proportions.csv");
}
