//! Bench: regenerates the paper's Fig 14 on the modelled 8x MI300X
//! machine and reports wall time. Run: `cargo bench --bench fig14_comparison`.
use std::time::Instant;

fn main() {
    let machine = ficco::hw::Machine::mi300x_8();
    let t0 = Instant::now();
    let exhibit = ficco::metrics::fig14_comparison(&machine);
    let dt = t0.elapsed();
    exhibit.print();
    let _ = exhibit.table.write_csv("results/fig14_comparison.csv");
    println!("[bench] fig14_comparison generated in {dt:?} -> results/fig14_comparison.csv");
}
