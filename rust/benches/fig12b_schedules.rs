//! Bench: regenerates the paper's Fig 12b on the modelled 8x MI300X
//! machine and reports wall time. Run: `cargo bench --bench fig12b_schedules`.
use std::time::Instant;

fn main() {
    let machine = ficco::hw::Machine::mi300x_8();
    let t0 = Instant::now();
    let exhibit = ficco::metrics::fig12b_schedules(&machine);
    let dt = t0.elapsed();
    exhibit.print();
    let _ = exhibit.table.write_csv("results/fig12b_schedules.csv");
    println!("[bench] fig12b_schedules generated in {dt:?} -> results/fig12b_schedules.csv");
}
