//! Bench: regenerates the paper's Fig 13 on the modelled 8x MI300X
//! machine and reports wall time. Run: `cargo bench --bench fig13_shard_overlap`.
use std::time::Instant;

fn main() {
    let machine = ficco::hw::Machine::mi300x_8();
    let t0 = Instant::now();
    let exhibit = ficco::metrics::fig13_shard_overlap(&machine);
    let dt = t0.elapsed();
    exhibit.print();
    let _ = exhibit.table.write_csv("results/fig13_shard_overlap.csv");
    println!("[bench] fig13_shard_overlap generated in {dt:?} -> results/fig13_shard_overlap.csv");
}
