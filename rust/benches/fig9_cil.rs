//! Bench: regenerates the paper's Fig 9 on the modelled 8x MI300X
//! machine and reports wall time. Run: `cargo bench --bench fig9_cil`.
use std::time::Instant;

fn main() {
    let machine = ficco::hw::Machine::mi300x_8();
    let t0 = Instant::now();
    let exhibit = ficco::metrics::fig9_cil(&machine);
    let dt = t0.elapsed();
    exhibit.print();
    let _ = exhibit.table.write_csv("results/fig9_cil.csv");
    println!("[bench] fig9_cil generated in {dt:?} -> results/fig9_cil.csv");
}
