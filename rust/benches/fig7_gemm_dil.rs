//! Bench: regenerates the paper's Fig 7 on the modelled 8x MI300X
//! machine and reports wall time. Run: `cargo bench --bench fig7_gemm_dil`.
use std::time::Instant;

fn main() {
    let machine = ficco::hw::Machine::mi300x_8();
    let t0 = Instant::now();
    let exhibit = ficco::metrics::fig7_gemm_dil(&machine);
    let dt = t0.elapsed();
    exhibit.print();
    let _ = exhibit.table.write_csv("results/fig7_gemm_dil.csv");
    println!("[bench] fig7_gemm_dil generated in {dt:?} -> results/fig7_gemm_dil.csv");
}
